"""Comparison compressors for the paper's tables (DESIGN.md section 8.2).

The paper compares against SZ and ZFP (and Zstd lossless).  cuSZ/cuZFP and
the real C codebases are out of scope offline, so we implement faithful
*algorithmic* counterparts whose cost/ratio structure matches:

  sz-lite  -- 1D Lorenzo prediction + error-controlled linear quantization
              (quantization_bin = round(pred_err / 2e) exactly as SZ 1.4/2.x)
              + zlib entropy stage, with verbatim fallback for unpredictable
              points.  Error-bounded.
  zfp-lite -- block transform coder: 64-value blocks, fixed-point alignment
              to the block exponent, reversible lifted transform (ZFP's
              decorrelation step in 1D), bit-plane truncation by error bound
              + zlib.  Error-bounded (conservative).
  zlib     -- lossless byte-stream baseline (stands in for Zstd, which is
              not installed offline; relationship CR_lossless << CR_lossy is
              what the table demonstrates).

Both lossy baselines intentionally use multiplies/divisions and a real
entropy stage -- the paper's point is exactly that SZx avoids those and is
therefore much faster at somewhat lower ratio.
"""
from __future__ import annotations

import struct
import zlib

import numpy as np


# ---------------------------------------------------------------------------
# sz-lite
# ---------------------------------------------------------------------------

def sz_lite_compress(x: np.ndarray, e: float) -> bytes:
    x = np.asarray(x, np.float32).reshape(-1)
    if e <= 0:
        raise ValueError("error bound must be positive")
    # Lorenzo-1D prediction with error-controlled quantization.  The SZ
    # recurrence recon[i] = recon[i-1] + 2e*round((x[i]-recon[i-1])/2e) with
    # an unbounded quantizer has the closed form recon[i] = 2e*round(x[i]/2e)
    # (round(a-k)+k == round(a) for integer k), so the quantization codes are
    # simply diffs of the rounded values -- exact, vectorized, |x-x'| <= e.
    two_e = 2.0 * float(e)
    n = x.size
    m = np.round(x.astype(np.float64) / two_e).astype(np.int64)
    q = np.diff(m, prepend=np.int64(0))
    small = np.abs(q) < 32768
    codes = np.where(small, q, 0).astype(np.int16)
    outliers = q[~small].astype(np.int64)
    out_idx = np.nonzero(~small)[0].astype(np.int64)
    payload = (
        struct.pack("<QdQ", n, e, out_idx.size)
        + zlib.compress(codes.tobytes(), 6)
    )
    return payload + out_idx.tobytes() + outliers.tobytes()


def sz_lite_decompress(buf: bytes) -> np.ndarray:
    n, e, n_out = struct.unpack_from("<QdQ", buf, 0)
    off = 24
    tail = 16 * n_out
    codes = np.frombuffer(
        zlib.decompress(buf[off : len(buf) - tail]), np.int16
    ).astype(np.int64)
    if n_out:
        out_idx = np.frombuffer(buf, np.int64, n_out, len(buf) - tail)
        outliers = np.frombuffer(buf, np.int64, n_out, len(buf) - 8 * n_out)
        codes = codes.copy()
        codes[out_idx] = outliers
    return (np.cumsum(codes) * (2.0 * e)).astype(np.float32)


# ---------------------------------------------------------------------------
# zfp-lite
# ---------------------------------------------------------------------------

_ZBS = 64


def _fwd_lift(v):
    """ZFP's reversible 1D lift (on int64 blocks of 4)."""
    x, y, z, w = v[..., 0], v[..., 1], v[..., 2], v[..., 3]
    x = x + w; x >>= 1; w = w - x
    z = z + y; z >>= 1; y = y - z
    x = x + z; x >>= 1; z = z - x
    w = w + y; w >>= 1; y = y - w
    w = w + (y >> 1); y = y - (w >> 1)
    return np.stack([x, y, z, w], axis=-1)


def _inv_lift(v):
    x, y, z, w = (v[..., i].copy() for i in range(4))
    y = y + (w >> 1); w = w - (y >> 1)
    y = y + w; w <<= 1; w = w - y
    z = z + x; x <<= 1; x = x - z
    y = y + z; z <<= 1; z = z - y
    w = w + x; x <<= 1; x = x - w
    return np.stack([x, y, z, w], axis=-1)


def zfp_lite_compress(x: np.ndarray, e: float) -> bytes:
    x = np.asarray(x, np.float32).reshape(-1)
    n = x.size
    pad = (-n) % _ZBS
    xp = np.pad(x, (0, pad))
    xb = xp.reshape(-1, _ZBS).astype(np.float64)
    emax = np.frexp(np.maximum(np.abs(xb).max(axis=1), 1e-300))[1]  # block exp
    scale = np.ldexp(1.0, 30 - emax)[:, None]
    q = np.round(xb * scale).astype(np.int64)                # fixed point
    t = _fwd_lift(q.reshape(-1, _ZBS // 4, 4)).reshape(-1, _ZBS)
    # keep bit planes down to the error bound: tolerance in fixed-point units
    tol = np.maximum((e * scale[:, 0] / 4.0), 1.0)           # conservative /4
    shift = np.floor(np.log2(tol)).astype(np.int64)
    shift = np.maximum(shift, 0)
    tq = (t >> shift[:, None]).astype(np.int32)
    payload = zlib.compress(tq.astype(np.int32).tobytes(), 6)
    hdr = struct.pack("<QdQ", n, e, xb.shape[0])
    return hdr + emax.astype(np.int16).tobytes() + shift.astype(np.int8).tobytes() + payload


def zfp_lite_decompress(buf: bytes) -> np.ndarray:
    n, e, nb = struct.unpack_from("<QdQ", buf, 0)
    off = 24
    emax = np.frombuffer(buf, np.int16, nb, off).astype(np.int64)
    off += 2 * nb
    shift = np.frombuffer(buf, np.int8, nb, off).astype(np.int64)
    off += nb
    tq = np.frombuffer(zlib.decompress(buf[off:]), np.int32).astype(np.int64)
    t = tq.reshape(nb, _ZBS) << shift[:, None]
    q = _inv_lift(t.reshape(-1, _ZBS // 4, 4)).reshape(nb, _ZBS)
    xb = q.astype(np.float64) * np.ldexp(1.0, emax - 30)[:, None]
    return xb.reshape(-1)[:n].astype(np.float32)


# ---------------------------------------------------------------------------
# lossless baseline
# ---------------------------------------------------------------------------

def zlib_compress(x: np.ndarray) -> bytes:
    return zlib.compress(np.asarray(x, np.float32).tobytes(), 6)


def zlib_decompress(buf: bytes) -> np.ndarray:
    return np.frombuffer(zlib.decompress(buf), np.float32)
