"""CI perf-regression gate for the codec benchmarks.

Compares a freshly generated ``chunked_dump_load`` JSON (``benchmarks.run
chunked_dump_load`` with ``SZX_BENCH_JSON`` pointing somewhere disposable)
against a committed baseline and exits non-zero if, for any kind present in
the baseline:

  * compression or decompression throughput dropped more than ``--max-drop``
    (default 30%), or
  * the compression ratio drifted more than ``--max-cr-drift`` (default 1%)
    in either direction.

The ``ingest_windowed`` row additionally carries absolute acceptance gates:
bytes_read_ratio must stay < 0.2, and on hosts with >=2 cpus the pipelined
loader must be >=1.5x the serial one (samples/sec).

The ``second_stage_frontier`` summary (stage-off vs each lossless second
stage at a pinned abs bound) is gated absolutely, not against the baseline:
at least one stage must deliver >=1.5x CR over stage-off while keeping
both comp and decomp throughput at >=70% of stage-off (the "<30% cost"
frontier claim), and per-frame negotiation means no stage may ever LOSE
ratio (cr_gain >= 0.999 for every row).

The ``telemetry_overhead`` summary is gated absolutely too: enabling
``SZX_OBS`` must cost <3% on both the chunked compress and decompress
paths.

CR depends on the synthetic input length, so the two files must have been
produced at the same ``n``; a mismatch is an error (regenerate the baseline
with the same ``SZX_BENCH_N``).

Usage (what .github/workflows/ci.yml runs):

    SZX_BENCH_N=4194304 SZX_BENCH_JSON=fresh.json \
        python -m benchmarks.run chunked_dump_load
    python -m benchmarks.check_regression \
        --baseline benchmarks/BENCH_codec_smoke.json --fresh fresh.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(REPO_ROOT, "BENCH_codec.json")
THROUGHPUT_KEYS = ("comp_mbs", "decomp_mbs")
# summary sections holding per-kind sub-dicts: excluded from the generic
# per-kind throughput/CR comparison, gated by their own absolute checks
SUMMARY_KEYS = frozenset({"second_stage_frontier", "telemetry_overhead"})
MAX_TELEMETRY_OVERHEAD = 0.03


def compare(baseline: dict, fresh: dict, *, max_drop: float, max_cr_drift: float) -> list[str]:
    """Return a list of human-readable regression messages (empty = pass)."""
    errors: list[str] = []
    base = baseline.get("chunked_dump_load", {})
    new = fresh.get("chunked_dump_load", {})
    if not base:
        return ["baseline has no chunked_dump_load section"]
    if not new:
        return ["fresh results have no chunked_dump_load section"]
    if base.get("n") != new.get("n"):
        return [
            f"input size mismatch: baseline n={base.get('n')}, fresh "
            f"n={new.get('n')} (regenerate the baseline at this SZX_BENCH_N)"
        ]
    kinds = [k for k, v in base.items()
             if isinstance(v, dict) and k not in SUMMARY_KEYS]
    if not kinds:
        return ["baseline chunked_dump_load section has no benchmark kinds"]
    # a fresh row with no committed counterpart means the baseline predates
    # the benchmark: a silent pass here would let the new row drift unchecked
    for kind in (k for k, v in new.items()
                 if isinstance(v, dict) and k not in SUMMARY_KEYS):
        if kind not in base:
            errors.append(
                f"baseline missing row {kind} -- regenerate "
                "BENCH_codec_smoke.json (SZX_BENCH_N-matched "
                "`python -m benchmarks.run chunked_dump_load`) so the new "
                "row is gated too"
            )
    for kind in kinds:
        got = new.get(kind)
        if not isinstance(got, dict):
            errors.append(f"{kind}: missing from fresh results")
            continue
        for key in THROUGHPUT_KEYS + ("cr",):
            missing = [side for side, row in (("baseline", base[kind]), ("fresh", got))
                       if key not in row]
            if missing:
                errors.append(
                    f"{kind}.{key}: missing from {' and '.join(missing)} results"
                )
        if any(e.startswith(f"{kind}.") and "missing" in e for e in errors):
            continue
        for key in THROUGHPUT_KEYS:
            b, f = float(base[kind][key]), float(got[key])
            if f < b * (1.0 - max_drop):
                errors.append(
                    f"{kind}.{key}: {f:.1f} MB/s is more than "
                    f"{max_drop:.0%} below the baseline {b:.1f} MB/s"
                )
        b_cr, f_cr = float(base[kind]["cr"]), float(got["cr"])
        if abs(f_cr - b_cr) > max_cr_drift * b_cr:
            errors.append(
                f"{kind}.cr: {f_cr:.4f} drifted more than "
                f"{max_cr_drift:.0%} from the baseline {b_cr:.4f}"
            )
    errors.extend(_check_ingest(new.get("ingest_windowed")))
    errors.extend(_check_second_stage(new.get("second_stage_frontier")))
    errors.extend(_check_telemetry(new.get("telemetry_overhead")))
    if ("second_stage_frontier" in new
            and "second_stage_frontier" not in base):
        errors.append(
            "baseline missing second_stage_frontier -- regenerate the "
            "baseline so the frontier rows are pinned too"
        )
    return errors


def _check_ingest(row: dict | None) -> list[str]:
    """Absolute acceptance gates for the streaming-ingest row.

    bytes_read_ratio < 0.2 always (a windowed epoch touching <=10% of the
    store must not read a fifth of the file); pipeline_speedup >= 1.5 only
    when the host can actually overlap (>=2 cpus and >=2 ingest workers) --
    single-core runners can't show the win, so the gate is skipped there.
    """
    if not isinstance(row, dict):
        return []
    errors: list[str] = []
    ratio = row.get("bytes_read_ratio")
    if ratio is None:
        errors.append("ingest_windowed.bytes_read_ratio: missing from fresh results")
    elif float(ratio) >= 0.2:
        errors.append(
            f"ingest_windowed.bytes_read_ratio: {float(ratio):.4f} is not "
            "< 0.2 (windowed epoch reads must scale with the windows, "
            "not the store)"
        )
    cpus = int(row.get("cpus", 1))
    workers = int(row.get("ingest_workers", 1))
    if cpus >= 2 and workers >= 2:
        speedup = row.get("pipeline_speedup")
        if speedup is None:
            errors.append(
                "ingest_windowed.pipeline_speedup: missing from fresh results"
            )
        elif float(speedup) < 1.5:
            errors.append(
                f"ingest_windowed.pipeline_speedup: {float(speedup):.2f}x is "
                f"below the 1.5x floor (workers={workers}, cpus={cpus})"
            )
    return errors


def _check_second_stage(frontier: dict | None) -> list[str]:
    """Absolute acceptance gates for the second-stage speed/ratio frontier.

    The frontier claim is a point, not a trend, so the gates are absolute:
    some stage must buy >=1.5x CR at >=0.70x of stage-off throughput both
    ways, and per-frame negotiation means no stage may shrink the ratio.
    """
    if not isinstance(frontier, dict):
        return ["fresh results have no second_stage_frontier section"]
    if "stage-off" not in frontier:
        return ["second_stage_frontier: missing the stage-off reference row"]
    errors: list[str] = []
    frontier_hit = False
    for kind, row in frontier.items():
        if kind == "stage-off":
            continue
        try:
            gain = float(row["cr_gain"])
            comp = float(row["comp_rel"])
            decomp = float(row["decomp_rel"])
        except (KeyError, TypeError, ValueError):
            errors.append(
                f"second_stage_frontier.{kind}: cr_gain/comp_rel/decomp_rel "
                "missing or non-numeric"
            )
            continue
        if gain < 0.999:
            errors.append(
                f"second_stage_frontier.{kind}: cr_gain {gain:.3f} < 1 -- "
                "per-frame negotiation must never lose ratio"
            )
        if gain >= 1.5 and comp >= 0.70 and decomp >= 0.70:
            frontier_hit = True
    if not errors and not frontier_hit:
        rows = "; ".join(
            f"{k}: gain={v.get('cr_gain', 0):.2f}x comp={v.get('comp_rel', 0):.2f} "
            f"decomp={v.get('decomp_rel', 0):.2f}"
            for k, v in frontier.items() if k != "stage-off"
        )
        errors.append(
            "second_stage_frontier: no stage reaches >=1.5x CR at >=0.70x "
            f"stage-off throughput ({rows})"
        )
    return errors


def _check_telemetry(row: dict | None) -> list[str]:
    """Absolute gate for the telemetry-overhead row: with SZX_OBS on, the
    chunked compress AND decompress paths must stay within
    ``MAX_TELEMETRY_OVERHEAD`` (3%) of the telemetry-off throughput.  The
    near-zero-cost-when-disabled claim is structural (span() returns a shared
    null object before any allocation), so only the enabled cost is gated."""
    if not isinstance(row, dict):
        return ["fresh results have no telemetry_overhead section"]
    errors: list[str] = []
    for key in ("comp_overhead", "decomp_overhead"):
        v = row.get(key)
        if v is None:
            errors.append(f"telemetry_overhead.{key}: missing from fresh results")
        elif float(v) > MAX_TELEMETRY_OVERHEAD:
            errors.append(
                f"telemetry_overhead.{key}: {float(v):.2%} exceeds the "
                f"{MAX_TELEMETRY_OVERHEAD:.0%} ceiling (SZX_OBS must stay "
                "near-free on the hot paths)"
            )
    return errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed BENCH JSON to compare against")
    ap.add_argument("--fresh", required=True,
                    help="freshly generated BENCH JSON (SZX_BENCH_JSON output)")
    ap.add_argument("--max-drop", type=float, default=0.30,
                    help="max tolerated fractional throughput drop (default 0.30)")
    ap.add_argument("--max-cr-drift", type=float, default=0.01,
                    help="max tolerated fractional CR drift (default 0.01)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    errors = compare(
        baseline, fresh, max_drop=args.max_drop, max_cr_drift=args.max_cr_drift
    )
    for msg in errors:
        print(f"REGRESSION: {msg}", file=sys.stderr)
    if errors:
        return 1
    kinds = [k for k, v in fresh["chunked_dump_load"].items() if isinstance(v, dict)]
    print(f"perf gate OK: {', '.join(kinds)} within {args.max_drop:.0%} "
          f"throughput / {args.max_cr_drift:.0%} CR of {args.baseline}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
