"""Render the second-stage speed/ratio frontier plot for the README.

Reads the ``second_stage_frontier`` section of a BENCH JSON (the committed
``BENCH_codec.json`` by default) and writes a two-panel scatter --
compression ratio vs compress / decompress throughput, one point per stage
-- to ``docs/frontier.png``.

    PYTHONPATH=src python -m benchmarks.plot_frontier \
        [--bench BENCH_codec.json] [--out docs/frontier.png]
"""
from __future__ import annotations

import argparse
import json
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_LABELS = {
    "stage-off": "stage off",
    "stage-rle": "bitshuffle-rle",
    "stage-deflate": "deflate",
    "stage-zstd": "bitshuffle-zstd",
}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default=os.path.join(REPO_ROOT, "BENCH_codec.json"))
    ap.add_argument("--out", default=os.path.join(REPO_ROOT, "docs", "frontier.png"))
    args = ap.parse_args(argv)

    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    with open(args.bench) as f:
        bench = json.load(f)
    frontier = bench["chunked_dump_load"].get("second_stage_frontier")
    if not frontier:
        raise SystemExit(f"{args.bench} has no second_stage_frontier section "
                         "(regenerate with `python -m benchmarks.run "
                         "chunked_dump_load`)")

    fig, axes = plt.subplots(1, 2, figsize=(9, 3.6), sharey=True)
    for ax, key, title in (
        (axes[0], "comp_mbs", "compress"),
        (axes[1], "decomp_mbs", "decompress"),
    ):
        for kind, row in frontier.items():
            marker = "o" if kind == "stage-off" else "D"
            ax.scatter(row[key], row["cr"], s=70, marker=marker, zorder=3,
                       label=_LABELS.get(kind, kind))
            ax.annotate(
                f"  {_LABELS.get(kind, kind)}\n  CR {row['cr']:.2f}",
                (row[key], row["cr"]), fontsize=8, va="center",
            )
        ax.set_xlabel(f"{title} MB/s")
        ax.set_xlim(left=0)
        ax.grid(alpha=0.3)
    axes[0].set_ylabel("compression ratio")
    off = frontier.get("stage-off", {})
    fig.suptitle(
        "Second-stage speed/ratio frontier "
        f"(n={bench['chunked_dump_load'].get('n')}, pinned abs bound; "
        f"stage-off CR {off.get('cr', 0):.2f})",
        fontsize=10,
    )
    fig.tight_layout()
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    fig.savefig(args.out, dpi=110)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
