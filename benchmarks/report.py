"""Render the dry-run roofline table (markdown) from benchmarks/out/dryrun/.

    PYTHONPATH=src:. python -m benchmarks.report [--mesh single|multi|both]
"""
import argparse
import glob
import json
import os


def rows_for(mesh_tag: str):
    out = []
    for f in sorted(glob.glob(os.path.join("benchmarks/out/dryrun", f"*.{mesh_tag}.json"))):
        r = json.load(open(f))
        if r["status"] == "SKIP":
            out.append((r["arch"], r["shape"], "SKIP", r.get("reason", "")))
            continue
        if r["status"] != "OK":
            out.append((r["arch"], r["shape"], "FAIL", r.get("error", "")[:60]))
            continue
        rl = r["roofline"]
        frac = rl.get("floor_fraction", rl["roofline_fraction"])
        out.append((
            r["arch"], r["shape"], "OK",
            dict(
                t_c=rl["t_compute_s"], t_m=rl["t_memory_s"], t_x=rl["t_collective_s"],
                bneck=rl["bottleneck"], frac=frac, useful=rl["useful_flops_ratio"],
                compile_s=r["compile_s"],
                temp_gb=r["memory"].get("temp_size_in_bytes", 0) / 1e9,
                args_gb=r["memory"].get("argument_size_in_bytes", 0) / 1e9,
            ),
        ))
    return out


def render(mesh_tag: str) -> str:
    lines = [
        f"### Mesh: {'16x16 (256 chips)' if mesh_tag == 'single' else '2x16x16 (512 chips)'}",
        "",
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | bottleneck |"
        " roofline frac | useful FLOPs | HBM args+temp (GB/dev) | compile (s) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch, shape, status, d in rows_for(mesh_tag):
        if status == "SKIP":
            lines.append(f"| {arch} | {shape} | - | - | - | SKIP ({d}) | - | - | - | - |")
        elif status == "FAIL":
            lines.append(f"| {arch} | {shape} | - | - | - | FAIL: {d} | - | - | - | - |")
        else:
            lines.append(
                f"| {arch} | {shape} | {d['t_c']:.3f} | {d['t_m']:.3f} | {d['t_x']:.3f} "
                f"| {d['bneck']} | {d['frac']:.3f} | {d['useful']:.2f} "
                f"| {d['args_gb']:.1f}+{d['temp_gb']:.1f} | {d['compile_s']:.0f} |"
            )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    args = ap.parse_args()
    tags = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for t in tags:
        print(render(t))
        print()


if __name__ == "__main__":
    main()
