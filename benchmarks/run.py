"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes detailed JSON under
benchmarks/out/.  Datasets are the synthetic scientific fields from
repro.data.scidata (SDRBench is offline-unavailable; DESIGN.md section 8.3).

  table3_compression_ratio   -- Table III: min/overall/max CR per app x REL
                                for szx / zfp-lite / sz-lite / zlib
  table4_compression_speed   -- Table IV: compression MB/s per app
  table5_decompression_speed -- Table V: decompression MB/s per app
  fig2_block_range_cdf       -- Fig 2: CDF of block relative value range
  fig6_shift_overhead        -- Fig 6: Solution-C byte-alignment overhead
  fig8_block_size            -- Fig 8: CR + PSNR vs block size
  fig10_quality              -- Fig 10: PSNR/SSIM at REL 1e-2..1e-4
  fig13_dump_load            -- Fig 13: compress+write / read+decompress wall
                                time vs raw I/O
  beyond_planes_codec        -- szx-planes (in-graph) throughput + wire bytes
                                for gradient/KV compression
  chunked_dump_load          -- monolithic vs chunked vs parallel-chunked
                                (frame-streamed) compression: throughput +
                                peak RSS; writes BENCH_codec.json at the repo
                                root (SZX_BENCH_N / SZX_BENCH_JSON override
                                input size / output path; CI runs this small
                                and gates via benchmarks/check_regression.py)

Run everything: ``PYTHONPATH=src python -m benchmarks.run``
Run a subset:   ``PYTHONPATH=src python -m benchmarks.run chunked_dump_load``
(must run as ``-m`` from the repo root so the ``benchmarks`` package imports)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks import baselines as B
from repro.core import metrics
from repro.core.codec import SZxCodec
from repro.data import scidata

OUT = os.path.join(os.path.dirname(__file__), "out")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RELS = (1e-2, 1e-3, 1e-4)
_SZX = SZxCodec(backend="numpy")
CODECS = {
    "szx": (_SZX.compress, _SZX.decompress),
    "zfp-lite": (B.zfp_lite_compress, B.zfp_lite_decompress),
    "sz-lite": (B.sz_lite_compress, B.sz_lite_decompress),
}

_rows: list[str] = []


def _emit(name: str, us: float, derived: str):
    row = f"{name},{us:.1f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def _apps():
    for app in scidata.APPLICATIONS:
        yield app, list(scidata.fields(app))


def table3_compression_ratio() -> dict:
    out: dict = {}
    for app, flds in _apps():
        for rel in RELS:
            for cname, (comp, _) in CODECS.items():
                t0 = time.time()
                crs = []
                for _, x in flds:
                    e = rel * float(x.max() - x.min())
                    crs.append(x.nbytes / len(comp(x, e)))
                hmean = len(crs) / sum(1.0 / c for c in crs)
                out[f"{app}|{rel:g}|{cname}"] = dict(
                    min=min(crs), overall=hmean, max=max(crs)
                )
                _emit(
                    f"table3/{app}/{rel:g}/{cname}",
                    (time.time() - t0) * 1e6,
                    f"CR_min={min(crs):.2f};CR={hmean:.2f};CR_max={max(crs):.2f}",
                )
            # lossless reference once per app
        crs = [x.nbytes / len(B.zlib_compress(x)) for _, x in flds]
        out[f"{app}|zlib"] = dict(overall=len(crs) / sum(1 / c for c in crs))
        _emit(f"table3/{app}/zlib", 0.0, f"CR={out[f'{app}|zlib']['overall']:.2f}")
    return out


def _throughput(direction: str) -> dict:
    out: dict = {}
    for app, flds in _apps():
        data = [x for _, x in flds]
        total_bytes = sum(x.nbytes for x in data)
        for cname, (comp, dec) in CODECS.items():
            rel = 1e-3
            bufs = []
            t0 = time.time()
            for x in data:
                e = rel * float(x.max() - x.min())
                bufs.append(comp(x, e))
            t_comp = time.time() - t0
            t0 = time.time()
            for b in bufs:
                dec(b)
            t_dec = time.time() - t0
            t = t_comp if direction == "comp" else t_dec
            mbs = total_bytes / 1e6 / max(t, 1e-9)
            out[f"{app}|{cname}"] = mbs
            _emit(f"table{'4' if direction=='comp' else '5'}/{app}/{cname}",
                  t * 1e6, f"MB/s={mbs:.0f}")
    return out


def table4_compression_speed() -> dict:
    return _throughput("comp")


def table5_decompression_speed() -> dict:
    return _throughput("dec")


def fig2_block_range_cdf() -> dict:
    out = {}
    for app, flds in _apps():
        cdf = np.mean([scidata.block_relative_range_cdf(x) for _, x in flds], axis=0)
        # fraction of size-8 blocks with relative range <= 0.01 (paper quotes
        # 80%+ for Miranda/QMCPack)
        t = np.logspace(-6, 0, 25)
        frac_001 = float(np.interp(0.01, t, cdf))
        out[app] = dict(cdf=cdf.tolist(), frac_le_001=frac_001)
        _emit(f"fig2/{app}", 0.0, f"frac_blocks_relrange<=0.01={frac_001:.2f}")
    return out


def fig6_shift_overhead() -> dict:
    """Solution C (byte-aligned, shift s) vs Solution B (bit-granular)."""
    from repro.core.codec import plan as codec_plan
    from repro.kernels import ops

    out = {}
    for app in ("Miranda", "NYX"):
        for rel in RELS:
            tot_c = tot_b = comp_bytes = 0
            for _, x in scidata.fields(app):
                p, xt = codec_plan.make_plan(
                    x, codec_plan.Bound.rel(rel), block_size=128, backend="numpy"
                )
                e = p.error_bound
                xb = codec_plan.to_blocks(xt, p)
                mu, rad, const, reqlen, shift, nbytes = [
                    np.asarray(a) for a in ops.block_stats(xb, e, backend="numpy")
                ]
                planes, L, mid = [
                    np.asarray(a) for a in ops.pack(xb, mu, shift, nbytes, backend="numpy")
                ]
                nc = ~const
                # Solution C: whole bytes, L' leading bytes elided
                bits_c = int(mid[nc].sum()) * 8
                # Solution B: reqlen bits minus leading bytes of the
                # UNSHIFTED word (bit-granular storage, Formula 6)
                _, L0, _ = [
                    np.asarray(a)
                    for a in ops.pack(xb, mu, np.zeros_like(shift), nbytes, backend="numpy")
                ]
                bits_b = int((reqlen[nc][:, None] - 8 * L0[nc]).clip(min=0).sum())
                tot_c += bits_c
                tot_b += bits_b
                comp_bytes += len(_SZX.compress(x, e))
            ovh = (tot_c - tot_b) / 8.0 / comp_bytes
            out[f"{app}|{rel:g}"] = ovh
            _emit(f"fig6/{app}/{rel:g}", 0.0, f"overhead={ovh*100:.2f}%")
    return out


def fig8_block_size() -> dict:
    out = {}
    flds = list(scidata.fields("Miranda"))
    for rel in (1e-3, 1e-4):
        for bs in (8, 16, 32, 64, 128, 256):
            crs, psnrs = [], []
            codec = SZxCodec(block_size=bs, backend="numpy")
            for _, x in flds:
                e = rel * float(x.max() - x.min())
                buf = codec.compress(x, e)
                y = codec.decompress(buf).reshape(-1)
                crs.append(x.nbytes / len(buf))
                psnrs.append(metrics.psnr(x, y))
            hm = len(crs) / sum(1 / c for c in crs)
            out[f"{rel:g}|{bs}"] = dict(cr=hm, psnr=float(np.mean(psnrs)))
            _emit(f"fig8/bs={bs}/{rel:g}", 0.0,
                  f"CR={hm:.2f};PSNR={np.mean(psnrs):.1f}")
    return out


def fig10_quality() -> dict:
    out = {}
    name, x = next(iter(scidata.fields("Hurricane")))
    for rel in RELS:
        e = rel * float(x.max() - x.min())
        y = _SZX.decompress(_SZX.compress(x, e)).reshape(x.shape)
        out[f"{rel:g}"] = dict(
            psnr=metrics.psnr(x, y), ssim=metrics.ssim(x, y),
            maxerr_over_e=float(np.abs(x - y).max() / e),
        )
        _emit(f"fig10/{rel:g}", 0.0,
              f"PSNR={out[f'{rel:g}']['psnr']:.1f};SSIM={out[f'{rel:g}']['ssim']:.4f}")
    return out


def fig13_dump_load(tmpdir: str = "/tmp/repro_io") -> dict:
    os.makedirs(tmpdir, exist_ok=True)
    data = [x for _, x in scidata.fields("NYX")]
    total = sum(x.nbytes for x in data)
    out = {}
    for rel in (1e-2, 1e-3):
        # dump: compress + write vs raw write
        t0 = time.time()
        paths = []
        for i, x in enumerate(data):
            e = rel * float(x.max() - x.min())
            buf = _SZX.compress(x, e)
            p = os.path.join(tmpdir, f"c{i}.szx")
            with open(p, "wb") as f:
                f.write(buf)
            paths.append(p)
        os.sync()
        t_comp_dump = time.time() - t0
        t0 = time.time()
        for i, x in enumerate(data):
            with open(os.path.join(tmpdir, f"r{i}.raw"), "wb") as f:
                f.write(x.tobytes())
        os.sync()
        t_raw_dump = time.time() - t0
        # load: read + decompress vs raw read
        t0 = time.time()
        for p in paths:
            with open(p, "rb") as f:
                _SZX.decompress(f.read())
        t_comp_load = time.time() - t0
        t0 = time.time()
        for i in range(len(data)):
            with open(os.path.join(tmpdir, f"r{i}.raw"), "rb") as f:
                np.frombuffer(f.read(), np.float32)
        t_raw_load = time.time() - t0
        comp_total = sum(os.path.getsize(p) for p in paths)
        # Modeled contended-PFS regime (the paper's Fig 13 runs 64-1024 MPI
        # ranks against one parallel FS; per-rank effective bandwidth is
        # ~100-250 MB/s).  This container's tmpfs is faster than its single
        # 20 MB/s core, inverting the paper's regime, so we report both the
        # raw local measurement and the modeled-PFS speedup with measured
        # compression times and ratios.
        cr = total / comp_total
        t_cpu_dump = t_comp_dump            # measured compress+write time
        t_cpu_load = t_comp_load
        modeled = {}
        # 25 MB/s == 1024 ranks contending a ~25 GB/s PFS (paper Fig 13 scale)
        for bw in (25e6, 100e6, 250e6):
            dump = (total / bw) / (t_cpu_dump + comp_total / bw)
            load = (total / bw) / (t_cpu_load + comp_total / bw)
            modeled[f"{bw/1e6:.0f}MBps"] = dict(dump=dump, load=load)
        out[f"{rel:g}"] = dict(
            dump_speedup_local=t_raw_dump / t_comp_dump,
            load_speedup_local=t_raw_load / t_comp_load,
            cr=cr,
            modeled=modeled,
            mb=total / 1e6,
        )
        m250 = modeled["250MBps"]
        _emit(f"fig13/{rel:g}", t_comp_dump * 1e6,
              f"local_dump={t_raw_dump/t_comp_dump:.2f};"
              f"pfs250_dump={m250['dump']:.2f};pfs250_load={m250['load']:.2f};CR={cr:.1f}")
    return out


def beyond_planes_codec() -> dict:
    """szx-planes in-graph codec: throughput + wire bytes (grad/KV use)."""
    import jax
    import jax.numpy as jnp

    from repro.core import planes as cp

    out = {}
    x = np.cumsum(
        np.random.default_rng(0).standard_normal(1 << 22), 0
    ).astype(np.float32)
    xj = jnp.asarray(x)
    for p in (1, 2):
        enc_fn = jax.jit(lambda v, p=p: cp.encode(v, num_planes=p))
        # n/block_size are static fields; close over them so jit only traces
        # the array leaves
        dec_fn = jax.jit(
            lambda mu, sexp, planes: cp.decode(
                cp.PlanesEncoded(mu, sexp, planes, x.size, cp.DEFAULT_BLOCK_SIZE),
                shape=x.shape,
            )
        )
        dec_call = lambda e: dec_fn(e.mu, e.sexp, e.planes)  # noqa: E731
        enc = enc_fn(xj)
        jax.block_until_ready(enc.planes)
        t0 = time.time()
        for _ in range(5):
            enc = enc_fn(xj)
            jax.block_until_ready(enc.planes)
        t_enc = (time.time() - t0) / 5
        y = dec_call(enc)
        jax.block_until_ready(y)
        t0 = time.time()
        for _ in range(5):
            jax.block_until_ready(dec_call(enc))
        t_dec = (time.time() - t0) / 5
        wire = cp.wire_bytes(enc)
        err = float(jnp.abs(xj - dec_call(enc)).max())
        out[f"P{p}"] = dict(
            enc_mbs=x.nbytes / 1e6 / t_enc,
            dec_mbs=x.nbytes / 1e6 / t_dec,
            wire_ratio=x.nbytes / wire,
            max_err=err,
        )
        _emit(f"beyond/planes/P{p}", t_enc * 1e6,
              f"enc_MB/s={x.nbytes/1e6/t_enc:.0f};dec_MB/s={x.nbytes/1e6/t_dec:.0f};"
              f"wire_ratio={x.nbytes/wire:.2f}")
    return out


_CHUNKED_CHILD = r"""
import json, os, resource, sys, time
import numpy as np
import ml_dtypes
from repro.core.codec import Bound, SZxCodec

mode, path = sys.argv[1], sys.argv[2]
kind, phase = mode.rsplit("_", 1)
n = int(os.environ.get("SZX_BENCH_N", 1 << 26))   # f32-equivalent elem count
# the dtype legs keep the BYTE volume constant (n * 4) so throughputs are
# comparable across rows: n_elems = n * 4 / itemsize
if kind.endswith("-f64"):
    dtype = np.dtype(np.float64)
elif kind.endswith("-bf16"):
    dtype = np.dtype(ml_dtypes.bfloat16)
else:
    dtype = np.dtype(np.float32)
n_elems = n * 4 // dtype.itemsize
workers = (os.cpu_count() or 1) if kind == "chunked-par" else 1
# chunked-dev-decode: the SAME frame pipeline on the device backend --
# encode_to_stream on dump, decode_stream on load (one transfer per chunk,
# on-device container parse + fused unpack+compose)
backend = "jax" if kind == "chunked-dev-decode" else "numpy"
# stage-* kinds: the negotiated lossless second stage over the mid bytes
second_stage = None
if kind.startswith("stage-"):
    second_stage = {"off": None, "rle": "bitshuffle-rle",
                    "deflate": "deflate", "zstd": "bitshuffle-zstd",
                    }[kind.split("-", 1)[1]]
codec = SZxCodec(backend=backend, workers=workers, stage=second_stage)
rel = 1e-3


def make_tree(x):
    # checkpoint-shaped pytree over the same bytes: 4 big float leaves plus
    # small integer leaves that ride in the shared raw pack frame
    q = x.size // 4
    return {
        "layers": {f"w{i}": x[i * q : (i + 1) * q] for i in range(4)},
        "step": np.int64(7),
        "opt": {"count": np.arange(64, dtype=np.int32)},
    }


if kind == "tree_checkpoint":
    from repro.core.codec import TreeCodec

    tree_codec = TreeCodec(
        codec=codec, bound=Bound.rel(rel), chunk_bytes=8 << 20
    )


class CountingFile:
    # byte-counting reader: measures the store ROI read's bytes-read ratio
    def __init__(self, raw):
        self.raw = raw
        self.n = 0

    def seek(self, *a):
        return self.raw.seek(*a)

    def tell(self):
        return self.raw.tell()

    def read(self, k=-1):
        data = self.raw.read(k)
        self.n += len(data)
        return data

    def close(self):
        self.raw.close()

reps = int(os.environ.get("SZX_BENCH_REPS", 3))   # best-of-N vs host noise
# device legs pay jit compile on the first call: run one extra untimed rep
# so the best-of-N measures steady-state throughput, not compile time
warmup = 1 if backend != "numpy" else 0
if kind == "pipeline_compressed_a2a":
    # gpipe dryrun: compressed vs raw activation shift on an 8-device host
    # mesh (parent sets XLA_FLAGS).  dump = compressed schedule, load = raw;
    # wire bytes are analytic (wire_bytes_per_value), so the parent's cr is
    # the deterministic compressed-vs-raw bytes-moved ratio.
    import jax, jax.numpy as jnp
    from repro.core import grad_compress as gc
    from repro.pipeline_par import pipeline_apply

    n_stages, n_micro, d = 4, 8, 512
    mb = max(n // (n_micro * d * 8), 1)           # scale batch with SZX_BENCH_N
    mesh = jax.make_mesh((n_stages,), ("stage",))
    rng = np.random.default_rng(0)
    ws = jnp.asarray((rng.standard_normal((n_stages, d, d)) * 0.1),
                     jnp.float32)
    xs = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)
    stage = lambda p, x: jnp.tanh(x @ p)
    planes = 1
    fn = pipeline_apply(
        stage, mesh, compress_activations=phase == "dump", num_planes=planes
    )
    jax.block_until_ready(fn(ws, xs))             # compile outside the timing
    dt = float("inf")
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(ws, xs))
        dt = min(dt, time.time() - t0)
    ticks = n_micro + n_stages - 1
    wire_raw = ticks * n_stages * mb * d * 4      # per-tick per-stage shift
    wire_comp = wire_raw / 4.0 * gc.wire_bytes_per_value(planes, 64)
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    print(json.dumps({"t": dt, "rss_mb": rss_mb, "stored": int(wire_comp),
                      "n": n, "dtype": "float32", "workers": 1,
                      "wire_raw_mb": wire_raw / 1e6,
                      "wire_comp_mb": wire_comp / 1e6}))
    sys.exit(0)
if kind == "ingest_windowed" and phase == "load":
    # streaming training ingest over the store: a serial shuffled-ROI-window
    # epoch through a byte-counting reader pins bytes-read ∝ windows (not
    # the store); a pipelined epoch (worker pool + bounded lookahead)
    # measures the overlap win as samples/sec vs the serial loader
    from repro.data.store_loader import StoreLoader
    from repro.store import ArrayStore

    file_bytes = os.path.getsize(path)
    win = (16, 4096)
    win_elems = win[0] * win[1]
    # epoch sized to touch <=10% of the store (~8% nominal coverage)
    windows = max(int(0.08 * n_elems / win_elems), 4)
    batch = min(8, windows)
    steps = max(windows // batch, 1)
    windows = steps * batch
    serial_t = float("inf")
    for _ in range(reps):
        counting = CountingFile(open(path, "rb"))
        with ArrayStore.open(counting) as ca:
            ld = StoreLoader(ca, win, batch, seed=5, workers=0)
            t0 = time.time()
            for s in range(steps):
                y = ld.batch_at(s)
            serial_t = min(serial_t, time.time() - t0)
            ld.close()
        read_ratio = counting.n / file_bytes
        counting.close()
    assert y.shape == (batch,) + win and y.dtype == dtype
    cpus = os.cpu_count() or 1
    pool = min(4, max(cpus, 2))
    dt = float("inf")
    for _ in range(reps):
        with StoreLoader(path, win, batch, seed=5, workers=pool,
                         lookahead=2) as ld:
            t0 = time.time()
            for _b in ld.batches(steps=steps):
                pass
            dt = min(dt, time.time() - t0)
    roi_bytes = windows * win_elems * dtype.itemsize
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    print(json.dumps({"t": dt, "rss_mb": rss_mb, "stored": file_bytes,
                      "n": n, "dtype": dtype.name, "workers": pool,
                      "roi_bytes": roi_bytes, "read_ratio": read_ratio,
                      "serial_t": serial_t, "samples": windows,
                      "cpus": cpus}))
    sys.exit(0)
if kind == "store_roi" and phase == "load":
    # lazy ROI read of the leading ~1% of rows: report ROI MB/s and the
    # bytes-read ratio (the "bytes read scale with the ROI" guarantee)
    from repro.store import ArrayStore

    file_bytes = os.path.getsize(path)
    dt = float("inf")
    for _ in range(reps):
        counting = CountingFile(open(path, "rb"))
        with ArrayStore.open(counting) as ca:
            rows = max(ca.shape[0] // 100, 1)
            t0 = time.time()
            y = ca[:rows]
            dt = min(dt, time.time() - t0)
            read_ratio = counting.n / file_bytes
            roi_bytes = y.nbytes
        counting.close()
    assert y.shape[0] == rows and y.dtype == dtype
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    print(json.dumps({"t": dt, "rss_mb": rss_mb, "stored": file_bytes,
                      "n": n, "dtype": dtype.name, "workers": workers,
                      "roi_bytes": roi_bytes, "read_ratio": read_ratio}))
    sys.exit(0)
if phase == "dump":
    rng = np.random.default_rng(0)
    x = np.cumsum(rng.standard_normal(n_elems, dtype=np.float32) * 0.01)
    x = x.astype(dtype)
    e = rel * float(x.astype(np.float32).max() - x.astype(np.float32).min())
    if kind.startswith("stage-"):
        # pinned ABS bound (= rel 1e-3 of the full 1<<26 walk): the frontier
        # rows compare stages in the SAME quantization regime at any
        # SZX_BENCH_N, so CR gains are size-independent
        e = 0.07230465698242187
    dt = float("inf")
    for r in range(reps + warmup):
        t0 = time.time()
        if kind == "store_roi":
            from repro.store import ArrayStore

            x3 = x.reshape(-1, 256, 256)
            ArrayStore.save(path, x3, e, workers=workers)
            stored = os.path.getsize(path)
        elif kind == "ingest_windowed":
            from repro.store import ArrayStore

            # leading-axis-slab grid: window reads stay block-tight
            x2 = x.reshape(-1, 4096)
            ArrayStore.save(path, x2, e, chunk_shape=(32, 4096),
                            workers=workers)
            stored = os.path.getsize(path)
        elif kind == "mono":
            buf = codec.compress(x, e)
            with open(path, "wb") as f:
                f.write(buf)
            stored = len(buf)
        elif kind == "tree_checkpoint":
            tree = make_tree(x)
            with open(path, "wb") as f:
                tree_codec.compress_tree(tree, f)
            stored = os.path.getsize(path)
        else:
            with open(path, "wb") as f:
                stored = codec.dump_chunked(x, f, e, chunk_bytes=8 << 20)
        if warmup and r == 0:
            continue
        dt = min(dt, time.time() - t0)
else:
    dt = float("inf")
    for r in range(reps + warmup):
        t0 = time.time()
        if kind == "mono":
            with open(path, "rb") as f:
                y = codec.decompress(f.read())
        elif kind == "tree_checkpoint":
            with open(path, "rb") as f:
                out = tree_codec.decompress_tree(f)
            y = np.concatenate([out[f"layers/w{i}"] for i in range(4)])
        else:
            with open(path, "rb") as f:
                y = codec.load_chunked(f)
        if warmup and r == 0:
            continue
        dt = min(dt, time.time() - t0)
    stored = os.path.getsize(path)
    if kind == "tree_checkpoint":
        assert y.size == 4 * (n_elems // 4) and y.dtype == dtype
    else:
        assert y.size == n_elems and y.dtype == dtype

rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
print(json.dumps({"t": dt, "rss_mb": rss_mb, "stored": stored, "n": n,
                  "dtype": dtype.name, "workers": workers}))
"""


def _telemetry_overhead(n: int) -> dict:
    """Telemetry-on cost of the chunked compress/decompress paths.

    The gated overheads are computed as (measured obs work per frame) /
    (measured codec wall time per frame).  The numerator microbenchmarks
    exactly the code telemetry adds to each path -- the span enter/exit
    plus every ``record_*`` call a frame triggers (including the per-frame
    L-code histogram) -- over thousands of reps, so it is stable to well
    under 0.1%.  The denominator is the best-of-reps per-frame wall time
    the row reports anyway.  An end-to-end on/off wall-clock ratio was
    tried first and swings +-3% run to run on shared hosts (bursty sibling
    load defeats even paired, locally-drift-normalized medians), which
    would flake the <3% absolute gate in benchmarks/check_regression.py;
    the quotient of two tight measurements gates the same regression class
    (obs hot-path code getting expensive) without the flake.  Telemetry
    cannot change the bytes themselves -- tests pin byte-identical output
    with obs on.  The workload size is pinned (independent of
    SZX_BENCH_N): overheads are ratios, not throughputs."""
    import io

    from repro import obs
    from repro.core.codec import container
    from repro.obs import stream_stats

    del n                                       # pinned size; see docstring
    reps = max(int(os.environ.get("SZX_BENCH_REPS", 3)), 5)
    n_elems = 1 << 23
    chunk_bytes = 4 << 20                       # 1 Mi elements -> many frames
    rng = np.random.default_rng(0)
    x = np.cumsum(rng.standard_normal(n_elems, dtype=np.float32) * 0.01)
    e = 1e-3 * float(x.max() - x.min())
    codec = SZxCodec(backend="numpy")
    was = obs.enabled()
    best = {"off": [float("inf")] * 2, "on": [float("inf")] * 2}
    nframes = 0

    def _one(mode):
        (obs.enable if mode == "on" else obs.disable)()
        obs.reset()
        bio = io.BytesIO()
        t0 = time.perf_counter()
        codec.dump_chunked(x, bio, e, chunk_bytes=chunk_bytes)
        tc = time.perf_counter() - t0
        bio.seek(0)
        t0 = time.perf_counter()
        y = codec.load_chunked(bio)
        td = time.perf_counter() - t0
        assert y.size == n_elems
        if mode == "on":
            nonlocal nframes
            nframes = len(obs.REGISTRY.frames())
            assert nframes > 0, "telemetry on but no frames logged"
        best[mode][0] = min(best[mode][0], tc)
        best[mode][1] = min(best[mode][1], td)

    def _per_call(fn, reps=2000):
        fn()                                    # warm-up
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        return (time.perf_counter() - t0) / reps

    try:
        _one("off")                             # warm-up, not scored
        for _ in range(reps):
            _one("off")
            _one("on")
        # microbenchmark the per-frame obs work on a representative payload
        obs.enable()
        obs.reset()
        payload = codec.compress(x[: chunk_bytes // 4], e)
        frame = container.build_frame(payload, 0, True)
        nbytes = len(x[: chunk_bytes // 4].tobytes())

        def comp_obs():
            with obs.span("codec.compress", n=chunk_bytes // 4,
                          dtype="float32"):
                pass
            stream_stats.record_compress(payload, 0.01)
            stream_stats.record_frame_built(payload, len(frame), 0, 0)

        def decomp_obs():
            with obs.span("codec.decompress"):
                pass
            stream_stats.record_decompress(nbytes, 0.01)

        t_comp_obs = _per_call(comp_obs)
        t_decomp_obs = _per_call(decomp_obs)
    finally:
        (obs.enable if was else obs.disable)()
        obs.reset()
    mb = x.nbytes / 1e6
    per_frame = {"comp": best["off"][0] / nframes,
                 "decomp": best["off"][1] / nframes}
    return dict(
        comp_mbs=mb / best["off"][0],
        decomp_mbs=mb / best["off"][1],
        comp_mbs_obs=mb / best["on"][0],
        decomp_mbs_obs=mb / best["on"][1],
        comp_overhead=t_comp_obs / per_frame["comp"],
        decomp_overhead=t_decomp_obs / per_frame["decomp"],
        obs_us_per_frame_comp=t_comp_obs * 1e6,
        obs_us_per_frame_decomp=t_decomp_obs * 1e6,
        frames=nframes,
        dtype="float32",
        workers=1,
    )


def _store_service_load(tmpdir: str, n: int) -> dict:
    """Load-generate against a live store service: cold vs warm-cache ROI
    latency (p50/p99), hit rate and request throughput.

    The latency probes use narrow-column ROIs (the read path still decodes
    ~the whole chunk's flat span cold, but the warm path answers from the
    decoded-chunk cache), so the warm/cold ratio isolates the cache win.
    Asserts the warm p50 is >=5x below the cold p50 at byte-identical
    responses; the throughput probes re-read whole chunks for a stable
    decomp_mbs.
    """
    import threading
    import urllib.request

    from repro.api import ArrayStore, Bound
    from repro.serve.store_service import make_server

    cols = 4096
    rows = max(n // cols, 64)
    rng = np.random.default_rng(12)
    base = np.cumsum(rng.standard_normal(rows)).astype(np.float32)
    x = base[:, None] + rng.standard_normal((rows, cols)).astype(np.float32) * 0.01
    path = os.path.join(tmpdir, "service.szs")
    t0 = time.perf_counter()
    idx = ArrayStore.save(path, x, Bound.rel(1e-3))
    save_t = time.perf_counter() - t0
    stored = sum(f[1] for f in idx["frames"])
    chunk_rows = idx["chunk_shape"][0]
    nchunks = len(idx["frames"])

    srv = make_server(path, port=0)
    thread = threading.Thread(target=srv.serve_forever, daemon=True)
    thread.start()
    host, port = srv.server_address
    url = f"http://{host}:{port}/v1/stores/default/read?roi="

    def fetch(roi: str) -> tuple[float, bytes]:
        t = time.perf_counter()
        with urllib.request.urlopen(url + roi, timeout=120) as r:
            body = r.read()
        return time.perf_counter() - t, body

    def pct(xs: list[float], q: float) -> float:
        xs = sorted(xs)
        return xs[min(int(q * (len(xs) - 1) + 0.5), len(xs) - 1)]

    try:
        probes = [
            f"{cid * chunk_rows}:{min((cid + 1) * chunk_rows, rows)},0:64"
            for cid in range(min(nchunks, 16))
        ]
        cold, bodies = [], {}
        for roi in probes:                      # first touch: decode path
            dt, body = fetch(roi)
            cold.append(dt)
            bodies[roi] = body
        warm = []
        warm_t0 = time.perf_counter()
        for _ in range(5):                      # repeats: cache path
            for roi in probes:
                dt, body = fetch(roi)
                warm.append(dt)
                assert body == bodies[roi], f"warm bytes diverged for {roi}"
        warm_wall = time.perf_counter() - warm_t0
        cold_p50, warm_p50 = pct(cold, 0.50), pct(warm, 0.50)
        assert warm_p50 * 5 <= cold_p50, (
            f"warm-cache p50 {warm_p50 * 1e3:.2f} ms not >=5x below cold "
            f"{cold_p50 * 1e3:.2f} ms"
        )
        # throughput probes: whole-chunk re-reads from the warm cache
        full = f"0:{min(chunk_rows, rows)},0:{cols}"
        fetch(full)                             # prime
        tput_bytes = 0
        tput_t0 = time.perf_counter()
        for _ in range(8):
            _dt, body = fetch(full)
            tput_bytes += len(body)
        tput_wall = time.perf_counter() - tput_t0
        cache = srv.service.cache.stats()
        assert cache["hits"] > 0
    finally:
        srv.shutdown()
        srv.server_close()
    return dict(
        comp_mbs=x.nbytes / 1e6 / save_t,       # store save (ingest) MB/s
        decomp_mbs=tput_bytes / 1e6 / tput_wall,  # warm whole-chunk read MB/s
        cr=x.nbytes / stored,
        cold_p50_ms=cold_p50 * 1e3, cold_p99_ms=pct(cold, 0.99) * 1e3,
        warm_p50_ms=warm_p50 * 1e3, warm_p99_ms=pct(warm, 0.99) * 1e3,
        warm_speedup=cold_p50 / warm_p50,
        hit_rate=cache["hit_rate"],
        req_s=len(warm) / warm_wall,
        dtype="float32",
        workers=1,
    )


def chunked_dump_load(tmpdir: str = "/tmp/repro_chunked") -> dict:
    """Monolithic vs chunked vs parallel-chunked codec: throughput + peak RSS.

    Each phase runs in a fresh subprocess so ru_maxrss isolates that phase's
    peak memory.  'chunked-par' runs the frame pipeline with one worker
    thread per core (byte output identical to 'chunked').  The
    'chunked-f64' / 'chunked-bf16' legs run the SAME byte volume
    (SZX_BENCH_N * 4 bytes) through the width-generic kernel layer in those
    dtypes, gating the per-dtype fast paths.  'tree_checkpoint' pushes the
    same bytes through the pytree front-end (TreeCodec: multi-leaf
    container-v3 stream with index footer), gating the checkpoint path.
    'store_roi_read' saves the same bytes as an N-d repro.store chunk grid
    and lazily reads a ~1% leading-rows ROI: comp_mbs is the store save
    throughput, decomp_mbs the ROI read MB/s, and roi_bytes_read_ratio pins
    that bytes read scale with the ROI, not the array.  'ingest_windowed'
    runs the streaming training-ingest loader over the same store: a
    shuffled-ROI-window epoch touching <=10% of the array, reporting
    samples/sec (pipelined vs serial) and the bytes-read ratio (must stay
    ≪ 1).  'chunked-dev-decode' runs the chunked pipeline on the device
    backend (one transfer per chunk both ways; the decode tentpole's
    symmetric path); device legs run one untimed warmup rep so jit compile
    stays out of the best-of-N.
    'pipeline_compressed_a2a' dry-runs the gpipe activation shift on an
    8-device host mesh: comp_mbs/decomp_mbs are the compressed/raw schedule
    wire-throughputs and cr is the analytic compressed-vs-raw bytes-moved
    ratio.  'store_service_load' load-generates against a live HTTP store
    service: comp_mbs is store-save (ingest) MB/s, decomp_mbs the warm
    whole-chunk read MB/s, plus cold/warm ROI p50/p99 latency, cache hit
    rate and req/s (asserts warm p50 >=5x below cold at byte-identical
    responses).  'telemetry_overhead' reports chunked f32 round-trip
    throughput with repro.obs off vs on plus the fractional cost of the
    per-frame telemetry work (microbenchmarked against the per-frame wall
    time; see _telemetry_overhead); check_regression.py gates that
    overhead below 3% absolutely.  Results
    also land in
    BENCH_codec.json at the repo root (override the path with
    SZX_BENCH_JSON, the f32-equivalent element count with SZX_BENCH_N) to
    anchor the codec perf trajectory; benchmarks/check_regression.py gates
    CI on them.
    """
    os.makedirs(tmpdir, exist_ok=True)
    n = int(os.environ.get("SZX_BENCH_N", 1 << 26))
    out: dict = {"n": n}
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO_ROOT, "src")}
    for kind in ("mono", "chunked", "chunked-par", "chunked-f64", "chunked-bf16",
                 "chunked-dev-decode", "tree_checkpoint", "store_roi_read",
                 "ingest_windowed", "pipeline_compressed_a2a"):
        child_kind = "store_roi" if kind == "store_roi_read" else kind
        child_env = env
        if kind == "pipeline_compressed_a2a":
            child_env = {
                **env, "XLA_FLAGS": "--xla_force_host_platform_device_count=8"
            }
        path = os.path.join(tmpdir, f"{kind}.szx")
        res = {}
        for phase in ("dump", "load"):
            r = subprocess.run(
                [sys.executable, "-c", _CHUNKED_CHILD, f"{child_kind}_{phase}", path],
                capture_output=True, text=True, timeout=1800, env=child_env,
            )
            assert r.returncode == 0, r.stderr[-2000:]
            res[phase] = json.loads(r.stdout.strip().splitlines()[-1])
        if kind == "pipeline_compressed_a2a":
            wire_raw_mb = res["dump"]["wire_raw_mb"]
            out[kind] = dict(
                comp_mbs=wire_raw_mb / res["dump"]["t"],    # compressed sched
                decomp_mbs=wire_raw_mb / res["load"]["t"],  # raw schedule
                cr=wire_raw_mb / res["dump"]["wire_comp_mb"],
                wire_raw_mb=wire_raw_mb,
                wire_comp_mb=res["dump"]["wire_comp_mb"],
                dtype="float32",
                workers=1,
            )
            _emit(
                f"beyond/chunked_dump_load/{kind}", res["dump"]["t"] * 1e6,
                f"comp_MB/s={out[kind]['comp_mbs']:.0f};"
                f"decomp_MB/s={out[kind]['decomp_mbs']:.0f};"
                f"wire_raw_MB={wire_raw_mb:.1f};"
                f"wire_comp_MB={out[kind]['wire_comp_mb']:.1f};"
                f"bytes_moved_ratio={out[kind]['cr']:.2f}",
            )
            continue
        raw_mb = n * 4 / 1e6
        # store_roi_read's load phase reads a ~1% ROI lazily: decomp_mbs is
        # ROI MB/s (the serving metric), and read_ratio pins bytes-read ∝ ROI
        load_mb = res["load"].get("roi_bytes", n * 4) / 1e6
        out[kind] = dict(
            comp_mbs=raw_mb / res["dump"]["t"],
            decomp_mbs=load_mb / res["load"]["t"],
            dump_peak_rss_mb=res["dump"]["rss_mb"],
            load_peak_rss_mb=res["load"]["rss_mb"],
            stored_mb=res["dump"]["stored"] / 1e6,
            cr=n * 4 / res["dump"]["stored"],
            dtype=res["dump"]["dtype"],
            workers=res["dump"]["workers"],
        )
        extra = ""
        if "read_ratio" in res["load"]:
            out[kind]["roi_bytes_read_ratio"] = res["load"]["read_ratio"]
            extra = f";roi_read_ratio={res['load']['read_ratio']:.4f}"
        if kind == "ingest_windowed":
            # decomp_mbs above is the pipelined loader's decoded-window MB/s;
            # the ingest metrics proper are samples/sec and the serial-vs-
            # pipelined speedup (gated in CI when the host has >=2 cpus)
            ld = res["load"]
            out[kind].update(
                samples_s=ld["samples"] / ld["t"],
                serial_samples_s=ld["samples"] / ld["serial_t"],
                pipeline_speedup=ld["serial_t"] / ld["t"],
                bytes_read_ratio=ld["read_ratio"],
                ingest_workers=ld["workers"],
                cpus=ld["cpus"],
            )
            extra += (
                f";samples_s={out[kind]['samples_s']:.0f}"
                f";serial_samples_s={out[kind]['serial_samples_s']:.0f}"
                f";speedup={out[kind]['pipeline_speedup']:.2f}"
                f";workers={ld['workers']};cpus={ld['cpus']}"
            )
        _emit(
            f"beyond/chunked_dump_load/{kind}", res["dump"]["t"] * 1e6,
            f"comp_MB/s={out[kind]['comp_mbs']:.0f};"
            f"decomp_MB/s={out[kind]['decomp_mbs']:.0f};"
            f"dump_RSS_MB={out[kind]['dump_peak_rss_mb']:.0f};"
            f"load_RSS_MB={out[kind]['load_peak_rss_mb']:.0f};"
            f"CR={out[kind]['cr']:.2f}" + extra,
        )
    # --- second-stage speed/ratio frontier: stage-off vs each lossless
    # second stage over the SAME bytes at a pinned abs bound (the child
    # overrides e so the quantization regime is size-independent).  Gated
    # absolutely in check_regression.py: at least one stage must buy
    # >=1.5x CR at <30% comp+decomp throughput cost.
    from repro.core.codec import stage as stage_mod

    stage_kinds = ["stage-off", "stage-rle", "stage-deflate"]
    if stage_mod._zstd() is not None:
        stage_kinds.append("stage-zstd")
    frontier: dict = {}
    for kind in stage_kinds:
        path = os.path.join(tmpdir, f"{kind}.szx")
        res = {}
        for phase in ("dump", "load"):
            r = subprocess.run(
                [sys.executable, "-c", _CHUNKED_CHILD, f"{kind}_{phase}", path],
                capture_output=True, text=True, timeout=1800, env=env,
            )
            assert r.returncode == 0, r.stderr[-2000:]
            res[phase] = json.loads(r.stdout.strip().splitlines()[-1])
        raw_mb = n * 4 / 1e6
        frontier[kind] = dict(
            comp_mbs=raw_mb / res["dump"]["t"],
            decomp_mbs=raw_mb / res["load"]["t"],
            stored_mb=res["dump"]["stored"] / 1e6,
            cr=n * 4 / res["dump"]["stored"],
        )
    off_row = frontier["stage-off"]
    for kind in stage_kinds:
        f_row = frontier[kind]
        f_row["cr_gain"] = f_row["cr"] / off_row["cr"]
        f_row["comp_rel"] = f_row["comp_mbs"] / off_row["comp_mbs"]
        f_row["decomp_rel"] = f_row["decomp_mbs"] / off_row["decomp_mbs"]
        _emit(
            f"beyond/chunked_dump_load/{kind}", 0.0,
            f"comp_MB/s={f_row['comp_mbs']:.0f};"
            f"decomp_MB/s={f_row['decomp_mbs']:.0f};"
            f"CR={f_row['cr']:.2f};"
            f"CR_gain={f_row['cr_gain']:.2f}x;"
            f"comp_rel={f_row['comp_rel']:.2f};"
            f"decomp_rel={f_row['decomp_rel']:.2f}",
        )
    out["second_stage_frontier"] = frontier

    row = out["telemetry_overhead"] = _telemetry_overhead(n)
    _emit(
        "beyond/chunked_dump_load/telemetry_overhead", 0.0,
        f"comp_MB/s={row['comp_mbs']:.0f};"
        f"comp_obs_MB/s={row['comp_mbs_obs']:.0f};"
        f"decomp_MB/s={row['decomp_mbs']:.0f};"
        f"decomp_obs_MB/s={row['decomp_mbs_obs']:.0f};"
        f"comp_ovh={row['comp_overhead'] * 100:.2f}%;"
        f"decomp_ovh={row['decomp_overhead'] * 100:.2f}%;"
        f"frames={row['frames']}",
    )

    row = out["store_service_load"] = _store_service_load(tmpdir, n)
    _emit(
        "beyond/chunked_dump_load/store_service_load",
        row["warm_p50_ms"] * 1e3,
        f"comp_MB/s={row['comp_mbs']:.0f};"
        f"decomp_MB/s={row['decomp_mbs']:.0f};"
        f"cold_p50_ms={row['cold_p50_ms']:.2f};"
        f"warm_p50_ms={row['warm_p50_ms']:.2f};"
        f"hit_rate={row['hit_rate']:.2f};"
        f"req_s={row['req_s']:.0f};"
        f"CR={row['cr']:.2f}",
    )
    bench_json = os.environ.get(
        "SZX_BENCH_JSON", os.path.join(REPO_ROOT, "BENCH_codec.json")
    )
    with open(bench_json, "w") as f:
        json.dump({"chunked_dump_load": out}, f, indent=1, default=float)
    return out


ALL = [
    table3_compression_ratio,
    table4_compression_speed,
    table5_decompression_speed,
    fig2_block_range_cdf,
    fig6_shift_overhead,
    fig8_block_size,
    fig10_quality,
    fig13_dump_load,
    beyond_planes_codec,
    chunked_dump_load,
]


def main(names: list[str] | None = None) -> None:
    os.makedirs(OUT, exist_ok=True)
    by_name = {fn.__name__: fn for fn in ALL}
    if names:
        unknown = [n for n in names if n not in by_name]
        if unknown:
            raise SystemExit(f"unknown benchmarks {unknown}; have {sorted(by_name)}")
        todo = [by_name[n] for n in names]
    else:
        todo = ALL
    results = {}
    print("name,us_per_call,derived")
    for fn in todo:
        results[fn.__name__] = fn()
    with open(os.path.join(OUT, "benchmarks.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"# wrote {os.path.join(OUT, 'benchmarks.json')}")


if __name__ == "__main__":
    main(sys.argv[1:])
