"""Device-resident stream assembly (repro.core.codec.device).

Pins the tentpole contracts: the encode path performs exactly ONE host
transfer per chunk (transfer spy over jax.device_get), the device-assembled
bytes are bit-identical to the host serializer for every dtype and device
backend (f32 golden bytes are pinned separately in test_codec.py), and
DeviceEncoding behaves as a pytree shared by the planes consumers.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.codec import DeviceEncoding, PlanesCodec, SZxCodec, device, plan

try:
    import ml_dtypes

    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes ships with jax
    BF16 = None

_DTYPES = [np.float32, np.float64, np.float16] + ([BF16] if BF16 is not None else [])


def _walk(n, seed=0, dtype=np.float32, scale=0.01):
    rng = np.random.default_rng(seed)
    return (np.cumsum(rng.standard_normal(n)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# transfer spy: ONE device_get per chunk
# ---------------------------------------------------------------------------

def test_encode_device_is_one_host_transfer(monkeypatch):
    calls = []
    real_get = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda v: calls.append(v) or real_get(v))
    x = _walk(100_000, seed=1)
    buf = SZxCodec(backend="jax").compress(x, 1e-3)
    assert len(calls) == 1, "encode path must read back exactly once per chunk"
    # ... and that single get carries the body plus the tiny header scalars
    assert isinstance(calls[0], tuple) and len(calls[0]) == 4
    assert buf == SZxCodec(backend="numpy").compress(x, 1e-3)


def test_chunked_encode_is_one_transfer_per_frame(monkeypatch):
    calls = []
    real_get = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda v: calls.append(v) or real_get(v))
    x = _walk(300_000, seed=2)
    frames = list(SZxCodec(backend="jax").compress_chunked(x, 1e-3, chunk_bytes=1 << 19))
    per = plan.chunk_elements(128, 1 << 19, 4)
    nchunks = -(-x.size // per)
    assert len(frames) == nchunks
    assert len(calls) == nchunks, "one device_get per chunk, no more"


# ---------------------------------------------------------------------------
# byte identity: device assembly == host serializer, every dtype x backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", _DTYPES, ids=lambda d: np.dtype(d).name)
@pytest.mark.parametrize("backend", ["jax", "kernel"])
def test_device_stream_bit_identical_to_host(dtype, backend):
    ref = SZxCodec(backend="numpy")
    dev = SZxCodec(backend=backend)
    for n, bs, e in ((9999, 128, 1e-3), (257, 32, 1e-2), (1000, 128, 1.0)):
        x = _walk(n, seed=n, dtype=dtype)
        assert (
            SZxCodec(block_size=bs, backend=backend).compress(x, e)
            == SZxCodec(block_size=bs, backend="numpy").compress(x, e)
        ), (np.dtype(dtype).name, backend, n, bs, e)
    # constant + verbatim extremes
    c = np.full(1500, 2.5).astype(dtype)
    assert dev.compress(c, 1e-3) == ref.compress(c, 1e-3)
    tiny = float(plan.finfo(np.dtype(dtype)).tiny)
    v = _walk(2000, seed=3, dtype=dtype, scale=1.0)
    assert dev.compress(v, tiny) == ref.compress(v, tiny)


def test_encode_device_host_mirror_matches_device_record():
    """encode_device on the numpy backend produces the same body bytes and
    scalars as the device route (the kept numpy mirror)."""
    x = _walk(20_000, seed=5)
    p, xt = plan.make_plan(x, 1e-3, backend="numpy")
    host = device.encode_device(plan.to_blocks(xt, p), p)
    pj, xtj = plan.make_plan(x, 1e-3, backend="jax")
    dev = device.encode_device(plan.to_blocks(xtj, pj), pj)
    h = jax.device_get((host["body"], host["total"], host["nnc"], host["nmid"]))
    d = jax.device_get((dev["body"], dev["total"], dev["nnc"], dev["nmid"]))
    assert int(h[1]) == int(d[1]) and int(h[2]) == int(d[2]) and int(h[3]) == int(d[3])
    np.testing.assert_array_equal(h[0][: int(h[1])], d[0][: int(d[1])])
    assert device.to_stream(host) == device.to_stream(dev)


# ---------------------------------------------------------------------------
# DeviceEncoding: the shared record
# ---------------------------------------------------------------------------

def test_device_encoding_is_a_pytree():
    enc = DeviceEncoding.make(
        "szx-planes",
        {"mu": jnp.ones((4,)), "sexp": jnp.zeros((4,), jnp.int32),
         "planes": jnp.zeros((1, 4, 8), jnp.uint8)},
        num_planes=1,
    )
    leaves, treedef = jax.tree_util.tree_flatten(enc)
    assert len(leaves) == 3
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.kind == "szx-planes" and rebuilt.info == {"num_planes": 1}
    # tree.map preserves the record; replace() swaps arrays only
    doubled = jax.tree.map(lambda a: a * 2, enc)
    np.testing.assert_array_equal(np.asarray(doubled["mu"]), 2 * np.ones(4))
    swapped = enc.replace(mu=jnp.zeros((4,)))
    assert swapped.kind == enc.kind
    with pytest.raises(KeyError):
        enc.replace(nope=jnp.zeros(1))


def test_planes_codec_device_encoding_roundtrip():
    xb = np.random.default_rng(11).standard_normal((6, 64)).astype(np.float32)
    for p in (1, 2):
        codec = PlanesCodec(p)
        enc = codec.encode_blocks_device(jnp.asarray(xb))
        assert enc.kind == "szx-planes" and enc.info["num_planes"] == p
        mu, sexp, planes = codec.encode_blocks(jnp.asarray(xb))
        np.testing.assert_array_equal(np.asarray(enc["planes"]), np.asarray(planes))
        dec = np.asarray(codec.decode_encoding(enc))
        np.testing.assert_array_equal(
            dec, np.asarray(codec.decode_blocks(mu, sexp, planes))
        )
    with pytest.raises(ValueError):
        PlanesCodec(3).decode_encoding(enc)          # plane-count mismatch
    with pytest.raises(ValueError):
        PlanesCodec(1).decode_encoding(
            DeviceEncoding.make("szx-v2", {"mu": jnp.zeros(1)})
        )


def test_to_stream_rejects_non_stream_kinds():
    enc = DeviceEncoding.make("szx-planes", {"mu": jnp.zeros(1)})
    with pytest.raises(ValueError):
        device.to_stream(enc)
