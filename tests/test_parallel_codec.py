"""Parallel (workers > 1) chunked frame pipeline.

The contract under test: with any worker count the chunked byte stream is
bit-identical to the serial one (frames are order-tagged and yielded in
order), errors still surface, and the edge shapes (empty array, single
element, sub-block arrays, chunk boundary exactly on a block edge) behave
identically to the serial path.
"""
import io

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.codec import SZxCodec, plan

try:
    import ml_dtypes

    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes ships with jax
    BF16 = None

CHUNK = 1 << 18
SERIAL = SZxCodec(backend="numpy")
PAR = SZxCodec(backend="numpy", workers=3)


def _walk(n, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (np.cumsum(rng.standard_normal(n)) * 0.01).astype(dtype)


_DTYPES = [np.float32, np.float64] + ([BF16] if BF16 is not None else [])


@pytest.mark.parametrize("dtype", _DTYPES, ids=lambda d: np.dtype(d).name)
def test_parallel_stream_is_byte_identical(dtype):
    x = _walk(600_001, seed=1, dtype=dtype)
    fs = list(SERIAL.compress_chunked(x, 1e-2, chunk_bytes=CHUNK))
    fp = list(PAR.compress_chunked(x, 1e-2, chunk_bytes=CHUNK))
    assert len(fs) > 3, "test must span multiple frames"
    assert [len(f) for f in fs] == [len(f) for f in fp]
    assert b"".join(fs) == b"".join(fp)
    ys = SERIAL.decompress_chunked(fs)
    yp = PAR.decompress_chunked(fp, n=x.size)
    assert yp.dtype == np.dtype(dtype)
    np.testing.assert_array_equal(
        np.asarray(ys).view(np.uint8), np.asarray(yp).view(np.uint8)
    )


def test_parallel_edge_cases():
    per = plan.chunk_elements(SERIAL.block_size, CHUNK, 4)
    cases = {
        "empty": np.zeros(0, np.float32),
        "single": np.float32([1.25]),
        "sub_block": _walk(SERIAL.block_size - 1, seed=2),
        "chunk_on_block_edge": _walk(2 * per, seed=3),
        "one_past_chunk": _walk(per + 1, seed=4),
    }
    for name, x in cases.items():
        fs = list(SERIAL.compress_chunked(x, 1e-3, chunk_bytes=CHUNK))
        fp = list(PAR.compress_chunked(x, 1e-3, chunk_bytes=CHUNK))
        assert b"".join(fs) == b"".join(fp), name
        y = PAR.decompress_chunked(fp)
        assert y.size == x.size, name
        if x.size:
            assert np.abs(x - y).max() <= 1e-3, name


def test_edge_shapes_exercise_fused_encode(monkeypatch):
    """Empty-input and sub-block-shaped arrays go through the FUSED encode:
    the numpy backend through the ``ops.encode`` host mirror, the jax backend
    through the one-transfer device-resident path (``device.encode_to_stream``)
    -- except the empty input, whose nb == 0 takes the host mirror."""
    from repro.core.codec import device
    from repro.kernels import ops

    calls = []
    real_encode = ops.encode
    monkeypatch.setattr(
        ops, "encode",
        lambda xb, e, **k: calls.append(np.asarray(xb).shape) or real_encode(xb, e, **k),
    )
    dev_calls = []
    real_dev = device.encode_to_stream
    monkeypatch.setattr(
        device, "encode_to_stream",
        lambda xb, p: dev_calls.append(np.asarray(xb).shape) or real_dev(xb, p),
    )
    for pf in (ops.block_stats, ops.pack):
        name = pf.__name__
        monkeypatch.setattr(
            ops, name,
            lambda *a, _n=name, **k: pytest.fail(f"edge shape used two-call {_n}"),
        )
    for backend in ("numpy", "jax"):
        codec = SZxCodec(backend=backend)
        for x in (
            np.zeros(0, np.float32),              # empty: nb == 0
            np.float32([1.25]),                   # single value, padded block
            _walk(codec.block_size - 1, seed=2),  # sub-block shape
        ):
            frames = list(codec.compress_chunked(x, 1e-3, chunk_bytes=CHUNK))
            y = codec.decompress_chunked(frames)
            assert y.size == x.size
            if x.size:
                assert np.abs(x - y).max() <= 1e-3
    # numpy: all 3 shapes fused host encode; jax: everything enters the
    # device path, whose nb == 0 case falls back to the fused host mirror
    assert calls == [(0, 128), (1, 128), (1, 128), (0, 128)]
    assert dev_calls == [(0, 128), (1, 128), (1, 128)]


def test_parallel_file_dump_load_identical(tmp_path):
    x = _walk(200_000, seed=5)
    ps, pp = tmp_path / "serial.szxf", tmp_path / "par.szxf"
    with open(ps, "wb") as f:
        ws = SERIAL.dump_chunked(x, f, 1e-4, chunk_bytes=CHUNK)
    with open(pp, "wb") as f:
        wp = PAR.dump_chunked(x, f, 1e-4, chunk_bytes=CHUNK)
    assert ws == wp and ps.read_bytes() == pp.read_bytes()
    with open(pp, "rb") as f:
        y = PAR.load_chunked(f, n=x.size)
    assert np.abs(x - y).max() <= 1e-4


def test_empty_sequence_raises_empty_error_even_with_n():
    for codec in (SERIAL, PAR):
        for frames in ([], b"", iter([]), io.BytesIO(b"")):
            with pytest.raises(ValueError, match="empty SZx frame sequence"):
                codec.decompress_chunked(frames, n=100)
        with pytest.raises(ValueError, match="empty SZx frame sequence"):
            codec.decompress_chunked([])


def test_parallel_corruption_still_rejected():
    frames = list(PAR.compress_chunked(_walk(150_000, seed=6), 1e-3, chunk_bytes=CHUNK))
    with pytest.raises(ValueError):   # out of order
        PAR.decompress_chunked([frames[1], frames[0]] + frames[2:])
    with pytest.raises(ValueError):   # missing LAST
        PAR.decompress_chunked(frames[:-1])
    with pytest.raises(ValueError):   # wrong n
        PAR.decompress_chunked(frames, n=7)
    blob = b"".join(frames)
    with pytest.raises(ValueError):   # truncated payload
        PAR.decompress_chunked(blob[:-3])


def test_checkpoint_workers_bytes_identical(tmp_path):
    tree = {"big": _walk(120_000, seed=7), "small": np.arange(7, dtype=np.int32)}
    outs = {}
    for workers in (1, 3):
        m = CheckpointManager(
            str(tmp_path / f"w{workers}"), compress=True,
            bound=plan.Bound.rel(1e-4), chunk_bytes=1 << 17, workers=workers,
        )
        m.save(0, tree)
        stream = tmp_path / f"w{workers}" / "step_000000000" / "tree.szt"
        outs[workers] = stream.read_bytes()
        restored, _ = m.restore(tree)
        e = 1e-4 * float(tree["big"].max() - tree["big"].min())
        assert np.abs(tree["big"] - np.asarray(restored["big"])).max() <= e
    assert outs[1] == outs[3], "checkpoint bytes depend on worker count"
