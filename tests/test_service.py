"""Service-tier and repro.api tests.

Covers the production store service (decoded-chunk LRU cache under
concurrent readers, ETag/If-None-Match/304, Range/206/416 over compressed
bytes, sharded-vs-single-file byte identity, /info revalidation, quotas)
and the unified ``Bound`` error-bound surface (new API warning-free,
legacy kwargs warn AND stay golden-byte identical).
"""
from __future__ import annotations

import concurrent.futures
import io
import json
import os
import threading
import urllib.error
import urllib.request
import warnings

import numpy as np
import pytest

from repro.api import ArrayStore, Bound, SZxCodec, TreeCodec, compress
from repro.core.codec import container
from repro.serve.service.app import HttpServer, _parse_range
from repro.serve.service.cache import LRUBytesCache
from repro.serve.store_service import make_server, make_service


# --------------------------------------------------------------------- helpers
def _data(shape=(40, 64), seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


class _Client:
    """Tiny urllib client returning (status, headers, body) for any status."""

    class _NoRedirect(urllib.request.HTTPRedirectHandler):
        def redirect_request(self, *a, **kw):
            return None

    def __init__(self, server):
        host, port = server.server_address
        self.base = f"http://{host}:{port}"
        self.opener = urllib.request.build_opener(self._NoRedirect)

    def get(self, path, headers=None, method="GET"):
        req = urllib.request.Request(self.base + path, headers=headers or {},
                                     method=method)
        try:
            with self.opener.open(req, timeout=30) as r:
                return r.status, dict(r.headers), r.read()
        except urllib.error.HTTPError as err:
            return err.code, dict(err.headers), err.read()


@pytest.fixture
def served(tmp_path):
    """A running server over one single-file store; yields (client, paths)."""
    x = _data()
    szs = tmp_path / "a.szs"
    ArrayStore.save(str(szs), x, Bound.abs(1e-3), chunk_shape=(8, 64))
    srv = make_server(str(szs), port=0)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    try:
        yield _Client(srv), {"szs": str(szs), "x": x, "service": srv.service,
                             "tmp": tmp_path}
    finally:
        srv.shutdown()
        srv.server_close()


# ------------------------------------------------------------- cache + threads
def test_concurrent_readers_byte_identical_and_cached(served):
    """N threads x mixed ROIs: every response byte-identical to a direct
    ArrayStore read, and the shared decoded-chunk cache registers hits."""
    client, ctx = served
    rois = ["0:8,0:64", "4:20,8:40", "5:13,0:32", "32:40,0:16", ":,:"]
    with ArrayStore.open(ctx["szs"]) as ca:
        from repro.store.grid import parse_roi
        direct = {roi: ca[parse_roi(roi)].tobytes() for roi in rois}

    def fetch(i):
        roi = rois[i % len(rois)]
        status, _h, body = client.get(f"/v1/stores/default/read?roi={roi}")
        assert status == 200
        return roi, body

    with concurrent.futures.ThreadPoolExecutor(max_workers=8) as pool:
        for roi, body in pool.map(fetch, range(40)):
            assert body == direct[roi]

    stats = ctx["service"].cache.stats()
    assert stats["hits"] > 0, stats
    assert stats["misses"] > 0


def test_tiny_cache_budget_evicts_but_stays_correct(tmp_path):
    """A cache budget far below the working set must thrash (evictions > 0)
    without ever corrupting a response."""
    x = _data()
    szs = tmp_path / "a.szs"
    ArrayStore.save(str(szs), x, Bound.abs(1e-3), chunk_shape=(8, 64))
    srv = make_server(str(szs), port=0, cache_bytes=2048)  # < one chunk
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    client = _Client(srv)
    try:
        with ArrayStore.open(str(szs)) as ca:
            want = ca[(slice(0, 40), slice(0, 64))].tobytes()
        for _ in range(6):
            status, _h, body = client.get("/v1/stores/default/read?roi=:,:")
            assert status == 200 and body == want
        stats = srv.service.cache.stats()
        assert stats["bytes"] <= 2048
    finally:
        srv.shutdown()
        srv.server_close()


def test_lru_cache_unit():
    c = LRUBytesCache(max_bytes=100)
    c.put("a", b"x", 60)
    c.put("b", b"y", 60)             # evicts a
    assert c.get("a") is None and c.get("b") == b"y"
    assert c.evictions == 1
    c.put("huge", b"z", 1000)        # over budget: rejected, no thrash
    assert len(c) == 1 and c.get("b") == b"y"
    s = c.stats()
    assert s["hits"] == 2 and s["misses"] == 1


# ------------------------------------------------------------------ ETag/Range
def test_etag_if_none_match_304(served):
    client, _ctx = served
    s1, h1, b1 = client.get("/v1/stores/default/info")
    assert s1 == 200
    etag = h1["ETag"]
    assert etag.startswith('"') and etag.endswith('"')
    # stable across requests and routes
    s2, h2, _ = client.get("/v1/stores/default/read?roi=0:8,0:8")
    assert h2["ETag"] == etag
    # If-None-Match -> 304, empty body
    for route in ("/v1/stores/default/info", "/v1/stores/default/read?roi=:,:",
                  "/v1/stores/default/raw", "/v1/stores/default/chunk/0"):
        s, h, b = client.get(route, {"If-None-Match": etag})
        assert (s, b) == (304, b""), route
        assert h["ETag"] == etag
    # wildcard and list forms
    s, _, _ = client.get("/v1/stores/default/info", {"If-None-Match": "*"})
    assert s == 304
    s, _, _ = client.get("/v1/stores/default/info",
                         {"If-None-Match": f'"nope", {etag}'})
    assert s == 304
    # mismatching validator -> 200
    s, _, _ = client.get("/v1/stores/default/info", {"If-None-Match": '"x"'})
    assert s == 200


def test_raw_range_conformance(served):
    client, _ctx = served
    s, h, full = client.get("/v1/stores/default/raw")
    assert s == 200 and h["Accept-Ranges"] == "bytes"
    size = len(full)
    s, h, part = client.get("/v1/stores/default/raw",
                            {"Range": "bytes=10-29"})
    assert s == 206 and part == full[10:30]
    assert h["Content-Range"] == f"bytes 10-29/{size}"
    # open-ended and suffix forms
    s, _, part = client.get("/v1/stores/default/raw",
                            {"Range": f"bytes={size - 7}-"})
    assert s == 206 and part == full[-7:]
    s, _, part = client.get("/v1/stores/default/raw", {"Range": "bytes=-16"})
    assert s == 206 and part == full[-16:]
    # unsatisfiable -> 416 with the total size
    s, h, _ = client.get("/v1/stores/default/raw",
                         {"Range": f"bytes={size}-"})
    assert s == 416 and h["Content-Range"] == f"bytes */{size}"
    # malformed -> 400
    s, _, _ = client.get("/v1/stores/default/raw", {"Range": "bytes=5-2,9-"})
    assert s == 400


def test_parse_range_unit():
    assert _parse_range("bytes=0-9", 100) == (0, 9)
    assert _parse_range("bytes=90-", 100) == (90, 99)
    assert _parse_range("bytes=-10", 100) == (90, 99)
    assert _parse_range("bytes=0-1000", 100) == (0, 99)
    assert _parse_range("bytes=100-", 100) == (None, None)
    assert _parse_range("bytes=-0", 100) == (None, None)
    with pytest.raises(ValueError):
        _parse_range("lines=0-9", 100)
    with pytest.raises(ValueError):
        _parse_range("bytes=1-2,4-5", 100)


# ------------------------------------------------------------------- sharding
def test_sharded_store_serves_same_bytes_as_single_file(tmp_path):
    """Pinned: a 2-shard store answers every route with the same content as
    its single-file equivalent (frame payloads identical; only the per-shard
    LAST flag in the frame header may differ)."""
    x = _data((40, 64), seed=3)
    szs = tmp_path / "one.szs"
    man = tmp_path / "two.json"
    ArrayStore.save(str(szs), x, Bound.abs(1e-3), chunk_shape=(8, 64))
    ArrayStore.save_sharded(str(man), x, Bound.abs(1e-3), nshards=2,
                            chunk_shape=(8, 64))
    assert sorted(p.name for p in tmp_path.glob("two.shard-*.szs")) == \
        ["two.shard-000.szs", "two.shard-001.szs"]

    service = make_service(str(szs))
    service.add_store("sharded", str(man))
    srv = HttpServer(service, "127.0.0.1", 0)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    client = _Client(srv)
    try:
        for roi in (":,:", "0:8,0:64", "7:25,3:61", "39:40,63:64"):
            s1, h1, b1 = client.get(f"/v1/stores/default/read?roi={roi}")
            s2, h2, b2 = client.get(f"/v1/stores/sharded/read?roi={roi}")
            assert (s1, s2) == (200, 200)
            assert b1 == b2, roi
            assert h1["X-Shape"] == h2["X-Shape"]
        # compressed-domain stats agree
        _, _, st1 = client.get("/v1/stores/default/stats")
        _, _, st2 = client.get("/v1/stores/sharded/stats")
        assert json.loads(st1) == json.loads(st2)
        # per-chunk frames: payload bytes identical, LAST flag may differ
        hs = container.FRAME_HEADER.size
        with ArrayStore.open(str(szs)) as ca:
            nchunks = ca.nchunks
        for cid in range(nchunks):
            s1, _, c1 = client.get(f"/v1/stores/default/chunk/{cid}")
            s2, _, c2 = client.get(f"/v1/stores/sharded/chunk/{cid}")
            assert (s1, s2) == (200, 200) and c1[hs:] == c2[hs:], cid
        # shard raw endpoints exist and concatenate to all frames
        _, _, sh0 = client.get("/v1/stores/sharded/raw?shard=0")
        _, _, sh1 = client.get("/v1/stores/sharded/raw?shard=1")
        assert len(sh0) > 0 and len(sh1) > 0
        s, _, _ = client.get("/v1/stores/sharded/raw?shard=9")
        assert s == 400
    finally:
        srv.shutdown()
        srv.server_close()


def test_sharded_open_direct_matches_array(tmp_path):
    """ArrayStore.open on a manifest reconstructs the array exactly like the
    single-file store does."""
    x = _data((17, 33), seed=5)
    man = tmp_path / "m.json"
    ArrayStore.save_sharded(str(man), x, Bound.abs(1e-3), nshards=3,
                            chunk_shape=(4, 33))
    szs = tmp_path / "one.szs"
    ArrayStore.save(str(szs), x, Bound.abs(1e-3), chunk_shape=(4, 33))
    with ArrayStore.open(str(man)) as sharded, ArrayStore.open(str(szs)) as one:
        np.testing.assert_array_equal(sharded[:, :], one[:, :])
        assert sharded.stats().to_dict() == one.stats().to_dict()


def test_remote_shard_chunk_redirects(tmp_path):
    """Chunks owned by a remote (URL) shard answer 307 with the frame's byte
    range in headers; local shards still serve bytes."""
    x = _data((40, 64), seed=7)
    man_path = tmp_path / "m.json"
    ArrayStore.save_sharded(str(man_path), x, Bound.abs(1e-3), nshards=2,
                            chunk_shape=(8, 64))
    man = json.loads(man_path.read_text())
    man["shards"][1]["file"] = "https://shards.example/two.shard-001.szs"
    man_path.write_text(json.dumps(man))

    service = make_service()
    service.add_store("s", str(man_path))
    service.default_store = "s"
    srv = HttpServer(service, "127.0.0.1", 0)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    client = _Client(srv)
    try:
        s, _, _ = client.get("/v1/stores/s/chunk/0")
        assert s == 200
        lo = man["shards"][1]["chunks"][0]
        off, length, _elems = man["shards"][1]["frames"][0]
        s, h, _ = client.get(f"/v1/stores/s/chunk/{lo}")
        assert s == 307
        assert h["Location"] == "https://shards.example/two.shard-001.szs"
        assert (int(h["X-Chunk-Offset"]), int(h["X-Chunk-Length"])) == \
            (off, length)
        # raw for the remote shard also redirects
        s, h, _ = client.get("/v1/stores/s/raw?shard=1")
        assert s == 307 and "Location" in h
    finally:
        srv.shutdown()
        srv.server_close()


# ------------------------------------------------------- revalidation + quotas
def test_info_served_from_current_file_and_410_when_gone(served):
    client, ctx = served
    s, h, b = client.get("/v1/stores/default/info")
    etag = h["ETag"]
    assert json.loads(b)["shape"] == [40, 64]
    # replace the file: metadata and ETag change on the next request
    x2 = _data((16, 64), seed=9)
    tmp = ctx["szs"] + ".tmp"
    ArrayStore.save(tmp, x2, Bound.abs(1e-3), chunk_shape=(8, 64))
    os.replace(tmp, ctx["szs"])
    s, h, b = client.get("/v1/stores/default/info")
    assert s == 200 and json.loads(b)["shape"] == [16, 64]
    assert h["ETag"] != etag
    # reads serve the NEW bytes
    s, _, body = client.get("/v1/stores/default/read?roi=:,:")
    with ArrayStore.open(ctx["szs"]) as ca:
        assert body == ca[:, :].tobytes()
    # vanished file -> 410 JSON envelope (both API generations)
    os.remove(ctx["szs"])
    s, _, b = client.get("/v1/stores/default/info")
    assert s == 410 and json.loads(b)["error"]["code"] == 410
    s, _, _ = client.get("/info")
    assert s == 410


def test_tenant_quota_429(served):
    client, ctx = served
    ctx["service"].registry.set_quota("t1", max_requests=3)
    for _ in range(3):
        s, _, _ = client.get("/v1/", {"X-Tenant": "t1"})
        assert s == 200
    s, _, b = client.get("/v1/", {"X-Tenant": "t1"})
    assert s == 429 and json.loads(b)["error"]["code"] == 429
    # other tenants unaffected
    s, _, _ = client.get("/v1/", {"X-Tenant": "t2"})
    assert s == 200
    # byte quotas meter response bytes
    ctx["service"].registry.set_quota("t3", max_bytes=64)
    client.get("/v1/stores/default/read?roi=:,:", {"X-Tenant": "t3"})
    s, _, _ = client.get("/v1/", {"X-Tenant": "t3"})
    assert s == 429


def test_metrics_and_errors(served):
    client, _ctx = served
    client.get("/v1/stores/default/read?roi=0:2,0:2")
    s, _, b = client.get("/v1/metrics")
    m = json.loads(b)
    assert m["requests"] >= 1 and "cache" in m
    assert "/v1/stores/default/read" in m["by_route"]
    lat = m["latency"]["/v1/stores/default/read"]
    assert lat["count"] >= 1 and lat["p99_ms"] >= lat["p50_ms"] >= 0.0
    # error envelopes
    s, _, b = client.get("/v1/stores/nope/info")
    assert s == 404 and json.loads(b)["error"]["code"] == 404
    s, _, b = client.get("/v1/stores/default/read?roi=bogus")
    assert s == 400 and "error" in json.loads(b)
    s, _, b = client.get("/nope")
    assert s == 404 and json.loads(b) == {"error": "unknown path /nope"}
    s, _, b = client.get("/v1/", method="PUT")
    assert s == 405


def test_head_requests(served):
    client, _ctx = served
    s, h, b = client.get("/info", method="HEAD")
    assert s == 200 and b == b"" and int(h["Content-Length"]) > 0


# --------------------------------------------------------------- Bound surface
def test_bound_constructors_and_parse():
    assert Bound.abs(1e-3) == Bound(1e-3, "abs")
    assert Bound.rel(1e-4) == Bound(1e-4, "rel")
    assert Bound.parse("1e-3") == Bound.abs(1e-3)
    assert Bound.parse("abs:1e-3") == Bound.abs(1e-3)
    assert Bound.parse("rel:1e-4") == Bound.rel(1e-4)
    assert str(Bound.rel(1e-4)) == "rel:0.0001"
    with pytest.raises(ValueError):
        Bound(0.0, "abs")
    with pytest.raises(ValueError):
        Bound(1e-3, "relative")
    with pytest.raises(ValueError):
        Bound.parse("pct:1")


def test_new_api_is_warning_free_and_legacy_warns_identically(tmp_path):
    """Every consumer accepts Bound with zero DeprecationWarnings; the old
    (error_bound, mode=) kwargs warn AND produce byte-identical output."""
    x = _data((100,), seed=11).astype(np.float32)
    codec = SZxCodec(block_size=64)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        new = codec.compress(x, Bound.abs(1e-3))
        new_mod = compress(x, Bound.abs(1e-3), block_size=64)
        tc = TreeCodec(codec=codec, bound=Bound.rel(1e-4), chunk_bytes=1 << 20)
        buf_new = io.BytesIO()
        tc.compress_tree({"w": x}, buf_new)
        szs_new = tmp_path / "new.szs"
        ArrayStore.save(str(szs_new), x.reshape(10, 10), Bound.abs(1e-3))
    with pytest.warns(DeprecationWarning):
        old = codec.compress(x, error_bound=1e-3)
    assert old == new == new_mod
    with pytest.warns(DeprecationWarning):
        tc_old = TreeCodec(codec=codec, error_bound=1e-4, mode="rel",
                           chunk_bytes=1 << 20)
    buf_old = io.BytesIO()
    tc_old.compress_tree({"w": x}, buf_old)
    assert buf_old.getvalue() == buf_new.getvalue()
    with pytest.warns(DeprecationWarning):
        szs_old = tmp_path / "old.szs"
        ArrayStore.save(str(szs_old), x.reshape(10, 10), 1e-3, mode="abs")
    assert szs_old.read_bytes() == szs_new.read_bytes()
