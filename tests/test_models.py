"""Model-stack tests: per-arch smoke (assignment requirement), attention and
SSD oracles, prefill/decode equivalence, MoE behaviours."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers as L
from repro.models import transformer as T
from repro.serve import engine as E


def _batch(cfg, key, B=2, S=24, extra=0):
    toks = jax.random.randint(key, (B, S + extra), 0, cfg.vocab_size)
    kw = {}
    if cfg.encoder_decoder:
        kw["frames"] = (
            jax.random.normal(jax.random.key(7), (B, cfg.encoder_len, cfg.d_model)) * 0.1
        )
    if cfg.prefix_embeds:
        kw["image_embeds"] = (
            jax.random.normal(jax.random.key(8), (B, cfg.prefix_embeds, cfg.d_model)) * 0.1
        )
    return toks, kw


# ---------------------------------------------------------------------------
# per-arch smoke tests (reduced config, one forward/train step, shapes + NaN)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_smoke(name):
    cfg = configs.get(name).reduced()
    params = T.init_params(cfg, jax.random.key(0))
    B, S = 2, 32
    toks, kw = _batch(cfg, jax.random.key(1), B, S)
    h, aux = T.forward(params, cfg, toks, **kw)
    exp_s = S + (cfg.prefix_embeds or 0)
    assert h.shape == (B, exp_s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))
    batch = {"tokens": toks, "labels": jnp.where(toks > 0, toks, -1), **kw}
    loss, grads = jax.value_and_grad(lambda p: T.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in flat)


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_full_config_consistency(name):
    """Full (non-reduced) configs are structurally sound: param math matches
    an eval_shape'd init, within the MoE/enc-dec accounting."""
    cfg = configs.get(name)
    specs = T.param_specs(cfg)
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs))
    analytic = cfg.param_count()
    assert abs(n - analytic) / analytic < 0.05, (n, analytic)


# ---------------------------------------------------------------------------
# attention oracle
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal, window):
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(hd)
    iq, ik = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= ik <= iq
    if window:
        mask &= iq - ik < window
    s_ = jnp.where(mask[None, None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 8), (False, 0)])
@pytest.mark.parametrize("s,hq,hkv", [(32, 4, 2), (48, 6, 1), (64, 4, 4)])
def test_flash_vs_naive(causal, window, s, hq, hkv):
    key = jax.random.key(s * hq + hkv)
    kq, kk, kv = jax.random.split(key, 3)
    b, hd = 2, 16
    q = jax.random.normal(kq, (b, s, hq, hd))
    k = jax.random.normal(kk, (b, s, hkv, hd))
    v = jax.random.normal(kv, (b, s, hkv, hd))
    # NOTE: grouped-head repeat order in the oracle must match (hkv-major)
    out = L.flash_attention(q, k, v, causal=causal, window=window, q_chunk=16, kv_chunk=16)
    ref = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


def test_flash_chunking_invariance():
    key = jax.random.key(0)
    q = jax.random.normal(key, (1, 64, 4, 16))
    k, v = q * 0.5, q * 0.25
    a = L.flash_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    b = L.flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# SSD (mamba2) oracle: chunked vs sequential recurrence
# ---------------------------------------------------------------------------

def _sequential_ssd(xh, bb, cc, dt, a):
    """Step-by-step recurrence; xh (B,S,H,P), bb/cc (B,S,N), dt (B,S,H), a (H,)."""
    b, s, h, p = xh.shape
    n = bb.shape[-1]
    state = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        da = jnp.exp(dt[:, t] * a[None, :])                   # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhnp", bb[:, t], dt[:, t], xh[:, t])
        state = da[:, :, None, None] * state + upd
        ys.append(jnp.einsum("bn,bhnp->bhp", cc[:, t], state))
    return jnp.stack(ys, axis=1)


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (24, 24)])
def test_ssd_chunked_matches_sequential(s, chunk):
    cfg = dataclasses.replace(configs.get("mamba2-1.3b").reduced(), ssm_chunk=chunk)
    b = 2
    di, h, n, hp = cfg.ssm_d_inner, cfg.ssm_n_heads, cfg.ssm_state, cfg.ssm_head_dim
    key = jax.random.key(3)
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (b, s, h, hp))
    bb = jax.random.normal(ks[1], (b, s, n)) * 0.5
    cc = jax.random.normal(ks[2], (b, s, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.3)

    ref = _sequential_ssd(xh, bb, cc, dt, a)

    # drive the chunked path through the same math by stubbing params so that
    # in/out projections are identity-like is complex; instead replicate the
    # chunk algorithm inline (mirrors layers.mamba2 internals)
    q = chunk
    nc = s // q
    da = (dt * a[None, None, :]).reshape(b, nc, q, h)
    cum = jnp.cumsum(da, axis=2)
    xsc = xh.reshape(b, nc, q, h, hp)
    bbc = bb.reshape(b, nc, q, n)
    ccc = cc.reshape(b, nc, q, n)
    dtc = dt.reshape(b, nc, q, h)
    state = jnp.zeros((b, h, n, hp))
    outs = []
    for c in range(nc):
        cumk = cum[:, c]
        seg = cumk[:, :, None, :] - cumk[:, None, :, :]
        iq = jnp.arange(q)
        causal = iq[:, None] >= iq[None, :]
        l_ = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bqn,bkn->bqk", ccc[:, c], bbc[:, c])
        w_ = cb[..., None] * l_ * dtc[:, c][:, None, :, :]
        y = jnp.einsum("bqkh,bkhp->bqhp", w_, xsc[:, c])
        y += jnp.einsum("bqn,bhnp,bqh->bqhp", ccc[:, c], state, jnp.exp(cumk))
        total = cumk[:, -1, :]
        decay_rest = jnp.exp(total[:, None, :] - cumk)
        upd = jnp.einsum("bkn,bkh,bkhp->bhnp", bbc[:, c], dtc[:, c] * decay_rest, xsc[:, c])
        state = jnp.exp(total)[:, :, None, None] * state + upd
        outs.append(y)
    out = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# prefill + decode == teacher forcing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_prefill_decode_matches_forward(name):
    cfg = configs.get(name).reduced()
    if cfg.n_experts:
        # capacity dropping makes train-form vs decode-form diverge by design;
        # test the drop-free regime (see DESIGN.md section 5)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_params(cfg, jax.random.key(0))
    B, S, extra = 2, 24, 3
    toks, kw = _batch(cfg, jax.random.key(1), B, S, extra)
    h, _ = T.forward(params, cfg, toks, **kw)
    full_logits = T.logits_for(params, cfg, h[:, -1:])
    for kv_mode in ["dense"] + (["compressed"] if cfg.n_heads else []):
        cache, _ = E.prefill(
            params, cfg, toks[:, :S],
            seq_len=S + extra + (cfg.prefix_embeds or 0),
            kv_mode=kv_mode, num_planes=2, **kw,
        )
        logits = None
        for i in range(extra):
            logits, cache = E.decode_step(
                params, cfg, cache, toks[:, S + i : S + i + 1],
                kv_mode=kv_mode, num_planes=2,
            )
        rel = float(jnp.max(jnp.abs(full_logits - logits))) / (
            float(jnp.max(jnp.abs(full_logits))) + 1e-9
        )
        tol = 1e-3 if kv_mode == "dense" else 0.06
        assert rel < tol, (name, kv_mode, rel)


def test_sliding_window_ring_eviction():
    """Ring cache with W < seq still matches teacher forcing (SWA semantics)."""
    cfg = dataclasses.replace(configs.get("h2o-danube-1.8b").reduced(), sliding_window=8)
    params = T.init_params(cfg, jax.random.key(0))
    B, S, extra = 1, 16, 6
    toks = jax.random.randint(jax.random.key(1), (B, S + extra), 0, cfg.vocab_size)
    h, _ = T.forward(params, cfg, toks)
    full_logits = T.logits_for(params, cfg, h[:, -1:])
    cache, _ = E.prefill(params, cfg, toks[:, :S], seq_len=S + extra)
    assert cache["slot_pos"].shape[0] == 8          # ring allocates the window
    logits = None
    for i in range(extra):
        logits, cache = E.decode_step(params, cfg, cache, toks[:, S + i : S + i + 1])
    rel = float(jnp.max(jnp.abs(full_logits - logits))) / float(jnp.max(jnp.abs(full_logits)))
    assert rel < 1e-3


def test_moe_load_balance_loss_positive():
    cfg = configs.get("deepseek-moe-16b").reduced()
    params = T.init_params(cfg, jax.random.key(0))
    toks, _ = _batch(cfg, jax.random.key(1))
    _, aux = T.forward(params, cfg, toks)
    assert float(aux) > 0.0
