"""Fault-tolerance contract of repro.checkpoint.CheckpointManager.

The three guarantees a 1000-node train loop leans on:
  * a crash mid-save never corrupts the previous _COMMITTED step (atomic
    tmp-dir + rename + marker commit);
  * keep-last-k GC deletes only COMMITTED steps (crashed leftovers are not
    silently reaped, half-written tmp dirs are not counted as checkpoints);
  * integer/bool leaves round-trip raw and bit-exact under compress=True.
Plus the MANIFEST-v2 single-stream layout and its partial-restore path.
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.codec.plan import Bound
from repro.core.codec.tree import TreeCodec


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": (np.cumsum(rng.standard_normal(50_000)) * 0.01).astype(np.float32),
        "step": np.int64(seed),
        "counts": rng.integers(0, 1 << 40, size=300).astype(np.int64),
        "mask": rng.integers(0, 2, size=200).astype(bool),
        "bytes8": rng.integers(0, 255, size=100).astype(np.uint8),
    }


def test_crash_mid_save_keeps_previous_step_restorable(tmp_path, monkeypatch):
    m = CheckpointManager(str(tmp_path), compress=True, bound=Bound.rel(1e-4))
    t0 = _tree(0)
    m.save(0, t0)
    assert m.all_steps() == [0]

    # crash while the step-1 stream is being written (before the marker)
    def boom(self, tree, fileobj):
        fileobj.write(b"half a stream")
        raise OSError("disk died mid-save")

    monkeypatch.setattr(TreeCodec, "compress_tree", boom)
    with pytest.raises(OSError):
        m.save(1, _tree(1))
    monkeypatch.undo()

    # the crashed step is not committed, the previous one restores cleanly
    assert m.all_steps() == [0]
    assert m.latest_step() == 0
    restored, step = m.restore(t0)
    assert step == 0
    np.testing.assert_array_equal(t0["counts"], restored["counts"])
    # and a later successful save of the same step replaces the wreckage
    m.save(1, _tree(1))
    assert m.all_steps() == [0, 1]


def test_uncommitted_dir_is_ignored_and_not_restored(tmp_path):
    m = CheckpointManager(str(tmp_path), compress=False)
    m.save(3, _tree(3))
    # a crashed writer's directory: structure present, marker missing
    fake = tmp_path / "step_000000009"
    fake.mkdir()
    (fake / "MANIFEST.json").write_text(json.dumps({"step": 9, "leaves": []}))
    assert m.all_steps() == [3]
    _, step = m.restore(_tree(3))
    assert step == 3


def test_gc_deletes_only_committed_steps(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, compress=False)
    # an uncommitted leftover must survive GC (it is evidence of a crash,
    # not a checkpoint) and never count against keep-last-k
    leftover = tmp_path / "step_000000001"
    leftover.mkdir()
    (leftover / "partial.bin").write_bytes(b"x" * 10)
    for s in (2, 3, 4, 5):
        m.save(s, _tree(s))
    assert m.all_steps() == [4, 5]
    assert leftover.exists(), "GC reaped an uncommitted directory"
    for s in (2, 3):
        assert not (tmp_path / f"step_{s:09d}").exists()


def test_integer_leaves_roundtrip_raw_bit_exact(tmp_path):
    m = CheckpointManager(str(tmp_path), compress=True, bound=Bound.rel(1e-2))
    t = _tree(7)
    m.save(0, t)
    with open(tmp_path / "step_000000000" / "MANIFEST.json") as f:
        manifest = json.load(f)
    codec_by_name = {m_["name"]: m_["codec"] for m_ in manifest["leaves"]}
    for name in ("step", "counts", "mask", "bytes8"):
        assert codec_by_name[name] == "raw", name
    restored, _ = m.restore(t)
    for name in ("step", "counts", "mask", "bytes8"):
        got = np.asarray(restored[name])
        assert got.dtype == np.asarray(t[name]).dtype
        np.testing.assert_array_equal(np.asarray(t[name]), got)


def test_manifest_v2_single_stream_and_partial_restore(tmp_path):
    m = CheckpointManager(str(tmp_path), compress=True, bound=Bound.rel(1e-4))
    t = _tree(11)
    m.save(0, t)
    d = tmp_path / "step_000000000"
    with open(d / "MANIFEST.json") as f:
        manifest = json.load(f)
    assert manifest["manifest_version"] == 2
    # ONE stream file per step (plus manifest + marker), not one per leaf
    files = sorted(os.listdir(d))
    assert files == ["MANIFEST.json", "_COMMITTED", manifest["file"]]
    part = m.restore_leaves(["step", "w"])
    assert set(part) == {"step", "w"}
    assert int(part["step"]) == 11
    e = 1e-4 * float(t["w"].max() - t["w"].min())
    assert np.abs(part["w"] - t["w"]).max() <= e


def test_v1_checkpoint_layout_still_restores(tmp_path):
    """Checkpoints written by the pre-TreeCodec manager (one .bin per leaf,
    no manifest_version) remain restorable."""
    t = {"w": _tree(5)["w"], "step": np.int64(5)}
    d = tmp_path / "step_000000005"
    d.mkdir()
    from repro.core.codec import SZxCodec

    codec = SZxCodec()
    leaves = []
    for i, (name, arr) in enumerate((("step", t["step"]), ("w", t["w"]))):
        arr = np.asarray(arr)
        fn = f"{i:05d}.bin"
        if name == "w":
            data = codec.compress(arr, Bound.rel(1e-4))
            leaf_codec = "szx"
        else:
            data = arr.tobytes()
            leaf_codec = "raw"
        (d / fn).write_bytes(data)
        leaves.append(
            {"name": name, "file": fn, "shape": list(arr.shape),
             "dtype": str(arr.dtype), "codec": leaf_codec,
             "raw_bytes": arr.nbytes, "stored_bytes": len(data)}
        )
    (d / "MANIFEST.json").write_text(
        json.dumps({"step": 5, "time": 0.0, "leaves": leaves})
    )
    (d / "_COMMITTED").write_text("ok")
    m = CheckpointManager(str(tmp_path), compress=True, bound=Bound.rel(1e-4))
    restored, step = m.restore(t)
    assert step == 5
    assert int(restored["step"]) == 5
    e = 1e-4 * float(t["w"].max() - t["w"].min())
    assert np.abs(np.asarray(restored["w"]) - t["w"]).max() <= e
    part = m.restore_leaves(["step"])
    assert int(part["step"]) == 5


def test_async_save_surfaces_errors_on_wait(tmp_path, monkeypatch):
    m = CheckpointManager(str(tmp_path), compress=True, async_save=True)
    m.save(0, _tree(0))
    m.wait()
    assert m.all_steps() == [0]

    def boom(self, tree, fileobj):
        raise RuntimeError("async writer died")

    monkeypatch.setattr(TreeCodec, "compress_tree", boom)
    m.save(1, _tree(1))
    with pytest.raises(RuntimeError, match="async writer died"):
        m.wait()
    assert m.all_steps() == [0]


def test_restore_leaf_slice_reads_only_intersecting_frames(tmp_path):
    """Store-backed sliced restore: leading-axis rows of a leaf come back
    bound-respecting, and only the frames covering those rows are read."""
    m = CheckpointManager(
        str(tmp_path), keep=1, compress=True, bound=Bound.rel(1e-5),
        chunk_bytes=1 << 18,           # force several frames per big leaf
    )
    rng = np.random.default_rng(7)
    w = (np.cumsum(rng.standard_normal(300_000)) * 0.01).astype(np.float32)
    tree = {
        "emb": w.reshape(3000, 100),
        "vec": w[:70_000].astype(np.float64),
        "ids": np.arange(400, dtype=np.int32).reshape(100, 4),
    }
    m.save(0, tree)
    e32 = 1e-5 * float(w.max() - w.min())

    # slices, ints, negative rows; dtype + shape preserved
    sl = m.restore_leaf_slice("emb", slice(100, 130))
    assert sl.shape == (30, 100) and sl.dtype == np.float32
    assert np.abs(sl - tree["emb"][100:130]).max() <= e32
    one = m.restore_leaf_slice("emb", -1)
    assert one.shape == (100,)
    assert np.abs(one - tree["emb"][-1]).max() <= e32
    v = m.restore_leaf_slice("vec", slice(60_000, 70_000))
    assert v.dtype == np.float64 and v.shape == (10_000,)
    # raw (integer) leaves slice bit-exactly
    np.testing.assert_array_equal(
        m.restore_leaf_slice("ids", slice(10, 20)), tree["ids"][10:20]
    )
    with pytest.raises(KeyError):
        m.restore_leaf_slice("nope", slice(0, 1))
    with pytest.raises(ValueError):
        m.restore_leaf_slice("emb", slice(0, 10, 2))
    with pytest.raises(IndexError):
        m.restore_leaf_slice("emb", 99_999)
    # empty/reversed slices follow numpy semantics on every codec path
    for empty in (slice(2, 2), slice(5, 3), slice(3000, 9999)):
        assert m.restore_leaf_slice("emb", empty).shape == (0, 100)
        assert m.restore_leaf_slice("ids", empty).shape == (0, 4)
    assert m.restore_leaf_slice("emb", empty).dtype == np.float32

    # seek-spy: only the emb frames intersecting rows [0, 30) are fully read
    with open(os.path.join(tmp_path, "step_000000000", "MANIFEST.json")) as f:
        manifest = json.load(f)
    by_name = {mm["name"]: mm for mm in manifest["leaves"]}
    lo_f, hi_f = by_name["emb"]["frames"]
    frames = manifest["frames"]

    import repro.checkpoint.manager as mgr_mod

    reads = []
    real_open = open

    class Spy:
        def __init__(self, raw):
            self.raw = raw

        def seek(self, *a):
            return self.raw.seek(*a)

        def tell(self):
            return self.raw.tell()

        def read(self, n=-1):
            off = self.raw.tell()
            data = self.raw.read(n)
            if data:
                reads.append((off, len(data)))
            return data

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            self.raw.close()

    def spy_open(path, mode="r", *a, **kw):
        f = real_open(path, mode, *a, **kw)
        return Spy(f) if str(path).endswith("tree.szt") else f

    try:
        mgr_mod.open = spy_open        # shadow builtins.open for the module
        out = m.restore_leaf_slice("emb", slice(0, 30))
    finally:
        del mgr_mod.open
    assert np.abs(out - tree["emb"][0:30]).max() <= e32
    # full-frame reads happened only inside the first emb frame's byte range
    # (plus 58-byte header peeks at later emb frames until the walk stops)
    first = frames[lo_f]
    full_reads = [(o, ln) for o, ln in reads if ln > 64]
    assert full_reads, "no frame payload read at all?"
    for off, ln in full_reads:
        assert first[0] <= off and off + ln <= first[0] + first[1], (
            f"read ({off}, {ln}) outside the first emb frame {first}"
        )
