"""Unified telemetry layer (repro.obs) tests.

Covers registry thread-safety under concurrent observers, span nesting in
the Chrome trace export, Prometheus text exposition validity (every line
parsed), disabled-mode no-op guarantees (spy asserts ZERO registry calls
from the codec/store hot paths), per-frame stream stats against container
ground truth across dtypes x stage on/off, byte-identity of compressed
output with telemetry on vs off, the serve tier's ``/v1/metrics`` content
negotiation, and the ``Metrics._pct`` ceil-rank percentile pins.
"""
from __future__ import annotations

import io
import json
import re
import threading

import numpy as np
import pytest

from repro import obs
from repro.core.codec import container
from repro.core.codec.plan import Bound
from repro.core.codec.szx_codec import SZxCodec
from repro.obs.registry import Registry
from repro.serve.service.metrics import Metrics
from repro.serve.store_service import make_service
from repro.store import ArrayStore

try:
    import ml_dtypes

    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes ships with jax
    BF16 = None


@pytest.fixture
def obs_on():
    """Telemetry enabled on a clean global registry; restored afterwards."""
    was = obs.enabled()
    obs.reset()
    obs.enable()
    yield obs.REGISTRY
    if not was:
        obs.disable()
    obs.reset()


@pytest.fixture
def obs_off():
    """Telemetry force-disabled; restored afterwards."""
    was = obs.enabled()
    obs.disable()
    yield
    if was:
        obs.enable()


def _walk(n, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = (np.cumsum(rng.standard_normal(n)) * 0.01).astype(dtype)
    x[: n // 4] = x.flat[0]                       # some constant blocks
    return x


# ---------------------------------------------------------------------------
# registry: thread safety
# ---------------------------------------------------------------------------
def test_registry_concurrent_counters_exact():
    reg = Registry()
    nthreads, nincs = 8, 2000

    def work():
        c = reg.counter("t.hits")
        h = reg.histogram("t.lat")
        for i in range(nincs):
            c.inc()
            reg.counter("t.bytes", route=f"/r{i % 3}").inc(2)
            h.observe(1e-3)

    threads = [threading.Thread(target=work) for _ in range(nthreads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("t.hits").value == nthreads * nincs
    total = sum(
        reg.counter("t.bytes", route=f"/r{i}").value for i in range(3)
    )
    assert total == nthreads * nincs * 2
    _counts, s, count = reg.histogram("t.lat").value
    assert count == nthreads * nincs
    assert s == pytest.approx(1e-3 * count)


def test_registry_concurrent_span_recording():
    reg = Registry()
    nthreads, nspans = 6, 300

    def work(tid):
        for i in range(nspans):
            reg.record_span("s", i, 10, tid, 1, None)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(nthreads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    count, total = reg.span_aggregates()["s"]
    assert count == nthreads * nspans
    assert total == 10 * count


def test_registry_kind_conflict_raises():
    reg = Registry()
    reg.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")


def test_span_log_bound_keeps_aggregates():
    reg = Registry(max_spans=4)
    for i in range(10):
        reg.record_span("s", i, 5, 0, 1, None)
    assert len(reg.spans()) == 4                  # log bounded
    assert reg.span_aggregates()["s"] == (10, 50)  # totals survive


# ---------------------------------------------------------------------------
# spans: nesting order in the Chrome trace
# ---------------------------------------------------------------------------
def test_span_nesting_chrome_trace(obs_on):
    with obs.span("outer", step=1):
        with obs.span("inner_a"):
            pass
        with obs.span("inner_b"):
            pass
    doc = obs.chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    ev = {e["name"]: e for e in doc["traceEvents"]}
    assert set(ev) == {"outer", "inner_a", "inner_b"}
    outer, a, b = ev["outer"], ev["inner_a"], ev["inner_b"]
    assert outer["ph"] == "X" and outer["args"]["step"] == 1
    assert a["tid"] == b["tid"] == outer["tid"]
    # timestamp containment: children inside the parent, a before b
    for child in (a, b):
        assert outer["ts"] <= child["ts"]
        assert child["ts"] + child["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert a["ts"] <= b["ts"]
    assert a["args"]["depth"] == b["args"]["depth"] == outer["args"]["depth"] + 1
    # events are sorted by start time
    ts = [e["ts"] for e in doc["traceEvents"]]
    assert ts == sorted(ts)


def test_write_chrome_trace_valid_json(obs_on, tmp_path):
    with obs.span("alpha"):
        pass
    p = tmp_path / "trace.json"
    obs.write_chrome_trace(str(p))
    doc = json.loads(p.read_text())
    assert [e["name"] for e in doc["traceEvents"]] == ["alpha"]


def test_traced_decorator_responds_to_late_enable():
    obs.reset()
    obs.disable()

    @obs.traced("deco.fn")
    def fn():
        return 7

    try:
        assert fn() == 7
        assert obs.REGISTRY.span_aggregates() == {}
        obs.enable()
        assert fn() == 7
        assert obs.REGISTRY.span_aggregates()["deco.fn"][0] == 1
    finally:
        obs.disable()
        obs.reset()


# ---------------------------------------------------------------------------
# Prometheus text exposition: parse every line
# ---------------------------------------------------------------------------
_PROM_SAMPLE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"                 # metric name
    r'(\{[a-zA-Z0-9_]+="[^"]*"(,[a-zA-Z0-9_]+="[^"]*")*\})?'
    r" -?[0-9.eE+\-]+(\+Inf)?$"
)
_PROM_TYPE = re.compile(
    r"^# TYPE [a-zA-Z_:][a-zA-Z0-9_:]*"
    r" (counter|gauge|histogram)$"
)


def test_prometheus_text_every_line_valid(obs_on):
    obs.counter("codec.compress.calls").inc(3)
    obs.counter("serve.requests", route="/v1/read").inc()
    obs.gauge("ingest.lookahead").set(2)
    h = obs.histogram("codec.compress.seconds")
    for v in (5e-5, 2e-3, 0.3, 50.0):
        h.observe(v)
    with obs.span("unit.span"):
        pass
    text = obs.prometheus_text()
    assert text.endswith("\n")
    lines = text.strip().split("\n")
    assert lines, "empty exposition"
    for line in lines:
        if line.startswith("#"):
            assert _PROM_TYPE.match(line), line
        else:
            assert _PROM_SAMPLE.match(line), line
    # histogram: cumulative buckets monotonic, +Inf equals _count
    buckets = [
        float(line.rsplit(" ", 1)[1])
        for line in lines
        if line.startswith("szx_codec_compress_seconds_bucket")
    ]
    assert buckets == sorted(buckets)
    count = [
        float(line.rsplit(" ", 1)[1])
        for line in lines
        if line.startswith("szx_codec_compress_seconds_count")
    ][0]
    assert buckets[-1] == count == 4
    # span aggregates exported as counters
    assert any(line.startswith('szx_span_count{name="unit.span"} ')
               for line in lines)
    # dotted names mapped, labels kept
    assert 'szx_serve_requests{route="/v1/read"} 1' in lines


def test_summary_renders(obs_on):
    assert obs.summary() == "(no telemetry recorded)\n"
    obs.counter("a.b").inc()
    with obs.span("s"):
        pass
    out = obs.summary()
    assert "a.b" in out and "s" in out and "spans" in out


# ---------------------------------------------------------------------------
# disabled mode: hot paths never touch the registry
# ---------------------------------------------------------------------------
def test_disabled_mode_is_noop(obs_off, monkeypatch, tmp_path):
    calls = []
    for name in ("_get", "record_span", "record_frame"):
        orig = getattr(Registry, name)

        def spy(self, *a, _orig=orig, _n=name, **kw):
            calls.append(_n)
            return _orig(self, *a, **kw)

        monkeypatch.setattr(Registry, name, spy)

    x = _walk(4096)
    codec = SZxCodec(backend="numpy", stage="deflate")
    buf = codec.compress(x, Bound.abs(1e-3))
    codec.decompress(buf)
    codec.decompress_range(buf, 0, 4)
    bio = io.BytesIO()
    codec.dump_chunked(x, bio, Bound.abs(1e-3), chunk_bytes=4096)
    bio.seek(0)
    codec.load_chunked(bio)
    szs = tmp_path / "a.szs"
    ArrayStore.save(str(szs), x.reshape(64, 64), Bound.abs(1e-3),
                    chunk_shape=(16, 64))
    with ArrayStore.open(str(szs)) as ca:
        ca[0:20, 0:32]
    assert calls == []
    # span() does not even allocate: the shared null singleton comes back
    assert obs.span("a") is obs.span("b")


def test_enabled_output_byte_identical(tmp_path):
    """SZX_OBS only observes: compressed bytes identical on vs off."""
    x = _walk(8192)
    obs.disable()
    obs.reset()
    try:
        bio_off = io.BytesIO()
        SZxCodec(backend="numpy", stage="deflate").dump_chunked(
            x, bio_off, Bound.abs(1e-3), chunk_bytes=8192)
        obs.enable()
        bio_on = io.BytesIO()
        SZxCodec(backend="numpy", stage="deflate").dump_chunked(
            x, bio_on, Bound.abs(1e-3), chunk_bytes=8192)
    finally:
        obs.disable()
        obs.reset()
    assert bio_off.getvalue() == bio_on.getvalue()


# ---------------------------------------------------------------------------
# per-frame stream stats vs container ground truth
# ---------------------------------------------------------------------------
def _dtypes():
    out = [np.dtype(np.float32), np.dtype(np.float64)]
    if BF16 is not None:
        out.append(BF16)
    return out


@pytest.mark.parametrize("dtype", _dtypes(), ids=lambda d: d.name)
@pytest.mark.parametrize("stage_name", [None, "deflate"])
def test_frame_stats_ground_truth(dtype, stage_name, obs_on):
    from repro.obs import stream_stats

    x = _walk(6000, dtype=dtype)
    codec = SZxCodec(backend="numpy", stage=stage_name)
    bio = io.BytesIO()
    codec.dump_chunked(x, bio, Bound.abs(1e-3), chunk_bytes=8192, index=False)
    data = bio.getvalue()

    # ground truth straight from the container layer
    frames = []
    off = 0
    while off < len(data):
        _m, _v, flags, seq, ln = container.FRAME_HEADER.unpack_from(data, off)
        frame = data[off:off + container.FRAME_HEADER.size + ln]
        frames.append((frame, flags, seq))
        off += container.FRAME_HEADER.size + ln
        if flags & container.FLAG_LAST:
            break

    recs = [stream_stats.frame_stats(f) for f, _fl, _s in frames]
    total_elems = sum(r["elements"] for r in recs)
    assert total_elems == x.size
    for rec, (frame, flags, seq) in zip(recs, frames):
        assert rec["seq"] == seq
        assert rec["frame_bytes"] == len(frame)
        assert rec["dtype"] == dtype.name
        # stage code in the record matches the frame's flag bits
        assert rec["stage"] == container.stage_of_flags(flags)
        if stage_name is None:
            assert rec["stage"] == 0
            assert rec["staged_mid_bytes"] == rec["raw_mid_bytes"]
        # CR against raw bytes of this frame's elements
        assert rec["ratio"] == pytest.approx(
            rec["elements"] * dtype.itemsize / rec["frame_bytes"])
        # const fraction + L histogram against a decoded-payload ground truth
        payload, _ = container.destage_frame_payload(
            frame[container.FRAME_HEADER.size:], flags)
        h = container.HEADER.unpack_from(payload, 0)
        _magic, _ver, _dc, bs, n, _e, nb, nnc, _nmid = h
        assert rec["nblocks"] == nb
        assert rec["const_blocks"] == nb - nnc
        assert rec["const_fraction"] == pytest.approx(
            (nb - nnc) / nb if nb else 0.0)
        assert sum(rec["l_hist"]) == nnc * bs

    # the codec's own frame log (fed by container.build_frame) agrees
    logged = {r["seq"]: r for r in obs.REGISTRY.frames()}
    for rec in recs:
        got = logged[rec["seq"]]
        for k in ("elements", "frame_bytes", "stage", "raw_mid_bytes",
                  "staged_mid_bytes"):
            assert got[k] == rec[k], k


def test_l_hist_matches_direct_bincount(obs_on):
    """L-code histogram via the byte-level table == per-element bincount."""
    from repro.obs import stream_stats

    x = _walk(5000)
    codec = SZxCodec(backend="numpy")
    buf = codec.compress(x, Bound.abs(1e-3))
    st = stream_stats.payload_stats(buf)
    sec = container.parse_stream_sections(buf, backend="numpy")
    L = np.asarray(sec.L)
    nonconst = ~np.asarray(sec.const)
    want = np.bincount(L[nonconst].ravel(), minlength=4)
    assert st["l_hist"] == [int(v) for v in want]


def test_l2bit_hist_matches_table_all_shapes():
    """Popcount-path 2-bit counting == byte-table bincount for every
    word-alignment: odd lengths (unaligned tail) and odd data-pointer
    offsets (unaligned uint64 view)."""
    from repro.obs import stream_stats

    rng = np.random.default_rng(42)
    base = rng.integers(0, 256, 1024 + 16, dtype=np.uint8)
    for off in (0, 1, 3, 7):
        for ln in (0, 1, 7, 8, 9, 64, 1021):
            lb = base[off:off + ln]
            want = stream_stats._l2bit_table().T @ np.bincount(
                lb, minlength=256
            )
            got = stream_stats._l2bit_hist(lb)
            assert np.array_equal(got, want), (off, ln, got, want)


# ---------------------------------------------------------------------------
# serve tier: _pct pins + /v1/metrics negotiation
# ---------------------------------------------------------------------------
def test_pct_ceil_rank_pins():
    samples = [float(v) for v in range(1, 101)]
    assert Metrics._pct(samples, 0.50) == 50.0
    assert Metrics._pct(samples, 0.99) == 99.0
    assert Metrics._pct([10.0, 20.0, 30.0, 40.0], 0.50) == 20.0
    assert Metrics._pct([10.0, 20.0, 30.0, 40.0], 0.99) == 40.0
    assert Metrics._pct([7.0], 0.50) == 7.0
    assert Metrics._pct([7.0], 0.99) == 7.0
    assert Metrics._pct([1.0, 2.0], 0.50) == 1.0
    assert Metrics._pct([1.0, 2.0], 0.99) == 2.0
    assert Metrics._pct([], 0.50) == 0.0


def test_metrics_endpoint_negotiation(tmp_path, obs_on):
    x = _walk(40 * 64).reshape(40, 64)
    szs = tmp_path / "m.szs"
    ArrayStore.save(str(szs), x, Bound.abs(1e-3), chunk_shape=(8, 64))
    service = make_service(str(szs))
    try:
        r = service.handle("GET", "/v1/stores/default/read?roi=0:8,:", {})
        assert r.status == 200
        # JSON default: legacy schema + additive obs key
        r = service.handle("GET", "/v1/metrics", {})
        assert r.content_type == "application/json"
        snap = json.loads(r.body)
        for k in ("requests", "errors", "bytes_sent", "by_route",
                  "by_status", "by_tenant", "latency", "cache"):
            assert k in snap
        assert snap["by_route"]["/v1/stores/default/read"] == 1
        assert "obs" in snap
        assert "serve.requests" in snap["obs"]["metrics"]
        assert "serve.request" in snap["obs"]["spans"]
        # Prometheus on Accept: text/plain
        r = service.handle("GET", "/v1/metrics", {"accept": "text/plain"})
        assert r.status == 200
        assert r.content_type.startswith("text/plain; version=0.0.4")
        text = r.body.decode()
        for line in text.strip().split("\n"):
            if line.startswith("#"):
                assert _PROM_TYPE.match(line), line
            else:
                assert _PROM_SAMPLE.match(line), line
        assert "szx_serve_requests" in text
        assert "szx_store_roi_reads" in text       # store counters flow in
    finally:
        service.close()


def test_metrics_endpoint_json_unchanged_when_disabled(tmp_path, obs_off):
    x = _walk(16 * 64).reshape(16, 64)
    szs = tmp_path / "m2.szs"
    ArrayStore.save(str(szs), x, Bound.abs(1e-3), chunk_shape=(8, 64))
    service = make_service(str(szs))
    try:
        service.handle("GET", "/info", {})
        r = service.handle("GET", "/v1/metrics", {})
        snap = json.loads(r.body)
        assert "obs" not in snap                   # additive key only when on
        assert snap["requests"] == 1
    finally:
        service.close()
