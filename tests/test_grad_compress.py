"""Cross-pod SZx gradient compression: encoded all-reduce correctness and
convergence-safe compressed-DP training (error feedback).

Runs in a subprocess with an 8-device host platform and a (2,2,2)
pod/data/model mesh so the main process keeps 1 device."""
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.core import grad_compress as gc


def test_wire_bytes_accounting():
    # block 64 (shard-local): 1 + 6/64 = 1.094 B/val -> ~3.7x vs fp32
    assert gc.wire_bytes_per_value(1) < 4.0 / 3.6
    assert gc.wire_bytes_per_value(2) < 4.0 / 1.9


def test_encode_decode_leaf_roundtrip():
    g = jnp.asarray(np.random.default_rng(0).standard_normal((333,)), jnp.float32)
    enc = gc._encode_leaf(g, 1, 256)
    dec = gc._decode_leaf(enc, g.shape, jnp.float32, 256)
    # P=1 block quantization: error bounded by per-block 2^(E-6)-ish; check
    # the residual is small relative to the gradient scale
    assert float(jnp.abs(g - dec).max()) < 0.05 * float(jnp.abs(g).max())


CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, numpy as np
import jax, jax.numpy as jnp
from repro import configs
from repro.models import sharding as shard_rules
from repro.optim import AdamW
from repro.train import step as step_mod

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = dataclasses.replace(configs.get("llama3.2-1b").reduced(), n_layers=2)
opt = AdamW(lr=1e-2)

def batches(step):
    rng = np.random.default_rng(step)
    t = rng.integers(0, cfg.vocab_size, (4, 32)).astype(np.int32)
    return {"tokens": jnp.asarray(t),
            "labels": jnp.asarray(np.roll(t, -1, 1))}

losses = {}
for planes in (0, 1):
    state = step_mod.init_state(cfg, opt, jax.random.key(0), ef_planes=planes)
    rules = dict(shard_rules.DEFAULT_RULES, act_batch=("data",))
    with shard_rules.use_rules(mesh, rules):
        fn = jax.jit(step_mod.make_train_step(
            cfg, opt, mesh=mesh, compress_planes=planes))
        ls = []
        for i in range(12):
            state, m = fn(state, batches(i))
            ls.append(float(m["loss"]))
    losses[planes] = ls

l0, l1 = losses[0], losses[1]
assert l0[-1] < l0[0], "uncompressed did not train"
assert l1[-1] < l1[0], "compressed did not train"
# compressed-DP with error feedback tracks the uncompressed loss closely
diff = abs(l0[-1] - l1[-1]) / abs(l0[-1])
assert diff < 0.08, (l0[-1], l1[-1])
print("GRADCOMP-OK", round(l0[-1], 4), round(l1[-1], 4))
"""


def test_compressed_dp_training_matches():
    r = subprocess.run(
        [sys.executable, "-c", CODE],
        capture_output=True, text=True, timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert "GRADCOMP-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


COLLECTIVES_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import grad_compress as gc

mesh = jax.make_mesh((4,), ("x",))
rng = np.random.default_rng(1)
perm = [(i, (i + 1) % 4) for i in range(4)]

def run(fn, x):
    return np.asarray(shard_map(
        fn, mesh=mesh, in_specs=(P("x"),), out_specs=P("x"),
        axis_names={"x"}, check_vma=False,
    )(x))

# compressed ppermute tracks the raw permute within the planes budget
x = rng.normal(size=(4, 8, 64)).astype(np.float32)
a = run(lambda xs: gc.compressed_ppermute(xs[0], "x", perm, num_planes=3)[None], x)
b = run(lambda xs: jax.lax.ppermute(xs[0], "x", perm)[None], x)
assert a.shape == b.shape and np.abs(a - b).max() < 0.05, np.abs(a - b).max()

# compressed all_to_all matches the raw exchange's shape and values
x2 = rng.normal(size=(4, 8, 12, 64)).astype(np.float32)
a2 = run(lambda xs: gc.compressed_all_to_all(xs[0], "x", 0, 1, num_planes=3)[None], x2)
b2 = run(lambda xs: jax.lax.all_to_all(xs[0], "x", 0, 1, tiled=True)[None], x2)
assert a2.shape == b2.shape and np.abs(a2 - b2).max() < 0.05, np.abs(a2 - b2).max()

# blocked-last-axis misuse is rejected
import traceback
try:
    run(lambda xs: gc.compressed_all_to_all(xs[0], "x", 0, 2, num_planes=1)[None], x2)
except ValueError as e:
    assert "blocked last axis" in str(e)
else:
    raise AssertionError("expected ValueError for last-axis exchange")

# gpipe compressed activation shift tracks the exact schedule
from repro.pipeline_par import pipeline_apply
smesh = jax.make_mesh((4,), ("stage",))
ws = (rng.normal(size=(4, 64, 64)) * 0.1).astype(np.float32)
xs = rng.normal(size=(8, 2, 64)).astype(np.float32)
stage = lambda p, x: jnp.tanh(x @ p)
raw = np.asarray(pipeline_apply(stage, smesh)(jnp.asarray(ws), jnp.asarray(xs)))
comp = np.asarray(pipeline_apply(
    stage, smesh, compress_activations=True, num_planes=3,
)(jnp.asarray(ws), jnp.asarray(xs)))
assert np.abs(raw - comp).max() < 0.05, np.abs(raw - comp).max()
print("COLLECTIVES-OK")
"""


def test_compressed_collectives_track_raw():
    r = subprocess.run(
        [sys.executable, "-c", COLLECTIVES_CODE],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert "COLLECTIVES-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
