"""Cross-pod SZx gradient compression: encoded all-reduce correctness and
convergence-safe compressed-DP training (error feedback).

Runs in a subprocess with an 8-device host platform and a (2,2,2)
pod/data/model mesh so the main process keeps 1 device."""
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.core import grad_compress as gc


def test_wire_bytes_accounting():
    # block 64 (shard-local): 1 + 6/64 = 1.094 B/val -> ~3.7x vs fp32
    assert gc.wire_bytes_per_value(1) < 4.0 / 3.6
    assert gc.wire_bytes_per_value(2) < 4.0 / 1.9


def test_encode_decode_leaf_roundtrip():
    g = jnp.asarray(np.random.default_rng(0).standard_normal((333,)), jnp.float32)
    enc = gc._encode_leaf(g, 1, 256)
    dec = gc._decode_leaf(enc, g.shape, jnp.float32, 256)
    # P=1 block quantization: error bounded by per-block 2^(E-6)-ish; check
    # the residual is small relative to the gradient scale
    assert float(jnp.abs(g - dec).max()) < 0.05 * float(jnp.abs(g).max())


CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, numpy as np
import jax, jax.numpy as jnp
from repro import configs
from repro.models import sharding as shard_rules
from repro.optim import AdamW
from repro.train import step as step_mod

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = dataclasses.replace(configs.get("llama3.2-1b").reduced(), n_layers=2)
opt = AdamW(lr=1e-2)

def batches(step):
    rng = np.random.default_rng(step)
    t = rng.integers(0, cfg.vocab_size, (4, 32)).astype(np.int32)
    return {"tokens": jnp.asarray(t),
            "labels": jnp.asarray(np.roll(t, -1, 1))}

losses = {}
for planes in (0, 1):
    state = step_mod.init_state(cfg, opt, jax.random.key(0), ef_planes=planes)
    rules = dict(shard_rules.DEFAULT_RULES, act_batch=("data",))
    with shard_rules.use_rules(mesh, rules):
        fn = jax.jit(step_mod.make_train_step(
            cfg, opt, mesh=mesh, compress_planes=planes))
        ls = []
        for i in range(12):
            state, m = fn(state, batches(i))
            ls.append(float(m["loss"]))
    losses[planes] = ls

l0, l1 = losses[0], losses[1]
assert l0[-1] < l0[0], "uncompressed did not train"
assert l1[-1] < l1[0], "compressed did not train"
# compressed-DP with error feedback tracks the uncompressed loss closely
diff = abs(l0[-1] - l1[-1]) / abs(l0[-1])
assert diff < 0.08, (l0[-1], l1[-1])
print("GRADCOMP-OK", round(l0[-1], 4), round(l1[-1], 4))
"""


def test_compressed_dp_training_matches():
    r = subprocess.run(
        [sys.executable, "-c", CODE],
        capture_output=True, text=True, timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert "GRADCOMP-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
