"""Substrate tests: checkpoint manager, trainer fault tolerance, data
pipeline determinism, compressed in-memory cache, optimizer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.codec.plan import Bound
from repro.data import CompressedInMemoryCache, DataConfig, SyntheticLM
from repro.optim import AdamW, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def _toy_state(key=0):
    k = jax.random.key(key)
    return {
        "w": jax.random.normal(k, (64, 64)),
        "b": jnp.zeros((64,)),
        "nested": {"scale": jnp.ones((3, 5))},
        "step_marker": jnp.int32(0),
    }


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    s = _toy_state()
    m.save(10, s)
    restored, step = m.restore(s)
    assert step == 10
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    s = _toy_state()
    for step in (1, 2, 3, 4):
        m.save(step, s)
    assert m.all_steps() == [3, 4]
    assert m.latest_step() == 4


def test_checkpoint_szx_compression_bounded(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=1, compress=True, bound=Bound.rel(1e-4))
    rng = np.random.default_rng(0)
    s = {"w": jnp.asarray(np.cumsum(rng.standard_normal((1 << 14,)), 0).astype(np.float32))}
    m.save(5, s)
    restored, _ = m.restore(s)
    w0, w1 = np.asarray(s["w"]), np.asarray(restored["w"])
    rng_w = w0.max() - w0.min()
    assert np.abs(w0 - w1).max() <= 1e-4 * rng_w * (1 + 1e-6)
    assert m.stats()["ratio"] > 1.5


def test_checkpoint_atomicity_partial_write_ignored(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    s = _toy_state()
    m.save(1, s)
    # simulate a crashed writer: uncommitted dir without marker
    os.makedirs(tmp_path / "step_000000002")
    (tmp_path / "step_000000002" / "MANIFEST.json").write_text("{}")
    assert m.latest_step() == 1


def test_checkpoint_async(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=1, async_save=True)
    s = _toy_state()
    m.save(7, s)
    m.wait()
    assert m.latest_step() == 7


# ---------------------------------------------------------------------------
# trainer fault tolerance
# ---------------------------------------------------------------------------

def _toy_trainer(tmp_path, fault_hook=None, total=30):
    opt = AdamW(lr=1e-2)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    @jax.jit
    def step_fn(state, batch):
        loss, g = jax.value_and_grad(loss_fn)(state["params"], batch)
        p, o, metrics = opt.update(g, state["opt"], state["params"])
        return {"params": p, "opt": o}, {"loss": loss, **metrics}

    def batch_fn(step):
        rng = np.random.default_rng(step)
        x = rng.standard_normal((8, 16)).astype(np.float32)
        w_true = np.linspace(-1, 1, 16 * 4).reshape(16, 4).astype(np.float32)
        return {"x": x, "y": x @ w_true}

    params = {
        "w": jax.random.normal(jax.random.key(0), (16, 4)) * 0.1,
        "b": jnp.zeros((4,)),
    }
    state = {"params": params, "opt": opt.init(params)}
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    tr = Trainer(
        TrainerConfig(total_steps=total, checkpoint_every=5, max_restarts=3),
        step_fn, batch_fn, ckpt, fault_hook=fault_hook,
    )
    return tr, state


def test_trainer_converges(tmp_path):
    tr, state = _toy_trainer(tmp_path)
    tr.run(state)
    assert tr.history[-1]["loss"] < tr.history[0]["loss"] * 0.5


def test_trainer_restarts_after_injected_fault(tmp_path):
    crashed = {"done": False}

    def fault(step):
        if step == 17 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    tr, state = _toy_trainer(tmp_path, fault_hook=fault)
    tr.run(state)
    assert tr.restarts == 1
    # replayed from the last checkpoint (step 15) and completed
    steps = [h["step"] for h in tr.history]
    assert steps.count(16) == 2          # replayed step
    assert steps[-1] == 29


def test_trainer_gives_up_after_max_restarts(tmp_path):
    def fault(step):
        if step >= 6:
            raise RuntimeError("permafault")

    tr, state = _toy_trainer(tmp_path, fault_hook=fault)
    with pytest.raises(RuntimeError):
        tr.run(state)
    assert tr.restarts == 4


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    ds = SyntheticLM(cfg)
    a = ds.batch_at(3, rank=0, num_ranks=2)
    b = ds.batch_at(3, rank=0, num_ranks=2)
    c = ds.batch_at(3, rank=1, num_ranks=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])   # deterministic
    assert not np.array_equal(a["tokens"], c["tokens"])       # disjoint ranks
    assert a["tokens"].shape == (4, 32)
    assert a["tokens"].max() < 1000 and a["tokens"].min() >= 0
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_compressed_inmemory_cache_bound():
    cache = CompressedInMemoryCache(Bound.abs(1e-3))
    rng = np.random.default_rng(1)
    x = np.cumsum(rng.standard_normal((256, 128)), axis=1).astype(np.float32)
    cache.put("shard0", x)
    y = cache.get("shard0")
    assert np.abs(x - y).max() <= 1e-3
    assert cache.compression_ratio > 1.5


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_moves_toward_minimum():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.ones((4,)) * 5.0}
    state = opt.init(params)
    for _ in range(50):
        g = jax.tree.map(lambda p: 2 * p, params)   # d/dp p^2
        params, state, _ = opt.update(g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.int32(0))) == 0.0
    assert abs(float(s(jnp.int32(10))) - 1.0) < 1e-6
    assert float(s(jnp.int32(100))) < 0.15
