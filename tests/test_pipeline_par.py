"""Pipeline-parallel (GPipe / ppermute) test.

Runs in a subprocess with an 8-device host platform so the main test process
keeps its single CPU device (per the dry-run isolation rule)."""
import subprocess
import sys

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.pipeline_par import pipeline_apply

mesh = jax.make_mesh((4,), ("stage",))
n_stages, n_micro, mb, d = 4, 8, 2, 16

key = jax.random.key(0)
ws = jax.random.normal(key, (n_stages, d, d)) * 0.3

def stage_fn(w, x):
    return jnp.tanh(x @ w)

xs = jax.random.normal(jax.random.key(1), (n_micro, mb, d))
out = pipeline_apply(stage_fn, mesh)( {"w": ws}["w"], xs )

# reference: sequential application of all stages
ref = xs
for i in range(n_stages):
    ref = jnp.tanh(ref @ ws[i])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-6)
print("PIPELINE-OK")
"""


def test_gpipe_matches_sequential():
    r = subprocess.run(
        [sys.executable, "-c", CODE],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert "PIPELINE-OK" in r.stdout, r.stdout + r.stderr
