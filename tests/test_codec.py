"""Tests for the layered repro.core.codec package.

Covers the four satellite areas: chunked-vs-monolithic equivalence,
multi-dtype error-bound adherence, corrupt/truncated stream rejection, and a
golden-bytes pin of the v2 container layout (backward compatibility with the
pre-refactor monolith).
"""
import hashlib
import io
import os

import numpy as np
import pytest

from repro.core import szx
from repro.core.codec import (
    PlanesCodec,
    SZxCodec,
    container,
    plan,
)

try:
    import ml_dtypes

    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes ships with jax
    BF16 = None

CODEC = SZxCodec(backend="numpy")


def _walk(n, seed=0, scale=0.01, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (np.cumsum(rng.standard_normal(n)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# golden bytes: v2 container layout is pinned (backward compatibility)
# ---------------------------------------------------------------------------

GOLDEN_SHA256 = {
    # digests produced by the pre-refactor monolithic encoder (seed commit)
    "sin_bs128_abs1e-3": "5a742780e9a5b13da14544a98e9c137e0d2ed0af99d54932497037e79fd2ec5e",
    "walk_bs64_rel1e-3": "8268a4b101cb0f0008d5e1f0279de3021c5ed93d5de50f92ad1dd0c61f9bb1c9",
    "const_bs128": "b1e68c21ff4f2c1a2e782f54a8c46a151610398ac78ae536d3460f0e8a0879fd",
    "spiky_bs32_abs1e-5": "f47e60993b1aa622798eb1d605d066665e6aac9c32ec672d3fe817b601f6bcfd",
}


def _golden_cases():
    t = np.linspace(0, 4 * np.pi, 10000).astype(np.float32)
    rng = np.random.default_rng(42)
    walk = np.cumsum(rng.standard_normal(7777)).astype(np.float32)
    spiky = rng.standard_normal(3001).astype(np.float32)
    spiky[::97] *= 1e4
    yield "sin_bs128_abs1e-3", szx.compress(
        np.sin(t) * np.exp(-t / 20), 1e-3, backend="numpy"
    )
    yield "walk_bs64_rel1e-3", szx.compress(
        walk, plan.Bound.rel(1e-3), block_size=64, backend="numpy"
    )
    yield "const_bs128", szx.compress(np.full(1000, 7.5, np.float32), 1e-3, backend="numpy")
    yield "spiky_bs32_abs1e-5", szx.compress(spiky, 1e-5, block_size=32, backend="numpy")


def test_golden_bytes_v2_layout():
    for name, buf in _golden_cases():
        assert hashlib.sha256(buf).hexdigest() == GOLDEN_SHA256[name], name
    # and the header prefix itself is stable: magic | v2 | dtype f32
    buf = next(_golden_cases())[1]
    assert buf[:4] == b"SZXJ" and buf[4] == 2 and buf[5] == 0


def test_shim_matches_codec_api():
    """core.szx is a thin shim: identical bytes to SZxCodec for f32."""
    x = _walk(12345, seed=3)
    assert szx.compress(x, 1e-3, backend="numpy") == CODEC.compress(x, 1e-3)
    buf = CODEC.compress(x, 1e-3)
    np.testing.assert_array_equal(szx.decompress(buf), CODEC.decompress(buf))


# ---------------------------------------------------------------------------
# chunked streaming
# ---------------------------------------------------------------------------

def test_chunked_roundtrip_and_per_chunk_bit_exactness():
    x = _walk(1_000_003, seed=1)
    e = 1e-3
    frames = list(CODEC.compress_chunked(x, e, chunk_bytes=1 << 20))
    assert len(frames) > 2
    per = plan.chunk_elements(CODEC.block_size, 1 << 20, 4)
    for i, payload in enumerate(container.iter_frames(frames)):
        mono = CODEC.compress(x[i * per : (i + 1) * per], e)
        assert payload == mono, f"chunk {i} diverges from monolithic bytes"
    # all three frame-source forms decode identically
    y = CODEC.decompress_chunked(frames)
    np.testing.assert_array_equal(y, CODEC.decompress_chunked(b"".join(frames)))
    np.testing.assert_array_equal(y, CODEC.load_chunked(io.BytesIO(b"".join(frames))))
    assert np.abs(x - y).max() <= e


def test_chunked_rel_mode_matches_monolithic_resolution():
    """'rel' resolves the bound over the FULL array, not per chunk."""
    x = _walk(300_000, seed=2, scale=1.0)
    frames = list(CODEC.compress_chunked(x, plan.Bound.rel(1e-3), chunk_bytes=1 << 19))
    hdr_e = [container.HEADER.unpack_from(p, 0)[5] for p in container.iter_frames(frames)]
    e_mono = container.HEADER.unpack_from(CODEC.compress(x, plan.Bound.rel(1e-3)), 0)[5]
    assert all(e == e_mono for e in hdr_e)
    y = CODEC.decompress_chunked(frames)
    assert np.abs(x - y).max() <= e_mono


def test_chunked_file_dump_load(tmp_path):
    x = _walk(200_000, seed=4)
    p = tmp_path / "field.szxf"
    with open(p, "wb") as f:
        written = CODEC.dump_chunked(x, f, 1e-4, chunk_bytes=1 << 18)
    assert written == os.path.getsize(p)
    with open(p, "rb") as f:
        y = CODEC.load_chunked(f)
    assert np.abs(x - y).max() <= 1e-4
    # preallocated (bounded-memory) load: identical result, wrong n rejected
    with open(p, "rb") as f:
        y2 = CODEC.load_chunked(f, n=x.size)
    np.testing.assert_array_equal(y, y2)
    for bad_n in (x.size - 1, x.size + 1):
        with open(p, "rb") as f, pytest.raises(ValueError):
            CODEC.load_chunked(f, n=bad_n)


@pytest.mark.parametrize(
    "dtype,n,e_rel",
    [
        (np.float32, 1 << 26, 1e-3),            # 256 MiB
        (np.float64, 1 << 25, 1e-4),            # 256 MiB
        pytest.param(
            BF16, 1 << 27, 1e-2,                # 256 MiB
            marks=pytest.mark.skipif(BF16 is None, reason="no ml_dtypes"),
        ),
    ],
    ids=["f32", "f64", "bf16"],
)
def test_chunked_256mb_field(dtype, n, e_rel):
    """Acceptance: >=256 MB chunked == monolithic bit-for-bit per chunk, and
    the error bound holds, for f32 / f64 / bf16 inputs.

    Verified streamingly (chunk by chunk) so the test itself stays in
    bounded memory.
    """
    itemsize = np.dtype(dtype).itemsize
    assert n * itemsize >= 256 << 20
    rng = np.random.default_rng(5)
    # blockwise-smooth field: varied reqlen without giant temporaries
    base = np.cumsum(rng.standard_normal(n // 4096)).astype(np.float32)
    x = (np.repeat(base, 4096) + rng.standard_normal(n).astype(np.float32) * 0.01)
    x = x.astype(dtype)
    spec = plan.spec_for(dtype)
    e = plan.resolve_error_bound(x, e_rel, "rel", spec)
    chunk_bytes = 32 << 20
    per = plan.chunk_elements(CODEC.block_size, chunk_bytes, itemsize)
    nchunks = (n + per - 1) // per
    seen = 0
    total_stored = 0
    for i, payload in enumerate(
        container.iter_frames(CODEC.compress_chunked(x, e, chunk_bytes=chunk_bytes))
    ):
        sl = x[i * per : (i + 1) * per]
        assert payload == CODEC.compress(sl, e), f"chunk {i} not bit-exact"
        y = CODEC.decompress(payload)
        assert y.dtype == np.dtype(dtype)
        err = np.abs(sl.astype(np.float64) - y.astype(np.float64)).max()
        assert err <= e, f"chunk {i}: {err} > {e}"
        seen += y.size
        total_stored += len(payload)
    assert seen == n and i == nchunks - 1
    assert total_stored < n * itemsize  # it actually compressed


# ---------------------------------------------------------------------------
# multi-dtype error-bound adherence
# ---------------------------------------------------------------------------

_DTYPES = [np.float32, np.float64, np.float16] + ([BF16] if BF16 is not None else [])


@pytest.mark.parametrize("dtype", _DTYPES, ids=lambda d: np.dtype(d).name)
def test_multi_dtype_error_bound(dtype):
    rng = np.random.default_rng(11)
    fields = {
        "walk": _walk(5000, seed=11, dtype=dtype),
        "gauss": rng.standard_normal(3333).astype(dtype),
        "const": np.full(999, 2.5).astype(dtype),
        "steps": np.repeat(rng.standard_normal(50), 41)[:2000].astype(dtype),
    }
    spiky = rng.standard_normal(2001).astype(np.float64)
    spiky[::53] *= 1e3
    fields["spiky"] = spiky.astype(dtype)
    for name, x in fields.items():
        for e in (1e-4, 1e-2, 1.0):
            buf = CODEC.compress(x, e)
            y = CODEC.decompress(buf)
            assert y.dtype == np.dtype(dtype)
            err = np.abs(x.astype(np.float64) - y.astype(np.float64)).max()
            assert err <= e, (name, np.dtype(dtype).name, e, err)


def test_dtype_is_preserved_in_stream():
    for dtype in _DTYPES:
        x = _walk(1000, dtype=dtype)
        buf = CODEC.compress(x, 1e-2)
        assert buf[5] == plan.spec_for(dtype).code
        assert CODEC.decompress(buf).dtype == np.dtype(dtype)


def test_f64_tight_bound_beats_f32_floor():
    """A bound below f32 ulp is only achievable with native f64 streams."""
    x = (np.cumsum(np.random.default_rng(0).standard_normal(20000)) * 100.0)
    e = 1e-9 * float(x.max() - x.min())
    y = CODEC.decompress(CODEC.compress(x, e))
    assert y.dtype == np.float64
    assert np.abs(x - y).max() <= e


def test_verbatim_blocks_are_bit_exact_all_dtypes():
    """Bounds below the values' ulp trigger verbatim storage: exact words."""
    for dtype in _DTYPES:
        x = _walk(2000, seed=9, scale=1.0, dtype=dtype)
        tiny = float(plan.finfo(np.dtype(dtype)).tiny)
        y = CODEC.decompress(CODEC.compress(x, tiny))
        np.testing.assert_array_equal(
            x.view(np.uint8), y.reshape(x.shape).view(np.uint8)
        )


def test_compress_rejects_unsupported_dtype():
    with pytest.raises(TypeError):
        CODEC.compress(np.arange(100, dtype=np.int32), 1e-3)


# ---------------------------------------------------------------------------
# corrupt / truncated stream + frame rejection
# ---------------------------------------------------------------------------

def _valid_stream():
    return CODEC.compress(_walk(4000, seed=13), 1e-3)


def test_truncated_stream_rejected():
    buf = _valid_stream()
    for cut in (3, container.HEADER.size - 1, container.HEADER.size + 5, len(buf) - 1):
        with pytest.raises(ValueError):
            CODEC.decompress(buf[:cut])


def test_corrupt_header_rejected():
    buf = bytearray(_valid_stream())
    bad_magic = b"XXXX" + bytes(buf[4:])
    with pytest.raises(ValueError):
        CODEC.decompress(bad_magic)
    bad_version = bytes(buf[:4]) + b"\x07" + bytes(buf[5:])
    with pytest.raises(ValueError):
        CODEC.decompress(bad_version)
    bad_dtype = bytes(buf[:5]) + b"\xee" + bytes(buf[6:])
    with pytest.raises(ValueError):
        CODEC.decompress(bad_dtype)


def test_corrupt_frames_rejected():
    frames = list(CODEC.compress_chunked(_walk(100_000), 1e-3, chunk_bytes=1 << 18))
    blob = b"".join(frames)
    # truncated mid-payload
    with pytest.raises(ValueError):
        CODEC.decompress_chunked(blob[:-10])
    # bad frame magic
    with pytest.raises(ValueError):
        CODEC.decompress_chunked(b"NOPE" + blob[4:])
    # out-of-order sequence numbers
    with pytest.raises(ValueError):
        CODEC.decompress_chunked([frames[1], frames[0]] + frames[2:])
    # missing LAST frame
    with pytest.raises(ValueError):
        CODEC.decompress_chunked(frames[:-1])
    # frame after the LAST-flagged frame (iterable, bytes, and file forms)
    with pytest.raises(ValueError):
        CODEC.decompress_chunked(frames + [frames[-1]])
    with pytest.raises(ValueError):
        CODEC.decompress_chunked(blob + frames[-1])
    # non-frame trailing bytes are a corrupt/foreign footer, not a frame:
    # tolerated with a warning so damaged v3 files stay decodable
    x_ref = CODEC.decompress_chunked(blob)
    for tail in (b"trailing garbage", b"x"):
        with pytest.warns(RuntimeWarning, match="trailing bytes"):
            y = CODEC.load_chunked(io.BytesIO(blob + tail))
        np.testing.assert_array_equal(x_ref, y)
    # empty sequence
    with pytest.raises(ValueError):
        CODEC.decompress_chunked([])


# ---------------------------------------------------------------------------
# satellite: corrupt-footer resilience + select= input validation
# ---------------------------------------------------------------------------

def _v3_stream():
    x = _walk(150_000, seed=21)
    buf = io.BytesIO()
    CODEC.dump_chunked(x, buf, 1e-3, chunk_bytes=1 << 18)
    return x, buf


def test_corrupt_footer_falls_back_to_sequential_decode():
    """A bit-flipped v3 index footer degrades to the sequential v2 decode
    with a warning -- for full loads AND for select= random access."""
    x, buf = _v3_stream()
    good = CODEC.load_chunked(io.BytesIO(buf.getvalue()))
    sel_good = CODEC.load_chunked(buf, select=[0, 2])
    raw = bytearray(buf.getvalue())
    raw[-35] ^= 0xFF                       # inside the JSON index -> CRC fails
    with pytest.raises(ValueError):        # strict reader still rejects it
        container.read_index_footer(io.BytesIO(bytes(raw)))
    # full sequential load never needed the footer
    np.testing.assert_array_equal(good, CODEC.load_chunked(io.BytesIO(bytes(raw))))
    # select= warns and falls back to a sequential walk, same result
    with pytest.warns(RuntimeWarning, match="corrupt container-v3"):
        sel = CODEC.load_chunked(io.BytesIO(bytes(raw)), select=[0, 2])
    np.testing.assert_array_equal(sel_good, sel)
    assert np.abs(good - x).max() <= 1e-3


def test_truncated_footer_mid_trailer():
    """Truncation inside the 20-byte trailer: sequential decode still works;
    random access reports the missing footer clearly."""
    x, buf = _v3_stream()
    good = CODEC.load_chunked(io.BytesIO(buf.getvalue()))
    for cut in (1, container.INDEX_TRAILER.size - 1, container.INDEX_TRAILER.size + 7):
        trunc = buf.getvalue()[:-cut]
        assert container.read_index_footer_safe(io.BytesIO(trunc)) is None
        np.testing.assert_array_equal(good, CODEC.load_chunked(io.BytesIO(trunc)))
    # footer sheared off entirely mid-JSON: CRC/parse fails -> safe reader
    # warns; sequential load still decodes the intact frames
    mid_json = buf.getvalue()[:-(container.INDEX_TRAILER.size + 30)]
    np.testing.assert_array_equal(
        good,
        CODEC.load_chunked(io.BytesIO(
            mid_json + buf.getvalue()[-container.INDEX_TRAILER.size:]
        )),
    )


def test_load_chunked_select_validation():
    """Out-of-range, duplicate, unsorted, and non-integer selections raise a
    clear ValueError (never numpy/IndexError)."""
    _x, buf = _v3_stream()
    nframes = len(container.read_index_footer(buf)["frames"])
    assert nframes >= 3
    for bad, msg in [
        ([2, 1], "strictly increasing"),
        ([1, 1], "strictly increasing"),
        ([nframes + 5], "out of range"),
        ([-1], "out of range"),
        ([0.5], "integer frame indices"),
        ([True], "integer frame indices"),
        ([], "empty"),
    ]:
        with pytest.raises(ValueError, match=msg):
            CODEC.load_chunked(buf, select=bad)


def test_decompress_tree_select_validation():
    import jax  # noqa: F401  (TreeCodec flattens via jax.tree_util)

    from repro.core.codec import TreeCodec

    tc = TreeCodec()
    buf = io.BytesIO()
    tc.compress_tree({"a": _walk(5000, seed=22), "b": np.arange(8)}, buf)
    with pytest.raises(ValueError, match="duplicate"):
        tc.decompress_tree(buf, select=["a", "a"])
    with pytest.raises(KeyError):
        tc.decompress_tree(buf, select=["nope"])


# ---------------------------------------------------------------------------
# PlanesCodec front-end
# ---------------------------------------------------------------------------

def test_planes_codec_matches_oracle():
    import jax.numpy as jnp

    from repro.kernels import ref

    xb = np.random.default_rng(17).standard_normal((9, 64)).astype(np.float32)
    for p in (1, 2, 3):
        codec = PlanesCodec(p)
        mu, sexp, planes = codec.encode_blocks(jnp.asarray(xb))
        mu_r, sexp_r, planes_r = ref.planes_encode_ref(jnp.asarray(xb), p)
        np.testing.assert_array_equal(np.asarray(planes), np.asarray(planes_r))
        dec = np.asarray(codec.decode_blocks(mu, sexp, planes))
        dec_r = np.asarray(ref.planes_decode_ref(mu_r, sexp_r, planes_r))
        np.testing.assert_array_equal(dec, dec_r)


def test_planes_codec_numpy_backend_mirrors_jax():
    xb = np.random.default_rng(19).standard_normal((5, 32)).astype(np.float32)
    for p in (1, 2):
        jx = PlanesCodec(p, backend="jax")
        npb = PlanesCodec(p, backend="numpy")
        mu_j, sexp_j, pl_j = (np.asarray(a) for a in jx.encode_blocks(xb))
        mu_n, sexp_n, pl_n = npb.encode_blocks(xb)
        np.testing.assert_array_equal(pl_j, pl_n)
        np.testing.assert_allclose(mu_j, mu_n)
        np.testing.assert_array_equal(sexp_j, sexp_n)
        np.testing.assert_allclose(
            np.asarray(jx.decode_blocks(mu_j, sexp_j, pl_j)),
            npb.decode_blocks(mu_n, sexp_n, pl_n),
            rtol=1e-6,
        )


def test_planes_codec_last_axis_roundtrip():
    import jax.numpy as jnp

    x = np.random.default_rng(23).standard_normal((3, 5, 70)).astype(np.float32)
    codec = PlanesCodec(2)
    enc = codec.encode_last_axis(jnp.asarray(x), block=32)
    y = np.asarray(codec.decode_last_axis(enc, x.shape, jnp.float32))
    assert y.shape == x.shape
    # P=2 block quantization: residual small relative to data scale
    assert np.abs(x - y).max() < 2e-3 * np.abs(x).max()


def test_planes_codec_validates_num_planes():
    with pytest.raises(ValueError):
        PlanesCodec(0)
    with pytest.raises(ValueError):
        PlanesCodec(4)


# ---------------------------------------------------------------------------
# checkpoint integration (TreeCodec stream, chunked large leaves)
# ---------------------------------------------------------------------------

def test_checkpoint_chunked_large_leaf(tmp_path):
    import json

    from repro.checkpoint import CheckpointManager

    m = CheckpointManager(
        str(tmp_path), keep=1, compress=True, bound=plan.Bound.rel(1e-5),
        chunk_bytes=1 << 18,       # force the chunked path at test sizes
    )
    tree = {
        "big_f32": _walk(200_000, seed=29),
        "big_f64": _walk(100_000, seed=31, dtype=np.float64),
        "small": np.arange(10, dtype=np.int32),
    }
    m.save(0, tree)
    with open(tmp_path / "step_000000000" / "MANIFEST.json") as f:
        manifest = json.load(f)
    # MANIFEST v2: one TreeCodec stream per step, leaves mapped by the index
    assert manifest["manifest_version"] == 2
    assert (tmp_path / "step_000000000" / manifest["file"]).exists()
    by_name = {m_["name"]: m_ for m_ in manifest["leaves"]}
    assert by_name["big_f32"]["codec"] == "szx"
    assert by_name["big_f64"]["codec"] == "szx"
    assert by_name["small"]["codec"] == "raw"
    # large leaves really went through the chunked frame pipeline
    lo, hi = by_name["big_f32"]["frames"]
    assert hi - lo > 1
    restored, step = m.restore(tree)
    assert step == 0
    for k in ("big_f32", "big_f64"):
        x, y = tree[k], restored[k]
        assert np.asarray(y).dtype == x.dtype
        e = 1e-5 * float(x.max() - x.min())
        assert np.abs(x - np.asarray(y)).max() <= e
    np.testing.assert_array_equal(tree["small"], restored["small"])
    # partial restore reads only the selected leaf
    part = m.restore_leaves(["small"])
    np.testing.assert_array_equal(part["small"], tree["small"])
