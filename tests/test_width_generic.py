"""Width-generic kernel layer: one DtypeSpec-parameterized transform.

The contracts under test:
  * cross-backend bit-identity -- for every dtype x backend ('jax'/'numpy',
    plus 'kernel', which runs the Pallas kernels under interpret=True on CPU)
    the encode/decode BYTE STREAMS and the reconstructions are identical;
  * the fused ``ops.encode`` is bit-identical to block_stats followed by pack;
  * the all-``L==0`` dense unpack fast path dispatches for EVERY dtype, not
    just float32;
  * the szx-planes 'kernel' route (Pallas) matches the jnp oracle;
  * (hypothesis, optional) the error bound |x - decode(encode(x))| <= e holds
    for all four dtypes on arbitrary inputs.
"""
import numpy as np
import pytest

from repro.core.codec import SZxCodec, plan as plan_mod, transform
from repro.kernels import ops, specs

try:  # property tests need hypothesis (dev extra); skip them if absent
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

    def _identity_deco(f):
        return f

    def given(*a, **k):  # noqa: D103
        return _identity_deco

    def settings(*a, **k):  # noqa: D103
        return _identity_deco

    class _St:  # placeholder so strategy expressions still evaluate at import
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed (pip install .[dev])"
)

BACKENDS = ["jax", "numpy", "kernel"]
DTYPES = [s.np_dtype for s in specs.SPECS]
_ids = [s.name for s in specs.SPECS]


def _field(n, dtype, seed=0, scale=0.1):
    rng = np.random.default_rng(seed)
    return (np.cumsum(rng.standard_normal(n)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# cross-backend bit-identity matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES, ids=_ids)
def test_stream_bytes_identical_across_backends(dtype):
    x = _field(20000, dtype, seed=1)
    e = 1e-2
    bufs = {b: SZxCodec(backend=b).compress(x, e) for b in BACKENDS}
    ys = {b: SZxCodec(backend=b).decompress(bufs[b]) for b in BACKENDS}
    for b in BACKENDS[1:]:
        assert bufs[b] == bufs["jax"], f"{np.dtype(dtype).name}: {b} bytes differ"
        np.testing.assert_array_equal(
            ys["jax"].view(np.uint8), ys[b].view(np.uint8),
            err_msg=f"{np.dtype(dtype).name}: {b} reconstruction differs",
        )
    err = np.abs(x.astype(np.float64) - ys["jax"].astype(np.float64)).max()
    assert err <= e


@pytest.mark.parametrize("dtype", DTYPES, ids=_ids)
def test_ops_backends_agree(dtype):
    """Op-level matrix: block_stats / pack / unpack / unpack_dense."""
    spec = specs.spec_for(dtype)
    x = _field(17 * 64, dtype, seed=2, scale=0.01).reshape(17, 64)
    e = 1e-3
    outs = {
        b: [np.asarray(a) for a in ops.block_stats(x, e, spec=spec, backend=b)]
        for b in BACKENDS
    }
    for b in BACKENDS[1:]:
        for a_ref, a_b in zip(outs["jax"], outs[b]):
            np.testing.assert_array_equal(a_ref, a_b, err_msg=f"stats {b}")
    mu, _rad, _const, _reqlen, shift, nbytes = outs["jax"]
    packs = {
        b: [np.asarray(a) for a in ops.pack(x, mu, shift, nbytes, spec=spec, backend=b)]
        for b in BACKENDS
    }
    for b in BACKENDS[1:]:
        for a_ref, a_b in zip(packs["jax"], packs[b]):
            np.testing.assert_array_equal(a_ref, a_b, err_msg=f"pack {b}")
    planes, L, _mid = packs["jax"]
    for b in BACKENDS:
        y = np.asarray(ops.unpack(planes, mu, shift, nbytes, L, spec=spec, backend=b))
        np.testing.assert_array_equal(
            y.view(np.uint8),
            np.asarray(ops.unpack(planes, mu, shift, nbytes, L,
                                  spec=spec, backend="jax")).view(np.uint8),
            err_msg=f"unpack {b}",
        )
        d = np.asarray(
            ops.unpack_dense(planes, mu, shift, nbytes, spec=spec, backend=b)
        )
        ref_d = np.asarray(
            ops.unpack(planes, mu, shift, nbytes, np.zeros_like(L),
                       spec=spec, backend="jax")
        )
        np.testing.assert_array_equal(
            d.view(np.uint8), ref_d.view(np.uint8), err_msg=f"unpack_dense {b}"
        )


@pytest.mark.parametrize("dtype", DTYPES, ids=_ids)
@pytest.mark.parametrize("backend", BACKENDS)
def test_unpack_range_matches_full_decode(dtype, backend):
    """Partial decode (the store ROI primitive): ``unpack_range(lo, hi)`` is
    bit-identical to the corresponding slice of the full decode on every
    backend, and validates its range."""
    spec = specs.spec_for(dtype)
    x = _field(23 * 64, dtype, seed=7, scale=0.01).reshape(23, 64)
    e = 1e-2
    mu, const, reqlen, shift, nbytes, planes, L = (
        np.asarray(a) for a in ops.encode(x, e, spec=spec, backend="numpy")
    )
    full = np.asarray(ops.unpack(planes, mu, shift, nbytes, L, spec=spec,
                                 backend=backend))
    for lo, hi in ((0, 23), (5, 9), (22, 23), (0, 1)):
        part = np.asarray(
            ops.unpack_range(planes, mu, shift, nbytes, L, lo, hi,
                             spec=spec, backend=backend)
        )
        np.testing.assert_array_equal(
            part.view(np.uint8), full[lo:hi].view(np.uint8),
            err_msg=f"range [{lo},{hi}) {backend}",
        )
    # dense fast path inside a range: all-L==0 ranges match unpack_dense
    z = np.zeros_like(L)
    d = np.asarray(ops.unpack_range(planes, mu, shift, nbytes, z, 3, 11,
                                    spec=spec, backend=backend))
    ref = np.asarray(ops.unpack_dense(planes[3:11], mu[3:11], shift[3:11],
                                      nbytes[3:11], spec=spec, backend=backend))
    np.testing.assert_array_equal(d.view(np.uint8), ref.view(np.uint8))
    for lo, hi in ((-1, 3), (5, 5), (9, 5), (0, 24)):
        with pytest.raises(ValueError):
            ops.unpack_range(planes, mu, shift, nbytes, L, lo, hi,
                             spec=spec, backend=backend)


@pytest.mark.parametrize("dtype", DTYPES, ids=_ids)
@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_encode_matches_two_call(dtype, backend):
    spec = specs.spec_for(dtype)
    x = _field(9 * 128, dtype, seed=3).reshape(9, 128)
    e = 1e-2
    mu, _rad, const, reqlen, shift, nbytes = [
        np.asarray(a) for a in ops.block_stats(x, e, spec=spec, backend=backend)
    ]
    planes, L, _mid = [
        np.asarray(a) for a in ops.pack(x, mu, shift, nbytes, spec=spec, backend=backend)
    ]
    fused = [np.asarray(a) for a in ops.encode(x, e, spec=spec, backend=backend)]
    two_call = [mu, const, reqlen, shift, nbytes, planes, L]
    names = ["mu", "const", "reqlen", "shift", "nbytes", "planes", "L"]
    for name, a_f, a_t in zip(names, fused, two_call):
        np.testing.assert_array_equal(a_f, a_t, err_msg=f"{backend} fused {name}")


def test_empty_and_subblock_shapes_all_backends():
    """The fused encode path handles nb == 0 and padded sub-block inputs."""
    for backend in BACKENDS:
        codec = SZxCodec(backend=backend)
        for n in (0, 1, 127):
            x = _field(n, np.float32, seed=4)
            frames = list(codec.compress_chunked(x, 1e-3))
            y = codec.decompress_chunked(frames)
            assert y.size == n
            if n:
                assert np.abs(x - y).max() <= 1e-3


# ---------------------------------------------------------------------------
# dense (all-L==0) fast path dispatches for every dtype
# ---------------------------------------------------------------------------

def _alternating(n, dtype):
    """Sign-alternating data: every shifted word's MSB byte differs from its
    predecessor's (and the first value's from the zero word), so L == 0."""
    x = np.linspace(1.0, 2.0, n)
    x[1::2] *= -1.0
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", DTYPES, ids=_ids)
def test_dense_unpack_dispatches_for_every_dtype(dtype, monkeypatch):
    x = _alternating(1024, dtype)
    p, xt = plan_mod.make_plan(x, 1e-3, backend="numpy")
    enc = transform.encode_blocks(plan_mod.to_blocks(xt, p), p)
    assert not enc.L.any(), "fixture must produce an all-L==0 frame"
    calls = []
    real_dense = ops.unpack_dense
    monkeypatch.setattr(
        ops, "unpack_dense",
        lambda *a, **k: calls.append("dense") or real_dense(*a, **k),
    )
    monkeypatch.setattr(
        ops, "unpack", lambda *a, **k: pytest.fail("dense frame used slow unpack")
    )
    y = transform.decode_blocks(enc, p)
    assert calls == ["dense"]
    assert np.abs(x.astype(np.float64) - y.reshape(-1)[: x.size].astype(np.float64)).max() <= 1e-3


@pytest.mark.parametrize("dtype", DTYPES, ids=_ids)
def test_dense_and_sparse_unpack_bit_identical(dtype):
    """unpack_dense(planes, ...) == unpack(planes, ..., L=0) for every dtype."""
    spec = specs.spec_for(dtype)
    x = _field(8 * 128, dtype, seed=5).reshape(8, 128)
    mu, _r, _c, _q, shift, nbytes = ops.block_stats(x, 1e-2, spec=spec, backend="numpy")
    planes, L, _m = ops.pack(x, mu, shift, nbytes, spec=spec, backend="numpy")
    dense = ops.unpack_dense(planes, mu, shift, nbytes, spec=spec, backend="numpy")
    sparse = ops.unpack(planes, mu, shift, nbytes, np.zeros_like(L),
                        spec=spec, backend="numpy")
    np.testing.assert_array_equal(
        np.asarray(dense).view(np.uint8), np.asarray(sparse).view(np.uint8)
    )


def test_f16_const_test_guards_subtraction_rounding():
    """float32 holds every f16 VALUE exactly but not every DIFFERENCE of two
    of them: the radius subtraction can round up to half an ulp below the
    true deviation, so a block could be declared constant with a real error
    just above e.  The 16-bit specs therefore test the next-up radius
    against e (DtypeSpec.stats_rounding_guard); this fixture sets e exactly
    AT the f32-rounded radius, which is BELOW the true deviation."""
    x = np.array([-1.751e-03, 2554.0], np.float16)
    mn, mx = (float(v) for v in x.astype(np.float64))
    mu = float(np.float16(np.float32(0.5) * (np.float32(mn) + np.float32(mx))))
    true_radius = max(mx - mu, mu - mn)                 # exact: f64 covers f16
    r32 = max(np.float32(mx) - np.float32(mu), np.float32(mu) - np.float32(mn))
    e = float(r32)
    assert e < true_radius, "fixture must round the radius below the truth"
    for backend in BACKENDS:
        codec = SZxCodec(block_size=2, backend=backend)
        y = codec.decompress(codec.compress(x, e))
        err = np.abs(x.astype(np.float64) - y.astype(np.float64)).max()
        assert err <= e, f"{backend}: {err} > {e}"


def test_backend_typo_rejected():
    """A misspelled backend (including via SZX_OPS_BACKEND) fails loudly
    instead of silently routing to the jax oracle."""
    with pytest.raises(ValueError, match="unknown SZx ops backend"):
        ops.block_stats(np.zeros((1, 8), np.float32), 1e-3, backend="kernels")
    with pytest.raises(ValueError, match="unknown SZx ops backend"):
        SZxCodec(backend="Kernel").compress(np.zeros(8, np.float32), 1e-3)


# ---------------------------------------------------------------------------
# szx-planes 'kernel' route (Pallas) matches the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_planes", [1, 2, 3])
def test_planes_kernel_backend_matches_jax(num_planes):
    xb = np.random.default_rng(7).standard_normal((9, 64)).astype(np.float32)
    mu_j, sexp_j, pl_j = (np.asarray(a) for a in
                          ops.planes_encode(xb, num_planes, backend="jax"))
    mu_k, sexp_k, pl_k = (np.asarray(a) for a in
                          ops.planes_encode(xb, num_planes, backend="kernel"))
    np.testing.assert_array_equal(mu_j, mu_k)
    np.testing.assert_array_equal(sexp_j, sexp_k)
    np.testing.assert_array_equal(pl_j, pl_k)
    dec_j = np.asarray(ops.planes_decode(mu_j, sexp_j, pl_j, backend="jax"))
    dec_k = np.asarray(ops.planes_decode(mu_j, sexp_j, pl_j, backend="kernel"))
    # the staged kernel may contract q*scale+mu into an FMA (single rounding)
    # where the eager oracle rounds twice -- integer planes above are exact,
    # the float reconstruction is compared to 1 ulp at the data's magnitude
    # (v + mu cancels, so the relative error of tiny results is larger)
    atol = float(np.abs(dec_j).max()) * 2e-7
    np.testing.assert_allclose(dec_j, dec_k, rtol=0, atol=atol)


def test_planes_kernel_leading_dims():
    """The ops layer flattens leading dims for the Pallas planes kernels."""
    x = np.random.default_rng(8).standard_normal((3, 5, 2, 32)).astype(np.float32)
    for b in ("jax", "kernel", "numpy"):
        mu, sexp, planes = (np.asarray(a) for a in ops.planes_encode(x, 2, backend=b))
        assert mu.shape == (3, 5, 2) and planes.shape == (2, 3, 5, 2, 32)
        y = np.asarray(ops.planes_decode(mu, sexp, planes, backend=b))
        assert y.shape == x.shape


# ---------------------------------------------------------------------------
# (optional) property-based round trip across all dtypes
# ---------------------------------------------------------------------------

@needs_hypothesis
@settings(max_examples=40, deadline=None)
@given(
    data=st.data(),
    n=st.integers(min_value=1, max_value=600),
    e_exp=st.integers(min_value=-6, max_value=0),
    dtype_i=st.integers(min_value=0, max_value=len(specs.SPECS) - 1),
)
def test_property_error_bound_all_dtypes(data, n, e_exp, dtype_i):
    spec = specs.SPECS[dtype_i]
    e = 10.0 ** e_exp
    raw = data.draw(
        st.lists(
            st.floats(min_value=-1e4, max_value=1e4, allow_nan=False, width=32),
            min_size=n, max_size=n,
        )
    )
    x = np.asarray(raw, np.float64).astype(spec.np_dtype)
    codec = SZxCodec(backend="numpy")
    y = codec.decompress(codec.compress(x, e))
    assert y.dtype == spec.np_dtype
    assert np.abs(x.astype(np.float64) - y.astype(np.float64)).max() <= e
