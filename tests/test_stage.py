"""Tests for the negotiated lossless second stage (container v3 stage bits).

Covers the stage payload/destage round-trip matrix (dtypes x backends x
stages), the golden pin that stage-off frames are byte-identical to the
pre-stage layout, fail-loudly semantics (unknown stage code, missing
optional zstd, corrupt second-stage payloads, raw frames with stage bits),
per-frame negotiation (a stage never loses), staged store ROI reads with a
seek-spy (header-tier queries and small ROIs never touch mid bytes beyond
the selected segment records), and the Pallas bitshuffle kernel's
bit-identity across backends.
"""
import io
import struct

import numpy as np
import pytest

from repro.core.codec import container, stage
from repro.core.codec.plan import Bound
from repro.core.codec.szx_codec import SZxCodec
from repro.kernels import ops, ref, specs
from repro.kernels.bitshuffle import tile_bytes
from repro.store import ArrayStore

try:
    import ml_dtypes

    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes ships with jax
    BF16 = None

HAVE_ZSTD = stage._zstd() is not None

STAGES = ["bitshuffle-rle", "deflate"] + (
    ["bitshuffle-zstd"] if HAVE_ZSTD else []
)


def _walk(n, seed=0, scale=0.01, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (np.cumsum(rng.standard_normal(n)) * scale).astype(dtype)


def _payload(x, **kw):
    return SZxCodec(backend="numpy", **kw).compress(x, Bound.rel(1e-3))


# ---------------------------------------------------------------------------
# bitshuffle kernel: bit-identity + involution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [specs.F32, specs.F64, specs.F16, specs.BF16])
def test_bitshuffle_backends_bit_identical(spec):
    if spec is None:
        pytest.skip("bfloat16 spec unavailable")
    rng = np.random.default_rng(3)
    T = tile_bytes(spec)
    tiles = rng.integers(0, 256, size=(5, T), dtype=np.uint8)
    fwd = {
        b: np.asarray(ops.bitshuffle(tiles, spec=spec, backend=b))
        for b in ("numpy", "jax", "kernel")
    }
    np.testing.assert_array_equal(fwd["numpy"], fwd["jax"])
    np.testing.assert_array_equal(fwd["numpy"], fwd["kernel"])
    np.testing.assert_array_equal(
        fwd["numpy"], np.asarray(ref.bitshuffle_ref(tiles))
    )
    for b in ("numpy", "jax", "kernel"):
        back = np.asarray(
            ops.bitshuffle(fwd[b], spec=spec, inverse=True, backend=b)
        )
        np.testing.assert_array_equal(back, tiles)


def test_bitshuffle_groups_bitplanes():
    # a tile whose bytes all have ONLY bit 5 set must shuffle into exactly
    # one all-ones bit-row (the transposed plane of bit 5) and zeros elsewhere
    T = tile_bytes(specs.F32)
    tiles = np.full((1, T), 1 << 5, np.uint8)
    out = np.asarray(ops.bitshuffle(tiles, spec=specs.F32, backend="numpy"))
    rows = out.reshape(8, T // 8)
    assert (rows[5] == 0xFF).all()
    mask = np.ones(8, bool)
    mask[5] = False
    assert (rows[mask] == 0).all()


# ---------------------------------------------------------------------------
# stage/destage round-trip matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "float64", "float16", "bf16"])
@pytest.mark.parametrize("backend", ["numpy", "jax", "kernel"])
@pytest.mark.parametrize("name", STAGES)
def test_stage_roundtrip_matrix(dtype, backend, name):
    if dtype == "bf16":
        if BF16 is None:
            pytest.skip("ml_dtypes not available")
        x = _walk(20_000, seed=1).astype(BF16)
    else:
        x = _walk(20_000, seed=1, dtype=np.dtype(dtype))
    payload = _payload(x)
    code = stage.resolve(name)
    staged = stage.stage_payload(payload, code, backend=backend)
    if staged is None:      # negotiation declined: nothing to verify but legality
        return
    assert len(staged) < len(payload)
    back = stage.destage_payload(staged, code, backend=backend)
    assert back == payload


def test_stage_roundtrip_tiny_and_empty_mid():
    # constant array -> zero mid bytes -> negotiation always declines
    x = np.full(1000, 7.5, np.float32)
    payload = _payload(x)
    for name in STAGES:
        assert stage.stage_payload(payload, stage.resolve(name)) is None


def test_stage_never_loses():
    # incompressible mid bytes: every frame must stay stage-off
    rng = np.random.default_rng(0)
    x = rng.standard_normal(60_000).astype(np.float32)
    payload = _payload(x)
    for name in STAGES:
        staged = stage.stage_payload(payload, stage.resolve(name))
        assert staged is None or len(staged) < len(payload)
    frame = container.build_frame(payload, 0, True, stage="deflate")
    plain = container.build_frame(payload, 0, True)
    assert len(frame) <= len(plain)


def test_stage_improves_ratio_on_smooth_corpus():
    x = _walk(300_000, seed=0)
    codec_off = SZxCodec(backend="numpy")
    codec_on = SZxCodec(backend="numpy", stage="deflate")
    off = b"".join(codec_off.compress_chunked(x, Bound.rel(1e-3)))
    on = b"".join(codec_on.compress_chunked(x, Bound.rel(1e-3)))
    assert len(on) < len(off)
    np.testing.assert_array_equal(
        codec_off.decompress_chunked(on), codec_off.decompress_chunked(off)
    )


# ---------------------------------------------------------------------------
# golden pin: stage-off bytes unchanged
# ---------------------------------------------------------------------------

def test_stage_off_frames_byte_identical_to_v3_layout():
    """A frame built WITHOUT stage= must be exactly the pre-stage layout:
    frame header struct + raw payload, no table, no flag bits."""
    x = _walk(10_000, seed=2)
    payload = _payload(x)
    frame = container.build_frame(payload, 3, False)
    want = container.FRAME_HEADER.pack(
        container.FRAME_MAGIC, container.FRAME_VERSION, 0, 3, len(payload)
    ) + payload
    assert frame == want
    last = container.build_frame(payload, 0, True)
    assert last[5] == container.FLAG_LAST
    assert container.stage_of_flags(last[5]) == 0

    # full chunked dump: stage=None codec writes the identical stream
    buf_a, buf_b = io.BytesIO(), io.BytesIO()
    SZxCodec(backend="numpy").dump_chunked(x, buf_a, Bound.rel(1e-3))
    SZxCodec(backend="numpy", stage=None).dump_chunked(x, buf_b, Bound.rel(1e-3))
    assert buf_a.getvalue() == buf_b.getvalue()

    # stage-off store footer carries no "stage" key (byte-stable footers)
    sbuf = io.BytesIO()
    idx = ArrayStore.save(sbuf, x.reshape(100, 100), 1e-3)
    assert "stage" not in idx


# ---------------------------------------------------------------------------
# fail-loudly: unknown/unavailable stages, corrupt payloads
# ---------------------------------------------------------------------------

def _staged_frame(payload, name="deflate", last=True):
    frame = container.build_frame(payload, 0, last, stage=name)
    assert container.stage_of_flags(frame[5]) == stage.resolve(name)
    return frame


def _with_stage_bits(frame, code):
    f = bytearray(frame)
    f[5] = (f[5] & ~container.FLAG_STAGE_MASK) | (code << container.FLAG_STAGE_SHIFT)
    return bytes(f)


def test_unknown_stage_code_fails_loudly():
    payload = _payload(_walk(5_000))
    frame = _with_stage_bits(container.build_frame(payload, 0, True), 5)
    with pytest.raises(ValueError, match="requires second stage"):
        list(container.iter_frames(iter([frame])))
    with pytest.raises(ValueError, match="requires second stage"):
        list(container.iter_frames(io.BytesIO(frame)))
    with pytest.raises(ValueError, match="requires second stage"):
        SZxCodec(backend="numpy").load_chunked(io.BytesIO(frame))


def test_zstd_stage_without_zstd_fails_loudly(monkeypatch):
    payload = _payload(_walk(50_000))
    if HAVE_ZSTD:
        frame = _staged_frame(payload, "bitshuffle-zstd")
        if not container.stage_of_flags(frame[5]):
            pytest.skip("zstd negotiation declined on this corpus")
    else:
        # no zstd anywhere: synthesize the flag over a deflate-staged body --
        # the reader must refuse BEFORE touching the (mismatched) records
        frame = _staged_frame(payload, "deflate")
        if not container.stage_of_flags(frame[5]):
            pytest.skip("negotiation declined on this corpus")
        frame = _with_stage_bits(frame, stage.BITSHUFFLE_ZSTD)
    monkeypatch.setenv("SZX_STAGE_DISABLE_ZSTD", "1")
    with pytest.raises(ValueError, match="zstandard package is not installed"):
        list(container.iter_frames(io.BytesIO(frame)))
    # and a WRITER without zstd refuses to construct the codec at all
    with pytest.raises(ValueError, match="zstandard"):
        SZxCodec(stage="bitshuffle-zstd")


def test_unknown_stage_name_rejected():
    with pytest.raises(ValueError, match="unknown second stage"):
        SZxCodec(stage="huffman")
    with pytest.raises(ValueError, match="unknown second stage"):
        stage.resolve(7)
    with pytest.raises(TypeError):
        stage.resolve(2.5)


def test_corrupt_second_stage_payload_rejected():
    payload = _payload(_walk(80_000, seed=4))
    frame = _staged_frame(payload)
    assert container.stage_of_flags(frame[5])
    hdr = container.FRAME_HEADER.size
    prefix_len = container.stream_prefix_length(payload)

    # flip a byte inside a compressed record body
    bad = bytearray(frame)
    bad[-10] ^= 0xFF
    with pytest.raises(ValueError, match="corrupt second-stage payload"):
        list(container.iter_frames(io.BytesIO(bytes(bad))))

    # truncate the stage table
    seg_blocks, nseg = struct.unpack_from("<HI", frame, hdr + prefix_len)
    bad = bytearray(frame)
    struct.pack_into("<HI", bad, hdr + prefix_len, seg_blocks, nseg + 3)
    with pytest.raises(ValueError, match="corrupt second-stage payload"):
        list(container.iter_frames(io.BytesIO(bytes(bad))))

    # zero seg_blocks
    bad = bytearray(frame)
    struct.pack_into("<HI", bad, hdr + prefix_len, 0, nseg)
    with pytest.raises(ValueError, match="corrupt second-stage payload"):
        list(container.iter_frames(io.BytesIO(bytes(bad))))


def test_raw_frame_with_stage_bits_rejected():
    frame = container.build_frame(b"rawbytes", 0, True, raw=True)
    bad = _with_stage_bits(frame, stage.DEFLATE)
    with pytest.raises(ValueError, match="raw frame"):
        list(container.iter_frames(iter([bad])))


def test_raw_frames_never_staged():
    # stage= on a raw payload is ignored (raw packs carry no mid section)
    frame = container.build_frame(b"rawbytes", 0, True, raw=True, stage="deflate")
    assert container.stage_of_flags(frame[5]) == 0
    payload, flags = next(container.iter_frames(iter([frame]), with_flags=True))
    assert payload == b"rawbytes" and flags & container.FLAG_RAW


def test_rle_decode_rejects_bad_pairs():
    with pytest.raises(ValueError, match="odd RLE pair"):
        stage._rle_decode(b"\x01\x02\x03", 3)
    with pytest.raises(ValueError, match="zero-length"):
        stage._rle_decode(b"\x01\x00", 1)
    with pytest.raises(ValueError, match="expands to"):
        stage._rle_decode(b"\x01\x05", 3)


# ---------------------------------------------------------------------------
# chunked + store + checkpoint integration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", STAGES)
def test_chunked_staged_roundtrip_and_select(name):
    x = _walk(500_000, seed=5)
    codec = SZxCodec(backend="numpy", stage=name, workers=2)
    buf = io.BytesIO()
    codec.dump_chunked(x, buf, Bound.rel(1e-3), chunk_bytes=1 << 19)
    buf.seek(0)
    got = codec.load_chunked(buf, n=x.size)
    np.testing.assert_array_equal(got, SZxCodec(backend="numpy").decompress(
        SZxCodec(backend="numpy").compress(x, Bound.rel(1e-3))
    ))
    # random access through the index still works on staged frames
    buf.seek(0)
    sel = codec.load_chunked(buf, select=[1, 3])
    per = 1 << 17
    np.testing.assert_array_equal(sel[:per], got[per : 2 * per])


@pytest.mark.parametrize("name", STAGES)
def test_store_staged_roi_reads_match(name):
    x = _walk(1 << 18, seed=6).reshape(512, 512)
    buf = io.BytesIO()
    idx = ArrayStore.save(buf, x, 1e-3, stage=name)
    assert idx.get("stage") == name
    plain = io.BytesIO()
    ArrayStore.save(plain, x, 1e-3)
    ca_s = ArrayStore.open(buf)
    ca_p = ArrayStore.open(plain)
    assert ca_s.stage == name and ca_p.stage is None
    for key in [np.s_[...], np.s_[7], np.s_[100:141, 3:401], np.s_[:, -1]]:
        np.testing.assert_array_equal(ca_s[key], ca_p[key])
    # compressed-domain queries identical too
    assert ca_s.stats().to_dict() == ca_p.stats().to_dict()
    assert ca_s.stats(header_only=True).to_dict() == \
        ca_p.stats(header_only=True).to_dict()


def test_store_sharded_staged_roundtrip(tmp_path):
    x = _walk(1 << 16, seed=7).reshape(256, 256)
    man_path = tmp_path / "arr.json"
    man = ArrayStore.save_sharded(
        man_path, x, 1e-3, nshards=2, chunk_shape=(64, 256), stage="deflate"
    )
    assert man.get("stage") == "deflate"
    with ArrayStore.open(str(man_path)) as ca:
        assert ca.stage == "deflate"
        plain = io.BytesIO()
        ArrayStore.save(plain, x, 1e-3)
        with ArrayStore.open(plain) as cp:
            np.testing.assert_array_equal(ca[10:30, 40:200], cp[10:30, 40:200])


def test_checkpoint_staged_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    tree = {"w": _walk(200_000, seed=8), "b": np.arange(7, dtype=np.int32)}
    mgr = CheckpointManager(
        str(tmp_path), compress=True, bound=Bound.rel(1e-4), stage="deflate"
    )
    mgr.save(0, tree)
    mgr_off = CheckpointManager(
        str(tmp_path / "off"), compress=True, bound=Bound.rel(1e-4)
    )
    mgr_off.save(0, tree)
    got, _ = mgr.restore(tree)
    want, _ = mgr_off.restore(tree)
    np.testing.assert_array_equal(got["w"], want["w"])
    np.testing.assert_array_equal(got["b"], tree["b"])


# ---------------------------------------------------------------------------
# seek-spy: staged stores keep byte reads proportional to the ROI
# ---------------------------------------------------------------------------

class SpyFile:
    def __init__(self, raw):
        self.raw = raw
        self.reads: list[tuple[int, int]] = []

    def seek(self, *a):
        return self.raw.seek(*a)

    def tell(self):
        return self.raw.tell()

    def read(self, n=-1):
        off = self.raw.tell()
        data = self.raw.read(n)
        if data:
            self.reads.append((off, len(data)))
        return data

    def bytes_read(self) -> int:
        return sum(ln for _, ln in self.reads)


def _covered(reads, ranges):
    for off, ln in reads:
        if not any(lo <= off and off + ln <= hi for lo, hi in ranges):
            return (off, ln)
    return None


def _staged_store():
    x = _walk(1 << 20, seed=9).reshape(1024, 1024)
    buf = io.BytesIO()
    idx = ArrayStore.save(buf, x, Bound.rel(1e-3), stage="deflate")
    return x, buf, idx


def _frame_regions(buf, idx):
    """Per chunk: (frame_off, prefix_end, table_end, seg_starts) where
    seg_starts are absolute file offsets of each segment record."""
    regions = []
    raw = buf.getvalue()
    for off, length, _n in idx["frames"]:
        hdr = container.FRAME_HEADER.size
        payload = raw[off + hdr : off + length]
        flags = raw[off + 5]
        prefix_len = container.stream_prefix_length(payload)
        if not container.stage_of_flags(flags):
            regions.append((off, off + hdr + prefix_len, None, None))
            continue
        seg_blocks, nseg = struct.unpack_from("<HI", payload, prefix_len)
        lens = np.frombuffer(
            payload, "<u4", nseg, prefix_len + 6
        ).astype(np.int64)
        table_end = off + hdr + prefix_len + 6 + 4 * nseg
        starts = table_end + np.concatenate(([0], np.cumsum(lens)))
        regions.append((off, off + hdr + prefix_len, table_end, starts))
    return regions


def test_staged_store_header_queries_read_zero_mid_bytes():
    _x, buf, idx = _staged_store()
    regions = _frame_regions(buf, idx)
    assert any(r[2] is not None for r in regions), "no chunk negotiated a stage"
    end = buf.seek(0, 2)
    spy = SpyFile(buf)
    ca = ArrayStore.open(spy)
    spy.reads.clear()
    ca.stats(header_only=True)
    # every read lies inside some frame's metadata prefix: the stage table
    # and the shuffled segment records are NEVER touched
    allowed = [(off, pend) for off, pend, _t, _s in regions]
    assert _covered(spy.reads, allowed) is None
    assert spy.bytes_read() < 0.40 * end


def test_staged_store_roi_reads_only_selected_segments():
    x, buf, idx = _staged_store()
    spy = SpyFile(buf)
    ca = ArrayStore.open(spy)
    spy.reads.clear()
    roi = np.s_[100:110, :]            # ~1% of the rows
    got = ca[roi]
    np.testing.assert_array_equal(got.shape, x[roi].shape)
    end = buf.seek(0, 2)
    assert spy.bytes_read() < 0.30 * end

    # reads inside the record area must cover ONLY the contiguous run of
    # segments holding the requested block range (plus prefix + table)
    regions = _frame_regions(buf, idx)
    touched = {}
    for off, ln in spy.reads:
        for ci, (foff, pend, tend, starts) in enumerate(regions):
            if foff <= off < (regions[ci + 1][0] if ci + 1 < len(regions)
                              else end):
                touched.setdefault(ci, []).append((off, ln))
    roi_chunks = [ci for ci, reads in touched.items()
                  if any(o >= regions[ci][1] for o, _ in reads)]
    assert roi_chunks, "ROI decoded no chunk?"
    for ci in roi_chunks:
        foff, pend, tend, starts = regions[ci]
        if tend is None:
            continue                    # chunk declined the stage: raw path
        rec_reads = [(o, ln) for o, ln in touched[ci] if o >= tend]
        if not rec_reads:
            continue
        lo = min(o for o, _ in rec_reads)
        hi = max(o + ln for o, ln in rec_reads)
        # one contiguous covering run, aligned on record boundaries
        assert lo in starts and hi in starts
        span = hi - lo
        total_records = int(starts[-1] - starts[0])
        assert span < 0.25 * total_records, (span, total_records)


def test_staged_store_full_read_roundtrip():
    x, buf, _idx = _staged_store()
    ca = ArrayStore.open(buf)
    got = ca[...]
    plain = io.BytesIO()
    ArrayStore.save(plain, x, Bound.rel(1e-3))
    with ArrayStore.open(plain) as cp:
        np.testing.assert_array_equal(got, cp[...])
