"""Unit tests for the CI perf-regression gate (benchmarks/check_regression.py):
doctored-slow and CR-drifted BENCH JSONs must fail, within-tolerance noise
must pass, and the CLI exit code must reflect it."""
import copy
import json
import os
import subprocess
import sys

from benchmarks.check_regression import compare, main

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BASE = {
    "chunked_dump_load": {
        "n": 4194304,
        "mono": {"comp_mbs": 100.0, "decomp_mbs": 50.0, "cr": 7.0},
        "chunked": {"comp_mbs": 120.0, "decomp_mbs": 80.0, "cr": 7.0},
        "second_stage_frontier": {
            "stage-off": {"comp_mbs": 100.0, "decomp_mbs": 100.0, "cr": 7.0,
                          "cr_gain": 1.0, "comp_rel": 1.0, "decomp_rel": 1.0},
            "stage-rle": {"comp_mbs": 55.0, "decomp_mbs": 95.0, "cr": 7.0,
                          "cr_gain": 1.0, "comp_rel": 0.55, "decomp_rel": 0.95},
            "stage-deflate": {"comp_mbs": 90.0, "decomp_mbs": 92.0, "cr": 10.7,
                              "cr_gain": 1.53, "comp_rel": 0.90,
                              "decomp_rel": 0.92},
        },
        "telemetry_overhead": {
            "comp_mbs": 100.0, "decomp_mbs": 50.0,
            "comp_mbs_obs": 99.0, "decomp_mbs_obs": 49.6,
            "comp_overhead": 0.010, "decomp_overhead": 0.008,
            "frames": 16,
        },
    }
}


def _doctor(**kv):
    doc = copy.deepcopy(BASE)
    doc["chunked_dump_load"]["mono"].update(kv)
    return doc


def _doctor_stage(kind, **kv):
    doc = copy.deepcopy(BASE)
    doc["chunked_dump_load"]["second_stage_frontier"][kind].update(kv)
    return doc


def _cmp(fresh, **kw):
    kw.setdefault("max_drop", 0.30)
    kw.setdefault("max_cr_drift", 0.01)
    return compare(BASE, fresh, **kw)


def test_identical_passes():
    assert _cmp(copy.deepcopy(BASE)) == []


def test_within_tolerance_passes():
    # 25% slower and 0.5% CR drift: inside the 30% / 1% envelope
    assert _cmp(_doctor(comp_mbs=75.0, decomp_mbs=40.0, cr=7.03)) == []


def test_throughput_drop_fails():
    errs = _cmp(_doctor(decomp_mbs=30.0))          # 40% drop
    assert len(errs) == 1 and "decomp_mbs" in errs[0]


def test_cr_drift_fails_both_directions():
    assert "cr" in _cmp(_doctor(cr=7.2))[0]        # ~2.9% up
    assert "cr" in _cmp(_doctor(cr=6.8))[0]        # ~2.9% down


def test_size_mismatch_fails():
    doc = copy.deepcopy(BASE)
    doc["chunked_dump_load"]["n"] = 1024
    errs = _cmp(doc)
    assert len(errs) == 1 and "size mismatch" in errs[0]


def test_fresh_only_row_fails_with_clear_message():
    """A new bench row with no committed baseline counterpart must produce
    the regenerate-the-baseline message, not a KeyError / silent pass."""
    doc = copy.deepcopy(BASE)
    doc["chunked_dump_load"]["tree_checkpoint"] = {
        "comp_mbs": 10.0, "decomp_mbs": 10.0, "cr": 5.0,
    }
    errs = _cmp(doc)
    assert len(errs) == 1
    assert "baseline missing row tree_checkpoint" in errs[0]
    assert "BENCH_codec_smoke.json" in errs[0]


def test_missing_metric_key_reported_not_keyerror():
    doc = copy.deepcopy(BASE)
    del doc["chunked_dump_load"]["mono"]["cr"]
    errs = _cmp(doc)
    assert errs == ["mono.cr: missing from fresh results"]


def test_missing_kind_and_section_fail():
    doc = copy.deepcopy(BASE)
    del doc["chunked_dump_load"]["chunked"]
    assert any("chunked: missing" in e for e in _cmp(doc))
    assert _cmp({}) == ["fresh results have no chunked_dump_load section"]


def test_frontier_missing_from_fresh_fails():
    doc = copy.deepcopy(BASE)
    del doc["chunked_dump_load"]["second_stage_frontier"]
    assert any("no second_stage_frontier" in e for e in _cmp(doc))


def test_frontier_missing_from_baseline_fails():
    base = copy.deepcopy(BASE)
    del base["chunked_dump_load"]["second_stage_frontier"]
    errs = compare(base, copy.deepcopy(BASE), max_drop=0.30, max_cr_drift=0.01)
    assert any("baseline missing second_stage_frontier" in e for e in errs)


def test_frontier_no_stage_on_target_fails():
    # deflate degraded below the 1.5x CR gain floor: nothing hits the frontier
    errs = _cmp(_doctor_stage("stage-deflate", cr_gain=1.2))
    assert len(errs) == 1 and "no stage reaches" in errs[0]
    # ...or the gain is there but the throughput cost blew the <30% budget
    errs = _cmp(_doctor_stage("stage-deflate", comp_rel=0.5))
    assert len(errs) == 1 and "no stage reaches" in errs[0]


def test_frontier_stage_losing_ratio_fails():
    # per-frame negotiation guarantees a stage never loses; cr_gain < 1 in
    # the bench means negotiation is broken, whatever the frontier says
    errs = _cmp(_doctor_stage("stage-rle", cr_gain=0.9))
    assert len(errs) == 1 and "never lose ratio" in errs[0]


def test_frontier_missing_stage_off_fails():
    doc = copy.deepcopy(BASE)
    del doc["chunked_dump_load"]["second_stage_frontier"]["stage-off"]
    assert any("stage-off reference" in e for e in _cmp(doc))


def _doctor_telemetry(**kv):
    doc = copy.deepcopy(BASE)
    doc["chunked_dump_load"]["telemetry_overhead"].update(kv)
    return doc


def test_telemetry_overhead_above_ceiling_fails():
    errs = _cmp(_doctor_telemetry(comp_overhead=0.05))
    assert len(errs) == 1 and "3%" in errs[0] and "comp_overhead" in errs[0]
    errs = _cmp(_doctor_telemetry(decomp_overhead=0.031))
    assert len(errs) == 1 and "decomp_overhead" in errs[0]
    # negative overhead (obs run measured faster: noise) passes
    assert _cmp(_doctor_telemetry(comp_overhead=-0.01)) == []


def test_telemetry_overhead_missing_fails():
    doc = copy.deepcopy(BASE)
    del doc["chunked_dump_load"]["telemetry_overhead"]
    assert any("no telemetry_overhead" in e for e in _cmp(doc))
    doc = copy.deepcopy(BASE)
    del doc["chunked_dump_load"]["telemetry_overhead"]["comp_overhead"]
    assert any("comp_overhead: missing" in e for e in _cmp(doc))


def test_main_exit_codes(tmp_path):
    b = tmp_path / "baseline.json"
    b.write_text(json.dumps(BASE))
    good = tmp_path / "good.json"
    good.write_text(json.dumps(BASE))
    slow = tmp_path / "slow.json"
    slow.write_text(json.dumps(_doctor(comp_mbs=10.0)))
    assert main(["--baseline", str(b), "--fresh", str(good)]) == 0
    assert main(["--baseline", str(b), "--fresh", str(slow)]) == 1
    # looser tolerance rescues the same file
    assert main(["--baseline", str(b), "--fresh", str(slow), "--max-drop", "0.95"]) == 0


def test_cli_exits_nonzero_on_doctored_json(tmp_path):
    """End to end: the exact command CI runs returns a non-zero exit code."""
    b = tmp_path / "baseline.json"
    b.write_text(json.dumps(BASE))
    slow = tmp_path / "slow.json"
    slow.write_text(json.dumps(_doctor(decomp_mbs=1.0)))
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--baseline", str(b), "--fresh", str(slow)],
        capture_output=True, text=True, cwd=REPO_ROOT,
    )
    assert r.returncode != 0
    assert "REGRESSION" in r.stderr
    r_ok = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--baseline", str(b), "--fresh", str(b)],
        capture_output=True, text=True, cwd=REPO_ROOT,
    )
    assert r_ok.returncode == 0, r_ok.stderr
