"""Pallas flash-attention kernel vs the pure-jnp scan implementation and the
naive oracle, swept over shapes/masks (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_fwd
from repro.models import layers as L


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
@pytest.mark.parametrize("s,hq,hkv,hd", [(64, 4, 2, 32), (96, 2, 1, 16), (128, 8, 8, 8)])
def test_flash_kernel_matches_scan(causal, window, s, hq, hkv, hd):
    key = jax.random.key(s * hq + hkv + hd)
    kq, kk, kv = jax.random.split(key, 3)
    b = 2
    q = jax.random.normal(kq, (b, s, hq, hd), jnp.float32)
    k = jax.random.normal(kk, (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(kv, (b, s, hkv, hd), jnp.float32)
    out_kernel = flash_attention_fwd(
        q, k, v, causal=causal, window=window, q_block=32, kv_block=32,
        interpret=True,
    )
    out_scan = L.flash_attention(
        q, k, v, causal=causal, window=window, q_chunk=32, kv_chunk=32
    )
    np.testing.assert_allclose(
        np.asarray(out_kernel), np.asarray(out_scan), rtol=2e-4, atol=2e-5
    )


def test_flash_kernel_unaligned_seq():
    q = jax.random.normal(jax.random.key(0), (1, 50, 4, 16))
    k = jax.random.normal(jax.random.key(1), (1, 50, 2, 16))
    v = jax.random.normal(jax.random.key(2), (1, 50, 2, 16))
    a = flash_attention_fwd(q, k, v, q_block=32, kv_block=32, interpret=True)
    b = L.flash_attention(q, k, v, q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)
