"""Streaming store-backed training ingest: sampler determinism, pipelined
vs serial equality, read coalescing, bytes-read ∝ windows, the Prefetcher
failure contract, cache thread-safety/LRU, and checkpoint/store convergence."""
import io
import os
import threading

import numpy as np
import pytest

from repro.api import ArrayStore, Bound
from repro.data import (
    CompressedInMemoryCache,
    DataConfig,
    Prefetcher,
    SteppedBatches,
    StoreLM,
    StoreLoader,
    WindowSampler,
    window_for_values,
)
from repro.data.store_loader import plan_batch
from repro.store.array import CompressedArray
from repro.store import grid as grid_mod


def _walk(n, seed=0, scale=0.01, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.standard_normal(n) * scale).astype(dtype)


def _store(x, error_bound, **kw):
    buf = io.BytesIO()
    idx = ArrayStore.save(buf, x, error_bound, **kw)
    buf.seek(0)
    return buf, idx


class SpyFile:
    """Byte-range-recording wrapper over a seekable binary file."""

    def __init__(self, raw):
        self.raw = raw
        self.reads: list[tuple[int, int]] = []

    def seek(self, *a):
        return self.raw.seek(*a)

    def tell(self):
        return self.raw.tell()

    def read(self, n=-1):
        off = self.raw.tell()
        data = self.raw.read(n)
        if data:
            self.reads.append((off, len(data)))
        return data

    def bytes_read(self) -> int:
        return sum(ln for _, ln in self.reads)


# ---------------------------------------------------------------- sampler
def test_sampler_restart_determinism_per_rank():
    shape, wshape = (512, 128), (8, 128)
    for rank in range(2):
        s1 = WindowSampler(shape, wshape, 8, seed=42, rank=rank, num_ranks=2)
        s2 = WindowSampler(shape, wshape, 8, seed=42, rank=rank, num_ranks=2)
        # seeking straight to step N equals iterating there: pure function
        for step in (0, 3, 17):
            assert np.array_equal(s1.origins_at(step), s2.origins_at(step))
    a = WindowSampler(shape, wshape, 8, seed=42, rank=0, num_ranks=2)
    b = WindowSampler(shape, wshape, 8, seed=42, rank=1, num_ranks=2)
    assert not np.array_equal(a.origins_at(0), b.origins_at(0))
    assert a.batch == 4


def test_sampler_origins_in_bounds():
    s = WindowSampler((40, 64), (40, 17), 16, seed=0)
    org = s.origins_at(5)
    assert org.shape == (16, 2)
    assert np.all(org[:, 0] == 0)           # window spans the whole dim
    assert np.all((org[:, 1] >= 0) & (org[:, 1] <= 64 - 17))


def test_sampler_validation():
    with pytest.raises(ValueError):
        WindowSampler((10, 10), (11, 1), 4)
    with pytest.raises(ValueError):
        WindowSampler((10,), (2,), 5, num_ranks=2)
    with pytest.raises(ValueError):
        WindowSampler((10,), (2,), 4, rank=2, num_ranks=2)


def test_window_for_values_trailing_whole():
    assert window_for_values((256, 512), 65) == (1, 65)
    assert window_for_values((100,), 65) == (65,)
    w = window_for_values((4, 8, 16), 100)
    assert np.prod(w) >= 100 and w[2] == 16


# ----------------------------------------------------------------- loader
def test_loader_restart_determinism_stream():
    x = _walk(256 * 256, seed=1).reshape(256, 256)
    buf, _ = _store(x, 1e-3, chunk_shape=(32, 256))
    with ArrayStore.open(buf) as ca:
        ld = StoreLoader(ca, (4, 256), 8, seed=7, workers=2)
        ref = [ld.batch_at(s) for s in range(6)]
        # resume at step 3 => byte-identical stream from there
        got = [b.copy() for b in ld.batches(start_step=3, steps=3)]
        for i, g in enumerate(got):
            assert np.array_equal(g, ref[3 + i])


def test_pipelined_equals_serial():
    x = _walk(128 * 300, seed=2).reshape(128, 300)
    buf, _ = _store(x, 1e-3, chunk_shape=(16, 300))
    with ArrayStore.open(buf) as ca:
        ld = StoreLoader(ca, (8, 40), 4, seed=11, workers=3, lookahead=2)
        with ld.batches(steps=5) as it:
            for step, batch in enumerate(it):
                assert np.array_equal(batch, ld.batch_at(step))


def test_loader_values_within_bound():
    x = _walk(64 * 512, seed=3).reshape(64, 512)
    buf, _ = _store(x, 1e-3, chunk_shape=(16, 512))
    with ArrayStore.open(buf) as ca:
        ld = StoreLoader(ca, (4, 64), 4, seed=5)
        batch = ld.batch_at(2)
        for wi, (r, c) in enumerate(ld.sampler.origins_at(2)):
            assert np.max(np.abs(batch[wi] - x[r:r + 4, c:c + 64])) \
                <= 1e-3 + 1e-6


def test_plan_coalesces_windows_per_chunk():
    grid = grid_mod.ChunkGrid((64, 64), (16, 64))
    # three windows in the SAME chunk -> exactly one merged task
    origins = np.array([[0, 0], [4, 8], [9, 16]])
    tasks, placements = plan_batch(grid, 64, origins, (2, 8))
    assert len(tasks) == 1 and len(placements) == 3
    (lo_b, hi_b), = tasks.values()
    assert lo_b == 0 and hi_b >= 1


def test_bytes_read_scale_with_windows():
    """Seek-spy: a small-window epoch over a large store reads ~windows
    bytes, far below the file size."""
    x = _walk(512 * 1024, seed=4).reshape(512, 1024)
    buf, _ = _store(x, 1e-3, chunk_shape=(32, 1024))
    file_bytes = len(buf.getvalue())
    spy = SpyFile(buf)
    with ArrayStore.open(spy) as ca:
        ld = StoreLoader(ca, (2, 1024), 2, seed=13)
        spy.reads.clear()
        steps = 2
        for s in range(steps):
            ld.batch_at(s)
        touched = spy.bytes_read()
    window_raw = steps * 2 * 2 * 1024 * 4
    # the 4 windows decode ~0.8% of the store; reads must stay far below
    # the file size and within a small multiple of the window bytes
    # (per-chunk metadata prefixes dominate at this tiny scale)
    assert touched < 0.15 * file_bytes
    assert touched < 8 * window_raw


def test_loader_worker_exception_propagates():
    x = _walk(64 * 64, seed=5).reshape(64, 64)
    buf, _ = _store(x, 1e-3, chunk_shape=(16, 64))
    with ArrayStore.open(buf) as ca:
        ld = StoreLoader(ca, (4, 64), 4, seed=1, workers=2)
        it = ld.batches()
        next(it)

        def explode(cid, lo_b, hi_b):
            raise ValueError("injected decode failure")

        ca._decode_chunk_range = explode    # workers hit this on later steps
        with pytest.raises(ValueError, match="injected"):
            for _ in range(8):
                next(it)
        with pytest.raises(StopIteration):
            next(it)                    # closed after the error


def test_loader_reuse_slots_and_copy():
    x = _walk(64 * 64, seed=6).reshape(64, 64)
    buf, _ = _store(x, 1e-3, chunk_shape=(16, 64))
    with ArrayStore.open(buf) as ca:
        ld = StoreLoader(ca, (4, 64), 2, seed=2, workers=1, reuse_slots=2)
        it = ld.batches(steps=4)
        b0 = next(it)
        b1 = next(it)
        b2 = next(it)                   # slot of b0 is recycled here
        assert b2 is b0 and b1 is not b0
        it.close()
        ldc = StoreLoader(ca, (4, 64), 2, seed=2, workers=1, copy=True)
        got = list(ldc.batches(steps=3))
        assert len({id(b) for b in got}) == 3


def test_stepped_batches_reopens_on_seek():
    x = _walk(64 * 128, seed=7).reshape(64, 128)
    buf, _ = _store(x, 1e-3, chunk_shape=(16, 128))
    with ArrayStore.open(buf) as ca:
        ld = StoreLoader(ca, (4, 128), 4, seed=3, workers=2)
        with SteppedBatches(lambda s: ld.batches(start_step=s)) as fn:
            b0, b1 = fn(0).copy(), fn(1).copy()
            # Trainer restart: jump back to step 0 -> same bytes again
            assert np.array_equal(fn(0), b0)
            assert np.array_equal(fn(1), b1)


def test_loader_over_shard_manifest(tmp_path):
    x = _walk(128 * 256, seed=8).reshape(128, 256)
    man = str(tmp_path / "m.json")
    ArrayStore.save_sharded(man, x, Bound.abs(1e-3), nshards=3,
                            chunk_shape=(16, 256))
    with StoreLoader(man, (4, 256), 4, seed=9, workers=2) as ld:
        got = [b.copy() for b in ld.batches(steps=3)]
        for s, g in enumerate(got):
            assert np.array_equal(g, ld.batch_at(s))


# ---------------------------------------------------------------- StoreLM
def test_store_lm_contract():
    x = _walk(128 * 128, seed=9).reshape(128, 128)
    buf, _ = _store(x, 1e-4, chunk_shape=(16, 128))
    with ArrayStore.open(buf) as ca:
        cfg = DataConfig(512, 32, 4, seed=21)
        lm = StoreLM(ca, cfg, workers=2)
        b = lm.batch_at(0)
        assert b["tokens"].shape == (4, 32)
        assert b["tokens"].dtype == np.int32
        assert b["tokens"].min() >= 1 and b["tokens"].max() <= 510
        assert np.array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
        # restart contract mirrors SyntheticLM
        assert np.array_equal(lm.batch_at(3)["tokens"],
                              lm.batch_at(3)["tokens"])
        it = lm.batches(start_step=2)
        p2 = next(it)
        assert np.array_equal(p2["tokens"], lm.batch_at(2)["tokens"])
        it.close()


# -------------------------------------------------------------- Prefetcher
def test_prefetcher_propagates_worker_exception():
    def gen():
        yield 1
        yield 2
        raise RuntimeError("boom")

    p = Prefetcher(gen(), depth=1)
    assert next(p) == 1 and next(p) == 2
    with pytest.raises(RuntimeError, match="boom"):
        next(p)
    with pytest.raises(StopIteration):
        next(p)


def test_prefetcher_close_joins_blocked_producer():
    def forever():
        i = 0
        while True:
            yield i
            i += 1

    with Prefetcher(forever(), depth=1) as p:
        assert next(p) == 0
    assert not p._thread.is_alive()
    p.close()                                     # idempotent


def test_prefetcher_normal_exhaustion():
    p = Prefetcher(iter([1, 2, 3]), depth=2)
    assert list(p) == [1, 2, 3]
    p.close()


# ------------------------------------------------------------------- cache
def test_compressed_cache_thread_safe_and_lru():
    c = CompressedInMemoryCache(1e-4, max_bytes=1 << 16)
    errs = []

    def worker(k):
        try:
            for i in range(30):
                key = (k, i % 7)
                c.put(key, np.full(1024, float(i + k), np.float32))
                if key in c:
                    c.get(key)
        except Exception as e:                    # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    assert c.stored_bytes <= 1 << 16


def test_compressed_cache_evicts_lru_order():
    c = CompressedInMemoryCache(1e-6, max_bytes=1)   # everything overflows
    c.put("a", _walk(4096, seed=1))
    c.put("b", _walk(4096, seed=2))
    assert len(c) == 1 and "b" in c and "a" not in c
    assert c.evictions >= 1
    with pytest.raises(KeyError):
        c.get("a")


def test_compressed_cache_get_touches_recency():
    vals = {k: _walk(4096, seed=i) for i, k in enumerate("abc")}
    one = len(__import__("repro.core.szx", fromlist=["compress"]).compress(
        vals["a"], 1e-6))
    c = CompressedInMemoryCache(1e-6, max_bytes=int(one * 2.5))
    c.put("a", vals["a"])
    c.put("b", vals["b"])
    c.get("a")                       # a becomes most-recent
    c.put("c", vals["c"])            # evicts b, not a
    assert "a" in c and "c" in c and "b" not in c


# --------------------------------------------- checkpoint/store convergence
def test_checkpoint_save_store_roundtrip_and_loader(tmp_path):
    from repro.checkpoint import CheckpointManager

    ck = CheckpointManager(str(tmp_path), compress=True,
                           bound=Bound.abs(1e-3))
    corpus = _walk(128 * 256, seed=10).reshape(128, 256)
    path = ck.save_store("corpus", corpus, chunk_shape=(16, 256))
    assert os.path.exists(path) and ck.stores() == ["corpus"]
    got = ck.restore_store("corpus")
    assert np.max(np.abs(got - corpus)) <= 1e-3 + 1e-6
    with ck.open_store("corpus") as ca:
        with StoreLoader(ca, (4, 256), 4, seed=1, workers=2) as ld:
            for s, b in enumerate(ld.batches(steps=2)):
                assert np.array_equal(b, ld.batch_at(s))
    with pytest.raises(ValueError):
        ck.save_store("../evil", corpus)


def test_checkpoint_leaf_store_window_queryable(tmp_path):
    from repro.checkpoint import CheckpointManager

    ck = CheckpointManager(str(tmp_path), compress=True,
                           bound=Bound.abs(1e-3), chunk_bytes=1 << 18)
    w = _walk(300 * 256, seed=11).reshape(300, 256)
    ck.save(0, {"w": w, "step": np.int32(7)})
    lv = ck.leaf_store("w", 0)
    try:
        assert isinstance(lv, CompressedArray)
        assert lv.shape == (300 * 256,) and lv.nchunks >= 2
        assert lv.attrs["leaf_shape"] == [300, 256]
        full = lv[...]
        assert np.max(np.abs(full.reshape(300, 256) - w)) \
            <= lv.error_bound + 1e-7
        # ROI read through the synthesized (seq_base) view
        assert np.array_equal(lv[1000:5000], full[1000:5000])
        # compressed-domain stats survive the seq offset
        assert lv.stats().mean[0] == pytest.approx(full.mean(), rel=1e-5)
        # and a checkpoint leaf streams through the SAME loader
        with StoreLoader(lv, (2048,), 4, seed=2, workers=2) as ld:
            for s, b in enumerate(ld.batches(steps=2)):
                assert np.array_equal(b, ld.batch_at(s))
    finally:
        lv.close()
    with pytest.raises(ValueError):
        ck.leaf_store("step", 0)     # raw-pack leaf is not store-viewable


# ---------------------------------------------------- epochs= sampling

def _epoch_origins(shape, wshape, gbatch, num_ranks, epochs, seed=21):
    """All origins drawn across every rank and step, grouped per epoch."""
    samplers = [
        WindowSampler(shape, wshape, gbatch, seed=seed, rank=r,
                      num_ranks=num_ranks, epochs=epochs)
        for r in range(num_ranks)
    ]
    nsteps = samplers[0].num_steps
    per_epoch: dict[int, list[tuple]] = {}
    nwin = samplers[0]._nwin
    for step in range(nsteps):
        for r, s in enumerate(samplers):
            for i, o in enumerate(s.origins_at(step)):
                g = step * gbatch + r * s.batch + i
                per_epoch.setdefault(g // nwin, []).append(tuple(o))
    return samplers[0], per_epoch


def test_sampler_epochs_without_replacement():
    # tiles (4, 4) -> 16 candidate windows; 2 epochs of 4 global steps
    s, per_epoch = _epoch_origins((40, 64), (10, 16), 4, 2, 2)
    assert s.num_steps == 8
    want = {(i * 10, j * 16) for i in range(4) for j in range(4)}
    for epoch, origins in per_epoch.items():
        assert len(origins) == 16
        assert set(origins) == want, f"epoch {epoch} is not a permutation"
    # the two epochs use different permutations
    assert per_epoch[0] != per_epoch[1]


def test_sampler_epochs_uneven_batch_spans_epochs():
    # nwin = 9, global batch 3 -> epoch boundary falls mid-run; every
    # epoch must still be an exact permutation of the 9 tiles
    s, per_epoch = _epoch_origins((9, 8), (3, 4), 3, 1, 3, seed=5)
    want = {(i * 3, j * 4) for i in range(3) for j in range(2)}
    assert s._nwin == 6
    assert s.num_steps == (3 * 6) // 3
    for origins in per_epoch.values():
        assert set(origins) == want and len(origins) == 6


def test_sampler_epochs_seek_deterministic():
    kw = dict(seed=9, rank=1, num_ranks=2, epochs=4)
    a = WindowSampler((64, 64), (8, 8), 8, **kw)
    b = WindowSampler((64, 64), (8, 8), 8, **kw)
    # out-of-order seeks (trainer restart) match in-order replay
    steps = [17, 0, 5, 17, 3, 0]
    for st in steps:
        np.testing.assert_array_equal(a.origins_at(st), b.origins_at(st))
    # and legacy with-replacement behaviour is untouched by the new kwarg
    legacy = WindowSampler((64, 64), (8, 8), 8, seed=9, rank=1, num_ranks=2)
    legacy2 = WindowSampler((64, 64), (8, 8), 8, seed=9, rank=1, num_ranks=2)
    np.testing.assert_array_equal(legacy.origins_at(3), legacy2.origins_at(3))


def test_sampler_epochs_rank_disjoint():
    rs = [WindowSampler((64, 64), (8, 8), 16, seed=2, rank=r, num_ranks=4,
                        epochs=1) for r in range(4)]
    for step in range(rs[0].num_steps):
        seen: set = set()
        for s in rs:
            mine = {tuple(o) for o in s.origins_at(step)}
            assert not (seen & mine)
            seen |= mine


def test_sampler_epochs_bounds_and_validation():
    s = WindowSampler((64, 64), (8, 8), 8, seed=0, epochs=2)
    assert s.num_steps == (2 * 64) // 8
    s.origins_at(s.num_steps - 1)
    with pytest.raises(ValueError, match="out of range"):
        s.origins_at(s.num_steps)
    with pytest.raises(ValueError, match="out of range"):
        s.origins_at(-1)
    with pytest.raises(ValueError, match="positive int"):
        WindowSampler((64, 64), (8, 8), 8, epochs=0)
    with pytest.raises(ValueError, match="positive int"):
        WindowSampler((64, 64), (8, 8), 8, epochs=True)
    with pytest.raises(ValueError, match="candidate windows"):
        # 2x2 tiling = 4 windows < global batch 8
        WindowSampler((64, 64), (32, 32), 8, epochs=1)
    with pytest.raises(ValueError, match="only defined"):
        _ = WindowSampler((64, 64), (8, 8), 8).num_steps


def test_loader_epochs_stops_at_num_steps():
    x = _walk(64 * 64, seed=30).reshape(64, 64)
    buf, _ = _store(x, 1e-3, chunk_shape=(16, 64))
    with ArrayStore.open(buf) as ca:
        ld = StoreLoader(ca, (8, 8), 4, seed=3, workers=2, epochs=1)
        assert ld.sampler.num_steps == 16
        with ld.batches() as it:
            got = sum(1 for _ in it)
        assert got == 16
        # explicit steps= beyond the epoch budget is clamped, not an error
        with ld.batches(start_step=14, steps=100) as it:
            assert sum(1 for _ in it) == 2
