"""Tests for repro.store: block-addressable compressed N-d array store.

Covers the acceptance contract (1% ROI of a >=64 MB store reads <5% of the
file and never parses non-intersecting chunks), numpy-equivalent ROI read
semantics across dtypes, the partial-decode entry points, the
compressed-domain query tiers, the grid math, the CLI, and the HTTP
slice-serving layer.
"""
import io
import json
import threading
import urllib.request

import numpy as np
import pytest

from repro.core.codec import SZxCodec, container, plan, transform
from repro.store import ArrayStore, grid as grid_mod
from repro.store.__main__ import main as store_main, parse_roi
from repro.store.grid import ChunkGrid

try:
    import ml_dtypes

    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes ships with jax
    BF16 = None

CODEC = SZxCodec(backend="numpy")


def _walk(n, seed=0, scale=0.01, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (np.cumsum(rng.standard_normal(n)) * scale).astype(dtype)


def _store(x, error_bound, **kw) -> tuple[io.BytesIO, dict]:
    buf = io.BytesIO()
    idx = ArrayStore.save(buf, x, error_bound, **kw)
    return buf, idx


class SpyFile:
    """Byte-range-recording wrapper over a seekable binary file."""

    def __init__(self, raw):
        self.raw = raw
        self.reads: list[tuple[int, int]] = []

    def seek(self, *a):
        return self.raw.seek(*a)

    def tell(self):
        return self.raw.tell()

    def read(self, n=-1):
        off = self.raw.tell()
        data = self.raw.read(n)
        if data:
            self.reads.append((off, len(data)))
        return data

    def bytes_read(self) -> int:
        return sum(ln for _, ln in self.reads)


def _covered(reads, ranges):
    for off, ln in reads:
        if not any(lo <= off and off + ln <= hi for lo, hi in ranges):
            return (off, ln)
    return None


# ---------------------------------------------------------------------------
# grid math
# ---------------------------------------------------------------------------

def test_default_chunk_shape_targets_bytes():
    assert grid_mod.default_chunk_shape((1024, 256, 256), 4, 2 << 20) == (8, 256, 256)
    assert grid_mod.default_chunk_shape((100,), 4, 2 << 20) == (100,)
    # one row bigger than the target: trailing dims split too
    assert grid_mod.default_chunk_shape((4, 1 << 22), 4, 1 << 20) == (1, 1 << 18)


def test_chunk_grid_geometry():
    g = ChunkGrid((10, 7), (4, 3))
    assert g.chunks_per_dim == (3, 3) and g.nchunks == 9
    for cid in range(g.nchunks):
        assert g.chunk_id(g.chunk_coord(cid)) == cid
    assert g.chunk_box((2, 2)) == ((8, 10), (6, 7))       # edge-clipped
    assert g.chunk_dims((2, 2)) == (2, 1)
    with pytest.raises(ValueError):
        ChunkGrid((10,), (11,))
    with pytest.raises(ValueError):
        ChunkGrid((10, 7), (4,))


def test_normalize_roi_matches_numpy_semantics():
    shape = (10, 8, 6)
    x = np.arange(np.prod(shape)).reshape(shape)
    for key in [np.s_[...], np.s_[2], np.s_[-1], np.s_[1:4], np.s_[:, 3],
                np.s_[2:5, ..., 1], np.s_[..., -2], np.s_[1:4, 2:3, 5],
                np.s_[9, 7, 5], np.s_[5:5]]:
        roi = grid_mod.normalize_roi(key, shape)
        want = x[key]
        assert roi.out_shape == want.shape, key
    with pytest.raises(ValueError):
        grid_mod.normalize_roi(np.s_[::2], shape)
    with pytest.raises(TypeError):
        grid_mod.normalize_roi([0, 2], shape)
    with pytest.raises(TypeError):
        grid_mod.normalize_roi(np.s_[True], shape)
    with pytest.raises(IndexError):
        grid_mod.normalize_roi(np.s_[10], shape)
    with pytest.raises(ValueError):
        grid_mod.normalize_roi(np.s_[0, 0, 0, 0], shape)


def test_block_range_for_box_is_tight_for_slabs():
    # leading-axis slab of a (8, 256) chunk with bs=128: 2 blocks per row
    assert grid_mod.block_range_for_box(((2, 4), (0, 256)), (8, 256), 128) == (4, 8)
    # single element
    assert grid_mod.block_range_for_box(((3, 4), (5, 6)), (8, 256), 128) == (6, 7)


# ---------------------------------------------------------------------------
# save / open / ROI reads
# ---------------------------------------------------------------------------

def test_roundtrip_and_roi_reads_match_numpy():
    x = _walk(64 * 48 * 32, seed=1).reshape(64, 48, 32)
    buf, idx = _store(x, plan.Bound.rel(1e-3), chunk_shape=(16, 48, 32))
    e = idx["e"]
    with ArrayStore.open(buf) as ca:
        assert ca.shape == x.shape and ca.dtype == x.dtype and ca.ndim == 3
        assert ca.nchunks == 4 and ca.error_bound == e
        full = ca[...]
        assert np.abs(full - x).max() <= e
        for key in [np.s_[3:9, 10:20, 5], np.s_[0], np.s_[:, 7], np.s_[-1, ...],
                    np.s_[60:, :, 30:], np.s_[5:5], np.s_[63, 47, 31],
                    np.s_[10:40]]:
            got = ca[key]
            want = x[key]
            assert got.shape == want.shape, key
            assert got.dtype == x.dtype
            if want.size:
                assert np.abs(
                    got.astype(np.float64) - want.astype(np.float64)
                ).max() <= e, key
        assert np.array_equal(ca.read(np.s_[2:4]), ca[2:4])
    with pytest.raises(ValueError):
        ca[0]                                  # closed


@pytest.mark.parametrize(
    "dtype,e",
    [(np.float32, 1e-3), (np.float64, 1e-7), (np.float16, 1e-2)]
    + ([(BF16, 1e-2)] if BF16 is not None else []),
    ids=lambda v: getattr(np.dtype(v), "name", str(v)) if not isinstance(v, float) else None,
)
def test_store_dtypes(dtype, e):
    x = _walk(5000, seed=2, dtype=dtype).reshape(50, 100)
    buf, idx = _store(x, e, chunk_shape=(16, 100))
    with ArrayStore.open(buf) as ca:
        got = ca[7:31, 20:90]
        assert got.dtype == np.dtype(dtype)
        err = np.abs(
            got.astype(np.float64) - x[7:31, 20:90].astype(np.float64)
        ).max()
        assert err <= e


def test_store_chunks_are_bit_identical_to_monolithic_compress():
    x = _walk(4 * 1000, seed=3).reshape(4, 1000)
    buf, idx = _store(x, 1e-3, chunk_shape=(1, 1000))
    raw = buf.getvalue()
    for cid, (off, length, elems) in enumerate(idx["frames"]):
        payload, _ = container.read_frame_at(io.BytesIO(raw), off, length, cid)
        assert payload == CODEC.compress(x[cid], 1e-3)
        assert elems == 1000


def test_store_workers_bytes_identical():
    x = _walk(1 << 16, seed=4).reshape(64, 1024)
    b1, _ = _store(x, 1e-3, chunk_shape=(8, 1024), workers=1)
    b2, _ = _store(x, 1e-3, chunk_shape=(8, 1024), workers=4)
    assert b1.getvalue() == b2.getvalue()


def test_store_rejects_bad_inputs():
    with pytest.raises(TypeError):
        ArrayStore.save(io.BytesIO(), np.arange(10), 1e-3)       # int dtype
    with pytest.raises(ValueError):
        ArrayStore.save(io.BytesIO(), np.float32(1.0), 1e-3)     # 0-d
    with pytest.raises(ValueError):
        ArrayStore.save(io.BytesIO(), np.empty((0, 4), np.float32), 1e-3)
    with pytest.raises(ValueError):
        ArrayStore.open(io.BytesIO(b""))                          # no footer
    chunked = io.BytesIO()
    CODEC.dump_chunked(_walk(1000), chunked, 1e-3)
    with pytest.raises(ValueError, match="kind"):
        ArrayStore.open(chunked)                                  # wrong kind


def test_store_file_paths(tmp_path):
    x = _walk(4096, seed=5).reshape(64, 64)
    p = tmp_path / "a.szs"
    ArrayStore.save(str(p), x, 1e-3)
    with ArrayStore.open(str(p)) as ca:
        assert np.abs(ca[...] - x).max() <= 1e-3
    # the store file is also a well-formed container-v3 stream
    with open(p, "rb") as f:
        assert container.read_index_footer(f)["kind"] == "szx-store"


# ---------------------------------------------------------------------------
# acceptance: seek-spy on a >= 64 MB store
# ---------------------------------------------------------------------------

def test_acceptance_roi_read_is_byte_proportional():
    """1% ROI of a >=64 MB stored array reads <5% of the file's bytes and
    never parses (or reads) non-intersecting chunks."""
    n = 1 << 24                                   # 64 MiB of float32
    rng = np.random.default_rng(6)
    base = np.cumsum(rng.standard_normal(n // 4096)).astype(np.float32)
    x = (np.repeat(base, 4096) + rng.standard_normal(n).astype(np.float32) * 0.01)
    x = x.reshape(256, 256, 256)
    assert x.nbytes >= 64 << 20
    buf = io.BytesIO()
    idx = ArrayStore.save(buf, x, plan.Bound.rel(1e-3), workers=2)
    end = buf.seek(0, 2)
    frames = idx["frames"]

    spy = SpyFile(buf)
    ca = ArrayStore.open(spy)
    spy.reads.clear()

    touched: list[int] = []
    orig = ca._decode_chunk_range

    def tracking(cid, lo_b, hi_b):
        touched.append(cid)
        return orig(cid, lo_b, hi_b)

    ca._decode_chunk_range = tracking
    roi = ca[100:103]                              # 3/256 rows = 1.2%
    assert roi.shape == (3, 256, 256)
    assert np.abs(roi - x[100:103]).max() <= idx["e"]

    # <5% of the file's bytes were read
    assert spy.bytes_read() < 0.05 * end, (spy.bytes_read(), end)

    # only the chunks the ROI intersects were decoded ...
    g = ChunkGrid(tuple(idx["shape"]), tuple(idx["chunk_shape"]))
    expected = [
        cid for cid, _, _ in grid_mod.intersecting_chunks(
            g, grid_mod.normalize_roi(np.s_[100:103], ca.shape)
        )
    ]
    assert touched == expected and 0 < len(touched) < ca.nchunks

    # ... and no byte of any NON-intersecting chunk was read
    allowed = [(frames[c][0], frames[c][0] + frames[c][1]) for c in expected]
    bad = _covered(spy.reads, allowed)
    assert bad is None, f"read outside intersecting chunks: {bad}"

    # a point read touches one chunk and reads at most that chunk's
    # metadata prefix plus one block's mid bytes -- never the whole chunk
    spy.reads.clear()
    touched.clear()
    v = ca[42, 17, 200]
    assert abs(float(v) - float(x[42, 17, 200])) <= idx["e"]
    assert len(touched) == 1
    assert spy.bytes_read() <= frames[touched[0]][1]
    assert spy.bytes_read() < 0.05 * end


# ---------------------------------------------------------------------------
# partial-decode entry points (codec layers)
# ---------------------------------------------------------------------------

def test_decompress_range_matches_full_decode():
    x = _walk(300_000, seed=7)
    buf = CODEC.compress(x, 1e-3)
    full = CODEC.decompress(buf)
    bs = CODEC.block_size
    for lo, hi in ((0, 5), (10, 17), (2343, 2344), (0, 2344)):
        np.testing.assert_array_equal(
            CODEC.decompress_range(buf, lo, hi),
            full[lo * bs : hi * bs],
        )
    with pytest.raises(ValueError):
        CODEC.decompress_range(buf, 5, 5)
    with pytest.raises(ValueError):
        CODEC.decompress_range(buf, 0, 99999)


def test_transform_decode_block_range():
    x = _walk(64 * 128, seed=8)
    p, xt = plan.make_plan(x, 1e-3, backend="numpy")
    xb = plan.to_blocks(xt, p)
    enc = transform.encode_blocks(xb, p)
    full = transform.decode_blocks(enc, p)
    part = transform.decode_block_range(enc, p, 10, 20)
    np.testing.assert_array_equal(part, full[10:20])
    with pytest.raises(ValueError):
        transform.decode_block_range(enc, p, 20, 10)


def test_parse_stream_sections_and_extract_block_range():
    """Section-level parse + mid-range extraction == full parse, per range."""
    x = _walk(100_000, seed=9)
    buf = CODEC.compress(x, 1e-4)
    p_full, enc_full = container.parse_stream(buf, backend="numpy")
    prefix_len = container.stream_prefix_length(buf[:container.HEADER.size])
    sec = container.parse_stream_sections(buf[:prefix_len], backend="numpy")
    assert sec.mid_offset == prefix_len
    assert sec.plan.n == p_full.n
    for lo, hi in ((0, p_full.nblocks), (3, 9), (700, 782)):
        mlo, mhi = sec.mid_range(lo, hi)
        mid = np.frombuffer(buf, np.uint8, mhi - mlo, prefix_len + mlo)
        enc = container.extract_block_range(sec, mid, lo, hi)
        np.testing.assert_array_equal(enc.planes, enc_full.planes[lo:hi])
        np.testing.assert_array_equal(enc.L, enc_full.L[lo:hi])
        np.testing.assert_array_equal(
            transform.decode_blocks(enc, sec.plan),
            transform.decode_blocks(enc_full, p_full)[lo:hi],
        )
    with pytest.raises(ValueError):                  # wrong mid byte count
        container.extract_block_range(sec, np.zeros(3, np.uint8), 0, 1)
    with pytest.raises(ValueError):                  # truncated prefix
        container.parse_stream_sections(buf[: prefix_len - 1])


# ---------------------------------------------------------------------------
# satellite: compressed-domain query tiers
# ---------------------------------------------------------------------------

def _query_fields(dtype):
    """all-constant / no-constant / mixed arrays for one dtype."""
    rng = np.random.default_rng(10)
    n = 40_000
    allc = np.full(n, 2.5).astype(dtype)
    noc = (rng.standard_normal(n) * 10).astype(dtype)
    mixed = np.where(
        (np.arange(n) // 4000) % 2 == 0, allc.astype(np.float64),
        noc.astype(np.float64),
    ).astype(dtype)
    return {"all_const": allc, "no_const": noc, "mixed": mixed}


@pytest.mark.parametrize(
    "dtype", [np.float32, np.float64] + ([BF16] if BF16 is not None else []),
    ids=lambda d: np.dtype(d).name,
)
def test_query_stats_match_numpy_within_bound(dtype):
    """Exact-tier queries agree with np.mean/min/max/sum of the DECOMPRESSED
    array (within the error bound); header-tier intervals always contain
    them -- for all-constant, no-constant, and mixed streams."""
    for name, x in _query_fields(dtype).items():
        e = 1e-2 * float(x.astype(np.float64).max() - x.astype(np.float64).min() or 1.0)
        buf, idx = _store(x.reshape(200, -1), e, chunk_shape=(64, x.size // 200))
        with ArrayStore.open(buf) as ca:
            dec = ca[...].astype(np.float64)
            st = ca.stats()
            assert st.exact and st.count == x.size
            assert abs(st.mean[0] - dec.mean()) <= e, name
            assert abs(st.sum[0] - dec.sum()) <= e * x.size, name
            assert abs(st.min[0] - dec.min()) <= e, name
            assert abs(st.max[0] - dec.max()) <= e, name
            assert ca.mean() == st.mean[0] and ca.sum() == st.sum[0]
            assert ca.min() == st.min[0] and ca.max() == st.max[0]
            hs = ca.stats(header_only=True)
            assert hs.min[0] <= dec.min() <= hs.min[1], name
            assert hs.max[0] <= dec.max() <= hs.max[1], name
            assert hs.sum[0] <= dec.sum() <= hs.sum[1], name
            assert hs.mean[0] <= dec.mean() <= hs.mean[1], name
            if name == "all_const":
                assert hs.exact and hs.const_blocks == hs.nblocks
                assert hs.mean[0] == dec.mean() == st.mean[0]


def test_query_header_only_never_reads_plane_bytes():
    """The header-only tier reads frame metadata only: no L-code or mid
    bytes -- pinned by byte coverage; the exact tier on an all-constant
    stream reads no mid bytes either (there are none to read)."""
    x = _walk(100_000, seed=11).reshape(100, 1000)
    buf, idx = _store(x, plan.Bound.rel(1e-3), chunk_shape=(25, 1000))
    raw = buf.getvalue()

    # per-frame allowed metadata range: frame header + stream header +
    # bitmap + mu + reqlen (everything BEFORE the L-code section)
    allowed = []
    for off, length, _elems in idx["frames"]:
        payload_off = off + container.FRAME_HEADER.size
        hdr = raw[payload_off : payload_off + container.HEADER.size]
        _m, _v, code, bs, n, _e, nb, nnc, _nm = container.HEADER.unpack_from(hdr, 0)
        spec = plan.spec_for_code(code)
        meta_end = payload_off + container.HEADER.size + (nb + 7) // 8 \
            + spec.itemsize * nb + nnc
        allowed.append((off, meta_end))
    footer_lo = idx["frames"][-1][0] + idx["frames"][-1][1]
    allowed.append((footer_lo, len(raw)))

    spy = SpyFile(io.BytesIO(raw))
    ca = ArrayStore.open(spy)
    spy.reads.clear()
    hs = ca.stats(header_only=True)
    assert not hs.exact                       # this field has non-const blocks
    bad = _covered(spy.reads, allowed)
    assert bad is None, f"header-only query read plane bytes: {bad}"

    # all-constant store: the EXACT tier is also metadata-only
    xc = np.full((64, 512), 3.25, np.float32)
    bufc, idxc = _store(xc, 1e-3, chunk_shape=(16, 512))
    rawc = bufc.getvalue()
    allowed_c = [(off, off + ln) for off, ln, _ in idxc["frames"]]
    # all-const payloads END at the mu section; assert no frame is larger
    # than header+bitmap+mu so full-frame coverage implies metadata-only
    spy = SpyFile(io.BytesIO(rawc))
    ca = ArrayStore.open(spy)
    spy.reads.clear()
    st = ca.stats()
    assert st.exact and st.mean[0] == 3.25
    assert st.const_blocks == st.nblocks


def test_query_verbatim_far_from_zero_header_intervals_still_contain():
    """Verbatim blocks store mu = 0, so their header tells NOTHING about the
    values' location: the min/max inner bounds must open to +-inf too, or
    values far from zero escape the 'guaranteed interval' contract."""
    x = (np.float64(1e30) + _walk(8000, seed=23, scale=1e24, dtype=np.float64))
    buf, idx = _store(x.reshape(80, 100), 1e-20, chunk_shape=(80, 100))
    with ArrayStore.open(buf) as ca:
        dec = ca[...].astype(np.float64)
        hs = ca.stats(header_only=True)
        assert hs.verbatim_blocks > 0
        assert hs.min[0] <= dec.min() <= hs.min[1]
        assert hs.max[0] <= dec.max() <= hs.max[1]
        assert hs.sum[0] <= dec.sum() <= hs.sum[1]


def test_query_verbatim_blocks_widen_header_intervals():
    """Bounds below the ulp force verbatim blocks; the header tier cannot
    bound them and must answer with infinite intervals, never wrong ones."""
    x = (_walk(4000, seed=12, scale=1.0) * 100).astype(np.float32)
    tiny = float(np.finfo(np.float32).tiny)
    buf, idx = _store(x.reshape(40, 100), tiny, chunk_shape=(40, 100))
    with ArrayStore.open(buf) as ca:
        dec = ca[...].astype(np.float64)
        np.testing.assert_array_equal(dec.astype(np.float32).reshape(-1), x)
        hs = ca.stats(header_only=True)
        assert hs.verbatim_blocks > 0 and not hs.exact
        assert hs.min[0] <= dec.min() <= hs.min[1]
        assert hs.sum[0] == -np.inf and hs.sum[1] == np.inf
        st = ca.stats()                       # exact tier still exact
        assert st.exact and st.min[0] == dec.min() and st.max[0] == dec.max()


# ---------------------------------------------------------------------------
# CLI + HTTP service
# ---------------------------------------------------------------------------

def test_parse_roi():
    assert parse_roi(None) is Ellipsis
    assert parse_roi("...") is Ellipsis
    assert parse_roi("0:16,:,3") == (slice(0, 16), slice(None), 3)
    assert parse_roi("5") == (5,)
    assert parse_roi("...,1") == (Ellipsis, 1)
    with pytest.raises(ValueError):
        parse_roi("1:2:3:4")


def test_store_cli_roundtrip(tmp_path, capsys):
    x = _walk(1 << 14, seed=13)
    raw = tmp_path / "in.bin"
    x.tofile(raw)
    szs = tmp_path / "a.szs"
    assert store_main([
        "create", str(raw), str(szs), "--shape", "128,128",
        "--error-bound", "1e-3", "--mode", "rel", "--chunk-shape", "32,128",
    ]) == 0
    out = tmp_path / "roi.bin"
    assert store_main(["read", str(szs), str(out), "--roi", "10:20,:"]) == 0
    roi = np.fromfile(out, np.float32).reshape(10, 128)
    e = 1e-3 * float(x.max() - x.min())
    assert np.abs(roi - x.reshape(128, 128)[10:20]).max() <= e
    capsys.readouterr()
    assert store_main(["query", str(szs), "--json"]) == 0
    txt = capsys.readouterr().out
    stats = json.loads(txt[txt.index("{"):])
    assert stats["exact"] and stats["count"] == x.size
    assert store_main(["query", str(szs), "--header-only"]) == 0
    assert store_main(["query", str(szs), "--roi", "0:4,0:4"]) == 0
    # JSON info is asserted in CI too; sanity-check the fields here
    capsys.readouterr()
    assert store_main(["info", str(szs), "--json"]) == 0
    txt = capsys.readouterr().out
    info = json.loads(txt[txt.index("{"):])
    assert info["shape"] == [128, 128] and info["kind"] == "szx-store"
    # errors exit non-zero
    assert store_main(["read", str(szs), str(out), "--roi", "0:4:2,:"]) == 1
    assert store_main(["info", str(raw)]) == 1


def test_store_http_service(tmp_path):
    from repro.serve.store_service import make_server

    x = _walk(1 << 14, seed=14).reshape(128, 128)
    szs = tmp_path / "b.szs"
    idx = ArrayStore.save(str(szs), x, plan.Bound.rel(1e-3))
    srv = make_server(str(szs), port=0)
    thread = threading.Thread(target=srv.serve_forever, daemon=True)
    thread.start()
    try:
        port = srv.server_address[1]
        base = f"http://127.0.0.1:{port}"
        info = json.load(urllib.request.urlopen(f"{base}/info"))
        assert info["shape"] == [128, 128]
        stats = json.load(urllib.request.urlopen(f"{base}/stats"))
        assert stats["exact"] and stats["count"] == x.size
        r = urllib.request.urlopen(f"{base}/read?roi=5:8,0:16")
        assert r.headers["X-Shape"] == "3,16"
        arr = np.frombuffer(r.read(), np.float32).reshape(3, 16)
        assert np.abs(arr - x[5:8, :16]).max() <= idx["e"]
        # concurrent readers: each request opens its own handle
        from concurrent.futures import ThreadPoolExecutor

        def hit(i):
            rr = urllib.request.urlopen(f"{base}/read?roi={i}:{i + 2},:")
            return np.frombuffer(rr.read(), np.float32).reshape(2, 128)

        with ThreadPoolExecutor(8) as pool:
            outs = list(pool.map(hit, range(32)))
        for i, o in enumerate(outs):
            assert np.abs(o - x[i : i + 2]).max() <= idx["e"]
        # bad requests: 400 with a JSON error, server stays up
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{base}/read?roi=0:4:2,:")
        assert ei.value.code == 400
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{base}/nope")
        assert ei.value.code == 404
        assert json.load(urllib.request.urlopen(f"{base}/info"))["shape"]
    finally:
        srv.shutdown()
        srv.server_close()
