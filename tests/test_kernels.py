"""Per-kernel validation: Pallas (interpret=True) vs. pure-jnp oracle vs. numpy
mirror, swept over shapes; plus bit-level invariants of the transform."""
import numpy as np
import pytest

from repro.core import planes as cplanes
from repro.kernels import ops

SHAPES = [(1, 128), (3, 64), (17, 128), (8, 256), (5, 32), (64, 128), (2, 8)]
BACKENDS = ["jax", "numpy", "kernel"]


def _mk(nb, bs, seed=0, scale=1.0, const_rows=()):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((nb, bs)) * scale).astype(np.float32)
    for r in const_rows:
        x[r % nb] = np.float32(1.2345)
    return x


@pytest.mark.parametrize("nb,bs", SHAPES)
def test_block_stats_backends_agree(nb, bs):
    x = _mk(nb, bs, const_rows=(1,))
    e = 1e-3 * float(x.max() - x.min())
    outs = {b: [np.asarray(a) for a in ops.block_stats(x, e, backend=b)] for b in BACKENDS}
    for b in BACKENDS[1:]:
        for a_ref, a_b in zip(outs["jax"], outs[b]):
            np.testing.assert_array_equal(a_ref, a_b, err_msg=f"backend={b}")


@pytest.mark.parametrize("nb,bs", SHAPES)
def test_pack_unpack_backends_agree_and_bounded(nb, bs):
    x = _mk(nb, bs, seed=nb * 1000 + bs, const_rows=(0,))
    e = 1e-4 * float(np.abs(x).max() + 1.0)
    mu, rad, const, reqlen, shift, nbytes = [
        np.asarray(a) for a in ops.block_stats(x, e, backend="jax")
    ]
    packs = {b: [np.asarray(a) for a in ops.pack(x, mu, shift, nbytes, backend=b)] for b in BACKENDS}
    for b in BACKENDS[1:]:
        for a_ref, a_b in zip(packs["jax"], packs[b]):
            np.testing.assert_array_equal(a_ref, a_b, err_msg=f"pack backend={b}")
    planes, L, mid = packs["jax"]
    ups = {
        b: np.asarray(ops.unpack(planes, mu, shift, nbytes, L, backend=b))
        for b in BACKENDS
    }
    for b in BACKENDS[1:]:
        np.testing.assert_array_equal(ups["jax"], ups[b], err_msg=f"unpack backend={b}")
    assert np.abs(ups["jax"] - x).max() <= e


def test_bitlevel_invariants():
    """Solution C: stored window is byte-aligned; L is capped; mid >= 0."""
    x = _mk(32, 128, seed=7)
    e = 1e-3
    mu, rad, const, reqlen, shift, nbytes = [
        np.asarray(a) for a in ops.block_stats(x, e, backend="jax")
    ]
    nc = ~const
    assert np.all((reqlen[nc] + shift[nc]) % 8 == 0)          # Formula (5)
    assert np.all((nbytes[nc] >= 2) & (nbytes[nc] <= 4))
    assert np.all(reqlen[nc] >= 9)
    planes, L, mid = [np.asarray(a) for a in ops.pack(x, mu, shift, nbytes, backend="jax")]
    assert L.min() >= 0 and L.max() <= 3
    assert np.all(mid >= 0)
    assert np.all(L <= nbytes[:, None])


def test_constant_block_roundtrip_is_mu():
    x = np.full((4, 128), 42.5, np.float32)
    e = 1e-6
    mu, rad, const, reqlen, shift, nbytes = ops.block_stats(x, e, backend="jax")
    assert np.asarray(const).all()
    planes, L, mid = ops.pack(x, np.asarray(mu), np.asarray(shift), np.asarray(nbytes), backend="jax")
    y = np.asarray(ops.unpack(planes, mu, shift, nbytes, L, backend="jax"))
    np.testing.assert_array_equal(y, np.asarray(mu)[:, None] * np.ones((1, 128), np.float32))


@pytest.mark.parametrize("num_planes", [1, 2, 3])
@pytest.mark.parametrize("n", [16, 128, 1000, 4096])
def test_planes_mode_bound(num_planes, n):
    rng = np.random.default_rng(num_planes * 100 + n)
    x = rng.standard_normal(n).astype(np.float32) * 3.0
    enc = cplanes.encode(x, num_planes=num_planes)
    y = np.asarray(cplanes.decode(enc, shape=(n,)))
    bound = float(np.asarray(cplanes.max_block_error_bound(enc)).max())
    assert np.abs(x - y).max() <= bound
    # wire accounting: padded-block planes + 8B/block of mu+sexp
    nb = (n + 127) // 128
    assert cplanes.wire_bytes(enc) == nb * 128 * num_planes + 8 * nb


@pytest.mark.parametrize("special", ["negzero", "tiny", "mixed_sign", "large"])
def test_special_values(special):
    if special == "negzero":
        x = np.zeros((2, 128), np.float32)
        x[0, ::2] = -0.0
    elif special == "tiny":
        x = (np.random.default_rng(0).standard_normal((2, 128)) * 1e-30).astype(np.float32)
    elif special == "mixed_sign":
        x = np.linspace(-1, 1, 256, dtype=np.float32).reshape(2, 128)
    else:
        x = (np.random.default_rng(1).standard_normal((2, 128)) * 1e30).astype(np.float32)
    e = max(1e-9, 1e-4 * float(np.abs(x).max() + 1e-30))
    mu, rad, const, reqlen, shift, nbytes = [
        np.asarray(a) for a in ops.block_stats(x, e, backend="jax")
    ]
    planes, L, mid = ops.pack(x, mu, shift, nbytes, backend="jax")
    y = np.asarray(ops.unpack(planes, mu, shift, nbytes, L, backend="jax"))
    assert np.abs(y - x).max() <= e
