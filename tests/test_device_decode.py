"""Device-resident decode (repro.core.codec.device decode_stream/decode_range).

Pins the decode tentpole contracts, mirroring test_device_encoding.py: the
device decode performs exactly ONE host->device transfer per chunk (spy over
jax.device_put), never touches the host section parser or the host unpack
mirror (zero numpy intermediates), and is bit-identical to the host decode
for every dtype and device backend (the Pallas kernel runs in interpret mode
on CPU).  Also covers the out= in-place decode paths and the store ROI
device opt-in.
"""
import io

import numpy as np
import pytest

import jax

from repro.core.codec import SZxCodec, container, device, plan, transform
from repro.store.array import ArrayStore

try:
    import ml_dtypes

    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes ships with jax
    BF16 = None

_DTYPES = [np.float32, np.float64, np.float16] + ([BF16] if BF16 is not None else [])


def _walk(n, seed=0, dtype=np.float32, scale=0.01):
    rng = np.random.default_rng(seed)
    return (np.cumsum(rng.standard_normal(n)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# transfer spy: ONE device_put per chunk, zero host numpy intermediates
# ---------------------------------------------------------------------------

def test_decode_device_is_one_device_put(monkeypatch):
    x = _walk(100_000, seed=1)
    buf = SZxCodec(backend="numpy").compress(x, 1e-3)
    ref = SZxCodec(backend="numpy").decompress(buf)
    SZxCodec(backend="jax").decompress(buf)      # warm the jit cache first
    calls = []
    real_put = jax.device_put
    monkeypatch.setattr(
        jax, "device_put", lambda v, *a, **k: calls.append(v) or real_put(v, *a, **k)
    )
    got = SZxCodec(backend="jax").decompress(buf)
    assert len(calls) == 1, "decode path must upload exactly once per chunk"
    assert calls[0].dtype == np.uint8           # ... and it is the raw body bytes
    np.testing.assert_array_equal(got.view(np.uint8), ref.view(np.uint8))


def test_decode_device_no_host_parse_or_unpack(monkeypatch):
    """The device route must never materialize host numpy section arrays:
    the host container parser and the host unpack mirror are off-limits."""
    from repro.kernels import ops

    x = _walk(50_000, seed=2)
    buf = SZxCodec(backend="numpy").compress(x, 1e-3)
    ref = SZxCodec(backend="numpy").decompress(buf)
    SZxCodec(backend="jax").decompress(buf)      # warm the jit cache first

    def _banned(name):
        def fn(*a, **k):
            raise AssertionError(f"device decode must not call {name}")
        return fn

    monkeypatch.setattr(container, "parse_stream", _banned("container.parse_stream"))
    monkeypatch.setattr(
        container, "parse_stream_sections", _banned("container.parse_stream_sections")
    )
    monkeypatch.setattr(ops, "_unpack_np", _banned("ops._unpack_np"))
    monkeypatch.setattr(transform, "decode_blocks", _banned("transform.decode_blocks"))
    got = SZxCodec(backend="jax").decompress(buf)
    np.testing.assert_array_equal(got.view(np.uint8), ref.view(np.uint8))


def test_chunked_decode_is_one_put_per_frame(monkeypatch):
    x = _walk(300_000, seed=3)
    host = SZxCodec(backend="numpy")
    frames = b"".join(host.compress_chunked(x, 1e-3, chunk_bytes=1 << 19))
    dev = SZxCodec(backend="jax")
    dev.decompress_chunked(frames, n=x.size)     # warm the jit cache first
    per = plan.chunk_elements(128, 1 << 19, 4)
    nchunks = -(-x.size // per)
    calls = []
    real_put = jax.device_put
    monkeypatch.setattr(
        jax, "device_put", lambda v, *a, **k: calls.append(v) or real_put(v, *a, **k)
    )
    got = dev.decompress_chunked(frames, n=x.size)
    assert len(calls) == nchunks, "one device_put per frame, no more"
    np.testing.assert_array_equal(
        got.view(np.uint8), host.decompress_chunked(frames).view(np.uint8)
    )


# ---------------------------------------------------------------------------
# bit identity: device decode == host decode, every dtype x backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", _DTYPES, ids=lambda d: np.dtype(d).name)
@pytest.mark.parametrize("backend", ["jax", "kernel"])
def test_device_decode_bit_identical_to_host(dtype, backend):
    for n, bs, e in ((9999, 128, 1e-3), (257, 32, 1e-2), (1000, 128, 1.0)):
        x = _walk(n, seed=n, dtype=dtype)
        buf = SZxCodec(block_size=bs, backend="numpy").compress(x, e)
        ref = SZxCodec(block_size=bs, backend="numpy").decompress(buf)
        got = SZxCodec(block_size=bs, backend=backend).decompress(buf)
        assert got.dtype == ref.dtype
        np.testing.assert_array_equal(
            got.view(np.uint8), ref.view(np.uint8),
            err_msg=f"{np.dtype(dtype).name}/{backend} n={n} bs={bs} e={e}",
        )
    # constant + verbatim extremes
    c = np.full(1500, 2.5).astype(dtype)
    bufc = SZxCodec(backend="numpy").compress(c, 1e-3)
    np.testing.assert_array_equal(
        SZxCodec(backend=backend).decompress(bufc).view(np.uint8),
        SZxCodec(backend="numpy").decompress(bufc).view(np.uint8),
    )
    tiny = float(plan.finfo(np.dtype(dtype)).tiny)
    v = _walk(2000, seed=4, dtype=dtype, scale=1.0)
    bufv = SZxCodec(backend="numpy").compress(v, tiny)
    np.testing.assert_array_equal(
        SZxCodec(backend=backend).decompress(bufv).view(np.uint8),
        SZxCodec(backend="numpy").decompress(bufv).view(np.uint8),
    )


@pytest.mark.parametrize("backend", ["jax", "kernel"])
def test_device_range_decode_matches_host(backend):
    x = _walk(9999, seed=7)
    buf = SZxCodec(backend="numpy").compress(x, 1e-3)
    host = SZxCodec(backend="numpy")
    dev = SZxCodec(backend=backend)
    for lo, hi in ((0, 1), (0, 3), (3, 11), (70, 79), (0, 79)):
        a = host.decompress_range(buf, lo, hi)
        b = dev.decompress_range(buf, lo, hi)
        np.testing.assert_array_equal(
            a.view(np.uint8), b.view(np.uint8), err_msg=f"[{lo}, {hi})"
        )
    with pytest.raises(ValueError):
        dev.decompress_range(buf, 5, 200)       # host-path range error preserved


# ---------------------------------------------------------------------------
# corrupt streams: the device path raises the canonical container errors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_corrupt_streams_same_errors_on_device_path(backend):
    x = _walk(5000, seed=9)
    buf = bytearray(SZxCodec(backend="numpy").compress(x, 1e-3))
    codec = SZxCodec(backend=backend)
    with pytest.raises(ValueError, match="shorter than header"):
        codec.decompress(bytes(buf[:10]))
    bad = bytearray(buf); bad[0] = 0
    with pytest.raises(ValueError, match="magic mismatch"):
        codec.decompress(bytes(bad))
    bad = bytearray(buf); bad[4] = 99
    with pytest.raises(ValueError, match="version 99"):
        codec.decompress(bytes(bad))
    with pytest.raises(ValueError, match="truncated SZx stream"):
        codec.decompress(bytes(buf[:-5]))
    # mid-length mismatch: shrink the header's nmid field (Q at offset 32)
    bad = bytearray(buf)
    nmid = int.from_bytes(bad[32:40], "little")
    bad[32:40] = (nmid + 1).to_bytes(8, "little")
    with pytest.raises(ValueError, match="truncated|mid-stream"):
        codec.decompress(bytes(bad))


# ---------------------------------------------------------------------------
# out= in-place decode (the chunked no-copy path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_decompress_out_param(backend):
    for n in (9999, 1024):                      # padded and exact final block
        x = _walk(n, seed=n)
        buf = SZxCodec(backend="numpy").compress(x, 1e-3)
        ref = SZxCodec(backend="numpy").decompress(buf)
        out = np.empty(n, np.float32)
        got = SZxCodec(backend=backend).decompress(buf, out=out)
        assert got is out
        np.testing.assert_array_equal(out.view(np.uint8), ref.view(np.uint8))


@pytest.mark.parametrize("workers", [1, 4])
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_decompress_chunked_out_matches(backend, workers):
    x = _walk(200_000, seed=11)
    frames = b"".join(
        SZxCodec(backend="numpy").compress_chunked(x, 1e-3, chunk_bytes=1 << 18)
    )
    codec = SZxCodec(backend=backend, workers=workers)
    pre = codec.decompress_chunked(frames, n=x.size)
    buf = codec.decompress_chunked(frames)
    np.testing.assert_array_equal(pre.view(np.uint8), buf.view(np.uint8))
    with pytest.raises(ValueError, match="longer than expected"):
        codec.decompress_chunked(frames, n=x.size - 1000)
    with pytest.raises(ValueError, match="expected"):
        codec.decompress_chunked(frames, n=x.size + 1000)


# ---------------------------------------------------------------------------
# store ROI reads: device= opt-in
# ---------------------------------------------------------------------------

def test_store_roi_device_reads_match_host():
    arr = _walk(64 * 130, seed=13).reshape(64, 130)
    bio = io.BytesIO()
    ArrayStore.save(bio, arr, 1e-3, chunk_shape=(32, 70))
    host = ArrayStore.open(io.BytesIO(bio.getvalue()), backend="numpy")
    dev = ArrayStore.open(io.BytesIO(bio.getvalue()), backend="jax", device=True)
    for roi in ((slice(5, 60), slice(3, 100)), (slice(0, 64), slice(0, 130)),
                (7, slice(10, 20)), Ellipsis):
        a, b = host[roi], dev[roi]
        np.testing.assert_array_equal(a.view(np.uint8), b.view(np.uint8))
    with pytest.raises(ValueError, match="device backend"):
        ArrayStore.open(io.BytesIO(bio.getvalue()), backend="numpy", device=True)


def test_decode_stream_falls_back_on_numpy_backend():
    x = _walk(1000, seed=17)
    buf = SZxCodec(backend="numpy").compress(x, 1e-3)
    assert device.decode_stream(buf, backend="numpy") is None
    assert device.decode_range(buf, b"", 0, 1, backend="numpy") is None
