"""End-to-end + property-based tests for the faithful SZx codec.

The system's central invariant (paper Formula 1): for every element,
|d_i - d'_i| <= e, for any input data and any positive error bound.
"""
import numpy as np
import pytest

try:  # property tests need hypothesis (dev extra); skip them if absent
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

    def _identity_deco(f):
        return f

    def given(*a, **k):  # noqa: D103
        return _identity_deco

    def settings(*a, **k):  # noqa: D103
        return _identity_deco

    class _St:  # placeholder so strategy expressions still evaluate at import
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed (pip install .[dev])"
)

from repro.core import metrics, szx
from repro.core.codec.plan import Bound


def _roundtrip(x, e, **kw):
    buf = szx.compress(x, e, **kw)
    y = szx.decompress(buf)
    return buf, y.reshape(x.shape)


# ---------------------------------------------------------------------------
# property-based: the error bound invariant
# ---------------------------------------------------------------------------

@needs_hypothesis
@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 2000),
    seed=st.integers(0, 2**31 - 1),
    log_e=st.floats(-6, 1),
    kind=st.sampled_from(["gauss", "walk", "spiky", "const", "steps"]),
    block_size=st.sampled_from([8, 32, 64, 128, 256]),
)
def test_error_bound_invariant(n, seed, log_e, kind, block_size):
    rng = np.random.default_rng(seed)
    if kind == "gauss":
        x = rng.standard_normal(n)
    elif kind == "walk":
        x = np.cumsum(rng.standard_normal(n)) * 0.01
    elif kind == "spiky":
        x = rng.standard_normal(n)
        x[rng.integers(0, n, max(1, n // 50))] *= 1e4
    elif kind == "const":
        x = np.full(n, float(rng.standard_normal()))
    else:
        x = np.repeat(rng.standard_normal(max(1, n // 17 + 1)), 17)[:n]
    x = x.astype(np.float32)
    e = float(10.0**log_e)
    buf, y = _roundtrip(x, e, block_size=block_size)
    assert np.abs(x - y).max() <= e


@needs_hypothesis
@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    rel=st.sampled_from([1e-2, 1e-3, 1e-4]),
)
def test_relative_bound_mode(seed, rel):
    rng = np.random.default_rng(seed)
    x = (np.cumsum(rng.standard_normal(3000)) * rng.uniform(0.1, 100)).astype(np.float32)
    e = rel * float(x.max() - x.min())
    buf, y = _roundtrip(x, Bound.rel(rel))
    assert np.abs(x - y).max() <= e * (1 + 1e-6)


def test_error_bound_invariant_deterministic():
    """Fixed-seed sweep of the Formula-1 invariant; always runs, so minimal
    installs (no hypothesis) still exercise the central property."""
    rng = np.random.default_rng(7)
    fields = {
        "gauss": rng.standard_normal(1999),
        "walk": np.cumsum(rng.standard_normal(2048)) * 0.01,
        "const": np.full(777, -3.25),
        "steps": np.repeat(rng.standard_normal(40), 31)[:1000],
    }
    for name, x in fields.items():
        x = x.astype(np.float32)
        for e in (1e-6, 1e-4, 1e-2, 1.0):
            for bs in (32, 128):
                _, y = _roundtrip(x, e, block_size=bs)
                assert np.abs(x - y).max() <= e, (name, e, bs)


# ---------------------------------------------------------------------------
# deterministic behaviours
# ---------------------------------------------------------------------------

def test_stream_is_deterministic():
    x = np.sin(np.linspace(0, 10, 5000)).astype(np.float32)
    assert szx.compress(x, 1e-3) == szx.compress(x, 1e-3)


def test_multidim_input_roundtrip():
    x = np.random.default_rng(3).standard_normal((7, 33, 12)).astype(np.float32)
    buf, st_ = szx.compress_with_stats(x, 1e-3)
    y = szx.decompress(buf).reshape(x.shape)
    assert np.abs(x - y).max() <= st_.error_bound


def test_smooth_data_compresses_well():
    """Paper Table III: smooth fields reach CR >= 4 at REL=1e-2."""
    t = np.linspace(0, 4 * np.pi, 1 << 18).astype(np.float32)
    x = np.sin(t) * np.exp(-t / 20)
    buf, stats = szx.compress_with_stats(x, Bound.rel(1e-2))
    assert stats.ratio > 4.0
    y = szx.decompress(buf)
    assert metrics.psnr(x, y) > 40.0


def test_constant_data_hits_block_floor():
    """All-constant data: ~4/128 bytes/value + header -> CR near 100x."""
    x = np.full(1 << 16, 7.5, np.float32)
    buf, stats = szx.compress_with_stats(x, 1e-3)
    assert stats.constant_block_fraction == 1.0
    assert stats.ratio > 80


def test_incompressible_data_bounded_expansion():
    """Worst case stays below 4 bytes + L-code overhead per value."""
    x = np.random.default_rng(0).standard_normal(1 << 16).astype(np.float32)
    buf, stats = szx.compress_with_stats(x, 1e-7)  # tiny bound -> keep ~all bits
    assert stats.mean_bytes_per_value < 4.5


def test_psnr_tracks_bound():
    rng = np.random.default_rng(2)
    x = np.cumsum(rng.standard_normal(1 << 16)).astype(np.float32)
    p = []
    for rel in (1e-2, 1e-3, 1e-4):
        y = szx.decompress(szx.compress(x, Bound.rel(rel)))
        p.append(metrics.psnr(x, y))
    assert p[0] < p[1] < p[2]          # tighter bound -> higher PSNR
    assert p[0] > 30                   # paper: visually fine at REL 1e-2


def test_bad_inputs():
    with pytest.raises(ValueError):
        szx.compress(np.zeros(4, np.float32), 0.0)
    with pytest.raises(ValueError):
        szx.decompress(b"not a stream at all....")
