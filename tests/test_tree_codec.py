"""TreeCodec multi-leaf streams + container-v3 random access.

Pins the acceptance contracts: select= partial restore provably reads ONLY
the selected leaves' byte ranges (seek-tracking file spy), v2 footer-less
streams still decode, the index footer survives/rejects corruption, and the
'rel'-mode bound is resolved once per leaf/array -- never per frame.
"""
import io

import numpy as np
import pytest

from repro.core.codec import SZxCodec, TreeCodec, container, plan
from repro.core.codec.tree import leaf_paths

try:
    import ml_dtypes

    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes ships with jax
    BF16 = None

CODEC = SZxCodec(backend="numpy")
TC = TreeCodec(codec=CODEC, bound=plan.Bound.rel(1e-4), chunk_bytes=1 << 18)


def _walk(n, seed=0, dtype=np.float32, scale=0.01):
    rng = np.random.default_rng(seed)
    return (np.cumsum(rng.standard_normal(n)) * scale).astype(dtype)


def _tree():
    t = {
        "params": {
            "w": _walk(150_000, seed=1),
            "b": _walk(80_000, seed=2, dtype=np.float64),
        },
        "step": np.int64(42),
        "tiny": np.float32([1.5, -2.5]),
        "ids": np.arange(100, dtype=np.int32),
    }
    if BF16 is not None:
        t["params"]["h"] = _walk(60_000, seed=3, dtype=BF16)
    return t


class SpyFile:
    """Byte-range-recording wrapper over a seekable binary file."""

    def __init__(self, raw):
        self.raw = raw
        self.reads: list[tuple[int, int]] = []

    def seek(self, *a):
        return self.raw.seek(*a)

    def tell(self):
        return self.raw.tell()

    def read(self, n=-1):
        off = self.raw.tell()
        data = self.raw.read(n)
        if data:
            self.reads.append((off, len(data)))
        return data


def _covered(reads, ranges):
    """Every read byte falls inside one of the allowed [lo, hi) ranges."""
    for off, ln in reads:
        if not any(lo <= off and off + ln <= hi for lo, hi in ranges):
            return (off, ln)
    return None


def test_roundtrip_template_select_and_dict():
    tree = _tree()
    buf = io.BytesIO()
    manifest = TC.compress_tree(tree, buf)
    names = {m["name"] for m in manifest["leaves"]}
    assert "step" in names and "params/w" in names
    # template restore: full tree, dtypes preserved, bounds hold per leaf
    out = TC.decompress_tree(buf, template=tree)
    for (name, a), (_, b) in zip(leaf_paths(tree), leaf_paths(out)):
        a, b = np.asarray(a), np.asarray(b)
        assert b.dtype == a.dtype, name
        if np.issubdtype(a.dtype, np.integer):
            np.testing.assert_array_equal(a, b)
        else:
            af, bf = a.astype(np.float64), b.astype(np.float64)
            e = 1e-4 * (af.max() - af.min()) if af.size > 2 else 0.0
            assert np.abs(af - bf).max() <= e + 1e-12, name
    # select restore: exactly the requested names
    sel = TC.decompress_tree(buf, select=["step", "params/b"])
    assert set(sel) == {"step", "params/b"}
    assert int(sel["step"]) == 42
    # dict restore: everything
    alld = TC.decompress_tree(buf)
    assert set(alld) == names
    with pytest.raises(KeyError):
        TC.decompress_tree(buf, select=["nope"])
    with pytest.raises(ValueError):
        TC.decompress_tree(buf, select=["step"], template=tree)


def test_select_reads_only_selected_byte_ranges():
    """The acceptance seek-spy: restoring leaves touches ONLY their frames'
    byte ranges (plus the fixed index footer at the tail)."""
    tree = _tree()
    base = io.BytesIO()
    manifest = TC.compress_tree(tree, base)
    end = base.seek(0, 2)
    frames = manifest["frames"]
    data_end = manifest["stored_bytes"]
    footer = (data_end, end)                     # index payload + trailer
    by_name = {m["name"]: m for m in manifest["leaves"]}

    def allowed_for(name):
        meta = by_name[name]
        if meta["codec"] == "raw":
            off, _len = frames[meta["frames"][0]]
            inner, size = meta["pack"]
            lo = off + container.FRAME_HEADER.size + inner
            return [(lo, lo + size)]
        lo_f, hi_f = meta["frames"]
        return [(frames[i][0], frames[i][0] + frames[i][1]) for i in range(lo_f, hi_f)]

    # big szx leaf: only its frames + footer are touched
    spy = SpyFile(base)
    out = TC.decompress_tree(spy, select=["params/w"])
    bad = _covered(spy.reads, allowed_for("params/w") + [footer])
    assert bad is None, f"read outside params/w ranges: {bad}"
    np.testing.assert_array_equal(out["params/w"], TC.decompress_tree(base)["params/w"])
    # raw leaf inside the shared pack frame: only ITS bytes, not the whole pack
    spy = SpyFile(base)
    out = TC.decompress_tree(spy, select=["step"])
    assert int(out["step"]) == 42
    bad = _covered(spy.reads, allowed_for("step") + [footer])
    assert bad is None, f"read outside step's pack slice: {bad}"
    selected_bytes = sum(ln for _, ln in spy.reads)
    assert selected_bytes <= (end - data_end) + 8 + container.FRAME_HEADER.size


def test_select_on_chunked_single_array_stream():
    """load_chunked(select=) random access over a dump_chunked v3 stream."""
    x = _walk(400_000, seed=9)
    buf = io.BytesIO()
    CODEC.dump_chunked(x, buf, 1e-3, chunk_bytes=1 << 18)
    per = plan.chunk_elements(CODEC.block_size, 1 << 18, 4)
    spy = SpyFile(buf)
    y = CODEC.load_chunked(spy, select=[1])
    full = CODEC.load_chunked(io.BytesIO(buf.getvalue()))
    np.testing.assert_array_equal(y, full[per : 2 * per])
    idx = container.read_index_footer(buf)
    lo, ln, _elems = idx["frames"][1]
    end = buf.seek(0, 2)
    data_end = idx["frames"][-1][0] + idx["frames"][-1][1]
    bad = _covered(spy.reads, [(lo, lo + ln), (data_end, end)])
    assert bad is None, f"select=[1] read outside frame 1: {bad}"


def test_v2_footerless_streams_still_decode():
    x = _walk(200_000, seed=4)
    v2 = io.BytesIO()
    CODEC.dump_chunked(x, v2, 1e-3, chunk_bytes=1 << 18, index=False)
    assert container.read_index_footer(v2) is None
    v2.seek(0)
    np.testing.assert_array_equal(CODEC.load_chunked(v2), CODEC.decompress_chunked(
        io.BytesIO(b"".join(CODEC.compress_chunked(x, 1e-3, chunk_bytes=1 << 18)))
    ))


def test_footer_corruption_rejected():
    tree = {"w": _walk(50_000, seed=6)}
    buf = io.BytesIO()
    TC.compress_tree(tree, buf)
    raw = bytearray(buf.getvalue())
    # flip a byte inside the JSON index -> CRC mismatch
    raw[-40] ^= 0xFF
    with pytest.raises(ValueError, match="CRC|corrupt|footer|Expecting"):
        TC.decompress_tree(io.BytesIO(bytes(raw)))
    # truncated trailer -> not recognized as a tree stream
    with pytest.raises(ValueError, match="index footer"):
        TC.decompress_tree(io.BytesIO(bytes(raw[:-10])))


def test_tree_stream_rejects_wrong_kind():
    x = _walk(50_000, seed=7)
    buf = io.BytesIO()
    CODEC.dump_chunked(x, buf, 1e-3)        # kind szx-chunked, not szx-tree
    with pytest.raises(ValueError, match="kind"):
        TC.decompress_tree(buf)


# ---------------------------------------------------------------------------
# satellite: 'rel' bound resolution audit (once per array/leaf, never per frame)
# ---------------------------------------------------------------------------

def test_chunked_rel_bound_is_global_even_with_disparate_chunk_ranges():
    """Frames covering wildly different value ranges must all carry the
    MONOLITHIC absolute bound: per-frame resolution would silently tighten
    the early chunks and loosen nothing (the bug this test pins against)."""
    lo = _walk(100_000, seed=10, scale=1e-5)          # tiny range
    hi = 1e4 + _walk(100_000, seed=11, scale=10.0)    # huge range, offset
    x = np.concatenate([lo, hi]).astype(np.float32)
    e_mono = container.HEADER.unpack_from(CODEC.compress(x, plan.Bound.rel(1e-3)), 0)[5]
    frames = list(CODEC.compress_chunked(x, plan.Bound.rel(1e-3), chunk_bytes=1 << 18))
    per = plan.chunk_elements(CODEC.block_size, 1 << 18, 4)
    assert len(frames) > 2
    for i, payload in enumerate(container.iter_frames(frames)):
        e_frame = container.HEADER.unpack_from(payload, 0)[5]
        assert e_frame == e_mono, f"frame {i} resolved its own rel bound"
        # and the payload is the monolithic encoding of its slice at e_mono
        assert payload == CODEC.compress(x[i * per : (i + 1) * per], e_mono)
    y = CODEC.decompress_chunked(frames)
    assert np.abs(x.astype(np.float64) - y.astype(np.float64)).max() <= e_mono


def test_tree_codec_rel_bound_is_per_leaf_monolithic():
    """TreeCodec resolves 'rel' once per LEAF over the leaf's full range --
    chunking a leaf into frames must not change its effective bound."""
    tree = {
        "small_range": _walk(120_000, seed=12, scale=1e-4),
        "large_range": 50.0 + _walk(120_000, seed=13, scale=5.0),
    }
    buf = io.BytesIO()
    manifest = TC.compress_tree(tree, buf)
    by_name = {m["name"]: m for m in manifest["leaves"]}
    for name, arr in tree.items():
        e_mono = container.HEADER.unpack_from(
            CODEC.compress(arr, plan.Bound.rel(1e-4)), 0
        )[5]
        lo_f, hi_f = by_name[name]["frames"]
        assert hi_f - lo_f > 1, "leaf must span multiple frames for this test"
        for i in range(lo_f, hi_f):
            off, ln = manifest["frames"][i]
            payload, _ = container.read_frame_at(buf, off, ln, i)
            assert container.HEADER.unpack_from(payload, 0)[5] == e_mono, name
    out = TC.decompress_tree(buf, template=tree)
    for name, arr in tree.items():
        e = 1e-4 * float(arr.max() - arr.min())
        assert np.abs(arr - out[name]).max() <= e, name


def test_sharded_encode_restores_identically():
    """compress_tree_sharded: one block-aligned shard per mesh-axis device;
    the stream restores through the ordinary frame path, and each shard
    payload is bit-identical to a monolithic compress of that shard at the
    leaf's resolved absolute bound."""
    import jax

    tree = {"w": _walk(100_000, seed=21), "step": np.int64(3)}
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()).reshape(-1, 1), ("data", "model")
    )
    tc = TreeCodec(codec=SZxCodec(backend="jax"), bound=plan.Bound.rel(1e-4))
    bio = io.BytesIO()
    man = tc.compress_tree_sharded(tree, bio, mesh, axis="data")
    bio.seek(0)
    out = tc.decompress_tree(bio, template=tree)
    assert int(out["step"]) == 3
    spec = plan.spec_for(tree["w"].dtype)
    e = plan.resolve_error_bound(tree["w"], 1e-4, "rel", spec)
    assert np.abs(out["w"] - tree["w"]).max() <= e * (1 + 1e-12)
    # shard payloads == compress(shard, e_abs): decode the frames directly
    wmeta = next(m for m in man["leaves"] if m["name"] == "w")
    lo, hi = wmeta["frames"]
    assert hi - lo == min(len(mesh.devices), -(-tree["w"].size // 128))
    with pytest.raises(ValueError, match="no axis"):
        tc.compress_tree_sharded(tree, io.BytesIO(), mesh, axis="nope")
