"""Roofline infrastructure tests: loop-aware flops/bytes and the collective
parser (the methodological core of section Roofline)."""
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.compat import compiled_cost_analysis
from repro.roofline import analysis, hlo_cost


def _scan_matmul(L, n=128):
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, None, length=L)[0]

    return (
        jax.jit(f)
        .lower(
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((n, n), jnp.float32),
        )
        .compile()
    )


def test_loop_aware_flops_exact():
    for L in (3, 8):
        c = hlo_cost.analyze_text(_scan_matmul(L).as_text())
        assert abs(c.flops - L * 2 * 128**3) / (L * 2 * 128**3) < 1e-6


def test_xla_cost_analysis_ignores_trip_count():
    """Documents WHY hlo_cost exists: XLA counts scan bodies once."""
    # cost_analysis() returns a dict on older JAX and a 1-element list of
    # dicts on current JAX; compiled_cost_analysis normalizes both
    a = compiled_cost_analysis(_scan_matmul(4))["flops"]
    b = compiled_cost_analysis(_scan_matmul(8))["flops"]
    assert a == b                     # broken-by-design for our purpose


def test_nested_scan_multiplies():
    def g(x, w):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), None
            return jax.lax.scan(inner, h, None, length=3)[0], None
        return jax.lax.scan(outer, x, None, length=5)[0]

    c = hlo_cost.analyze_text(
        jax.jit(g)
        .lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
        )
        .compile()
        .as_text()
    )
    assert abs(c.flops - 15 * 2 * 64**3) / (15 * 2 * 64**3) < 1e-6


def test_roofline_terms_and_bottleneck():
    rl = analysis.Roofline(
        flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=0,
        collectives={}, model_flops=197e12 * 256, chips=256,
    )
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert abs(rl.t_memory - 2.0) < 1e-9
    assert rl.bottleneck == "memory"
    assert abs(rl.roofline_fraction - 0.5) < 1e-9


CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline import hlo_cost

mesh = jax.make_mesh((8,), ("model",))
def f(a, b):
    return a @ b                      # contraction over the sharded dim
sh_a = NamedSharding(mesh, P(None, "model"))
sh_b = NamedSharding(mesh, P("model", None))
c = jax.jit(f, in_shardings=(sh_a, sh_b), out_shardings=NamedSharding(mesh, P()))
cc = c.lower(jax.ShapeDtypeStruct((256, 512), jnp.float32),
             jax.ShapeDtypeStruct((512, 256), jnp.float32)).compile()
cost = hlo_cost.analyze_text(cc.as_text())
ar = cost.coll.get("all-reduce", 0)
assert ar >= 256*256*4, f"expected a (256,256) f32 all-reduce, got {cost.coll}"
print("COLL-OK", cost.coll)
"""


def test_collective_parse_on_sharded_matmul():
    r = subprocess.run(
        [sys.executable, "-c", CODE],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert "COLL-OK" in r.stdout, r.stdout + r.stderr
