"""Mamba2-1.3B: attention-free SSD (state-space duality) [arXiv:2405.21060].
Chunked intra/inter block algorithm; O(1)-state decode -> long_500k runs."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    source="arXiv:2405.21060; unverified",
)
