"""Assigned-architecture configs (public literature) + the registry."""
from repro.configs.base import ARCH_NAMES, ArchConfig, SHAPES, all_configs, get, input_specs  # noqa: F401
