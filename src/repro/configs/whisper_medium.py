"""Whisper-medium backbone: 24L encoder + 24L decoder, d=1024, MHA
[arXiv:2212.04356].  Conv/mel frontend is a STUB per assignment --
input_specs supplies (B, 1500, 1024) precomputed frame embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    encoder_decoder=True,
    n_encoder_layers=24,
    encoder_len=1500,
    source="arXiv:2212.04356; unverified",
    shape_skips={"long_500k": "full quadratic attention at 524k context"},
)
