"""StableLM-3B: dense MHA transformer [hf:stabilityai/stablelm-2-1_6b family;
unverified tier].  Full attention -> long_500k skipped (quadratic)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    head_dim=80,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
    shape_skips={"long_500k": "full quadratic attention at 524k context"},
)
