"""Yi-6B: llama-arch GQA kv=4 [arXiv:2403.04652]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5000000.0,
    fsdp=True,
    source="arXiv:2403.04652; hf",
    shape_skips={"long_500k": "full quadratic attention at 524k context"},
)
