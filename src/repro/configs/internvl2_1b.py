"""InternVL2-1B backbone (Qwen2-0.5B-style LLM) [arXiv:2404.16821].
InternViT frontend is a STUB per assignment -- input_specs supplies
(B, 256, 896) patch embeddings prepended to the text sequence."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    head_dim=64,
    prefix_embeds=256,
    tie_embeddings=True,
    source="arXiv:2404.16821; hf",
    shape_skips={"long_500k": "full quadratic attention at 524k context"},
)
