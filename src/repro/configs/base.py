"""Architecture configuration system + registry.

Every assigned architecture is a frozen ``ArchConfig`` in its own module under
``repro.configs``; ``get(name)`` resolves it, ``cfg.reduced()`` gives the
CPU-smoke-test variant of the same family, and ``input_specs(cfg, shape)``
yields ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp


# The four assigned input-shape cells (LM-family: seq_len x global_batch).
SHAPES: dict[str, dict[str, Any]] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # 0 => attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (fine-grained MoE)
    dense_ff_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # --- attention extras ---
    sliding_window: int = 0          # 0 => full causal attention
    rope_theta: float = 10000.0
    # --- encoder-decoder / multimodal frontends (stubs per assignment) ---
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 0             # stub frame/patch count for the encoder
    prefix_embeds: int = 0           # VLM: image-patch embeddings prepended
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    param_dtype: str = "float32"     # "bfloat16" for the very large archs
    compute_dtype: str = "bfloat16"  # activations/matmuls (f32 accumulation)
    fsdp: bool = False               # shard params/optimizer over 'data' too
    remat: bool = True               # activation checkpoint each layer
    source: str = ""                 # public-literature citation
    # which shape cells are skipped and why (e.g. quadratic attn @ 500k)
    shape_skips: dict[str, str] = field(default_factory=dict)

    # ---------------- derived ----------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // 128) * 128

    @property
    def is_attention_free(self) -> bool:
        return self.n_heads == 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs accounting)."""
        d, v = self.d_model, self.padded_vocab
        hd = self.resolved_head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        attn = d * n_q + 2 * d * n_kv + n_q * d if self.n_heads else 0
        ffn = 3 * d * self.d_ff if self.d_ff else 0
        moe = 0
        if self.n_experts:
            moe = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
            moe += self.n_shared_experts * 3 * d * self.moe_d_ff
        ssm = 0
        if self.ssm_state:
            di, n, h = self.ssm_d_inner, self.ssm_state, self.ssm_n_heads
            ssm = d * (2 * di + 2 * n + h) + di * d + (di + 2 * n) * self.ssm_conv_width + 3 * h
        per_layer = attn + ffn + moe + ssm
        total = self.n_layers * per_layer + v * d * (1 if self.tie_embeddings else 2)
        if self.encoder_decoder:
            enc_per = attn + ffn
            total += self.n_encoder_layers * enc_per + self.n_layers * (attn)  # cross-attn
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top_k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        routed_all = self.n_layers * self.n_experts * 3 * self.d_model * self.moe_d_ff
        routed_active = self.n_layers * self.top_k * 3 * self.d_model * self.moe_d_ff
        return full - routed_all + routed_active

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict[str, Any] = dict(
            n_layers=2,
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            head_dim=16,
            param_dtype="float32",
            compute_dtype="float32",
            fsdp=False,
            remat=False,
        )
        if self.n_heads:
            kw["n_heads"] = 4
            kw["n_kv_heads"] = max(1, 4 * self.n_kv_heads // max(self.n_heads, 1))
        if self.n_experts:
            kw["n_experts"] = 8
            kw["top_k"] = min(self.top_k, 2)
            kw["moe_d_ff"] = 32
        if self.ssm_state:
            kw["ssm_state"] = 16
            kw["ssm_head_dim"] = 16
            kw["ssm_chunk"] = 16
        if self.sliding_window:
            kw["sliding_window"] = 32
        if self.encoder_decoder:
            kw["n_encoder_layers"] = 2
            kw["encoder_len"] = 24
        if self.prefix_embeds:
            kw["prefix_embeds"] = 8
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

ARCH_NAMES = [
    "hymba-1.5b",
    "h2o-danube-1.8b",
    "stablelm-3b",
    "llama3.2-1b",
    "yi-6b",
    "whisper-medium",
    "arctic-480b",
    "deepseek-moe-16b",
    "mamba2-1.3b",
    "internvl2-1b",
]

_MODULE_FOR = {n: "repro.configs." + n.replace("-", "_").replace(".", "p") for n in ARCH_NAMES}


def get(name: str) -> ArchConfig:
    if name not in _MODULE_FOR:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(_MODULE_FOR[name])
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {n: get(n) for n in ARCH_NAMES}


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct only -- never allocates)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape_name: str, *, reduced: bool = False) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    kind='train'   -> {tokens, labels [, frames | image_embeds]}
    kind='prefill' -> {tokens [, frames | image_embeds]}
    kind='decode'  -> {token} (+ cache specs come from the serve module)
    """
    spec = SHAPES[shape_name]
    s, b = spec["seq_len"], spec["global_batch"]
    if reduced:
        s, b = min(s, 64), min(b, 4)
    kind = spec["kind"]
    f32 = jnp.float32
    i32 = jnp.int32
    out: dict[str, Any] = {}
    if kind in ("train", "prefill"):
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    else:
        out["token"] = jax.ShapeDtypeStruct((b, 1), i32)
    if cfg.encoder_decoder:
        out["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_len, cfg.d_model), f32)
    if cfg.prefix_embeds:
        out["image_embeds"] = jax.ShapeDtypeStruct((b, cfg.prefix_embeds, cfg.d_model), f32)
    return out
