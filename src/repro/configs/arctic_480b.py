"""Snowflake Arctic (480B): 128-expert top-2 MoE with a parallel dense-FFN
residual in every layer [hf:Snowflake/snowflake-arctic-base].
bf16 params + FSDP so 480B fits 512 x 16GB (DESIGN.md section 6)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,                # dense residual FFN
    vocab_size=32000,
    head_dim=128,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_ff_residual=True,
    param_dtype="bfloat16",
    fsdp=True,
    source="hf:Snowflake/snowflake-arctic-base; hf",
    shape_skips={"long_500k": "full quadratic attention at 524k context"},
)
