"""DeepSeekMoE-16B: fine-grained MoE, 2 shared + 64 routed experts top-6
[arXiv:2401.06066].  Homogeneous layers (paper's dense layer-0 simplification
noted in DESIGN.md section 5)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,                    # no dense FFN; shared experts play that role
    vocab_size=102400,
    head_dim=128,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    fsdp=True,
    source="arXiv:2401.06066; hf",
    shape_skips={"long_500k": "full quadratic attention at 524k context"},
)
