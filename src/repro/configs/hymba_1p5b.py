"""Hymba-1.5B: hybrid-head transformer -- parallel attention + Mamba heads in
every block [arXiv:2411.13676].  Meta-tokens omitted; branch outputs averaged
after per-branch norm (DESIGN.md section 5).  SWA lets long_500k run."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    sliding_window=2048,
    source="arXiv:2411.13676; hf",
)
