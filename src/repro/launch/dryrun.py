import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run harness.

For every (architecture x input-shape x mesh) cell this lowers + compiles the
real step function (train_step / prefill / decode_step) against
ShapeDtypeStruct stand-ins on the production mesh, then records
memory_analysis / cost_analysis / the collective schedule and the roofline
terms.  No arrays are ever allocated at full size.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out benchmarks/out/dryrun
  ... --multi-pod           (2,16,16) pod/data/model mesh
  ... --kv-mode compressed  SZx-planes KV cache for decode cells
  ... --grad-compress 1     SZx cross-pod gradient compression (multi-pod)
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPES, input_specs
from repro.launch import mesh as mesh_lib
from repro.models import sharding as shard_rules
from repro.models import transformer as T
from repro.optim import AdamW, warmup_cosine
from repro.roofline import analysis as roofline
from repro.serve import engine
from repro.train import step as train_step_mod


def _shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    kv_mode: str = "dense",
    num_planes: int = 1,
    grad_compress: int = 0,
    remat: bool | None = None,
    parallelism: str = "tp",        # "tp" (baseline) | "dp" (small models)
    serve_layout: bool = False,     # H1: decode-oriented weight layout
    serve_bf16: bool = False,       # H3: bf16 serving weights
):
    """Lower + compile one cell.  Returns (record dict, compiled)."""
    cfg = configs.get(arch)
    if remat is not None:
        import dataclasses

        cfg = dataclasses.replace(cfg, remat=remat)
    if shape_name in cfg.shape_skips:
        return {"arch": arch, "shape": shape_name, "status": "SKIP",
                "reason": cfg.shape_skips[shape_name]}, None

    spec = SHAPES[shape_name]
    kind = spec["kind"]
    seq_len, global_batch = spec["seq_len"], spec["global_batch"]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    long_ctx = shape_name == "long_500k"
    rules = dict(shard_rules.LONG_CONTEXT_RULES if long_ctx else shard_rules.DEFAULT_RULES)
    if parallelism == "dp":
        rules = dict(shard_rules.PURE_DP_RULES)
    if serve_layout and cfg.n_experts:
        rules.update(shard_rules.SERVE_MOE_RULES)
    if grad_compress:
        # inside the manual-'pod' shard_map region only auto axes may appear
        rules["act_batch"] = ("data",)

    def make_pspecs(tree):
        if parallelism == "dp":
            return mesh_lib.replicated_specs_tree(tree)
        if serve_layout:
            return mesh_lib.serve_param_specs_tree(cfg, tree, mesh)
        return mesh_lib.param_specs_tree(cfg, tree, mesh)

    def pspec_source():
        specs = T.param_specs(cfg)
        if serve_bf16:
            specs = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype
                ),
                specs,
            )
        return specs

    t0 = time.time()
    with shard_rules.use_rules(mesh, rules):
        if kind == "train":
            opt = AdamW(lr=warmup_cosine(3e-4, 2000, 100000))
            state_shapes = jax.eval_shape(
                functools.partial(
                    train_step_mod.init_state, cfg, opt, jax.random.key(0),
                    ef_planes=grad_compress,
                )
            )
            sspecs = train_step_mod.state_specs(cfg, state_shapes, mesh)
            if parallelism == "dp":
                sspecs = {
                    "params": mesh_lib.replicated_specs_tree(state_shapes["params"]),
                    "opt": type(state_shapes["opt"])(
                        step=P(),
                        m=mesh_lib.replicated_specs_tree(state_shapes["opt"].m),
                        v=mesh_lib.replicated_specs_tree(state_shapes["opt"].v),
                    ),
                }
            batch = input_specs(cfg, shape_name)
            bspecs = mesh_lib.batch_specs_tree(cfg, mesh, batch)
            fn = train_step_mod.make_train_step(
                cfg, opt, mesh=mesh, compress_planes=grad_compress
            )
            jitted = jax.jit(
                fn,
                in_shardings=(_shardings(mesh, sspecs), _shardings(mesh, bspecs)),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_shapes, batch)
            model_flops = roofline.train_model_flops(cfg, seq_len * global_batch)
        elif kind == "prefill":
            pspecs = make_pspecs(pspec_source())
            batch = input_specs(cfg, shape_name)
            bspecs = mesh_lib.batch_specs_tree(cfg, mesh, batch)

            def fn(params, batch):
                return engine.prefill(
                    params, cfg, batch["tokens"],
                    frames=batch.get("frames"),
                    image_embeds=batch.get("image_embeds"),
                    seq_len=seq_len, kv_mode=kv_mode, num_planes=num_planes,
                )

            jitted = jax.jit(
                fn,
                in_shardings=(
                    _shardings(mesh, pspecs), _shardings(mesh, bspecs)),
            )
            lowered = jitted.lower(pspec_source(), batch)
            # prefill = fwd only: 2ND over the prompt tokens
            model_flops = 2.0 * cfg.active_param_count() * seq_len * global_batch
        else:  # decode
            pspecs = make_pspecs(pspec_source())
            cache_shapes = engine.cache_specs(
                cfg, global_batch, seq_len, kv_mode=kv_mode, num_planes=num_planes
            )
            cspecs = mesh_lib.cache_specs_tree(
                cfg, mesh, cache_shapes, long_context=long_ctx
            )
            batch = input_specs(cfg, shape_name)
            bspecs = mesh_lib.batch_specs_tree(
                cfg, mesh, batch, long_context=long_ctx
            )

            def fn(params, cache, batch):
                return engine.decode_step(
                    params, cfg, cache, batch["token"],
                    kv_mode=kv_mode, num_planes=num_planes,
                )

            jitted = jax.jit(
                fn,
                in_shardings=(
                    _shardings(mesh, pspecs),
                    _shardings(mesh, cspecs),
                    _shardings(mesh, bspecs),
                ),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(pspec_source(), cache_shapes, batch)
            model_flops = roofline.decode_model_flops(cfg, global_batch)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    rl = roofline.analyze(compiled, model_flops=model_flops, chips=chips)
    extra = {}
    if kind == "decode":
        ideal = roofline.sharded_bytes_per_device(
            pspec_source(), pspecs, mesh
        ) + roofline.sharded_bytes_per_device(cache_shapes, cspecs, mesh)
        extra["ideal_bytes_per_device"] = ideal
        extra["floor_fraction"] = roofline.decode_floor_fraction(ideal, rl)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind,
        "kv_mode": kv_mode if kind == "decode" else None,
        "grad_compress": grad_compress,
        "status": "OK",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": roofline.memory_analysis_dict(compiled),
        "roofline": {**rl.to_dict(), **extra},
    }
    return rec, compiled


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--kv-mode", default="dense", choices=["dense", "compressed"])
    ap.add_argument("--num-planes", type=int, default=1)
    ap.add_argument("--grad-compress", type=int, default=0)
    ap.add_argument("--out", default=None, help="directory for per-cell JSON")
    args = ap.parse_args()

    cells = []
    archs = configs.ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    results = []
    for a, s, mp in cells:
        tag = f"{a}|{s}|{'multi' if mp else 'single'}"
        try:
            rec, compiled = lower_cell(
                a, s, multi_pod=mp, kv_mode=args.kv_mode,
                num_planes=args.num_planes, grad_compress=args.grad_compress,
            )
            del compiled
        except Exception as e:  # a failing cell is a bug: record + continue
            rec = {"arch": a, "shape": s, "mesh": "multi" if mp else "single",
                   "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        results.append(rec)
        status = rec["status"]
        extra = ""
        if status == "OK":
            r = rec["roofline"]
            frac = r.get("floor_fraction", r["roofline_fraction"])
            extra = (f" compile={rec['compile_s']}s bottleneck={r['bottleneck']}"
                     f" frac={frac:.3f}")
        elif status == "FAIL":
            extra = " " + rec["error"][:120]
        print(f"[{status}] {tag}{extra}", flush=True)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            suffix = "" if args.kv_mode == "dense" else f".{args.kv_mode}"
            if args.grad_compress:
                suffix += f".gc{args.grad_compress}"
            fn = f"{a}.{s}.{'multi' if mp else 'single'}{suffix}.json"
            with open(os.path.join(args.out, fn), "w") as f:
                json.dump(rec, f, indent=1)

    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n{n_ok} OK, {n_skip} SKIP, {n_fail} FAIL / {len(results)} cells")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
