"""Production mesh + partition-spec rules for parameters, batches and caches.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (16, 16) = 256 chips ('data', 'model').
Multi-pod: (2, 16, 16) = 512 chips ('pod', 'data', 'model') -- the 'pod' axis
is the slow (DCN / inter-pod ICI) dimension and is where SZx gradient
compression applies (DESIGN.md section 3)."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# parameter partition specs (Megatron TP + optional FSDP over 'data')
# ---------------------------------------------------------------------------

def _param_rule(path: tuple[str, ...], ndim: int, cfg: ArchConfig):
    name = path[-1]
    stacked = "layers" in path            # leading L axis from the layer stack
    fsdp = "data" if cfg.fsdp else None
    lead = (None,) if stacked else ()

    if name in ("ln1", "ln2", "ln_cross", "final_ln", "norm", "dt_bias", "A_log", "D"):
        return P(*lead, *((None,) * (ndim - len(lead))))
    if name == "embed":
        return P("model", fsdp)                           # vocab x d_model
    if name == "lm_head":
        return P(fsdp, "model")                           # d_model x vocab
    if name == "frontend_proj":
        return P(fsdp, "model")
    if name in ("wq", "wk", "wv", "wi", "in", "router", "shared_wi"):
        return P(*lead, fsdp, "model")                    # column parallel
    if name in ("wo", "out", "shared_wo"):
        return P(*lead, "model", fsdp)                    # row parallel
    if name == "conv":
        return P(*lead, None, "model")                    # depthwise channels
    raise ValueError(f"no partition rule for param {'/'.join(path)}")


def _moe_rule(path, ndim, cfg):
    name = path[-1]
    fsdp = "data" if cfg.fsdp else None
    if name == "wi":
        return P(None, "model", fsdp, None)               # (L, E, D, 2F): EP
    if name == "wo":
        return P(None, "model", None, fsdp)               # (L, E, F, D): EP
    return None


def _tree_paths(tree):
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: (tuple(getattr(k, "key", str(k)) for k in kp), x), tree
    )


def _sanitize(spec: P, shape, mesh) -> P:
    """Drop mesh axes whose size doesn't divide the dim (e.g. hymba's SSM
    in-proj Z = 2*di + 2*N + H = 6482 on a 16-way 'model' axis); jit input
    shardings must divide evenly."""
    if mesh is None:
        return spec
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        out.append(ax if dim % total == 0 else None)
    return P(*out)


def replicated_specs_tree(params_tree):
    """All-replicated specs (pure-DP profile for small models)."""
    return jax.tree.map(lambda leaf: P(*((None,) * leaf.ndim)), params_tree)


def serve_param_specs_tree(cfg: ArchConfig, params_tree, mesh=None):
    """Decode-oriented weight layout (section Perf hillclimb H1).

    FSDP weight-gathers are catastrophic at decode (one all-gather of the
    full layer weights per token), so: no fsdp on dense/attention weights
    (they are small), and MoE experts sharded over BOTH axes -- E over
    'data', per-expert F over 'model' -- so the big expert tensors stay fully
    sharded without any per-step weight collective (dispatch moves MB-scale
    activations instead)."""
    import dataclasses as _dc

    cfg_noshard = _dc.replace(cfg, fsdp=False)

    def rule(kp, leaf):
        path = tuple(getattr(k, "key", str(k)) for k in kp)
        if "moe" in path and path[-1] == "wi":
            return _sanitize(P(None, "data", None, "model"), leaf.shape, mesh)
        if "moe" in path and path[-1] == "wo":
            return _sanitize(P(None, "data", "model", None), leaf.shape, mesh)
        return _sanitize(_param_rule(path, leaf.ndim, cfg_noshard), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def param_specs_tree(cfg: ArchConfig, params_tree, mesh=None):
    """PartitionSpec pytree matching `params_tree` (params or eval_shape)."""

    def rule(kp, leaf):
        path = tuple(getattr(k, "key", str(k)) for k in kp)
        if "moe" in path and path[-1] in ("wi", "wo"):
            spec = _moe_rule(path, leaf.ndim, cfg)
            if spec is not None:
                return _sanitize(spec, leaf.shape, mesh)
        return _sanitize(_param_rule(path, leaf.ndim, cfg), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def param_shardings(cfg, mesh, params_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs_tree(cfg, params_tree, mesh)
    )


# ---------------------------------------------------------------------------
# batch / cache partition specs
# ---------------------------------------------------------------------------

def batch_specs_tree(cfg: ArchConfig, mesh, batch_tree, *, long_context: bool = False):
    """tokens/labels: (B, S); frames/image_embeds: (B, T, D)."""
    dp = dp_axes(mesh)
    bspec = None if long_context else dp

    def rule(kp, leaf):
        return _sanitize(P(bspec, *((None,) * (leaf.ndim - 1))), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def cache_specs_tree(cfg: ArchConfig, mesh, cache_tree, *, long_context: bool = False):
    """Decode-cache sharding.

    Dense KV slabs (L, B, W, Hkv, hd): batch over DP, head_dim over 'model'
    (hd is 16-divisible for every assigned arch, so no padding waste even for
    kv-head counts like 2 or 4).  Long-context (B=1): batch replicated,
    window/seq dim over 'data' (sequence parallelism).
    """
    dp = dp_axes(mesh)
    b_ax = None if long_context else dp
    w_ax = "data" if long_context else None

    def rule(kp, leaf):
        path = tuple(getattr(k, "key", str(k)) for k in kp)
        name = path[-1]
        if name in ("pos", "slot_pos"):
            return P(*((None,) * leaf.ndim))
        if name in ("k", "v"):                     # (L,B,W,Hkv,hd) [cross: no W ring]
            return P(None, b_ax, w_ax, None, "model")
        if name.endswith("mu") or name.endswith("sexp"):   # (L,B,W,Hkv)
            return P(None, b_ax, w_ax, None)
        if name.endswith("pl"):                    # (L,P,B,W,Hkv,hd)
            return P(None, None, b_ax, w_ax, None, "model")
        if name == "state":                        # (L,B,H,N,hp)
            return P(None, b_ax, "model", None, None)
        if name == "conv":                         # (L,B,W-1,CC)
            return P(None, b_ax, None, "model")
        raise ValueError(f"no cache rule for {'/'.join(path)}")

    def rule_sane(kp, leaf):
        return _sanitize(rule(kp, leaf), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule_sane, cache_tree)
