"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 100 --reduced            # CPU-runnable smoke run
    ... --grad-compress 1                # SZx cross-pod gradient compression
        (full-size configs target the production mesh; on real hardware the
        same entry point runs under the TPU runtime, and XLA's latency-hiding
        scheduler overlaps the collectives this module emits with compute)
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro import configs, obs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SteppedBatches, StoreLM, SyntheticLM
from repro.launch import mesh as mesh_lib
from repro.optim import AdamW, warmup_cosine
from repro.train import step as step_mod
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--grad-compress", type=int, default=0)
    ap.add_argument("--ckpt", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-compress", action="store_true")
    ap.add_argument("--data-store", default=None,
                    help="train from a compressed ArrayStore corpus (store "
                         "path, shard-manifest .json, or service URL) "
                         "instead of the synthetic stream; tokens are "
                         "quantized ROI windows (see docs/INGEST.md)")
    ap.add_argument("--data-workers", type=int, default=2,
                    help="ingest worker threads for --data-store")
    ap.add_argument("--profile-dir", default=None,
                    help="enable telemetry and write <dir>/trace.json "
                         "(Chrome trace, opens in Perfetto) plus "
                         "<dir>/metrics.prom; also starts a jax.profiler "
                         "trace into the same directory when available")
    args = ap.parse_args()

    jax_profiler = False
    if args.profile_dir:
        obs.enable()
        os.makedirs(args.profile_dir, exist_ok=True)
        try:
            jax.profiler.start_trace(args.profile_dir)
            jax_profiler = True
        except Exception:
            pass  # profiler backend unavailable (e.g. minimal CPU builds)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt = AdamW(lr=warmup_cosine(3e-4, 20, args.steps))
    state = step_mod.init_state(cfg, opt, jax.random.key(0),
                                ef_planes=args.grad_compress)
    mesh = None
    if args.grad_compress:
        mesh = mesh_lib.make_production_mesh(multi_pod=True)
    step_fn = jax.jit(
        step_mod.make_train_step(cfg, opt, mesh=mesh,
                                 compress_planes=args.grad_compress),
        donate_argnums=(0,),
    )

    if args.data_store:
        # compressed-corpus ingest: pipelined ROI-window loader, same
        # (seed, step, rank) replay contract as the synthetic stream
        ds = StoreLM(
            args.data_store, DataConfig(cfg.vocab_size, args.seq, args.batch),
            workers=args.data_workers,
        )
        src = SteppedBatches(lambda s: ds.batches(start_step=s))
    else:
        ds = SyntheticLM(DataConfig(
            cfg.vocab_size, args.seq, args.batch,
            frames=cfg.encoder_len, frame_dim=cfg.d_model if cfg.encoder_decoder else 0,
            prefix_embeds=cfg.prefix_embeds,
            prefix_dim=cfg.d_model if cfg.prefix_embeds else 0,
        ))
        src = ds.batch_at
    batch_fn = lambda s: {k: jnp.asarray(v) for k, v in src(s).items()}  # noqa: E731

    ckpt = CheckpointManager(args.ckpt, keep=2, compress=args.ckpt_compress)
    tr = Trainer(TrainerConfig(total_steps=args.steps, checkpoint_every=25),
                 step_fn, batch_fn, ckpt)
    tr.run(state)

    if args.profile_dir:
        if jax_profiler:
            try:
                jax.profiler.stop_trace()
            except Exception:
                pass
        obs.write_chrome_trace(os.path.join(args.profile_dir, "trace.json"))
        with open(os.path.join(args.profile_dir, "metrics.prom"), "w") as f:
            f.write(obs.prometheus_text())
        print(f"telemetry written to {args.profile_dir}/trace.json")

    print(f"arch={args.arch} loss {tr.history[0]['loss']:.3f} -> "
          f"{tr.history[-1]['loss']:.3f} ({len(tr.history)} steps)")


if __name__ == "__main__":
    main()
