"""Launchers: production mesh, dry-run harness, train/serve CLIs."""
