"""Serving launcher: batched generation against any assigned arch.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --kv-mode compressed --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--kv-mode", default="dense", choices=["dense", "compressed"])
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(cfg, jax.random.key(0))
    kw = {}
    if cfg.encoder_decoder:
        kw["frames"] = jnp.zeros((args.batch, cfg.encoder_len, cfg.d_model))
    if cfg.prefix_embeds:
        kw["image_embeds"] = jnp.zeros((args.batch, cfg.prefix_embeds, cfg.d_model))
    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt), 0, cfg.vocab_size
    )
    cache, logits = engine.prefill(
        params, cfg, prompts,
        seq_len=args.prompt + args.tokens + (cfg.prefix_embeds or 0),
        kv_mode=args.kv_mode, **kw,
    )
    dec = jax.jit(lambda p, c, t: engine.decode_step(p, cfg, c, t, kv_mode=args.kv_mode))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    logits, cache = dec(params, cache, tok)
    t0 = time.time()
    outs = [tok]
    for _ in range(args.tokens - 1):
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
        logits, cache = dec(params, cache, tok)
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"{args.arch} kv={args.kv_mode}: "
          f"{args.batch*(args.tokens-1)/dt:.1f} tok/s; "
          f"sample row: {[int(t[0,0]) for t in outs[:8]]}")


if __name__ == "__main__":
    main()
