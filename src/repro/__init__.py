"""repro: SZx (ultra-fast error-bounded lossy compression) as a first-class
feature of a multi-pod JAX training/serving framework.

The supported public surface is :mod:`repro.api`; its names are re-exported
here (``repro.SZxCodec``, ``repro.Bound``, ...).  See ``repro.api.__doc__``
for the deprecation policy.
"""

__version__ = "1.1.0"

__all__ = [
    "api",
    "Bound",
    "SZxCodec",
    "TreeCodec",
    "PlanesCodec",
    "ArrayStore",
    "CompressedArray",
    "CheckpointManager",
    "CompressionStats",
    "compress",
    "compress_with_stats",
    "decompress",
]


def __getattr__(name):
    # Top-level names resolve through repro.api lazily: `import repro` stays
    # import-cheap, and repro.api remains the one definition of the surface.
    if name in __all__:
        from repro import api

        if name == "api":
            return api
        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
