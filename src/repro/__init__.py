"""repro: SZx (ultra-fast error-bounded lossy compression) as a first-class
feature of a multi-pod JAX training/serving framework."""

__version__ = "1.0.0"
