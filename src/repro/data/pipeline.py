"""Deterministic synthetic token pipeline with a compressed in-memory cache.

The paper's quantum-circuit-simulation use case (Section I) keeps working
data SZx-compressed in RAM and decompresses on demand; the pipeline mirrors
that: shards of the token stream are stored compressed (here: token-embedding
noise fields for modality stubs; token ids stay raw int32) and each batch is
materialized on the fly.

Sharding contract: every DP rank calls ``batches(rank, num_ranks)`` and gets
a disjoint, deterministic, restart-reproducible stream (seeded by (seed,
step, rank)), so restoring a checkpoint at step N resumes the exact stream.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.core import szx


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    frames: int = 0            # enc-dec stub frames per example
    frame_dim: int = 0
    prefix_embeds: int = 0     # VLM stub patches per example
    prefix_dim: int = 0


class SyntheticLM:
    """Markov-ish synthetic token stream: deterministic, seekable, sharded."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, rank: int = 0, num_ranks: int = 1) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % num_ranks == 0
        b = cfg.global_batch // num_ranks
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, rank])
        )
        # zipf-ish marginal over the vocab with local repetition structure
        base = rng.zipf(1.3, size=(b, cfg.seq_len)).astype(np.int64)
        toks = (base % (cfg.vocab_size - 2)) + 1
        rep = rng.random((b, cfg.seq_len)) < 0.3
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        tokens = toks.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((b, 1), -1, np.int32)], axis=1
        )
        out = {"tokens": tokens, "labels": labels}
        if cfg.frames:
            out["frames"] = rng.standard_normal(
                (b, cfg.frames, cfg.frame_dim), dtype=np.float32
            )
        if cfg.prefix_embeds:
            out["image_embeds"] = rng.standard_normal(
                (b, cfg.prefix_embeds, cfg.prefix_dim), dtype=np.float32
            )
        return out

    def batches(self, rank: int = 0, num_ranks: int = 1, start_step: int = 0
                ) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step, rank, num_ranks)
            step += 1


class CompressedInMemoryCache:
    """SZx-compressed RAM cache of float shards (the QC-simulation pattern).

    put() compresses; get() decompresses on demand.  ``bound`` is a
    :class:`repro.api.Bound` or a bare float (``Bound.abs``); the default is
    absolute and strict, so consumers can rely on |x - x'| <= e.

    Thread-safe: a single lock covers the entry map and the byte counters,
    so loader worker pools can share one cache.  ``max_bytes`` caps the
    COMPRESSED footprint with LRU eviction (both ``put`` and ``get`` touch
    recency); ``None`` means unbounded (the historical behavior)."""

    def __init__(self, bound=None, *, error_bound=None, mode=None,
                 max_bytes: int | None = None):
        from repro.core.codec import plan as _plan

        if bound is None and error_bound is None and mode is None:
            bound = _plan.Bound.abs(1e-4)
        self.bound = _plan.as_bound(bound, mode, error_bound=error_bound,
                                    owner="CompressedInMemoryCache")
        if max_bytes is not None and max_bytes <= 0:
            raise ValueError(f"max_bytes must be positive, got {max_bytes}")
        self.max_bytes = max_bytes
        self._lock = threading.Lock()
        self._store: collections.OrderedDict = collections.OrderedDict()
        self._raw_bytes = 0
        self._stored_bytes = 0
        self._evictions = 0

    @property
    def error_bound(self) -> float:
        return self.bound.value

    @property
    def mode(self) -> str:
        return self.bound.mode

    def put(self, key, arr: np.ndarray) -> None:
        arr = np.asarray(arr, np.float32)
        buf = szx.compress(arr, self.bound)     # compress outside the lock
        with self._lock:
            old = self._store.pop(key, None)
            if old is not None:
                self._raw_bytes -= old[2]
                self._stored_bytes -= len(old[0])
            self._store[key] = (buf, arr.shape, arr.nbytes)
            self._raw_bytes += arr.nbytes
            self._stored_bytes += len(buf)
            if self.max_bytes is not None:
                while self._stored_bytes > self.max_bytes and len(self._store) > 1:
                    _, (ebuf, _eshape, eraw) = self._store.popitem(last=False)
                    self._raw_bytes -= eraw
                    self._stored_bytes -= len(ebuf)
                    self._evictions += 1

    def get(self, key) -> np.ndarray:
        with self._lock:
            buf, shape, _raw = self._store[key]
            self._store.move_to_end(key)
        return szx.decompress(buf).reshape(shape)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._store

    @property
    def compression_ratio(self) -> float:
        with self._lock:
            return self._raw_bytes / max(self._stored_bytes, 1)

    @property
    def stored_bytes(self) -> int:
        with self._lock:
            return self._stored_bytes

    @property
    def evictions(self) -> int:
        with self._lock:
            return self._evictions

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)


class Prefetcher:
    """Background-thread prefetch of a batch iterator (host-side overlap).

    A worker exception does NOT die silently in the daemon thread: it is
    queued and re-raised from ``__next__`` on the consumer, after which the
    iterator is exhausted.  ``close()`` (or ``with Prefetcher(...)``) stops
    the worker, drains the queue, and joins the thread -- the contract the
    store loader's worker pool shares (exceptions surface on ``__next__``,
    shutdown is explicit and non-blocking-safe)."""

    _ITEM, _DONE, _ERROR = 0, 1, 2

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._stop = threading.Event()
        self._finished = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if not self._enqueue((self._ITEM, item)):
                    return
        except BaseException as exc:    # noqa: BLE001 -- relayed to consumer
            self._enqueue((self._ERROR, exc))
        else:
            self._enqueue((self._DONE, None))

    def _enqueue(self, msg) -> bool:
        """Bounded put that gives up once close() is requested (a plain
        blocking put would deadlock shutdown against a full queue)."""
        while not self._stop.is_set():
            try:
                self._q.put(msg, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self):
        return self

    def __next__(self):
        if self._finished:
            raise StopIteration
        kind, val = self._q.get()
        if kind == self._ITEM:
            return val
        self._finished = True
        if kind == self._ERROR:
            raise val
        raise StopIteration

    def close(self) -> None:
        """Stop the worker and reclaim the thread; idempotent."""
        self._stop.set()
        self._finished = True
        while self._thread.is_alive():
            try:                        # drain so a blocked put can exit
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(0.05)

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
