"""Streaming training ingest from compressed array stores.

The paper's headline use-cases keep data compressed and materialize values
only at the moment of use; this module makes the TRAINING INGEST path do the
same.  A :class:`StoreLoader` samples shuffled N-d ROI windows from an
:class:`repro.store.ArrayStore` (local file, shard manifest, or a running
store-service URL) and yields device-ready host batches, reading and
decoding ONLY the SZx block ranges the batch touches -- bytes read scale
with the batch, never the corpus.

Determinism contract (shared with ``SyntheticLM``): the window plan is a
pure function of ``(seed, step, rank)``, so restoring a checkpoint at step N
and calling ``batches(start_step=N)`` replays the exact window stream, per
rank, byte-identically.

Hot path: per batch the planner COALESCES windows landing in the same chunk
into one merged block-range task (a chunk is fetched and decoded once per
batch, not once per window), a worker pool runs the two-phase partial reads
and range decodes concurrently with bounded batch lookahead, and batches are
assembled into a small ring of preallocated reuse buffers.  Worker
exceptions propagate to the consumer on ``__next__`` and ``close()``
reclaims the pool -- the same contract as ``data.pipeline.Prefetcher``.

``StoreLM`` adapts a loader into the LM batch interface (quantized window
values as token streams) so ``launch/train.py --data-store`` can train
straight from a compressed corpus; see ``docs/INGEST.md``.
"""
from __future__ import annotations

import math
import os
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro import obs
from repro.data.pipeline import DataConfig
from repro.store import grid as grid_mod


# ------------------------------------------------------------------ sampling
class WindowSampler:
    """Deterministic, restart-reproducible, rank-sharded window plan.

    ``origins_at(step)`` returns the ``(batch, ndim)`` window origins for
    one step, seeded by ``SeedSequence([seed, step, rank])`` -- a pure
    function of its inputs, independent of iteration history, so any rank
    can seek to any step.  ``global_batch`` splits evenly across ranks
    (each rank draws its own ``batch = global_batch // num_ranks`` windows
    from a rank-disjoint stream, mirroring ``SyntheticLM``).

    ``epochs=N`` switches to multi-epoch WITHOUT-REPLACEMENT sampling: the
    candidate set is the non-overlapping window tiling of the array
    (``prod(d_i // w_i)`` windows), each epoch visits every candidate
    exactly once in a fresh ``SeedSequence([seed, _EPOCH_TAG, epoch])``
    permutation, and the permutation is consumed in global-draw order
    (``step * global_batch + rank * batch + i``), so ranks stay disjoint
    and any rank can still seek to any step without history.  Iteration is
    bounded: ``origins_at`` raises past :attr:`num_steps` (the last step
    whose full global batch fits in ``epochs`` passes).
    """

    _EPOCH_TAG = 0x5A17EB   # domain-separates epoch perms from step draws

    def __init__(self, shape, window_shape, global_batch: int, *,
                 seed: int = 0, rank: int = 0, num_ranks: int = 1,
                 epochs: int | None = None):
        self.shape = tuple(int(d) for d in shape)
        self.window_shape = tuple(int(w) for w in window_shape)
        if len(self.window_shape) != len(self.shape):
            raise ValueError(
                f"window shape {self.window_shape} rank does not match "
                f"array shape {self.shape}"
            )
        for w, d in zip(self.window_shape, self.shape):
            if not 1 <= w <= d:
                raise ValueError(
                    f"window dim {w} out of range [1, {d}] for shape "
                    f"{self.shape}"
                )
        if num_ranks < 1 or not 0 <= rank < num_ranks:
            raise ValueError(f"rank {rank} out of range [0, {num_ranks})")
        if global_batch < 1 or global_batch % num_ranks:
            raise ValueError(
                f"global batch {global_batch} does not split over "
                f"{num_ranks} ranks"
            )
        self.seed = int(seed)
        self.rank = int(rank)
        self.num_ranks = int(num_ranks)
        self.batch = global_batch // num_ranks
        if epochs is None:
            self.epochs = None
        else:
            if isinstance(epochs, bool) or int(epochs) < 1:
                raise ValueError(f"epochs must be a positive int, got {epochs!r}")
            self.epochs = int(epochs)
            self._tiles = tuple(
                d // w for d, w in zip(self.shape, self.window_shape)
            )
            self._nwin = int(np.prod(self._tiles, dtype=np.int64))
            if self._nwin < global_batch:
                raise ValueError(
                    f"epochs= mode needs at least one global batch of "
                    f"candidate windows per epoch ({self._nwin} non-"
                    f"overlapping windows < global batch {global_batch})"
                )
            self._perm_cache: tuple[int | None, np.ndarray | None] = (None, None)

    @property
    def num_steps(self) -> int:
        """Steps available under ``epochs=`` (full global batches only)."""
        if self.epochs is None:
            raise ValueError("num_steps is only defined with epochs= set")
        return (self.epochs * self._nwin) // (self.batch * self.num_ranks)

    def _epoch_perm(self, epoch: int) -> np.ndarray:
        cached_epoch, cached = self._perm_cache
        if cached_epoch == epoch:
            return cached
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self._EPOCH_TAG, epoch])
        )
        perm = rng.permutation(self._nwin)
        self._perm_cache = (epoch, perm)
        return perm

    def origins_at(self, step: int) -> np.ndarray:
        if self.epochs is None:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, int(step), self.rank])
            )
            cols = [
                rng.integers(0, d - w + 1, size=self.batch, dtype=np.int64)
                for d, w in zip(self.shape, self.window_shape)
            ]
            return np.stack(cols, axis=1)
        step = int(step)
        gb = self.batch * self.num_ranks
        g = step * gb + self.rank * self.batch \
            + np.arange(self.batch, dtype=np.int64)
        if step < 0 or int(g[-1]) >= self.epochs * self._nwin:
            raise ValueError(
                f"step {step} out of range [0, {self.num_steps}) for "
                f"epochs={self.epochs} over {self._nwin} candidate windows"
            )
        epoch = g // self._nwin
        pos = g % self._nwin
        flat = np.empty(self.batch, np.int64)
        for e in np.unique(epoch):       # a batch spans at most 2 epochs
            m = epoch == e
            flat[m] = self._epoch_perm(int(e))[pos[m]]
        coords = np.stack(np.unravel_index(flat, self._tiles), axis=1)
        return coords * np.asarray(self.window_shape, dtype=np.int64)


def window_for_values(shape, nvalues: int) -> tuple[int, ...]:
    """Smallest trailing-dims-whole window holding >= ``nvalues`` values.

    Mirrors ``grid.default_chunk_shape``: windows that keep trailing dims
    whole map to leading-axis slabs of each chunk, where the block range
    covering the window is tight -- decoded bytes ~ window bytes.
    """
    shape = tuple(int(d) for d in shape)
    rem = max(int(nvalues), 1)
    out: list[int] = []
    for dim in reversed(shape):
        take = min(dim, rem)
        out.append(take)
        rem = -(-rem // dim) if take == dim else 1
    return tuple(reversed(out))


# ------------------------------------------------------------------ planning
def plan_batch(grid, block_size: int, origins: np.ndarray, window_shape):
    """Coalesced read plan for one batch of windows.

    Returns ``(tasks, placements)``: ``tasks`` maps each touched chunk id to
    the MERGED SZx block range ``[lo_b, hi_b)`` covering every window piece
    that lands in it (one fetch + one range decode per chunk per batch);
    ``placements`` are ``(window_index, chunk_id, local_ranges, out_ranges)``
    records describing how decoded segments scatter into the batch array.
    """
    tasks: dict[int, tuple[int, int]] = {}
    placements: list[tuple] = []
    window_shape = tuple(window_shape)
    dims_cache: dict[int, tuple[int, ...]] = {}
    for wi, origin in enumerate(origins):
        roi = grid_mod.ROI(
            tuple((int(o), int(o) + w) for o, w in zip(origin, window_shape)),
            (False,) * len(window_shape),
        )
        for cid, local, outr in grid_mod.intersecting_chunks(grid, roi):
            cdims = dims_cache.get(cid)
            if cdims is None:
                cdims = dims_cache[cid] = grid.chunk_dims(grid.chunk_coord(cid))
            lo_b, hi_b = grid_mod.block_range_for_box(local, cdims, block_size)
            cur = tasks.get(cid)
            tasks[cid] = (lo_b, hi_b) if cur is None else (
                min(cur[0], lo_b), max(cur[1], hi_b)
            )
            placements.append((wi, cid, local, outr))
    return tasks, placements


def _assemble(out: np.ndarray, placements, segs, grid, block_size: int):
    """Scatter decoded chunk segments into the batch array.

    ``segs`` maps chunk id -> ``(flat_values, lo_b)`` where ``flat_values``
    covers the chunk's blocks ``[lo_b, hi_b)`` in C order (exactly what
    ``CompressedArray._decode_chunk_range`` returns).
    """
    dims_cache: dict[int, tuple[int, ...]] = {}
    for wi, cid, local, outr in placements:
        seg, lo_b = segs[cid]
        cdims = dims_cache.get(cid)
        if cdims is None:
            cdims = dims_cache[cid] = grid.chunk_dims(grid.chunk_coord(cid))
        out_sl = (wi,) + tuple(slice(lo, hi) for lo, hi in outr)
        if all(hi - lo == d for (lo, hi), d in zip(local, cdims)):
            out[out_sl] = np.asarray(seg).reshape(cdims)
        else:
            idx = np.ravel_multi_index(
                np.ix_(*[np.arange(lo, hi) for lo, hi in local]), cdims
            ) - lo_b * block_size
            out[out_sl] = np.asarray(seg)[idx]


# ------------------------------------------------------------------- sources
class StoreSource:
    """Thread-safe chunk-range reader over a local ``ArrayStore``.

    ``CompressedArray`` instances are NOT thread-safe (one shared seek
    cursor), so path/manifest targets get one lazily opened handle PER
    WORKER THREAD (footer parsed once per thread, then reused for every
    batch); an already-open ``CompressedArray`` is shared behind a lock
    instead (reads serialize -- handy for spy-file tests and tiny stores).
    An attached ``cache`` memoizes decoded chunk ranges across all handles.
    """

    granularity = "chunk"

    def __init__(self, target, *, backend: str = "numpy",
                 device: bool = False, cache=None, cache_ns: str | None = None):
        from repro.store.array import CompressedArray

        self._lock = threading.Lock()
        self._handles: list = []
        self._tl = threading.local()
        self._closed = False
        if isinstance(target, CompressedArray):
            self._shared = target
            self._open_kw = None
            head = target
        else:
            self._shared = None
            self._target = target if isinstance(target, dict) \
                else os.fspath(target)
            self._open_kw = dict(backend=backend, device=device, cache=cache,
                                 cache_ns=cache_ns)
            head = self._handle()
        self.grid = head._grid
        self.block_size = head._block_size
        self.shape = head.shape
        self.dtype = head.dtype
        self.error_bound = head.error_bound
        self.stored_bytes = head.stored_bytes

    def _handle(self):
        ca = getattr(self._tl, "ca", None)
        if ca is None:
            from repro.store import ArrayStore

            ca = ArrayStore.open(self._target, **self._open_kw)
            self._tl.ca = ca
            with self._lock:
                self._handles.append(ca)
        return ca

    def read_range(self, cid: int, lo_b: int, hi_b: int) -> np.ndarray:
        """Flat decoded values of blocks ``[lo_b, hi_b)`` of chunk ``cid``."""
        if self._shared is not None:
            with self._lock:
                return self._shared._decode_chunk_range(cid, lo_b, hi_b)
        return self._handle()._decode_chunk_range(cid, lo_b, hi_b)

    def close(self) -> None:
        with self._lock:
            handles, self._handles = self._handles, []
            self._closed = True
        for ca in handles:
            ca.close()


class HttpStoreSource:
    """Window reader over a running store service (``docs/SERVICE.md``).

    Reads are window-granular (``/read?roi=``): coalescing and the decoded
    chunk cache live SERVER-side, so the wire carries exactly the decoded
    window bytes and repeated-chunk decode cost is amortized by the
    service's LRU.  One client serves all worker threads (each request is
    an independent connection).
    """

    granularity = "window"

    def __init__(self, url: str, *, timeout: float = 60.0):
        from repro.serve.client import RemoteStore

        self.remote = RemoteStore(url, timeout=timeout)
        self.shape = self.remote.shape
        self.dtype = self.remote.dtype

    def read_window(self, origin, window_shape) -> np.ndarray:
        roi = ",".join(
            f"{int(o)}:{int(o) + int(w)}"
            for o, w in zip(origin, window_shape)
        )
        headers, body = self.remote.read_bytes(roi)
        return np.frombuffer(body, self.dtype).reshape(tuple(window_shape))

    def close(self) -> None:
        pass


def make_source(store, *, backend: str = "numpy", device: bool = False,
                cache=None, timeout: float = 60.0):
    """Normalize a loader target into a source: an existing source passes
    through, ``http(s)://`` URLs become :class:`HttpStoreSource`, everything
    else (path, shard-manifest path, manifest dict, open ``CompressedArray``)
    becomes a :class:`StoreSource`."""
    if hasattr(store, "granularity"):
        return store
    if isinstance(store, str) and store.startswith(("http://", "https://")):
        return HttpStoreSource(store, timeout=timeout)
    return StoreSource(store, backend=backend, device=device, cache=cache)


# -------------------------------------------------------------------- loader
class StoreLoader:
    """Streaming window-batch loader over a compressed array store.

    ``batch_at(step)`` is the serial reference: the exact ``(batch,
    *window_shape)`` array the pipelined iterator yields for that step.
    ``batches(start_step)`` returns the pipelined iterator (worker pool +
    bounded lookahead); both read only the coalesced block ranges the
    batch's windows touch.

    Yielded batches live in a ring of ``reuse_slots`` preallocated buffers:
    a batch is valid until ``reuse_slots`` further batches have been drawn
    (pass ``copy=True`` to own every batch).  ``workers=0`` keeps planning
    + decode on the consumer thread.
    """

    def __init__(self, store, window_shape, batch_size: int, *,
                 seed: int = 0, rank: int = 0, num_ranks: int = 1,
                 epochs: int | None = None,
                 workers: int = 2, lookahead: int = 2,
                 backend: str = "numpy", device: bool = False, cache=None,
                 copy: bool = False, reuse_slots: int = 3):
        self.source = make_source(store, backend=backend, device=device,
                                  cache=cache)
        self._owns_source = self.source is not store
        self.window_shape = tuple(int(w) for w in window_shape)
        self.sampler = WindowSampler(
            self.source.shape, self.window_shape, batch_size,
            seed=seed, rank=rank, num_ranks=num_ranks, epochs=epochs,
        )
        self.workers = max(int(workers), 0)
        self.lookahead = max(int(lookahead), 1)
        self.copy = bool(copy)
        self.reuse_slots = max(int(reuse_slots), 2)

    # ------------------------------------------------------------- metadata
    @property
    def batch_shape(self) -> tuple[int, ...]:
        return (self.sampler.batch,) + self.window_shape

    @property
    def dtype(self) -> np.dtype:
        return self.source.dtype

    @property
    def window_bytes(self) -> int:
        return math.prod(self.window_shape) * self.dtype.itemsize

    # ---------------------------------------------------------- serial path
    def batch_at(self, step: int, *, out: np.ndarray | None = None
                 ) -> np.ndarray:
        if not obs.enabled():
            return self._batch_at_impl(step, out=out)
        with obs.span("ingest.batch", step=step):
            res = self._batch_at_impl(step, out=out)
        obs.counter("ingest.batches", mode="serial").inc()
        return res

    def _batch_at_impl(self, step: int, *, out: np.ndarray | None = None
                       ) -> np.ndarray:
        if out is None:
            out = np.empty(self.batch_shape, self.dtype)
        origins = self.sampler.origins_at(step)
        if self.source.granularity == "window":
            for wi, org in enumerate(origins):
                out[wi] = self.source.read_window(org, self.window_shape)
            return out
        tasks, placements = plan_batch(
            self.source.grid, self.source.block_size, origins,
            self.window_shape,
        )
        segs = {
            cid: (self.source.read_range(cid, lo_b, hi_b), lo_b)
            for cid, (lo_b, hi_b) in tasks.items()
        }
        _assemble(out, placements, segs, self.source.grid,
                  self.source.block_size)
        return out

    # ------------------------------------------------------- pipelined path
    def batches(self, start_step: int = 0, steps: int | None = None
                ) -> "PipelinedBatches":
        return PipelinedBatches(self, start_step, steps)

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        if self._owns_source:
            self.source.close()

    def __enter__(self) -> "StoreLoader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class PipelinedBatches:
    """Ordered pipelined batch iterator (the loader's hot path).

    Chunk tasks for up to ``lookahead + 1`` upcoming batches are in flight
    on the pool at once; batches yield strictly in step order.  Consumer
    contract matches ``Prefetcher``: a worker exception re-raises from
    ``__next__`` (after which the iterator is closed), ``close()`` cancels
    pending work and reclaims the pool, and the iterator is a context
    manager.
    """

    def __init__(self, loader: StoreLoader, start_step: int,
                 steps: int | None):
        self._ld = loader
        self._next_step = int(start_step)
        self._end = None if steps is None else int(start_step) + int(steps)
        if loader.sampler.epochs is not None:
            # without-replacement sampling is bounded: stop at the last full
            # global batch instead of letting origins_at raise mid-iteration
            bound = loader.sampler.num_steps
            self._end = bound if self._end is None else min(self._end, bound)
        self._pending: deque = deque()
        self._pool = ThreadPoolExecutor(
            max_workers=max(loader.workers, 1),
            thread_name_prefix="store-loader",
        )
        self._slots = None if loader.copy else [
            np.empty(loader.batch_shape, loader.dtype)
            for _ in range(loader.reuse_slots)
        ]
        self._closed = False

    def _submit_one(self) -> bool:
        step = self._next_step
        if self._end is not None and step >= self._end:
            return False
        ld = self._ld
        track = obs.enabled()
        t0 = time.perf_counter() if track else 0.0
        origins = ld.sampler.origins_at(step)
        if ld.source.granularity == "window":
            futs = [
                self._pool.submit(ld.source.read_window, org, ld.window_shape)
                for org in origins
            ]
            self._pending.append((step, futs, None))
        else:
            tasks, placements = plan_batch(
                ld.source.grid, ld.source.block_size, origins,
                ld.window_shape,
            )
            futs = {
                cid: self._pool.submit(ld.source.read_range, cid, lo_b, hi_b)
                for cid, (lo_b, hi_b) in tasks.items()
            }
            self._pending.append((step, futs, (tasks, placements)))
        if track:
            obs.histogram("ingest.plan_seconds").observe(
                time.perf_counter() - t0
            )
            obs.gauge("ingest.lookahead").set(len(self._pending))
        self._next_step = step + 1
        return True

    def __iter__(self) -> "PipelinedBatches":
        return self

    def __next__(self) -> np.ndarray:
        if self._closed:
            raise StopIteration
        while len(self._pending) <= self._ld.lookahead and self._submit_one():
            pass
        if not self._pending:
            self.close()
            raise StopIteration
        step, futs, plan = self._pending.popleft()
        track = obs.enabled()
        if track:
            obs.gauge("ingest.lookahead").set(len(self._pending))
        out = np.empty(self._ld.batch_shape, self._ld.dtype) \
            if self._slots is None \
            else self._slots[step % len(self._slots)]
        t0 = time.perf_counter() if track else 0.0
        try:
            if plan is None:
                for wi, fut in enumerate(futs):
                    out[wi] = fut.result()
            else:
                tasks, placements = plan
                segs = {
                    cid: (fut.result(), tasks[cid][0])
                    for cid, fut in futs.items()
                }
                _assemble(out, placements, segs, self._ld.source.grid,
                          self._ld.source.block_size)
        except BaseException:
            self.close()
            raise
        if track:
            obs.histogram("ingest.wait_seconds").observe(
                time.perf_counter() - t0
            )
            obs.counter("ingest.batches", mode="pipelined").inc()
            obs.counter("ingest.bytes_out").inc(int(out.nbytes))
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for entry in self._pending:
            futs = entry[1]
            for fut in (futs.values() if isinstance(futs, dict) else futs):
                fut.cancel()
        self._pending.clear()
        self._pool.shutdown(wait=True, cancel_futures=True)

    def __enter__(self) -> "PipelinedBatches":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------- LM adapter
class StoreLM:
    """LM batch source over a compressed store: the ``--data-store`` path.

    Each sampled window's first ``seq_len + 1`` values (C order) are
    min/max-normalized per window and quantized into token ids
    ``[1, vocab - 2]`` (0 and ``vocab - 1`` stay reserved); ``labels`` is
    the one-step shift.  ``batch_at(step, rank, num_ranks)`` mirrors
    ``SyntheticLM`` exactly -- the stream is a pure function of the store
    contents and ``(cfg.seed, step, rank)``, so Trainer's
    restart-from-checkpoint replay holds.
    """

    def __init__(self, store, cfg: DataConfig, *, window_shape=None,
                 workers: int = 2, lookahead: int = 2,
                 backend: str = "numpy", device: bool = False, cache=None):
        if cfg.vocab_size < 4:
            raise ValueError("StoreLM needs vocab_size >= 4")
        self.cfg = cfg
        self.source = make_source(store, backend=backend, device=device,
                                  cache=cache)
        self._needs = cfg.seq_len + 1
        self.window_shape = tuple(int(w) for w in window_shape) \
            if window_shape is not None \
            else window_for_values(self.source.shape, self._needs)
        if math.prod(self.window_shape) < self._needs:
            raise ValueError(
                f"window {self.window_shape} holds "
                f"{math.prod(self.window_shape)} values; seq_len "
                f"{cfg.seq_len} needs {self._needs}"
            )
        self._workers = workers
        self._lookahead = lookahead
        self._loaders: dict[tuple[int, int], StoreLoader] = {}

    def _loader(self, rank: int, num_ranks: int) -> StoreLoader:
        key = (rank, num_ranks)
        ld = self._loaders.get(key)
        if ld is None:
            ld = self._loaders[key] = StoreLoader(
                self.source, self.window_shape, self.cfg.global_batch,
                seed=self.cfg.seed, rank=rank, num_ranks=num_ranks,
                workers=self._workers, lookahead=self._lookahead,
            )
        return ld

    def _to_batch(self, wins: np.ndarray) -> dict:
        vocab = self.cfg.vocab_size
        b = wins.shape[0]
        v = np.asarray(wins, np.float64).reshape(b, -1)[:, : self._needs]
        lo = v.min(axis=1, keepdims=True)
        hi = v.max(axis=1, keepdims=True)
        span = np.where(hi > lo, hi - lo, 1.0)
        q = np.floor((v - lo) / span * (vocab - 3)).astype(np.int32) + 1
        q = np.clip(q, 1, vocab - 2)
        return {"tokens": np.ascontiguousarray(q[:, :-1]),
                "labels": np.ascontiguousarray(q[:, 1:])}

    def batch_at(self, step: int, rank: int = 0, num_ranks: int = 1) -> dict:
        return self._to_batch(self._loader(rank, num_ranks).batch_at(step))

    def batches(self, rank: int = 0, num_ranks: int = 1, start_step: int = 0):
        it = self._loader(rank, num_ranks).batches(start_step=start_step)
        try:
            for wins in it:
                yield self._to_batch(wins)
        finally:
            it.close()

    def close(self) -> None:
        self.source.close()


class SteppedBatches:
    """``batch_fn(step)`` adapter over a pipelined batch stream.

    The Trainer calls ``batch_fn`` with monotonically increasing steps --
    except after restart-from-checkpoint, where it jumps backward.  The
    adapter keeps one pipelined iterator alive for the common sequential
    case and transparently re-opens it at the requested step whenever the
    sequence breaks, so fault-tolerant replay stays exact while steady
    state stays pipelined.

    ``open_at`` is any ``start_step -> iterator`` factory (e.g.
    ``lambda s: store_lm.batches(start_step=s)``).
    """

    def __init__(self, open_at):
        self._open_at = open_at
        self._it = None
        self._expect: int | None = None

    def __call__(self, step: int):
        if self._it is None or step != self._expect:
            self.close()
            self._it = self._open_at(step)
        batch = next(self._it)
        self._expect = step + 1
        return batch

    def close(self) -> None:
        it, self._it = self._it, None
        self._expect = None
        if it is not None:
            close = getattr(it, "close", None)
            if close is not None:
                close()

    def __enter__(self) -> "SteppedBatches":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
