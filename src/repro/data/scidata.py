"""Synthetic scientific-field generator for the compressor benchmarks.

SDRBench is not downloadable offline, so we synthesize six "applications"
whose block-smoothness statistics are shaped to match the paper's Fig. 2 CDF
characterization (e.g. Miranda/QMCPack: 80+% of size-8 blocks with relative
range <= 0.01; Hurricane/NYX rougher).  Each application has several fields
with different roughness/feature mixes so min/avg/max CR spread like
Table III.
"""
from __future__ import annotations

import numpy as np


def _smooth_field(rng, shape, octaves, roughness, spike_frac=0.0):
    """Multi-octave separable smooth noise + optional spikes."""
    out = np.zeros(shape, np.float32)
    for o in range(octaves):
        amp = roughness**o
        coarse = [max(2, s // (2 ** (octaves - o))) for s in shape]
        small = rng.standard_normal(coarse).astype(np.float32)
        for ax, (cs, fs) in enumerate(zip(coarse, shape)):
            reps = int(np.ceil(fs / cs))
            small = np.repeat(small, reps, axis=ax)
            sl = [slice(None)] * len(shape)
            sl[ax] = slice(0, fs)
            small = small[tuple(sl)]
            # box smooth along the axis
            k = max(1, fs // cs // 2)
            if k > 1:
                c = np.cumsum(small, axis=ax)
                sl_a = [slice(None)] * len(shape)
                sl_b = [slice(None)] * len(shape)
                sl_a[ax] = slice(k, None)
                sl_b[ax] = slice(0, -k)
                body = (c[tuple(sl_a)] - c[tuple(sl_b)]) / k
                pad = [(0, 0)] * len(shape)
                pad[ax] = (0, small.shape[ax] - body.shape[ax])
                small = np.pad(body, pad, mode="edge")
        out += amp * small
    if spike_frac:
        n = int(out.size * spike_frac)
        idx = rng.integers(0, out.size, n)
        out.reshape(-1)[idx] *= 50.0
    return out


# (octaves, roughness, spike_frac, scale) per field; tuned so the block-range
# CDFs span the paper's smooth (Miranda/QMCPack) to rough (NYX) spectrum
APPLICATIONS = {
    "CESM": dict(shape=(1800, 360), fields=6, octaves=5, rough=0.55, spikes=0.0002),
    "Hurricane": dict(shape=(100, 500, 50), fields=5, octaves=4, rough=0.65, spikes=0.0005),
    "Miranda": dict(shape=(256, 384, 38), fields=4, octaves=6, rough=0.22, spikes=0.0),
    "NYX": dict(shape=(256, 256, 64), fields=4, octaves=3, rough=0.85, spikes=0.001),
    "QMCPack": dict(shape=(288, 115, 69), fields=2, octaves=6, rough=0.18, spikes=0.0),
    "SCALE-LetKF": dict(shape=(98, 1200, 12), fields=5, octaves=4, rough=0.6, spikes=0.0003),
}


def field(app: str, idx: int) -> np.ndarray:
    spec = APPLICATIONS[app]
    rng = np.random.default_rng(np.random.SeedSequence([hash(app) % 2**31, idx]))
    rough = spec["rough"] * (1.0 + 0.25 * (idx - spec["fields"] / 2) / spec["fields"])
    f = _smooth_field(rng, spec["shape"], spec["octaves"], rough, spec["spikes"])
    scale = 10.0 ** rng.integers(-2, 4)
    return (f * scale).astype(np.float32)


def fields(app: str):
    for i in range(APPLICATIONS[app]["fields"]):
        yield f"{app}.f{i}", field(app, i)


def block_relative_range_cdf(x: np.ndarray, block: int = 8) -> np.ndarray:
    """Fraction of blocks with relative value range <= thresholds (Fig. 2)."""
    flat = x.reshape(-1)
    n = (flat.size // block) * block
    xb = flat[:n].reshape(-1, block)
    rng_b = xb.max(1) - xb.min(1)
    g = x.max() - x.min()
    rel = rng_b / max(g, 1e-30)
    thresholds = np.logspace(-6, 0, 25)
    return np.array([(rel <= t).mean() for t in thresholds])
