"""Data substrate: synthetic pipelines + SZx-compressed in-memory cache +
synthetic scientific fields for the compressor benchmarks."""
from repro.data.pipeline import CompressedInMemoryCache, DataConfig, Prefetcher, SyntheticLM  # noqa: F401
