"""Data substrate: synthetic pipelines + SZx-compressed in-memory cache +
store-backed streaming ingest + synthetic scientific fields for the
compressor benchmarks."""
from repro.data.pipeline import CompressedInMemoryCache, DataConfig, Prefetcher, SyntheticLM  # noqa: F401
from repro.data.store_loader import (  # noqa: F401
    PipelinedBatches,
    SteppedBatches,
    StoreLM,
    StoreLoader,
    WindowSampler,
    window_for_values,
)
