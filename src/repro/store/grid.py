"""N-d chunk-grid geometry for the block-addressable array store.

Pure index math, no I/O: how an N-d array is tiled into chunk
hyperrectangles, how a region-of-interest (ROI) maps onto the chunks it
intersects, and how a chunk-local ROI box maps onto the contiguous range of
SZx blocks that covers it in the chunk's C-order flattening.  Everything the
lazy read path needs to guarantee "bytes read scale with the ROI, not the
array" lives here.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Iterator

# ~2 MB of raw input per chunk: small enough that a boxy ROI of a large
# array touches only a few percent of the file, large enough that per-chunk
# header overhead stays negligible and per-chunk encode stays fast.
DEFAULT_CHUNK_TARGET_BYTES = 2 << 20


def parse_roi(text: str | None):
    """'0:16,:,3' -> an N-d index tuple (step-1 slices and ints only).

    The ONE textual ROI parser, shared by the CLI and the HTTP service
    (promoted out of ``store.__main__`` so library code never imports a
    CLI module).
    """
    if text is None or text.strip() in ("", "..."):
        return Ellipsis
    out = []
    for part in text.split(","):
        part = part.strip()
        if part == "...":
            out.append(Ellipsis)
        elif ":" in part:
            fields = part.split(":")
            if len(fields) > 3:
                raise ValueError(f"bad ROI slice {part!r}")
            vals = [int(v) if v else None for v in fields]
            out.append(slice(*vals))
        else:
            out.append(int(part))
    return tuple(out)


def default_chunk_shape(
    shape: tuple[int, ...], itemsize: int,
    target_bytes: int = DEFAULT_CHUNK_TARGET_BYTES,
) -> tuple[int, ...]:
    """zarr-style default chunking: keep trailing dimensions whole and split
    leading ones until a chunk holds at most ``target_bytes`` of raw input."""
    rem = max(target_bytes // itemsize, 1)
    out: list[int] = []
    for dim in reversed(shape):
        take = min(dim, rem)
        out.append(take)
        rem = max(rem // dim, 1) if take == dim else 1
    return tuple(reversed(out))


@dataclass(frozen=True)
class ChunkGrid:
    """C-order grid of chunk hyperrectangles over an N-d array shape.

    Chunk ids are the C-order enumeration of N-d chunk coordinates; edge
    chunks are clipped to the array bounds.  This id order is also the frame
    order of a store stream, which makes the footer's ``frames`` list the
    block-grid index: ``frames[grid.chunk_id(coord)]`` is the byte range of
    the chunk at ``coord``.
    """

    shape: tuple[int, ...]
    chunk_shape: tuple[int, ...]

    def __post_init__(self):
        if len(self.shape) != len(self.chunk_shape):
            raise ValueError(
                f"chunk shape {self.chunk_shape} rank does not match array "
                f"shape {self.shape}"
            )
        if not self.shape:
            raise ValueError("0-d arrays are not chunkable; reshape to (1,)")
        for d, c in zip(self.shape, self.chunk_shape):
            if d <= 0:
                raise ValueError(f"array shape {self.shape} has an empty dim")
            if not 1 <= c <= d:
                raise ValueError(
                    f"chunk dim {c} out of range [1, {d}] for shape {self.shape}"
                )

    @staticmethod
    def for_shape(shape, chunk_shape=None, *, itemsize: int = 4,
                  target_bytes: int = DEFAULT_CHUNK_TARGET_BYTES) -> "ChunkGrid":
        shape = tuple(int(d) for d in shape)
        if chunk_shape is None:
            chunk_shape = default_chunk_shape(shape, itemsize, target_bytes)
        else:
            chunk_shape = tuple(
                min(max(int(c), 1), d) for c, d in zip(chunk_shape, shape)
            )
        return ChunkGrid(shape, chunk_shape)

    @property
    def chunks_per_dim(self) -> tuple[int, ...]:
        return tuple(
            (d + c - 1) // c for d, c in zip(self.shape, self.chunk_shape)
        )

    @property
    def nchunks(self) -> int:
        return math.prod(self.chunks_per_dim)

    def chunk_coord(self, cid: int) -> tuple[int, ...]:
        per = self.chunks_per_dim
        if not 0 <= cid < self.nchunks:
            raise ValueError(f"chunk id {cid} out of range [0, {self.nchunks})")
        coord = []
        for n in reversed(per):
            coord.append(cid % n)
            cid //= n
        return tuple(reversed(coord))

    def chunk_id(self, coord: tuple[int, ...]) -> int:
        cid = 0
        for c, n in zip(coord, self.chunks_per_dim):
            if not 0 <= c < n:
                raise ValueError(f"chunk coord {coord} out of grid {self.chunks_per_dim}")
            cid = cid * n + c
        return cid

    def chunk_box(self, coord: tuple[int, ...]) -> tuple[tuple[int, int], ...]:
        """Per-dim [lo, hi) extents of the chunk at ``coord`` (edge-clipped)."""
        return tuple(
            (c * cs, min((c + 1) * cs, d))
            for c, cs, d in zip(coord, self.chunk_shape, self.shape)
        )

    def chunk_dims(self, coord: tuple[int, ...]) -> tuple[int, ...]:
        return tuple(hi - lo for lo, hi in self.chunk_box(coord))

    def chunk_elements(self, coord: tuple[int, ...]) -> int:
        return math.prod(self.chunk_dims(coord))


@dataclass(frozen=True)
class ROI:
    """A normalized region of interest: per-dim [start, stop) plus which
    dims came from integer indices (and are squeezed out of the result)."""

    ranges: tuple[tuple[int, int], ...]
    squeeze: tuple[bool, ...]

    @property
    def box_shape(self) -> tuple[int, ...]:
        return tuple(hi - lo for lo, hi in self.ranges)

    @property
    def out_shape(self) -> tuple[int, ...]:
        return tuple(
            hi - lo for (lo, hi), sq in zip(self.ranges, self.squeeze) if not sq
        )

    @property
    def size(self) -> int:
        return math.prod(self.box_shape)


def normalize_roi(key, shape: tuple[int, ...]) -> ROI:
    """Normalize a ``__getitem__`` key into per-dim [start, stop) ranges.

    Supports integers (negative ok, dim squeezed), step-1 slices, Ellipsis,
    and full-dim fill for unspecified trailing dims.  Fancy/boolean indexing
    and non-unit steps raise TypeError/ValueError -- ROI reads are
    hyperrectangles by design (each maps to a contiguous block range per
    chunk).
    """
    if not isinstance(key, tuple):
        key = (key,)
    n_ell = sum(1 for k in key if k is Ellipsis)
    if n_ell > 1:
        raise ValueError("an index can only have a single Ellipsis")
    explicit = len(key) - n_ell
    if explicit > len(shape):
        raise ValueError(
            f"too many indices ({explicit}) for a rank-{len(shape)} array"
        )
    expanded: list = []
    for k in key:
        if k is Ellipsis:
            expanded.extend([slice(None)] * (len(shape) - explicit))
        else:
            expanded.append(k)
    expanded.extend([slice(None)] * (len(shape) - len(expanded)))

    ranges: list[tuple[int, int]] = []
    squeeze: list[bool] = []
    for k, d in zip(expanded, shape):
        if isinstance(k, bool):
            raise TypeError("boolean indices are not supported by ROI reads")
        if isinstance(k, slice):
            if k.step not in (None, 1):
                raise ValueError(
                    f"ROI reads support step-1 slices only, got step {k.step}"
                )
            lo, hi, _ = k.indices(d)
            ranges.append((lo, max(hi, lo)))
            squeeze.append(False)
        elif isinstance(k, (int,)) or hasattr(k, "__index__"):
            i = k.__index__()
            if i < 0:
                i += d
            if not 0 <= i < d:
                raise IndexError(f"index {k} out of bounds for dim of size {d}")
            ranges.append((i, i + 1))
            squeeze.append(True)
        else:
            raise TypeError(
                f"ROI reads support ints, step-1 slices, and Ellipsis; "
                f"got {type(k).__name__}"
            )
    return ROI(tuple(ranges), tuple(squeeze))


def intersecting_chunks(
    grid: ChunkGrid, roi: ROI
) -> Iterator[tuple[int, tuple[tuple[int, int], ...], tuple[tuple[int, int], ...]]]:
    """Yield ``(chunk_id, local_ranges, out_ranges)`` for every chunk the ROI
    intersects, in chunk-id order.

    ``local_ranges`` are [lo, hi) within the chunk's own (clipped) dims;
    ``out_ranges`` are [lo, hi) within the ROI's box shape.  Chunks outside
    the ROI are never yielded -- the "never parses non-intersecting chunks"
    guarantee starts here.
    """
    if roi.size == 0:
        return
    per_dim = []
    for (lo, hi), cs in zip(roi.ranges, grid.chunk_shape):
        per_dim.append(range(lo // cs, (hi - 1) // cs + 1))
    for coord in itertools.product(*per_dim):
        box = grid.chunk_box(coord)
        local, out = [], []
        for (rlo, rhi), (blo, bhi) in zip(roi.ranges, box):
            ilo, ihi = max(rlo, blo), min(rhi, bhi)
            local.append((ilo - blo, ihi - blo))
            out.append((ilo - rlo, ihi - rlo))
        yield grid.chunk_id(coord), tuple(local), tuple(out)


def block_range_for_box(
    local_ranges: tuple[tuple[int, int], ...],
    chunk_dims: tuple[int, ...],
    block_size: int,
) -> tuple[int, int]:
    """Contiguous SZx block range [lo, hi) covering a local ROI box in the
    chunk's C-order flattening.

    The first and last elements of the box bound every element's flat index,
    so the block span of the box is the span of those two corners -- tight
    for leading-axis slabs, and never larger than the chunk.
    """
    first = last = 0
    for (lo, hi), d in zip(local_ranges, chunk_dims):
        first = first * d + lo
        last = last * d + (hi - 1)
    return first // block_size, last // block_size + 1
