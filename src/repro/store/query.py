"""Compressed-domain analytics: aggregate queries without full decompression.

SZx's block structure is what makes in-place analytics possible: a constant
block stores ONLY its value ``mu`` (every decoded element equals it
exactly), and a non-constant block's header (``mu`` + its required-length
byte) bounds the block's whole value range.  Two query tiers exploit this:

* **exact** (default): constant blocks are answered from their headers
  alone; only non-constant blocks decode.  Results equal the stats of the
  decompressed array (up to float64 accumulation order).  On the
  constant-heavy streams scientific data produces, most plane bytes are
  never read -- an all-constant stream reads headers only.
* **header-only**: NEVER reads L codes or mid/plane bytes -- one metadata
  read per frame.  Returns guaranteed ``[lo, hi]`` intervals: a
  non-constant block's radius ``r`` satisfies ``r < 2**(R + p(e))`` where
  ``R = reqlen - 1 - exp_bits`` is read straight from the header (Formula 4
  inverted), so its decoded values all lie within ``mu +- (2**(R + p(e)) +
  e)``.  Verbatim blocks (``R == mant_bits``) are unbounded from the header
  and widen the interval to infinity.

Both tiers stream frame-by-frame in O(frame) memory and accumulate in
float64.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.codec import container, plan as plan_mod, transform
from repro.core.codec.transform import BlockEncoding
from repro.kernels import specs


@dataclass(frozen=True)
class QueryStats:
    """Aggregate query result; every stat is a ``(lo, hi)`` interval that is
    guaranteed to contain the corresponding stat of the decompressed array.
    ``exact=True`` means every interval has zero width (``lo == hi``)."""

    count: int
    nblocks: int
    const_blocks: int
    verbatim_blocks: int
    sum: tuple[float, float]
    min: tuple[float, float]
    max: tuple[float, float]
    exact: bool

    @property
    def mean(self) -> tuple[float, float]:
        return (self.sum[0] / self.count, self.sum[1] / self.count)

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "nblocks": self.nblocks,
            "const_blocks": self.const_blocks,
            "verbatim_blocks": self.verbatim_blocks,
            "exact": self.exact,
            "sum": list(self.sum),
            "mean": list(self.mean),
            "min": list(self.min),
            "max": list(self.max),
        }


class _Acc:
    def __init__(self):
        self.count = 0
        self.nblocks = 0
        self.const_blocks = 0
        self.verbatim_blocks = 0
        self.sum_lo = self.sum_hi = 0.0
        self.min_lo = self.min_hi = np.inf
        self.max_lo = self.max_hi = -np.inf
        self.exact = True

    def add_points(self, values: np.ndarray, weights=None) -> None:
        """Exact contributions: per-block (or per-element) known values."""
        if values.size == 0:
            return
        v = values.astype(np.float64, copy=False)
        s = float(v.sum() if weights is None else (v * weights).sum())
        self.sum_lo += s
        self.sum_hi += s
        lo, hi = float(v.min()), float(v.max())
        self.min_lo, self.min_hi = min(self.min_lo, lo), min(self.min_hi, lo)
        self.max_lo, self.max_hi = max(self.max_lo, hi), max(self.max_hi, hi)

    def done(self) -> QueryStats:
        return QueryStats(
            self.count, self.nblocks, self.const_blocks, self.verbatim_blocks,
            (self.sum_lo, self.sum_hi), (self.min_lo, self.min_hi),
            (self.max_lo, self.max_hi), self.exact,
        )


def _frame_meta(f, off: int, length: int, seq: int):
    """Read + parse ONLY the header-tier metadata of one frame: stream
    header, const bitmap, mu section, reqlen section.  Never touches the
    L-code or mid sections."""
    _flags, plen, sheader = container.read_frame_stream_header_at(f, off, seq)
    _m, _sv, dtype_code, bs, n, e, nb, nnc, _nmid = container.HEADER.unpack_from(
        sheader, 0
    )
    spec = plan_mod.spec_for_code(dtype_code)
    nbm = (nb + 7) // 8
    meta = container._read_exact(f, nbm + spec.itemsize * nb + nnc)
    const = np.unpackbits(np.frombuffer(meta, np.uint8, nbm, 0))[:nb].astype(bool)
    mu = np.frombuffer(meta, spec.np_dtype, nb, nbm)
    reqlen_nc = np.frombuffer(meta, np.uint8, nnc, nbm + spec.itemsize * nb)
    if int((~const).sum()) != nnc:
        raise ValueError("corrupt SZx stream (const bitmap / n_nonconst mismatch)")
    return spec, int(bs), int(n), float(e), const, mu, reqlen_nc, int(plen)


def _valid_counts(n: int, nb: int, bs: int) -> np.ndarray:
    """Logical (un-padded) element count of each block."""
    counts = np.full(nb, bs, np.int64)
    if nb:
        counts[-1] = n - (nb - 1) * bs
    return counts


def scan_frames(f, frames, *, backend: str = "numpy",
                header_only: bool = False, locs=None) -> QueryStats:
    """Aggregate stats over an indexed frame sequence (store or chunked
    stream): ``frames`` is the footer's ``[offset, length, elements]`` list.
    ``locs`` overrides the frame locations for multi-file (sharded) stores:
    an iterable of ``(fileobj, seq, offset, length, elements)``.  See the
    module docstring for the two tiers."""
    if locs is None:
        locs = (
            (f, seq, int(fr[0]), int(fr[1]), int(fr[2]))
            for seq, fr in enumerate(frames)
        )
    acc = _Acc()
    for f, seq, off, length, elements in locs:
        spec, bs, n, e, const, mu, reqlen_nc, plen = _frame_meta(f, off, length, seq)
        if n != elements:
            raise ValueError(
                f"corrupt store index (frame {seq}: stream has {n} elements, "
                f"index says {elements})"
            )
        nb = const.size
        counts = _valid_counts(n, nb, bs)
        acc.count += n
        acc.nblocks += nb
        acc.const_blocks += int(const.sum())
        # constant blocks: every decoded element IS mu -- exact from headers
        mu_c = mu[const].astype(np.float64)
        acc.add_points(mu_c, weights=counts[const].astype(np.float64))
        if int((~const).sum()) == 0:
            continue
        if header_only:
            _add_header_intervals(acc, spec, e, mu, const, reqlen_nc, counts)
        else:
            _add_exact_nonconst(acc, f, off, length, seq, const, counts, backend)
    return acc.done()


def _add_header_intervals(acc, spec, e, mu, const, reqlen_nc, counts) -> None:
    """Interval contributions of non-constant blocks, headers only."""
    p_e = specs.exact_exponent_of(e)
    R = reqlen_nc.astype(np.int64) - 1 - spec.exp_bits
    verbatim = R >= spec.mant_bits
    acc.verbatim_blocks += int(verbatim.sum())
    # r < 2**(R + p_e) (Formula 4 inverted); decoded values within r + e of mu
    with np.errstate(over="ignore"):
        r_ub = np.exp2((R + p_e).astype(np.float64))
    r_ub[verbatim] = np.inf
    b = r_ub + e
    mu_nc = mu[~const].astype(np.float64)
    cnt = counts[~const].astype(np.float64)
    vb = verbatim            # already per-non-const (reqlen_nc order)
    acc.exact = False
    acc.sum_lo += float(((mu_nc - b) * cnt).sum())
    acc.sum_hi += float(((mu_nc + b) * cnt).sum())
    # block min is within [mu - b, mu + e], block max within [mu - e, mu + b]
    # -- EXCEPT verbatim blocks, whose stored mu is zeroed (the values are
    # exact but unbounded from the header): their block min/max can sit
    # anywhere, so the inner bounds must open up to +-inf too
    min_hi_blk = np.where(vb, np.inf, mu_nc + e)
    max_lo_blk = np.where(vb, -np.inf, mu_nc - e)
    acc.min_lo = min(acc.min_lo, float((mu_nc - b).min()))
    acc.min_hi = min(acc.min_hi, float(min_hi_blk.min()))
    acc.max_lo = max(acc.max_lo, float(max_lo_blk.max()))
    acc.max_hi = max(acc.max_hi, float((mu_nc + b).max()))


def _add_exact_nonconst(acc, f, off, length, seq, const, counts, backend) -> None:
    """Exact contributions of non-constant blocks: decode ONLY those blocks
    of the frame's payload."""
    payload, _flags = container.read_frame_at(f, off, length, seq)
    p, enc = container.parse_stream(payload, backend=backend)
    nc = ~const
    sub = BlockEncoding(
        enc.mu[nc], enc.const[nc], enc.reqlen[nc], enc.shift[nc],
        enc.nbytes[nc], enc.planes[nc], enc.L[nc],
    )
    dec = np.asarray(transform.decode_blocks(sub, p)).astype(np.float64)
    cnt = counts[nc]
    full = cnt == p.block_size
    acc.add_points(dec[full].reshape(-1))
    for row in np.flatnonzero(~full):        # at most the stream's last block
        acc.add_points(dec[row, : cnt[row]])
