"""repro.store -- block-addressable compressed N-d array store.

A zarr-like on-disk store over the SZx codec: ``ArrayStore.save`` writes an
N-d array as a grid of independently addressable compressed chunks (a
container-v3 stream whose footer is the block-grid index), and
``ArrayStore.open`` returns a lazy :class:`CompressedArray` supporting

* **ROI reads** -- ``ca[10:20, :, 5]`` decodes only the chunks and SZx
  blocks intersecting the request (bytes read scale with the ROI, not the
  array), and
* **compressed-domain queries** -- ``ca.mean()/min()/max()/sum()`` answered
  from block headers wherever blocks are constant, decoding only what is
  not (``repro.store.query``).

CLI: ``python -m repro.store {create,info,read,query,serve}``.
"""
from repro.store.array import ArrayStore, CompressedArray  # noqa: F401
from repro.store.grid import ChunkGrid  # noqa: F401
from repro.store.query import QueryStats  # noqa: F401

save = ArrayStore.save
open = ArrayStore.open  # noqa: A001 - mirrors zarr's module-level open()
