"""On-disk format of the array store: container v3 with a block-grid index.

A store file IS a container-v3 stream (`docs/FORMAT.md`): a sequence of
self-delimiting frames -- one frame per N-d chunk, each payload an
independent v2 SZx stream of the chunk's C-order flattening -- followed by
the seekable index footer.  The footer's ``kind`` is ``"szx-store"`` and its
index extends the chunked schema with the chunk-grid geometry:

    {
      "v": 1, "kind": "szx-store", "store_v": 1,
      "shape": [...], "chunk_shape": [...],
      "dtype": <dtype code>, "block_size": <int>, "e": <absolute bound>,
      "frames": [[offset, length, elements], ...],   # one per chunk, C order
      "attrs": {...},                                 # user metadata
    }

``frames[grid.chunk_id(coord)]`` is the byte range of the chunk at N-d
coordinate ``coord`` -- the block-grid index mapping chunk coordinates to
byte ranges.  Any container-v3 reader can still walk the frames
sequentially; ``SZxCodec.load_chunked``-style readers see a normal chunked
stream whose chunk order happens to be the grid's C order.
"""
from __future__ import annotations

import math

from repro.core.codec import plan as plan_mod

from repro.store.grid import ChunkGrid

STORE_KIND = "szx-store"
STORE_SHARD_KIND = "szx-store-shard"
MANIFEST_KIND = "szx-store-manifest"
STORE_VERSION = 1
MANIFEST_VERSION = 1


def build_store_index(
    grid: ChunkGrid,
    dtype_code: int,
    block_size: int,
    e: float,
    frames: list[list[int]],
    attrs: dict | None = None,
    *,
    stage: str | None = None,
) -> dict:
    if len(frames) != grid.nchunks:
        raise ValueError(
            f"store index needs one frame per chunk ({grid.nchunks}), got "
            f"{len(frames)}"
        )
    from repro.core.codec import container

    idx = {
        "v": container.INDEX_VERSION,
        "kind": STORE_KIND,
        "store_v": STORE_VERSION,
        "shape": list(grid.shape),
        "chunk_shape": list(grid.chunk_shape),
        "dtype": int(dtype_code),
        "block_size": int(block_size),
        "e": float(e),
        "frames": frames,
        "attrs": dict(attrs or {}),
    }
    # advisory only (the frame flags are the source of truth per chunk);
    # omitted when stage-off so stage-less footers stay byte-identical
    if stage is not None:
        idx["stage"] = stage
    return idx


def validate_store_index(idx: dict) -> tuple[ChunkGrid, object, int, float]:
    """Check a footer dict is a readable store index; returns
    ``(grid, dtype_spec, block_size, e)``."""
    if idx.get("kind") != STORE_KIND:
        raise ValueError(
            f"not an array-store stream (footer kind {idx.get('kind')!r}); "
            "chunked streams load via SZxCodec.load_chunked, tree streams "
            "via TreeCodec.decompress_tree"
        )
    if idx.get("store_v", 0) > STORE_VERSION:
        raise ValueError(
            f"unsupported array-store version {idx.get('store_v')}"
        )
    spec = plan_mod.spec_for_code(int(idx["dtype"]))
    shape = tuple(int(d) for d in idx["shape"])
    chunk_shape = tuple(int(c) for c in idx["chunk_shape"])
    grid = ChunkGrid(shape, chunk_shape)
    frames = idx["frames"]
    if len(frames) != grid.nchunks:
        raise ValueError(
            f"corrupt store index ({len(frames)} frames for {grid.nchunks} "
            "chunks)"
        )
    total = sum(int(f[2]) for f in frames)
    if total != math.prod(shape):
        raise ValueError(
            f"corrupt store index (frames cover {total} elements, shape "
            f"needs {math.prod(shape)})"
        )
    return grid, spec, int(idx["block_size"]), float(idx["e"])


# --------------------------------------------------------------- sharded stores
#
# A sharded store is a JSON MANIFEST plus N ordinary shard files.  Each shard
# file holds a CONTIGUOUS range of the grid's chunk frames, written with their
# GLOBAL sequence numbers (so the per-frame seq==chunk-id validation of
# ``container.read_frame_at`` holds unchanged), closed by a footer of kind
# ``"szx-store-shard"``.  The manifest schema (docs/FORMAT.md):
#
#     {
#       "kind": "szx-store-manifest", "manifest_v": 1, "store_v": 1,
#       "shape": [...], "chunk_shape": [...],
#       "dtype": <dtype code>, "block_size": <int>, "e": <absolute bound>,
#       "shards": [
#         {"file": <relative path or URL>,
#          "chunks": [lo, hi),                  # global chunk-id range
#          "frames": [[offset, length, elements], ...]},  # SHARD-local offsets
#         ...
#       ],
#       "attrs": {...},
#     }
#
# Shard ranges partition [0, nchunks) in order; concatenating the shards'
# ``frames`` lists yields exactly the single-file footer's frames list (up to
# the offset rebasing), so a manifest open needs NO reads from the shard
# files themselves.

def build_store_manifest(
    grid: ChunkGrid,
    dtype_code: int,
    block_size: int,
    e: float,
    shards: list[dict],
    attrs: dict | None = None,
    *,
    stage: str | None = None,
) -> dict:
    man = {
        "kind": MANIFEST_KIND,
        "manifest_v": MANIFEST_VERSION,
        "store_v": STORE_VERSION,
        "shape": list(grid.shape),
        "chunk_shape": list(grid.chunk_shape),
        "dtype": int(dtype_code),
        "block_size": int(block_size),
        "e": float(e),
        "shards": shards,
        "attrs": dict(attrs or {}),
    }
    if stage is not None:
        man["stage"] = stage
    return man


def build_shard_index(
    grid: ChunkGrid,
    dtype_code: int,
    block_size: int,
    e: float,
    chunk_range: tuple[int, int],
    frames: list[list[int]],
    attrs: dict | None = None,
) -> dict:
    """Footer of ONE shard file: the store schema plus its chunk range."""
    lo, hi = chunk_range
    if len(frames) != hi - lo:
        raise ValueError(
            f"shard index needs one frame per owned chunk ({hi - lo}), got "
            f"{len(frames)}"
        )
    from repro.core.codec import container

    return {
        "v": container.INDEX_VERSION,
        "kind": STORE_SHARD_KIND,
        "store_v": STORE_VERSION,
        "shape": list(grid.shape),
        "chunk_shape": list(grid.chunk_shape),
        "dtype": int(dtype_code),
        "block_size": int(block_size),
        "e": float(e),
        "chunks": [int(lo), int(hi)],
        "frames": frames,
        "attrs": dict(attrs or {}),
    }


def validate_store_manifest(
    man: dict,
) -> tuple[ChunkGrid, object, int, float, list[dict]]:
    """Check a parsed manifest dict; returns
    ``(grid, dtype_spec, block_size, e, shards)``."""
    if man.get("kind") != MANIFEST_KIND:
        raise ValueError(
            f"not a store manifest (kind {man.get('kind')!r})"
        )
    if man.get("manifest_v", 0) > MANIFEST_VERSION:
        raise ValueError(
            f"unsupported store-manifest version {man.get('manifest_v')}"
        )
    spec = plan_mod.spec_for_code(int(man["dtype"]))
    grid = ChunkGrid(
        tuple(int(d) for d in man["shape"]),
        tuple(int(c) for c in man["chunk_shape"]),
    )
    shards = man["shards"]
    nxt = 0
    total = 0
    for sh in shards:
        lo, hi = (int(v) for v in sh["chunks"])
        if lo != nxt or hi <= lo:
            raise ValueError(
                f"corrupt manifest (shard ranges must partition "
                f"[0, {grid.nchunks}) in order; got [{lo}, {hi}) after {nxt})"
            )
        if len(sh["frames"]) != hi - lo:
            raise ValueError(
                f"corrupt manifest (shard [{lo}, {hi}) lists "
                f"{len(sh['frames'])} frames for {hi - lo} chunks)"
            )
        total += sum(int(f[2]) for f in sh["frames"])
        nxt = hi
    if nxt != grid.nchunks:
        raise ValueError(
            f"corrupt manifest (shards cover {nxt} of {grid.nchunks} chunks)"
        )
    if total != math.prod(grid.shape):
        raise ValueError(
            f"corrupt manifest (frames cover {total} elements, shape needs "
            f"{math.prod(grid.shape)})"
        )
    return grid, spec, int(man["block_size"]), float(man["e"]), shards
