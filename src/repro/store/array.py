"""Block-addressable compressed array store: save/open + lazy ROI reads.

``ArrayStore.save`` writes an N-d array as a grid of independently
addressable SZx chunks (one container-v3 frame per chunk, footer =
block-grid index); ``ArrayStore.open`` returns a lazy :class:`CompressedArray`
whose ``__getitem__`` decodes ONLY the chunks -- and within each chunk only
the contiguous SZx block range -- intersecting the requested ROI.

The read path is two-phase per intersecting chunk: (1) read the chunk's
metadata prefix (stream header, const bitmap, mu, reqlen, L codes -- a few
percent of the chunk) and (2) read exactly the mid-byte range of the
intersecting blocks.  Bytes read therefore scale with the ROI, never the
array, and non-intersecting chunks are never even parsed.

Arrays larger than one file shard across files: ``ArrayStore.save_sharded``
writes N shard files plus a JSON manifest (chunk-coord ranges -> shard
files); ``ArrayStore.open`` on the manifest path reads transparently across
the shards -- same chunk frames, same bytes per chunk, same API.
"""
from __future__ import annotations

import json
import math
import os
from typing import Iterator

import numpy as np

from repro import obs
from repro.core.codec import container, plan as plan_mod, transform
from repro.core.codec.szx_codec import SZxCodec, _imap_ordered
from repro.store import format as format_mod, grid as grid_mod, query as query_mod
from repro.store.grid import ChunkGrid

DEFAULT_STORE_CHUNK_BYTES = grid_mod.DEFAULT_CHUNK_TARGET_BYTES


def _resolve_stage_name(stage) -> str | None:
    """Validate a ``stage=`` save option up front; returns the canonical
    stage name (or None).  Unknown stages and stages whose optional
    dependency is missing raise BEFORE any bytes are written."""
    if stage is None:
        return None
    from repro.core.codec import stage as stage_mod

    code = stage_mod.resolve(stage)
    return stage_mod.name_of(code) if code else None


class ArrayStore:
    """Namespace front-end: ``ArrayStore.save(...)`` / ``ArrayStore.open(...)``."""

    @staticmethod
    def save(
        path_or_file,
        arr,
        bound=None,
        *,
        mode: str | None = None,
        chunk_shape: tuple[int, ...] | None = None,
        chunk_bytes: int = DEFAULT_STORE_CHUNK_BYTES,
        block_size: int = plan_mod.DEFAULT_BLOCK_SIZE,
        backend: str = "numpy",
        workers: int = 1,
        attrs: dict | None = None,
        stage: str | int | None = None,
        error_bound: float | None = None,
    ) -> dict:
        """Write ``arr`` as a chunk-grid store stream; returns the index dict.

        ``bound`` is a :class:`repro.api.Bound` or a bare float (meaning
        ``Bound.abs``); it is resolved ONCE over the full array (so
        ``Bound.rel`` means the same thing it does monolithically), then
        every chunk is compressed independently at that absolute bound --
        each chunk payload is bit-identical to ``SZxCodec.compress`` of that
        chunk.  ``workers > 1`` compresses chunk bodies on a thread pool;
        the bytes on disk are identical for every worker count.  The legacy
        ``(error_bound, mode=)`` kwargs still work (``DeprecationWarning``).
        """
        b = plan_mod.as_bound(bound, mode, error_bound=error_bound,
                              owner="ArrayStore.save")
        stage_name = _resolve_stage_name(stage)
        arr = np.asarray(arr)
        if arr.ndim == 0:
            raise ValueError("0-d arrays are not storable; reshape to (1,)")
        if arr.size == 0:
            raise ValueError("empty arrays are not storable")
        spec = plan_mod.spec_for(arr.dtype)     # TypeError on non-float dtypes
        grid = ChunkGrid.for_shape(
            arr.shape, chunk_shape, itemsize=spec.itemsize,
            target_bytes=chunk_bytes,
        )
        e = plan_mod.resolve_error_bound(arr, b, spec=spec)
        payloads = _chunk_payloads(
            arr, grid, e, block_size=block_size, backend=backend,
            workers=workers,
        )
        f, own = _as_file(path_or_file, "wb")
        try:
            written = 0
            frames: list[list[int]] = []
            for cid, pl in enumerate(payloads):
                frame = container.build_frame(
                    pl, cid, last=cid == grid.nchunks - 1, stage=stage_name,
                )
                frames.append([
                    written, len(frame),
                    grid.chunk_elements(grid.chunk_coord(cid)),
                ])
                f.write(frame)
                written += len(frame)
            idx = format_mod.build_store_index(
                grid, spec.code, block_size, e, frames, attrs,
                stage=stage_name,
            )
            f.write(container.build_index_footer(idx))
        finally:
            if own:
                f.close()
        return idx

    @staticmethod
    def save_sharded(
        manifest_path,
        arr,
        bound=None,
        *,
        nshards: int = 2,
        mode: str | None = None,
        chunk_shape: tuple[int, ...] | None = None,
        chunk_bytes: int = DEFAULT_STORE_CHUNK_BYTES,
        block_size: int = plan_mod.DEFAULT_BLOCK_SIZE,
        backend: str = "numpy",
        workers: int = 1,
        attrs: dict | None = None,
        stage: str | int | None = None,
        error_bound: float | None = None,
    ) -> dict:
        """Write ``arr`` as ``nshards`` shard files plus a JSON manifest at
        ``manifest_path``; returns the manifest dict.

        Chunk ids partition into contiguous balanced ranges, one per shard;
        every chunk frame carries its GLOBAL sequence number and is
        byte-identical to the frame :meth:`save` would write, so a sharded
        store serves exactly the same bytes per chunk as its single-file
        equivalent.  Shard files land next to the manifest as
        ``<stem>.shard-NNN.szs`` and each closes with its own
        ``szx-store-shard`` footer (self-describing even without the
        manifest).
        """
        b = plan_mod.as_bound(bound, mode, error_bound=error_bound,
                              owner="ArrayStore.save_sharded")
        stage_name = _resolve_stage_name(stage)
        arr = np.asarray(arr)
        if arr.ndim == 0:
            raise ValueError("0-d arrays are not storable; reshape to (1,)")
        if arr.size == 0:
            raise ValueError("empty arrays are not storable")
        spec = plan_mod.spec_for(arr.dtype)
        grid = ChunkGrid.for_shape(
            arr.shape, chunk_shape, itemsize=spec.itemsize,
            target_bytes=chunk_bytes,
        )
        e = plan_mod.resolve_error_bound(arr, b, spec=spec)
        if not 1 <= nshards <= grid.nchunks:
            raise ValueError(
                f"nshards {nshards} out of range [1, {grid.nchunks}] "
                f"(one shard needs at least one chunk)"
            )
        payloads = _chunk_payloads(
            arr, grid, e, block_size=block_size, backend=backend,
            workers=workers,
        )
        manifest_path = os.fspath(manifest_path)
        stem = manifest_path[:-5] if manifest_path.endswith(".json") \
            else manifest_path
        base = os.path.dirname(manifest_path)
        bounds = [i * grid.nchunks // nshards for i in range(nshards + 1)]
        shards: list[dict] = []
        it = iter(payloads)
        for si, (lo, hi) in enumerate(zip(bounds, bounds[1:])):
            shard_path = f"{stem}.shard-{si:03d}.szs"
            frames: list[list[int]] = []
            with open(shard_path, "wb") as f:
                written = 0
                for cid in range(lo, hi):
                    # global seq; LAST closes each shard's frame sequence
                    frame = container.build_frame(
                        next(it), cid, last=cid == hi - 1, stage=stage_name,
                    )
                    frames.append([
                        written, len(frame),
                        grid.chunk_elements(grid.chunk_coord(cid)),
                    ])
                    f.write(frame)
                    written += len(frame)
                f.write(container.build_index_footer(
                    format_mod.build_shard_index(
                        grid, spec.code, block_size, e, (lo, hi), frames, attrs
                    )
                ))
            shards.append({
                "file": os.path.relpath(shard_path, base) if base
                else os.path.basename(shard_path),
                "chunks": [lo, hi],
                "frames": frames,
            })
        man = format_mod.build_store_manifest(
            grid, spec.code, block_size, e, shards, attrs, stage=stage_name,
        )
        with open(manifest_path, "w") as f:
            json.dump(man, f)
        return man

    @staticmethod
    def open(
        path_or_file, *, backend: str = "numpy", device: bool = False,
        cache=None, cache_ns: str | None = None,
    ) -> "CompressedArray":
        """Open a store stream lazily: reads ONLY the index footer.

        A ``*.json`` path (or a parsed manifest dict) opens a SHARDED store:
        the manifest alone carries every frame byte range, so no shard file
        is read until a chunk is actually decoded.  ``device=True`` opts ROI
        reads into the device-resident range decode (one ``jax.device_put``
        of prefix+mid bytes per touched chunk, fused on-device
        unpack+compose -- see ``codec.device.decode_range``); requires a
        device backend ('jax'/'kernel').  ``cache`` (a mapping-like object
        with ``get(key)``/``put(key, value, nbytes)``, e.g.
        ``repro.serve.service.cache.LRUBytesCache``) memoizes decoded chunk
        ranges under namespace ``cache_ns``.
        """
        if isinstance(path_or_file, dict):
            return ArrayStore._open_manifest(
                path_or_file, base=".", backend=backend, device=device,
                cache=cache, cache_ns=cache_ns or "<manifest>",
            )
        if isinstance(path_or_file, (str, os.PathLike)) \
                and os.fspath(path_or_file).endswith(".json"):
            path = os.fspath(path_or_file)
            with open(path) as f:
                man = json.load(f)
            return ArrayStore._open_manifest(
                man, base=os.path.dirname(path) or ".", backend=backend,
                device=device, cache=cache, cache_ns=cache_ns or path,
            )
        f, own = _as_file(path_or_file, "rb")
        try:
            idx = container.read_index_footer(f)
        except Exception:
            if own:
                f.close()
            raise
        if idx is None:
            if own:
                f.close()
            raise ValueError(
                "not an array-store stream (no container-v3 index footer)"
            )
        try:
            return CompressedArray(
                f, idx, backend=backend, own_file=own, device=device,
                cache=cache,
                cache_ns=cache_ns if cache_ns is not None
                else str(path_or_file),
            )
        except Exception:
            if own:
                f.close()
            raise

    @staticmethod
    def _open_manifest(man: dict, *, base: str, backend: str, device: bool,
                       cache, cache_ns: str) -> "CompressedArray":
        grid, spec, block_size, e, shards = \
            format_mod.validate_store_manifest(man)
        files: list = []
        frame_src: list[int] = []
        frames: list[list[int]] = []
        try:
            for si, sh in enumerate(shards):
                loc = sh["file"]
                if "://" in str(loc):
                    raise ValueError(
                        f"shard {si} lives at {loc!r}: remote shards are "
                        "served by the store service (which proxies or "
                        "redirects); ArrayStore.open needs local files"
                    )
                files.append(open(os.path.join(base, str(loc)), "rb"))
                frames.extend(sh["frames"])
                frame_src.extend([si] * len(sh["frames"]))
        except Exception:
            for f in files:
                f.close()
            raise
        idx = format_mod.build_store_index(
            grid, spec.code, block_size, e, frames, man.get("attrs"),
            stage=man.get("stage"),
        )
        try:
            return CompressedArray(
                files[0], idx, backend=backend, own_file=True, device=device,
                shard_files=files, frame_src=frame_src,
                cache=cache, cache_ns=cache_ns,
            )
        except Exception:
            for f in files:
                f.close()
            raise


def _as_file(path_or_file, fallback_mode):
    if isinstance(path_or_file, (str, os.PathLike)):
        return open(path_or_file, fallback_mode), True
    return path_or_file, False


def _chunk_payloads(arr, grid: ChunkGrid, e: float, *, block_size: int,
                    backend: str, workers: int) -> Iterator[bytes]:
    """Compressed payload per chunk id, in id order (shared by save and
    save_sharded, so both write bit-identical per-chunk payloads)."""
    codec = SZxCodec(block_size=block_size, backend=backend, workers=workers)

    def payload(cid: int) -> bytes:
        coord = grid.chunk_coord(cid)
        box = tuple(slice(lo, hi) for lo, hi in grid.chunk_box(coord))
        chunk = np.ascontiguousarray(arr[box]).reshape(-1)
        return codec.compress(chunk, e)

    cids = range(grid.nchunks)
    if workers > 1 and grid.nchunks > 1:
        return _imap_ordered(payload, cids, workers)
    return map(payload, cids)


class CompressedArray:
    """Lazy view of a stored array: numpy-style ROI reads + compressed-domain
    queries, decoding only what each request touches.

    Supports ints, step-1 slices, and Ellipsis in ``__getitem__`` (every ROI
    is a hyperrectangle; ``ca[...]`` materializes the whole array).  Queries
    (:meth:`mean`/:meth:`min`/:meth:`max`/:meth:`sum`) run straight on the
    compressed stream -- see :mod:`repro.store.query`.  Instances are not
    thread-safe (one shared seek cursor); concurrent readers each ``open``
    their own.
    """

    def __init__(self, fileobj, idx: dict, *, backend: str = "numpy",
                 own_file: bool = False, device: bool = False,
                 shard_files: list | None = None,
                 frame_src: list[int] | None = None,
                 cache=None, cache_ns: str = "", seq_base: int = 0):
        grid, spec, block_size, e = format_mod.validate_store_index(idx)
        if device:
            from repro.kernels import ops

            if ops._resolve(backend) == "numpy":
                raise ValueError(
                    "device=True needs a device backend ('jax'/'kernel'), "
                    f"got {backend!r}"
                )
        self._f = fileobj
        self._files = list(shard_files) if shard_files is not None else [fileobj]
        self._frame_src = frame_src    # None -> every frame lives in _files[0]
        self._grid = grid
        self._spec = spec
        self._block_size = block_size
        self._e = e
        self._frames = idx["frames"]
        self._backend = backend
        self._own = own_file
        self._device = device
        self._closed = False
        self._cache = cache
        self._cache_ns = cache_ns
        # frame seq numbers are validated as seq_base + chunk_id: a view
        # synthesized over a SLICE of a larger container's frame sequence
        # (e.g. CheckpointManager.leaf_store over one leaf's chunk frames
        # inside tree.szt, which carry global seqs) sets seq_base to the
        # first frame's global sequence number
        self._seq_base = int(seq_base)
        self.attrs = dict(idx.get("attrs") or {})
        # advisory writer-side stage name (per-chunk truth is in frame flags)
        self.stage = idx.get("stage")

    def _src(self, cid: int):
        """File object holding chunk ``cid``'s frame (sharded stores map
        chunk ranges to shard files; frame offsets are file-local)."""
        return self._files[self._frame_src[cid]] if self._frame_src \
            else self._files[0]

    # ------------------------------------------------------------- metadata
    @property
    def shape(self) -> tuple[int, ...]:
        return self._grid.shape

    @property
    def chunk_shape(self) -> tuple[int, ...]:
        return self._grid.chunk_shape

    @property
    def dtype(self) -> np.dtype:
        return self._spec.np_dtype

    @property
    def ndim(self) -> int:
        return len(self._grid.shape)

    @property
    def size(self) -> int:
        return math.prod(self._grid.shape)

    @property
    def nbytes(self) -> int:
        return self.size * self._spec.itemsize

    @property
    def error_bound(self) -> float:
        return self._e

    @property
    def nchunks(self) -> int:
        return self._grid.nchunks

    @property
    def stored_bytes(self) -> int:
        return sum(fr[1] for fr in self._frames)

    def __repr__(self) -> str:
        return (
            f"CompressedArray(shape={self.shape}, dtype={self.dtype.name}, "
            f"chunks={self.chunk_shape}, e={self._e:g}, "
            f"CR={self.nbytes / max(self.stored_bytes, 1):.2f})"
        )

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        if not self._closed:
            self._closed = True
            if self._own:
                for f in self._files:
                    f.close()

    def __enter__(self) -> "CompressedArray":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise ValueError("I/O operation on a closed CompressedArray")

    # ------------------------------------------------------------ ROI reads
    def __getitem__(self, key) -> np.ndarray:
        self._check_open()
        roi = grid_mod.normalize_roi(key, self.shape)
        if not obs.enabled():
            return self._read_roi(roi)
        with obs.span("store.read"):
            out = self._read_roi(roi)
        obs.counter("store.roi.reads").inc()
        obs.counter("store.roi.bytes_out").inc(int(out.nbytes))
        return out

    def _read_roi(self, roi) -> np.ndarray:
        out = np.empty(roi.box_shape, self.dtype)
        bs = self._block_size
        track = obs.enabled()
        for cid, local, outr in grid_mod.intersecting_chunks(self._grid, roi):
            if track:
                obs.counter("store.roi.chunks").inc()
            cdims = self._grid.chunk_dims(self._grid.chunk_coord(cid))
            lo_b, hi_b = grid_mod.block_range_for_box(local, cdims, bs)
            seg = self._decode_chunk_range(cid, lo_b, hi_b)
            out_sl = tuple(slice(lo, hi) for lo, hi in outr)
            if all(hi - lo == d for (lo, hi), d in zip(local, cdims)):
                # whole chunk requested: the segment IS the chunk, C order
                out[out_sl] = seg.reshape(cdims)
            else:
                idx = np.ravel_multi_index(
                    np.ix_(*[np.arange(lo, hi) for lo, hi in local]), cdims
                ) - lo_b * bs
                out[out_sl] = seg[idx]
        return out.reshape(roi.out_shape)

    def read(self, key=Ellipsis) -> np.ndarray:
        return self[key]

    def _decode_chunk_range(self, cid: int, lo_b: int, hi_b: int) -> np.ndarray:
        """Decode blocks [lo_b, hi_b) of chunk ``cid`` -> flat values.

        Reads (1) the frame header + stream metadata prefix and (2) exactly
        the mid-byte range of the requested blocks; returns the flat decoded
        values with the final block's padding clipped.  With ``device=True``
        the prefix+mid bytes go through the device-resident range decode
        (the host section parse stays, but only for disk-offset planning).
        An attached ``cache`` memoizes the decoded range (read-only arrays,
        keyed by namespace + chunk + block range).
        """
        if self._cache is not None:
            key = (self._cache_ns, cid, lo_b, hi_b)
            hit = self._cache.get(key)
            if hit is not None:
                if obs.enabled():
                    obs.counter("store.cache.hits").inc()
                return hit
            if obs.enabled():
                obs.counter("store.cache.misses").inc()
            seg = np.asarray(self._decode_chunk_range_uncached(cid, lo_b, hi_b))
            seg.setflags(write=False)       # cached values are shared
            self._cache.put(key, seg, seg.nbytes)
            return seg
        return self._decode_chunk_range_uncached(cid, lo_b, hi_b)

    def _decode_chunk_range_uncached(self, cid: int, lo_b: int,
                                     hi_b: int) -> np.ndarray:
        off, length, elements = (int(v) for v in self._frames[cid])
        f = self._src(cid)
        _flags, plen, sheader = container.read_frame_stream_header_at(
            f, off, cid + self._seq_base
        )
        if container.FRAME_HEADER.size + plen != length:
            raise ValueError("corrupt store index (frame length mismatch)")
        prefix_len = container.stream_prefix_length(sheader)
        if prefix_len > plen:
            raise ValueError("truncated SZx stream (metadata exceeds payload)")
        rest = container._read_exact(f, prefix_len - container.HEADER.size)
        sec = container.parse_stream_sections(
            sheader + rest, backend=self._backend
        )
        if sec.plan.n != elements:
            raise ValueError(
                f"corrupt store index (chunk {cid}: stream has {sec.plan.n} "
                f"elements, index says {elements})"
            )
        hi_b = min(hi_b, sec.plan.nblocks)
        mlo, mhi = sec.mid_range(lo_b, hi_b)
        mid = b""
        if mhi > mlo:
            stage_code = container.stage_of_flags(_flags)
            if stage_code:
                # staged frame: read the stage table + only the segment
                # records covering [lo_b, hi_b) and destage them -- bytes
                # read stay proportional to the ROI, like the raw path
                from repro.core.codec import stage as stage_mod

                mid = stage_mod.read_mid_range(
                    f, off + container.FRAME_HEADER.size + prefix_len,
                    sec, stage_code, lo_b, hi_b,
                )
            else:
                f.seek(off + container.FRAME_HEADER.size + prefix_len + mlo)
                mid = container._read_exact(f, mhi - mlo)
                if obs.enabled():
                    obs.counter("store.roi.mid_bytes_read").inc(mhi - mlo)
        if obs.enabled():
            # staged mid reads are counted (at actual on-disk size) by
            # stage.read_mid_range as codec.stage.roi_bytes_read
            obs.counter("store.roi.prefix_bytes_read").inc(
                container.FRAME_HEADER.size + prefix_len
            )
            obs.counter("store.chunk.decodes").inc()
        if self._device:
            from repro.core.codec import device as device_mod

            flat = device_mod.decode_range(
                sheader + rest, mid, lo_b, hi_b, backend=self._backend
            )
            if flat is not None:
                bs = sec.plan.block_size
                return flat[: min(hi_b * bs, elements) - lo_b * bs]
        enc = container.extract_block_range(
            sec, np.frombuffer(mid, np.uint8), lo_b, hi_b
        )
        flat = np.asarray(transform.decode_blocks(enc, sec.plan)).reshape(-1)
        bs = sec.plan.block_size
        return flat[: min(hi_b * bs, elements) - lo_b * bs]

    # ----------------------------------------------------- compressed queries
    def stats(self, *, header_only: bool = False) -> "query_mod.QueryStats":
        """Aggregate stats straight from the compressed stream.

        Default: exact stats of the decompressed array (constant blocks are
        answered from their headers alone; only non-constant blocks decode).
        ``header_only=True`` never reads plane/mid bytes at all and returns
        guaranteed ``[lo, hi]`` intervals instead (width <= 2*(radius bound
        + e) per non-constant block; exact when every block is constant).
        """
        self._check_open()
        locs = None
        if self._frame_src is not None or self._seq_base:
            locs = [
                (self._src(seq), seq + self._seq_base,
                 int(fr[0]), int(fr[1]), int(fr[2]))
                for seq, fr in enumerate(self._frames)
            ]
        return query_mod.scan_frames(
            self._f, self._frames, backend=self._backend,
            header_only=header_only, locs=locs,
        )

    def mean(self) -> float:
        return self.stats().mean[0]

    def sum(self) -> float:
        return self.stats().sum[0]

    def min(self) -> float:
        return self.stats().min[0]

    def max(self) -> float:
        return self.stats().max[0]
