"""File CLI for the array store.

    python -m repro.store create  IN.bin OUT.szs --shape 256,256,256 \
        --dtype float32 --bound rel:1e-3
    python -m repro.store info    STORE.szs [--json]
    python -m repro.store read    STORE.szs OUT.bin --roi "0:16,:,3"
    python -m repro.store query   STORE.szs [--roi ...] [--header-only] [--json]
    python -m repro.store serve   STORE.szs [--port 8117]

``create`` writes a chunk-grid store from a raw binary array; ``read``
decodes only the requested ROI; ``query`` runs the compressed-domain stats
scan; ``serve`` starts the HTTP slice/query service
(:mod:`repro.serve.store_service`).  Exit code is non-zero on any error.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


from repro.store.grid import parse_roi  # noqa: F401  (compat re-export)


def _shape(text: str) -> tuple[int, ...]:
    return tuple(int(v) for v in text.split(",") if v.strip())


def _cmd_create(args) -> int:
    from repro.core.codec.__main__ import resolve_cli_bound
    from repro.core.codec.tree import np_dtype_for
    from repro.store import ArrayStore

    dtype = np_dtype_for(args.dtype)
    data = np.fromfile(args.input, dtype=dtype)
    shape = _shape(args.shape)
    data = data.reshape(shape)
    kw = dict(
        chunk_shape=_shape(args.chunk_shape) if args.chunk_shape else None,
        block_size=args.block_size, backend=args.backend, workers=args.workers,
        stage=args.stage,
    )
    if args.shards:
        man = ArrayStore.save_sharded(
            args.output, data, resolve_cli_bound(args), nshards=args.shards,
            **kw,
        )
        frames = [fr for sh in man["shards"] for fr in sh["frames"]]
        chunk_shape, e = man["chunk_shape"], man["e"]
        where = f"{len(man['shards'])} shard files + manifest"
    else:
        idx = ArrayStore.save(args.output, data, resolve_cli_bound(args), **kw)
        frames, chunk_shape, e = idx["frames"], idx["chunk_shape"], idx["e"]
        where = "1 file"
    stored = sum(f[1] for f in frames)
    print(
        f"{args.input}: {data.nbytes} -> {stored} bytes in "
        f"{len(frames)} chunks of {tuple(chunk_shape)} ({where}, "
        f"CR {data.nbytes / max(stored, 1):.2f}, e={e:g})"
    )
    return 0


def _cmd_info(args) -> int:
    from repro.store import ArrayStore

    with ArrayStore.open(args.input) as ca:
        info = {
            "kind": "szx-store",
            "shape": list(ca.shape),
            "chunk_shape": list(ca.chunk_shape),
            "dtype": ca.dtype.name,
            "e": ca.error_bound,
            "nchunks": ca.nchunks,
            "raw_bytes": ca.nbytes,
            "stored_bytes": ca.stored_bytes,
            "cr": ca.nbytes / max(ca.stored_bytes, 1),
            "attrs": ca.attrs,
            "stage": ca.stage,
        }
    if args.json:
        print(json.dumps(info, indent=1))
    else:
        print(
            f"store {tuple(info['shape'])} {info['dtype']} in "
            f"{info['nchunks']} chunks of {tuple(info['chunk_shape'])}, "
            f"e={info['e']:g}, CR={info['cr']:.2f}"
        )
    return 0


def _cmd_read(args) -> int:
    from repro.store import ArrayStore

    with ArrayStore.open(args.input, backend=args.backend) as ca:
        roi = parse_roi(args.roi)
        out = ca[roi]
    out.tofile(args.output)
    print(f"{args.input}[{args.roi or '...'}]: {out.shape} {out.dtype} "
          f"({out.nbytes} bytes) -> {args.output}")
    return 0


def _cmd_query(args) -> int:
    from repro.store import ArrayStore

    with ArrayStore.open(args.input, backend=args.backend) as ca:
        if args.roi:
            # ROI queries decode the (small) region and answer in numpy
            sub = ca[parse_roi(args.roi)].astype(np.float64)
            stats = {
                "count": int(sub.size), "exact": True,
                "sum": [float(sub.sum())] * 2, "mean": [float(sub.mean())] * 2,
                "min": [float(sub.min())] * 2, "max": [float(sub.max())] * 2,
            }
        else:
            stats = ca.stats(header_only=args.header_only).to_dict()
    if args.json:
        print(json.dumps(stats, indent=1))
    elif stats["exact"]:
        print(
            f"count={stats['count']} mean={stats['mean'][0]:.8g} "
            f"min={stats['min'][0]:.8g} max={stats['max'][0]:.8g} "
            f"sum={stats['sum'][0]:.8g}"
        )
    else:
        print(
            f"count={stats['count']} "
            f"mean=[{stats['mean'][0]:.8g}, {stats['mean'][1]:.8g}] "
            f"min=[{stats['min'][0]:.8g}, {stats['min'][1]:.8g}] "
            f"max=[{stats['max'][0]:.8g}, {stats['max'][1]:.8g}]"
        )
    return 0


def _cmd_serve(args) -> int:
    from repro.serve.store_service import serve_store

    serve_store(args.input, host=args.host, port=args.port,
                backend=args.backend)
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.store", description=__doc__.splitlines()[0]
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("create", help="raw binary -> chunk-grid store")
    c.add_argument("input")
    c.add_argument("output")
    c.add_argument("--shape", required=True, help="comma-separated dims")
    c.add_argument("--bound", default=None, metavar="SPEC",
                   help="error bound: '1e-3' (abs), 'abs:1e-3', 'rel:1e-4'")
    c.add_argument("--error-bound", type=float, default=None,
                   help="legacy: ABS bound, or REL factor with --mode rel")
    c.add_argument("--mode", choices=("abs", "rel"), default=None)
    c.add_argument("--dtype", default="float32")
    c.add_argument("--chunk-shape", default=None, help="comma-separated dims")
    c.add_argument("--shards", type=int, default=0,
                   help="write N shard files + a JSON manifest (OUTPUT is "
                        "the manifest path) instead of one store file")
    c.add_argument("--block-size", type=int, default=128)
    c.add_argument("--workers", type=int, default=1)
    c.add_argument("--backend", default="numpy")
    c.add_argument("--stage", default=None,
                   choices=("bitshuffle-rle", "bitshuffle-zstd", "deflate"),
                   help="negotiated lossless second stage over the mid-byte "
                        "section (per-chunk; skipped when it would not shrink)")
    c.set_defaults(fn=_cmd_create)

    i = sub.add_parser("info", help="print store geometry")
    i.add_argument("input")
    i.add_argument("--json", action="store_true")
    i.set_defaults(fn=_cmd_info)

    r = sub.add_parser("read", help="ROI -> raw binary")
    r.add_argument("input")
    r.add_argument("output")
    r.add_argument("--roi", default=None, help='e.g. "0:16,:,3"')
    r.add_argument("--backend", default="numpy")
    r.set_defaults(fn=_cmd_read)

    q = sub.add_parser("query", help="compressed-domain stats")
    q.add_argument("input")
    q.add_argument("--roi", default=None)
    q.add_argument("--header-only", action="store_true",
                   help="interval stats, never reading plane bytes")
    q.add_argument("--json", action="store_true")
    q.add_argument("--backend", default="numpy")
    q.set_defaults(fn=_cmd_query)

    s = sub.add_parser("serve", help="HTTP slice/query service")
    s.add_argument("input")
    s.add_argument("--host", default="127.0.0.1")
    s.add_argument("--port", type=int, default=8117)
    s.add_argument("--backend", default="numpy")
    s.set_defaults(fn=_cmd_serve)

    args = ap.parse_args(argv)
    import struct

    try:
        return args.fn(args)
    except (OSError, ValueError, TypeError, KeyError, IndexError,
            struct.error) as err:
        print(f"error: {err}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    raise SystemExit(main())
