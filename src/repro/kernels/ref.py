"""Pure-jnp oracles for the width-generic SZx block-compression kernels.

These functions are the ground-truth semantics for the Pallas kernels in
``block_stats.py`` / ``pack.py`` / ``unpack.py`` / ``encode.py``.  Everything
here is fixed-shape (the variable-length byte compaction happens at the
host/serialization boundary in ``repro.core.codec.container``), which is what
makes the algorithm expressible on TPU.

Every transform op is parameterized by a :class:`repro.kernels.specs.DtypeSpec`
-- ONE implementation covers float32/float64/float16/bfloat16.  Per-block
statistics run in the spec's *compute dtype* (f32 for words up to 4 bytes,
f64 for float64; the 16-bit formats are exact subsets of f32), the bit-level
split runs on the *storage* word after rounding the normalized residual to the
input dtype.  With ``spec=specs.F32`` the results are bit-identical to the
original float32-only oracles.

Notation follows the paper (Algorithm 1 / Formulas 4-5):
  mu      -- mean of min and max of a block ("mean of min/max", mu_k)
  radius  -- variation radius r_k = max(|max-mu|, |mu-min|)
  reqlen  -- required number of leading IEEE-754 bits: 1 sign + exp_bits
             exponent + R_k mantissa bits, R_k = clip(p(r_k) - p(e) + 1, 0,
             mant_bits).  (+1 is a guard bit so the mu-subtraction rounding
             keeps the bound strict; see DESIGN.md section 2.)
  shift   -- Solution-C right shift s = (8 - reqlen % 8) % 8 (Formula 5)
  nbytes  -- bytes kept per value = (reqlen + shift) / 8; 0 marks a constant
             block.
  L       -- identical-leading-byte count vs. the predecessor (2-bit code,
             capped at min(3, itemsize)); predecessor of the first value in a
             block is the zero word (blocks are independently decodable, as in
             the GPU design).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import specs
from repro.kernels.specs import DtypeSpec

F32_EXP_BIAS = 127


def float_exponent(x, spec: DtypeSpec):
    """Biased-removed binary exponent field of |x| in the spec's COMPUTE dtype.

    floor(log2|x|) for compute-dtype normals; ``-compute_exp_bias`` for
    zero/subnormals (conservative: a too-large exponent keeps more bits).
    """
    c = jnp.asarray(x, spec.compute_np_dtype)
    bits = jax.lax.bitcast_convert_type(c, spec.compute_uint_dtype)
    field = (bits >> spec.compute_mant_bits) & ((1 << spec.compute_exp_bits) - 1)
    return field.astype(jnp.int32) - spec.compute_exp_bias


def f32_exponent(x):
    """Back-compat alias: exponent field of float32 |x|."""
    return float_exponent(x, specs.F32)


def block_stats_ref(xb: jax.Array, e, spec: DtypeSpec = specs.F32, p_e=None) -> tuple:
    """Per-block statistics (paper Alg. 1 lines 3-7), width-generic.

    xb: (nb, bs) in the spec's dtype (or castable).  e: scalar absolute error
    bound (> 0).  p_e: optional exact floor(log2 e) (int32 scalar); computed
    from the compute-dtype exponent field of e when absent.
    Returns (mu, radius, const, reqlen, shift, nbytes); mu is (nb,) in the
    spec's dtype, radius in the compute dtype, the rest int32/bool (nb,)-shaped
    with reqlen/shift/nbytes 0 for constant blocks.
    """
    cdt = spec.compute_np_dtype
    x = jnp.asarray(xb, spec.np_dtype).astype(cdt)
    e = jnp.asarray(e, cdt)
    mn = jnp.min(x, axis=-1)
    mx = jnp.max(x, axis=-1)
    mu = (0.5 * (mn + mx)).astype(spec.np_dtype)   # storage-rounded mu
    mu_w = mu.astype(cdt)                          # exact widening
    # radius vs the ROUNDED mu: the constant-block test then already covers
    # the mu storage rounding of the narrow dtypes
    radius = jnp.maximum(mx - mu_w, mu_w - mn)
    r_test = radius
    if spec.stats_rounding_guard:
        # 16-bit formats: the f32 subtraction can round BELOW the true block
        # deviation (<= 0.5 ulp); testing the next-up radius keeps the bound
        # strict (see DtypeSpec.stats_rounding_guard)
        bits = jax.lax.bitcast_convert_type(radius, spec.compute_uint_dtype) + 1
        r_test = jax.lax.bitcast_convert_type(bits, cdt)
    const = r_test <= e
    if p_e is None:
        p_e = float_exponent(e, spec)
    req_m_raw = float_exponent(radius, spec) - jnp.asarray(p_e, jnp.int32) + 1
    req_m = jnp.clip(req_m_raw, 0, spec.mant_bits)
    # Verbatim blocks (beyond-paper robustness): if the bound is below the
    # ulp of the normalized values (req_m_raw > mant_bits), the mu-subtraction
    # rounding alone can break the bound, so store the block bit-exactly by
    # normalizing against mu = 0.  Real SZx silently violates the bound here.
    mu = jnp.where(req_m_raw > spec.mant_bits, jnp.zeros_like(mu), mu)
    reqlen = 1 + spec.exp_bits + req_m      # 1 sign + exponent + R_k mantissa
    shift = (8 - reqlen % 8) % 8            # Formula (5), Solution C
    nbytes = (reqlen + shift) // 8
    zero = jnp.zeros_like(reqlen)
    return (
        mu,
        radius,
        const,
        jnp.where(const, zero, reqlen),
        jnp.where(const, zero, shift),
        jnp.where(const, zero, nbytes),
    )


def pack_ref(xb: jax.Array, mu: jax.Array, shift: jax.Array, nbytes: jax.Array,
             spec: DtypeSpec = specs.F32):
    """Normalize, right-shift (Solution C), XOR-lead, and byte-plane split.

    xb: (nb, bs) spec dtype; mu: (nb,) spec dtype; shift/nbytes: (nb,) int32.
    Returns:
      planes: (nb, itemsize, bs) uint8 -- byte j of the shifted word (0 = most
              significant).  Fixed shape; the serializer keeps only bytes with
              L <= j < nbytes.
      L:      (nb, bs) int32 -- identical leading bytes vs. predecessor,
              clipped to [0, min(lead_cap, nbytes)].
      mid:    (nb, bs) int32 -- mid-bytes to store per value (nbytes - L).
    """
    cdt = spec.compute_np_dtype
    udt = spec.uint_dtype
    x = jnp.asarray(xb, spec.np_dtype).astype(cdt)
    mu_w = jnp.asarray(mu, spec.np_dtype).astype(cdt)
    v = (x - mu_w[:, None]).astype(spec.np_dtype)  # storage-rounded residual
    w = jax.lax.bitcast_convert_type(v, udt)
    ws = w >> shift[:, None].astype(udt)
    prev = jnp.concatenate(
        [jnp.zeros((ws.shape[0], 1), udt), ws[:, :-1]], axis=1
    )
    xw = ws ^ prev
    # leading identical bytes vs predecessor (cumulative AND over MSB-first
    # byte equality), capped by the 2-bit code / word width at lead_cap
    L = jnp.zeros(ws.shape, jnp.int32)
    run = jnp.ones(ws.shape, bool)
    for j in range(spec.lead_cap):
        run = run & ((xw >> jnp.asarray(8 * (spec.itemsize - 1 - j), udt)) == 0)
        L = L + run.astype(jnp.int32)
    L = jnp.minimum(L, nbytes[:, None])
    planes = jnp.stack(
        [
            ((ws >> jnp.asarray(8 * (spec.itemsize - 1 - j), udt))
             & jnp.asarray(0xFF, udt)).astype(jnp.uint8)
            for j in range(spec.itemsize)
        ],
        axis=1,
    )
    mid = nbytes[:, None] - L
    return planes, L, mid


def encode_ref(xb: jax.Array, e, spec: DtypeSpec = specs.F32, p_e=None):
    """Fused block_stats + pack: one traced program, one device round trip.

    Returns (mu, const, reqlen, shift, nbytes, planes, L) -- exactly the
    fields the container layer serializes.  Bit-identical to calling
    :func:`block_stats_ref` then :func:`pack_ref`.
    """
    mu, _radius, const, reqlen, shift, nbytes = block_stats_ref(xb, e, spec, p_e)
    planes, L, _mid = pack_ref(xb, mu, shift, nbytes, spec)
    return mu, const, reqlen, shift, nbytes, planes, L


def _compose_word(ws, mu, shift, nbytes, spec: DtypeSpec):
    """Shift the reassembled word back, bitcast, and re-add mu (in the
    compute dtype, rounded to storage); constant blocks decode to mu."""
    w = ws << shift[:, None].astype(spec.uint_dtype)
    v = jax.lax.bitcast_convert_type(w, spec.np_dtype)
    mu_w = jnp.asarray(mu, spec.np_dtype).astype(spec.compute_np_dtype)
    x = (v.astype(spec.compute_np_dtype) + mu_w[:, None]).astype(spec.np_dtype)
    return jnp.where((nbytes == 0)[:, None], jnp.asarray(mu, spec.np_dtype)[:, None], x)


def unpack_ref(planes, mu, shift, nbytes, L, spec: DtypeSpec = specs.F32):
    """Inverse of pack_ref.

    Reconstructs each byte either from the stored plane entry or, for the L
    leading bytes, from the most recent predecessor that stored that plane --
    the paper's GPU "index propagation" realized as a cumulative max
    (associative scan) along the block.  Planes past the lead cap (L <= 3)
    are always stored for live blocks, so they skip the scan entirely.
    Returns (nb, bs) reconstruction in the spec's dtype (mu for constant
    blocks).
    """
    nb, _, bs = planes.shape
    udt = spec.uint_dtype
    idxs = jnp.broadcast_to(jnp.arange(bs, dtype=jnp.int32)[None, :], (nb, bs))
    ws = jnp.zeros((nb, bs), udt)
    for j in range(spec.itemsize):
        sh = jnp.asarray(8 * (spec.itemsize - 1 - j), udt)
        live = j < nbytes[:, None]
        if j >= spec.lead_cap:
            # L <= lead_cap <= j: every live value stores this plane itself
            byte = jnp.where(live, planes[:, j, :].astype(udt), jnp.asarray(0, udt))
            ws = ws | (byte << sh)
            continue
        stored = (L <= j) & live
        src = jnp.where(stored, idxs, -1)
        src = jax.lax.cummax(src, axis=1)              # index propagation
        byte = jnp.take_along_axis(
            planes[:, j, :].astype(udt), jnp.maximum(src, 0), axis=1
        )
        byte = jnp.where(src >= 0, byte, jnp.asarray(0, udt))
        ws = ws | (byte << sh)
    return _compose_word(ws, mu, shift, nbytes, spec)


def unpack_dense_ref(planes, mu, shift, nbytes, spec: DtypeSpec = specs.F32):
    """``unpack_ref`` specialized to all-zero L codes (no XOR-lead elision).

    With L == 0 every live plane byte (j < nbytes) is stored at its own value,
    so the index-propagation scan degenerates to a masked byte composition.
    Bit-identical to ``unpack_ref(planes, mu, shift, nbytes, L=0)``.
    """
    nb, _, bs = planes.shape
    udt = spec.uint_dtype
    ws = jnp.zeros((nb, bs), udt)
    for j in range(spec.itemsize):
        live = (nbytes > j)[:, None]
        byte = jnp.where(live, planes[:, j, :].astype(udt), jnp.asarray(0, udt))
        ws = ws | (byte << jnp.asarray(8 * (spec.itemsize - 1 - j), udt))
    return _compose_word(ws, mu, shift, nbytes, spec)


# ---------------------------------------------------------------------------
# Device-resident stream decode: the inverse of core.codec.device assembly.
# ---------------------------------------------------------------------------

def parse_body_ref(body, nnc, spec: DtypeSpec, nb: int):
    """On-device parse of the v2 metadata sections from raw stream bytes.

    ``body`` is the stream minus its 40-byte header -- ONE uint8 vector,
    zero-padded to a static capacity so chunk geometry (not payload size)
    decides the compiled program.  ``nnc`` is the header's n_nonconst field
    (traced scalar).  Section offsets are derived here exactly as the host
    serializer lays them out: ``[const bitmap][mu words][compacted reqlen]``.

    Returns (const, mu, shift, nbytes, rank, nnc_seen): per-block metadata
    (rank = compacted index of each non-const block, -1 for const) plus the
    bitmap's own nonconst count -- compared against the header's ``nnc`` on
    the host after the single readback (corrupt-stream validation).
    """
    W = spec.itemsize
    nbm = (nb + 7) // 8
    req_off = nbm + W * nb
    # const bitmap, MSB-first (numpy packbits order)
    bits = (body[:nbm][:, None] >> jnp.arange(7, -1, -1, dtype=jnp.uint8)) & 1
    const = bits.reshape(-1)[:nb].astype(bool)
    # mu words: little-endian bytes, the exact inverse of the encode-side
    # bitcast_convert_type(mu, uint8) scatter
    mu = jax.lax.bitcast_convert_type(
        body[nbm:req_off].reshape(nb, W), spec.np_dtype
    )
    nonconst = ~const
    incl = jnp.cumsum(nonconst.astype(jnp.int32))
    rank = jnp.where(nonconst, incl - 1, -1)
    ridx = jnp.clip(req_off + rank, 0, body.shape[0] - 1)
    reqlen = jnp.where(nonconst, body[ridx].astype(jnp.int32), 0)
    # layout derivation (Formula 5, Solution C) -- same as derive_layout
    shift = jnp.where(const, 0, (8 - reqlen % 8) % 8)
    nbytes = (reqlen + shift) // 8
    return const, mu, shift, nbytes, rank, incl[-1]


def decode_body_ref(body, nnc, lo, mu, shift, nbytes, rank, spec: DtypeSpec,
                    *, bs: int, rb: int, rebase: bool = False):
    """Fused unpack+compose straight from raw body bytes (decode oracle).

    Expands the compacted 2-bit L codes, derives each value's mid-stream
    offset as the exclusive cumsum of ``nbytes - L``, gathers the stored
    bytes directly out of ``body`` (no intermediate planes array), runs the
    XOR-lead index propagation as a fused-key cummax, and composes via
    :func:`_compose_word`.  ``lo`` is the first decoded block (traced);
    ``rb`` (static) blocks are produced.  ``rebase=True`` reads the mid
    section as starting at block ``lo``'s first mid byte -- the store ROI
    buffer layout (metadata prefix + the requested blocks' mid range).

    Returns (vals (rb, bs) in the spec's dtype, mid_total int32): the
    full-stream mid byte count implied by the L codes, for host-side
    validation against the header's nmid after the single readback.
    """
    W = spec.itemsize
    nb = rank.shape[0]
    nbm = (nb + 7) // 8
    req_off = nbm + W * nb
    l_off = req_off + nnc
    nl = (nnc * bs + 3) // 4
    mid_off = l_off + nl
    cap = body.shape[0]
    # 2-bit L codes: little-endian 4 per byte, compacted over non-const blocks
    pos = rank[:, None] * bs + jnp.arange(bs, dtype=jnp.int32)[None, :]
    live_blk = (rank >= 0)[:, None]
    lidx = jnp.clip(jnp.where(live_blk, l_off + pos // 4, 0), 0, cap - 1)
    code = (body[lidx].astype(jnp.int32) >> ((pos % 4) * 2)) & 3
    L = jnp.where(live_blk, code, 0)
    # mid-stream offsets: exclusive cumsum of per-value stored-byte counts
    counts = jnp.maximum(nbytes[:, None] - L, 0)
    ends = jnp.cumsum(counts.reshape(-1)).reshape(nb, bs)
    start = ends - counts
    mid_total = ends.reshape(-1)[-1]
    base = mid_off - (
        jax.lax.dynamic_slice_in_dim(start, lo, 1, axis=0)[0, 0] if rebase else 0
    )

    def sl(a):
        return jax.lax.dynamic_slice_in_dim(a, lo, rb, axis=0)

    L, start = sl(L), sl(start)
    nbytes_r, shift_r, mu_r = sl(nbytes), sl(shift), sl(mu)
    udt = spec.uint_dtype
    idxs = jnp.broadcast_to(jnp.arange(bs, dtype=jnp.int32)[None, :], (rb, bs))
    ws = jnp.zeros((rb, bs), udt)
    for j in range(W):
        sh = jnp.asarray(8 * (W - 1 - j), udt)
        stored = (L <= j) & (j < nbytes_r[:, None])
        gidx = jnp.clip(jnp.where(stored, base + start + (j - L), 0), 0, cap - 1)
        byte = jnp.where(stored, body[gidx].astype(jnp.int32), 0)
        if j >= spec.lead_cap:
            # L <= lead_cap <= j: every live value stores this plane itself
            ws = ws | (byte.astype(udt) << sh)
            continue
        # fused-key index propagation (idx dominates; the surviving key
        # carries the byte of the nearest preceding stored position)
        key = jnp.where(stored, idxs * 256 + byte, -1)
        key = jax.lax.cummax(key, axis=1)
        b = jnp.where(key >= 0, (key & 0xFF).astype(udt), jnp.asarray(0, udt))
        ws = ws | (b << sh)
    return _compose_word(ws, mu_r, shift_r, nbytes_r, spec), mid_total


# ---------------------------------------------------------------------------
# Fixed-plane ("szx-planes") in-graph mode -- see DESIGN.md section 2.
# ---------------------------------------------------------------------------

def planes_encode_ref(xb, num_planes: int):
    """Error-bounded-by-construction block quantization to `num_planes` bytes.

    xb: (nb, bs) f32.  Returns (mu (nb,) f32, sexp (nb,) int32, planes
    (num_planes, nb, bs) uint8).  q = rint(v * 2^sexp) with sexp chosen from the
    block radius exponent so |q| < 2^(8P-1); reconstruction error is
    <= 2^(E+1-8P) where E = p(radius).
    """
    assert 1 <= num_planes <= 3, "szx-planes supports 1..3 byte planes"
    xb = jnp.asarray(xb, jnp.float32)
    mn = jnp.min(xb, axis=-1)
    mx = jnp.max(xb, axis=-1)
    mu = 0.5 * (mn + mx)
    radius = jnp.maximum(mx - mu, mu - mn)
    E = f32_exponent(radius)
    nbits = 8 * num_planes
    sexp = (nbits - 2) - E
    v = xb - mu[..., None]
    scale = jnp.exp2(sexp.astype(jnp.float32))[..., None]
    lim = jnp.float32(2.0 ** (nbits - 1))
    q = jnp.clip(jnp.round(v * scale), -lim, lim - 1).astype(jnp.int32)
    uq = q.astype(jnp.uint32)
    planes = jnp.stack(
        [((uq >> (8 * p)) & jnp.uint32(0xFF)).astype(jnp.uint8) for p in range(num_planes)],
        axis=0,
    )
    return mu, sexp, planes


def planes_decode_ref(mu, sexp, planes):
    """Inverse of planes_encode_ref -> (..., bs) f32.  num_planes must be <= 3."""
    num_planes = planes.shape[0]
    assert num_planes <= 3, "szx-planes supports 1..3 byte planes"
    nbits = 8 * num_planes
    uq = jnp.zeros(planes.shape[1:], jnp.int32)
    for p in range(num_planes):
        uq = uq | (planes[p].astype(jnp.int32) << (8 * p))
    # sign-extend a width-`nbits` two's-complement integer (fits in int32)
    q = jnp.where(uq >= (1 << (nbits - 1)), uq - (1 << nbits), uq).astype(jnp.float32)
    v = q * jnp.exp2(-sexp.astype(jnp.float32))[..., None]
    return v + mu[..., None]


# ---------------------------------------------------------------------------
# bitplane shuffle (second-stage transform; see repro.kernels.bitshuffle)
# ---------------------------------------------------------------------------

def bitshuffle_ref(tiles, *, inverse: bool = False):
    """Bit-transpose (nt, T) uint8 tiles, T % 8 == 0 (little-endian packing).

    Ground truth for the Pallas kernel in ``bitshuffle.py``: forward places
    bit k of every tile byte contiguously (bit-row k); ``inverse`` undoes it.
    Bit-identical to ``np.unpackbits``/``np.packbits`` with
    ``bitorder='little'`` (pinned by tests against the numpy mirror).
    """
    from repro.kernels.bitshuffle import shuffle_body

    nt, T = tiles.shape
    if T % 8:
        raise ValueError(f"bitshuffle tile width {T} is not a multiple of 8")
    if nt == 0:
        return jnp.zeros((0, T), jnp.uint8)
    return shuffle_body(jnp.asarray(tiles, jnp.uint8), inverse=inverse)
