"""Pure-jnp oracles for the width-generic SZx block-compression kernels.

These functions are the ground-truth semantics for the Pallas kernels in
``block_stats.py`` / ``pack.py`` / ``unpack.py`` / ``encode.py``.  Everything
here is fixed-shape (the variable-length byte compaction happens at the
host/serialization boundary in ``repro.core.codec.container``), which is what
makes the algorithm expressible on TPU.

Every transform op is parameterized by a :class:`repro.kernels.specs.DtypeSpec`
-- ONE implementation covers float32/float64/float16/bfloat16.  Per-block
statistics run in the spec's *compute dtype* (f32 for words up to 4 bytes,
f64 for float64; the 16-bit formats are exact subsets of f32), the bit-level
split runs on the *storage* word after rounding the normalized residual to the
input dtype.  With ``spec=specs.F32`` the results are bit-identical to the
original float32-only oracles.

Notation follows the paper (Algorithm 1 / Formulas 4-5):
  mu      -- mean of min and max of a block ("mean of min/max", mu_k)
  radius  -- variation radius r_k = max(|max-mu|, |mu-min|)
  reqlen  -- required number of leading IEEE-754 bits: 1 sign + exp_bits
             exponent + R_k mantissa bits, R_k = clip(p(r_k) - p(e) + 1, 0,
             mant_bits).  (+1 is a guard bit so the mu-subtraction rounding
             keeps the bound strict; see DESIGN.md section 2.)
  shift   -- Solution-C right shift s = (8 - reqlen % 8) % 8 (Formula 5)
  nbytes  -- bytes kept per value = (reqlen + shift) / 8; 0 marks a constant
             block.
  L       -- identical-leading-byte count vs. the predecessor (2-bit code,
             capped at min(3, itemsize)); predecessor of the first value in a
             block is the zero word (blocks are independently decodable, as in
             the GPU design).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import specs
from repro.kernels.specs import DtypeSpec

F32_EXP_BIAS = 127


def float_exponent(x, spec: DtypeSpec):
    """Biased-removed binary exponent field of |x| in the spec's COMPUTE dtype.

    floor(log2|x|) for compute-dtype normals; ``-compute_exp_bias`` for
    zero/subnormals (conservative: a too-large exponent keeps more bits).
    """
    c = jnp.asarray(x, spec.compute_np_dtype)
    bits = jax.lax.bitcast_convert_type(c, spec.compute_uint_dtype)
    field = (bits >> spec.compute_mant_bits) & ((1 << spec.compute_exp_bits) - 1)
    return field.astype(jnp.int32) - spec.compute_exp_bias


def f32_exponent(x):
    """Back-compat alias: exponent field of float32 |x|."""
    return float_exponent(x, specs.F32)


def block_stats_ref(xb: jax.Array, e, spec: DtypeSpec = specs.F32, p_e=None) -> tuple:
    """Per-block statistics (paper Alg. 1 lines 3-7), width-generic.

    xb: (nb, bs) in the spec's dtype (or castable).  e: scalar absolute error
    bound (> 0).  p_e: optional exact floor(log2 e) (int32 scalar); computed
    from the compute-dtype exponent field of e when absent.
    Returns (mu, radius, const, reqlen, shift, nbytes); mu is (nb,) in the
    spec's dtype, radius in the compute dtype, the rest int32/bool (nb,)-shaped
    with reqlen/shift/nbytes 0 for constant blocks.
    """
    cdt = spec.compute_np_dtype
    x = jnp.asarray(xb, spec.np_dtype).astype(cdt)
    e = jnp.asarray(e, cdt)
    mn = jnp.min(x, axis=-1)
    mx = jnp.max(x, axis=-1)
    mu = (0.5 * (mn + mx)).astype(spec.np_dtype)   # storage-rounded mu
    mu_w = mu.astype(cdt)                          # exact widening
    # radius vs the ROUNDED mu: the constant-block test then already covers
    # the mu storage rounding of the narrow dtypes
    radius = jnp.maximum(mx - mu_w, mu_w - mn)
    r_test = radius
    if spec.stats_rounding_guard:
        # 16-bit formats: the f32 subtraction can round BELOW the true block
        # deviation (<= 0.5 ulp); testing the next-up radius keeps the bound
        # strict (see DtypeSpec.stats_rounding_guard)
        bits = jax.lax.bitcast_convert_type(radius, spec.compute_uint_dtype) + 1
        r_test = jax.lax.bitcast_convert_type(bits, cdt)
    const = r_test <= e
    if p_e is None:
        p_e = float_exponent(e, spec)
    req_m_raw = float_exponent(radius, spec) - jnp.asarray(p_e, jnp.int32) + 1
    req_m = jnp.clip(req_m_raw, 0, spec.mant_bits)
    # Verbatim blocks (beyond-paper robustness): if the bound is below the
    # ulp of the normalized values (req_m_raw > mant_bits), the mu-subtraction
    # rounding alone can break the bound, so store the block bit-exactly by
    # normalizing against mu = 0.  Real SZx silently violates the bound here.
    mu = jnp.where(req_m_raw > spec.mant_bits, jnp.zeros_like(mu), mu)
    reqlen = 1 + spec.exp_bits + req_m      # 1 sign + exponent + R_k mantissa
    shift = (8 - reqlen % 8) % 8            # Formula (5), Solution C
    nbytes = (reqlen + shift) // 8
    zero = jnp.zeros_like(reqlen)
    return (
        mu,
        radius,
        const,
        jnp.where(const, zero, reqlen),
        jnp.where(const, zero, shift),
        jnp.where(const, zero, nbytes),
    )


def pack_ref(xb: jax.Array, mu: jax.Array, shift: jax.Array, nbytes: jax.Array,
             spec: DtypeSpec = specs.F32):
    """Normalize, right-shift (Solution C), XOR-lead, and byte-plane split.

    xb: (nb, bs) spec dtype; mu: (nb,) spec dtype; shift/nbytes: (nb,) int32.
    Returns:
      planes: (nb, itemsize, bs) uint8 -- byte j of the shifted word (0 = most
              significant).  Fixed shape; the serializer keeps only bytes with
              L <= j < nbytes.
      L:      (nb, bs) int32 -- identical leading bytes vs. predecessor,
              clipped to [0, min(lead_cap, nbytes)].
      mid:    (nb, bs) int32 -- mid-bytes to store per value (nbytes - L).
    """
    cdt = spec.compute_np_dtype
    udt = spec.uint_dtype
    x = jnp.asarray(xb, spec.np_dtype).astype(cdt)
    mu_w = jnp.asarray(mu, spec.np_dtype).astype(cdt)
    v = (x - mu_w[:, None]).astype(spec.np_dtype)  # storage-rounded residual
    w = jax.lax.bitcast_convert_type(v, udt)
    ws = w >> shift[:, None].astype(udt)
    prev = jnp.concatenate(
        [jnp.zeros((ws.shape[0], 1), udt), ws[:, :-1]], axis=1
    )
    xw = ws ^ prev
    # leading identical bytes vs predecessor (cumulative AND over MSB-first
    # byte equality), capped by the 2-bit code / word width at lead_cap
    L = jnp.zeros(ws.shape, jnp.int32)
    run = jnp.ones(ws.shape, bool)
    for j in range(spec.lead_cap):
        run = run & ((xw >> jnp.asarray(8 * (spec.itemsize - 1 - j), udt)) == 0)
        L = L + run.astype(jnp.int32)
    L = jnp.minimum(L, nbytes[:, None])
    planes = jnp.stack(
        [
            ((ws >> jnp.asarray(8 * (spec.itemsize - 1 - j), udt))
             & jnp.asarray(0xFF, udt)).astype(jnp.uint8)
            for j in range(spec.itemsize)
        ],
        axis=1,
    )
    mid = nbytes[:, None] - L
    return planes, L, mid


def encode_ref(xb: jax.Array, e, spec: DtypeSpec = specs.F32, p_e=None):
    """Fused block_stats + pack: one traced program, one device round trip.

    Returns (mu, const, reqlen, shift, nbytes, planes, L) -- exactly the
    fields the container layer serializes.  Bit-identical to calling
    :func:`block_stats_ref` then :func:`pack_ref`.
    """
    mu, _radius, const, reqlen, shift, nbytes = block_stats_ref(xb, e, spec, p_e)
    planes, L, _mid = pack_ref(xb, mu, shift, nbytes, spec)
    return mu, const, reqlen, shift, nbytes, planes, L


def _compose_word(ws, mu, shift, nbytes, spec: DtypeSpec):
    """Shift the reassembled word back, bitcast, and re-add mu (in the
    compute dtype, rounded to storage); constant blocks decode to mu."""
    w = ws << shift[:, None].astype(spec.uint_dtype)
    v = jax.lax.bitcast_convert_type(w, spec.np_dtype)
    mu_w = jnp.asarray(mu, spec.np_dtype).astype(spec.compute_np_dtype)
    x = (v.astype(spec.compute_np_dtype) + mu_w[:, None]).astype(spec.np_dtype)
    return jnp.where((nbytes == 0)[:, None], jnp.asarray(mu, spec.np_dtype)[:, None], x)


def unpack_ref(planes, mu, shift, nbytes, L, spec: DtypeSpec = specs.F32):
    """Inverse of pack_ref.

    Reconstructs each byte either from the stored plane entry or, for the L
    leading bytes, from the most recent predecessor that stored that plane --
    the paper's GPU "index propagation" realized as a cumulative max
    (associative scan) along the block.  Planes past the lead cap (L <= 3)
    are always stored for live blocks, so they skip the scan entirely.
    Returns (nb, bs) reconstruction in the spec's dtype (mu for constant
    blocks).
    """
    nb, _, bs = planes.shape
    udt = spec.uint_dtype
    idxs = jnp.broadcast_to(jnp.arange(bs, dtype=jnp.int32)[None, :], (nb, bs))
    ws = jnp.zeros((nb, bs), udt)
    for j in range(spec.itemsize):
        sh = jnp.asarray(8 * (spec.itemsize - 1 - j), udt)
        live = j < nbytes[:, None]
        if j >= spec.lead_cap:
            # L <= lead_cap <= j: every live value stores this plane itself
            byte = jnp.where(live, planes[:, j, :].astype(udt), jnp.asarray(0, udt))
            ws = ws | (byte << sh)
            continue
        stored = (L <= j) & live
        src = jnp.where(stored, idxs, -1)
        src = jax.lax.cummax(src, axis=1)              # index propagation
        byte = jnp.take_along_axis(
            planes[:, j, :].astype(udt), jnp.maximum(src, 0), axis=1
        )
        byte = jnp.where(src >= 0, byte, jnp.asarray(0, udt))
        ws = ws | (byte << sh)
    return _compose_word(ws, mu, shift, nbytes, spec)


def unpack_dense_ref(planes, mu, shift, nbytes, spec: DtypeSpec = specs.F32):
    """``unpack_ref`` specialized to all-zero L codes (no XOR-lead elision).

    With L == 0 every live plane byte (j < nbytes) is stored at its own value,
    so the index-propagation scan degenerates to a masked byte composition.
    Bit-identical to ``unpack_ref(planes, mu, shift, nbytes, L=0)``.
    """
    nb, _, bs = planes.shape
    udt = spec.uint_dtype
    ws = jnp.zeros((nb, bs), udt)
    for j in range(spec.itemsize):
        live = (nbytes > j)[:, None]
        byte = jnp.where(live, planes[:, j, :].astype(udt), jnp.asarray(0, udt))
        ws = ws | (byte << jnp.asarray(8 * (spec.itemsize - 1 - j), udt))
    return _compose_word(ws, mu, shift, nbytes, spec)


# ---------------------------------------------------------------------------
# Fixed-plane ("szx-planes") in-graph mode -- see DESIGN.md section 2.
# ---------------------------------------------------------------------------

def planes_encode_ref(xb, num_planes: int):
    """Error-bounded-by-construction block quantization to `num_planes` bytes.

    xb: (nb, bs) f32.  Returns (mu (nb,) f32, sexp (nb,) int32, planes
    (num_planes, nb, bs) uint8).  q = rint(v * 2^sexp) with sexp chosen from the
    block radius exponent so |q| < 2^(8P-1); reconstruction error is
    <= 2^(E+1-8P) where E = p(radius).
    """
    assert 1 <= num_planes <= 3, "szx-planes supports 1..3 byte planes"
    xb = jnp.asarray(xb, jnp.float32)
    mn = jnp.min(xb, axis=-1)
    mx = jnp.max(xb, axis=-1)
    mu = 0.5 * (mn + mx)
    radius = jnp.maximum(mx - mu, mu - mn)
    E = f32_exponent(radius)
    nbits = 8 * num_planes
    sexp = (nbits - 2) - E
    v = xb - mu[..., None]
    scale = jnp.exp2(sexp.astype(jnp.float32))[..., None]
    lim = jnp.float32(2.0 ** (nbits - 1))
    q = jnp.clip(jnp.round(v * scale), -lim, lim - 1).astype(jnp.int32)
    uq = q.astype(jnp.uint32)
    planes = jnp.stack(
        [((uq >> (8 * p)) & jnp.uint32(0xFF)).astype(jnp.uint8) for p in range(num_planes)],
        axis=0,
    )
    return mu, sexp, planes


def planes_decode_ref(mu, sexp, planes):
    """Inverse of planes_encode_ref -> (..., bs) f32.  num_planes must be <= 3."""
    num_planes = planes.shape[0]
    assert num_planes <= 3, "szx-planes supports 1..3 byte planes"
    nbits = 8 * num_planes
    uq = jnp.zeros(planes.shape[1:], jnp.int32)
    for p in range(num_planes):
        uq = uq | (planes[p].astype(jnp.int32) << (8 * p))
    # sign-extend a width-`nbits` two's-complement integer (fits in int32)
    q = jnp.where(uq >= (1 << (nbits - 1)), uq - (1 << nbits), uq).astype(jnp.float32)
    v = q * jnp.exp2(-sexp.astype(jnp.float32))[..., None]
    return v + mu[..., None]
