"""Pure-jnp oracles for the SZx block-compression kernels.

These functions are the ground-truth semantics for the Pallas kernels in
``block_stats.py`` / ``pack.py`` / ``unpack.py``.  Everything here is fixed-shape
(the variable-length byte compaction happens at the host/serialization boundary
in ``repro.core.szx``), which is what makes the algorithm expressible on TPU.

Notation follows the paper (Algorithm 1 / Formulas 4-5):
  mu      -- mean of min and max of a block ("mean of min/max", mu_k)
  radius  -- variation radius r_k = max(|max-mu|, |mu-min|)
  reqlen  -- required number of leading IEEE-754 bits: 1 sign + 8 exponent +
             R_k mantissa bits, R_k = clip(p(r_k) - p(e) + 1, 0, 23).
             (+1 is a guard bit so the mu-subtraction rounding keeps the bound
             strict; see DESIGN.md section 2.)
  shift   -- Solution-C right shift s = (8 - reqlen % 8) % 8 (Formula 5)
  nbytes  -- bytes kept per value = (reqlen + shift) / 8, in {2,3,4}; 0 marks a
             constant block.
  L       -- identical-leading-byte count vs. the predecessor (2-bit code),
             predecessor of the first value in a block is the zero word (blocks
             are independently decodable, as in the GPU design).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32_EXP_BIAS = 127


def f32_exponent(x):
    """Biased-removed binary exponent field of float32 |x|.

    floor(log2|x|) for normal values; -127 for zero/subnormals (conservative).
    """
    bits = jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.uint32)
    return ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32) - F32_EXP_BIAS


def block_stats_ref(xb: jax.Array, e) -> tuple:
    """Per-block statistics (paper Alg. 1 lines 3-7).

    xb: (nb, bs) float32.  e: scalar absolute error bound (> 0).
    Returns (mu, radius, const, reqlen, shift, nbytes) each (nb,)-shaped;
    reqlen/shift/nbytes are 0 for constant blocks.
    """
    xb = jnp.asarray(xb, jnp.float32)
    mn = jnp.min(xb, axis=-1)
    mx = jnp.max(xb, axis=-1)
    mu = 0.5 * (mn + mx)
    radius = jnp.maximum(mx - mu, mu - mn)
    const = radius <= e
    req_m_raw = f32_exponent(radius) - f32_exponent(jnp.float32(e)) + 1
    req_m = jnp.clip(req_m_raw, 0, 23)
    # Verbatim blocks (beyond-paper robustness): if the bound is below the
    # ulp of the normalized values (req_m_raw > 23), the mu-subtraction
    # rounding alone can break the bound, so store the block bit-exactly by
    # normalizing against mu = 0.  Real SZx silently violates the bound here.
    mu = jnp.where(req_m_raw > 23, jnp.float32(0), mu)
    reqlen = 9 + req_m                      # 1 sign + 8 exponent + R_k mantissa
    shift = (8 - reqlen % 8) % 8            # Formula (5), Solution C
    nbytes = (reqlen + shift) // 8          # in {2, 3, 4}
    zero = jnp.zeros_like(reqlen)
    return (
        mu,
        radius,
        const,
        jnp.where(const, zero, reqlen),
        jnp.where(const, zero, shift),
        jnp.where(const, zero, nbytes),
    )


def pack_ref(xb: jax.Array, mu: jax.Array, shift: jax.Array, nbytes: jax.Array):
    """Normalize, right-shift (Solution C), XOR-lead, and byte-plane split.

    xb: (nb, bs) f32; mu/shift/nbytes: (nb,).
    Returns:
      planes: (nb, 4, bs) uint8 -- byte j of the shifted word (0 = most
              significant).  Fixed shape; the serializer keeps only bytes with
              L <= j < nbytes.
      L:      (nb, bs) int32 -- identical leading bytes vs. predecessor,
              clipped to [0, min(3, nbytes)].
      mid:    (nb, bs) int32 -- mid-bytes to store per value (nbytes - L).
    """
    xb = jnp.asarray(xb, jnp.float32)
    v = xb - mu[:, None]
    w = jax.lax.bitcast_convert_type(v, jnp.uint32)
    ws = w >> shift[:, None].astype(jnp.uint32)
    prev = jnp.concatenate(
        [jnp.zeros((ws.shape[0], 1), jnp.uint32), ws[:, :-1]], axis=1
    )
    xw = ws ^ prev
    b0 = ((xw >> 24) == 0).astype(jnp.int32)
    b1 = ((xw >> 16) == 0).astype(jnp.int32)
    b2 = ((xw >> 8) == 0).astype(jnp.int32)
    L = b0 + b0 * b1 + b0 * b1 * b2                    # leading zero bytes, <= 3
    L = jnp.minimum(L, nbytes[:, None])
    planes = jnp.stack(
        [((ws >> (24 - 8 * j)) & jnp.uint32(0xFF)).astype(jnp.uint8) for j in range(4)],
        axis=1,
    )
    mid = nbytes[:, None] - L
    return planes, L, mid


def unpack_ref(planes, mu, shift, nbytes, L):
    """Inverse of pack_ref.

    Reconstructs each byte either from the stored plane entry or, for the L
    leading bytes, from the most recent predecessor that stored that plane --
    the paper's GPU "index propagation" realized as a cumulative max
    (associative scan) along the block.
    Returns (nb, bs) float32 reconstruction (mu for constant blocks).
    """
    nb, _, bs = planes.shape
    idxs = jnp.broadcast_to(jnp.arange(bs, dtype=jnp.int32)[None, :], (nb, bs))
    ws = jnp.zeros((nb, bs), jnp.uint32)
    for j in range(4):
        stored = (L <= j) & (j < nbytes[:, None])
        src = jnp.where(stored, idxs, -1)
        src = jax.lax.cummax(src, axis=1)              # index propagation
        byte = jnp.take_along_axis(
            planes[:, j, :].astype(jnp.uint32), jnp.maximum(src, 0), axis=1
        )
        byte = jnp.where(src >= 0, byte, jnp.uint32(0))
        ws = ws | (byte << (24 - 8 * j))
    w = ws << shift[:, None].astype(jnp.uint32)
    v = jax.lax.bitcast_convert_type(w, jnp.float32)
    x = v + mu[:, None]
    return jnp.where((nbytes == 0)[:, None], mu[:, None], x)


def unpack_dense_ref(planes, mu, shift, nbytes):
    """``unpack_ref`` specialized to all-zero L codes (no XOR-lead elision).

    With L == 0 every live plane byte (j < nbytes) is stored at its own value,
    so the index-propagation scan degenerates to a masked byte composition.
    Bit-identical to ``unpack_ref(planes, mu, shift, nbytes, L=0)``.
    """
    nb, _, bs = planes.shape
    ws = jnp.zeros((nb, bs), jnp.uint32)
    for j in range(4):
        live = (nbytes > j)[:, None]
        byte = jnp.where(live, planes[:, j, :].astype(jnp.uint32), jnp.uint32(0))
        ws = ws | (byte << (24 - 8 * j))
    w = ws << shift[:, None].astype(jnp.uint32)
    v = jax.lax.bitcast_convert_type(w, jnp.float32)
    x = v + mu[:, None]
    return jnp.where((nbytes == 0)[:, None], mu[:, None], x)


# ---------------------------------------------------------------------------
# Fixed-plane ("szx-planes") in-graph mode -- see DESIGN.md section 2.
# ---------------------------------------------------------------------------

def planes_encode_ref(xb, num_planes: int):
    """Error-bounded-by-construction block quantization to `num_planes` bytes.

    xb: (nb, bs) f32.  Returns (mu (nb,) f32, sexp (nb,) int32, planes
    (num_planes, nb, bs) uint8).  q = rint(v * 2^sexp) with sexp chosen from the
    block radius exponent so |q| < 2^(8P-1); reconstruction error is
    <= 2^(E+1-8P) where E = p(radius).
    """
    assert 1 <= num_planes <= 3, "szx-planes supports 1..3 byte planes"
    xb = jnp.asarray(xb, jnp.float32)
    mn = jnp.min(xb, axis=-1)
    mx = jnp.max(xb, axis=-1)
    mu = 0.5 * (mn + mx)
    radius = jnp.maximum(mx - mu, mu - mn)
    E = f32_exponent(radius)
    nbits = 8 * num_planes
    sexp = (nbits - 2) - E
    v = xb - mu[..., None]
    scale = jnp.exp2(sexp.astype(jnp.float32))[..., None]
    lim = jnp.float32(2.0 ** (nbits - 1))
    q = jnp.clip(jnp.round(v * scale), -lim, lim - 1).astype(jnp.int32)
    uq = q.astype(jnp.uint32)
    planes = jnp.stack(
        [((uq >> (8 * p)) & jnp.uint32(0xFF)).astype(jnp.uint8) for p in range(num_planes)],
        axis=0,
    )
    return mu, sexp, planes


def planes_decode_ref(mu, sexp, planes):
    """Inverse of planes_encode_ref -> (..., bs) f32.  num_planes must be <= 3."""
    num_planes = planes.shape[0]
    assert num_planes <= 3, "szx-planes supports 1..3 byte planes"
    nbits = 8 * num_planes
    uq = jnp.zeros(planes.shape[1:], jnp.int32)
    for p in range(num_planes):
        uq = uq | (planes[p].astype(jnp.int32) << (8 * p))
    # sign-extend a width-`nbits` two's-complement integer (fits in int32)
    q = jnp.where(uq >= (1 << (nbits - 1)), uq - (1 << nbits), uq).astype(jnp.float32)
    v = q * jnp.exp2(-sexp.astype(jnp.float32))[..., None]
    return v + mu[..., None]
