"""Pallas TPU kernel: fused flash-attention forward (GQA, causal/windowed).

This is the fused kernel the roofline cost model assumes for the attention
tile loops (hlo_cost 'vmem_tile'): per (batch*kv-head, q-block) grid cell the
kernel streams K/V blocks through VMEM, keeps the online-softmax accumulators
in VMEM, and only q/k/v/out ever touch HBM.

Grid: (B*Hkv, nq).  Block shapes: q (1, G, CQ, hd), k/v (1, CK_total... the
kv stream is delivered block-by-block via the third grid dim so BlockSpec
tiling stays explicit:
  grid = (B*Hkv, nq, nk); accumulators live in VMEM scratch across the nk
  steps (sequential innermost dim), flushed to the output on the last step.

Validated in interpret mode against ``models.layers.flash_attention`` /
the naive oracle (tests/test_flash_kernel.py); compiles natively on TPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, cq, ck, nk,
            causal, window, sq, skv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                        # (G, CQ, hd)
    k = k_ref[0]                        # (CK, hd)
    v = v_ref[0]
    g, _, hd = q.shape
    s = jax.lax.dot_general(
        q.reshape(g * cq, hd), k,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(g, cq, ck) * (1.0 / math.sqrt(hd))

    qpos = qi * cq + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
    kpos = ki * ck + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
    valid = (kpos < skv) & (qpos < sq)
    if causal:
        valid &= kpos <= qpos
    if window:
        valid &= qpos - kpos < window
    s = jnp.where(valid[None], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[..., None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    pv = jax.lax.dot_general(
        p.reshape(g * cq, ck), v.astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(g, cq, hd)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...][..., None], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_block", "kv_block", "interpret")
)
def flash_attention_fwd(
    q, k, v, *, causal=True, window=0, q_block=128, kv_block=128,
    interpret: bool | None = None,
):
    """q: (B, Sq, Hq, hd); k/v: (B, Skv, Hkv, hd) -> (B, Sq, Hq, hd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    cq, ck = min(q_block, sq), min(kv_block, skv)
    pq, pk = (-sq) % cq, (-skv) % ck
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (sq + pq) // cq, (skv + pk) // ck

    # layout: (B*Hkv, G, S, hd) so one grid cell owns one kv-head's group
    qg = q.reshape(b, sq + pq, hkv, g, hd).transpose(0, 2, 3, 1, 4)
    qg = qg.reshape(b * hkv, g, sq + pq, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv + pk, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv + pk, hd)

    kern = functools.partial(
        _kernel, cq=cq, ck=ck, nk=nk, causal=causal, window=window,
        sq=sq, skv=skv,
    )
    out = pl.pallas_call(
        kern,
        grid=(b * hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, g, cq, hd), lambda h, i, j: (h, 0, i, 0)),
            pl.BlockSpec((1, ck, hd), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, ck, hd), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, cq, hd), lambda h, i, j: (h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, sq + pq, hd), q.dtype),
        # VMEM accumulators persist across the sequential innermost (nk) dim
        scratch_shapes=[
            pltpu.VMEM((g, cq), jnp.float32),
            pltpu.VMEM((g, cq), jnp.float32),
            pltpu.VMEM((g, cq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    out = out.reshape(b, hkv, g, sq + pq, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, sq + pq, hq, hd)[:, :sq]
