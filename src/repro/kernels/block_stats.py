"""Pallas TPU kernel: per-block SZx statistics (paper Alg. 1 lines 3-7).

Width-generic: the kernel is parameterized by a
:class:`repro.kernels.specs.DtypeSpec` -- stats run in the spec's compute
dtype (f32 for words up to 4 bytes, f64 for float64), the exponent is read
from the compute dtype's bit field, and ``mu`` is rounded to the storage
dtype inside the kernel.

Tiling: TILE_BLOCKS=8 SZx blocks per grid step so a tile is an (8, 128)
VPU-shaped array in VMEM (sublane x lane).  All math is add/sub/shift/compare
(the paper's "super-lightweight" constraint); min/max are VPU lane reductions
(the TPU analogue of the paper's warp-level reductions).

Validated against ``ref.block_stats_ref`` in interpret mode (CPU container);
on a real TPU the same ``pl.pallas_call`` compiles natively for 16/32-bit
words (float64 has no 64-bit TPU words -- ``repro.kernels.ops`` falls back to
the jitted oracle there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import specs
from repro.kernels.specs import DtypeSpec

TILE_BLOCKS = 8


def stats_body(spec: DtypeSpec, x_storage, e, p_e):
    """Trace-time stats body (paper Alg. 1 lines 3-7), shared between this
    kernel and the fused encode kernel -- a future semantics change lands in
    both by construction.  Returns (mu, radius, const, reqlen, shift, nbytes)
    with reqlen/shift/nbytes already zeroed for constant blocks."""
    cdt = spec.compute_np_dtype
    cexp_mask = (1 << spec.compute_exp_bits) - 1
    x = x_storage.astype(cdt)               # (TB, bs) compute dtype
    mn = jnp.min(x, axis=1)
    mx = jnp.max(x, axis=1)
    mu = (0.5 * (mn + mx)).astype(spec.np_dtype)   # storage-rounded mu
    mu_w = mu.astype(cdt)
    r = jnp.maximum(mx - mu_w, mu_w - mn)
    r_test = r
    if spec.stats_rounding_guard:
        # 16-bit formats: next-up radius keeps the constant-block bound
        # strict against the f32 subtraction rounding (see DtypeSpec)
        bits = jax.lax.bitcast_convert_type(r, spec.compute_uint_dtype) + 1
        r_test = jax.lax.bitcast_convert_type(bits, cdt)
    const = r_test <= e
    rexp = (
        (jax.lax.bitcast_convert_type(r, spec.compute_uint_dtype)
         >> spec.compute_mant_bits) & cexp_mask
    ).astype(jnp.int32) - spec.compute_exp_bias
    req_m_raw = rexp - p_e + 1
    req_m = jnp.clip(req_m_raw, 0, spec.mant_bits)
    mu = jnp.where(req_m_raw > spec.mant_bits, jnp.zeros_like(mu), mu)
    reqlen = 1 + spec.exp_bits + req_m
    shift = (8 - reqlen % 8) % 8
    nbytes = (reqlen + shift) // 8
    zero = jnp.zeros_like(reqlen)
    return (
        mu,
        r,
        const,
        jnp.where(const, zero, reqlen),
        jnp.where(const, zero, shift),
        jnp.where(const, zero, nbytes),
    )


def _make_kernel(spec: DtypeSpec):
    def _kernel(e_ref, pe_ref, x_ref, mu_ref, rad_ref, const_ref, reqlen_ref,
                shift_ref, nbytes_ref):
        mu, r, const, reqlen, shift, nbytes = stats_body(
            spec, x_ref[...], e_ref[0], pe_ref[0]
        )
        mu_ref[...] = mu
        rad_ref[...] = r
        const_ref[...] = const.astype(jnp.int32)
        reqlen_ref[...] = reqlen
        shift_ref[...] = shift
        nbytes_ref[...] = nbytes

    return _kernel


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def block_stats(xb: jax.Array, e: jax.Array, p_e: jax.Array, *,
                spec: DtypeSpec = specs.F32, interpret: bool | None = None):
    """xb: (nb, bs) spec dtype, e: scalar compute dtype, p_e: scalar int32
    (exact floor(log2 e)) -> same tuple as ref.block_stats_ref."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, bs = xb.shape
    if nb == 0:
        z = jnp.zeros((0,), jnp.int32)
        return (jnp.zeros((0,), spec.np_dtype), jnp.zeros((0,), spec.compute_np_dtype),
                jnp.zeros((0,), bool), z, z, z)
    pad = (-nb) % TILE_BLOCKS
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
    nbp = nb + pad
    grid = (nbp // TILE_BLOCKS,)
    vec = pl.BlockSpec((TILE_BLOCKS,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((nbp,), spec.np_dtype),          # mu
        jax.ShapeDtypeStruct((nbp,), spec.compute_np_dtype),  # radius
        jax.ShapeDtypeStruct((nbp,), jnp.int32),              # const flag
        jax.ShapeDtypeStruct((nbp,), jnp.int32),              # reqlen
        jax.ShapeDtypeStruct((nbp,), jnp.int32),              # shift
        jax.ShapeDtypeStruct((nbp,), jnp.int32),              # nbytes
    )
    mu, rad, const, reqlen, shift, nbytes = pl.pallas_call(
        _make_kernel(spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),                  # e (broadcast)
            pl.BlockSpec((1,), lambda i: (0,)),                  # p_e (broadcast)
            pl.BlockSpec((TILE_BLOCKS, bs), lambda i: (i, 0)),   # x tile in VMEM
        ],
        out_specs=(vec,) * 6,
        out_shape=out_shapes,
        interpret=interpret,
    )(
        jnp.reshape(e.astype(spec.compute_np_dtype), (1,)),
        jnp.reshape(p_e.astype(jnp.int32), (1,)),
        xb,
    )
    sl = slice(0, nb)
    return mu[sl], rad[sl], const[sl].astype(bool), reqlen[sl], shift[sl], nbytes[sl]
