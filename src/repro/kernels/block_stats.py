"""Pallas TPU kernel: per-block SZx statistics (paper Alg. 1 lines 3-7).

Tiling: TILE_BLOCKS=8 SZx blocks per grid step so a tile is an (8, 128) f32
VPU-shaped array in VMEM (sublane x lane).  All math is add/sub/shift/compare
(the paper's "super-lightweight" constraint); min/max are VPU lane reductions
(the TPU analogue of the paper's warp-level reductions).

Validated against ``ref.block_stats_ref`` in interpret mode (CPU container);
on a real TPU the same ``pl.pallas_call`` compiles natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_BLOCKS = 8


def _kernel(e_ref, x_ref, mu_ref, rad_ref, const_ref, reqlen_ref, shift_ref, nbytes_ref):
    x = x_ref[...]                      # (TB, bs) f32
    e = e_ref[0]
    mn = jnp.min(x, axis=1)
    mx = jnp.max(x, axis=1)
    mu = 0.5 * (mn + mx)
    r = jnp.maximum(mx - mu, mu - mn)
    const = r <= e
    rexp = (
        (jax.lax.bitcast_convert_type(r, jnp.uint32) >> 23) & jnp.uint32(0xFF)
    ).astype(jnp.int32) - 127
    eexp = (
        (jax.lax.bitcast_convert_type(e, jnp.uint32) >> 23) & jnp.uint32(0xFF)
    ).astype(jnp.int32) - 127
    req_m_raw = rexp - eexp + 1
    req_m = jnp.clip(req_m_raw, 0, 23)
    mu = jnp.where(req_m_raw > 23, jnp.float32(0), mu)  # verbatim blocks
    reqlen = 9 + req_m
    shift = (8 - reqlen % 8) % 8
    nbytes = (reqlen + shift) // 8
    zero = jnp.zeros_like(reqlen)
    mu_ref[...] = mu
    rad_ref[...] = r
    const_ref[...] = const.astype(jnp.int32)
    reqlen_ref[...] = jnp.where(const, zero, reqlen)
    shift_ref[...] = jnp.where(const, zero, shift)
    nbytes_ref[...] = jnp.where(const, zero, nbytes)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_stats(xb: jax.Array, e: jax.Array, *, interpret: bool | None = None):
    """xb: (nb, bs) f32, e: scalar f32 -> same tuple as ref.block_stats_ref."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, bs = xb.shape
    pad = (-nb) % TILE_BLOCKS
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
    nbp = nb + pad
    grid = (nbp // TILE_BLOCKS,)
    vec = pl.BlockSpec((TILE_BLOCKS,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((nbp,), jnp.float32),   # mu
        jax.ShapeDtypeStruct((nbp,), jnp.float32),   # radius
        jax.ShapeDtypeStruct((nbp,), jnp.int32),     # const flag
        jax.ShapeDtypeStruct((nbp,), jnp.int32),     # reqlen
        jax.ShapeDtypeStruct((nbp,), jnp.int32),     # shift
        jax.ShapeDtypeStruct((nbp,), jnp.int32),     # nbytes
    )
    mu, rad, const, reqlen, shift, nbytes = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),                  # e (broadcast)
            pl.BlockSpec((TILE_BLOCKS, bs), lambda i: (i, 0)),   # x tile in VMEM
        ],
        out_specs=(vec,) * 6,
        out_shape=out_shapes,
        interpret=interpret,
    )(jnp.reshape(e.astype(jnp.float32), (1,)), xb)
    sl = slice(0, nb)
    return mu[sl], rad[sl], const[sl].astype(bool), reqlen[sl], shift[sl], nbytes[sl]
