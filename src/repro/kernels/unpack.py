"""Pallas TPU kernel: SZx decompression (leading-byte retrieval + reassembly).

The paper's GPU "index propagation" (Fig. 9: O(log n) interleaved-addressing
max propagation) maps 1:1 onto a log2(bs) sequence of lane shifts + maxima.
To avoid an in-kernel gather we propagate a fused key ``idx*256 + byte`` --
idx dominates the max, so the surviving key carries the byte of the nearest
preceding stored position; ``key & 0xFF`` recovers it.  This is the TPU
analogue of the paper's warp-shuffle propagation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_BLOCKS = 8


def _kernel(planes_ref, mu_ref, shift_ref, nbytes_ref, L_ref, out_ref):
    planes = planes_ref[...]                        # (TB, 4, bs) uint8
    mu = mu_ref[...]
    shift = shift_ref[...]
    nbytes = nbytes_ref[...]
    L = L_ref[...]
    tb, _, bs = planes.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (tb, bs), 1)
    ws = jnp.zeros((tb, bs), jnp.uint32)
    for j in range(4):
        stored = (L <= j) & (j < nbytes[:, None])
        byte = planes[:, j, :].astype(jnp.int32)
        key = jnp.where(stored, idx * 256 + byte, -1)
        step = 1
        while step < bs:                             # interleaved propagation
            shifted = jnp.pad(key, ((0, 0), (step, 0)), constant_values=-1)[:, :bs]
            key = jnp.maximum(key, shifted)
            step *= 2
        b = jnp.where(key >= 0, (key & 0xFF).astype(jnp.uint32), jnp.uint32(0))
        ws = ws | (b << (24 - 8 * j))
    w = ws << shift[:, None].astype(jnp.uint32)
    v = jax.lax.bitcast_convert_type(w, jnp.float32)
    out_ref[...] = jnp.where((nbytes == 0)[:, None], mu[:, None], v + mu[:, None])


@functools.partial(jax.jit, static_argnames=("interpret",))
def unpack(planes, mu, shift, nbytes, L, *, interpret: bool | None = None):
    """Same contract as ref.unpack_ref -> (nb, bs) f32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, _, bs = planes.shape
    pad = (-nb) % TILE_BLOCKS
    if pad:
        planes = jnp.pad(planes, ((0, pad), (0, 0), (0, 0)))
        mu = jnp.pad(mu, (0, pad))
        shift = jnp.pad(shift, (0, pad))
        nbytes = jnp.pad(nbytes, (0, pad))
        L = jnp.pad(L, ((0, pad), (0, 0)))
    nbp = nb + pad
    grid = (nbp // TILE_BLOCKS,)
    vec = pl.BlockSpec((TILE_BLOCKS,), lambda i: (i,))
    tile = pl.BlockSpec((TILE_BLOCKS, bs), lambda i: (i, 0))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_BLOCKS, 4, bs), lambda i: (i, 0, 0)),
            vec,
            vec,
            vec,
            tile,
        ],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((nbp, bs), jnp.float32),
        interpret=interpret,
    )(planes, mu, shift, nbytes, L)
    return out[:nb]
