"""Pallas TPU kernels: SZx decompression (leading-byte retrieval + reassembly).

Width-generic: parameterized by a :class:`repro.kernels.specs.DtypeSpec` --
the loop runs over ``itemsize`` MSB-first byte planes and reassembles the
spec's word.  The paper's GPU "index propagation" (Fig. 9: O(log n)
interleaved-addressing max propagation) maps 1:1 onto a log2(bs) sequence of
lane shifts + maxima.  To avoid an in-kernel gather we propagate a fused key
``idx*256 + byte`` -- idx dominates the max, so the surviving key carries the
byte of the nearest preceding stored position; ``key & 0xFF`` recovers it.
This is the TPU analogue of the paper's warp-shuffle propagation.  Planes past
the lead cap (the 2-bit L code tops out at 3) are always stored for live
blocks, so they skip the propagation entirely -- which is also what makes
``unpack_dense`` (all-``L==0`` frames) a plain masked byte composition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import specs
from repro.kernels.specs import DtypeSpec

TILE_BLOCKS = 8


def _compose(ws, mu, shift, nbytes, spec: DtypeSpec):
    udt = spec.uint_dtype
    cdt = spec.compute_np_dtype
    w = ws << shift[:, None].astype(udt)
    v = jax.lax.bitcast_convert_type(w, spec.np_dtype)
    x = (v.astype(cdt) + mu[:, None].astype(cdt)).astype(spec.np_dtype)
    return jnp.where((nbytes == 0)[:, None], mu[:, None], x)


def _make_kernel(spec: DtypeSpec):
    udt = spec.uint_dtype

    def _kernel(planes_ref, mu_ref, shift_ref, nbytes_ref, L_ref, out_ref):
        planes = planes_ref[...]                        # (TB, itemsize, bs) u8
        mu = mu_ref[...]
        shift = shift_ref[...]
        nbytes = nbytes_ref[...]
        L = L_ref[...]
        tb, _, bs = planes.shape
        idx = jax.lax.broadcasted_iota(jnp.int32, (tb, bs), 1)
        ws = jnp.zeros((tb, bs), udt)
        for j in range(spec.itemsize):
            sh = jnp.asarray(8 * (spec.itemsize - 1 - j), udt)
            live = j < nbytes[:, None]
            if j >= spec.lead_cap:
                # L <= lead_cap <= j: every live value stored this plane
                b = jnp.where(live, planes[:, j, :].astype(udt), jnp.asarray(0, udt))
                ws = ws | (b << sh)
                continue
            stored = (L <= j) & live
            byte = planes[:, j, :].astype(jnp.int32)
            key = jnp.where(stored, idx * 256 + byte, -1)
            step = 1
            while step < bs:                             # interleaved propagation
                shifted = jnp.pad(key, ((0, 0), (step, 0)), constant_values=-1)[:, :bs]
                key = jnp.maximum(key, shifted)
                step *= 2
            b = jnp.where(key >= 0, (key & 0xFF).astype(udt), jnp.asarray(0, udt))
            ws = ws | (b << sh)
        out_ref[...] = _compose(ws, mu, shift, nbytes, spec)

    return _kernel


def _make_dense_kernel(spec: DtypeSpec):
    udt = spec.uint_dtype

    def _kernel(planes_ref, mu_ref, shift_ref, nbytes_ref, out_ref):
        planes = planes_ref[...]
        mu = mu_ref[...]
        shift = shift_ref[...]
        nbytes = nbytes_ref[...]
        tb, _, bs = planes.shape
        ws = jnp.zeros((tb, bs), udt)
        for j in range(spec.itemsize):
            live = (nbytes > j)[:, None]
            b = jnp.where(live, planes[:, j, :].astype(udt), jnp.asarray(0, udt))
            ws = ws | (b << jnp.asarray(8 * (spec.itemsize - 1 - j), udt))
        out_ref[...] = _compose(ws, mu, shift, nbytes, spec)

    return _kernel


def _padded_call(kernel, planes, mu, shift, nbytes, extra_tiles, spec: DtypeSpec,
                 interpret: bool):
    nb, _, bs = planes.shape
    pad = (-nb) % TILE_BLOCKS
    if pad:
        planes = jnp.pad(planes, ((0, pad), (0, 0), (0, 0)))
        mu = jnp.pad(mu, (0, pad))
        shift = jnp.pad(shift, (0, pad))
        nbytes = jnp.pad(nbytes, (0, pad))
        extra_tiles = [jnp.pad(t, ((0, pad), (0, 0))) for t in extra_tiles]
    nbp = nb + pad
    grid = (nbp // TILE_BLOCKS,)
    vec = pl.BlockSpec((TILE_BLOCKS,), lambda i: (i,))
    tile = pl.BlockSpec((TILE_BLOCKS, bs), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_BLOCKS, spec.itemsize, bs), lambda i: (i, 0, 0)),
            vec,
            vec,
            vec,
        ] + [tile] * len(extra_tiles),
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((nbp, bs), spec.np_dtype),
        interpret=interpret,
    )(planes, mu, shift, nbytes, *extra_tiles)
    return out[:nb]


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def unpack(planes, mu, shift, nbytes, L, *, spec: DtypeSpec = specs.F32,
           interpret: bool | None = None):
    """Same contract as ref.unpack_ref -> (nb, bs) in the spec's dtype."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, _, bs = planes.shape
    if nb == 0:
        return jnp.zeros((0, bs), spec.np_dtype)
    return _padded_call(_make_kernel(spec), planes, mu, shift, nbytes, [L],
                        spec, interpret)


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def unpack_dense(planes, mu, shift, nbytes, *, spec: DtypeSpec = specs.F32,
                 interpret: bool | None = None):
    """All-``L==0`` fast path; bit-identical to ``unpack(..., L=0)``."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, _, bs = planes.shape
    if nb == 0:
        return jnp.zeros((0, bs), spec.np_dtype)
    return _padded_call(_make_dense_kernel(spec), planes, mu, shift, nbytes, [],
                        spec, interpret)
