"""Pallas TPU kernel: SZx normalize + Solution-C shift + XOR-lead + byte planes.

One grid step processes TILE_BLOCKS=8 SZx blocks -> an (8, 128) tile.  The
XOR-with-predecessor is a lane shift (pad+slice), the paper's per-value
leading-byte count becomes three vectorized compares, and the byte planes are
lane-aligned slices (Solution C is *structural* here: byte alignment is what
makes the plane layout legal).  Output planes stay fixed-shape; compaction is
host-side (see repro.core.szx).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_BLOCKS = 8


def _kernel(x_ref, mu_ref, shift_ref, nbytes_ref, planes_ref, L_ref, mid_ref):
    x = x_ref[...]                                   # (TB, bs) f32
    mu = mu_ref[...]
    shift = shift_ref[...]
    nbytes = nbytes_ref[...]
    v = x - mu[:, None]
    w = jax.lax.bitcast_convert_type(v, jnp.uint32)
    ws = w >> shift[:, None].astype(jnp.uint32)
    prev = jnp.pad(ws, ((0, 0), (1, 0)))[:, :-1]     # lane shift by 1
    xw = ws ^ prev
    b0 = ((xw >> 24) == 0).astype(jnp.int32)
    b1 = ((xw >> 16) == 0).astype(jnp.int32)
    b2 = ((xw >> 8) == 0).astype(jnp.int32)
    L = jnp.minimum(b0 + b0 * b1 + b0 * b1 * b2, nbytes[:, None])
    for j in range(4):
        planes_ref[:, j, :] = ((ws >> (24 - 8 * j)) & jnp.uint32(0xFF)).astype(
            jnp.uint8
        )
    L_ref[...] = L
    mid_ref[...] = nbytes[:, None] - L


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack(xb, mu, shift, nbytes, *, interpret: bool | None = None):
    """Same contract as ref.pack_ref."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, bs = xb.shape
    pad = (-nb) % TILE_BLOCKS
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
        mu = jnp.pad(mu, (0, pad))
        shift = jnp.pad(shift, (0, pad))
        nbytes = jnp.pad(nbytes, (0, pad))
    nbp = nb + pad
    grid = (nbp // TILE_BLOCKS,)
    vec = pl.BlockSpec((TILE_BLOCKS,), lambda i: (i,))
    tile = pl.BlockSpec((TILE_BLOCKS, bs), lambda i: (i, 0))
    planes, L, mid = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[tile, vec, vec, vec],
        out_specs=(
            pl.BlockSpec((TILE_BLOCKS, 4, bs), lambda i: (i, 0, 0)),
            tile,
            tile,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nbp, 4, bs), jnp.uint8),
            jax.ShapeDtypeStruct((nbp, bs), jnp.int32),
            jax.ShapeDtypeStruct((nbp, bs), jnp.int32),
        ),
        interpret=interpret,
    )(xb, mu, shift, nbytes)
    return planes[:nb], L[:nb], mid[:nb]
