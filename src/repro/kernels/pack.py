"""Pallas TPU kernel: SZx normalize + Solution-C shift + XOR-lead + byte planes.

Width-generic: parameterized by a :class:`repro.kernels.specs.DtypeSpec`; the
normalized residual is rounded to the storage dtype, bitcast to the spec's
word, and split into ``itemsize`` MSB-first byte planes.  One grid step
processes TILE_BLOCKS=8 SZx blocks -> an (8, 128) tile.  The
XOR-with-predecessor is a lane shift (pad+slice), the paper's per-value
leading-byte count becomes ``lead_cap`` vectorized compares, and the byte
planes are lane-aligned slices (Solution C is *structural* here: byte
alignment is what makes the plane layout legal).  Output planes stay
fixed-shape; compaction is host-side (see repro.core.codec.container).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import specs
from repro.kernels.specs import DtypeSpec

TILE_BLOCKS = 8


def pack_body(spec: DtypeSpec, x_storage, mu, shift, nbytes):
    """Trace-time pack body (paper Alg. 1 lines 8-9), shared between this
    kernel and the fused encode kernel.  Returns (ws, L, mid): the shifted
    words plus the XOR-lead counts; the caller splits ``ws`` into planes
    with :func:`plane_byte` (plane writes go straight to output refs)."""
    cdt = spec.compute_np_dtype
    udt = spec.uint_dtype
    x = x_storage.astype(cdt)                        # (TB, bs)
    mu_w = mu.astype(cdt)
    v = (x - mu_w[:, None]).astype(spec.np_dtype)    # storage-rounded
    w = jax.lax.bitcast_convert_type(v, udt)
    ws = w >> shift[:, None].astype(udt)
    prev = jnp.pad(ws, ((0, 0), (1, 0)))[:, :-1]     # lane shift by 1
    xw = ws ^ prev
    L = jnp.zeros(ws.shape, jnp.int32)
    run = jnp.ones(ws.shape, bool)
    for j in range(spec.lead_cap):
        run = run & ((xw >> jnp.asarray(8 * (spec.itemsize - 1 - j), udt)) == 0)
        L = L + run.astype(jnp.int32)
    L = jnp.minimum(L, nbytes[:, None])
    return ws, L, nbytes[:, None] - L


def plane_byte(spec: DtypeSpec, ws, j: int):
    """MSB-first byte plane j of the shifted words."""
    udt = spec.uint_dtype
    return (
        (ws >> jnp.asarray(8 * (spec.itemsize - 1 - j), udt))
        & jnp.asarray(0xFF, udt)
    ).astype(jnp.uint8)


def _make_kernel(spec: DtypeSpec):
    def _kernel(x_ref, mu_ref, shift_ref, nbytes_ref, planes_ref, L_ref, mid_ref):
        ws, L, mid = pack_body(
            spec, x_ref[...], mu_ref[...], shift_ref[...], nbytes_ref[...]
        )
        for j in range(spec.itemsize):
            planes_ref[:, j, :] = plane_byte(spec, ws, j)
        L_ref[...] = L
        mid_ref[...] = mid

    return _kernel


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def pack(xb, mu, shift, nbytes, *, spec: DtypeSpec = specs.F32,
         interpret: bool | None = None):
    """Same contract as ref.pack_ref."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, bs = xb.shape
    if nb == 0:
        return (jnp.zeros((0, spec.itemsize, bs), jnp.uint8),
                jnp.zeros((0, bs), jnp.int32), jnp.zeros((0, bs), jnp.int32))
    pad = (-nb) % TILE_BLOCKS
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
        mu = jnp.pad(mu, (0, pad))
        shift = jnp.pad(shift, (0, pad))
        nbytes = jnp.pad(nbytes, (0, pad))
    nbp = nb + pad
    grid = (nbp // TILE_BLOCKS,)
    vec = pl.BlockSpec((TILE_BLOCKS,), lambda i: (i,))
    tile = pl.BlockSpec((TILE_BLOCKS, bs), lambda i: (i, 0))
    planes, L, mid = pl.pallas_call(
        _make_kernel(spec),
        grid=grid,
        in_specs=[tile, vec, vec, vec],
        out_specs=(
            pl.BlockSpec((TILE_BLOCKS, spec.itemsize, bs), lambda i: (i, 0, 0)),
            tile,
            tile,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nbp, spec.itemsize, bs), jnp.uint8),
            jax.ShapeDtypeStruct((nbp, bs), jnp.int32),
            jax.ShapeDtypeStruct((nbp, bs), jnp.int32),
        ),
        interpret=interpret,
    )(xb, mu, shift, nbytes)
    return planes[:nb], L[:nb], mid[:nb]
