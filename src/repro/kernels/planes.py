"""Pallas TPU kernels: szx-planes fixed-plane encode/decode.

The in-graph (static-shape) SZx variant used for gradient/KV compression:
per-block mu + radius-exponent-derived scale + P uint8 quantization planes.
Previously the 'kernel' backend silently routed to the jitted jnp oracle;
these kernels give it a real Pallas route (oracle:
``ref.planes_encode_ref`` / ``ref.planes_decode_ref``, bit-identical).

Shapes: the ops layer flattens leading dims to (nb, bs) before the call and
restores them after, so the kernels only ever see 2-D tiles
(TILE_BLOCKS=8 blocks x bs lanes, float32 -- szx-planes is an f32-only mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_BLOCKS = 8


def _make_encode_kernel(num_planes: int):
    nbits = 8 * num_planes
    lim = float(2.0 ** (nbits - 1))   # python literal: closure-safe in pallas

    def _kernel(x_ref, mu_ref, sexp_ref, planes_ref):
        x = x_ref[...]                                   # (TB, bs) f32
        mn = jnp.min(x, axis=1)
        mx = jnp.max(x, axis=1)
        mu = 0.5 * (mn + mx)
        radius = jnp.maximum(mx - mu, mu - mn)
        E = (
            (jax.lax.bitcast_convert_type(radius, jnp.uint32) >> 23)
            & jnp.uint32(0xFF)
        ).astype(jnp.int32) - 127
        sexp = (nbits - 2) - E
        v = x - mu[:, None]
        scale = jnp.exp2(sexp.astype(jnp.float32))[:, None]
        q = jnp.clip(jnp.round(v * scale), -lim, lim - 1).astype(jnp.int32)
        uq = q.astype(jnp.uint32)
        for p in range(num_planes):
            planes_ref[p, :, :] = ((uq >> (8 * p)) & jnp.uint32(0xFF)).astype(jnp.uint8)
        mu_ref[...] = mu
        sexp_ref[...] = sexp

    return _kernel


def _make_decode_kernel(num_planes: int):
    nbits = 8 * num_planes

    def _kernel(planes_ref, mu_ref, sexp_ref, out_ref):
        planes = planes_ref[...]                         # (P, TB, bs) u8
        mu = mu_ref[...]
        sexp = sexp_ref[...]
        uq = jnp.zeros(planes.shape[1:], jnp.int32)
        for p in range(num_planes):
            uq = uq | (planes[p].astype(jnp.int32) << (8 * p))
        # sign-extend a width-`nbits` two's-complement integer (fits in int32)
        q = jnp.where(uq >= (1 << (nbits - 1)), uq - (1 << nbits), uq).astype(
            jnp.float32
        )
        out_ref[...] = q * jnp.exp2(-sexp.astype(jnp.float32))[:, None] + mu[:, None]

    return _kernel


@functools.partial(jax.jit, static_argnames=("num_planes", "interpret"))
def planes_encode(xb, num_planes: int, *, interpret: bool | None = None):
    """Same contract as ref.planes_encode_ref; xb may have leading dims."""
    assert 1 <= num_planes <= 3, "szx-planes supports 1..3 byte planes"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    xb = jnp.asarray(xb, jnp.float32)
    lead = xb.shape[:-1]
    bs = xb.shape[-1]
    x2 = xb.reshape(-1, bs)
    nb = x2.shape[0]
    if nb == 0:
        return (jnp.zeros(lead, jnp.float32), jnp.zeros(lead, jnp.int32),
                jnp.zeros((num_planes,) + lead + (bs,), jnp.uint8))
    pad = (-nb) % TILE_BLOCKS
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    nbp = nb + pad
    grid = (nbp // TILE_BLOCKS,)
    vec = pl.BlockSpec((TILE_BLOCKS,), lambda i: (i,))
    mu, sexp, planes = pl.pallas_call(
        _make_encode_kernel(num_planes),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_BLOCKS, bs), lambda i: (i, 0))],
        out_specs=(
            vec,
            vec,
            pl.BlockSpec((num_planes, TILE_BLOCKS, bs), lambda i: (0, i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nbp,), jnp.float32),
            jax.ShapeDtypeStruct((nbp,), jnp.int32),
            jax.ShapeDtypeStruct((num_planes, nbp, bs), jnp.uint8),
        ),
        interpret=interpret,
    )(x2)
    return (mu[:nb].reshape(lead), sexp[:nb].reshape(lead),
            planes[:, :nb].reshape((num_planes,) + lead + (bs,)))


@functools.partial(jax.jit, static_argnames=("interpret",))
def planes_decode(mu, sexp, planes, *, interpret: bool | None = None):
    """Same contract as ref.planes_decode_ref -> (..., bs) f32."""
    num_planes = planes.shape[0]
    assert num_planes <= 3, "szx-planes supports 1..3 byte planes"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lead = planes.shape[1:-1]
    bs = planes.shape[-1]
    p2 = planes.reshape(num_planes, -1, bs)
    nb = p2.shape[1]
    if nb == 0:
        return jnp.zeros(lead + (bs,), jnp.float32)
    mu2 = jnp.asarray(mu, jnp.float32).reshape(-1)
    sexp2 = jnp.asarray(sexp, jnp.int32).reshape(-1)
    pad = (-nb) % TILE_BLOCKS
    if pad:
        p2 = jnp.pad(p2, ((0, 0), (0, pad), (0, 0)))
        mu2 = jnp.pad(mu2, (0, pad))
        sexp2 = jnp.pad(sexp2, (0, pad))
    nbp = nb + pad
    grid = (nbp // TILE_BLOCKS,)
    vec = pl.BlockSpec((TILE_BLOCKS,), lambda i: (i,))
    out = pl.pallas_call(
        _make_decode_kernel(num_planes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((num_planes, TILE_BLOCKS, bs), lambda i: (0, i, 0)),
            vec,
            vec,
        ],
        out_specs=pl.BlockSpec((TILE_BLOCKS, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, bs), jnp.float32),
        interpret=interpret,
    )(p2, mu2, sexp2)
    return out[:nb].reshape(lead + (bs,))
