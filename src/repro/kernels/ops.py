"""jit'd wrappers + backend dispatch for the SZx kernels.

Backends:
  'jax'    -- jnp oracle from ``ref.py`` under ``jax.jit`` (CPU default)
  'kernel' -- Pallas TPU kernels (``interpret=True`` automatically off-TPU)
  'numpy'  -- pure-numpy mirror (no jit/dispatch overhead; host-side use)
  'auto'   -- 'kernel' on TPU, 'jax' elsewhere
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "jax"
    return backend


# --------------------------------------------------------------------------
# jit'd oracle paths
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def _block_stats_jax(xb, e):
    return ref.block_stats_ref(xb, e)


@jax.jit
def _pack_jax(xb, mu, shift, nbytes):
    return ref.pack_ref(xb, mu, shift, nbytes)


@jax.jit
def _unpack_jax(planes, mu, shift, nbytes, L):
    return ref.unpack_ref(planes, mu, shift, nbytes, L)


@jax.jit
def _unpack_dense_jax(planes, mu, shift, nbytes):
    return ref.unpack_dense_ref(planes, mu, shift, nbytes)


# --------------------------------------------------------------------------
# numpy mirrors (bit-identical to ref.py)
# --------------------------------------------------------------------------

def _np_exponent(x):
    bits = np.asarray(x, np.float32).view(np.uint32)
    return ((bits >> 23) & 0xFF).astype(np.int32) - 127


def _block_stats_np(xb, e):
    xb = np.asarray(xb, np.float32)
    mn = xb.min(axis=1)
    mx = xb.max(axis=1)
    mu = np.float32(0.5) * (mn + mx)
    radius = np.maximum(mx - mu, mu - mn)
    const = radius <= np.float32(e)
    req_m_raw = _np_exponent(radius) - _np_exponent(np.float32(e)) + 1
    req_m = np.clip(req_m_raw, 0, 23)
    mu = np.where(req_m_raw > 23, np.float32(0), mu)  # verbatim blocks
    reqlen = 9 + req_m
    shift = (8 - reqlen % 8) % 8
    nbytes = (reqlen + shift) // 8
    z = np.zeros_like(reqlen)
    return (
        mu,
        radius,
        const,
        np.where(const, z, reqlen),
        np.where(const, z, shift),
        np.where(const, z, nbytes),
    )


def _pack_np(xb, mu, shift, nbytes):
    """Bit-identical to ``ref.pack_ref`` but allocation-lean: the shift runs
    in place on the normalized words and the XOR-lead run length is computed
    by byte-view equality against the predecessor (no xor word, no shifts)."""
    xb = np.asarray(xb, np.float32)
    nb, bs = xb.shape
    v = xb - mu[:, None]
    ws = v.view(np.uint32)
    np.right_shift(ws, shift[:, None].astype(np.uint32), out=ws)
    # little-endian byte view: plane j (MSB-first) is byte 3-j -- no shifts.
    # L counts how many leading bytes equal the predecessor's (the first
    # value compares against the zero word), capped at 3 by the 2-bit code.
    wsb = ws.view(np.uint8).reshape(nb, bs, 4)
    L = np.zeros((nb, bs), np.int32)
    run = np.empty((nb, bs), bool)
    eq = np.empty((nb, bs), bool)
    for j in range(3):
        pj = wsb[:, :, 3 - j]
        eq[:, 0] = pj[:, 0] == 0
        np.equal(pj[:, 1:], pj[:, :-1], out=eq[:, 1:])
        if j == 0:
            run[:] = eq
        else:
            run &= eq
        L += run
    np.minimum(L, nbytes[:, None], out=L)
    planes = np.ascontiguousarray(wsb[:, :, ::-1].transpose(0, 2, 1))
    mid = nbytes[:, None] - L
    return planes, L, mid


def _unpack_np(planes, mu, shift, nbytes, L):
    """Bit-identical to ``ref.unpack_ref`` but byte-oriented: planes are written
    straight into a little-endian uint32 byte view, index propagation runs only
    on planes that actually need it (some value has ``L > j``) and only over
    blocks where the plane is live (``nbytes > j``)."""
    nb, _, bs = planes.shape
    ws = np.zeros((nb, bs), np.uint32)
    wsb = ws.view(np.uint8).reshape(nb, bs, 4)         # little-endian host:
    idxs = np.arange(bs, dtype=np.int32)[None, :]      # plane j is byte 3-j
    for j in range(min(4, int(nbytes.max(initial=0)))):
        live = nbytes > j
        act = slice(None) if live.all() else np.flatnonzero(live)
        pj = planes[act, j, :]
        Lj = L[act]
        # L <= 3, so plane 3 (and any plane with no L > j value) is stored
        # verbatim for every live value -- no propagation pass needed
        if j >= 3 or not (Lj > j).any():
            wsb[act, :, 3 - j] = pj
            continue
        src = np.where(Lj <= j, idxs, np.int32(-1))
        np.maximum.accumulate(src, axis=1, out=src)    # index propagation
        byte = np.take_along_axis(pj, np.maximum(src, 0), axis=1)
        byte[src < 0] = 0
        wsb[act, :, 3 - j] = byte
    w = ws << shift[:, None].astype(np.uint32)
    v = w.view(np.float32)
    x = v + mu[:, None]
    return np.where((nbytes == 0)[:, None], mu[:, None], x)


def _unpack_dense_np(planes, mu, shift, nbytes):
    """All-``L==0`` fast path.  ``_unpack_np`` already degenerates to verbatim
    byte composition on every plane when no value has ``L > j``, so delegate
    with a broadcastable all-zero L instead of duplicating the loop (the real
    dense-path win is the jitted oracle, which drops the propagation scan)."""
    return _unpack_np(
        planes, mu, shift, nbytes, np.zeros((planes.shape[0], 1), np.int32)
    )


# --------------------------------------------------------------------------
# szx-planes numpy mirrors (bit-identical to ref.py)
# --------------------------------------------------------------------------

def _planes_encode_np(xb, num_planes):
    assert 1 <= num_planes <= 3, "szx-planes supports 1..3 byte planes"
    xb = np.asarray(xb, np.float32)
    mn = xb.min(axis=-1)
    mx = xb.max(axis=-1)
    mu = np.float32(0.5) * (mn + mx)
    radius = np.maximum(mx - mu, mu - mn)
    E = _np_exponent(radius)
    nbits = 8 * num_planes
    sexp = (nbits - 2) - E
    v = xb - mu[..., None]
    scale = np.exp2(sexp.astype(np.float32))[..., None]
    lim = np.float32(2.0 ** (nbits - 1))
    q = np.clip(np.rint(v * scale), -lim, lim - 1).astype(np.int32)
    uq = q.astype(np.uint32)
    planes = np.stack(
        [((uq >> np.uint32(8 * p)) & np.uint32(0xFF)).astype(np.uint8) for p in range(num_planes)],
        axis=0,
    )
    return mu, sexp, planes


def _planes_decode_np(mu, sexp, planes):
    num_planes = planes.shape[0]
    assert num_planes <= 3, "szx-planes supports 1..3 byte planes"
    nbits = 8 * num_planes
    uq = np.zeros(planes.shape[1:], np.int32)
    for p in range(num_planes):
        uq = uq | (planes[p].astype(np.int32) << (8 * p))
    q = np.where(uq >= (1 << (nbits - 1)), uq - (1 << nbits), uq).astype(np.float32)
    v = q * np.exp2(-np.asarray(sexp, np.int32).astype(np.float32))[..., None]
    return v + np.asarray(mu, np.float32)[..., None]


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

def block_stats(xb, e, *, backend: str = "auto"):
    backend = _resolve(backend)
    if backend == "numpy":
        return _block_stats_np(xb, e)
    if backend == "kernel":
        from repro.kernels import block_stats as k

        return k.block_stats(jnp.asarray(xb, jnp.float32), jnp.float32(e))
    return _block_stats_jax(jnp.asarray(xb, jnp.float32), jnp.float32(e))


def pack(xb, mu, shift, nbytes, *, backend: str = "auto"):
    backend = _resolve(backend)
    if backend == "numpy":
        return _pack_np(
            np.asarray(xb), np.asarray(mu), np.asarray(shift), np.asarray(nbytes)
        )
    if backend == "kernel":
        from repro.kernels import pack as k

        return k.pack(
            jnp.asarray(xb, jnp.float32),
            jnp.asarray(mu, jnp.float32),
            jnp.asarray(shift, jnp.int32),
            jnp.asarray(nbytes, jnp.int32),
        )
    return _pack_jax(
        jnp.asarray(xb, jnp.float32),
        jnp.asarray(mu, jnp.float32),
        jnp.asarray(shift, jnp.int32),
        jnp.asarray(nbytes, jnp.int32),
    )


def planes_encode(xb, num_planes: int, *, backend: str = "auto"):
    """szx-planes fixed-plane encode (see kernels.ref.planes_encode_ref).

    The jax path calls the oracle untraced -- in-graph callers (jit /
    shard_map / scan bodies) stage it into their own program; there is no
    Pallas kernel for planes yet, so 'kernel' also routes to the oracle.
    """
    if _resolve(backend) == "numpy":
        return _planes_encode_np(xb, num_planes)
    return ref.planes_encode_ref(jnp.asarray(xb, jnp.float32), num_planes)


def planes_decode(mu, sexp, planes, *, backend: str = "auto"):
    """Inverse of :func:`planes_encode`."""
    if _resolve(backend) == "numpy":
        return _planes_decode_np(mu, sexp, planes)
    return ref.planes_decode_ref(
        jnp.asarray(mu, jnp.float32), jnp.asarray(sexp, jnp.int32),
        jnp.asarray(planes, jnp.uint8),
    )


def unpack(planes, mu, shift, nbytes, L, *, backend: str = "auto"):
    backend = _resolve(backend)
    if backend == "numpy":
        return _unpack_np(
            np.asarray(planes),
            np.asarray(mu),
            np.asarray(shift),
            np.asarray(nbytes),
            np.asarray(L),
        )
    if backend == "kernel":
        from repro.kernels import unpack as k

        return k.unpack(
            jnp.asarray(planes, jnp.uint8),
            jnp.asarray(mu, jnp.float32),
            jnp.asarray(shift, jnp.int32),
            jnp.asarray(nbytes, jnp.int32),
            jnp.asarray(L, jnp.int32),
        )
    return _unpack_jax(
        jnp.asarray(planes, jnp.uint8),
        jnp.asarray(mu, jnp.float32),
        jnp.asarray(shift, jnp.int32),
        jnp.asarray(nbytes, jnp.int32),
        jnp.asarray(L, jnp.int32),
    )


def unpack_dense(planes, mu, shift, nbytes, *, backend: str = "auto"):
    """Batched fast path for frames whose L codes are all zero: every stored
    byte sits at its own value, so decode skips the per-byte index-propagation
    scan entirely.  Bit-identical to ``unpack(..., L=0)``.  There is no Pallas
    kernel for this path yet, so 'kernel' routes to the jitted oracle.
    """
    if _resolve(backend) == "numpy":
        return _unpack_dense_np(
            np.asarray(planes), np.asarray(mu), np.asarray(shift), np.asarray(nbytes)
        )
    return _unpack_dense_jax(
        jnp.asarray(planes, jnp.uint8),
        jnp.asarray(mu, jnp.float32),
        jnp.asarray(shift, jnp.int32),
        jnp.asarray(nbytes, jnp.int32),
    )
