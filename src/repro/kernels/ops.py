"""jit'd wrappers + backend dispatch for the width-generic SZx kernels.

Backends:
  'jax'    -- jnp oracle from ``ref.py`` under ``jax.jit`` (CPU default)
  'kernel' -- Pallas TPU kernels (``interpret=True`` automatically off-TPU)
  'numpy'  -- pure-numpy mirror (no jit/dispatch overhead; host-side use)
  'auto'   -- 'kernel' on TPU, 'jax' elsewhere (override with the
              ``SZX_OPS_BACKEND`` env var, e.g. to force the Pallas
              interpret path on CPU CI runners)

Every transform op takes a ``spec`` (:class:`repro.kernels.specs.DtypeSpec`,
default float32) and all three backends are bit-identical per spec.  float64
needs 64-bit words, which jax disables by default, so the jax/kernel routes
wrap those calls in ``jax.experimental.enable_x64``; on a real TPU (no 64-bit
words in hardware) the f64 'kernel' route falls through to the jitted oracle
with a one-time warning.

``encode`` is the fused stats+pack op: one traced program and a single
host<->device round trip instead of two, which is what the chunked codec hot
path stages per frame.
"""
from __future__ import annotations

import contextlib
import functools
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref, specs
from repro.kernels.specs import DtypeSpec

_WARNED: set = set()


def _warn_once(key: str, msg: str) -> None:
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)


def _resolve(backend: str) -> str:
    if backend == "auto":
        backend = os.environ.get("SZX_OPS_BACKEND") or (
            "kernel" if jax.default_backend() == "tpu" else "jax"
        )
    if backend not in ("jax", "kernel", "numpy"):
        raise ValueError(
            f"unknown SZx ops backend {backend!r}; "
            "expected 'jax', 'kernel', 'numpy', or 'auto'"
        )
    return backend


def _x64_scope(spec: DtypeSpec):
    """Context enabling 64-bit words for specs that need them (float64)."""
    if spec.needs_x64:
        from jax.experimental import enable_x64

        return enable_x64()
    return contextlib.nullcontext()


def _kernel_route(spec: DtypeSpec, op: str) -> bool:
    """True if the Pallas route can run this spec here; warn + False if not.

    TPUs have no 64-bit words, so native (non-interpret) f64 kernels cannot
    compile; everywhere else the kernels run (natively or interpreted).
    """
    if spec.needs_x64 and jax.default_backend() == "tpu":
        _warn_once(
            f"kernel-f64-{op}",
            f"SZx '{op}' has no 64-bit Pallas kernel on TPU; "
            "falling back to the jitted jnp oracle for float64",
        )
        return False
    return True


# --------------------------------------------------------------------------
# jit'd oracle paths (spec is static: one program per dtype geometry)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("spec",))
def _block_stats_jax(xb, e, p_e, spec):
    return ref.block_stats_ref(xb, e, spec, p_e)


@functools.partial(jax.jit, static_argnames=("spec",))
def _pack_jax(xb, mu, shift, nbytes, spec):
    return ref.pack_ref(xb, mu, shift, nbytes, spec)


@functools.partial(jax.jit, static_argnames=("spec",))
def _encode_jax(xb, e, p_e, spec):
    return ref.encode_ref(xb, e, spec, p_e)


@functools.partial(jax.jit, static_argnames=("spec",))
def _unpack_jax(planes, mu, shift, nbytes, L, spec):
    return ref.unpack_ref(planes, mu, shift, nbytes, L, spec)


@functools.partial(jax.jit, static_argnames=("spec",))
def _unpack_dense_jax(planes, mu, shift, nbytes, spec):
    return ref.unpack_dense_ref(planes, mu, shift, nbytes, spec)


# --------------------------------------------------------------------------
# numpy mirrors (bit-identical to ref.py, width-generic)
# --------------------------------------------------------------------------

def _np_exponent(x, spec: DtypeSpec = specs.F32):
    """Exponent field of |x| in the spec's COMPUTE dtype, bias removed."""
    cdt = spec.compute_np_dtype
    bits = np.asarray(x, cdt).view(spec.compute_uint_dtype)
    field = (bits >> np.asarray(spec.compute_mant_bits, spec.compute_uint_dtype)) & (
        (1 << spec.compute_exp_bits) - 1
    )
    return field.astype(np.int32) - spec.compute_exp_bias


def _to_compute(xb, spec: DtypeSpec):
    """Input -> storage-rounded -> compute dtype (no-copy when already there)."""
    return (
        np.asarray(xb)
        .astype(spec.np_dtype, copy=False)
        .astype(spec.compute_np_dtype, copy=False)
    )


def _block_stats_np(xb, e, spec: DtypeSpec, p_e: int | None = None):
    return _block_stats_np_c(_to_compute(xb, spec), e, spec, p_e)


def _block_stats_np_c(x, e, spec: DtypeSpec, p_e: int | None = None):
    cdt = spec.compute_np_dtype
    mn = x.min(axis=1)
    mx = x.max(axis=1)
    mu = (cdt.type(0.5) * (mn + mx)).astype(spec.np_dtype)
    mu_w = mu.astype(cdt)
    radius = np.maximum(mx - mu_w, mu_w - mn)
    r_test = radius
    if spec.stats_rounding_guard:
        # 16-bit formats: next-up radius keeps the constant-block bound
        # strict against the f32 subtraction rounding (see DtypeSpec)
        r_test = (
            radius.view(spec.compute_uint_dtype) + spec.compute_uint_dtype.type(1)
        ).view(cdt)
    const = r_test <= cdt.type(e)
    if p_e is None:
        p_e = specs.exact_exponent_of(float(e))
    req_m_raw = _np_exponent(radius, spec) - np.int32(p_e) + 1
    req_m = np.clip(req_m_raw, 0, spec.mant_bits)
    mu = np.where(req_m_raw > spec.mant_bits, np.zeros_like(mu), mu)  # verbatim
    reqlen = 1 + spec.exp_bits + req_m
    shift = (8 - reqlen % 8) % 8
    nbytes = (reqlen + shift) // 8
    z = np.zeros_like(reqlen)
    return (
        mu,
        radius,
        const,
        np.where(const, z, reqlen),
        np.where(const, z, shift),
        np.where(const, z, nbytes),
    )


def _pack_np(xb, mu, shift, nbytes, spec: DtypeSpec):
    return _pack_np_c(_to_compute(xb, spec), mu, shift, nbytes, spec)


def _pack_np_c(x, mu, shift, nbytes, spec: DtypeSpec):
    """Bit-identical to ``ref.pack_ref`` but allocation-lean: the shift runs
    in place on the normalized words and the XOR-lead run length is computed
    by byte-view equality against the predecessor (no xor word, no shifts)."""
    cdt = spec.compute_np_dtype
    udt = spec.uint_dtype
    itemsize = spec.itemsize
    nb, bs = x.shape
    mu_w = np.asarray(mu).astype(cdt, copy=False)
    v = x - mu_w[:, None]                  # fresh, contiguous
    if v.dtype != spec.np_dtype:
        v = v.astype(spec.np_dtype)        # storage-rounded residual
    ws = v.view(udt)
    np.right_shift(ws, shift[:, None].astype(udt), out=ws)
    # little-endian byte view: plane j (MSB-first) is byte itemsize-1-j -- no
    # shifts.  L counts how many leading bytes equal the predecessor's (the
    # first value compares against the zero word), capped at lead_cap.
    wsb = ws.view(np.uint8).reshape(nb, bs, itemsize)
    L = np.zeros((nb, bs), np.int32)
    run = np.empty((nb, bs), bool)
    eq = np.empty((nb, bs), bool)
    for j in range(spec.lead_cap):
        pj = wsb[:, :, itemsize - 1 - j]
        eq[:, 0] = pj[:, 0] == 0
        np.equal(pj[:, 1:], pj[:, :-1], out=eq[:, 1:])
        if j == 0:
            run[:] = eq
        else:
            run &= eq
        L += run
    np.minimum(L, nbytes[:, None], out=L)
    planes = np.ascontiguousarray(wsb[:, :, ::-1].transpose(0, 2, 1))
    mid = nbytes[:, None] - L
    return planes, L, mid


def _encode_np(xb, e, spec: DtypeSpec, p_e: int | None = None):
    """Fused mirror: the storage->compute upcast runs ONCE and feeds both
    stats and pack (the 16-bit dtypes otherwise pay the widening twice)."""
    x = _to_compute(xb, spec)
    mu, _radius, const, reqlen, shift, nbytes = _block_stats_np_c(x, e, spec, p_e)
    planes, L, _mid = _pack_np_c(x, mu, shift, nbytes, spec)
    return mu, const, reqlen, shift, nbytes, planes, L


def _finish_unpack_np(ws, mu, shift, nbytes, spec: DtypeSpec, out=None):
    """Shared tail of the numpy unpack mirrors: Solution-C shift back,
    bitcast, mu add, constant-block fill.  ``ws`` is consumed (shifted in
    place).  With ``out`` the reconstruction lands in the caller's buffer --
    for f32/f64 the mu add itself writes there, dropping the frame-sized
    temporary entirely."""
    udt = spec.uint_dtype
    np.left_shift(ws, shift[:, None].astype(udt), out=ws)
    v = ws.view(spec.np_dtype)
    cdt = spec.compute_np_dtype
    mu_w = np.asarray(mu).astype(cdt, copy=False)
    if out is not None and np.dtype(cdt) == np.dtype(spec.np_dtype):
        x = np.add(v, mu_w[:, None], out=out)
    else:
        x = (v.astype(cdt, copy=False) + mu_w[:, None]).astype(
            spec.np_dtype, copy=False
        )
    constm = nbytes == 0
    if out is None:
        return np.where(constm[:, None], np.asarray(mu)[:, None], x)
    if x is not out:
        np.copyto(out, x)
    if constm.any():
        out[constm] = np.asarray(mu)[constm, None]
    return out


def _unpack16_np(planes, mu, shift, nbytes, L, spec: DtypeSpec, out=None):
    """2-plane (float16/bfloat16) specialization of ``_unpack_np``.

    The generic loop pays per-plane index compression (``flatnonzero`` +
    fancy gathers) and strided byte-view scatters that dominate 16-bit decode
    time.  With exactly two planes the word composes arithmetically:
    propagate each plane only when some value actually elides it, then
    ``msb << 8 | lsb`` -- full-width masked ops, no index arrays, one
    contiguous word write.  Bit-identical to the generic path."""
    nb, _, bs = planes.shape
    msb = planes[:, 0, :]
    lsb = planes[:, 1, :]
    live0 = (nbytes > 0)[:, None]
    live1 = (nbytes > 1)[:, None]
    idxs256 = (np.arange(bs, dtype=np.int32) << 8)[None, :]
    if (L > 0).any():
        key = np.where((L <= 0) & live0, idxs256 | msb, np.int32(-1))
        np.maximum.accumulate(key, axis=1, out=key)
        b0 = (key & 0xFF).astype(np.uint16)
        b0[key < 0] = 0
    else:
        b0 = np.where(live0, msb, 0).astype(np.uint16)
    if (L > 1).any():
        key = np.where((L <= 1) & live1, idxs256 | lsb, np.int32(-1))
        np.maximum.accumulate(key, axis=1, out=key)
        b1 = (key & 0xFF).astype(np.uint16)
        b1[key < 0] = 0
    else:
        b1 = np.where(live1, lsb, 0).astype(np.uint16)
    ws = (b0 << np.uint16(8)) | b1
    return _finish_unpack_np(ws, mu, shift, nbytes, spec, out)


def _unpack_np(planes, mu, shift, nbytes, L, spec: DtypeSpec, out=None):
    """Bit-identical to ``ref.unpack_ref`` but byte-oriented: planes are written
    straight into a little-endian word byte view, index propagation runs only
    on planes that actually need it (some value has ``L > j``) and only over
    blocks where the plane is live (``nbytes > j``).  The propagation itself
    is the fused-key trick of the Pallas kernel: one cumulative max over
    ``idx*256 + byte`` (idx dominates, so the surviving key carries the byte
    of the nearest preceding stored position) -- no gather pass."""
    if spec.itemsize == 2:
        return _unpack16_np(planes, mu, shift, nbytes, L, spec, out)
    udt = spec.uint_dtype
    itemsize = spec.itemsize
    nb, _, bs = planes.shape
    ws = np.zeros((nb, bs), udt)
    wsb = ws.view(np.uint8).reshape(nb, bs, itemsize)  # little-endian host:
    idxs256 = (np.arange(bs, dtype=np.int32) << 8)[None, :]  # plane j is byte
    for j in range(min(itemsize, int(nbytes.max(initial=0)))):   # W-1-j
        live = nbytes > j
        act = slice(None) if live.all() else np.flatnonzero(live)
        pj = planes[act, j, :]
        Lj = L[act]
        # L <= lead_cap, so planes past it (and any plane with no L > j value)
        # are stored verbatim for every live value -- no propagation pass
        if j >= spec.lead_cap or not (Lj > j).any():
            wsb[act, :, itemsize - 1 - j] = pj
            continue
        key = np.where(Lj <= j, idxs256 | pj, np.int32(-1))
        np.maximum.accumulate(key, axis=1, out=key)    # index propagation
        byte = (key & 0xFF).astype(np.uint8)
        byte[key < 0] = 0
        wsb[act, :, itemsize - 1 - j] = byte
    return _finish_unpack_np(ws, mu, shift, nbytes, spec, out)


def _unpack_dense_np(planes, mu, shift, nbytes, spec: DtypeSpec, out=None):
    """All-``L==0`` fast path.  ``_unpack_np`` already degenerates to verbatim
    byte composition on every plane when no value has ``L > j``, so delegate
    with a broadcastable all-zero L instead of duplicating the loop (the real
    dense-path win is the jitted oracle, which drops the propagation scan)."""
    return _unpack_np(
        planes, mu, shift, nbytes, np.zeros((planes.shape[0], 1), np.int32),
        spec, out,
    )


# --------------------------------------------------------------------------
# szx-planes numpy mirrors (bit-identical to ref.py)
# --------------------------------------------------------------------------

def _planes_encode_np(xb, num_planes):
    assert 1 <= num_planes <= 3, "szx-planes supports 1..3 byte planes"
    xb = np.asarray(xb, np.float32)
    mn = xb.min(axis=-1)
    mx = xb.max(axis=-1)
    mu = np.float32(0.5) * (mn + mx)
    radius = np.maximum(mx - mu, mu - mn)
    E = _np_exponent(radius)
    nbits = 8 * num_planes
    sexp = (nbits - 2) - E
    v = xb - mu[..., None]
    scale = np.exp2(sexp.astype(np.float32))[..., None]
    lim = np.float32(2.0 ** (nbits - 1))
    q = np.clip(np.rint(v * scale), -lim, lim - 1).astype(np.int32)
    uq = q.astype(np.uint32)
    planes = np.stack(
        [((uq >> np.uint32(8 * p)) & np.uint32(0xFF)).astype(np.uint8) for p in range(num_planes)],
        axis=0,
    )
    return mu, sexp, planes


def _planes_decode_np(mu, sexp, planes):
    num_planes = planes.shape[0]
    assert num_planes <= 3, "szx-planes supports 1..3 byte planes"
    nbits = 8 * num_planes
    uq = np.zeros(planes.shape[1:], np.int32)
    for p in range(num_planes):
        uq = uq | (planes[p].astype(np.int32) << (8 * p))
    q = np.where(uq >= (1 << (nbits - 1)), uq - (1 << nbits), uq).astype(np.float32)
    v = q * np.exp2(-np.asarray(sexp, np.int32).astype(np.float32))[..., None]
    return v + np.asarray(mu, np.float32)[..., None]


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

def _as_words(xb, spec: DtypeSpec):
    return jnp.asarray(xb, spec.np_dtype)


def block_stats(xb, e, *, spec: DtypeSpec = specs.F32, backend: str = "auto"):
    backend = _resolve(backend)
    if backend == "numpy":
        return _block_stats_np(xb, e, spec)
    p_e = specs.exact_exponent_of(float(e))
    with _x64_scope(spec):
        if backend == "kernel" and _kernel_route(spec, "block_stats"):
            from repro.kernels import block_stats as k

            return k.block_stats(
                _as_words(xb, spec),
                jnp.asarray(float(e), spec.compute_np_dtype),
                jnp.int32(p_e),
                spec=spec,
            )
        return _block_stats_jax(
            _as_words(xb, spec),
            jnp.asarray(float(e), spec.compute_np_dtype),
            jnp.int32(p_e),
            spec,
        )


def pack(xb, mu, shift, nbytes, *, spec: DtypeSpec = specs.F32, backend: str = "auto"):
    backend = _resolve(backend)
    if backend == "numpy":
        return _pack_np(
            np.asarray(xb), np.asarray(mu), np.asarray(shift), np.asarray(nbytes),
            spec,
        )
    with _x64_scope(spec):
        args = (
            _as_words(xb, spec),
            _as_words(mu, spec),
            jnp.asarray(shift, jnp.int32),
            jnp.asarray(nbytes, jnp.int32),
        )
        if backend == "kernel" and _kernel_route(spec, "pack"):
            from repro.kernels import pack as k

            return k.pack(*args, spec=spec)
        return _pack_jax(*args, spec)


def encode_staged(xb, e, p_e, *, spec: DtypeSpec = specs.F32, backend: str = "jax"):
    """Trace-composable fused encode: dispatch WITHOUT host syncs.

    For callers that stage the encode into a larger jitted program (the
    device-resident stream assembly in ``repro.core.codec.device``): the
    error-bound exponent ``p_e`` is passed in as a traced value instead of
    being derived via ``float(e)``, and ``backend`` must already be resolved
    to 'jax' or 'kernel'.  Same outputs as :func:`encode`.
    """
    if backend not in ("jax", "kernel"):
        raise ValueError(
            f"encode_staged needs a resolved device backend, got {backend!r}"
        )
    if backend == "kernel" and _kernel_route(spec, "encode"):
        from repro.kernels import encode as k

        return k.encode(xb, e, p_e, spec=spec)
    return ref.encode_ref(xb, e, spec, p_e)


def decode_staged(body, nnc, lo=0, *, spec: DtypeSpec = specs.F32, nb: int,
                  bs: int, rb: int | None = None, rebase: bool = False,
                  backend: str = "jax"):
    """Trace-composable fused stream decode: dispatch WITHOUT host syncs.

    The decode mirror of :func:`encode_staged`, for callers that stage the
    decode into a larger jitted program (the device-resident container parse
    in ``repro.core.codec.device``).  ``body`` is the raw stream body (40-byte
    header stripped, zero-padded to a static capacity); ``nnc`` the header's
    n_nonconst field and ``lo`` the first decoded block, both traced; ``nb``/
    ``bs``/``rb`` static.  Parses the metadata sections on device
    (``ref.parse_body_ref``) then runs the fused unpack+compose -- the
    single-``pallas_call`` kernel on the 'kernel' route, the jnp oracle on
    'jax'.  Returns (vals (rb, bs), measured (3,) int32): the bitmap's
    nonconst count, the max per-block nbytes, and the L-implied mid-stream
    total -- checked against the header fields on the host after its single
    readback (the device-side half of ``container.parse_stream`` validation).
    """
    if backend not in ("jax", "kernel"):
        raise ValueError(
            f"decode_staged needs a resolved device backend, got {backend!r}"
        )
    if rb is None:
        rb = nb
    _const, mu, shift, nbytes, rank, nnc_seen = ref.parse_body_ref(
        body, nnc, spec, nb
    )
    if backend == "kernel" and _kernel_route(spec, "decode"):
        from repro.kernels import decode as k

        vals, mid_total = k.decode_body(
            body, nnc, lo, mu, shift, nbytes, rank,
            spec=spec, bs=bs, rb=rb, rebase=rebase,
        )
    else:
        vals, mid_total = ref.decode_body_ref(
            body, nnc, lo, mu, shift, nbytes, rank, spec,
            bs=bs, rb=rb, rebase=rebase,
        )
    measured = jnp.stack(
        [nnc_seen, jnp.max(nbytes).astype(jnp.int32), mid_total]
    )
    return vals, measured


def encode(xb, e, *, spec: DtypeSpec = specs.F32, backend: str = "auto"):
    """Fused block_stats + pack: (mu, const, reqlen, shift, nbytes, planes, L).

    One dispatched program (and for the jax/kernel routes a single
    host<->device round trip) instead of the two-call stats-then-pack
    sequence; bit-identical to calling :func:`block_stats` + :func:`pack`.
    """
    backend = _resolve(backend)
    if backend == "numpy":
        return _encode_np(xb, e, spec)
    p_e = specs.exact_exponent_of(float(e))
    with _x64_scope(spec):
        args = (
            _as_words(xb, spec),
            jnp.asarray(float(e), spec.compute_np_dtype),
            jnp.int32(p_e),
        )
        if backend == "kernel" and _kernel_route(spec, "encode"):
            from repro.kernels import encode as k

            return k.encode(*args, spec=spec)
        return _encode_jax(*args, spec)


def unpack(planes, mu, shift, nbytes, L, *, spec: DtypeSpec = specs.F32,
           backend: str = "auto", out=None):
    """Inverse of :func:`pack`.  With ``out`` (a (nb, bs) array in the spec's
    dtype) the reconstruction is written into the caller's buffer and ``out``
    is returned -- allocation-free on the numpy route, one copy elsewhere."""
    backend = _resolve(backend)
    if backend == "numpy":
        return _unpack_np(
            np.asarray(planes),
            np.asarray(mu),
            np.asarray(shift),
            np.asarray(nbytes),
            np.asarray(L),
            spec,
            out,
        )
    with _x64_scope(spec):
        args = (
            jnp.asarray(np.asarray(planes), jnp.uint8),
            _as_words(mu, spec),
            jnp.asarray(shift, jnp.int32),
            jnp.asarray(nbytes, jnp.int32),
            jnp.asarray(L, jnp.int32),
        )
        if backend == "kernel" and _kernel_route(spec, "unpack"):
            from repro.kernels import unpack as k

            res = k.unpack(*args, spec=spec)
        else:
            res = _unpack_jax(*args, spec)
    if out is not None:
        np.copyto(out, np.asarray(res))
        return out
    return res


def unpack_dense(planes, mu, shift, nbytes, *, spec: DtypeSpec = specs.F32,
                 backend: str = "auto", out=None):
    """Batched fast path for frames whose L codes are all zero: every stored
    byte sits at its own value, so decode skips the per-byte index-propagation
    scan entirely.  Bit-identical to ``unpack(..., L=0)``.
    """
    backend = _resolve(backend)
    if backend == "numpy":
        return _unpack_dense_np(
            np.asarray(planes), np.asarray(mu), np.asarray(shift),
            np.asarray(nbytes), spec, out,
        )
    with _x64_scope(spec):
        args = (
            jnp.asarray(np.asarray(planes), jnp.uint8),
            _as_words(mu, spec),
            jnp.asarray(shift, jnp.int32),
            jnp.asarray(nbytes, jnp.int32),
        )
        if backend == "kernel" and _kernel_route(spec, "unpack_dense"):
            from repro.kernels import unpack as k

            res = k.unpack_dense(*args, spec=spec)
        else:
            res = _unpack_dense_jax(*args, spec)
    if out is not None:
        np.copyto(out, np.asarray(res))
        return out
    return res


def unpack_range(planes, mu, shift, nbytes, L, lo: int, hi: int, *,
                 spec: DtypeSpec = specs.F32, backend: str = "auto"):
    """Partial decode of blocks [lo, hi): the ROI read primitive.

    Slices every per-block operand to the range, then dispatches the same
    width-generic ``unpack``/``unpack_dense`` pair -- so the partial decode
    is bit-identical to ``unpack(...)[lo:hi]`` on every backend (jax /
    kernel / numpy) at O(hi - lo) cost, and ranges with no XOR-lead elision
    take the dense fast path like full frames do.
    """
    nb = np.asarray(mu).shape[0]
    if not 0 <= lo < hi <= nb:
        raise ValueError(f"block range [{lo}, {hi}) out of [0, {nb})")
    L_r = L[lo:hi]
    args = (planes[lo:hi], mu[lo:hi], shift[lo:hi], nbytes[lo:hi])
    if not np.asarray(L_r).any():
        return unpack_dense(*args, spec=spec, backend=backend)
    return unpack(*args, L_r, spec=spec, backend=backend)


def planes_encode(xb, num_planes: int, *, backend: str = "auto"):
    """szx-planes fixed-plane encode (see kernels.ref.planes_encode_ref).

    The jax path calls the oracle untraced -- in-graph callers (jit /
    shard_map / scan bodies) stage it into their own program.  'kernel'
    dispatches the Pallas kernel (``repro.kernels.planes``).
    """
    backend = _resolve(backend)
    if backend == "numpy":
        return _planes_encode_np(xb, num_planes)
    if backend == "kernel":
        from repro.kernels import planes as k

        return k.planes_encode(jnp.asarray(xb, jnp.float32), num_planes)
    return ref.planes_encode_ref(jnp.asarray(xb, jnp.float32), num_planes)


def planes_decode(mu, sexp, planes, *, backend: str = "auto"):
    """Inverse of :func:`planes_encode`."""
    backend = _resolve(backend)
    if backend == "numpy":
        return _planes_decode_np(mu, sexp, planes)
    if backend == "kernel":
        from repro.kernels import planes as k

        return k.planes_decode(
            jnp.asarray(mu, jnp.float32), jnp.asarray(sexp, jnp.int32),
            jnp.asarray(planes, jnp.uint8),
        )
    return ref.planes_decode_ref(
        jnp.asarray(mu, jnp.float32), jnp.asarray(sexp, jnp.int32),
        jnp.asarray(planes, jnp.uint8),
    )


# --------------------------------------------------------------------------
# bitplane shuffle (second-stage transform)
# --------------------------------------------------------------------------

def _bitshuffle_np(tiles, inverse):
    """numpy mirror of ``ref.bitshuffle_ref`` (independent ground truth:
    built on np.unpackbits/np.packbits instead of the shared jnp body)."""
    tiles = np.ascontiguousarray(tiles, np.uint8)
    nt, T = tiles.shape
    if T % 8:
        raise ValueError(f"bitshuffle tile width {T} is not a multiple of 8")
    if nt == 0:
        return tiles.copy()
    bits = np.unpackbits(tiles, axis=1, bitorder="little").reshape(nt, T, 8)
    if inverse:
        bits = bits.reshape(nt, 8, T // 8, 8).transpose(0, 2, 3, 1)
    else:
        bits = bits.transpose(0, 2, 1)
    return np.packbits(bits.reshape(nt, T * 8), axis=1, bitorder="little")


@functools.partial(jax.jit, static_argnames=("inverse",))
def _bitshuffle_jax(tiles, inverse):
    return ref.bitshuffle_ref(tiles, inverse=inverse)


def bitshuffle(tiles, *, spec: DtypeSpec = specs.F32, inverse: bool = False,
               backend: str = "auto"):
    """Bit-transpose uint8 tiles of ``bitshuffle.tile_bytes(spec)`` bytes.

    ``tiles``: (nt, tile_bytes) uint8.  Forward groups bit k of every tile
    byte contiguously; ``inverse=True`` is the exact inverse.  All three
    backends are bit-identical (the second-stage container bytes must not
    depend on the backend).
    """
    backend = _resolve(backend)
    if backend == "numpy":
        return _bitshuffle_np(np.asarray(tiles), inverse)
    if backend == "kernel" and _kernel_route(spec, "bitshuffle"):
        from repro.kernels import bitshuffle as k

        return k.bitshuffle(
            jnp.asarray(tiles, jnp.uint8), spec=spec, inverse=inverse
        )
    return _bitshuffle_jax(jnp.asarray(tiles, jnp.uint8), inverse)
