"""Pallas TPU kernel: bitplane shuffle (bit transpose) over byte tiles.

The lossless second stage (``repro.core.codec.stage``) groups a block range's
mid bytes into byteplane-major order and then bit-transposes fixed-size
tiles so that bit k of every byte in a tile lands contiguously -- turning the
per-value "top magnitude bits rarely set / Solution-C shift pad bits always
zero" structure into long zero runs an RLE can consume (FZ-GPU's
bitshuffle+sparsification, PAPERS.md).

Geometry: a tile is ``TILE_VALUES * spec.itemsize`` bytes (one Pallas grid
step handles ``TILE_ROWS`` tiles).  Within a tile the transform is the
classic bitshuffle involution pair: ``(T, 8)`` little-endian bit matrix ->
transpose -> repack, so ``bitunshuffle(bitshuffle(x)) == x`` for every tile
independently -- tiles never mix, which is what keeps the stage addressable
per ROI block range.  The jnp oracle in ``ref.py`` and the numpy mirror in
``ops.py`` are bit-identical to this kernel (pinned by tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import specs
from repro.kernels.specs import DtypeSpec

TILE_VALUES = 1024          # values per tile; tile bytes = TILE_VALUES * itemsize
TILE_ROWS = 8               # tiles per grid step


def tile_bytes(spec: DtypeSpec) -> int:
    """Bytes per shuffle tile for this dtype geometry (multiple of 8)."""
    return TILE_VALUES * spec.itemsize


def shuffle_body(t, *, inverse: bool):
    """Trace-time bit transpose of ``(rows, T)`` uint8 tiles (T % 8 == 0).

    Forward: out bit-row k holds bit k of every input byte (little-endian
    packing, matching ``np.packbits(..., bitorder='little')``).  ``inverse``
    runs the exact inverse permutation.
    """
    rows, T = t.shape
    k = jnp.arange(8, dtype=jnp.uint8)
    bits = (t[:, :, None] >> k) & jnp.uint8(1)          # (rows, T, 8)
    if inverse:
        # forward wrote (8, T) row-major; read it back as (T, 8)
        bits = bits.reshape(rows, 8, T // 8, 8)
        bits = bits.transpose(0, 2, 3, 1).reshape(rows, T, 8)
    else:
        bits = bits.transpose(0, 2, 1).reshape(rows, T, 8)
    weights = (jnp.uint8(1) << k)                        # little-endian pack
    return (bits * weights).sum(axis=-1, dtype=jnp.int32).astype(jnp.uint8)


def _make_kernel(inverse: bool):
    def _kernel(t_ref, out_ref):
        out_ref[...] = shuffle_body(t_ref[...], inverse=inverse)

    return _kernel


@functools.partial(jax.jit, static_argnames=("spec", "inverse", "interpret"))
def bitshuffle(tiles, *, spec: DtypeSpec = specs.F32, inverse: bool = False,
               interpret: bool | None = None):
    """Bit-transpose ``(nt, tile_bytes(spec))`` uint8 tiles (Pallas route)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nt, T = tiles.shape
    if T != tile_bytes(spec):
        raise ValueError(
            f"bitshuffle tile width {T} != tile_bytes({spec.name}) = "
            f"{tile_bytes(spec)}"
        )
    if nt == 0:
        return jnp.zeros((0, T), jnp.uint8)
    pad = (-nt) % TILE_ROWS
    if pad:
        tiles = jnp.pad(tiles, ((0, pad), (0, 0)))
    ntp = nt + pad
    out = pl.pallas_call(
        _make_kernel(inverse),
        grid=(ntp // TILE_ROWS,),
        in_specs=[pl.BlockSpec((TILE_ROWS, T), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_ROWS, T), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ntp, T), jnp.uint8),
        interpret=interpret,
    )(tiles)
    return out[:nt]
