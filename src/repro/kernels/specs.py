"""Width-generic dtype specifications for the SZx kernel layer.

One :class:`DtypeSpec` carries everything the transform needs to run on a
given IEEE-754 float format: the on-stream dtype ``code`` (container header
byte), the storage word geometry (``itemsize``/``exp_bits``/``mant_bits``),
and the *compute* geometry -- the dtype the per-block statistics run in.

Storage vs compute dtype
------------------------
Stats (min/max/mu/radius) run in the **compute dtype**: float32 for words of
up to 4 bytes, float64 for float64.  The two 16-bit formats are exact subsets
of float32, so their stats lose nothing to the upcast while staying
expressible on accelerators that have no 64-bit words.  The binary exponent
``p(x) = floor(log2 x)`` is read from the compute dtype's exponent bit field
(conservative ``-bias`` for zero/subnormals, exactly like the original f32
path); the scalar error-bound exponent ``p(e)`` is computed exactly on the
host (``math.frexp``) and passed into the kernels.

float64 needs 64-bit words, which jax disables by default; the dispatch layer
(``repro.kernels.ops``) wraps those calls in ``jax.experimental.enable_x64``.
This module is the bottom of the stack: it must not import from
``repro.core``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

try:  # bfloat16 is a numpy extension dtype shipped by ml_dtypes (a jax dep)
    import ml_dtypes

    _BFLOAT16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes ships with jax
    _BFLOAT16 = None


@dataclass(frozen=True)
class DtypeSpec:
    """IEEE-754 geometry of one supported input dtype.

    ``code`` is the on-stream dtype id (container header byte); the remaining
    fields parameterize the width-generic transform: required-bit computation
    uses ``exp_bits``/``mant_bits``, the byte-plane split uses ``itemsize``.
    Instances are frozen and hashable, so they are valid jit static args.
    """

    code: int
    name: str
    np_dtype: np.dtype
    uint_dtype: np.dtype
    itemsize: int
    exp_bits: int
    mant_bits: int
    exp_bias: int

    @property
    def word_bits(self) -> int:
        return 8 * self.itemsize

    @property
    def lead_cap(self) -> int:
        """Max XOR-lead elision count: the 2-bit L code caps at 3, a 2-byte
        word at its own plane count."""
        return min(3, self.itemsize)

    # ------------------------------------------------------------ compute side
    @property
    def needs_x64(self) -> bool:
        return self.itemsize == 8

    @property
    def compute_np_dtype(self) -> np.dtype:
        return np.dtype(np.float64) if self.itemsize == 8 else np.dtype(np.float32)

    @property
    def compute_uint_dtype(self) -> np.dtype:
        return np.dtype(np.uint64) if self.itemsize == 8 else np.dtype(np.uint32)

    @property
    def compute_mant_bits(self) -> int:
        return 52 if self.itemsize == 8 else 23

    @property
    def compute_exp_bits(self) -> int:
        return 11 if self.itemsize == 8 else 8

    @property
    def compute_exp_bias(self) -> int:
        return 1023 if self.itemsize == 8 else 127

    @property
    def stats_rounding_guard(self) -> bool:
        """True for the 16-bit formats, whose stats run in a WIDER compute
        dtype: the radius subtraction can still round below the true block
        deviation (f32 holds any f16/bf16 value exactly, but not every
        difference of two of them), so the constant-block test compares the
        next-representable-up radius against ``e`` to keep the bound strict.
        f32/f64 compute in their own width and keep the paper's exact-width
        semantics (f32 is golden-bytes pinned)."""
        return self.compute_np_dtype != self.np_dtype


F32 = DtypeSpec(0, "float32", np.dtype(np.float32), np.dtype(np.uint32), 4, 8, 23, 127)
F64 = DtypeSpec(1, "float64", np.dtype(np.float64), np.dtype(np.uint64), 8, 11, 52, 1023)
F16 = DtypeSpec(2, "float16", np.dtype(np.float16), np.dtype(np.uint16), 2, 5, 10, 15)

SPECS = [F32, F64, F16]
if _BFLOAT16 is not None:
    BF16 = DtypeSpec(3, "bfloat16", _BFLOAT16, np.dtype(np.uint16), 2, 8, 7, 127)
    SPECS.append(BF16)
else:  # pragma: no cover - ml_dtypes ships with jax
    BF16 = None

BY_CODE = {s.code: s for s in SPECS}
BY_DTYPE = {s.np_dtype: s for s in SPECS}


def spec_for(dtype) -> DtypeSpec:
    spec = BY_DTYPE.get(np.dtype(dtype))
    if spec is None:
        raise TypeError(
            f"unsupported dtype {np.dtype(dtype)}; supported: "
            + ", ".join(s.name for s in SPECS)
        )
    return spec


def spec_for_code(code: int) -> DtypeSpec:
    spec = BY_CODE.get(int(code))
    if spec is None:
        raise ValueError(f"unknown dtype code {code} in SZx stream")
    return spec


def exact_exponent_of(e: float) -> int:
    """Exact floor(log2 e) of a positive python float (Formula 4's p(e))."""
    m, ex = math.frexp(e)  # e = m * 2**ex with 0.5 <= m < 1
    return ex - 1
