"""Pallas TPU kernel: fused SZx encode (block stats + pack in ONE kernel).

The two-call pipeline (``block_stats`` then ``pack``) reads the input tile
from VMEM twice and, driven from the host, costs two program dispatches and
two host<->device round trips per chunk.  This kernel fuses paper Algorithm 1
lines 3-9: each (TILE_BLOCKS, bs) tile is loaded once, the per-block stats
(min/max/mu/radius/reqlen/shift/nbytes) are computed on the VPU lane
reductions, and the SAME resident tile is immediately normalized, shifted
(Solution C), XOR-lead counted, and split into byte planes.  Width-generic
via :class:`repro.kernels.specs.DtypeSpec`, like the unfused kernels.

Outputs are exactly the fields the container serializes:
(mu, const, reqlen, shift, nbytes, planes, L) -- bit-identical to the
two-call sequence (``ref.encode_ref`` is the oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import specs
from repro.kernels.specs import DtypeSpec

TILE_BLOCKS = 8


def _make_kernel(spec: DtypeSpec):
    from repro.kernels.block_stats import stats_body
    from repro.kernels.pack import pack_body, plane_byte

    def _kernel(e_ref, pe_ref, x_ref, mu_ref, const_ref, reqlen_ref, shift_ref,
                nbytes_ref, planes_ref, L_ref):
        x = x_ref[...]                                   # (TB, bs) storage dtype
        # stats (Alg. 1 lines 3-7) then pack (lines 8-9) on the SAME resident
        # tile -- both bodies are the exact trace-time functions the unfused
        # kernels run, so fused == two-call bit-identity holds by construction
        mu, _r, const, reqlen, shift, nbytes = stats_body(
            spec, x, e_ref[0], pe_ref[0]
        )
        ws, L, _mid = pack_body(spec, x, mu, shift, nbytes)
        for j in range(spec.itemsize):
            planes_ref[:, j, :] = plane_byte(spec, ws, j)
        mu_ref[...] = mu
        const_ref[...] = const.astype(jnp.int32)
        reqlen_ref[...] = reqlen
        shift_ref[...] = shift
        nbytes_ref[...] = nbytes
        L_ref[...] = L

    return _kernel


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def encode(xb: jax.Array, e: jax.Array, p_e: jax.Array, *,
           spec: DtypeSpec = specs.F32, interpret: bool | None = None):
    """Fused stats+pack -> (mu, const, reqlen, shift, nbytes, planes, L).

    Bit-identical to ``block_stats`` followed by ``pack`` (oracle:
    ``ref.encode_ref``); one kernel launch, one read of the input tile.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, bs = xb.shape
    if nb == 0:
        z = jnp.zeros((0,), jnp.int32)
        return (jnp.zeros((0,), spec.np_dtype), jnp.zeros((0,), bool), z, z, z,
                jnp.zeros((0, spec.itemsize, bs), jnp.uint8),
                jnp.zeros((0, bs), jnp.int32))
    pad = (-nb) % TILE_BLOCKS
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
    nbp = nb + pad
    grid = (nbp // TILE_BLOCKS,)
    vec = pl.BlockSpec((TILE_BLOCKS,), lambda i: (i,))
    tile = pl.BlockSpec((TILE_BLOCKS, bs), lambda i: (i, 0))
    mu, const, reqlen, shift, nbytes, planes, L = pl.pallas_call(
        _make_kernel(spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),                  # e (broadcast)
            pl.BlockSpec((1,), lambda i: (0,)),                  # p_e (broadcast)
            tile,                                                # x tile in VMEM
        ],
        out_specs=(
            vec, vec, vec, vec, vec,
            pl.BlockSpec((TILE_BLOCKS, spec.itemsize, bs), lambda i: (i, 0, 0)),
            tile,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nbp,), spec.np_dtype),         # mu
            jax.ShapeDtypeStruct((nbp,), jnp.int32),             # const flag
            jax.ShapeDtypeStruct((nbp,), jnp.int32),             # reqlen
            jax.ShapeDtypeStruct((nbp,), jnp.int32),             # shift
            jax.ShapeDtypeStruct((nbp,), jnp.int32),             # nbytes
            jax.ShapeDtypeStruct((nbp, spec.itemsize, bs), jnp.uint8),
            jax.ShapeDtypeStruct((nbp, bs), jnp.int32),          # L
        ),
        interpret=interpret,
    )(
        jnp.reshape(e.astype(spec.compute_np_dtype), (1,)),
        jnp.reshape(p_e.astype(jnp.int32), (1,)),
        xb,
    )
    sl = slice(0, nb)
    return (mu[sl], const[sl].astype(bool), reqlen[sl], shift[sl], nbytes[sl],
            planes[sl], L[sl])
