"""Pallas TPU kernels for the SZx hot loops + pure-jnp oracles.

All transform kernels are width-generic: parameterized by a
:class:`repro.kernels.specs.DtypeSpec` so one implementation covers
float32/float64/float16/bfloat16 (f64 needs 64-bit words; the dispatch layer
runs it under ``jax.experimental.enable_x64`` and falls back to the jitted
oracle on real TPUs, which have no 64-bit words).

Modules:
  specs.py       -- DtypeSpec: storage + compute IEEE-754 geometry
  ref.py         -- pure-jnp oracles (ground truth)
  block_stats.py -- per-block min/max/mu/radius/reqlen (Alg. 1 lines 3-7)
  pack.py        -- normalize + Solution-C shift + XOR-lead + byte planes
  encode.py      -- FUSED stats+pack (one kernel, one round trip per chunk)
  unpack.py      -- decompression with log-time index propagation (Fig. 9)
                    + the all-L==0 dense fast path
  planes.py      -- szx-planes fixed-plane encode/decode (in-graph mode)
  ops.py         -- jit'd wrappers + backend dispatch
"""
