"""Pallas TPU kernels for the SZx hot loops + pure-jnp oracles.

Modules:
  ref.py         -- pure-jnp oracles (ground truth)
  block_stats.py -- per-block min/max/mu/radius/reqlen (Alg. 1 lines 3-7)
  pack.py        -- normalize + Solution-C shift + XOR-lead + byte planes
  unpack.py      -- decompression with log-time index propagation (Fig. 9)
  ops.py         -- jit'd wrappers + backend dispatch
"""
