"""Pallas kernel: fused SZx stream decode (unpack + compose in ONE kernel).

The inverse of ``encode.py`` at the stream level: one ``pallas_call`` takes
the raw container body bytes (header stripped, zero-padded to a static
capacity) plus the per-block metadata vectors parsed on device by
``ref.parse_body_ref`` and produces the reconstructed values directly --
2-bit L-code expansion, exclusive-cumsum ``nbytes - L`` mid-stream offsets,
gathered byte compose, XOR-lead/shift reconstruction, and the mu add, with
no intermediate planes array ever materialized.

The mid-offset cumsum couples every block to its predecessors, so the kernel
runs gridless over the whole chunk (the chunk IS the tile; chunked codecs
bound it to a few MB).  Width-generic via :class:`repro.kernels.specs
.DtypeSpec`; the index propagation is the same interleaved pad-shift-max
scan as ``unpack.py``, so all three backends stay bit-identical
(``ref.decode_body_ref`` is the oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import specs
from repro.kernels.specs import DtypeSpec
from repro.kernels.unpack import _compose


def _make_kernel(spec: DtypeSpec, nb: int, bs: int, rb: int, rebase: bool):
    W = spec.itemsize
    nbm = (nb + 7) // 8
    req_off = nbm + W * nb
    udt = spec.uint_dtype

    def _kernel(body_ref, nnc_ref, lo_ref, mu_ref, shift_ref, nbytes_ref,
                rank_ref, out_ref, mid_total_ref):
        body = body_ref[...]
        nnc = nnc_ref[0]
        lo = lo_ref[0]
        rank = rank_ref[...]
        nbytes = nbytes_ref[...]
        cap = body.shape[0]
        l_off = req_off + nnc
        mid_off = l_off + (nnc * bs + 3) // 4
        # 2-bit L codes (little-endian 4/byte, compacted over non-const blocks)
        pos = rank[:, None] * bs + jnp.arange(bs, dtype=jnp.int32)[None, :]
        live_blk = (rank >= 0)[:, None]
        lidx = jnp.clip(jnp.where(live_blk, l_off + pos // 4, 0), 0, cap - 1)
        code = (body[lidx].astype(jnp.int32) >> ((pos % 4) * 2)) & 3
        L = jnp.where(live_blk, code, 0)
        # exclusive cumsum of stored-byte counts -> absolute mid offsets
        counts = jnp.maximum(nbytes[:, None] - L, 0)
        ends = jnp.cumsum(counts.reshape(-1)).reshape(nb, bs)
        start = ends - counts
        mid_total_ref[0] = ends.reshape(-1)[-1]
        base = mid_off - (
            jax.lax.dynamic_slice_in_dim(start, lo, 1, axis=0)[0, 0]
            if rebase else 0
        )

        def sl(a):
            return jax.lax.dynamic_slice_in_dim(a, lo, rb, axis=0)

        L, start = sl(L), sl(start)
        nbytes_r = sl(nbytes)
        idxs = jnp.broadcast_to(
            jnp.arange(bs, dtype=jnp.int32)[None, :], (rb, bs)
        )
        ws = jnp.zeros((rb, bs), udt)
        for j in range(W):
            sh = jnp.asarray(8 * (W - 1 - j), udt)
            stored = (L <= j) & (j < nbytes_r[:, None])
            gidx = jnp.clip(
                jnp.where(stored, base + start + (j - L), 0), 0, cap - 1
            )
            byte = jnp.where(stored, body[gidx].astype(jnp.int32), 0)
            if j >= spec.lead_cap:
                # every live value stores this plane itself (L <= lead_cap)
                ws = ws | (byte.astype(udt) << sh)
                continue
            # fused key: idx dominates, so the max carries the byte of the
            # nearest preceding stored position (interleaved log-step scan,
            # same shape as the unpack.py kernel)
            key = jnp.where(stored, idxs * 256 + byte, -1)
            step = 1
            while step < bs:
                shifted = jnp.pad(
                    key, ((0, 0), (step, 0)), constant_values=-1
                )[:, :bs]
                key = jnp.maximum(key, shifted)
                step *= 2
            b = jnp.where(
                key >= 0, (key & 0xFF).astype(udt), jnp.asarray(0, udt)
            )
            ws = ws | (b << sh)
        out_ref[...] = _compose(
            ws, sl(mu_ref[...]), sl(shift_ref[...]), nbytes_r, spec
        )

    return _kernel


@functools.partial(
    jax.jit, static_argnames=("spec", "bs", "rb", "rebase", "interpret")
)
def decode_body(body, nnc, lo, mu, shift, nbytes, rank, *,
                spec: DtypeSpec = specs.F32, bs: int, rb: int,
                rebase: bool = False, interpret: bool | None = None):
    """Fused stream-body decode -> (vals (rb, bs), mid_total int32).

    Bit-identical to ``ref.decode_body_ref`` (the oracle); one kernel launch
    over the whole chunk.  Pass the full (nb,) metadata vectors from
    ``ref.parse_body_ref``; the kernel slices the decoded range internally.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb = rank.shape[0]
    out, mid_total = pl.pallas_call(
        _make_kernel(spec, nb, bs, rb, rebase),
        out_shape=(
            jax.ShapeDtypeStruct((rb, bs), spec.np_dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        interpret=interpret,
    )(
        body,
        jnp.reshape(jnp.asarray(nnc, jnp.int32), (1,)),
        jnp.reshape(jnp.asarray(lo, jnp.int32), (1,)),
        mu,
        shift,
        nbytes,
        rank,
    )
    return out, mid_total[0]
