"""Thread-safe metric registry: counters, gauges, fixed-bucket histograms,
plus the bounded span log and codec frame log.

Everything here is stdlib-only and allocation-light: one small lock per
metric (so concurrent observers never contend on a global lock for the
increment itself), one registry-level lock for metric creation and the two
bounded logs.  The registry never samples the clock -- callers time with
``time.perf_counter_ns`` and hand finished durations in -- so a
:class:`Registry` is equally usable from tests, the serve tier, and the
codec hot paths.

Metric names are dotted lowercase (``codec.compress.calls``); label sets are
part of the metric identity, so ``counter("x", route="/a")`` and
``counter("x", route="/b")`` are two series of one family (exactly the
Prometheus data model, see :mod:`repro.obs.export`).
"""
from __future__ import annotations

import bisect
import threading
from collections import deque

# Default histogram buckets: wall-time seconds from 100us to 10s.  Chosen to
# straddle the codec's per-chunk encode/decode times (ms) and the serve
# tier's request latencies (sub-ms cache hits to multi-second cold reads).
DEFAULT_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0,
)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class Counter:
    """Monotonically increasing value."""

    kind = "counter"
    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class Gauge:
    """Last-written value (also supports add/sub for occupancy tracking)."""

    kind = "gauge"
    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    def add(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram (cumulative counts at export time).

    ``buckets`` are ascending upper bounds; one implicit +Inf bucket is
    appended.  ``observe`` is O(log n_buckets) via bisect.
    """

    kind = "histogram"
    __slots__ = ("name", "labels", "buckets", "_lock", "_counts", "_sum",
                 "_count")

    def __init__(self, name: str, labels: dict, buckets=DEFAULT_BUCKETS):
        self.name = name
        self.labels = labels
        self.buckets = tuple(buckets)
        if list(self.buckets) != sorted(self.buckets):
            raise ValueError(f"histogram buckets must ascend: {buckets}")
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.buckets) + 1)
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def value(self):
        """(per-bucket counts, sum, count) -- non-cumulative counts."""
        with self._lock:
            return list(self._counts), self._sum, self._count


class Registry:
    """Thread-safe home for metrics, the span log, and the codec frame log.

    The two logs are bounded deques (oldest entries drop); aggregate span
    timings survive the bound in ``span_aggregates`` so long runs still
    export correct totals.
    """

    def __init__(self, *, max_spans: int = 16384, max_frames: int = 4096):
        self._lock = threading.Lock()
        self._metrics: dict[tuple, object] = {}
        self._spans: deque = deque(maxlen=max_spans)
        self._frames: deque = deque(maxlen=max_frames)
        self._span_agg: dict[str, list] = {}

    # ------------------------------------------------------------- metrics
    def _get(self, cls, name: str, labels: dict, **kw):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, labels, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}"
                )
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, *, buckets=DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets)

    def metrics(self) -> list:
        with self._lock:
            return list(self._metrics.values())

    # ---------------------------------------------------------------- logs
    def record_span(self, name: str, t0_ns: int, dur_ns: int, tid: int,
                    depth: int, attrs: dict | None) -> None:
        with self._lock:
            self._spans.append((name, t0_ns, dur_ns, tid, depth, attrs))
            agg = self._span_agg.get(name)
            if agg is None:
                self._span_agg[name] = [1, dur_ns]
            else:
                agg[0] += 1
                agg[1] += dur_ns

    def spans(self) -> list:
        with self._lock:
            return list(self._spans)

    def span_aggregates(self) -> dict[str, tuple[int, int]]:
        """name -> (count, total_ns); survives the span-log bound."""
        with self._lock:
            return {k: (v[0], v[1]) for k, v in self._span_agg.items()}

    def record_frame(self, rec: dict) -> None:
        with self._lock:
            self._frames.append(rec)

    def frames(self) -> list[dict]:
        with self._lock:
            return list(self._frames)

    # ------------------------------------------------------------ lifecycle
    def snapshot(self) -> dict:
        """JSON-able view: metric families -> {label-string: value}."""
        out: dict = {}
        for m in self.metrics():
            fam = out.setdefault(m.name, {"kind": m.kind, "series": {}})
            lbl = ",".join(f"{k}={v}" for k, v in sorted(m.labels.items()))
            if m.kind == "histogram":
                counts, total, count = m.value
                fam["series"][lbl] = {
                    "count": count, "sum": total,
                    "buckets": dict(zip([*map(str, m.buckets), "+Inf"],
                                        counts)),
                }
            else:
                fam["series"][lbl] = m.value
        spans = {
            name: {"count": c, "total_s": t * 1e-9}
            for name, (c, t) in sorted(self.span_aggregates().items())
        }
        return {"metrics": out, "spans": spans}

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._spans.clear()
            self._frames.clear()
            self._span_agg.clear()
