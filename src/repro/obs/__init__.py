"""repro.obs: dependency-free runtime telemetry for the SZx stack.

One global :class:`Registry` of counters / gauges / fixed-bucket histograms,
a ``span(name, **attrs)`` context manager with monotonic timing and
nesting, a bounded per-frame codec stream-stats log, and three exporters
(Prometheus text, Chrome ``trace_event`` JSON, human summary table).  See
docs/OBSERVABILITY.md.

Telemetry is OFF by default and costs nearly nothing while off: every
instrumented hot path checks :func:`enabled` -- a module-level flag read --
before allocating or recording anything, and ``span()`` returns a shared
no-op context manager when disabled.  Turn it on with ``SZX_OBS=1`` in the
environment or :func:`enable` at runtime::

    from repro import obs

    obs.enable()
    ... run compression / training / serving ...
    print(obs.summary())
    open("trace.json", "w").write(json.dumps(obs.chrome_trace()))

With ``SZX_OBS`` unset the instrumented code paths are byte-identical in
output and within measurement noise in throughput (gated by the
``telemetry_overhead`` benchmark row).
"""
from __future__ import annotations

import functools
import os
import threading
import time

from repro.obs import stream_stats
from repro.obs.export import (
    chrome_trace,
    prometheus_text,
    summary,
    write_chrome_trace,
)
from repro.obs.registry import DEFAULT_BUCKETS, Registry

__all__ = [
    "Registry", "REGISTRY", "DEFAULT_BUCKETS",
    "enabled", "enable", "disable",
    "counter", "gauge", "histogram", "span", "traced",
    "prometheus_text", "chrome_trace", "write_chrome_trace", "summary",
    "stream_stats", "reset",
]

REGISTRY = Registry()

_ENABLED = os.environ.get("SZX_OBS", "") not in ("", "0")
_local = threading.local()


def enabled() -> bool:
    """True when telemetry is recording (``SZX_OBS=1`` or :func:`enable`)."""
    return _ENABLED


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def reset() -> None:
    """Clear every metric, span, and frame record in the global registry."""
    REGISTRY.reset()


def counter(name: str, **labels):
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels):
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, *, buckets=DEFAULT_BUCKETS, **labels):
    return REGISTRY.histogram(name, buckets=buckets, **labels)


def _depth() -> int:
    return getattr(_local, "depth", 0)


class _Span:
    """Live span: times with ``perf_counter_ns``, records on exit."""

    __slots__ = ("name", "attrs", "_t0")

    def __init__(self, name: str, attrs: dict | None):
        self.name = name
        self.attrs = attrs or None

    def __enter__(self):
        _local.depth = _depth() + 1
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.perf_counter_ns() - self._t0
        depth = _depth()
        _local.depth = depth - 1
        REGISTRY.record_span(
            self.name, self._t0, dur, threading.get_ident(), depth,
            self.attrs,
        )
        return False

    def __call__(self, fn):
        name, attrs = self.name, self.attrs

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _ENABLED:
                return fn(*args, **kwargs)
            with _Span(name, attrs):
                return fn(*args, **kwargs)

        return wrapper


class _NullSpan:
    """Shared disabled-mode span: no allocation, no clock, no record."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def __call__(self, fn):
        # decorator applied while disabled: stay live under the function's
        # qualname so a later obs.enable() still instruments the calls
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _ENABLED:
                return fn(*args, **kwargs)
            with _Span(fn.__qualname__, None):
                return fn(*args, **kwargs)

        return wrapper


_NULL = _NullSpan()


def span(name: str, **attrs):
    """Timed span context manager / decorator.

    When telemetry is disabled this returns a shared no-op object (the
    enabled flag is checked before any allocation).  When enabled, the span
    records (name, start, duration, thread, nesting depth, attrs) into the
    registry's span log on exit.
    """
    if not _ENABLED:
        return _NULL
    return _Span(name, attrs)


def traced(name: str | None = None, **attrs):
    """Decorator form with a late enabled check on every call, so functions
    decorated at import time respond to :func:`enable` later."""

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _ENABLED:
                return fn(*args, **kwargs)
            with _Span(label, attrs or None):
                return fn(*args, **kwargs)

        return wrapper

    return deco
