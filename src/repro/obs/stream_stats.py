"""Per-frame codec stream statistics.

Two halves:

* Pure parsers -- :func:`payload_stats` / :func:`frame_stats` compute a
  frame's ground-truth record (elements, raw/compressed bytes, CR,
  const-block fraction, L-code histogram, stage chosen, staged vs raw mid
  bytes) straight from container bytes.  They read ONLY the v2 metadata
  prefix, which the second stage keeps verbatim, so they work identically on
  stage-on and stage-off frames without destaging anything.

* Runtime recorders -- ``record_*`` helpers called from the codec hot paths
  when :func:`repro.obs.enabled`.  They feed the global registry's counters/
  histograms and the bounded frame log that ``python -m repro.core.codec
  info --stats`` and ``/v1/metrics`` surface.

Container imports are deferred into the functions: ``repro.core.codec``
modules import :mod:`repro.obs` at module scope, and this keeps the obs
package import-free of the codec (no cycle).
"""
from __future__ import annotations

import numpy as np

# counts of each 2-bit field value per byte: _L2BIT_TABLE[b, c] = how many of
# byte b's four 2-bit fields equal c.  Field order inside the byte does not
# matter for counting, so this is packing-order agnostic.
_L2BIT_TABLE = None
_M01 = np.uint64(0x5555555555555555)            # low bit of every 2-bit field


def _l2bit_table() -> np.ndarray:
    global _L2BIT_TABLE
    if _L2BIT_TABLE is None:
        b = np.arange(256, dtype=np.uint16)
        fields = np.stack([(b >> s) & 0x3 for s in (0, 2, 4, 6)], axis=1)
        tbl = np.zeros((256, 4), np.int64)
        for c in range(4):
            tbl[:, c] = (fields == c).sum(axis=1)
        _L2BIT_TABLE = tbl
    return _L2BIT_TABLE


def _l2bit_hist(lbytes: np.ndarray) -> np.ndarray:
    """Exact per-code counts of the packed 2-bit fields in ``lbytes``.

    This sits on the telemetry-on compress hot path (once per frame), so it
    counts via popcount identities over a uint64 view -- for each 2-bit
    field f: popcount(f) = [f==1] + [f==2] + 2*[f==3], the high bits alone
    give c2+c3, and low&high gives c3 -- which is ~3x faster than a
    256-bin bincount.  Falls back to the byte-table bincount on numpy < 2
    (no ``bitwise_count``)."""
    if not hasattr(np, "bitwise_count"):
        hist = _l2bit_table().T @ np.bincount(lbytes, minlength=256)
        return hist
    nw = len(lbytes) // 8
    v = np.frombuffer(lbytes, np.uint64, nw)
    hi = (v >> np.uint64(1)) & _M01
    p = int(np.bitwise_count(v).sum(dtype=np.int64))      # c1 + c2 + 2*c3
    h = int(np.bitwise_count(hi).sum(dtype=np.int64))     # c2 + c3
    c3 = int(np.bitwise_count(v & hi).sum(dtype=np.int64))
    c2 = h - c3
    c1 = p - h - c3
    if len(lbytes) > nw * 8:                              # unaligned tail
        tc = _l2bit_table()[lbytes[nw * 8:]].sum(axis=0)
        c1 += int(tc[1]); c2 += int(tc[2]); c3 += int(tc[3])
    return np.array([len(lbytes) * 4 - c1 - c2 - c3, c1, c2, c3], np.int64)


def payload_stats(payload, *, l_hist: bool = True) -> dict:
    """Ground-truth stats of one v2 stream payload from its metadata prefix.

    ``payload`` may be a full stream, a staged frame payload, or just the
    metadata prefix -- only the header + L sections are touched.  The L-code
    histogram is computed with one byte-level bincount (O(prefix), no block
    decode); ``l_hist=False`` skips it (header-only cost) for recorders that
    only feed counters.
    """
    from repro.core.codec import container, plan as plan_mod

    buf = bytes(payload) if not isinstance(payload, (bytes, bytearray)) \
        else payload
    magic, version, dtype_code, bs, n, e, nb, nnc, nmid = \
        container.HEADER.unpack_from(buf, 0)
    if magic != container.MAGIC:
        raise ValueError("bad SZx stream header (magic mismatch)")
    spec = plan_mod.spec_for_code(dtype_code)
    nbm = (nb + 7) // 8
    nl = (nnc * bs + 3) // 4
    off_l = container.HEADER.size + nbm + spec.itemsize * nb + nnc
    if len(buf) < off_l + nl:
        raise ValueError("truncated SZx stream (metadata prefix)")
    hist = np.zeros(4, np.int64)
    if nl and l_hist:
        lbytes = np.frombuffer(buf, np.uint8, nl, off_l)
        hist = _l2bit_hist(lbytes)
        hist[0] -= nl * 4 - nnc * bs      # 2-bit padding fields pack as 0
    raw_bytes = n * spec.itemsize
    return {
        "elements": int(n),
        "dtype": spec.name,
        "error_bound": float(e),
        "block_size": int(bs),
        "nblocks": int(nb),
        "const_blocks": int(nb - nnc),
        "const_fraction": float(nb - nnc) / nb if nb else 0.0,
        "raw_bytes": int(raw_bytes),
        "prefix_bytes": int(off_l + nl),
        "mid_bytes": int(nmid),
        "l_hist": [int(c) for c in hist],
    }


def frame_stats(frame: bytes) -> dict:
    """Ground-truth record of one self-delimiting container frame.

    Extends :func:`payload_stats` with the frame envelope: seq, stage chosen
    (from the frame-flag stage bits), staged vs raw mid bytes, frame bytes,
    and the frame-level compression ratio.  Raw (``FLAG_RAW``) frames yield a
    minimal record with ``"raw": True``.
    """
    from repro.core.codec import container, stage as stage_mod

    magic, version, flags, seq, ln = container.FRAME_HEADER.unpack_from(
        frame, 0
    )
    if magic != container.FRAME_MAGIC:
        raise ValueError("bad SZx frame header (magic mismatch)")
    payload = frame[container.FRAME_HEADER.size:container.FRAME_HEADER.size
                    + ln]
    if len(payload) != ln:
        raise ValueError("truncated SZx frame")
    frame_bytes = container.FRAME_HEADER.size + ln
    if flags & container.FLAG_RAW:
        return {
            "seq": int(seq), "raw": True, "frame_bytes": int(frame_bytes),
            "payload_bytes": int(ln),
        }
    code = container.stage_of_flags(flags)
    rec = payload_stats(payload)
    staged_mid = int(ln) - rec["prefix_bytes"]
    rec.update({
        "seq": int(seq),
        "raw": False,
        "frame_bytes": int(frame_bytes),
        "payload_bytes": int(ln),
        "stage": int(code),
        "stage_name": stage_mod.name_of(code),
        "raw_mid_bytes": rec["mid_bytes"],
        "staged_mid_bytes": staged_mid if code else rec["mid_bytes"],
        "ratio": rec["raw_bytes"] / frame_bytes if frame_bytes else 0.0,
    })
    return rec


# ---------------------------------------------------------------------------
# runtime recorders (callers MUST guard with obs.enabled())
# ---------------------------------------------------------------------------

def record_compress(payload, seconds: float) -> None:
    """One SZxCodec.compress call -> counters + encode-time histogram.

    Header-only stats (no L bincount): the per-frame log, fed once per frame
    by :func:`record_frame_built`, carries the histogram."""
    from repro import obs

    st = payload_stats(payload, l_hist=False)
    r = obs.REGISTRY
    r.counter("codec.compress.calls").inc()
    r.counter("codec.compress.raw_bytes").inc(st["raw_bytes"])
    r.counter("codec.compress.compressed_bytes").inc(len(payload))
    r.counter("codec.compress.const_blocks").inc(st["const_blocks"])
    r.counter("codec.compress.blocks").inc(st["nblocks"])
    r.histogram("codec.compress.seconds").observe(seconds)


def record_decompress(nbytes_out: int, seconds: float,
                      kind: str = "full") -> None:
    """One SZxCodec.decompress / decompress_range call."""
    from repro import obs

    r = obs.REGISTRY
    r.counter("codec.decompress.calls", kind=kind).inc()
    r.counter("codec.decompress.raw_bytes", kind=kind).inc(nbytes_out)
    r.histogram("codec.decompress.seconds", kind=kind).observe(seconds)


def record_frame_built(payload, frame_len: int, seq: int,
                       stage_code: int) -> None:
    """One container frame built -> per-frame record in the frame log."""
    from repro import obs
    from repro.core.codec import container

    rec = payload_stats(payload)
    staged_mid = frame_len - container.FRAME_HEADER.size - rec["prefix_bytes"]
    rec.update({
        "seq": int(seq),
        "stage": int(stage_code),
        "frame_bytes": int(frame_len),
        "raw_mid_bytes": rec["mid_bytes"],
        "staged_mid_bytes": staged_mid if stage_code else rec["mid_bytes"],
        "ratio": rec["raw_bytes"] / frame_len if frame_len else 0.0,
    })
    r = obs.REGISTRY
    r.record_frame(rec)
    r.counter("codec.frames.built", stage=stage_code).inc()
    r.counter("codec.frames.raw_bytes").inc(rec["raw_bytes"])
    r.counter("codec.frames.frame_bytes").inc(frame_len)
