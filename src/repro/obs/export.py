"""Exporters over a :class:`repro.obs.Registry`: Prometheus text exposition,
Chrome ``trace_event`` JSON (chrome://tracing / Perfetto), and a human
``summary()`` table.

Naming: internal metric names are dotted lowercase (``codec.compress.calls``)
and export as ``szx_`` + underscores (``szx_codec_compress_calls``).  Span
aggregates export as the ``szx_span_count`` / ``szx_span_seconds_total``
families labelled by span name, so Prometheus consumers see span timing
without parsing the trace log.
"""
from __future__ import annotations

import json
import os
import re

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return "szx_" + _NAME_RE.sub("_", name)


def _prom_label_value(v) -> str:
    s = str(v)
    return s.replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _prom_labels(labels: dict, extra: dict | None = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    body = ",".join(
        f'{_NAME_RE.sub("_", str(k))}="{_prom_label_value(v)}"'
        for k, v in sorted(merged.items())
    )
    return "{" + body + "}"


def _prom_num(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


def prometheus_text(registry=None) -> str:
    """Render the registry in Prometheus text exposition format 0.0.4."""
    from repro import obs

    registry = registry if registry is not None else obs.REGISTRY
    by_family: dict[str, list] = {}
    for m in registry.metrics():
        by_family.setdefault(m.name, []).append(m)
    lines: list[str] = []
    for name in sorted(by_family):
        series = by_family[name]
        kind = series[0].kind
        pname = _prom_name(name)
        lines.append(f"# TYPE {pname} {kind}")
        for m in series:
            if kind == "histogram":
                counts, total, count = m.value
                cum = 0
                for ub, c in zip(m.buckets, counts):
                    cum += c
                    lines.append(
                        f"{pname}_bucket"
                        f"{_prom_labels(m.labels, {'le': repr(float(ub))})}"
                        f" {cum}"
                    )
                cum += counts[-1]
                lines.append(
                    f"{pname}_bucket{_prom_labels(m.labels, {'le': '+Inf'})}"
                    f" {cum}"
                )
                lines.append(
                    f"{pname}_sum{_prom_labels(m.labels)} {_prom_num(total)}"
                )
                lines.append(
                    f"{pname}_count{_prom_labels(m.labels)} {count}"
                )
            else:
                lines.append(
                    f"{pname}{_prom_labels(m.labels)} {_prom_num(m.value)}"
                )
    agg = registry.span_aggregates()
    if agg:
        lines.append("# TYPE szx_span_count counter")
        for name in sorted(agg):
            lines.append(
                f"szx_span_count{_prom_labels({'name': name})} {agg[name][0]}"
            )
        lines.append("# TYPE szx_span_seconds_total counter")
        for name in sorted(agg):
            lines.append(
                f"szx_span_seconds_total{_prom_labels({'name': name})}"
                f" {_prom_num(agg[name][1] * 1e-9)}"
            )
    return "\n".join(lines) + "\n"


def chrome_trace(registry=None) -> dict:
    """Span log as a Chrome ``trace_event`` document (complete 'X' events).

    Load the JSON in chrome://tracing or https://ui.perfetto.dev -- nesting
    renders from per-thread timestamp containment, which the span stack
    guarantees.  Timestamps are ``perf_counter_ns``-based microseconds
    (monotonic within the process; absolute epoch is meaningless).
    """
    from repro import obs

    registry = registry if registry is not None else obs.REGISTRY
    pid = os.getpid()
    events = []
    for name, t0_ns, dur_ns, tid, depth, attrs in registry.spans():
        ev = {
            "name": name, "cat": "szx", "ph": "X",
            "ts": t0_ns / 1e3, "dur": dur_ns / 1e3,
            "pid": pid, "tid": tid,
        }
        args = {"depth": depth}
        if attrs:
            args.update(attrs)
        ev["args"] = args
        events.append(ev)
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path, registry=None) -> str:
    """Serialize :func:`chrome_trace` to ``path``; returns the path."""
    doc = chrome_trace(registry)
    with open(path, "w") as f:
        json.dump(doc, f, default=str)
    return str(path)


def _fmt_table(rows: list[list[str]], header: list[str]) -> list[str]:
    widths = [
        max(len(str(r[i])) for r in [header, *rows])
        for i in range(len(header))
    ]
    def fmt(r):
        return "  ".join(str(c).ljust(w) for c, w in zip(r, widths)).rstrip()
    out = [fmt(header), fmt(["-" * w for w in widths])]
    out.extend(fmt(r) for r in rows)
    return out


def summary(registry=None) -> str:
    """Human-readable aggregate table: spans, counters/gauges, histograms."""
    from repro import obs

    registry = registry if registry is not None else obs.REGISTRY
    sections: list[str] = []
    agg = registry.span_aggregates()
    if agg:
        rows = [
            [name, c, f"{t * 1e-9:.4f}", f"{t / c * 1e-6:.3f}"]
            for name, (c, t) in sorted(agg.items())
        ]
        sections.append("spans")
        sections.extend(_fmt_table(rows, ["span", "count", "total_s",
                                          "mean_ms"]))
    scalars, hists = [], []
    for m in registry.metrics():
        lbl = ",".join(f"{k}={v}" for k, v in sorted(m.labels.items()))
        label = f"{m.name}{{{lbl}}}" if lbl else m.name
        if m.kind == "histogram":
            _, total, count = m.value
            mean = total / count if count else 0.0
            hists.append([label, count, f"{total:.4f}", f"{mean * 1e3:.3f}"])
        else:
            v = m.value
            scalars.append([label, m.kind,
                            f"{v:.6g}" if isinstance(v, float) else v])
    if scalars:
        if sections:
            sections.append("")
        sections.append("metrics")
        sections.extend(_fmt_table(sorted(scalars), ["metric", "kind",
                                                     "value"]))
    if hists:
        sections.append("")
        sections.append("histograms")
        sections.extend(_fmt_table(sorted(hists), ["histogram", "count",
                                                   "sum_s", "mean_ms"]))
    if not sections:
        return "(no telemetry recorded)\n"
    return "\n".join(sections) + "\n"
