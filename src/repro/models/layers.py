"""Model layers, pure-functional JAX (params are plain pytrees).

Everything is written scan-friendly (fixed shapes, O(1) HLO in depth) and
GSPMD-friendly (no shard_map in the model body, so uneven head counts like
hymba's 25 heads legally pad on a 16-way axis).  Memory-critical paths
(attention at 32k+, SSD) are chunked ``lax.scan`` implementations so the peak
temp is a tile, not an S x S buffer.
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp

from repro.models.sharding import shard_activation as _sa


def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def dense(x, w):
    """Matmul in the activation dtype with f32 accumulation."""
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(dt)


# ---------------------------------------------------------------------------
# flash-style chunked attention (pure lax.scan; O(tile) memory)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _chunked(x, n, c):
    """(B, S, ...) -> (n, B, c, ...) scan-ready."""
    b = x.shape[0]
    return jnp.moveaxis(x.reshape((b, n, c) + x.shape[2:]), 1, 0)


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    q_offset=0,
):
    """Online-softmax attention.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) with Hq % Hkv == 0.
    window > 0 => sliding-window causal.  q_offset: absolute position of
    q[:, 0] (for decode against a longer cache).
    Returns (B, Sq, Hq, hd) in q.dtype.
    """
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)

    cq = min(q_chunk, sq)
    ck = min(kv_chunk, skv)
    pad_q = (-sq) % cq
    pad_k = (-skv) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = (sq + pad_q) // cq, (skv + pad_k) // ck

    qc = _chunked(q.reshape(b, sq + pad_q, hkv, g, hd), nq, cq)  # (nq,B,cq,hkv,g,hd)
    kc = _chunked(k, nk, ck)
    vc = _chunked(v, nk, ck)

    def q_step(_, qi_x):
        qi, qx = qi_x
        qpos = q_offset + qi * cq + jnp.arange(cq)

        def kv_step(carry, ki_kv):
            # named_scope tags this tile's ops as VMEM-resident for the
            # roofline analyzer: on TPU this body is the Pallas flash kernel
            # (kernels/flash_attention.py) whose tiles never touch HBM.
            with jax.named_scope("vmem_tile"):
                return _kv_tile(carry, ki_kv)

        def _kv_tile(carry, ki_kv):
            m, l, acc = carry
            ki, kx, vx = ki_kv
            kpos = ki * ck + jnp.arange(ck)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qx, kx, preferred_element_type=jnp.float32
            ) * scale
            valid = kpos[None, :] < skv
            valid &= (qpos[:, None] < q_offset + sq)
            if causal:
                valid &= kpos[None, :] <= qpos[:, None]
            if window:
                valid &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # fully-masked chunks have s == m_new == NEG_INF; exp(0) would be
            # 1 there, so re-mask after the subtraction
            p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[..., None]), 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vx, preferred_element_type=jnp.float32
            )
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, hd), jnp.float32)
        # checkpoint each kv tile: without this, scan-AD stacks every f32
        # probability tile across (nq x nk) steps -- GBs per layer.  With it,
        # the backward recomputes p from (q, k, v) exactly like FlashAttention.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0), (jnp.arange(nk), kc, vc)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = jnp.moveaxis(out, 3, 1).reshape(b, cq, hq, hd)  # (B,cq,Hq,hd)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qc))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, (sq + pad_q), hq, hd)
    return out[:, :sq]


# ---------------------------------------------------------------------------
# attention layer (GQA / SWA), training/prefill form
# ---------------------------------------------------------------------------

def attention(p, x, cfg, *, positions=None, causal=True, kv_override=None):
    """p: {'wq','wk','wv','wo'}; x: (B,S,D).

    kv_override: (k, v) already-projected tensors (whisper cross-attention).
    Returns (B,S,D) and the (k, v) tensors for cache construction.
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    if kv_override is None:
        k = dense(x, p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
        v = dense(x, p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
        if positions is None:
            positions = jnp.arange(s)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
        if positions is not None:
            q = rope(q, positions, cfg.rope_theta)
    # only the query gets an explicit heads-over-model hint; k/v sharding is
    # left to GSPMD propagation, which picks (kv_heads x head_dim) factorings
    # that a blanket 16-way heads constraint would fight (forced remat copies)
    q = _sa(q, ("act_batch", None, "act_heads", None))
    o = flash_attention(q, k, v, causal=causal, window=cfg.sliding_window if causal else 0)
    o = dense(o.reshape(b, s, cfg.n_heads * hd), p["wo"])
    return o, (k, v)


def cross_kv(p, enc_out, cfg):
    """Project encoder output to (k, v) for cross-attention."""
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = dense(enc_out, p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(enc_out, p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def swiglu_mlp(p, x):
    """p: {'wi': (D, 2F), 'wo': (F, D)} -- fused gate+up projection."""
    gu = dense(x, p["wi"])
    gate, up = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = _sa(h, ("act_batch", None, "act_ff"))
    return dense(h, p["wo"])


def moe_ffn(p, x, cfg):
    """Top-k MoE with per-expert FIFO capacity, formulated scatter-free.

    p: {'router': (D,E), 'wi': (E,D,2Fe), 'wo': (E,Fe,D) [, 'shared_wi','shared_wo']}
    x: (B,S,D).  Experts shard over 'model' (EP), tokens over 'data'.

    GSPMD note: the classic flattened-scatter dispatch forces the partitioner
    to all-gather the (T*K, D) expanded tokens on every model shard (~50 GB /
    layer at deepseek scale).  Instead:
      dispatch -- per-expert top_k over token indices (FIFO capacity, GShard
                  drop semantics) + batched gather: indices are E-sharded, the
                  token operand is model-replicated -> fully local;
      combine  -- batched scatter-add of E-sharded expert outputs into the
                  model-replicated (B,S,D) result -> partial sums + ONE
                  (B,S,D) all-reduce over 'model', same wire cost as a TP
                  row-parallel matmul.
    Returns (out, aux_loss).
    """
    b, s, d = x.shape
    e_, k_ = cfg.n_experts, cfg.top_k
    cap = min(s, max(8, int(s * k_ / e_ * cfg.capacity_factor)))
    logits = dense(x, p["router"]).astype(jnp.float32)        # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k_)                      # (B,S,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(idx, e_, dtype=jnp.float32)       # (B,S,K,E)
    routed = onehot.sum(2) > 0                                # (B,S,E)
    gate_full = (onehot * gate[..., None]).sum(2)             # (B,S,E)

    # FIFO top-C token ids per expert (earliest-token priority, GShard drop)
    spos = jnp.arange(s, dtype=jnp.float32)[None, :, None]
    score = jnp.where(routed, -spos, -jnp.float32(1e9))       # (B,S,E)
    top_sc, src = jax.lax.top_k(jnp.swapaxes(score, 1, 2), cap)  # (B,E,C)
    valid = top_sc > -5e8
    src = jnp.where(valid, src, 0)
    src = _sa(src, ("act_moe_batch", "act_expert", None))

    xin = jax.vmap(lambda xb, ib: xb[ib])(x, src)             # (B,E,C,D) gather
    xin = xin * valid[..., None].astype(x.dtype)
    xin = _sa(xin, ("act_moe_batch", "act_expert", None, None))

    gu = jnp.einsum("becd,edf->becf", xin, p["wi"].astype(x.dtype))
    g_, u_ = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g_.astype(jnp.float32)).astype(x.dtype) * u_
    xout = jnp.einsum("becf,efd->becd", h, p["wo"].astype(x.dtype))

    # per-slot gate weight: gate_full[b, src[b,e,c], e]
    gf_t = jnp.swapaxes(gate_full, 1, 2)                      # (B,E,S)
    gate_slot = jax.vmap(lambda g2, i2: jnp.take_along_axis(g2, i2, axis=1))(
        gf_t, src
    )                                                         # (B,E,C)
    w_slot = (gate_slot * valid).astype(x.dtype)

    upd = (xout * w_slot[..., None]).reshape(b, e_ * cap, d)
    flat_idx = src.reshape(b, e_ * cap)
    y = jax.vmap(
        lambda ib, ub: jnp.zeros((s, d), x.dtype).at[ib].add(ub)
    )(flat_idx, upd)
    y = _sa(y, ("act_batch", None, None))

    if "shared_wi" in p:
        y = y + swiglu_mlp({"wi": p["shared_wi"], "wo": p["shared_wo"]}, x)

    # Switch-style load-balance aux loss
    me = probs.mean(axis=(0, 1))
    ce = routed.astype(jnp.float32).mean(axis=(0, 1))
    aux = e_ * jnp.sum(me * ce)
    return y, aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD -- state-space duality), chunked scan + O(1) decode
# ---------------------------------------------------------------------------

def _ssm_dims(cfg):
    di = cfg.ssm_d_inner
    h = cfg.ssm_n_heads
    n = cfg.ssm_state
    return di, h, n, cfg.ssm_head_dim


def _ssm_conv(u, w):
    """Depthwise causal conv1d.  u: (B,S,C); w: (W,C)."""
    width = w.shape[0]
    u_pad = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(width):
        out = out + u_pad[:, i : i + u.shape[1], :] * w[i][None, None, :]
    return out


def mamba2(p, x, cfg, *, init_state=None, return_state=False):
    """Chunked SSD forward.  x: (B,S,D) -> (B,S,D).

    p: {'in': (D,Z), 'conv': (W,CC), 'dt_bias': (H,), 'A_log': (H,),
        'D': (H,), 'norm': (di,), 'out': (di,D)}
    with Z = 2*di + 2*N + H and CC = di + 2*N (x, B, C channels get conv'd).
    With return_state=True also returns (final_state, conv_tail) for decode.
    """
    b, s, _ = x.shape
    di, h, n, hp = _ssm_dims(cfg)
    q = min(cfg.ssm_chunk, s)
    if s % q:
        # fall back to the largest divisor (only hit by odd test lengths;
        # production cells are powers of two)
        q = next(d for d in range(q, 0, -1) if s % d == 0)
    nc = s // q

    zxbcdt = dense(x, p["in"])
    # split: z (di) | xbc (di + 2n) | dt (h)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    conv_tail = xbc[:, s - (cfg.ssm_conv_width - 1) :, :]      # pre-conv history
    xbc = _ssm_conv(xbc, p["conv"].astype(x.dtype))
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :di].reshape(b, s, h, hp)
    bb = xbc[..., di : di + n]                                 # (B,S,N) (G=1)
    cc = xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))               # (H,)

    # chunked views
    xsc = xs.reshape(b, nc, q, h, hp)
    bbc = bb.reshape(b, nc, q, n)
    ccc = cc.reshape(b, nc, q, n)
    dtc = dt.reshape(b, nc, q, h)
    da = dtc * a[None, None, None, :]                          # (B,nc,Q,H)
    cum = jnp.cumsum(da, axis=2)                               # within-chunk

    xs_sc = jnp.moveaxis(xsc, 1, 0)
    bb_sc = jnp.moveaxis(bbc, 1, 0)
    cc_sc = jnp.moveaxis(ccc, 1, 0)
    dt_sc = jnp.moveaxis(dtc, 1, 0)
    cum_sc = jnp.moveaxis(cum, 1, 0)

    if init_state is None:
        init_state = jnp.zeros((b, h, n, hp), jnp.float32)

    def chunk_step(state, xs_):
        # tagged VMEM-resident: the SSD chunk math is a fused TPU kernel
        # (intra-chunk tiles never hit HBM); see roofline/hlo_cost.py
        with jax.named_scope("vmem_tile"):
            return _ssd_chunk(state, xs_)

    def _ssd_chunk(state, xs_):
        xk, bk, ck, dtk, cumk = xs_
        # intra-chunk (quadratic within chunk)
        seg = cumk[:, :, None, :] - cumk[:, None, :, :]        # (B,Q,Q,H)
        iq = jnp.arange(q)
        causal = iq[:, None] >= iq[None, :]
        # mask BEFORE exp: upper-triangle seg is positive (cum is decreasing),
        # exp would overflow and poison the backward pass with inf * 0
        seg = jnp.where(causal[None, :, :, None], seg, NEG_INF)
        l_ = jnp.exp(seg)
        cb = jnp.einsum("bqn,bkn->bqk", ck, bk, preferred_element_type=jnp.float32)
        w_ = cb[..., None] * l_ * dtk[:, None, :, :]           # (B,Q,K,H)
        y_intra = jnp.einsum(
            "bqkh,bkhp->bqhp", w_, xk.astype(jnp.float32)
        )
        # inter-chunk (contribution of carried state)
        y_inter = jnp.einsum(
            "bqn,bhnp,bqh->bqhp", ck.astype(jnp.float32), state, jnp.exp(cumk)
        )
        # state update
        total = cumk[:, -1, :]                                 # (B,H)
        decay_rest = jnp.exp(total[:, None, :] - cumk)         # (B,Q,H)
        upd = jnp.einsum(
            "bkn,bkh,bkhp->bhnp",
            bk.astype(jnp.float32),
            dtk * decay_rest,
            xk.astype(jnp.float32),
        )
        new_state = jnp.exp(total)[:, :, None, None] * state + upd
        return new_state, (y_intra + y_inter).astype(x.dtype)

    final_state, ys = jax.lax.scan(
        chunk_step, init_state, (xs_sc, bb_sc, cc_sc, dt_sc, cum_sc)
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, hp)
    y = y + xs * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = dense(y, p["out"])
    if return_state:
        return out, (final_state, conv_tail)
    return out


def mamba2_decode(p, x1, state, conv_state, cfg):
    """Single-token SSD step.  x1: (B,1,D); state: (B,H,N,hp);
    conv_state: (B, W-1, CC).  Returns (out (B,1,D), state, conv_state)."""
    b = x1.shape[0]
    di, h, n, hp = _ssm_dims(cfg)
    zxbcdt = dense(x1, p["in"])[:, 0]                          # (B,Z)
    z = zxbcdt[:, :di]
    xbc = zxbcdt[:, di : 2 * di + 2 * n]
    dt = zxbcdt[:, 2 * di + 2 * n :]
    # causal conv via rolling state
    w = p["conv"].astype(x1.dtype)                             # (W,CC)
    hist = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B,W,CC)
    xbc = jnp.einsum("bwc,wc->bc", hist, w)
    new_conv_state = hist[:, 1:]
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x1.dtype)
    xh = xbc[:, :di].reshape(b, h, hp)
    bb = xbc[:, di : di + n]
    cc = xbc[:, di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * a[None, :])                              # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhnp", bb.astype(jnp.float32), dt, xh.astype(jnp.float32))
    state = da[:, :, None, None] * state + upd
    y = jnp.einsum("bn,bhnp->bhp", cc.astype(jnp.float32), state)
    y = y.astype(x1.dtype) + xh * p["D"].astype(x1.dtype)[None, :, None]
    y = y.reshape(b, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x1.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = dense(y, p["out"])[:, None, :]
    return out, state, new_conv_state


def ssm_conv_channels(cfg) -> int:
    return cfg.ssm_d_inner + 2 * cfg.ssm_state


def ssm_in_features(cfg) -> int:
    return 2 * cfg.ssm_d_inner + 2 * cfg.ssm_state + cfg.ssm_n_heads
