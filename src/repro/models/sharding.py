"""Logical-axis activation sharding hints.

Model code calls ``shard_activation(x, logical_axes)`` with *logical* names;
the launcher installs a rule table mapping logical -> mesh axes for the
current mesh/cell via ``use_rules``.  Outside any rule context the calls are
no-ops, so the model runs unchanged on a single CPU device in tests.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat

_state = threading.local()


DEFAULT_RULES: dict[str, object] = {
    # activation batch over all data-parallel axes
    "act_batch": ("pod", "data"),
    "act_heads": "model",
    "act_hd": "model",        # decode: head_dim-sharded q/KV (kv-head agnostic)
    "act_ff": "model",
    "act_expert": "model",
    "act_moe_batch": ("pod", "data"),   # batch dim of MoE dispatch buffers
    "act_seq": None,
    "act_embed": None,
}

# long-context decode (batch=1): batch replicated, sequence sharded over data
LONG_CONTEXT_RULES = dict(DEFAULT_RULES, act_batch=None, act_seq="data")

# pure data parallelism: for small models (<~1B) on a big mesh, TP collectives
# on (B,S,D) activations dwarf the compute; replicate params and shard the
# batch over EVERY mesh axis instead (section Perf hillclimb H2)
PURE_DP_RULES = dict(
    DEFAULT_RULES,
    act_batch=("pod", "data", "model"),
    act_heads=None, act_hd=None, act_ff=None, act_expert=None,
    act_moe_batch=("pod", "data", "model"),
)

# serve-layout MoE (H1): experts live on 'data' x 'model'; dispatch buffers
# follow the weights' E-sharding (activations are tiny at decode, weights are
# not -- replicate the token dim, shard E over 'data')
SERVE_MOE_RULES = dict(act_expert="data", act_moe_batch=None)


@contextlib.contextmanager
def use_rules(mesh, rules: dict | None = None):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, dict(DEFAULT_RULES, **(rules or {})))
    try:
        yield
    finally:
        _state.ctx = prev


def rules_active() -> bool:
    return getattr(_state, "ctx", None) is not None


def shard_activation(x, logical_axes):
    ctx = getattr(_state, "ctx", None)
    if ctx is None or not compat.sharding_hints_supported():
        return x
    mesh, rules = ctx
    axes = []
    for name in logical_axes:
        axis = rules.get(name) if name else None
        if isinstance(axis, tuple):
            axis = tuple(a for a in axis if a in mesh.axis_names) or None
        elif axis is not None and axis not in mesh.axis_names:
            axis = None
        axes.append(axis)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*axes)))
