"""Model assembly: parameter init, forward pass, loss.

All families (dense / moe / ssm / hybrid / audio enc-dec / vlm) share one
block vocabulary; layers are stacked on a leading L axis and run under
``jax.lax.scan`` so the HLO is O(1) in depth (critical for the 512-device
dry-run compiles).  The vocabulary is padded to a multiple of 128 and masked
in the loss; the CE loss is computed in sequence chunks so (B, S, 128k)
logits never materialize.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.sharding import shard_activation as _sa

Params = dict


def compute_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_init(cfg, shape):
    return jnp.ones(shape, jnp.dtype(cfg.param_dtype))


def _dense_init(key, cfg, fan_in, shape):
    w = jax.random.normal(key, shape, jnp.float32) * (fan_in ** -0.5)
    return w.astype(jnp.dtype(cfg.param_dtype))


def _init_attn(key, cfg) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": _dense_init(kq, cfg, d, (d, cfg.n_heads * hd)),
        "wk": _dense_init(kk, cfg, d, (d, cfg.n_kv_heads * hd)),
        "wv": _dense_init(kv, cfg, d, (d, cfg.n_kv_heads * hd)),
        "wo": _dense_init(ko, cfg, cfg.n_heads * hd, (cfg.n_heads * hd, d)),
    }


def _init_mlp(key, cfg, d_ff) -> Params:
    d = cfg.d_model
    ki, ko = jax.random.split(key)
    return {
        "wi": _dense_init(ki, cfg, d, (d, 2 * d_ff)),
        "wo": _dense_init(ko, cfg, d_ff, (d_ff, d)),
    }


def _init_moe(key, cfg) -> Params:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    kr, ki, ko, ksi, kso = jax.random.split(key, 5)
    p = {
        "router": _dense_init(kr, cfg, d, (d, e)),
        "wi": _dense_init(ki, cfg, d, (e, d, 2 * f)),
        "wo": _dense_init(ko, cfg, f, (e, f, d)),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        p["shared_wi"] = _dense_init(ksi, cfg, d, (d, 2 * fs))
        p["shared_wo"] = _dense_init(kso, cfg, fs, (fs, d))
    return p


def _init_ssm(key, cfg) -> Params:
    d = cfg.d_model
    di, h = cfg.ssm_d_inner, cfg.ssm_n_heads
    kin, kconv, kout, kdt = jax.random.split(key, 4)
    z = L.ssm_in_features(cfg)
    cc = L.ssm_conv_channels(cfg)
    return {
        "in": _dense_init(kin, cfg, d, (d, z)),
        "conv": _dense_init(kconv, cfg, cfg.ssm_conv_width, (cfg.ssm_conv_width, cc)),
        "dt_bias": jnp.zeros((h,), jnp.dtype(cfg.param_dtype)),
        "A_log": jnp.log(
            jax.random.uniform(kdt, (h,), jnp.float32, 1.0, 16.0)
        ).astype(jnp.dtype(cfg.param_dtype)),
        "D": jnp.ones((h,), jnp.dtype(cfg.param_dtype)),
        "norm": _norm_init(cfg, (di,)),
        "out": _dense_init(kout, cfg, di, (di, d)),
    }


def _init_layer(key, cfg, *, decoder: bool) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": _norm_init(cfg, (d,))}
    if cfg.n_heads:
        p["attn"] = _init_attn(ks[0], cfg)
    if cfg.ssm_state and cfg.family in ("ssm", "hybrid"):
        p["ssm"] = _init_ssm(ks[1], cfg)
    if cfg.n_experts:
        p["moe"] = _init_moe(ks[2], cfg)
        p["ln2"] = _norm_init(cfg, (d,))
        if cfg.dense_ff_residual:
            p["mlp"] = _init_mlp(ks[3], cfg, cfg.d_ff)
    elif cfg.d_ff:
        p["mlp"] = _init_mlp(ks[3], cfg, cfg.d_ff)
        p["ln2"] = _norm_init(cfg, (d,))
    if decoder and cfg.encoder_decoder:
        p["cross"] = _init_attn(ks[4], cfg)
        p["ln_cross"] = _norm_init(cfg, (d,))
    return p


def init_params(cfg: ArchConfig, key) -> Params:
    ke, kl, kh, kf, kenc = jax.random.split(key, 5)
    v, d = cfg.padded_vocab, cfg.d_model
    params: Params = {
        "embed": _dense_init(ke, cfg, d, (v, d)),
        "layers": jax.vmap(
            lambda k: _init_layer(k, cfg, decoder=True)
        )(jax.random.split(kl, cfg.n_layers)),
        "final_ln": _norm_init(cfg, (d,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(kh, cfg, d, (d, v))
    if cfg.encoder_decoder or cfg.prefix_embeds:
        params["frontend_proj"] = _dense_init(kf, cfg, d, (d, d))
    if cfg.encoder_decoder:
        params["encoder"] = {
            "layers": jax.vmap(
                lambda k: _init_layer(k, cfg, decoder=False)
            )(jax.random.split(kenc, cfg.n_encoder_layers)),
            "final_ln": _norm_init(cfg, (d,)),
        }
    return params


def param_specs(cfg: ArchConfig) -> Any:
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.key(0))
    )


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def ffn_part(p: Params, h, cfg: ArchConfig):
    """Post-mixer FFN residual (dense MLP and/or MoE).  Returns (h, aux)."""
    aux = jnp.float32(0)
    if "ln2" in p:
        hn = L.rms_norm(h, p["ln2"], cfg.norm_eps)
        ff = jnp.zeros_like(h)
        if "moe" in p:
            moe_out, aux = L.moe_ffn(p["moe"], hn, cfg)
            ff = ff + moe_out
        if "mlp" in p:
            ff = ff + L.swiglu_mlp(p["mlp"], hn)
        h = h + ff
    return h, aux


def _block(p: Params, h, cfg: ArchConfig, *, causal: bool, enc_out=None):
    """One transformer block (train/prefill form).

    Returns (h, aux_loss, caps) where caps holds the per-layer state a serving
    cache needs (k/v, ssm state, cross k/v)."""
    caps: Params = {}
    hn = L.rms_norm(h, p["ln1"], cfg.norm_eps)
    mix = None
    if cfg.n_heads and cfg.family != "ssm":
        attn_out, (k, v) = L.attention(p["attn"], hn, cfg, causal=causal)
        caps["k"], caps["v"] = k, v
        mix = attn_out
    if "ssm" in p:
        ssm_out, (state, conv_tail) = L.mamba2(p["ssm"], hn, cfg, return_state=True)
        caps["state"], caps["conv"] = state, conv_tail
        # hybrid: parallel heads, outputs averaged (Hymba)
        mix = ssm_out if mix is None else 0.5 * (mix + ssm_out)
    h = h + mix
    if enc_out is not None and "cross" in p:
        hn = L.rms_norm(h, p["ln_cross"], cfg.norm_eps)
        kv = L.cross_kv(p["cross"], enc_out, cfg)
        caps["cross_k"], caps["cross_v"] = kv
        out, _ = L.attention(p["cross"], hn, cfg, causal=False, kv_override=kv)
        h = h + out
    h, aux = ffn_part(p, h, cfg)
    return h, aux, caps


def _run_layers(layers: Params, h, cfg, *, causal: bool, enc_out=None, capture=False):
    def body(carry, lp):
        h, aux = carry
        h = _sa(h, ("act_batch", "act_seq", "act_embed"))
        h, a, caps = _block(lp, h, cfg, causal=causal, enc_out=enc_out)
        return (h, aux + a), (caps if capture else None)

    if cfg.remat:
        body = jax.checkpoint(body)
    (h, aux), caps = jax.lax.scan(body, (h, jnp.float32(0)), layers)
    return (h, aux, caps) if capture else (h, aux)


# ---------------------------------------------------------------------------
# forward + loss
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg, tokens):
    return jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype(cfg))


def encode(params, cfg, frames):
    """Whisper encoder over stub frame embeddings (B, T, D)."""
    h = L.dense(frames.astype(compute_dtype(cfg)), params["frontend_proj"])
    h, _ = _run_layers(params["encoder"]["layers"], h, cfg, causal=False)
    return L.rms_norm(h, params["encoder"]["final_ln"], cfg.norm_eps)


def forward(params, cfg: ArchConfig, tokens, *, frames=None, image_embeds=None):
    """-> (hidden (B, S', D), aux_loss); S' includes any VLM prefix."""
    h = embed_tokens(params, cfg, tokens)
    if cfg.prefix_embeds and image_embeds is not None:
        pre = L.dense(image_embeds.astype(h.dtype), params["frontend_proj"])
        h = jnp.concatenate([pre, h], axis=1)
    enc_out = None
    if cfg.encoder_decoder:
        enc_out = encode(params, cfg, frames)
    h, aux = _run_layers(params["layers"], h, cfg, causal=True, enc_out=enc_out)
    return L.rms_norm(h, params["final_ln"], cfg.norm_eps), aux


def lm_head_weight(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def logits_for(params, cfg, h):
    out = L.dense(h, lm_head_weight(params, cfg)).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad = cfg.padded_vocab - cfg.vocab_size
        out = out - jnp.pad(
            jnp.zeros((cfg.vocab_size,), jnp.float32),
            (0, pad),
            constant_values=1e9,
        )
    return out


def chunked_ce_loss(params, cfg, h, labels, *, chunk: int = 512):
    """Cross-entropy without materializing (B, S, V): scan over seq chunks.

    labels: (B, S) int32, -1 = ignore.  Returns (loss_sum, token_count).
    """
    b, s, d = h.shape
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = (s + pad) // c
    hc = jnp.moveaxis(h.reshape(b, n, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)
    w = lm_head_weight(params, cfg)

    def body(carry, xs):
        loss, cnt = carry
        hx, lx = xs
        logits = L.dense(hx, w).astype(jnp.float32)            # (B,c,V)
        mask = lx >= 0
        lse = jax.nn.logsumexp(logits[..., : cfg.vocab_size], axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lx, 0)[..., None], axis=-1
        )[..., 0]
        nll = jnp.where(mask, lse - gold, 0.0)
        return (loss + nll.sum(), cnt + mask.sum()), None

    body = jax.checkpoint(body)
    (loss, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.int32(0)), (hc, lc))
    return loss, cnt


def loss_fn(params, cfg: ArchConfig, batch, *, aux_weight: float = 0.01):
    """Scalar training loss for a batch dict (tokens, labels [, frames, ...])."""
    h, aux = forward(
        params,
        cfg,
        batch["tokens"],
        frames=batch.get("frames"),
        image_embeds=batch.get("image_embeds"),
    )
    labels = batch["labels"]
    if cfg.prefix_embeds:                      # VLM: no loss on image prefix
        h = h[:, cfg.prefix_embeds :]
    loss, cnt = chunked_ce_loss(params, cfg, h, labels)
    loss = loss / jnp.maximum(cnt.astype(jnp.float32), 1.0)
    return loss + aux_weight * aux / max(cfg.n_layers, 1)
