"""Composable model definitions: one block vocabulary covering dense / MoE /
SSM / hybrid / enc-dec / VLM families, assembled per ArchConfig."""
from repro.models import layers, transformer  # noqa: F401
