"""AdamW in pure JAX pytrees (fp32 moments, global-norm clip, decay masking).

The moments inherit the parameter PartitionSpecs, so FSDP shards optimizer
state together with the parameters (ZeRO-style)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


class AdamW(NamedTuple):
    lr: Any                      # float or callable(step) -> float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params):
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm:
            gn = global_norm(g32)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        else:
            gn = jnp.float32(0)
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        new_m = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g, state.m, g32)
        new_v = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g, state.v, g32)

        def upd(p, m, v):
            u = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            if self.weight_decay and p.ndim >= 2:      # no decay on norms/bias
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, new_m, new_v)
        return new_params, AdamWState(step, new_m, new_v), {"grad_norm": gn, "lr": lr}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup, warm, cos)

    return sched
