"""Pure-JAX optimizers + schedules."""
from repro.optim.adamw import AdamW, AdamWState, global_norm, warmup_cosine  # noqa: F401
