"""Fault-tolerant checkpointing with optional SZx compression."""
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
