"""Fault-tolerant checkpoint manager with optional SZx compression.

Layout (one directory per step, MANIFEST v2):
    <root>/step_000123/
        MANIFEST.json      -- {"manifest_version": 2, "step", "time",
                               "file": "tree.szt", "leaves": [...]}
        tree.szt           -- ONE container-v3 multi-leaf TreeCodec stream
                              (small/integer leaves in the shared raw pack,
                              large float leaves as chunked SZx frames,
                              seekable index footer)
        _COMMITTED         -- atomic commit marker (written last)

v1 checkpoints (one ``<leaf-id>.bin`` file per leaf, written by earlier
revisions) remain restorable; ``manifest_version`` is absent there.

Features required at 1000-node scale and implemented here:
  * atomic commit (tmp dir + rename + marker file): a crashed writer never
    corrupts the latest checkpoint; the previous _COMMITTED step stays
    restorable through any mid-save crash
  * keep-last-k garbage collection over COMMITTED steps only
  * background (async) save thread so the train loop is not blocked
  * error-bounded SZx compression of float leaves (the paper's Fig. 13
    dump/load use case: compression above PFS bandwidth = faster I/O wall)
    through ``TreeCodec`` -- one stream file per step instead of per leaf,
    chunked frame bodies on ``workers`` threads, bounded save/restore memory
  * partial restore: ``restore_leaves(names)`` reads ONLY the selected
    leaves' byte ranges via the v3 index footer (elastic single-shard
    restore); full ``restore`` also reads leaf-by-leaf through the index
  * cross-topology restore: leaves are stored as full logical arrays, so any
    mesh can load any checkpoint (elastic scaling); device placement is the
    caller's (jax.device_put with the new sharding)
  * integer leaves that SZx would mangle (ints, step counters) are stored
    raw in the shared pack frame and round-trip bit-exactly
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Iterable, Optional

import jax
import numpy as np

from repro.core.codec import SZxCodec, TreeCodec
from repro.core.codec.tree import leaf_name, np_dtype_for

_MARKER = "_COMMITTED"
_STREAM = "tree.szt"
MANIFEST_VERSION = 2


class CheckpointManager:
    def __init__(
        self,
        root: str,
        *,
        keep: int = 3,
        compress: bool = False,
        error_bound: float = 1e-6,
        mode: str = "rel",
        async_save: bool = False,
        chunk_bytes: int = 64 << 20,
        workers: int = 1,
        backend: str = "numpy",
    ):
        self.root = root
        self.keep = keep
        self.compress = compress
        self.error_bound = error_bound
        self.mode = mode
        self.async_save = async_save
        self.chunk_bytes = chunk_bytes
        # leaves are device_get'd to host before they reach the codec, so the
        # numpy host mirror is the default; pass backend='auto' to route the
        # frame bodies through the device-resident encode instead
        self._codec = SZxCodec(workers=workers, backend=backend)
        # compress=False stores EVERY leaf raw: min_compress_elems above any
        # real leaf size routes all of them into the shared pack frame
        self._tree_codec = TreeCodec(
            codec=self._codec,
            error_bound=error_bound,
            mode=mode,
            chunk_bytes=chunk_bytes,
            min_compress_elems=1024 if compress else (1 << 62),
        )
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        os.makedirs(root, exist_ok=True)

    # ----------------------------------------------------------- save
    def save(self, step: int, tree) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self.wait()

            def run():
                try:
                    self._save_sync(step, host_tree)
                except BaseException as e:  # surfaced on next wait()
                    self._last_error = e

            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            self._save_sync(step, host_tree)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _save_sync(self, step: int, host_tree) -> None:
        final = os.path.join(self.root, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        with open(os.path.join(tmp, _STREAM), "wb") as f:
            stream_manifest = self._tree_codec.compress_tree(host_tree, f)
        manifest = {
            "manifest_version": MANIFEST_VERSION,
            "step": step,
            "time": time.time(),
            "file": _STREAM,
            "leaves": stream_manifest["leaves"],
            "raw_bytes": stream_manifest["raw_bytes"],
            "stored_bytes": stream_manifest["stored_bytes"],
        }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, _MARKER), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)      # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()   # committed steps only, by construction
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"), ignore_errors=True)

    # ----------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in sorted(os.listdir(self.root)):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, d, _MARKER)):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _step_dir(self, step: Optional[int]) -> tuple[str, dict]:
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        return d, manifest

    def restore(self, template, step: Optional[int] = None, *, shardings=None):
        """Restore into the structure of `template` (arrays or ShapeDtypeStructs).

        `shardings`: optional matching pytree of Shardings -- enables elastic
        restore onto any mesh topology."""
        d, manifest = self._step_dir(step)
        by_name = {m["name"]: m for m in manifest["leaves"]}

        leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        names = [leaf_name(kp) for kp, _ in leaves_t]
        for name in names:
            if name not in by_name:
                raise KeyError(f"leaf {name} not in checkpoint step {manifest['step']}")
        if manifest.get("manifest_version", 1) >= 2:
            with open(os.path.join(d, manifest["file"]), "rb") as f:
                arrays = self._tree_codec.decompress_tree(f, select=names)
        else:
            arrays = {n: self._restore_leaf_v1(d, by_name[n]) for n in names}
        out = []
        for idx, name in enumerate(names):
            arr = arrays[name]
            if shard_leaves is not None:
                arr = jax.device_put(arr, shard_leaves[idx])
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]

    def restore_leaves(
        self, names: Iterable[str], step: Optional[int] = None
    ) -> dict[str, np.ndarray]:
        """Partial restore: read ONLY the named leaves' byte ranges (v3 index
        seek) -- the elastic single-shard restore path."""
        d, manifest = self._step_dir(step)
        if manifest.get("manifest_version", 1) >= 2:
            with open(os.path.join(d, manifest["file"]), "rb") as f:
                return self._tree_codec.decompress_tree(f, select=list(names))
        by_name = {m["name"]: m for m in manifest["leaves"]}
        out = {}
        for n in names:
            if n not in by_name:
                raise KeyError(f"leaf {n} not in checkpoint step {manifest['step']}")
            out[n] = self._restore_leaf_v1(d, by_name[n])
        return out

    def _restore_leaf_v1(self, d: str, meta: dict) -> np.ndarray:
        """Per-leaf-file layout of pre-TreeCodec checkpoints."""
        dtype = np_dtype_for(meta["dtype"])
        if meta["codec"] == "szx-chunked":
            n = int(np.prod(meta["shape"], dtype=np.int64)) if meta["shape"] else 1
            with open(os.path.join(d, meta["file"]), "rb") as f:
                arr = self._codec.load_chunked(f, n=n)
            return arr.reshape(meta["shape"]).astype(dtype)
        with open(os.path.join(d, meta["file"]), "rb") as f:
            data = f.read()
        if meta["codec"] == "szx":
            return self._codec.decompress(data).reshape(meta["shape"]).astype(dtype)
        return np.frombuffer(data, dtype=dtype).reshape(meta["shape"])

    def stats(self, step: Optional[int] = None) -> dict:
        _, manifest = self._step_dir(step)
        raw = sum(m["raw_bytes"] for m in manifest["leaves"])
        stored = sum(m["stored_bytes"] for m in manifest["leaves"])
        return {"step": manifest["step"], "raw_bytes": raw, "stored_bytes": stored,
                "ratio": raw / max(stored, 1)}
