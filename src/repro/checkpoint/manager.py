"""Fault-tolerant checkpoint manager with optional SZx compression.

Layout (one directory per step, MANIFEST v2):
    <root>/step_000123/
        MANIFEST.json      -- {"manifest_version": 2, "step", "time",
                               "file": "tree.szt", "leaves": [...]}
        tree.szt           -- ONE container-v3 multi-leaf TreeCodec stream
                              (small/integer leaves in the shared raw pack,
                              large float leaves as chunked SZx frames,
                              seekable index footer)
        _COMMITTED         -- atomic commit marker (written last)

v1 checkpoints (one ``<leaf-id>.bin`` file per leaf, written by earlier
revisions) remain restorable; ``manifest_version`` is absent there.

Features required at 1000-node scale and implemented here:
  * atomic commit (tmp dir + rename + marker file): a crashed writer never
    corrupts the latest checkpoint; the previous _COMMITTED step stays
    restorable through any mid-save crash
  * keep-last-k garbage collection over COMMITTED steps only
  * background (async) save thread so the train loop is not blocked
  * error-bounded SZx compression of float leaves (the paper's Fig. 13
    dump/load use case: compression above PFS bandwidth = faster I/O wall)
    through ``TreeCodec`` -- one stream file per step instead of per leaf,
    chunked frame bodies on ``workers`` threads, bounded save/restore memory
  * partial restore: ``restore_leaves(names)`` reads ONLY the selected
    leaves' byte ranges via the v3 index footer (elastic single-shard
    restore); full ``restore`` also reads leaf-by-leaf through the index
  * cross-topology restore: leaves are stored as full logical arrays, so any
    mesh can load any checkpoint (elastic scaling); device placement is the
    caller's (jax.device_put with the new sharding)
  * integer leaves that SZx would mangle (ints, step counters) are stored
    raw in the shared pack frame and round-trip bit-exactly
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Iterable, Optional

import jax
import numpy as np

from repro import obs
from repro.core.codec import SZxCodec, TreeCodec
from repro.core.codec.plan import Bound, as_bound
from repro.core.codec.tree import leaf_name, np_dtype_for

_MARKER = "_COMMITTED"
_STREAM = "tree.szt"
MANIFEST_VERSION = 2


class CheckpointManager:
    def __init__(
        self,
        root: str,
        *,
        keep: int = 3,
        compress: bool = False,
        bound: Bound | float | None = None,
        error_bound: float | None = None,
        mode: str | None = None,
        async_save: bool = False,
        chunk_bytes: int = 64 << 20,
        workers: int = 1,
        backend: str = "numpy",
        stage: str | int | None = None,
    ):
        self.root = root
        self.keep = keep
        self.compress = compress
        if bound is None and error_bound is None and mode is None:
            self.bound = Bound.rel(1e-6)   # the manager's historical default
        else:
            # legacy error_bound= without mode= historically meant 'rel' here
            if error_bound is not None and mode is None:
                mode = "rel"
            self.bound = as_bound(bound, mode, error_bound=error_bound,
                                  owner="CheckpointManager")
        self.async_save = async_save
        self.chunk_bytes = chunk_bytes
        # leaves are device_get'd to host before they reach the codec, so the
        # numpy host mirror is the default; pass backend='auto' to route the
        # frame bodies through the device-resident encode instead
        self._codec = SZxCodec(workers=workers, backend=backend, stage=stage)
        # compress=False stores EVERY leaf raw: min_compress_elems above any
        # real leaf size routes all of them into the shared pack frame
        self._tree_codec = TreeCodec(
            codec=self._codec,
            bound=self.bound,
            chunk_bytes=chunk_bytes,
            min_compress_elems=1024 if compress else (1 << 62),
        )
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        os.makedirs(root, exist_ok=True)

    # ----------------------------------------------------------- save
    def save(self, step: int, tree) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self.wait()

            def run():
                try:
                    self._save_sync(step, host_tree)
                except BaseException as e:  # surfaced on next wait()
                    self._last_error = e

            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            self._save_sync(step, host_tree)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _save_sync(self, step: int, host_tree) -> None:
        final = os.path.join(self.root, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        with open(os.path.join(tmp, _STREAM), "wb") as f:
            # per-leaf encode timing lands as tree.leaf_encode spans
            with obs.span("checkpoint.save", step=step):
                stream_manifest = self._tree_codec.compress_tree(host_tree, f)
        if obs.enabled():
            obs.counter("checkpoint.saves").inc()
            obs.counter("checkpoint.saved_raw_bytes").inc(
                int(stream_manifest["raw_bytes"])
            )
            obs.counter("checkpoint.saved_bytes").inc(
                int(stream_manifest["stored_bytes"])
            )
        manifest = {
            "manifest_version": MANIFEST_VERSION,
            "step": step,
            "time": time.time(),
            "file": _STREAM,
            "leaves": stream_manifest["leaves"],
            # frame byte ranges duplicated from the stream's index footer:
            # sliced restore seeks without re-reading the footer, and the
            # ranges survive even if the stream's own footer is damaged
            "frames": stream_manifest["frames"],
            "raw_bytes": stream_manifest["raw_bytes"],
            "stored_bytes": stream_manifest["stored_bytes"],
        }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, _MARKER), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)      # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()   # committed steps only, by construction
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"), ignore_errors=True)

    # ----------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in sorted(os.listdir(self.root)):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, d, _MARKER)):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _step_dir(self, step: Optional[int]) -> tuple[str, dict]:
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        return d, manifest

    def restore(self, template, step: Optional[int] = None, *, shardings=None):
        """Restore into the structure of `template` (arrays or ShapeDtypeStructs).

        `shardings`: optional matching pytree of Shardings -- enables elastic
        restore onto any mesh topology."""
        d, manifest = self._step_dir(step)
        by_name = {m["name"]: m for m in manifest["leaves"]}

        leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        names = [leaf_name(kp) for kp, _ in leaves_t]
        for name in names:
            if name not in by_name:
                raise KeyError(f"leaf {name} not in checkpoint step {manifest['step']}")
        # per-leaf decode timing lands as tree.leaf_decode spans
        with obs.span("checkpoint.restore", step=int(manifest["step"])):
            if manifest.get("manifest_version", 1) >= 2:
                with open(os.path.join(d, manifest["file"]), "rb") as f:
                    arrays = self._tree_codec.decompress_tree(f, select=names)
            else:
                arrays = {n: self._restore_leaf_v1(d, by_name[n]) for n in names}
        if obs.enabled():
            obs.counter("checkpoint.restores").inc()
        out = []
        for idx, name in enumerate(names):
            arr = arrays[name]
            if shard_leaves is not None:
                arr = jax.device_put(arr, shard_leaves[idx])
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]

    def restore_leaves(
        self, names: Iterable[str], step: Optional[int] = None
    ) -> dict[str, np.ndarray]:
        """Partial restore: read ONLY the named leaves' byte ranges (v3 index
        seek) -- the elastic single-shard restore path."""
        d, manifest = self._step_dir(step)
        if manifest.get("manifest_version", 1) >= 2:
            with open(os.path.join(d, manifest["file"]), "rb") as f:
                return self._tree_codec.decompress_tree(f, select=list(names))
        by_name = {m["name"]: m for m in manifest["leaves"]}
        out = {}
        for n in names:
            if n not in by_name:
                raise KeyError(f"leaf {n} not in checkpoint step {manifest['step']}")
            out[n] = self._restore_leaf_v1(d, by_name[n])
        return out

    def restore_leaf_slice(
        self, name: str, rows, step: Optional[int] = None
    ) -> np.ndarray:
        """Store-backed sliced restore: rows ``rows`` (an int or a step-1
        slice over the LEADING axis) of leaf ``name``, reading and decoding
        only the frames -- and within boundary frames only the SZx block
        range -- that the slice touches.

        A leading-axis slice of a C-order array is one contiguous flat
        element range, and a leaf's chunk frames partition its flat range,
        so the read path seeks straight to the intersecting frames via the
        v3 index (raw pack leaves read just the byte sub-range).  This is
        the elastic sub-shard restore: a host that owns rows [lo, hi) of a
        sharded parameter pulls exactly those rows out of a full checkpoint.
        """
        d, manifest = self._step_dir(step)
        if manifest.get("manifest_version", 1) < 2:
            # v1 layouts have no per-leaf frame index; restore + slice
            return self._restore_leaf_v1(
                d, {m["name"]: m for m in manifest["leaves"]}[name]
            )[rows]
        by_name = {m["name"]: m for m in manifest["leaves"]}
        if name not in by_name:
            raise KeyError(f"leaf {name} not in checkpoint step {manifest['step']}")
        meta = by_name[name]
        shape = tuple(meta["shape"])
        if not shape:
            raise ValueError(f"leaf {name} is a scalar; use restore_leaves")
        dtype = np_dtype_for(meta["dtype"])
        if isinstance(rows, slice):
            if rows.step not in (None, 1):
                raise ValueError("restore_leaf_slice supports step-1 slices only")
            lo, hi, _ = rows.indices(shape[0])
            if hi <= lo:                    # numpy semantics: empty slice
                return np.empty((0,) + shape[1:], dtype)
            squeeze = False
        else:
            lo = int(rows) + (shape[0] if int(rows) < 0 else 0)
            if not 0 <= lo < shape[0]:
                raise IndexError(f"row {rows} out of range for shape {shape}")
            hi, squeeze = lo + 1, True
        row_elems = int(np.prod(shape[1:], dtype=np.int64)) if len(shape) > 1 else 1
        flat_lo, flat_hi = lo * row_elems, hi * row_elems
        out = np.empty(flat_hi - flat_lo, dtype)
        with open(os.path.join(d, manifest["file"]), "rb") as f:
            stream_idx = {"frames": manifest.get("frames")} if "frames" in manifest \
                else None
            if stream_idx is None:
                from repro.core.codec import container as _c

                stream_idx = _c.read_index_footer(f)
            if meta["codec"] == "raw":
                frame_off, _len = stream_idx["frames"][meta["frames"][0]]
                inner, _size = meta["pack"]
                from repro.core.codec import container as _c

                f.seek(frame_off + _c.FRAME_HEADER.size + inner
                       + flat_lo * dtype.itemsize)
                data = _c._read_exact(f, out.nbytes)
                out[:] = np.frombuffer(data, dtype=dtype)
            else:
                self._fill_from_szx_frames(
                    f, stream_idx["frames"], meta["frames"], flat_lo, flat_hi, out
                )
        out = out.reshape((hi - lo,) + shape[1:])
        return out[0] if squeeze else out

    def _fill_from_szx_frames(self, f, frames, frame_range, flat_lo, flat_hi,
                              out) -> None:
        """Fill ``out`` with elements [flat_lo, flat_hi) of a leaf stored as
        chunk frames [lo_f, hi_f): peek each frame's element count from its
        header (58-byte reads), then fully read + block-range-decode only
        the intersecting frames."""
        from repro.core.codec import container as _c

        lo_f, hi_f = frame_range
        base = 0                           # flat offset of the current frame
        for i in range(lo_f, hi_f):
            off, length = frames[i][:2]
            _flags, _plen, sheader = _c.read_frame_stream_header_at(f, off, i)
            _m, _v, _dt, bs, n, _e, _nb, _nnc, _nmid = _c.HEADER.unpack_from(
                sheader, 0
            )
            frame_lo, frame_hi = base, base + n
            base = frame_hi
            if frame_hi <= flat_lo:
                continue
            if frame_lo >= flat_hi:
                break
            payload, _flags = _c.read_frame_at(f, off, length, i)
            ilo, ihi = max(flat_lo, frame_lo), min(flat_hi, frame_hi)
            b_lo, b_hi = (ilo - frame_lo) // bs, (ihi - frame_lo - 1) // bs + 1
            seg = self._codec.decompress_range(payload, b_lo, b_hi)
            out[ilo - flat_lo : ihi - flat_lo] = seg[
                (ilo - frame_lo) - b_lo * bs : (ihi - frame_lo) - b_lo * bs
            ]
        if base < flat_hi:
            raise ValueError(
                f"leaf frames cover {base} elements, slice needs {flat_hi}"
            )

    def _restore_leaf_v1(self, d: str, meta: dict) -> np.ndarray:
        """Per-leaf-file layout of pre-TreeCodec checkpoints."""
        dtype = np_dtype_for(meta["dtype"])
        if meta["codec"] == "szx-chunked":
            n = int(np.prod(meta["shape"], dtype=np.int64)) if meta["shape"] else 1
            with open(os.path.join(d, meta["file"]), "rb") as f:
                arr = self._codec.load_chunked(f, n=n)
            return arr.reshape(meta["shape"]).astype(dtype)
        with open(os.path.join(d, meta["file"]), "rb") as f:
            data = f.read()
        if meta["codec"] == "szx":
            return self._codec.decompress(data).reshape(meta["shape"]).astype(dtype)
        return np.frombuffer(data, dtype=dtype).reshape(meta["shape"])

    # ------------------------------------------------- checkpoint <-> store
    # The convergence half-steps: a training corpus written through the
    # manager is an ordinary ArrayStore (window-queryable by the ingest
    # loader, restorable in full), and an SZx-compressed checkpoint leaf is
    # openable AS a store view without rewriting a byte -- the leaf's chunk
    # frames inside tree.szt already are store chunk frames.

    def store_path(self, name: str) -> str:
        if not name or any(c in name for c in "/\\") or name.startswith("."):
            raise ValueError(f"bad store name {name!r}")
        return os.path.join(self.root, "stores", f"{name}.szs")

    def save_store(self, name: str, arr, *, bound=None,
                   chunk_shape: tuple[int, ...] | None = None,
                   chunk_bytes: int | None = None,
                   attrs: Optional[dict] = None) -> str:
        """Write ``arr`` as an ArrayStore under ``<root>/stores/<name>.szs``
        (tmp + rename, so a crashed writer never corrupts a published
        corpus); returns the path.  Defaults to the manager's bound and the
        store's ingest-friendly ~2 MB chunks (NOT the manager's coarse
        checkpoint chunking)."""
        from repro.store import ArrayStore
        from repro.store.grid import DEFAULT_CHUNK_TARGET_BYTES

        path = self.store_path(name)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        host = np.asarray(jax.device_get(arr))
        tmp = path + ".tmp"
        try:
            ArrayStore.save(
                tmp, host, self.bound if bound is None else bound,
                chunk_shape=chunk_shape,
                chunk_bytes=chunk_bytes or DEFAULT_CHUNK_TARGET_BYTES,
                workers=self._codec.workers,
                attrs=attrs,
                stage=self._codec.stage,
            )
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.remove(tmp)
            raise
        return path

    def open_store(self, name: str, **open_kw):
        """Lazy :class:`repro.store.array.CompressedArray` over a saved
        corpus (pass ``backend=``/``device=``/``cache=`` through)."""
        from repro.store import ArrayStore

        return ArrayStore.open(self.store_path(name), **open_kw)

    def restore_store(self, name: str) -> np.ndarray:
        with self.open_store(name) as ca:
            return ca[...]

    def stores(self) -> list[str]:
        d = os.path.join(self.root, "stores")
        if not os.path.isdir(d):
            return []
        return sorted(fn[:-4] for fn in os.listdir(d) if fn.endswith(".szs"))

    def leaf_store(self, name: str, step: Optional[int] = None, *,
                   backend: str = "numpy"):
        """Open ONE SZx-compressed checkpoint leaf as a lazy store view.

        Synthesizes a 1-d block-grid index over the leaf's chunk frames in
        ``tree.szt`` (same container, same per-chunk SZx streams as an
        ArrayStore file, just with GLOBAL frame sequence numbers -- hence
        ``seq_base``), so the leaf is ROI/window-queryable through
        ``CompressedArray`` and ``StoreLoader`` with bytes read ∝ ROI.
        The view is 1-d over the leaf's C-order flattening; its ``attrs``
        carry the logical ``leaf_shape``.
        """
        from repro.core.codec import container as _c
        from repro.core.codec import plan as _plan
        from repro.store import format as _format
        from repro.store.array import CompressedArray
        from repro.store.grid import ChunkGrid

        d, manifest = self._step_dir(step)
        if manifest.get("manifest_version", 1) < 2:
            raise ValueError(
                "leaf_store needs a v2 (tree-stream) checkpoint"
            )
        by_name = {m["name"]: m for m in manifest["leaves"]}
        if name not in by_name:
            raise KeyError(
                f"leaf {name} not in checkpoint step {manifest['step']}"
            )
        meta = by_name[name]
        if meta["codec"] != "szx":
            raise ValueError(
                f"leaf {name} is stored {meta['codec']!r}; only "
                "szx-compressed leaves are store-viewable (raw-pack leaves "
                "restore via restore_leaves)"
            )
        shape = tuple(int(s) for s in meta["shape"]) or (1,)
        n = int(np.prod(shape, dtype=np.int64))
        lo_f, hi_f = (int(v) for v in meta["frames"])
        frames_all = manifest["frames"]
        spec = _plan.spec_for(np_dtype_for(meta["dtype"]))
        f = open(os.path.join(d, manifest["file"]), "rb")
        try:
            off0 = int(frames_all[lo_f][0])
            _flags, _plen, sheader = _c.read_frame_stream_header_at(
                f, off0, lo_f
            )
            _m, _v, _dt, bs, n0, e, _nb, _nnc, _nmid = _c.HEADER.unpack_from(
                sheader, 0
            )
            # tree chunking is uniform except the tail, so the first frame's
            # element count IS the chunk size of a 1-d grid over the leaf
            per = n if hi_f - lo_f == 1 else int(n0)
            grid = ChunkGrid((n,), (min(per, n),))
            if grid.nchunks != hi_f - lo_f:
                raise ValueError(
                    f"leaf {name}: {hi_f - lo_f} frames do not form a "
                    f"uniform chunk grid ({per} elements/frame over {n})"
                )
            frames = []
            for i in range(lo_f, hi_f):
                off, length = (int(v) for v in frames_all[i][:2])
                frames.append([
                    off, length,
                    grid.chunk_elements(grid.chunk_coord(i - lo_f)),
                ])
            idx = _format.build_store_index(
                grid, spec.code, int(bs), float(e), frames,
                {"leaf": name, "leaf_shape": list(shape),
                 "step": manifest["step"]},
            )
            return CompressedArray(
                f, idx, backend=backend, own_file=True, seq_base=lo_f,
            )
        except BaseException:
            f.close()
            raise

    def stats(self, step: Optional[int] = None) -> dict:
        _, manifest = self._step_dir(step)
        raw = sum(m["raw_bytes"] for m in manifest["leaves"])
        stored = sum(m["stored_bytes"] for m in manifest["leaves"])
        return {"step": manifest["step"], "raw_bytes": raw, "stored_bytes": stored,
                "ratio": raw / max(stored, 1)}
