"""Fault-tolerant checkpoint manager with optional SZx compression.

Layout (one directory per step):
    <root>/step_000123/
        MANIFEST.json      -- tree structure, shapes, dtypes, codec, step
        <leaf-id>.bin      -- raw .npy bytes or SZx stream per leaf
        _COMMITTED         -- atomic commit marker (written last)

Features required at 1000-node scale and implemented here:
  * atomic commit (tmp dir + rename + marker file): a crashed writer never
    corrupts the latest checkpoint
  * keep-last-k garbage collection
  * background (async) save thread so the train loop is not blocked
  * error-bounded SZx compression of float leaves (the paper's Fig. 13
    dump/load use case: compression above PFS bandwidth = faster I/O wall),
    native per-dtype streams (f32/f64/f16/bf16) via repro.core.codec
  * chunked frame streams for large leaves: bounded-memory compression and
    restore of arbitrarily big arrays (codec 'szx-chunked'); ``workers > 1``
    runs the frame bodies on a thread pool with byte-identical output
  * cross-topology restore: leaves are stored as full logical arrays, so any
    mesh can load any checkpoint (elastic scaling); device placement is the
    caller's (jax.device_put with the new sharding)
  * integer leaves that SZx would mangle (ints, step counters) are stored raw
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.core.codec import SZxCodec, plan as codec_plan

_MARKER = "_COMMITTED"


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(
        self,
        root: str,
        *,
        keep: int = 3,
        compress: bool = False,
        error_bound: float = 1e-6,
        mode: str = "rel",
        async_save: bool = False,
        chunk_bytes: int = 64 << 20,
        workers: int = 1,
    ):
        self.root = root
        self.keep = keep
        self.compress = compress
        self.error_bound = error_bound
        self.mode = mode
        self.async_save = async_save
        # leaves larger than chunk_bytes are written as self-delimiting SZx
        # frame sequences so save/restore memory stays bounded per leaf;
        # workers > 1 runs those frames on a thread pool (identical bytes)
        self.chunk_bytes = chunk_bytes
        self._codec = SZxCodec(workers=workers)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        os.makedirs(root, exist_ok=True)

    # ----------------------------------------------------------- save
    def save(self, step: int, tree) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self.wait()

            def run():
                try:
                    self._save_sync(step, host_tree)
                except BaseException as e:  # surfaced on next wait()
                    self._last_error = e

            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            self._save_sync(step, host_tree)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _save_sync(self, step: int, host_tree) -> None:
        final = os.path.join(self.root, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "leaves": []}
        for i, (name, leaf) in enumerate(_leaf_paths(host_tree)):
            arr = np.asarray(leaf)
            fn = f"{i:05d}.bin"
            codec = "raw"
            compressible = (
                self.compress
                and arr.dtype in codec_plan.BY_DTYPE
                and arr.size >= 1024
            )
            path = os.path.join(tmp, fn)
            if compressible and arr.nbytes > self.chunk_bytes:
                # large leaf: stream self-delimiting frames, O(chunk) memory
                with open(path, "wb") as f:
                    stored = self._codec.dump_chunked(
                        arr, f, self.error_bound, mode=self.mode,
                        chunk_bytes=self.chunk_bytes,
                    )
                codec = "szx-chunked"
            else:
                if compressible:
                    data = self._codec.compress(arr, self.error_bound, mode=self.mode)
                    codec = "szx"
                else:
                    data = arr.tobytes()
                with open(path, "wb") as f:
                    f.write(data)
                stored = len(data)
            manifest["leaves"].append(
                {
                    "name": name,
                    "file": fn,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "codec": codec,
                    "raw_bytes": arr.nbytes,
                    "stored_bytes": stored,
                }
            )
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, _MARKER), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)      # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"), ignore_errors=True)

    # ----------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in sorted(os.listdir(self.root)):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, d, _MARKER)):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None, *, shardings=None):
        """Restore into the structure of `template` (arrays or ShapeDtypeStructs).

        `shardings`: optional matching pytree of Shardings -- enables elastic
        restore onto any mesh topology."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        by_name = {m["name"]: m for m in manifest["leaves"]}

        leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        out = []
        for idx, (kp, leaf) in enumerate(leaves_t):
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
            meta = by_name.get(name)
            if meta is None:
                raise KeyError(f"leaf {name} not in checkpoint step {step}")
            dtype = np.dtype(meta["dtype"]) if meta["dtype"] != "bfloat16" else jax.numpy.bfloat16
            if meta["codec"] == "szx-chunked":
                n = int(np.prod(meta["shape"], dtype=np.int64)) if meta["shape"] else 1
                with open(os.path.join(d, meta["file"]), "rb") as f:
                    arr = self._codec.load_chunked(f, n=n)   # O(leaf+chunk) peak
                arr = arr.reshape(meta["shape"]).astype(dtype)
            elif meta["codec"] == "szx":
                with open(os.path.join(d, meta["file"]), "rb") as f:
                    data = f.read()
                arr = self._codec.decompress(data).reshape(meta["shape"]).astype(dtype)
            else:
                with open(os.path.join(d, meta["file"]), "rb") as f:
                    data = f.read()
                arr = np.frombuffer(data, dtype=dtype).reshape(meta["shape"])
            if shard_leaves is not None:
                arr = jax.device_put(arr, shard_leaves[idx])
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]

    def stats(self, step: Optional[int] = None) -> dict:
        if step is None:
            step = self.latest_step()
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        raw = sum(m["raw_bytes"] for m in manifest["leaves"])
        stored = sum(m["stored_bytes"] for m in manifest["leaves"])
        return {"step": step, "raw_bytes": raw, "stored_bytes": stored,
                "ratio": raw / max(stored, 1)}
