"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

shard_map manual over 'stage'; microbatches stream through stages via
``jax.lax.ppermute``.  The schedule runs (n_micro + n_stages - 1) ticks; each
tick every stage processes one microbatch (bubble at the edges, the classic
GPipe cost).  Stage-local layer stacks are plain scans, so this composes with
the TP/DP shardings of the stage-interior (auto axes).

This is the optional PP axis (DESIGN.md section 6): the production dry-run
grid uses DP x TP x EP x FSDP x SP, and PP is validated separately by
tests/test_pipeline.py on an 8-device host mesh.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.compat import shard_map


def pipeline_apply(
    stage_fn: Callable,        # (stage_params, x) -> y   (per-stage compute)
    mesh,
    stage_axis: str = "stage",
    *,
    compress_activations: bool = False,
    num_planes: int = 1,
    compress_block: int = 64,
    compress_backend: str = "jax",
):
    """Returns fn(stacked_stage_params, microbatches) -> outputs.

    stacked_stage_params: pytree with leading [n_stages] dim (stage-sharded).
    microbatches: (n_micro, mb, ...) input microbatches.
    Output: (n_micro, mb, ...) as produced by the LAST stage.

    ``compress_activations=True`` routes the per-tick activation shift
    through ``grad_compress.compressed_ppermute``: each stage szx-planes
    encodes its output, permutes the encoding arrays (~4x fewer wire bytes
    at P=1), and the next stage decodes -- the paper's
    faster-than-the-link compression applied to pipeline traffic.  Lossy
    (bounded by the planes budget); leave off for exact schedules.
    """
    n_stages = mesh.shape[stage_axis]

    def run(params, xs):
        n_micro = xs.shape[0]
        ticks = n_micro + n_stages - 1
        if obs.enabled():
            # trace-time accounting (scan body runs once per trace): bytes a
            # stage shifts per tick, raw vs on-the-wire when compressed
            mb = xs[0]
            raw = int(mb.size) * jnp.dtype(mb.dtype).itemsize
            wire = raw
            if compress_activations:
                from repro.core import grad_compress

                wire = int(
                    mb.size * grad_compress.wire_bytes_per_value(
                        num_planes, compress_block
                    )
                )
            obs.counter("pipeline.programs").inc()
            obs.gauge("pipeline.ticks").set(ticks)
            obs.gauge("pipeline.tick_raw_bytes").set(raw)
            obs.gauge("pipeline.tick_wire_bytes").set(wire)

        def body(carry, t):
            buf, outs = carry          # buf: (1, mb, ...) current stage input
            stage = jax.lax.axis_index(stage_axis)
            # stage 0 injects microbatch t (or zeros past the end)
            inject = jnp.where(
                t < n_micro,
                jax.lax.dynamic_index_in_dim(
                    xs, jnp.minimum(t, n_micro - 1), 0, keepdims=False
                ),
                jnp.zeros_like(buf[0]),
            )
            x = jnp.where(stage == 0, inject, buf[0])
            y = stage_fn(jax.tree.map(lambda p: p[0], params), x)
            # last stage emits its result for microbatch (t - n_stages + 1)
            emit_idx = t - (n_stages - 1)
            outs = jax.lax.cond(
                (stage == n_stages - 1) & (emit_idx >= 0),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(emit_idx, 0), 0
                ),
                lambda o: o,
                outs,
            )
            # shift activations to the next stage
            ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            if compress_activations:
                from repro.core import grad_compress

                nxt = grad_compress.compressed_ppermute(
                    y, stage_axis, ring, num_planes=num_planes,
                    block=compress_block, backend=compress_backend,
                )
            else:
                nxt = jax.lax.ppermute(y, stage_axis, ring)
            return (nxt[None], outs), None

        buf0 = jnp.zeros_like(xs[:1])
        outs0 = jnp.zeros_like(xs)
        (buf, outs), _ = jax.lax.scan(body, (buf0, outs0), jnp.arange(ticks))
        # only the last stage holds real outputs (zeros elsewhere): psum
        # broadcasts them so the P() out_spec is truthful
        return jax.lax.psum(outs, stage_axis)

    return shard_map(
        run,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        axis_names={stage_axis},
        check_vma=False,
    )
