"""Optional GPipe-style pipeline-parallel axis (lax.ppermute microbatching)."""
from repro.pipeline_par.gpipe import pipeline_apply  # noqa: F401
