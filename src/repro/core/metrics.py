"""Reconstruction-quality metrics used throughout the paper (Section III)."""
from __future__ import annotations

import numpy as np


def psnr(orig, recon) -> float:
    """Peak signal-to-noise ratio, Formula (7) of the paper."""
    orig = np.asarray(orig, np.float64).reshape(-1)
    recon = np.asarray(recon, np.float64).reshape(-1)
    rng = orig.max() - orig.min()
    mse = float(np.mean((orig - recon) ** 2))
    if mse == 0:
        return float("inf")
    return 20.0 * np.log10(rng / np.sqrt(mse))


def ssim(orig, recon, *, window: int = 7) -> float:
    """Mean 1-D windowed SSIM (flattened); sufficient for regression checks."""
    x = np.asarray(orig, np.float64).reshape(-1)
    y = np.asarray(recon, np.float64).reshape(-1)
    rng = x.max() - x.min()
    if rng == 0:
        return 1.0
    c1, c2 = (0.01 * rng) ** 2, (0.03 * rng) ** 2
    n = (x.size // window) * window
    xw = x[:n].reshape(-1, window)
    yw = y[:n].reshape(-1, window)
    mx, my = xw.mean(1), yw.mean(1)
    vx, vy = xw.var(1), yw.var(1)
    cov = ((xw - mx[:, None]) * (yw - my[:, None])).mean(1)
    s = ((2 * mx * my + c1) * (2 * cov + c2)) / (
        (mx**2 + my**2 + c1) * (vx + vy + c2)
    )
    return float(s.mean())


def max_abs_error(orig, recon) -> float:
    return float(
        np.max(np.abs(np.asarray(orig, np.float64) - np.asarray(recon, np.float64)))
    )


def compression_ratio(raw_bytes: int, compressed_bytes: int) -> float:
    return raw_bytes / max(compressed_bytes, 1)
