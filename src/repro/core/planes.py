"""szx-planes: fixed-shape in-graph byte-plane codec (DESIGN.md section 2).

This is the static-shape TPU variant of SZx used *inside* jit/GSPMD programs
(gradient compression, KV-cache compression) where XLA cannot represent
data-dependent output sizes.  It keeps the paper's structure -- block mu,
radius-exponent-derived bit budget, byte-aligned planes -- and trades the
per-value XOR leading-byte elision for a static plane count P in {1,2,3}.

Encoded pytree for an input of shape (..., n) flattened to blocks of `bs`:
  mu     : (nb,)  f32     block mean-of-min/max
  sexp   : (nb,)  int32   quantization exponent (power-of-two scale)
  planes : (P, nb, bs) uint8

Wire size = n*P + 6*ceil(n/bs) bytes vs 4n raw  (P=1, bs=128 -> 3.83x).
Reconstruction error <= 2^(E_k + 1 - 8P) per block (E_k = radius exponent),
i.e. ~0.4% of block range at P=1.  Exactly error-bounded whenever the bound
satisfies e >= 2^(E_k+1-8P); otherwise the residual goes through the error
feedback path (grad compression) -- see repro.core.grad_compress.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.codec import PlanesCodec

DEFAULT_BLOCK_SIZE = 128


class PlanesEncoded(NamedTuple):
    mu: jax.Array        # (nb,) f32
    sexp: jax.Array      # (nb,) int32
    planes: jax.Array    # (P, nb, bs) uint8
    n: int               # logical element count (static)
    block_size: int      # static


def wire_bytes(enc: PlanesEncoded) -> int:
    """Bytes actually moved by a collective transferring `enc`."""
    return int(enc.planes.size) + 8 * int(enc.mu.size)


def encode(x: jax.Array, *, num_planes: int = 1, block_size: int = DEFAULT_BLOCK_SIZE) -> PlanesEncoded:
    """Compress a flat f32 array into the fixed-shape plane representation."""
    mu, sexp, planes = PlanesCodec(num_planes).encode_flat(x, block_size)
    return PlanesEncoded(mu, sexp, planes, x.size, block_size)


def decode(enc: PlanesEncoded, shape=None, dtype=jnp.float32) -> jax.Array:
    """Reconstruct the (optionally reshaped) array."""
    xb = PlanesCodec(enc.planes.shape[0]).decode_blocks(enc.mu, enc.sexp, enc.planes)
    flat = xb.reshape(-1)[: enc.n]
    if shape is not None:
        flat = flat.reshape(shape)
    return flat.astype(dtype)


def roundtrip(x, *, num_planes: int = 1, block_size: int = DEFAULT_BLOCK_SIZE):
    """decode(encode(x)) with the original shape -- the lossy identity."""
    return decode(
        encode(x, num_planes=num_planes, block_size=block_size),
        shape=x.shape,
        dtype=x.dtype,
    )


def max_block_error_bound(enc: PlanesEncoded) -> jax.Array:
    """Per-block a-priori error bound (excludes clamp events).

    Quantization contributes 2^(E+1-8P); for P=3 the 24-bit integers sit at
    the edge of the f32 mantissa so the encode/decode product rounding adds up
    to a further 2^(8P-23) multiple of it (negligible for P=1,2).
    """
    num_planes = enc.planes.shape[0]
    E = (8 * num_planes - 2) - enc.sexp
    fp_slack = 1.0 + 2.0 ** (8 * num_planes - 23)
    return fp_slack * jnp.exp2((E + 1 - 8 * num_planes).astype(jnp.float32))
