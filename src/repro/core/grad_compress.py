"""SZx gradient compression for the slow (cross-pod) data-parallel axis.

The paper's pitch -- compression throughput above link bandwidth -- pays off
exactly where links are slowest: the DCN/inter-pod reduction.  We therefore
compress ONLY the 'pod'-axis all-reduce: within a pod, gradients reduce in
full precision via GSPMD; across pods we run a manual shard_map collective
(auto-GSPMD inside) that

  1. adds the error-feedback accumulator,
  2. szx-planes-encodes the sum (per-block mu + sexp + P uint8 planes),
  3. all_gathers the (~4x smaller at P=1) encoded payload over 'pod',
  4. decodes + means, and
  5. stores the local residual back into the accumulator.

Error feedback makes the scheme convergence-safe (the compression error is
re-applied next step instead of being lost).  Everything is fixed-shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat, obs
from repro.core.codec import PlanesCodec

DEFAULT_BLOCK = 64


def _record_wire(op: str, x, enc, members: int = 1) -> None:
    """Trace-time wire accounting: these collectives run inside jit/shard_map
    tracing, so this executes ONCE per compiled program -- counters record
    bytes-per-call of the traced shapes, not per executed step."""
    if not obs.enabled():
        return
    raw = int(x.size) * jnp.dtype(x.dtype).itemsize
    wire = sum(
        int(enc[k].size) * jnp.dtype(enc[k].dtype).itemsize
        for k in ("mu", "sexp", "planes")
    )
    obs.counter("collective.calls", op=op).inc()
    obs.counter("collective.raw_bytes", op=op).inc(raw * members)
    obs.counter("collective.wire_bytes", op=op).inc(wire * members)


def _encode_leaf(g, num_planes, block, backend="jax"):
    """Blocks run along the LAST axis, leading dims untouched.

    Flattening the leaf would destroy its TP/FSDP sharding and make GSPMD
    all-gather the full-precision gradient before encoding (measured +11 GB
    of intra-pod collectives per step on llama -- EXPERIMENTS section Perf);
    keeping the leaf shape keeps every encode op local to its shard.  The
    default 'jax' backend stages the whole encode into the caller's
    shard_map program (one fused program per leaf); 'kernel' dispatches the
    Pallas planes kernels instead.

    Returns the shared device-resident record (``DeviceEncoding``, kind
    'szx-planes') -- a registered pytree, so it flows through ``all_gather``
    and ``tree.map`` like the plain dict it replaced."""
    enc = PlanesCodec(num_planes, backend=backend).encode_last_axis_device(g, block)
    return enc.replace(sexp=enc["sexp"].astype(jnp.int16))  # wire: halve sexp bytes


def _decode_leaf(enc, shape, dtype, block, backend="jax"):
    return PlanesCodec(
        enc["planes"].shape[0], backend=backend
    ).decode_last_axis_encoding(enc, shape, dtype)


def compressed_psum_mean(grads, axis_name: str, *, num_planes: int = 1,
                         block: int = DEFAULT_BLOCK, backend: str = "jax"):
    """Inside shard_map: compressed all-reduce-mean over `axis_name`.

    Returns the mean of the decoded per-member gradients plus this member's
    compression residual (for error feedback)."""
    n = compat.axis_size(axis_name)

    def leaf(g):
        enc = _encode_leaf(g, num_planes, block, backend)
        _record_wire("psum_mean", g, enc, members=n)
        dec_local = _decode_leaf(enc, g.shape, jnp.float32, block, backend)
        residual = g.astype(jnp.float32) - dec_local
        gathered = jax.lax.all_gather(enc, axis_name)     # leading axis n
        total = jnp.zeros(g.shape, jnp.float32)
        for i in range(n):                                # n == 2 pods: unrolled
            member = jax.tree.map(lambda a: a[i], gathered)
            total = total + _decode_leaf(member, g.shape, jnp.float32, block, backend)
        return (total / n).astype(g.dtype), residual

    pairs = jax.tree.map(leaf, grads)
    mean = jax.tree.map(lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return mean, resid


def compressed_ppermute(x, axis_name: str, perm, *, num_planes: int = 1,
                        block: int = DEFAULT_BLOCK, backend: str = "jax"):
    """Inside shard_map: szx-planes-compressed ``jax.lax.ppermute``.

    Encodes ``x`` along its last axis, permutes the (~4x smaller at P=1)
    encoding arrays over ``axis_name``, and decodes on the receiving member.
    The point-to-point activation shift of pipeline parallelism
    (``pipeline_par.gpipe``) is the intended caller: the wire moves
    ``wire_bytes_per_value`` bytes/value instead of 4.0.
    """
    enc = _encode_leaf(x, num_planes, block, backend)
    _record_wire("ppermute", x, enc)
    moved = jax.tree.map(
        lambda a: jax.lax.ppermute(a, axis_name, perm), enc
    )
    return _decode_leaf(moved, x.shape, x.dtype, block, backend)


def compressed_all_to_all(x, axis_name: str, split_axis: int, concat_axis: int,
                          *, num_planes: int = 1, block: int = DEFAULT_BLOCK,
                          backend: str = "jax"):
    """Inside shard_map: szx-planes-compressed ``jax.lax.all_to_all``.

    Encodes along the LAST axis (which becomes the block grid and must not
    be the split/concat axis), moves each encoding array with a tiled
    ``all_to_all`` -- the ``planes`` array's leading plane axis shifts the
    operand axes by one -- and decodes to the post-exchange shape.
    """
    if x.ndim < 2:
        raise ValueError("compressed_all_to_all needs >= 2 dims (last = blocks)")
    split_axis, concat_axis = split_axis % x.ndim, concat_axis % x.ndim
    if x.ndim - 1 in (split_axis, concat_axis):
        raise ValueError(
            "compressed_all_to_all cannot split/concat the blocked last axis"
        )
    n = compat.axis_size(axis_name)
    enc = _encode_leaf(x, num_planes, block, backend)
    _record_wire("all_to_all", x, enc)

    def move(a, lead):
        return jax.lax.all_to_all(
            a, axis_name, split_axis + lead, concat_axis + lead, tiled=True
        )

    moved = enc.replace(
        mu=move(enc["mu"], 0),
        sexp=move(enc["sexp"], 0),
        planes=move(enc["planes"], 1),
    )
    shape = list(x.shape)
    shape[split_axis] //= n
    shape[concat_axis] *= n
    return _decode_leaf(moved, tuple(shape), x.dtype, block, backend)


def wire_bytes_per_value(num_planes: int, block: int = DEFAULT_BLOCK) -> float:
    """Bytes/gradient-value moved over the pod axis (vs 4.0 uncompressed)."""
    return PlanesCodec(num_planes).wire_bytes_per_value(block)
