"""File CLI for the SZx codec (parity with the reference ``szx`` tool).

    python -m repro.core.codec compress   IN.bin OUT.szx --dtype float32 \
        --bound rel:1e-3
    python -m repro.core.codec decompress IN.szx OUT.bin
    python -m repro.core.codec info       IN.szx [--stats] [--json]

``--bound`` takes the unified spelling (``1e-3`` = abs, ``abs:1e-3``,
``rel:1e-4``); the legacy ``--error-bound``/``--mode`` pair still works.

``compress`` reads a raw binary array (``--dtype`` elements), writes a
chunked container-v3 stream (self-delimiting frames + seekable index
footer; ``--no-index`` emits a footer-less v2 frame sequence).
``decompress`` restores the raw binary; ``info`` prints the stream header
and index without decoding.  Exit code is non-zero on any error.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _dtype(name: str) -> np.dtype:
    from repro.core.codec.tree import np_dtype_for

    return np_dtype_for(name)


def resolve_cli_bound(args):
    """--bound SPEC, or the legacy --error-bound/--mode pair -> Bound."""
    from repro.core.codec.plan import Bound

    if getattr(args, "bound", None) is not None:
        if args.error_bound is not None or args.mode is not None:
            raise ValueError("pass --bound OR --error-bound/--mode, not both")
        return Bound.parse(args.bound)
    if args.error_bound is None:
        raise ValueError("an error bound is required (--bound SPEC)")
    return Bound(args.error_bound, args.mode or "abs")


def _cmd_compress(args) -> int:
    from repro.core.codec import SZxCodec

    dtype = _dtype(args.dtype)
    data = np.fromfile(args.input, dtype=dtype)
    bound = resolve_cli_bound(args)
    codec = SZxCodec(
        block_size=args.block_size, backend=args.backend, workers=args.workers,
        stage=args.stage,
    )
    with open(args.output, "wb") as f:
        written = codec.dump_chunked(
            data, f, bound,
            chunk_bytes=args.chunk_bytes, index=not args.no_index,
        )
    raw = data.nbytes
    print(
        f"{args.input}: {raw} -> {written} bytes "
        f"(CR {raw / max(written, 1):.2f}, n={data.size} {dtype.name}, "
        f"{bound})"
    )
    return 0


def _cmd_decompress(args) -> int:
    from repro.core.codec import SZxCodec

    codec = SZxCodec(backend=args.backend, workers=args.workers)
    with open(args.input, "rb") as f:
        arr = codec.load_chunked(f)
    arr.tofile(args.output)
    print(f"{args.input}: restored {arr.size} {arr.dtype} -> {args.output}")
    return 0


def _scan_frames(f, container):
    """Sequential frame walk for footer-less v2 streams, through the
    container's validating iterator (magic/version/seq-order/LAST checks):
    (nframes, nraw, total elements, dtype code, e)."""
    nframes = nraw = 0
    total_n = 0
    dtype_code = None
    e = None
    for payload, flags in container.iter_frames(f, with_flags=True):
        nframes += 1
        if flags & container.FLAG_RAW:
            nraw += 1                          # raw pack: no v2 header inside
        else:
            dtype_code, n, e = container.peek_stream_meta(payload)
            total_n += n
    return nframes, nraw, total_n, dtype_code, e


def _iter_whole_frames(f, container):
    """Yield (frame bytes incl. header, flags) sequentially until LAST."""
    while True:
        head = f.read(container.FRAME_HEADER.size)
        if len(head) < container.FRAME_HEADER.size:
            return
        magic, _v, flags, _seq, ln = container.FRAME_HEADER.unpack_from(head, 0)
        if magic != container.FRAME_MAGIC:
            return
        body = f.read(ln)
        if len(body) != ln:
            raise ValueError("truncated SZx frame")
        yield head + body, flags
        if flags & container.FLAG_LAST:
            return


def _frame_stats_rows(path: str, container) -> list[dict]:
    """Per-frame ground-truth records (obs.stream_stats) plus a measured
    decode time per non-raw frame."""
    import time

    from repro.core.codec import SZxCodec
    from repro.obs import stream_stats

    codec = SZxCodec(backend="numpy")
    rows = []
    with open(path, "rb") as f:
        for frame, flags in _iter_whole_frames(f, container):
            rec = stream_stats.frame_stats(frame)
            if not rec.get("raw"):
                payload, _ = container.destage_frame_payload(
                    frame[container.FRAME_HEADER.size:], flags
                )
                t0 = time.perf_counter()
                codec.decompress(payload)
                rec["decode_ms"] = (time.perf_counter() - t0) * 1e3
            rows.append(rec)
    return rows


def _print_stats_table(rows: list[dict]) -> None:
    print(f"{'seq':>5} {'elements':>10} {'frame_B':>10} {'CR':>7} "
          f"{'const%':>7} {'stage':>15} {'mid raw->staged':>18} {'dec_ms':>8}")
    for r in rows:
        if r.get("raw"):
            print(f"{r['seq']:>5} {'-':>10} {r['frame_bytes']:>10} "
                  f"{'-':>7} {'-':>7} {'raw-pack':>15} {'-':>18} {'-':>8}")
            continue
        mid = f"{r['raw_mid_bytes']}->{r['staged_mid_bytes']}"
        print(f"{r['seq']:>5} {r['elements']:>10} {r['frame_bytes']:>10} "
              f"{r['ratio']:>7.2f} {100 * r['const_fraction']:>6.1f}% "
              f"{r['stage_name']:>15} {mid:>18} {r['decode_ms']:>8.2f}")


def _cmd_info(args) -> int:
    import json

    from repro.core.codec import container, plan

    with open(args.input, "rb") as f:
        # corrupt footers degrade to the sequential scan with a warning --
        # info stays usable on damaged v3 files
        idx = container.read_index_footer_safe(f)
        if idx is None:
            f.seek(0)
            nframes, nraw, total_n, dtype_code, e = _scan_frames(f, container)
        else:
            # answer from the index: no full-file walk.  Read at most one
            # frame header (the first non-raw frame) for dtype/e.
            kind = idx.get("kind")
            nframes = len(idx["frames"])
            nraw = 1 if kind == "szx-tree" else 0
            dtype_code = e = None
            if kind == "szx-tree":
                total_n = sum(
                    m["n"] for m in idx["leaves"] if m["codec"] == "szx"
                )
                szx_leaves = [m for m in idx["leaves"] if m["codec"] == "szx"]
                first = szx_leaves[0]["frames"][0] if szx_leaves else None
            else:
                if idx.get("kind") == "szx-store":
                    import math

                    total_n = math.prod(idx["shape"])
                    e = idx.get("e")       # store footer carries the bound
                else:
                    total_n = idx.get("n", 0)
                dtype_code = idx.get("dtype")
                first = 0 if idx["frames"] else None
            if first is not None and (dtype_code is None or e is None):
                off, length = idx["frames"][first][:2]
                payload, _flags = container.read_frame_at(f, off, length, first)
                dtype_code, _n, e = container.peek_stream_meta(payload)
    dtype = plan.spec_for_code(dtype_code).name if dtype_code is not None else None
    stats_rows = _frame_stats_rows(args.input, container) if args.stats else None
    if args.json:
        info = {
            "frames": nframes,
            "raw_frames": nraw,
            "n": total_n,
            "dtype": dtype,
            "e": e,
            "index": ("v" + str(idx["v"])) if idx else None,
            "kind": idx.get("kind") if idx else None,
            # per-frame [offset, length(, elements)] byte ranges when indexed
            "frame_ranges": idx["frames"] if idx else None,
        }
        if idx and idx.get("kind") == "szx-tree":
            info["leaves"] = [m["name"] for m in idx["leaves"]]
            info["raw_bytes"] = idx["raw_bytes"]
            info["stored_bytes"] = idx["stored_bytes"]
        if idx and idx.get("kind") == "szx-store":
            info["shape"] = idx["shape"]
            info["chunk_shape"] = idx["chunk_shape"]
        if idx and idx.get("stage"):
            info["stage"] = idx["stage"]
        if stats_rows is not None:
            info["frames_stats"] = stats_rows
        print(json.dumps(info, indent=1))
        return 0
    bound = f"{e:g}" if e is not None else "n/a"
    print(f"frames: {nframes} ({nraw} raw), elements: {total_n}, "
          f"dtype: {dtype or 'n/a'}, e: {bound}")
    print(f"index footer: {'v' + str(idx['v']) if idx else 'absent (v2 stream)'}")
    if idx:
        print(f"indexed frames: {len(idx['frames'])}, kind: {idx.get('kind')}")
        if idx.get("kind") == "szx-tree":
            print(f"leaves: {len(idx['leaves'])} "
                  f"(raw {idx['raw_bytes']} -> stored {idx['stored_bytes']} bytes)")
    if stats_rows is not None:
        _print_stats_table(stats_rows)
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.codec", description=__doc__.splitlines()[0]
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("compress", help="raw binary -> chunked SZx stream")
    c.add_argument("input")
    c.add_argument("output")
    c.add_argument("--bound", default=None, metavar="SPEC",
                   help="error bound: '1e-3' (abs), 'abs:1e-3', 'rel:1e-4'")
    c.add_argument("--error-bound", type=float, default=None,
                   help="legacy: ABS bound, or REL factor with --mode rel")
    c.add_argument("--mode", choices=("abs", "rel"), default=None)
    c.add_argument("--dtype", default="float32",
                   help="element dtype of the raw input (float32/float64/"
                        "float16/bfloat16)")
    c.add_argument("--block-size", type=int, default=128)
    c.add_argument("--chunk-bytes", type=int, default=64 << 20)
    c.add_argument("--workers", type=int, default=1)
    c.add_argument("--backend", default="auto")
    c.add_argument("--no-index", action="store_true",
                   help="omit the container-v3 index footer")
    c.add_argument("--stage", default=None,
                   choices=("bitshuffle-rle", "bitshuffle-zstd", "deflate"),
                   help="negotiated lossless second stage over the mid-byte "
                        "section (per-frame; skipped when it would not shrink)")
    c.set_defaults(fn=_cmd_compress)

    d = sub.add_parser("decompress", help="SZx stream -> raw binary")
    d.add_argument("input")
    d.add_argument("output")
    d.add_argument("--workers", type=int, default=1)
    d.add_argument("--backend", default="auto")
    d.set_defaults(fn=_cmd_decompress)

    i = sub.add_parser("info", help="print stream header/index summary")
    i.add_argument("input")
    i.add_argument("--json", action="store_true",
                   help="machine-readable summary incl. per-frame byte ranges")
    i.add_argument("--stats", action="store_true",
                   help="per-frame stream stats (elements, CR, const-block "
                        "fraction, stage, mid bytes, measured decode time)")
    i.set_defaults(fn=_cmd_info)

    args = ap.parse_args(argv)
    import struct

    try:
        return args.fn(args)
    except (OSError, ValueError, TypeError, struct.error) as err:
        print(f"error: {err}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    raise SystemExit(main())
