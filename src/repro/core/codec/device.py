"""Device layer: device-resident stream assembly + the shared encoding record.

The FZ-GPU / cuSZ lesson (PAPERS.md) is that an ultra-fast compressor must
keep the variable-length block compaction ON the accelerator and read back a
single contiguous payload; anything else turns the PCIe/ICI link into the
bottleneck.  Before this layer, ``transform.encode_blocks`` pulled seven
fixed-shape arrays (mu/const/reqlen/shift/nbytes/planes/L) to the host and
derived the byte layout there -- up to ``itemsize + 1`` times the compressed
size crossing the link, plus a host-side gather per frame.

:func:`encode_device` stages the whole encode as ONE jitted program: the
fused stats+pack kernel (``ops.encode_staged``) AND the layout derivation --
the ``nbytes - L`` per-value byte counts, their exclusive-cumsum offsets, and
the scatter of every section (const bitmap, mu words, compacted reqlen,
2-bit L codes, mid-byte stream) into one contiguous ``uint8`` body buffer.
A chunk therefore reaches the host as ONE ``jax.device_get`` of final
container bytes plus a tiny header struct (:func:`to_stream` -- the
transfer-spy test in ``tests/test_device_encoding.py`` pins the single-get
contract).  The byte layout is bit-identical to the host serializer
``container.build_stream`` for every dtype/backend (golden f32 bytes
unchanged); the numpy mirror is kept for the host backend.

:class:`DeviceEncoding` is the shared device-resident representation: a
registered pytree of named arrays plus static metadata.  The byte-stream
codec uses kind ``"szx-v2"`` (body/total/nnc/nmid); the fixed-shape
in-graph codec (``PlanesCodec`` -- gradient and KV-cache compression) uses
kind ``"szx-planes"`` (mu/sexp/planes), so checkpointing, grad compression,
and serving all speak one encoding record.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.codec import container, transform
from repro.core.codec.plan import Plan
from repro.kernels.specs import DtypeSpec

_INT32_SAFE = np.iinfo(np.int32).max - 16


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True, eq=False)
class DeviceEncoding:
    """A named bundle of (device or host) arrays plus static metadata.

    Registered as a jax pytree -- instances flow through ``jit`` /
    ``shard_map`` / ``lax.scan`` / collectives like ``all_gather`` exactly
    like a dict of arrays, while carrying the encoding ``kind`` and any
    static metadata (e.g. the resolved :class:`Plan`) out of band.
    """

    kind: str
    arrays: dict[str, Any]
    meta: tuple = ()               # sorted (key, value) pairs; values hashable

    @classmethod
    def make(cls, kind: str, arrays: Mapping[str, Any], **meta) -> "DeviceEncoding":
        return cls(kind, dict(arrays), tuple(sorted(meta.items())))

    @property
    def info(self) -> dict:
        return dict(self.meta)

    def __getitem__(self, key: str):
        return self.arrays[key]

    def replace(self, **arrays) -> "DeviceEncoding":
        """New encoding with some arrays swapped (kind/meta preserved)."""
        unknown = set(arrays) - set(self.arrays)
        if unknown:
            raise KeyError(f"unknown encoding arrays {sorted(unknown)}")
        return DeviceEncoding(self.kind, {**self.arrays, **arrays}, self.meta)

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        names = tuple(sorted(self.arrays))
        return tuple(self.arrays[n] for n in names), (self.kind, names, self.meta)

    @classmethod
    def tree_unflatten(cls, aux, children):
        kind, names, meta = aux
        return cls(kind, dict(zip(names, children)), meta)


# ---------------------------------------------------------------------------
# on-device layout derivation (the container byte layout, as scatters)
# ---------------------------------------------------------------------------

def _assemble_body(spec: DtypeSpec, mu, const, reqlen, nbytes, planes, L):
    """Scatter every v2 section into one contiguous uint8 body buffer.

    Pure jnp -- runs inside the fused encode program.  The buffer is sized to
    the static worst case (``cap``); ``total`` is the actual body length.
    Section layouts mirror ``container.build_stream`` byte for byte.
    """
    nb, bs = L.shape
    W = spec.itemsize
    nbm = (nb + 7) // 8
    mu_off = nbm
    req_off = nbm + W * nb
    cap = req_off + nb + (nb * bs + 3) // 4 + nb * bs * W
    idt = jnp.int32        # caller guarantees cap fits (host fallback otherwise)

    body = jnp.zeros((cap,), jnp.uint8)
    # const bitmap (np.packbits order: MSB-first within each byte)
    cpad = jnp.pad(const.astype(jnp.int32), (0, nbm * 8 - nb))
    bitmap = (cpad.reshape(nbm, 8) << jnp.arange(7, -1, -1)).sum(axis=1)
    body = body.at[:nbm].set(bitmap.astype(jnp.uint8))
    # mu words, little-endian bytes (same order as the host .view(np.uint8))
    body = body.at[mu_off:req_off].set(
        jax.lax.bitcast_convert_type(mu, jnp.uint8).reshape(-1)
    )
    # compacted reqlen: rank = position among non-constant blocks; constant
    # blocks scatter to `cap`, which mode="drop" discards
    nonconst = ~const
    incl = jnp.cumsum(nonconst.astype(idt))
    nnc = incl[-1]
    rank = incl - 1
    dst = jnp.where(nonconst, req_off + rank, cap)
    body = body.at[dst].set(reqlen.astype(jnp.uint8), mode="drop")
    # 2-bit L codes, 4 per byte little-endian: byte = c0|c1<<2|c2<<4|c3<<6.
    # Contributions hit disjoint bit positions of a zeroed buffer, so
    # scatter-add composes them exactly like the host pack_2bit.
    l_off = req_off + nnc
    pos = rank[:, None] * bs + jnp.arange(bs, dtype=idt)[None, :]
    contrib = (L << ((pos % 4) * 2).astype(jnp.int32)).astype(jnp.uint8)
    ldst = jnp.where(nonconst[:, None], l_off + pos // 4, cap)
    body = body.at[ldst.reshape(-1)].add(contrib.reshape(-1), mode="drop")
    nl = (nnc * bs + 3) // 4
    # mid stream in (block, value, byteplane) order: value v stores bytes
    # L[v] .. nbytes[v]-1 of its plane column at offset start[v] (the
    # exclusive prefix sum of the per-value counts `nbytes - L`)
    mid_off = l_off + nl
    counts = jnp.maximum(nbytes[:, None] - L, 0).reshape(-1).astype(idt)
    ends = jnp.cumsum(counts)
    start = ends - counts
    nmid = ends[-1]
    for k in range(W):
        plane = jnp.clip(L + k, 0, W - 1)[:, None, :]
        byte = jnp.take_along_axis(planes, plane, axis=1).reshape(-1)
        mdst = jnp.where(counts > k, mid_off + start + k, cap)
        body = body.at[mdst].set(byte, mode="drop")
    return body, mid_off + nmid, nnc, nmid


@functools.partial(jax.jit, static_argnames=("spec", "backend"))
def _encode_device_jit(xb, e, p_e, *, spec: DtypeSpec, backend: str):
    from repro.kernels import ops

    mu, const, reqlen, _shift, nbytes, planes, L = ops.encode_staged(
        xb, e, p_e, spec=spec, backend=backend
    )
    return _assemble_body(spec, mu, const, reqlen, nbytes, planes, L)


def _body_cap(p: Plan) -> int:
    nb, bs, W = p.nblocks, p.block_size, p.dtype.itemsize
    return (nb + 7) // 8 + W * nb + nb + (nb * bs + 3) // 4 + nb * bs * W


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def encode_device(xb, p: Plan) -> DeviceEncoding:
    """(nb, bs) blocks -> kind ``"szx-v2"`` encoding, resident where it ran.

    On the 'jax'/'kernel' backends everything up to (and including) the final
    byte layout stays on device; the host backend ('numpy', or any input too
    large for int32 scatter indices) produces the byte-identical numpy
    mirror via the host serializer.  Arrays: ``body`` (worst-case-cap uint8
    buffer), ``total`` (actual body length), ``nnc``, ``nmid``.
    """
    from repro.kernels import ops

    backend = ops._resolve(p.backend)
    if backend == "numpy" or p.nblocks == 0 or _body_cap(p) > _INT32_SAFE:
        return _encode_host(xb, p)
    spec = p.dtype
    from repro.kernels import specs

    p_e = specs.exact_exponent_of(float(p.error_bound))
    with ops._x64_scope(spec):        # f64 words need x64 for asarray AND trace
        # jnp.asarray handles numpy AND already-device inputs -- no host bounce
        body, total, nnc, nmid = _encode_device_jit(
            jnp.asarray(xb, spec.np_dtype),
            jnp.asarray(p.error_bound, spec.compute_np_dtype),
            jnp.int32(p_e),
            spec=spec,
            backend=backend,
        )
    return DeviceEncoding.make(
        "szx-v2", {"body": body, "total": total, "nnc": nnc, "nmid": nmid}, plan=p
    )


def _encode_host(xb, p: Plan) -> DeviceEncoding:
    """Numpy mirror: same record, bytes from the host serializer."""
    enc = transform.encode_blocks(xb, p)
    stream = container.build_stream(p, enc)
    (_m, _v, _d, _bs, _n, _e, _nb, nnc, nmid) = container.HEADER.unpack_from(stream, 0)
    body = np.frombuffer(stream, np.uint8, offset=container.HEADER.size)
    return DeviceEncoding.make(
        "szx-v2",
        {"body": body, "total": np.int64(body.size), "nnc": np.int64(nnc),
         "nmid": np.int64(nmid)},
        plan=p,
    )


def to_stream(enc: DeviceEncoding) -> bytes:
    """Materialize a ``"szx-v2"`` encoding as one self-contained v2 stream.

    Exactly ONE ``jax.device_get`` (body buffer + the tiny header scalars in
    a single transfer); the 40-byte header is packed on the host from the
    plan plus those scalars.  Host-mirror encodings pass through device_get
    untouched (numpy in, numpy out -- no transfer).
    """
    if enc.kind != "szx-v2":
        raise ValueError(f"cannot serialize encoding kind {enc.kind!r}")
    p: Plan = enc.info["plan"]
    body, total, nnc, nmid = jax.device_get(
        (enc["body"], enc["total"], enc["nnc"], enc["nmid"])
    )
    if obs.enabled():
        obs.counter("device.get.calls", op="encode_stream").inc()
        obs.counter("device.get.bytes", op="encode_stream").inc(
            int(np.asarray(body).nbytes)
        )
    header = container.HEADER.pack(
        container.MAGIC, container.VERSION, p.dtype.code, p.block_size, p.n,
        p.error_bound, p.nblocks, int(nnc), int(nmid),
    )
    return header + body[: int(total)].tobytes()


def encode_to_stream(xb, p: Plan) -> bytes:
    """One-transfer encode: blocks -> final container bytes."""
    return to_stream(encode_device(xb, p))


# ---------------------------------------------------------------------------
# device-resident decode (the mirror: ONE device_put of raw frame bytes)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("spec", "backend", "nb", "bs", "rb", "rebase")
)
def _decode_device_jit(body, nnc, lo, *, spec: DtypeSpec, backend: str,
                       nb: int, bs: int, rb: int, rebase: bool):
    from repro.kernels import ops

    return ops.decode_staged(
        body, nnc, lo, spec=spec, nb=nb, bs=bs, rb=rb, rebase=rebase,
        backend=backend,
    )


def _bucket_body(raw: np.ndarray, cap: int) -> np.ndarray:
    """Zero-pad the body bytes to the next power-of-2 bucket (bounded by the
    worst-case capacity) so each chunk geometry compiles a handful of decode
    programs instead of one per payload length.  Gathers in the decode
    program are index-clipped, so the padding is never observable."""
    size = min(cap, 1 << (max(int(raw.size), 1) - 1).bit_length())
    padded = np.zeros(size, np.uint8)
    padded[: raw.size] = raw
    return padded


def _checked_stream_header(buf):
    """Host-side header-only validation (mirrors ``container.parse_stream``'s
    messages); returns the unpacked fields + spec + section geometry."""
    from repro.core.codec import plan as plan_mod

    if len(buf) < container.HEADER.size:
        raise ValueError("truncated SZx stream (shorter than header)")
    magic, version, dtype_code, bs, n, e, nb, nnc, nmid = (
        container.HEADER.unpack_from(buf, 0)
    )
    if magic != container.MAGIC:
        raise ValueError("bad SZx stream header (magic mismatch)")
    if version != container.VERSION:
        raise ValueError(f"unsupported SZx stream version {version}")
    spec = plan_mod.spec_for_code(dtype_code)           # raises on unknown code
    if nnc > nb:
        raise ValueError("corrupt SZx stream (n_nonconst > nblocks)")
    if bs == 0 or nb != (n + bs - 1) // bs:
        raise ValueError("corrupt SZx stream (block count mismatch)")
    prefix_len = (
        container.HEADER.size + (nb + 7) // 8 + spec.itemsize * nb + nnc
        + (nnc * bs + 3) // 4
    )
    if len(buf) < prefix_len:
        raise ValueError(
            f"truncated SZx stream ({len(buf)} bytes, metadata sections "
            f"need {prefix_len})"
        )
    return spec, bs, n, nb, nnc, nmid, prefix_len


def _check_measured(meas, nnc: int, nmid: int, spec: DtypeSpec) -> None:
    """Raise the canonical ``container`` corrupt-stream errors from the
    data-dependent checks the device program measured (fetched alongside the
    decoded values in its single readback)."""
    if int(meas[0]) != nnc:
        raise ValueError("corrupt SZx stream (const bitmap / n_nonconst mismatch)")
    if int(meas[1]) > spec.itemsize:
        raise ValueError("corrupt SZx stream (reqlen exceeds dtype width)")
    if int(meas[2]) != nmid:
        raise ValueError("corrupt SZx stream (mid-stream length mismatch)")


def decode_stream(buf, *, backend: str = "auto", out=None, block_range=None):
    """Device-resident decompress of ONE v2 stream -> flat (n,) numpy array.

    The decode mirror of :func:`encode_to_stream`: the 40-byte header is
    unpacked on the host (pure struct math, no numpy section parsing), the
    raw body bytes cross the link as exactly ONE ``jax.device_put``, and the
    section offsets, metadata parse, and fused unpack+compose all run inside
    one jitted program (``ops.decode_staged``).  The decoded values return
    with the validation scalars in a single ``jax.device_get``.

    Returns None when the device route does not apply (numpy backend, empty
    stream, int32-unsafe capacity, or a body longer than the worst case) --
    callers then take the host path.  With ``out`` (flat (n,) array in the
    stream dtype) the result is written in place.  ``block_range=(lo, hi)``
    decodes only those blocks of the same device-put body (mid offsets stay
    absolute) and returns their clipped flat values.
    """
    from repro.kernels import ops

    backend = ops._resolve(backend)
    if backend == "numpy":
        return None
    spec, bs, n, nb, nnc, nmid, prefix_len = _checked_stream_header(buf)
    expected = prefix_len + nmid
    if len(buf) < expected:
        raise ValueError(
            f"truncated SZx stream ({len(buf)} bytes, expected {expected})"
        )
    cap = nb and (
        (nb + 7) // 8 + spec.itemsize * nb + nb + (nb * bs + 3) // 4
        + nb * bs * spec.itemsize
    )
    blen = expected - container.HEADER.size
    if nb == 0 or cap > _INT32_SAFE or blen > cap:
        return None
    lo, hi = (0, nb) if block_range is None else block_range
    if not 0 <= lo < hi <= nb:
        return None                      # host path raises the canonical error
    raw = np.frombuffer(buf, np.uint8, blen, container.HEADER.size)
    with ops._x64_scope(spec):
        dev_body = jax.device_put(_bucket_body(raw, cap))
        vals, meas = _decode_device_jit(
            dev_body, np.int32(nnc), np.int32(lo),
            spec=spec, backend=backend, nb=nb, bs=bs, rb=hi - lo, rebase=False,
        )
        vals, meas = jax.device_get((vals, meas))
    if obs.enabled():
        obs.counter("device.put.calls", op="decode_stream").inc()
        obs.counter("device.put.bytes", op="decode_stream").inc(
            int(dev_body.nbytes)
        )
        obs.counter("device.get.calls", op="decode_stream").inc()
        obs.counter("device.get.bytes", op="decode_stream").inc(
            int(vals.nbytes) + int(np.asarray(meas).nbytes)
        )
    _check_measured(meas, nnc, nmid, spec)
    flat = vals.reshape(-1)[: min(hi * bs, n) - lo * bs]
    if out is not None:
        np.copyto(out, flat)
        return out
    return flat


def decode_range(prefix: bytes, mid: bytes, lo: int, hi: int, *,
                 backend: str = "auto"):
    """Device decode of blocks [lo, hi) from a metadata prefix + exactly that
    range's mid bytes (the store ROI read layout) -> flat (hi-lo)*bs values.

    The combined ``prefix[40:] + mid`` buffer has the SAME section offsets as
    a full body (the mid section simply starts at block ``lo``'s first mid
    byte), so this shares the full-decode program with ``rebase=True``: the
    kernel re-derives block ``lo``'s absolute mid offset from the L-code
    cumsum and subtracts it.  One ``device_put``, one jitted program, one
    readback.  Returns None when the device route does not apply.
    """
    from repro.kernels import ops

    backend = ops._resolve(backend)
    if backend == "numpy":
        return None
    spec, bs, n, nb, nnc, nmid, prefix_len = _checked_stream_header(prefix)
    cap = nb and (
        (nb + 7) // 8 + spec.itemsize * nb + nb + (nb * bs + 3) // 4
        + nb * bs * spec.itemsize
    )
    if nb == 0 or cap > _INT32_SAFE or not 0 <= lo < hi <= nb:
        return None
    raw = np.concatenate([
        np.frombuffer(prefix, np.uint8, prefix_len - container.HEADER.size,
                      container.HEADER.size),
        np.frombuffer(mid, np.uint8),
    ])
    if raw.size > cap:
        return None
    with ops._x64_scope(spec):
        dev_body = jax.device_put(_bucket_body(raw, cap))
        vals, meas = _decode_device_jit(
            dev_body, np.int32(nnc), np.int32(lo),
            spec=spec, backend=backend, nb=nb, bs=bs, rb=hi - lo, rebase=True,
        )
        vals, meas = jax.device_get((vals, meas))
    if obs.enabled():
        obs.counter("device.put.calls", op="decode_range").inc()
        obs.counter("device.put.bytes", op="decode_range").inc(
            int(dev_body.nbytes)
        )
        obs.counter("device.get.calls", op="decode_range").inc()
        obs.counter("device.get.bytes", op="decode_range").inc(
            int(vals.nbytes) + int(np.asarray(meas).nbytes)
        )
    _check_measured(meas, nnc, nmid, spec)
    return vals.reshape(-1)
