"""Plan layer: dtype resolution, error-bound resolution, blocking/padding.

This is the first stage of the codec pipeline (paper Algorithm 1, lines 1-2):
everything that must be decided *before* any per-block math runs.  A
:class:`Plan` is a tiny immutable record that the transform and container
layers consume; it is also what makes multi-dtype support principled -- the
IEEE-754 exponent/mantissa geometry is carried explicitly instead of silently
upcasting every input to float32.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# The dtype geometry lives in the kernel layer (repro.kernels.specs) so the
# width-generic kernels need nothing from repro.core; the plan re-exports it
# because the spec doubles as the stream's dtype-code table.
from repro.kernels.specs import (  # noqa: F401  (re-exports)
    BY_CODE,
    BY_DTYPE,
    SPECS as _SPECS,
    DtypeSpec,
    exact_exponent_of,
    spec_for,
    spec_for_code,
)

DEFAULT_BLOCK_SIZE = 128  # paper Fig. 8: best compression-ratio/PSNR tradeoff


def finfo(dtype):
    """np.finfo that also understands ml_dtypes extension floats (bf16)."""
    try:
        return np.finfo(dtype)
    except ValueError:
        import ml_dtypes

        return ml_dtypes.finfo(dtype)


@dataclass(frozen=True)
class Plan:
    """Resolved compression parameters for one array (or one chunk of it)."""

    dtype: DtypeSpec
    n: int                 # logical element count
    block_size: int
    nblocks: int
    error_bound: float     # resolved ABSOLUTE bound (rel already applied)
    backend: str           # kernels.ops backend (width-generic, all dtypes)

    @property
    def raw_bytes(self) -> int:
        return self.n * self.dtype.itemsize


def resolve_error_bound(x: np.ndarray, error_bound: float, mode: str, spec: DtypeSpec) -> float:
    """Resolve the user bound to an absolute e > 0 (paper REL semantics)."""
    if mode == "rel":
        rng = float(x.max() - x.min()) if x.size else 0.0
        e = float(error_bound) * rng
        if e == 0.0:
            e = float(finfo(spec.np_dtype).tiny)
    elif mode == "abs":
        e = float(error_bound)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    if e <= 0:
        raise ValueError("error bound must be positive")
    return e


def make_plan(
    x,
    error_bound: float,
    *,
    mode: str = "abs",
    block_size: int = DEFAULT_BLOCK_SIZE,
    backend: str = "auto",
    dtype=None,
) -> tuple[Plan, np.ndarray]:
    """Build the plan for ``x`` and return ``(plan, x_as_plan_dtype)``.

    ``dtype`` forces the codec dtype (the input is cast); by default the
    input's own dtype is kept -- no silent upcast.
    """
    x = np.asarray(x)
    if dtype is not None:
        x = x.astype(np.dtype(dtype), copy=False)
    if not np.issubdtype(np.asarray(x).dtype, np.floating) and np.asarray(x).dtype not in BY_DTYPE:
        raise TypeError(f"SZx compresses float arrays, got {x.dtype}")
    spec = spec_for(x.dtype)
    if not 1 <= block_size <= 0xFFFF:
        raise ValueError(f"block_size {block_size} out of range [1, 65535]")
    e = resolve_error_bound(x, error_bound, mode, spec)
    n = int(x.size)
    nblocks = max((n + block_size - 1) // block_size, 0)
    return Plan(spec, n, block_size, nblocks, e, backend), x


def plan_for_stream(dtype_code: int, block_size: int, n: int, e: float, backend: str) -> Plan:
    """Reconstruct the plan of an existing stream (decode side)."""
    spec = spec_for_code(dtype_code)
    nblocks = max((n + block_size - 1) // block_size, 0)
    return Plan(spec, int(n), int(block_size), nblocks, float(e), backend)


def to_blocks(x: np.ndarray, plan: Plan) -> np.ndarray:
    """Flatten and pad (edge-replicate) to (nblocks, block_size)."""
    flat = np.asarray(x, plan.dtype.np_dtype).reshape(-1)
    pad = (-flat.size) % plan.block_size
    if pad:
        flat = np.concatenate([flat, np.full(pad, flat[-1], plan.dtype.np_dtype)])
    return flat.reshape(-1, plan.block_size)


def float_exponent_of(e: float) -> int:
    """Exact floor(log2 e) of a positive python float (Formula 4's p(e))."""
    return exact_exponent_of(e)


def chunk_elements(plan_block_size: int, chunk_bytes: int, itemsize: int) -> int:
    """Largest chunk element count <= chunk_bytes, aligned to block_size."""
    elems = max(chunk_bytes // itemsize, plan_block_size)
    return max(elems // plan_block_size, 1) * plan_block_size
