"""Plan layer: dtype resolution, error-bound resolution, blocking/padding.

This is the first stage of the codec pipeline (paper Algorithm 1, lines 1-2):
everything that must be decided *before* any per-block math runs.  A
:class:`Plan` is a tiny immutable record that the transform and container
layers consume; it is also what makes multi-dtype support principled -- the
IEEE-754 exponent/mantissa geometry is carried explicitly instead of silently
upcasting every input to float32.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

# The dtype geometry lives in the kernel layer (repro.kernels.specs) so the
# width-generic kernels need nothing from repro.core; the plan re-exports it
# because the spec doubles as the stream's dtype-code table.
from repro.kernels.specs import (  # noqa: F401  (re-exports)
    BY_CODE,
    BY_DTYPE,
    SPECS as _SPECS,
    DtypeSpec,
    exact_exponent_of,
    spec_for,
    spec_for_code,
)

DEFAULT_BLOCK_SIZE = 128  # paper Fig. 8: best compression-ratio/PSNR tradeoff


@dataclass(frozen=True)
class Bound:
    """The unified error-bound spec accepted everywhere a bound is taken.

    ``Bound.abs(1e-3)`` is an absolute bound ``e``; ``Bound.rel(1e-4)`` is a
    value-range-relative factor (``e = value * (max - min)``, the paper's REL
    semantics, resolved over the full array being compressed).  Every API
    that takes a bound also accepts a bare float, which means ``Bound.abs``.
    This replaces the scattered ``(error_bound, mode=)`` kwarg pairs; the
    old kwargs keep working through deprecation shims (:func:`as_bound`).
    """

    value: float
    mode: str = "abs"

    def __post_init__(self):
        if self.mode not in ("abs", "rel"):
            raise ValueError(f"unknown bound mode {self.mode!r} (abs/rel)")
        if not float(self.value) > 0:
            raise ValueError("error bound must be positive")
        object.__setattr__(self, "value", float(self.value))

    @classmethod
    def abs(cls, value: float) -> "Bound":  # noqa: A003 - reads as Bound.abs
        """Absolute bound: ``|x - x'| <= value`` element-wise."""
        return cls(value, "abs")

    @classmethod
    def rel(cls, value: float) -> "Bound":
        """Value-range-relative bound: ``e = value * (max(x) - min(x))``."""
        return cls(value, "rel")

    @classmethod
    def parse(cls, text: str) -> "Bound":
        """CLI spelling: ``'1e-3'`` (abs), ``'abs:1e-3'``, or ``'rel:1e-4'``."""
        text = text.strip()
        if ":" in text:
            mode, _, value = text.partition(":")
            return cls(float(value), mode.strip())
        return cls(float(text), "abs")

    def __str__(self) -> str:
        return f"{self.mode}:{self.value:g}"


def as_bound(bound=None, mode: str | None = None, *, error_bound=None,
             owner: str = "", stacklevel: int = 3) -> Bound:
    """Normalize the unified bound argument (the ONE deprecation shim).

    ``bound`` is a :class:`Bound` or a bare positive number (meaning
    ``Bound.abs``).  ``mode`` and ``error_bound`` are the legacy kwargs:
    passing either emits a ``DeprecationWarning`` and resolves them the old
    way (``Bound(error_bound, mode or 'abs')``).
    """
    if error_bound is not None:
        if bound is not None:
            raise TypeError(
                f"{owner or 'bound'}: pass bound OR the legacy error_bound=, "
                "not both"
            )
        warnings.warn(
            f"{owner or 'this API'}: the (error_bound, mode=) kwargs are "
            "deprecated; pass repro.api.Bound.abs(e) / Bound.rel(r) instead",
            DeprecationWarning,
            stacklevel=stacklevel,
        )
        return Bound(float(error_bound), mode or "abs")
    if isinstance(bound, Bound):
        if mode is not None:
            raise TypeError(
                f"{owner or 'bound'}: pass the mode inside Bound "
                "(Bound.abs/Bound.rel), not as a mode= kwarg"
            )
        return bound
    if bound is None:
        raise TypeError(f"{owner or 'bound'}: an error bound is required")
    if mode is not None:
        warnings.warn(
            f"{owner or 'this API'}: the (error_bound, mode=) kwargs are "
            "deprecated; pass repro.api.Bound.abs(e) / Bound.rel(r) instead",
            DeprecationWarning,
            stacklevel=stacklevel,
        )
        return Bound(float(bound), mode)
    return Bound(float(bound), "abs")


def finfo(dtype):
    """np.finfo that also understands ml_dtypes extension floats (bf16)."""
    try:
        return np.finfo(dtype)
    except ValueError:
        import ml_dtypes

        return ml_dtypes.finfo(dtype)


@dataclass(frozen=True)
class Plan:
    """Resolved compression parameters for one array (or one chunk of it)."""

    dtype: DtypeSpec
    n: int                 # logical element count
    block_size: int
    nblocks: int
    error_bound: float     # resolved ABSOLUTE bound (rel already applied)
    backend: str           # kernels.ops backend (width-generic, all dtypes)

    @property
    def raw_bytes(self) -> int:
        return self.n * self.dtype.itemsize


def resolve_error_bound(x: np.ndarray, bound, mode: str = "abs",
                        spec: DtypeSpec | None = None) -> float:
    """Resolve a bound to an absolute e > 0 (paper REL semantics).

    The ONE place bound resolution happens.  ``bound`` is a :class:`Bound`
    (``mode`` is then ignored) or a bare number interpreted under ``mode``
    -- the legacy calling convention, kept so stream headers and existing
    call sites resolve identically.
    """
    if not isinstance(bound, Bound):
        if mode == "rel":
            bound = Bound(float(bound), "rel")
        elif mode == "abs":
            bound = Bound(float(bound), "abs")
        else:
            raise ValueError(f"unknown mode {mode!r}")
    if bound.mode == "rel":
        rng = float(x.max() - x.min()) if x.size else 0.0
        e = bound.value * rng
        if e == 0.0:
            e = float(finfo((spec or spec_for(np.asarray(x).dtype)).np_dtype).tiny)
    else:
        e = bound.value
    if e <= 0:
        raise ValueError("error bound must be positive")
    return e


def make_plan(
    x,
    bound,
    *,
    mode: str = "abs",
    block_size: int = DEFAULT_BLOCK_SIZE,
    backend: str = "auto",
    dtype=None,
) -> tuple[Plan, np.ndarray]:
    """Build the plan for ``x`` and return ``(plan, x_as_plan_dtype)``.

    ``bound`` is a :class:`Bound` or a bare number interpreted under
    ``mode``.  ``dtype`` forces the codec dtype (the input is cast); by
    default the input's own dtype is kept -- no silent upcast.
    """
    x = np.asarray(x)
    if dtype is not None:
        x = x.astype(np.dtype(dtype), copy=False)
    if not np.issubdtype(np.asarray(x).dtype, np.floating) and np.asarray(x).dtype not in BY_DTYPE:
        raise TypeError(f"SZx compresses float arrays, got {x.dtype}")
    spec = spec_for(x.dtype)
    if not 1 <= block_size <= 0xFFFF:
        raise ValueError(f"block_size {block_size} out of range [1, 65535]")
    e = resolve_error_bound(x, bound, mode, spec)
    n = int(x.size)
    nblocks = max((n + block_size - 1) // block_size, 0)
    return Plan(spec, n, block_size, nblocks, e, backend), x


def plan_for_stream(dtype_code: int, block_size: int, n: int, e: float, backend: str) -> Plan:
    """Reconstruct the plan of an existing stream (decode side)."""
    spec = spec_for_code(dtype_code)
    nblocks = max((n + block_size - 1) // block_size, 0)
    return Plan(spec, int(n), int(block_size), nblocks, float(e), backend)


def to_blocks(x: np.ndarray, plan: Plan) -> np.ndarray:
    """Flatten and pad (edge-replicate) to (nblocks, block_size)."""
    flat = np.asarray(x, plan.dtype.np_dtype).reshape(-1)
    pad = (-flat.size) % plan.block_size
    if pad:
        flat = np.concatenate([flat, np.full(pad, flat[-1], plan.dtype.np_dtype)])
    return flat.reshape(-1, plan.block_size)


def float_exponent_of(e: float) -> int:
    """Exact floor(log2 e) of a positive python float (Formula 4's p(e))."""
    return exact_exponent_of(e)


def chunk_elements(plan_block_size: int, chunk_bytes: int, itemsize: int) -> int:
    """Largest chunk element count <= chunk_bytes, aligned to block_size."""
    elems = max(chunk_bytes // itemsize, plan_block_size)
    return max(elems // plan_block_size, 1) * plan_block_size
