"""Negotiated lossless second stage over the mid-byte section (container v3).

SZx trades ratio for speed: after the error-bounded quantization the mid-byte
stream still carries 10-40% redundancy (FZ-GPU / cuSZ recover it with
bitshuffle + sparsification / Huffman).  This module implements that ratio
tier as a *per-frame negotiated* stage recorded in the frame-flag stage bits
(``container.FLAG_STAGE_MASK``): only the mid-byte section is transformed --
the header, const bitmap, mu, reqlen and L sections stay raw so the
header-only query tier and ROI block arithmetic keep working on untouched
bytes.

Layout of a staged frame payload::

    [v2 metadata prefix]                      -- byte-identical to stage-off
    [stage table '<HI': seg_blocks | nseg]
    [u32 * nseg: byte length of each segment record]
    [record 0] ... [record nseg-1]            -- mode u8 (0 raw | 1 staged)
                                                 + segment body

Segments are fixed block ranges (``seg_blocks`` blocks), so ROI readers map a
block range to a segment range, read ONLY those records (offsets from the
cumulative length table) and destage them -- bytes read stay proportional to
the ROI (:func:`read_mid_range`).  Negotiation is two-level: a segment whose
staged body is not smaller stays raw (mode 0), and a frame whose staged
payload is not smaller than the raw payload stays stage-off entirely
(:func:`stage_payload` returns ``None``), so a stage can never lose.

Stage codecs:

  1 ``bitshuffle-rle``   byteplane-major shuffle (within a segment, the k-th
                         stored byte planes are grouped together; the
                         permutation is derived from the raw metadata prefix,
                         so it costs no side data) -> bit transpose (the
                         Pallas kernel in ``repro.kernels.bitshuffle``) ->
                         (value, run) byte-pair RLE.  Wins when shift pad
                         bits / rarely-set top magnitude bits dominate.
  2 ``bitshuffle-zstd``  same bit-transposed planes through ``zstandard``
                         (optional dependency; readers without it fail
                         loudly, writers refuse).
  3 ``deflate``          segment bytes in their natural (block, value,
                         byteplane) order through stdlib DEFLATE -- always
                         available, the best ratio/speed point on the bench
                         corpus (see benchmarks ``second_stage_frontier``).
                         Natural order is deliberate: the byteplane shuffle
                         buys deflate only ~4% more CR but costs more time
                         than deflate itself, which would blow the <30%
                         throughput budget of the frontier claim.

Readers that meet a stage code they do not know (or whose dependency is
missing) raise ``ValueError: stream requires second stage ...`` -- never a
CRC/garbage error.  Stage-off streams are byte-identical to pre-stage
container v3 (golden-pinned).
"""
from __future__ import annotations

import os
import struct
import zlib

import numpy as np

from repro import obs
from repro.core.codec import container
from repro.kernels.bitshuffle import tile_bytes

NONE = 0
BITSHUFFLE_RLE = 1
BITSHUFFLE_ZSTD = 2
DEFLATE = 3

_NAMES = {
    NONE: "none",
    BITSHUFFLE_RLE: "bitshuffle-rle",
    BITSHUFFLE_ZSTD: "bitshuffle-zstd",
    DEFLATE: "deflate",
}
_CODES = {v: k for k, v in _NAMES.items()}

DEFAULT_SEG_BLOCKS = 256       # blocks per ROI-addressable shuffle segment
DEFLATE_LEVEL = 2
ZSTD_LEVEL = 3
_TABLE = struct.Struct("<HI")  # seg_blocks u16 | nseg u32


def _zstd():
    """The zstandard module, or None (absent, or disabled for CI matrix runs
    via ``SZX_STAGE_DISABLE_ZSTD=1``)."""
    if os.environ.get("SZX_STAGE_DISABLE_ZSTD"):
        return None
    try:
        import zstandard
    except ImportError:
        return None
    return zstandard


def name_of(code: int) -> str:
    return _NAMES.get(code, f"#{code}")


def resolve(stage) -> int:
    """Normalize a user-facing stage spec (None/name/code) to a stage code.

    Raises on unknown names/codes and on known stages whose dependency is
    missing -- a writer must not emit frames it could not read back.
    """
    if stage is None or stage == NONE or stage == "none":
        return NONE
    if isinstance(stage, str):
        if stage not in _CODES:
            raise ValueError(
                f"unknown second stage {stage!r}; expected one of "
                f"{sorted(_CODES)}"
            )
        code = _CODES[stage]
    elif isinstance(stage, int) and not isinstance(stage, bool):
        if stage not in _NAMES:
            raise ValueError(
                f"unknown second stage code {stage}; expected one of "
                f"{sorted(_NAMES)}"
            )
        code = stage
    else:
        raise TypeError(f"stage must be a name, code, or None; got {stage!r}")
    if code == BITSHUFFLE_ZSTD and _zstd() is None:
        raise ValueError(
            "second stage 'bitshuffle-zstd' needs the zstandard package "
            "(not installed); use stage='deflate' or 'bitshuffle-rle'"
        )
    return code


def require_readable(code: int) -> None:
    """Fail loudly when this reader cannot destage ``code``."""
    if code == NONE:
        return
    if code not in _NAMES:
        raise ValueError(
            f"stream requires second stage #{code}, which this reader does "
            "not implement (newer writer?)"
        )
    if code == BITSHUFFLE_ZSTD and _zstd() is None:
        raise ValueError(
            "stream requires second stage 'bitshuffle-zstd' but the "
            "zstandard package is not installed"
        )


# ---------------------------------------------------------------------------
# byteplane-major shuffle permutation
# ---------------------------------------------------------------------------

def _plane_perm(sec, lo_b: int, hi_b: int) -> np.ndarray | None:
    """Permutation grouping blocks [lo_b, hi_b)'s mid bytes by byteplane.

    ``mid_planar = mid[perm]``.  The j-th stored byte of a value with lead
    count L sits in plane ``L + j``; grouping planes together puts the
    low-entropy leading planes (sign + rarely-set top magnitude bits) and the
    Solution-C shift pad bits next to each other, which is what the stage
    codecs feed on.  Derived entirely from the raw metadata prefix --
    identical on the stage and destage sides, no side data.
    """
    L = sec.L[lo_b:hi_b]
    nbytes = sec.nbytes[lo_b:hi_b]
    counts = np.maximum(nbytes[:, None].astype(np.int64) - L, 0).reshape(-1)
    tot = int(counts.sum())
    if tot == 0:
        return None
    starts = np.cumsum(counts) - counts
    Lf = L.reshape(-1)
    perm = np.empty(tot, np.int64)
    pos = 0
    # value v stores planes [L, nbytes) at mid positions starts[v] + (k - L):
    # one O(nvalues) pass per plane (positions ascend with v, so the order
    # matches a stable counting sort over the per-byte plane labels)
    for k in range(int(sec.plan.dtype.itemsize)):
        m = (Lf <= k) & (counts > k - Lf)
        idx = starts[m] + (k - Lf[m])
        perm[pos : pos + idx.size] = idx
        pos += idx.size
    return perm


# ---------------------------------------------------------------------------
# inner codecs
# ---------------------------------------------------------------------------

def _rle_encode(b: np.ndarray) -> bytes:
    """(value, run-length) byte pairs; runs longer than 255 split."""
    if b.size == 0:
        return b""
    change = np.flatnonzero(b[1:] != b[:-1])
    starts = np.concatenate(([0], change + 1))
    lens = np.diff(np.concatenate((starts, [b.size])))
    vals = b[starts]
    rep = (lens + 254) // 255
    vals = np.repeat(vals, rep)
    out_lens = np.full(vals.size, 255, np.uint8)
    out_lens[np.cumsum(rep) - 1] = (lens - (rep - 1) * 255).astype(np.uint8)
    out = np.empty(vals.size * 2, np.uint8)
    out[0::2] = vals
    out[1::2] = out_lens
    return out.tobytes()


def _rle_decode(body: bytes, expect: int) -> np.ndarray:
    pairs = np.frombuffer(body, np.uint8)
    if pairs.size % 2:
        raise ValueError("corrupt second-stage payload (odd RLE pair bytes)")
    vals = pairs[0::2]
    lens = pairs[1::2].astype(np.int64)
    if vals.size and int(lens.min(initial=1)) == 0:
        raise ValueError("corrupt second-stage payload (zero-length RLE run)")
    out = np.repeat(vals, lens)
    if out.size != expect:
        raise ValueError(
            f"corrupt second-stage payload (RLE expands to {out.size} bytes, "
            f"segment holds {expect})"
        )
    return out


def _to_tiles(pm: np.ndarray, T: int) -> np.ndarray:
    pad = (-pm.size) % T
    if pad:
        pm = np.concatenate([pm, np.zeros(pad, np.uint8)])
    return pm.reshape(-1, T)


def _seg_encode(code: int, seg: np.ndarray, perm, spec, backend: str) -> bytes:
    pm = seg[perm] if perm is not None else seg
    if code == DEFLATE:
        return zlib.compress(pm.tobytes(), DEFLATE_LEVEL)
    from repro.kernels import ops

    T = tile_bytes(spec)
    sh = np.asarray(
        ops.bitshuffle(_to_tiles(pm, T), spec=spec, backend=backend)
    ).reshape(-1)
    if code == BITSHUFFLE_RLE:
        return _rle_encode(sh)
    if code == BITSHUFFLE_ZSTD:
        return _zstd().ZstdCompressor(level=ZSTD_LEVEL).compress(sh.tobytes())
    raise ValueError(f"unknown second stage code {code}")


def _seg_decode(code: int, body: bytes, raw_len: int, perm, spec,
                backend: str) -> np.ndarray:
    if code == DEFLATE:
        try:
            pm_b = zlib.decompress(body)
        except zlib.error as err:
            raise ValueError(
                f"corrupt second-stage payload (deflate: {err})"
            ) from err
        if len(pm_b) != raw_len:
            raise ValueError(
                f"corrupt second-stage payload (deflate yields {len(pm_b)} "
                f"bytes, segment holds {raw_len})"
            )
        pm = np.frombuffer(pm_b, np.uint8)
    else:
        from repro.kernels import ops

        T = tile_bytes(spec)
        padded = -(-raw_len // T) * T
        if code == BITSHUFFLE_RLE:
            sh = _rle_decode(body, padded)
        elif code == BITSHUFFLE_ZSTD:
            try:
                sh_b = _zstd().ZstdDecompressor().decompress(
                    body, max_output_size=padded
                )
            except Exception as err:
                raise ValueError(
                    f"corrupt second-stage payload (zstd: {err})"
                ) from err
            if len(sh_b) != padded:
                raise ValueError(
                    f"corrupt second-stage payload (zstd yields {len(sh_b)} "
                    f"bytes, segment holds {padded})"
                )
            sh = np.frombuffer(sh_b, np.uint8)
        else:
            raise ValueError(f"unknown second stage code {code}")
        pm = np.asarray(
            ops.bitshuffle(
                sh.reshape(-1, T), spec=spec, inverse=True, backend=backend
            )
        ).reshape(-1)[:raw_len]
    if perm is None:
        return np.asarray(pm)
    out = np.empty(raw_len, np.uint8)
    out[perm] = pm
    return out


# ---------------------------------------------------------------------------
# frame payload stage / destage
# ---------------------------------------------------------------------------

def _seg_ranges(nb: int, seg_blocks: int):
    for lo in range(0, nb, seg_blocks):
        yield lo, min(lo + seg_blocks, nb)


def _perm_for(code: int, sec, lo_b: int, hi_b: int) -> np.ndarray | None:
    # DEFLATE runs on the natural mid order: the shuffle costs more time
    # than deflate itself for ~4% extra CR (see the module docstring)
    if code == DEFLATE:
        return None
    return _plane_perm(sec, lo_b, hi_b)


def stage_payload(payload, code: int, *, seg_blocks: int = DEFAULT_SEG_BLOCKS,
                  backend: str = "numpy") -> bytes | None:
    """Apply stage ``code`` to one v2 payload; None when it would not shrink.

    The metadata prefix is copied verbatim; the mid section becomes the stage
    table + per-segment records.  ``None`` (negotiation declined: empty mid,
    or staged >= raw) means the caller must write the frame stage-off.
    """
    if code == NONE:
        return None
    track = obs.enabled()
    if track:
        obs.counter("codec.stage.try", stage=name_of(code)).inc()
    if not 0 < seg_blocks <= 0xFFFF:
        raise ValueError(f"seg_blocks {seg_blocks} out of range [1, 65535]")
    buf = bytes(payload) if not isinstance(payload, (bytes, bytearray)) else payload
    prefix_len = container.stream_prefix_length(buf)
    sec = container.parse_stream_sections(buf[:prefix_len], backend="numpy")
    nb = sec.plan.nblocks
    if sec.nmid == 0 or nb == 0:
        if track:
            obs.counter("codec.stage.fallback", stage=name_of(code)).inc()
        return None
    mid = np.frombuffer(buf, np.uint8, sec.nmid, prefix_len)
    spec = sec.plan.dtype
    records = []
    seg_staged = seg_raw = 0
    for lo, hi in _seg_ranges(nb, seg_blocks):
        mlo, mhi = sec.mid_range(lo, hi)
        seg = mid[mlo:mhi]
        body = _seg_encode(code, seg, _perm_for(code, sec, lo, hi), spec, backend)
        if len(body) < seg.size:
            records.append(b"\x01" + body)
            seg_staged += 1
        else:
            records.append(b"\x00" + seg.tobytes())
            seg_raw += 1
    nseg = len(records)
    table = _TABLE.pack(seg_blocks, nseg) + np.asarray(
        [len(r) for r in records], dtype="<u4"
    ).tobytes()
    staged_len = prefix_len + len(table) + sum(len(r) for r in records)
    if staged_len >= len(buf):
        if track:
            obs.counter("codec.stage.fallback", stage=name_of(code)).inc()
        return None
    if track:
        name = name_of(code)
        obs.counter("codec.stage.win", stage=name).inc()
        obs.counter("codec.stage.segments_staged", stage=name).inc(seg_staged)
        obs.counter("codec.stage.segments_raw", stage=name).inc(seg_raw)
        obs.counter("codec.stage.mid_bytes_in", stage=name).inc(int(sec.nmid))
        obs.counter("codec.stage.mid_bytes_out", stage=name).inc(
            staged_len - prefix_len
        )
    return b"".join([buf[:prefix_len], table, *records])


def _parse_table(buf, prefix_len: int, nb: int, seg_blocks_hint=None):
    """(seg_blocks, record_lengths, records_offset) of a staged payload."""
    if len(buf) < prefix_len + _TABLE.size:
        raise ValueError("corrupt second-stage payload (truncated stage table)")
    seg_blocks, nseg = _TABLE.unpack_from(buf, prefix_len)
    if seg_blocks == 0:
        raise ValueError("corrupt second-stage payload (seg_blocks == 0)")
    if nseg != -(-nb // seg_blocks):
        raise ValueError(
            f"corrupt second-stage payload (stage table has {nseg} segments, "
            f"{nb} blocks at {seg_blocks}/segment need {-(-nb // seg_blocks)})"
        )
    off = prefix_len + _TABLE.size
    if len(buf) < off + 4 * nseg:
        raise ValueError("corrupt second-stage payload (truncated stage table)")
    lens = np.frombuffer(buf, "<u4", nseg, off).astype(np.int64)
    return seg_blocks, lens, off + 4 * nseg


def destage_payload(payload, code: int, *, backend: str = "numpy") -> bytes:
    """Invert :func:`stage_payload`: staged payload -> raw v2 stream bytes."""
    require_readable(code)
    buf = bytes(payload) if not isinstance(payload, (bytes, bytearray)) else payload
    prefix_len = container.stream_prefix_length(buf)
    sec = container.parse_stream_sections(buf[:prefix_len], backend="numpy")
    nb = sec.plan.nblocks
    seg_blocks, lens, off = _parse_table(buf, prefix_len, nb)
    if off + int(lens.sum()) != len(buf):
        raise ValueError(
            "corrupt second-stage payload (segment records do not span the "
            "frame payload)"
        )
    spec = sec.plan.dtype
    out = bytearray(prefix_len + sec.nmid)
    out[:prefix_len] = buf[:prefix_len]
    for (lo, hi), ln in zip(_seg_ranges(nb, seg_blocks), lens):
        record = buf[off : off + int(ln)]
        off += int(ln)
        mlo, mhi = sec.mid_range(lo, hi)
        out[prefix_len + mlo : prefix_len + mhi] = _destage_record(
            record, code, mhi - mlo, sec, lo, hi, spec, backend
        )
    return bytes(out)


def _destage_record(record: bytes, code: int, raw_len: int, sec, lo: int,
                    hi: int, spec, backend: str) -> bytes:
    if len(record) < 1:
        raise ValueError("corrupt second-stage payload (empty segment record)")
    mode = record[0]
    body = record[1:]
    if mode == 0:
        if len(body) != raw_len:
            raise ValueError(
                f"corrupt second-stage payload (raw segment has {len(body)} "
                f"bytes, expected {raw_len})"
            )
        return body
    if mode != 1:
        raise ValueError(
            f"corrupt second-stage payload (unknown segment mode {mode})"
        )
    return _seg_decode(
        code, body, raw_len, _perm_for(code, sec, lo, hi), spec, backend
    ).tobytes()


# ---------------------------------------------------------------------------
# ROI partial reads over staged frames
# ---------------------------------------------------------------------------

def read_mid_range(f, table_offset: int, sec, code: int, lo_b: int,
                   hi_b: int, *, backend: str = "numpy") -> bytes:
    """Read + destage EXACTLY blocks [lo_b, hi_b)'s mid bytes from a staged
    frame in an open seekable stream.

    ``table_offset`` is the file offset of the stage table (frame payload
    start + metadata prefix length); ``sec`` the frame's parsed sections.
    Reads the stage table plus only the segment records overlapping the block
    range (one contiguous read), so bytes read scale with the ROI, exactly
    like the stage-off two-phase read.  Returns ``sec.mid_range(lo_b, hi_b)``
    bytes.
    """
    require_readable(code)
    nb = sec.plan.nblocks
    f.seek(table_offset)
    head = container._read_exact(f, _TABLE.size)
    seg_blocks, nseg = _TABLE.unpack_from(head, 0)
    if seg_blocks == 0:
        raise ValueError("corrupt second-stage payload (seg_blocks == 0)")
    if nseg != -(-nb // seg_blocks):
        raise ValueError(
            f"corrupt second-stage payload (stage table has {nseg} segments, "
            f"{nb} blocks at {seg_blocks}/segment need {-(-nb // seg_blocks)})"
        )
    lens = np.frombuffer(
        container._read_exact(f, 4 * nseg), "<u4"
    ).astype(np.int64)
    if not 0 <= lo_b < hi_b <= nb:
        raise ValueError(f"block range [{lo_b}, {hi_b}) out of [0, {nb})")
    s_lo = lo_b // seg_blocks
    s_hi = -(-hi_b // seg_blocks)
    rec_base = table_offset + _TABLE.size + 4 * nseg
    starts = np.concatenate(([0], np.cumsum(lens)))
    f.seek(rec_base + int(starts[s_lo]))
    blob = container._read_exact(f, int(starts[s_hi] - starts[s_lo]))
    if obs.enabled():
        obs.counter("codec.stage.roi_bytes_read", stage=name_of(code)).inc(
            _TABLE.size + 4 * nseg + len(blob)
        )
    spec = sec.plan.dtype
    parts = []
    pos = 0
    for s in range(s_lo, s_hi):
        ln = int(lens[s])
        record = blob[pos : pos + ln]
        pos += ln
        lo, hi = s * seg_blocks, min((s + 1) * seg_blocks, nb)
        mlo, mhi = sec.mid_range(lo, hi)
        parts.append(
            _destage_record(record, code, mhi - mlo, sec, lo, hi, spec, backend)
        )
    seg_mid = b"".join(parts)
    base = sec.mid_range(s_lo * seg_blocks, min(s_hi * seg_blocks, nb))[0]
    mlo, mhi = sec.mid_range(lo_b, hi_b)
    return seg_mid[mlo - base : mhi - base]
