"""Fixed-shape front-end: PlanesCodec (szx-planes, in-graph).

The static-shape TPU variant of SZx used *inside* jit/GSPMD programs
(gradient compression, KV-cache compression) where XLA cannot represent
data-dependent output sizes.  It keeps the paper's structure -- block mu,
radius-exponent-derived bit budget, byte-aligned planes -- and trades the
per-value XOR leading-byte elision for a static plane count P in {1,2,3}.

All block math dispatches through ``repro.kernels.ops`` so in-graph callers
(under jit / shard_map / scan) and host callers share one implementation.
The 'jax' backend stages the oracle straight into the caller's program (one
fused program under jit / shard_map); 'kernel' dispatches the real Pallas
kernels in ``repro.kernels.planes``.  Consumers
(``repro.core.grad_compress``, ``repro.serve.engine``) go through this class
instead of reaching into ``repro.kernels.ref`` directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class PlanesCodec:
    """Configured fixed-shape codec; instances are cheap, hashable, and safe
    to close over inside jit."""

    num_planes: int = 1
    backend: str = "jax"       # kernels.ops planes dispatch

    def __post_init__(self):
        if not 1 <= self.num_planes <= 3:
            raise ValueError("szx-planes supports 1..3 byte planes")

    # ----------------------------------------------------------- block level
    def encode_blocks(self, xb) -> tuple:
        """xb (..., bs) f32 -> (mu (...,), sexp (...,) int32, planes (P, ..., bs))."""
        from repro.kernels import ops

        return ops.planes_encode(xb, self.num_planes, backend=self.backend)

    def decode_blocks(self, mu, sexp, planes):
        """Inverse of :meth:`encode_blocks` -> (..., bs) f32."""
        from repro.kernels import ops

        return ops.planes_decode(mu, sexp, planes, backend=self.backend)

    # -------------------------------------------------- DeviceEncoding views
    def encode_blocks_device(self, xb) -> "DeviceEncoding":
        """:meth:`encode_blocks` as the shared device-resident record
        (kind ``"szx-planes"``, arrays mu/sexp/planes)."""
        from repro.core.codec.device import DeviceEncoding

        mu, sexp, planes = self.encode_blocks(xb)
        return DeviceEncoding.make(
            "szx-planes",
            {"mu": mu, "sexp": sexp, "planes": planes},
            num_planes=self.num_planes,
        )

    def decode_encoding(self, enc: "DeviceEncoding"):
        """Inverse of :meth:`encode_blocks_device` (accepts any integer sexp
        storage dtype -- wire/cache casts are the caller's)."""
        self._check_kind(enc)
        return self.decode_blocks(
            enc["mu"], jnp.asarray(enc["sexp"], jnp.int32), enc["planes"]
        )

    def encode_last_axis_device(self, x, block: int) -> "DeviceEncoding":
        """:meth:`encode_last_axis` as a ``DeviceEncoding`` (the gradient
        all-gather payload: a pytree, so it flows through collectives)."""
        from repro.core.codec.device import DeviceEncoding

        return DeviceEncoding.make(
            "szx-planes",
            self.encode_last_axis(x, block),
            num_planes=self.num_planes,
            block=block,
        )

    def decode_last_axis_encoding(self, enc: "DeviceEncoding", shape, dtype):
        self._check_kind(enc)
        return self.decode_last_axis(
            dict(enc.arrays, sexp=jnp.asarray(enc["sexp"], jnp.int32)), shape, dtype
        )

    def _check_kind(self, enc) -> None:
        if enc.kind != "szx-planes":
            raise ValueError(f"PlanesCodec cannot decode encoding kind {enc.kind!r}")
        got = enc.info.get("num_planes", self.num_planes)
        if got != self.num_planes:
            raise ValueError(
                f"encoding has {got} planes, codec configured for {self.num_planes}"
            )

    # ------------------------------------------------------------ leaf level
    def encode_last_axis(self, x, block: int) -> dict[str, Any]:
        """Block along the LAST axis only, leading dims untouched.

        Keeping the leaf shape keeps every encode op local to its shard under
        GSPMD (flattening would all-gather the full-precision array first).
        Zero-pads the last axis to a whole number of blocks.
        """
        x = jnp.asarray(x, jnp.float32)
        if x.ndim == 0:
            x = x[None]
        pad = (-x.shape[-1]) % block
        if pad:
            x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        xb = x.reshape(x.shape[:-1] + (-1, block))
        mu, sexp, planes = self.encode_blocks(xb)
        return {"mu": mu, "sexp": sexp, "planes": planes}

    def decode_last_axis(self, enc: dict[str, Any], shape, dtype):
        """Inverse of :meth:`encode_last_axis`, trimming the pad."""
        xb = self.decode_blocks(enc["mu"], enc["sexp"], enc["planes"])
        last = shape[-1] if shape else 1
        out = xb.reshape(xb.shape[:-2] + (-1,))[..., :last]
        return out.reshape(shape).astype(dtype)

    # -------------------------------------------------------------- flat API
    def encode_flat(self, x, block_size: int) -> tuple:
        """Flatten + edge-pad to blocks; returns (mu, sexp, planes) with
        (nb,)-shaped stats -- the layout of ``repro.core.planes``."""
        n = x.size
        flat = jnp.ravel(x).astype(jnp.float32)
        pad = (-n) % block_size
        if pad:
            flat = jnp.pad(flat, (0, pad), mode="edge")
        xb = flat.reshape(-1, block_size)
        return self.encode_blocks(xb)

    # ------------------------------------------------------------ accounting
    def wire_bytes_per_value(self, block: int) -> float:
        """Bytes/value moved by a collective (vs 4.0 uncompressed fp32):
        P planes plus f32 mu + int16 sexp per block."""
        return self.num_planes + 6.0 / block
