"""Container layer: versioned stream serialization + self-delimiting frames.

Stage three of the pipeline: the only place that knows the byte layout.  The
v2 stream layout is pinned by a golden-bytes test (tests/test_codec.py) and
specified in docs/FORMAT.md:

  header  '<4sBBHQdIIQ': magic 'SZXJ' | version u8 | dtype u8 |
          block_size u16 | n u64 | e f64 | nblocks u32 | n_nonconst u32 |
          nmid u64
  const bitmap  ceil(nb/8) bytes (np.packbits order)
  mu            itemsize * nb bytes (input dtype, one per block)
  reqlen        u8 * n_nonconst
  L codes       2-bit * (n_nonconst * block_size), little-endian packed
  mid stream    nmid bytes in (block, value, byteplane) order

Chunked streams are sequences of frames, each framing one independent v2
stream:

  frame header '<4sBBIQ': magic 'SZXF' | version u8 | flags u8 (bit0 = last)
               | seq u32 | payload_len u64
"""
from __future__ import annotations

import struct
from typing import Iterator

import numpy as np

from repro.core.codec import plan as plan_mod
from repro.core.codec.plan import Plan
from repro.core.codec.transform import BlockEncoding, derive_layout

MAGIC = b"SZXJ"
VERSION = 2
HEADER = struct.Struct("<4sBBHQdIIQ")

FRAME_MAGIC = b"SZXF"
FRAME_VERSION = 1
FRAME_HEADER = struct.Struct("<4sBBIQ")
FLAG_LAST = 0x01


# ---------------------------------------------------------------------------
# 2-bit code packing
# ---------------------------------------------------------------------------

def pack_2bit(codes: np.ndarray) -> np.ndarray:
    """codes: (m,) uint8 in [0,3] -> ceil(m/4) bytes."""
    pad = (-codes.size) % 4
    if pad:
        codes = np.concatenate([codes, np.zeros(pad, np.uint8)])
    c = codes.reshape(-1, 4)
    return (c[:, 0] | (c[:, 1] << 2) | (c[:, 2] << 4) | (c[:, 3] << 6)).astype(np.uint8)


def unpack_2bit(raw: np.ndarray, m: int) -> np.ndarray:
    b = raw.astype(np.uint8)
    out = np.empty((b.size, 4), np.uint8)
    out[:, 0] = b & 3
    out[:, 1] = (b >> 2) & 3
    out[:, 2] = (b >> 4) & 3
    out[:, 3] = (b >> 6) & 3
    return out.reshape(-1)[:m]


# ---------------------------------------------------------------------------
# monolithic v2 stream
# ---------------------------------------------------------------------------

def build_stream(p: Plan, enc: BlockEncoding) -> bytes:
    """Serialize one plan + block encoding into a self-contained v2 stream."""
    nc = ~enc.const
    nnc = int(nc.sum())
    itemsize = p.dtype.itemsize
    # mid-byte mask in (block, value, byteplane) order so each value's bytes
    # are contiguous in the stream (paper Fig. 4 layout)
    planes_t = enc.planes.transpose(0, 2, 1)            # (nb, bs, W)
    j = np.arange(itemsize, dtype=np.int32)[None, None, :]
    mask = (enc.L[:, :, None] <= j) & (j < enc.nbytes[:, None, None])
    mask &= nc[:, None, None]
    mid_stream = planes_t[mask]                         # (nmid,) uint8
    out = [
        HEADER.pack(
            MAGIC, VERSION, p.dtype.code, p.block_size, p.n, p.error_bound,
            p.nblocks, nnc, int(mid_stream.size),
        ),
        np.packbits(enc.const.astype(np.uint8)).tobytes(),
        np.ascontiguousarray(enc.mu).tobytes(),
        enc.reqlen[nc].astype(np.uint8).tobytes(),
        pack_2bit(enc.L[nc].reshape(-1).astype(np.uint8)).tobytes(),
        mid_stream.tobytes(),
    ]
    return b"".join(out)


def parse_stream(buf: bytes, *, backend: str = "auto") -> tuple[Plan, BlockEncoding]:
    """Validate + deserialize a v2 stream into (plan, block encoding)."""
    if len(buf) < HEADER.size:
        raise ValueError("truncated SZx stream (shorter than header)")
    magic, version, dtype_code, bs, n, e, nb, nnc, nmid = HEADER.unpack_from(buf, 0)
    if magic != MAGIC:
        raise ValueError("bad SZx stream header (magic mismatch)")
    if version != VERSION:
        raise ValueError(f"unsupported SZx stream version {version}")
    spec = plan_mod.spec_for_code(dtype_code)           # raises on unknown code
    if nnc > nb:
        raise ValueError("corrupt SZx stream (n_nonconst > nblocks)")
    if bs == 0 or nb != (n + bs - 1) // bs:
        raise ValueError("corrupt SZx stream (block count mismatch)")
    p = plan_mod.plan_for_stream(dtype_code, bs, n, e, backend)

    nbm = (nb + 7) // 8
    nl = (nnc * bs + 3) // 4
    expected = HEADER.size + nbm + spec.itemsize * nb + nnc + nl + nmid
    if len(buf) < expected:
        raise ValueError(
            f"truncated SZx stream ({len(buf)} bytes, expected {expected})"
        )
    off = HEADER.size
    const = np.unpackbits(np.frombuffer(buf, np.uint8, nbm, off))[:nb].astype(bool)
    off += nbm
    mu = np.frombuffer(buf, spec.np_dtype, nb, off).copy()
    off += spec.itemsize * nb
    reqlen_nc = np.frombuffer(buf, np.uint8, nnc, off).astype(np.int32)
    off += nnc
    L_nc = unpack_2bit(np.frombuffer(buf, np.uint8, nl, off), nnc * bs)
    off += nl
    mid_stream = np.frombuffer(buf, np.uint8, nmid, off)

    nc = ~const
    if int(nc.sum()) != nnc:
        raise ValueError("corrupt SZx stream (const bitmap / n_nonconst mismatch)")
    reqlen = np.zeros(nb, np.int32)
    reqlen[nc] = reqlen_nc
    shift, nbytes = derive_layout(reqlen, const, spec)
    if nbytes.max(initial=0) > spec.itemsize:
        raise ValueError("corrupt SZx stream (reqlen exceeds dtype width)")
    L = np.zeros((nb, bs), np.int32)
    L[nc] = L_nc.reshape(nnc, bs)

    planes_t = np.zeros((nb, bs, spec.itemsize), np.uint8)
    j = np.arange(spec.itemsize, dtype=np.int32)[None, None, :]
    mask = (L[:, :, None] <= j) & (j < nbytes[:, None, None])
    mask &= nc[:, None, None]
    if int(mask.sum()) != nmid:
        raise ValueError("corrupt SZx stream (mid-stream length mismatch)")
    planes_t[mask] = mid_stream
    planes = planes_t.transpose(0, 2, 1)
    return p, BlockEncoding(mu, const, reqlen, shift, nbytes, planes, L)


# ---------------------------------------------------------------------------
# self-delimiting frames (chunked streaming)
# ---------------------------------------------------------------------------

def build_frame(payload: bytes, seq: int, last: bool) -> bytes:
    """Wrap one v2 stream as a self-delimiting frame."""
    flags = FLAG_LAST if last else 0
    return FRAME_HEADER.pack(FRAME_MAGIC, FRAME_VERSION, flags, seq, len(payload)) + payload


def _read_exact(f, size: int) -> bytes:
    data = f.read(size)
    if len(data) != size:
        raise ValueError(
            f"truncated SZx frame sequence (wanted {size} bytes, got {len(data)})"
        )
    return data


def iter_frames(source) -> Iterator[bytes]:
    """Yield frame payloads from bytes, a binary file object, or an iterable
    of frame byte strings.  Validates magic, version, sequence numbers, and
    that the sequence terminates with a LAST-flagged frame."""
    if isinstance(source, (bytes, bytearray, memoryview)):
        import io

        source = io.BytesIO(source)
    if hasattr(source, "read"):
        yield from _iter_frames_file(source)
        return
    # iterable of per-frame byte strings (e.g. straight from compress_chunked)
    seq_expected = 0
    saw_last = False
    for frame in source:
        if saw_last:
            raise ValueError("SZx frame after the LAST-flagged frame")
        payload, last = _parse_one_frame(frame, seq_expected)
        saw_last = last
        seq_expected += 1
        yield payload
    if not saw_last:
        raise ValueError("SZx frame sequence ended without a LAST frame")


def _parse_one_frame(frame: bytes, seq_expected: int) -> tuple[bytes, bool]:
    if len(frame) < FRAME_HEADER.size:
        raise ValueError("truncated SZx frame (shorter than frame header)")
    magic, version, flags, seq, plen = FRAME_HEADER.unpack_from(frame, 0)
    if magic != FRAME_MAGIC:
        raise ValueError("bad SZx frame (magic mismatch)")
    if version != FRAME_VERSION:
        raise ValueError(f"unsupported SZx frame version {version}")
    if seq != seq_expected:
        raise ValueError(f"SZx frame out of order (seq {seq}, expected {seq_expected})")
    if len(frame) != FRAME_HEADER.size + plen:
        raise ValueError("truncated SZx frame (payload length mismatch)")
    return frame[FRAME_HEADER.size:], bool(flags & FLAG_LAST)


def _iter_frames_file(f) -> Iterator[bytes]:
    seq_expected = 0
    while True:
        hdr = _read_exact(f, FRAME_HEADER.size)
        magic, version, flags, seq, plen = FRAME_HEADER.unpack(hdr)
        if magic != FRAME_MAGIC:
            raise ValueError("bad SZx frame (magic mismatch)")
        if version != FRAME_VERSION:
            raise ValueError(f"unsupported SZx frame version {version}")
        if seq != seq_expected:
            raise ValueError(
                f"SZx frame out of order (seq {seq}, expected {seq_expected})"
            )
        yield _read_exact(f, plen)
        seq_expected += 1
        if flags & FLAG_LAST:
            if f.read(1):
                raise ValueError("SZx frame after the LAST-flagged frame")
            return
