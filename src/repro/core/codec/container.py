"""Container layer: versioned stream serialization + self-delimiting frames.

Stage three of the pipeline: the only place that knows the byte layout.  The
v2 stream layout is pinned by a golden-bytes test (tests/test_codec.py) and
specified in docs/FORMAT.md:

  header  '<4sBBHQdIIQ': magic 'SZXJ' | version u8 | dtype u8 |
          block_size u16 | n u64 | e f64 | nblocks u32 | n_nonconst u32 |
          nmid u64
  const bitmap  ceil(nb/8) bytes (np.packbits order)
  mu            itemsize * nb bytes (input dtype, one per block)
  reqlen        u8 * n_nonconst
  L codes       2-bit * (n_nonconst * block_size), little-endian packed
  mid stream    nmid bytes in (block, value, byteplane) order

Chunked streams are sequences of frames, each framing one independent v2
stream:

  frame header '<4sBBIQ': magic 'SZXF' | version u8 | flags u8 (bit0 = last,
               bit1 = raw, bits 2-4 = second-stage code, see stage.py)
               | seq u32 | payload_len u64
"""
from __future__ import annotations

import functools
import struct
from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.core.codec import plan as plan_mod
from repro.core.codec.plan import Plan
from repro.core.codec.transform import BlockEncoding, derive_layout

MAGIC = b"SZXJ"
VERSION = 2
HEADER = struct.Struct("<4sBBHQdIIQ")

FRAME_MAGIC = b"SZXF"
FRAME_VERSION = 1
FRAME_HEADER = struct.Struct("<4sBBIQ")
FLAG_LAST = 0x01
FLAG_RAW = 0x02        # payload is raw bytes, not a v2 SZx stream (v3 packs)
# bits 2-4: negotiated lossless second-stage code over the mid-byte section
# (0 = none; see repro.core.codec.stage).  Readers that meet a non-zero code
# they cannot destage MUST fail loudly, never hand out garbage bytes.
FLAG_STAGE_SHIFT = 2
FLAG_STAGE_MASK = 0x7 << FLAG_STAGE_SHIFT


def stage_of_flags(flags: int) -> int:
    """Second-stage code recorded in a frame's flag bits (0 = stage-off)."""
    return (flags & FLAG_STAGE_MASK) >> FLAG_STAGE_SHIFT

# container v3: a frame sequence MAY be followed by a seekable index footer
# (JSON index payload + fixed trailer at the very end of the stream), which
# gives per-frame/per-leaf random access.  v2 streams (no footer) still
# decode; v2 readers predating the footer reject v3 files on the trailing
# bytes, which is the intended forward-compat failure mode.
INDEX_MAGIC = b"SZXI"
INDEX_VERSION = 1
INDEX_TRAILER = struct.Struct("<4sBBHQI")   # magic|ver|flags|reserved|len|crc32


# ---------------------------------------------------------------------------
# 2-bit code packing
# ---------------------------------------------------------------------------

def pack_2bit(codes: np.ndarray) -> np.ndarray:
    """codes: (m,) uint8 in [0,3] -> ceil(m/4) bytes."""
    pad = (-codes.size) % 4
    if pad:
        codes = np.concatenate([codes, np.zeros(pad, np.uint8)])
    c = codes.reshape(-1, 4)
    return (c[:, 0] | (c[:, 1] << 2) | (c[:, 2] << 4) | (c[:, 3] << 6)).astype(np.uint8)


def unpack_2bit(raw: np.ndarray, m: int) -> np.ndarray:
    b = raw.astype(np.uint8)
    out = np.empty((b.size, 4), np.uint8)
    out[:, 0] = b & 3
    out[:, 1] = (b >> 2) & 3
    out[:, 2] = (b >> 4) & 3
    out[:, 3] = (b >> 6) & 3
    return out.reshape(-1)[:m]


# ---------------------------------------------------------------------------
# monolithic v2 stream
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4)
def _value_base_cached(nb: int, bs: int, itemsize: int, wide: bool) -> np.ndarray:
    dt = np.int64 if wide else np.int32
    return (
        np.arange(nb, dtype=dt)[:, None] * (itemsize * bs)
        + np.arange(bs, dtype=dt)
    ).reshape(-1)


def _value_base(nb: int, bs: int, itemsize: int, wide: bool) -> np.ndarray:
    """Flat plane-0 index of each value in a C-contiguous (nb, itemsize, bs)
    plane array.  Small shapes are cached (read-only) because the chunked
    paths reuse one shape for every frame; larger ones are rebuilt per call
    so the cache pins at most 4 x 16 MB for the process lifetime."""
    if nb * bs <= 1 << 22:
        return _value_base_cached(nb, bs, itemsize, wide)
    return _value_base_cached.__wrapped__(nb, bs, itemsize, wide)


def _mid_plan(L: np.ndarray, nbytes: np.ndarray, itemsize: int):
    """Exact mid-stream layout from per-value counts (``nbytes - L``).

    Returns ``(counts, start, nmid, wide)``: flat per-value byte counts, the
    exclusive prefix sum (each value's offset into the mid stream), the total
    mid-byte count, and whether flat plane indices overflow int32.  Replaces
    the O(nblocks*block_size*itemsize) boolean mask of the v1 implementation.
    """
    nb, bs = L.shape
    wide = nb * bs * itemsize > np.iinfo(np.int32).max - bs
    counts = np.maximum(nbytes[:, None] - L, 0).reshape(-1)
    ends = np.cumsum(counts, dtype=np.int64 if wide else np.int32)
    nmid = int(ends[-1]) if counts.size else 0
    return counts, ends - counts, nmid, wide


def _copy_mid(L, nbytes, itemsize, counts, start, wide, src, dst, *, gather):
    """Move mid bytes between a flat (nb, itemsize, bs) plane array and the
    packed mid stream, in (block, value, byteplane) order.

    One fancy-index copy per byte slot k (<= itemsize passes, each over only
    the values with ``counts > k``): value v's k-th stored byte is plane
    ``L[v] + k`` and lands at mid offset ``start[v] + k``.  Indices are unique,
    so plain fancy assignment suffices -- no ``np.add.at``.
    """
    nb, bs = L.shape
    lb = L.reshape(-1)
    if wide:
        lb = lb.astype(np.int64)
    src0 = _value_base(nb, bs, itemsize, wide) + lb * bs
    for k in range(itemsize):
        sel = np.flatnonzero(counts > k)
        if sel.size == 0:
            break
        plane_idx = src0[sel] + k * bs
        mid_idx = start[sel] + k
        if gather:
            dst[mid_idx] = src[plane_idx]
        else:
            dst[plane_idx] = src[mid_idx]


@dataclass(frozen=True)
class StreamSections:
    """Parsed metadata sections of one v2 stream -- everything EXCEPT the
    mid-byte stream.

    This is the partial-decode contract: the metadata prefix (header, const
    bitmap, mu, reqlen, L codes) is tiny relative to the mid stream, and
    ``block_mid_start`` locates every block's mid bytes, so a reader can
    fetch the prefix, pick a block range, and then read ONLY that range's
    mid bytes (``repro.store`` ROI reads do exactly this).
    """

    plan: Plan
    const: np.ndarray            # (nb,) bool
    mu: np.ndarray               # (nb,) stream dtype
    reqlen: np.ndarray           # (nb,) int32 (0 for const blocks)
    shift: np.ndarray            # (nb,) int32
    nbytes: np.ndarray           # (nb,) int32
    L: np.ndarray                # (nb, bs) int32
    nmid: int                    # total mid-stream length (header field)
    mid_offset: int              # byte offset of the mid stream in the stream
    block_mid_start: np.ndarray  # (nb,) int64 exclusive cumsum of block mid bytes

    def mid_range(self, lo: int, hi: int) -> tuple[int, int]:
        """[start, stop) byte offsets WITHIN the mid stream holding the mid
        bytes of blocks [lo, hi)."""
        nb = self.plan.nblocks
        start = int(self.block_mid_start[lo]) if lo < nb else self.nmid
        stop = int(self.block_mid_start[hi]) if hi < nb else self.nmid
        return start, stop


def stream_prefix_length(header: bytes) -> int:
    """Byte length of the metadata prefix (header through L codes) of a v2
    stream, computed from its 40-byte header alone."""
    if len(header) < HEADER.size:
        raise ValueError("truncated SZx stream (shorter than header)")
    _m, _v, dtype_code, bs, _n, _e, nb, nnc, _nmid = HEADER.unpack_from(header, 0)
    spec = plan_mod.spec_for_code(dtype_code)
    nbm = (nb + 7) // 8
    nl = (nnc * bs + 3) // 4
    return HEADER.size + nbm + spec.itemsize * nb + nnc + nl


def parse_stream_sections(prefix, *, backend: str = "auto") -> StreamSections:
    """Validate + deserialize the metadata prefix of a v2 stream.

    ``prefix`` must cover at least the metadata sections (header, const
    bitmap, mu, reqlen, L codes); the mid-byte stream may be absent -- its
    layout is returned as ``block_mid_start`` so callers can read just the
    ranges they need (see :func:`extract_block_range`).
    """
    buf = bytes(prefix) if not isinstance(prefix, (bytes, bytearray)) else prefix
    if len(buf) < HEADER.size:
        raise ValueError("truncated SZx stream (shorter than header)")
    magic, version, dtype_code, bs, n, e, nb, nnc, nmid = HEADER.unpack_from(buf, 0)
    if magic != MAGIC:
        raise ValueError("bad SZx stream header (magic mismatch)")
    if version != VERSION:
        raise ValueError(f"unsupported SZx stream version {version}")
    spec = plan_mod.spec_for_code(dtype_code)           # raises on unknown code
    if nnc > nb:
        raise ValueError("corrupt SZx stream (n_nonconst > nblocks)")
    if bs == 0 or nb != (n + bs - 1) // bs:
        raise ValueError("corrupt SZx stream (block count mismatch)")
    p = plan_mod.plan_for_stream(dtype_code, bs, n, e, backend)

    nbm = (nb + 7) // 8
    nl = (nnc * bs + 3) // 4
    prefix_len = HEADER.size + nbm + spec.itemsize * nb + nnc + nl
    if len(buf) < prefix_len:
        raise ValueError(
            f"truncated SZx stream ({len(buf)} bytes, metadata sections "
            f"need {prefix_len})"
        )
    off = HEADER.size
    const = np.unpackbits(np.frombuffer(buf, np.uint8, nbm, off))[:nb].astype(bool)
    off += nbm
    mu = np.frombuffer(buf, spec.np_dtype, nb, off).copy()
    off += spec.itemsize * nb
    reqlen_nc = np.frombuffer(buf, np.uint8, nnc, off).astype(np.int32)
    off += nnc
    L_nc = unpack_2bit(np.frombuffer(buf, np.uint8, nl, off), nnc * bs)
    off += nl

    nc = ~const
    if int(nc.sum()) != nnc:
        raise ValueError("corrupt SZx stream (const bitmap / n_nonconst mismatch)")
    reqlen = np.zeros(nb, np.int32)
    reqlen[nc] = reqlen_nc
    shift, nbytes = derive_layout(reqlen, const, spec)
    if nbytes.max(initial=0) > spec.itemsize:
        raise ValueError("corrupt SZx stream (reqlen exceeds dtype width)")
    L = np.zeros((nb, bs), np.int32)
    L[nc] = L_nc.reshape(nnc, bs)

    # sum_v max(nbytes - L_v, 0) == bs*nbytes - sum_v min(L_v, nbytes);
    # computed on the non-const rows only (L is all-zero elsewhere)
    block_counts = nbytes.astype(np.int64) * bs
    if nnc:
        block_counts[nc] -= np.minimum(
            L_nc.reshape(nnc, bs), nbytes[nc, None]
        ).sum(axis=1, dtype=np.int64)
    ends = np.cumsum(block_counts)
    total = int(ends[-1]) if nb else 0
    if total != nmid:
        raise ValueError("corrupt SZx stream (mid-stream length mismatch)")
    return StreamSections(
        p, const, mu, reqlen, shift, nbytes, L, int(nmid), off,
        ends - block_counts,
    )


def extract_block_range(sec: StreamSections, mid, lo: int, hi: int) -> BlockEncoding:
    """Materialize the block encoding of blocks [lo, hi) of a parsed stream.

    ``mid`` holds EXACTLY those blocks' mid bytes (the ``sec.mid_range(lo,
    hi)`` slice of the mid stream).  The returned encoding is self-contained
    (block axis rebased to start at ``lo``) and decodes with the ordinary
    :func:`repro.core.codec.transform.decode_blocks` on any backend --
    partial decode costs O(hi - lo), not O(nblocks).
    """
    nb = sec.plan.nblocks
    if not 0 <= lo < hi <= nb:
        raise ValueError(f"block range [{lo}, {hi}) out of [0, {nb})")
    spec = sec.plan.dtype
    itemsize = spec.itemsize
    bs = sec.plan.block_size
    L_r = np.ascontiguousarray(sec.L[lo:hi])
    nbytes_r = np.ascontiguousarray(sec.nbytes[lo:hi])
    counts, start, nmid_r, wide = _mid_plan(L_r, nbytes_r, itemsize)
    mid_u8 = np.frombuffer(mid, np.uint8) if not isinstance(mid, np.ndarray) else mid
    if mid_u8.size != nmid_r:
        raise ValueError(
            f"mid-byte range for blocks [{lo}, {hi}) has {mid_u8.size} bytes, "
            f"expected {nmid_r}"
        )
    planes = np.zeros((hi - lo, itemsize, bs), np.uint8)
    if nmid_r:
        _copy_mid(
            L_r, nbytes_r, itemsize, counts, start, wide,
            mid_u8, planes.reshape(-1), gather=False,
        )
    return BlockEncoding(
        sec.mu[lo:hi], sec.const[lo:hi], sec.reqlen[lo:hi],
        sec.shift[lo:hi], nbytes_r, planes, L_r,
    )


def build_stream(p: Plan, enc: BlockEncoding) -> bytes:
    """Serialize one plan + block encoding into a self-contained v2 stream."""
    nc = ~enc.const
    nnc = int(nc.sum())
    itemsize = p.dtype.itemsize
    nb = p.nblocks
    bs = p.block_size
    counts, start, nmid, wide = _mid_plan(enc.L, enc.nbytes, itemsize)
    nbm = (nb + 7) // 8
    nl = (nnc * bs + 3) // 4
    # one preallocated buffer, every section written in place (no join copies)
    out = bytearray(HEADER.size + nbm + itemsize * nb + nnc + nl + nmid)
    HEADER.pack_into(
        out, 0, MAGIC, VERSION, p.dtype.code, p.block_size, p.n,
        p.error_bound, p.nblocks, nnc, nmid,
    )
    u8 = np.frombuffer(out, np.uint8)
    off = HEADER.size
    u8[off : off + nbm] = np.packbits(enc.const.astype(np.uint8))
    off += nbm
    u8[off : off + itemsize * nb] = np.ascontiguousarray(enc.mu).view(np.uint8)
    off += itemsize * nb
    u8[off : off + nnc] = enc.reqlen[nc].astype(np.uint8)
    off += nnc
    u8[off : off + nl] = pack_2bit(enc.L[nc].reshape(-1).astype(np.uint8))
    off += nl
    if nmid:
        _copy_mid(
            enc.L, enc.nbytes, itemsize, counts, start, wide,
            np.ascontiguousarray(enc.planes).reshape(-1),
            u8[off : off + nmid], gather=True,
        )
    return bytes(out)


def parse_stream(buf: bytes, *, backend: str = "auto") -> tuple[Plan, BlockEncoding]:
    """Validate + deserialize a v2 stream into (plan, block encoding)."""
    sec = parse_stream_sections(buf, backend=backend)
    expected = sec.mid_offset + sec.nmid
    if len(buf) < expected:
        raise ValueError(
            f"truncated SZx stream ({len(buf)} bytes, expected {expected})"
        )
    nb = sec.plan.nblocks
    if nb == 0:
        spec = sec.plan.dtype
        planes = np.zeros((0, spec.itemsize, sec.plan.block_size), np.uint8)
        return sec.plan, BlockEncoding(
            sec.mu, sec.const, sec.reqlen, sec.shift, sec.nbytes, planes, sec.L
        )
    mid_stream = np.frombuffer(buf, np.uint8, sec.nmid, sec.mid_offset)
    return sec.plan, extract_block_range(sec, mid_stream, 0, nb)


# ---------------------------------------------------------------------------
# self-delimiting frames (chunked streaming)
# ---------------------------------------------------------------------------

def build_frame(payload: bytes, seq: int, last: bool, *, raw: bool = False,
                stage=None) -> bytes:
    """Wrap one payload (v2 stream, or raw bytes with ``raw=True``) as a
    self-delimiting frame.

    ``stage`` (a ``repro.core.codec.stage`` name or code) requests the
    negotiated lossless second stage over the payload's mid-byte section:
    the frame is staged only when that actually shrinks it (and never for
    ``raw`` payloads), so a frame with stage bits set is always smaller than
    its stage-off form and ``stage=...`` can never lose.  Stage-off frames
    are byte-identical to frames built before the stage existed.
    """
    flags = (FLAG_LAST if last else 0) | (FLAG_RAW if raw else 0)
    staged_code = 0
    orig_payload = payload
    if stage is not None and not raw:
        from repro.core.codec import stage as stage_mod

        code = stage_mod.resolve(stage)
        if code:
            staged = stage_mod.stage_payload(payload, code)
            if staged is not None:
                payload = staged
                flags |= code << FLAG_STAGE_SHIFT
                staged_code = code
    frame = FRAME_HEADER.pack(
        FRAME_MAGIC, FRAME_VERSION, flags, seq, len(payload)
    ) + payload
    if not raw:
        from repro import obs

        if obs.enabled():
            obs.stream_stats.record_frame_built(
                orig_payload, len(frame), seq, staged_code
            )
    return frame


def destage_frame_payload(payload: bytes, flags: int) -> tuple[bytes, int]:
    """Undo a frame's second stage: ``(raw v2 payload, flags sans stage bits)``.

    Stage-off frames pass through untouched.  Frames whose stage this reader
    cannot run (unknown code, missing optional dependency) raise the
    fail-loudly ``stream requires second stage ...`` ValueError; raw frames
    with stage bits set are rejected as corrupt (writers never emit them).
    """
    code = stage_of_flags(flags)
    if not code:
        return payload, flags
    if flags & FLAG_RAW:
        raise ValueError(
            "corrupt SZx frame (raw frame carries second-stage flag bits)"
        )
    from repro.core.codec import stage as stage_mod

    return stage_mod.destage_payload(payload, code), flags & ~FLAG_STAGE_MASK


# ---------------------------------------------------------------------------
# container v3: seekable index footer
# ---------------------------------------------------------------------------

def build_index_footer(index: dict) -> bytes:
    """Serialize an index dict as the v3 footer: JSON payload + trailer.

    Appended AFTER the LAST-flagged frame; the trailer sits at the very end
    of the stream so a reader can find the index with two seeks.
    """
    import json
    import zlib

    payload = json.dumps(index, separators=(",", ":"), default=float).encode()
    # leading sentinel magic: lets a sequential frame reader recognize "the
    # rest of this stream is the index footer" from the first 4 bytes
    return INDEX_MAGIC + payload + INDEX_TRAILER.pack(
        INDEX_MAGIC, INDEX_VERSION, 0, 0, len(payload), zlib.crc32(payload)
    )


def read_index_footer(f) -> dict | None:
    """Read the v3 index footer of a seekable stream; None if absent (v2).

    Corrupt footers (bad CRC, truncated index, unsupported version) raise --
    a stream that CLAIMS to have an index must have a valid one.  The file
    position is left at the start of the index payload's JSON on success.
    """
    import json
    import zlib

    end = f.seek(0, 2)
    if end < INDEX_TRAILER.size:
        return None
    f.seek(end - INDEX_TRAILER.size)
    magic, version, _flags, _res, ilen, crc = INDEX_TRAILER.unpack(
        _read_exact(f, INDEX_TRAILER.size)
    )
    if magic != INDEX_MAGIC:
        return None
    if version != INDEX_VERSION:
        raise ValueError(f"unsupported SZx index footer version {version}")
    if ilen > end - INDEX_TRAILER.size:
        raise ValueError("corrupt SZx index footer (index longer than stream)")
    f.seek(end - INDEX_TRAILER.size - ilen)
    payload = _read_exact(f, ilen)
    if zlib.crc32(payload) != crc:
        raise ValueError("corrupt SZx index footer (CRC mismatch)")
    return json.loads(payload)


def read_index_footer_safe(f) -> dict | None:
    """Corruption-tolerant :func:`read_index_footer`: a bit-flipped or
    truncated footer returns ``None`` after a ``RuntimeWarning`` instead of
    raising, so callers can fall back to a sequential v2 decode.  A stream
    with no footer at all returns ``None`` silently, exactly like
    :func:`read_index_footer`."""
    import json
    import warnings

    try:
        return read_index_footer(f)
    except (ValueError, json.JSONDecodeError, struct.error) as err:
        warnings.warn(
            f"corrupt container-v3 index footer ({err}); treating the stream "
            "as a sequential (v2) frame sequence",
            RuntimeWarning,
            stacklevel=2,
        )
        return None


def read_frame_at(f, offset: int, length: int, seq: int) -> tuple[bytes, int]:
    """Random-access read of one frame via its index entry.

    Seeks to ``offset``, reads exactly ``length`` bytes, validates the frame
    header against the expected ``seq``, and returns ``(payload, flags)``.
    """
    f.seek(offset)
    frame = _read_exact(f, length)
    if len(frame) < FRAME_HEADER.size:
        raise ValueError("truncated SZx frame (shorter than frame header)")
    magic, version, flags, fseq, plen = FRAME_HEADER.unpack_from(frame, 0)
    if magic != FRAME_MAGIC:
        raise ValueError("bad SZx frame (magic mismatch)")
    if version != FRAME_VERSION:
        raise ValueError(f"unsupported SZx frame version {version}")
    if fseq != seq:
        raise ValueError(f"SZx index/frame seq mismatch (frame {fseq}, index {seq})")
    if len(frame) != FRAME_HEADER.size + plen:
        raise ValueError("truncated SZx frame (payload length mismatch)")
    return destage_frame_payload(frame[FRAME_HEADER.size:], flags)


def read_frame_stream_header_at(f, offset: int, seq: int) -> tuple[int, int, bytes]:
    """Random-access 58-byte peek at a frame's headers: seek to ``offset``,
    validate the frame header against ``seq`` and the payload's v2 stream
    header, and return ``(flags, payload_len, stream_header)``.

    The shared entry for every partial reader (store ROI reads, query
    scans, checkpoint sliced restore) -- none of them should interpret
    index-supplied offsets without these checks.  The file position is left
    right after the stream header.  Raw frames (no v2 payload) are the
    caller's job to route around via the index.
    """
    f.seek(offset)
    head = _read_exact(f, FRAME_HEADER.size + HEADER.size)
    magic, version, flags, fseq, plen = FRAME_HEADER.unpack_from(head, 0)
    if magic != FRAME_MAGIC:
        raise ValueError("bad SZx frame (magic mismatch)")
    if version != FRAME_VERSION:
        raise ValueError(f"unsupported SZx frame version {version}")
    if fseq != seq:
        raise ValueError(f"SZx index/frame seq mismatch (frame {fseq}, index {seq})")
    if plen < HEADER.size:
        raise ValueError("truncated SZx stream (shorter than header)")
    sheader = head[FRAME_HEADER.size:]
    if sheader[:4] != MAGIC:
        raise ValueError("bad SZx stream header (magic mismatch)")
    if sheader[4] != VERSION:
        raise ValueError(f"unsupported SZx stream version {sheader[4]}")
    return flags, plen, sheader


def _read_exact(f, size: int) -> bytes:
    data = f.read(size)
    if len(data) != size:
        raise ValueError(
            f"truncated SZx frame sequence (wanted {size} bytes, got {len(data)})"
        )
    return data


def peek_stream_meta(payload: bytes) -> tuple[int, int, float]:
    """(dtype code, element count, absolute bound) of one v2 payload's
    header -- the layout-aware peek for index builders and `info` tools."""
    if len(payload) < HEADER.size:
        raise ValueError("truncated SZx stream (shorter than header)")
    _m, _v, dtype_code, _bs, n, e, _nb, _nnc, _nmid = HEADER.unpack_from(payload, 0)
    return dtype_code, n, e


def iter_frames(source, *, with_flags: bool = False) -> Iterator:
    """Yield frame payloads from bytes, a binary file object, or an iterable
    of frame byte strings.  Validates magic, version, sequence numbers, and
    that the sequence terminates with a LAST-flagged frame.  With
    ``with_flags=True`` yields ``(payload, flags)`` pairs instead."""
    if isinstance(source, (bytes, bytearray, memoryview)):
        import io

        source = io.BytesIO(source)
    if hasattr(source, "read"):
        it = _iter_frames_file(source)
    else:
        it = _iter_frames_iterable(source)
    for payload, flags in it:
        yield (payload, flags) if with_flags else payload


def _iter_frames_iterable(source) -> Iterator[tuple[bytes, int]]:
    # iterable of per-frame byte strings (e.g. straight from compress_chunked)
    seq_expected = 0
    saw_last = False
    for frame in source:
        if saw_last:
            raise ValueError("SZx frame after the LAST-flagged frame")
        payload, flags = _parse_one_frame(frame, seq_expected)
        saw_last = bool(flags & FLAG_LAST)
        seq_expected += 1
        yield payload, flags
    if seq_expected == 0:
        raise ValueError("empty SZx frame sequence")
    if not saw_last:
        raise ValueError("SZx frame sequence ended without a LAST frame")


def _parse_one_frame(frame: bytes, seq_expected: int) -> tuple[bytes, int]:
    if len(frame) < FRAME_HEADER.size:
        raise ValueError("truncated SZx frame (shorter than frame header)")
    magic, version, flags, seq, plen = FRAME_HEADER.unpack_from(frame, 0)
    if magic != FRAME_MAGIC:
        raise ValueError("bad SZx frame (magic mismatch)")
    if version != FRAME_VERSION:
        raise ValueError(f"unsupported SZx frame version {version}")
    if seq != seq_expected:
        raise ValueError(f"SZx frame out of order (seq {seq}, expected {seq_expected})")
    if len(frame) != FRAME_HEADER.size + plen:
        raise ValueError("truncated SZx frame (payload length mismatch)")
    return destage_frame_payload(frame[FRAME_HEADER.size:], flags)


def _iter_frames_file(f) -> Iterator[tuple[bytes, int]]:
    seq_expected = 0
    while True:
        if seq_expected == 0:
            hdr = f.read(FRAME_HEADER.size)
            if not hdr:
                raise ValueError("empty SZx frame sequence")
            if len(hdr) != FRAME_HEADER.size:
                raise ValueError(
                    f"truncated SZx frame sequence (wanted {FRAME_HEADER.size} "
                    f"bytes, got {len(hdr)})"
                )
        else:
            hdr = _read_exact(f, FRAME_HEADER.size)
        magic, version, flags, seq, plen = FRAME_HEADER.unpack(hdr)
        if magic != FRAME_MAGIC:
            raise ValueError("bad SZx frame (magic mismatch)")
        if version != FRAME_VERSION:
            raise ValueError(f"unsupported SZx frame version {version}")
        if seq != seq_expected:
            raise ValueError(
                f"SZx frame out of order (seq {seq}, expected {seq_expected})"
            )
        yield destage_frame_payload(_read_exact(f, plen), flags)
        seq_expected += 1
        if flags & FLAG_LAST:
            # v3 streams carry an index footer after the LAST frame.  A
            # further frame (FRAME_MAGIC) is always an error; any OTHER
            # trailing bytes are most plausibly a corrupted footer, and the
            # frames themselves are intact, so tolerate them with a warning
            # (sequential decode is the corrupt-footer fallback path).
            tail = f.read(len(INDEX_MAGIC))
            if tail and tail != INDEX_MAGIC:
                if tail.startswith(FRAME_MAGIC[: len(tail)]):
                    raise ValueError("SZx frame after the LAST-flagged frame")
                import warnings

                warnings.warn(
                    "ignoring unrecognized trailing bytes after the LAST "
                    "SZx frame (corrupt index footer?)",
                    RuntimeWarning,
                    stacklevel=3,
                )
            return
