"""Transform layer: per-block stats, Solution-C shift, XOR-lead, byte planes.

Stage two of the pipeline (paper Algorithm 1 lines 3-9).  Everything here is
fixed-shape; the variable-length compaction happens in the container layer.

Two execution paths:
  * float32 -- dispatched through ``repro.kernels.ops`` (Pallas kernel, jnp
    oracle, or numpy mirror), bit-identical to the original monolith and able
    to run device-resident on TPU.
  * float64 / float16 / bfloat16 -- a width-parameterized numpy
    implementation driven by the :class:`~repro.core.codec.plan.DtypeSpec`
    exponent/mantissa geometry.  Stats run in float64 so the 16-bit formats
    don't lose the bound to intermediate rounding; the normalized residual is
    rounded to the *input* dtype before the bit-level split, so the stored
    word is exactly the dtype's IEEE-754 word (verbatim blocks stay
    bit-exact).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.codec import plan as plan_mod
from repro.core.codec.plan import DtypeSpec, Plan


@dataclass(frozen=True)
class BlockEncoding:
    """Fixed-shape transform output, ready for container serialization."""

    mu: np.ndarray       # (nb,) plan dtype -- block mean-of-min/max
    const: np.ndarray    # (nb,) bool -- constant-block flags
    reqlen: np.ndarray   # (nb,) int32 -- required bits (0 for const blocks)
    shift: np.ndarray    # (nb,) int32 -- Solution-C right shift
    nbytes: np.ndarray   # (nb,) int32 -- stored bytes/value before XOR-lead
    planes: np.ndarray   # (nb, W, bs) uint8 -- byte planes, MSB-first
    L: np.ndarray        # (nb, bs) int32 -- identical-leading-byte counts


def derive_layout(reqlen: np.ndarray, const: np.ndarray, spec: DtypeSpec):
    """(shift, nbytes) from stored reqlen (Formula 5); 0 for const blocks."""
    reqlen = reqlen.astype(np.int32)
    shift = np.where(const, 0, (8 - reqlen % 8) % 8).astype(np.int32)
    nbytes = np.where(const, 0, (reqlen + shift) // 8).astype(np.int32)
    return shift, nbytes


# ---------------------------------------------------------------------------
# float32 fast path: kernels.ops dispatch (jax / pallas / numpy)
# ---------------------------------------------------------------------------

def _encode_f32(xb: np.ndarray, p: Plan) -> BlockEncoding:
    from repro.kernels import ops

    mu, _radius, const, reqlen, shift, nbytes = ops.block_stats(
        xb, p.error_bound, backend=p.backend
    )
    planes, L, _mid = ops.pack(xb, mu, shift, nbytes, backend=p.backend)
    mu, const, reqlen, shift, nbytes, planes, L = (
        np.asarray(a) for a in (mu, const, reqlen, shift, nbytes, planes, L)
    )
    return BlockEncoding(mu, const.astype(bool), reqlen.astype(np.int32),
                         shift.astype(np.int32), nbytes.astype(np.int32),
                         planes, L.astype(np.int32))


def _decode_f32(enc: BlockEncoding, p: Plan) -> np.ndarray:
    from repro.kernels import ops

    return np.asarray(
        ops.unpack(enc.planes, enc.mu, enc.shift, enc.nbytes, enc.L, backend=p.backend)
    )


# ---------------------------------------------------------------------------
# generic width-parameterized path (f64 / f16 / bf16)
# ---------------------------------------------------------------------------

def _exponent_exact(x64: np.ndarray) -> np.ndarray:
    """Exact floor(log2 |x|) per element (frexp); garbage for x == 0."""
    return (np.frexp(x64)[1] - 1).astype(np.int32)


def _encode_generic(xb: np.ndarray, p: Plan) -> BlockEncoding:
    spec = p.dtype
    xb = np.ascontiguousarray(xb, dtype=spec.np_dtype)
    nb, bs = xb.shape
    x64 = xb.astype(np.float64)
    mn = x64.min(axis=1)
    mx = x64.max(axis=1)
    mu = (0.5 * (mn + mx)).astype(spec.np_dtype)       # storage-rounded mu
    mu64 = mu.astype(np.float64)
    # radius vs the ROUNDED mu: the constant-block test then already covers
    # the mu storage rounding of the narrow dtypes
    radius = np.maximum(mx - mu64, mu64 - mn)
    const = radius <= p.error_bound
    p_e = plan_mod.float_exponent_of(p.error_bound)
    req_m_raw = np.where(radius > 0, _exponent_exact(radius), np.int32(0)) - p_e + 1
    req_m = np.clip(req_m_raw, 0, spec.mant_bits)
    # Verbatim blocks: bound below the values' ulp -- store words bit-exactly
    # by normalizing against mu = 0 (same beyond-paper rule as the f32 path)
    verbatim = ~const & (req_m_raw > spec.mant_bits)
    mu = np.where(verbatim, np.zeros_like(mu), mu)
    mu64 = mu.astype(np.float64)
    reqlen = (1 + spec.exp_bits + req_m).astype(np.int32)
    reqlen = np.where(const, np.int32(0), reqlen)
    shift, nbytes = derive_layout(reqlen, const, spec)   # Formula 5, shared
                                                         # with the decode side

    v = (x64 - mu64[:, None]).astype(spec.np_dtype)    # exact for verbatim
    w = v.view(spec.uint_dtype)
    ws = w >> shift[:, None].astype(spec.uint_dtype)
    prev = np.concatenate(
        [np.zeros((nb, 1), spec.uint_dtype), ws[:, :-1]], axis=1
    )
    xw = ws ^ prev
    # leading identical bytes vs predecessor, capped by the 2-bit code at 3
    itemsize = spec.itemsize
    lz = np.zeros((nb, bs), np.int32)
    run = np.ones((nb, bs), bool)
    for j in range(min(3, itemsize)):
        run = run & ((xw >> np.array(8 * (itemsize - 1 - j), spec.uint_dtype)) == 0)
        lz += run
    L = np.minimum(lz, nbytes[:, None])
    # little-endian host: plane j (MSB-first) is byte itemsize-1-j
    planes = np.ascontiguousarray(
        ws.view(np.uint8).reshape(nb, bs, itemsize)[:, :, ::-1].transpose(0, 2, 1)
    )
    return BlockEncoding(mu, const, reqlen, shift, nbytes, planes, L)


def _decode_generic(enc: BlockEncoding, p: Plan) -> np.ndarray:
    spec = p.dtype
    nb, itemsize, bs = enc.planes.shape
    idxs = np.arange(bs, dtype=np.int32)[None, :]
    ws = np.zeros((nb, bs), spec.uint_dtype)
    # little-endian host: plane j (MSB-first) is byte itemsize-1-j of the word
    wsb = ws.view(np.uint8).reshape(nb, bs, itemsize)
    for j in range(min(itemsize, int(enc.nbytes.max(initial=0)))):
        live = enc.nbytes > j
        act = slice(None) if live.all() else np.flatnonzero(live)
        pj = enc.planes[act, j, :]
        Lj = enc.L[act]
        # L <= 3, so planes past 2 (or with no L > j value) are stored verbatim
        # for every live value -- the propagation scan is skipped
        if j >= 3 or not (Lj > j).any():
            wsb[act, :, itemsize - 1 - j] = pj
            continue
        src = np.where(Lj <= j, idxs, np.int32(-1))
        np.maximum.accumulate(src, axis=1, out=src)    # index propagation
        byte = np.take_along_axis(pj, np.maximum(src, 0), axis=1)
        byte[src < 0] = 0
        wsb[act, :, itemsize - 1 - j] = byte
    w = ws << enc.shift[:, None].astype(spec.uint_dtype)
    v = w.view(spec.np_dtype)
    mu64 = enc.mu.astype(np.float64)
    x = (v.astype(np.float64) + mu64[:, None]).astype(spec.np_dtype)
    return np.where((enc.nbytes == 0)[:, None], enc.mu[:, None], x)


# ---------------------------------------------------------------------------
# public dispatch
# ---------------------------------------------------------------------------

def encode_blocks(xb: np.ndarray, p: Plan) -> BlockEncoding:
    """(nb, bs) blocks -> fixed-shape encoding per the plan's dtype."""
    if p.dtype.code == 0:
        return _encode_f32(np.asarray(xb, np.float32), p)
    return _encode_generic(xb, p)


def decode_blocks(enc: BlockEncoding, p: Plan) -> np.ndarray:
    """Inverse of :func:`encode_blocks` -> (nb, bs) in the plan dtype.

    Frames whose L codes are all zero (no XOR-lead elision anywhere) take the
    batched dense f32 path, which skips the per-byte index-propagation scan.
    """
    if p.dtype.code == 0:
        if not enc.L.any():
            from repro.kernels import ops

            return np.asarray(
                ops.unpack_dense(
                    enc.planes, enc.mu, enc.shift, enc.nbytes, backend=p.backend
                )
            )
        return _decode_f32(enc, p)
    return _decode_generic(enc, p)
