"""Transform layer: per-block stats, Solution-C shift, XOR-lead, byte planes.

Stage two of the pipeline (paper Algorithm 1 lines 3-9).  Everything here is
fixed-shape; the variable-length compaction happens in the container layer.

ONE execution path for every dtype (f32/f64/f16/bf16): the width-generic
kernel layer in ``repro.kernels`` -- the plan's
:class:`~repro.core.codec.plan.DtypeSpec` parameterizes the word geometry and
the ``backend`` field picks the implementation ('jax' jitted oracle, 'kernel'
Pallas, 'numpy' mirror; all bit-identical per spec).

These are thin HOST adapters: the device backends' encode hot path lives in
``repro.core.codec.device`` (fused stats+pack AND byte-layout derivation in
one jitted program, one ``device_get`` per chunk); :func:`encode_blocks`
remains the fixed-shape entry the host ('numpy') serializer and the decode
side use.  Encode stays the FUSED ``ops.encode`` (stats + pack as one
program); decode dispatches the all-``L==0`` dense fast path whenever a
frame has no XOR-lead elision, for every dtype.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.codec.plan import DtypeSpec, Plan


@dataclass(frozen=True)
class BlockEncoding:
    """Fixed-shape transform output, ready for container serialization."""

    mu: np.ndarray       # (nb,) plan dtype -- block mean-of-min/max
    const: np.ndarray    # (nb,) bool -- constant-block flags
    reqlen: np.ndarray   # (nb,) int32 -- required bits (0 for const blocks)
    shift: np.ndarray    # (nb,) int32 -- Solution-C right shift
    nbytes: np.ndarray   # (nb,) int32 -- stored bytes/value before XOR-lead
    planes: np.ndarray   # (nb, W, bs) uint8 -- byte planes, MSB-first
    L: np.ndarray        # (nb, bs) int32 -- identical-leading-byte counts


def derive_layout(reqlen: np.ndarray, const: np.ndarray, spec: DtypeSpec):
    """(shift, nbytes) from stored reqlen (Formula 5); 0 for const blocks."""
    reqlen = reqlen.astype(np.int32)
    shift = np.where(const, 0, (8 - reqlen % 8) % 8).astype(np.int32)
    nbytes = np.where(const, 0, (reqlen + shift) // 8).astype(np.int32)
    return shift, nbytes


def encode_blocks(xb: np.ndarray, p: Plan) -> BlockEncoding:
    """(nb, bs) blocks -> fixed-shape encoding per the plan's dtype.

    One fused ``ops.encode`` dispatch: on device backends the whole
    stats+pack pipeline is a single staged program.
    """
    from repro.kernels import ops

    xb = np.ascontiguousarray(np.asarray(xb), dtype=p.dtype.np_dtype)
    mu, const, reqlen, shift, nbytes, planes, L = ops.encode(
        xb, p.error_bound, spec=p.dtype, backend=p.backend
    )
    mu, const, reqlen, shift, nbytes, planes, L = (
        np.asarray(a) for a in (mu, const, reqlen, shift, nbytes, planes, L)
    )
    return BlockEncoding(mu, const.astype(bool), reqlen.astype(np.int32),
                         shift.astype(np.int32), nbytes.astype(np.int32),
                         planes, L.astype(np.int32))


def decode_blocks(enc: BlockEncoding, p: Plan, *, out=None) -> np.ndarray:
    """Inverse of :func:`encode_blocks` -> (nb, bs) in the plan dtype.

    Frames whose L codes are all zero (no XOR-lead elision anywhere) take the
    batched dense path -- for EVERY dtype -- which skips the per-byte
    index-propagation scan.  With ``out`` (a (nb, bs) array in the plan
    dtype) the frame is reconstructed straight into the caller's buffer and
    ``out`` is returned -- the chunked decompressors pass views of their
    preallocated output so no per-frame result array is ever materialized.
    """
    from repro.kernels import ops

    if not enc.L.any():
        res = ops.unpack_dense(
            enc.planes, enc.mu, enc.shift, enc.nbytes,
            spec=p.dtype, backend=p.backend, out=out,
        )
    else:
        res = ops.unpack(
            enc.planes, enc.mu, enc.shift, enc.nbytes, enc.L,
            spec=p.dtype, backend=p.backend, out=out,
        )
    return res if out is not None else np.asarray(res)


def decode_block_range(enc: BlockEncoding, p: Plan, lo: int, hi: int) -> np.ndarray:
    """Partial decode: blocks [lo, hi) only -> (hi - lo, bs) in the plan dtype.

    The ROI entry point: decode cost scales with the requested range, not the
    stream -- all three backends, through the same ``ops`` dispatch (dense
    fast path included via :func:`repro.kernels.ops.unpack_range`)."""
    from repro.kernels import ops

    if not 0 <= lo < hi <= enc.mu.shape[0]:
        raise ValueError(f"block range [{lo}, {hi}) out of [0, {enc.mu.shape[0]})")
    return np.asarray(
        ops.unpack_range(
            enc.planes, enc.mu, enc.shift, enc.nbytes, enc.L, lo, hi,
            spec=p.dtype, backend=p.backend,
        )
    )
