"""Pytree front-end: TreeCodec (multi-leaf container-v3 streams).

One codec call per PYTREE instead of per leaf: :meth:`TreeCodec.compress_tree`
flattens any pytree into one container-v3 stream -- small leaves (integers,
step counters, tiny floats) are packed back-to-back into a single shared
raw frame near the start of the file, large float leaves run through the
existing chunked worker pipeline (``SZxCodec.iter_chunk_payloads``, one
independent v2 payload per block-aligned chunk) -- and appends the seekable
index footer mapping every leaf to its frames and byte ranges.

:meth:`TreeCodec.decompress_tree` restores the whole tree into a template,
or -- with ``select=`` -- reads ONLY the byte ranges of the named leaves
(elastic single-shard restore: any host can pull just its shard's leaves out
of a full checkpoint stream without touching the rest of the file).

The error bound is resolved PER LEAF over the leaf's full value range (so
``mode='rel'`` means the same thing it does for a monolithic compression of
that leaf, regardless of how the leaf is chunked into frames).  This is the
tree-level API the checkpoint manager, and any future sharded/async stream
writer, sit on.
"""
from __future__ import annotations

from dataclasses import InitVar, dataclass, field
from typing import Any, Iterable, Iterator

import numpy as np

from repro import obs
from repro.core.codec import container, plan as plan_mod
from repro.core.codec.plan import Bound
from repro.core.codec.szx_codec import (
    DEFAULT_CHUNK_BYTES,
    SZxCodec,
    _imap_ordered,
)

STREAM_KIND = "szx-tree"


def leaf_name(keypath) -> str:
    """'/'-joined name of one pytree keypath (dict keys, sequence indices,
    dataclass fields).  The ONE definition shared by save and restore --
    these strings are the lookup keys joining the two sides."""
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)


def leaf_paths(tree) -> list[tuple[str, Any]]:
    """Flatten a pytree into ``(name, leaf)`` pairs (see :func:`leaf_name`)."""
    import jax

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(leaf_name(kp), leaf) for kp, leaf in flat]


def np_dtype_for(name: str) -> np.dtype:
    """np.dtype from its manifest string, including the ml_dtypes extension
    floats (bfloat16) that plain ``np.dtype`` does not know."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        try:
            return np.dtype(getattr(ml_dtypes, name))
        except (AttributeError, TypeError):
            raise TypeError(f"unknown dtype name {name!r}") from None


@dataclass(frozen=True)
class TreeCodec:
    """Configured pytree codec; instances are cheap and immutable.

    ``codec`` supplies the per-chunk byte codec (backend, block size, worker
    pool); ``bound`` (a :class:`repro.api.Bound`, default ``Bound.rel(1e-6)``)
    is resolved per leaf; leaves smaller than ``min_compress_elems`` (or of
    non-float dtype) are stored raw in the shared pack frame.  The legacy
    ``(error_bound, mode=)`` ctor kwargs still work (``DeprecationWarning``)
    and keep their historical rel default.
    """

    codec: SZxCodec = field(default_factory=SZxCodec)
    bound: Bound | float | None = None
    chunk_bytes: int = DEFAULT_CHUNK_BYTES
    min_compress_elems: int = 1024
    error_bound: InitVar[float | None] = None
    mode: InitVar[str | None] = None

    def __post_init__(self, error_bound, mode):
        if error_bound is None and mode is None and self.bound is None:
            b = Bound.rel(1e-6)            # the codec's historical default
        else:
            # legacy error_bound= without mode= historically meant 'rel'
            # here (unlike SZxCodec's abs) -- preserve that under the shim
            if error_bound is not None and mode is None:
                mode = "rel"
            b = plan_mod.as_bound(self.bound, mode, error_bound=error_bound,
                                  owner="TreeCodec", stacklevel=4)
        object.__setattr__(self, "bound", b)

    # ------------------------------------------------------------- compress
    def _compressible(self, arr: np.ndarray) -> bool:
        return arr.dtype in plan_mod.BY_DTYPE and arr.size >= self.min_compress_elems

    def compress_tree(self, tree, fileobj, *, _leaf_payloads=None) -> dict:
        """Write ``tree`` as one container-v3 multi-leaf stream; returns the
        stream manifest (the same dict stored in the index footer).

        Layout: frame 0 is the shared raw pack (all small/integer leaves
        back-to-back), then each large float leaf's chunk frames in leaf
        order; the index footer closes the stream.  Peak memory stays
        O(workers * chunk) for the compressed leaves.
        """
        import jax

        if _leaf_payloads is None:
            def _leaf_payloads(arr):
                return self.codec.iter_chunk_payloads(
                    arr, self.bound, chunk_bytes=self.chunk_bytes,
                )

        leaves = [
            (name, np.asarray(jax.device_get(leaf)))
            for name, leaf in leaf_paths(tree)
        ]
        raw_leaves = [(n, a) for n, a in leaves if not self._compressible(a)]
        big_leaves = [(n, a) for n, a in leaves if self._compressible(a)]

        manifest: dict = {
            "v": container.INDEX_VERSION,
            "kind": STREAM_KIND,
            "leaves": [],
            "frames": [],
        }

        # frame 0: shared raw pack, STREAMED leaf by leaf (the payload length
        # is known upfront, so no concatenated in-memory copy is built).
        # Every stream carries this frame -- possibly empty -- so the frame
        # sequence is well-formed even for all-raw or empty trees; it is
        # also the LAST frame when no compressed leaves follow.
        pack_size = sum(int(a.nbytes) for _, a in raw_leaves)
        flags = container.FLAG_RAW | (0 if big_leaves else container.FLAG_LAST)
        header = container.FRAME_HEADER.pack(
            container.FRAME_MAGIC, container.FRAME_VERSION, flags, 0, pack_size
        )
        manifest["frames"].append([0, len(header) + pack_size])
        fileobj.write(header)
        written = len(header)
        inner = 0
        for name, arr in raw_leaves:
            data = arr.tobytes()               # O(leaf), not O(total raw)
            fileobj.write(data)
            manifest["leaves"].append(
                {
                    "name": name,
                    "codec": "raw",
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                    "n": int(arr.size),
                    "raw_bytes": int(arr.nbytes),
                    "stored_bytes": len(data),
                    "frames": [0, 1],
                    "pack": [inner, len(data)],
                }
            )
            inner += len(data)
            written += len(data)
        seq = 1

        # large float leaves: chunked worker pipeline, one frame per chunk;
        # the codec's counted payload stream is the single source of "is this
        # the leaf's final chunk", so the file's LAST flag lands on the final
        # leaf's final frame by construction
        for li, (name, arr) in enumerate(big_leaves):
            lo = seq
            stored = 0
            final_leaf = li == len(big_leaves) - 1
            with obs.span("tree.leaf_encode", leaf=name,
                          elements=int(arr.size)):
                for payload, pl_last in _leaf_payloads(arr):
                    frame = container.build_frame(
                        payload, seq, last=final_leaf and pl_last,
                        stage=self.codec.stage,
                    )
                    manifest["frames"].append([written, len(frame)])
                    fileobj.write(frame)
                    written += len(frame)
                    stored += len(frame)
                    seq += 1
            manifest["leaves"].append(
                {
                    "name": name,
                    "codec": "szx",
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                    "n": int(arr.size),
                    "raw_bytes": int(arr.nbytes),
                    "stored_bytes": stored,
                    "frames": [lo, seq],
                }
            )

        manifest["raw_bytes"] = int(sum(m["raw_bytes"] for m in manifest["leaves"]))
        manifest["stored_bytes"] = written
        fileobj.write(container.build_index_footer(manifest))
        return manifest

    def _sharded_leaf_payloads(
        self, arr: np.ndarray, devices
    ) -> Iterator[tuple[bytes, bool]]:
        """One block-aligned shard per device; shard ``i`` compresses under
        ``jax.default_device(devices[i])`` so its whole device-resident
        encode (transform + stream assembly) runs on that device.  The error
        bound is resolved over the FULL leaf first, so each payload is
        bit-identical to ``compress(shard, e_abs)`` -- the stream layout is
        indistinguishable from a host chunked encode with shard-sized
        chunks, and restores through the ordinary frame path.
        """
        import jax

        spec = plan_mod.spec_for(arr.dtype)
        e = plan_mod.resolve_error_bound(arr, self.bound, spec=spec)
        flat = arr.reshape(-1)
        bs = self.codec.block_size
        ndev = max(len(devices), 1)
        blocks_total = max((flat.size + bs - 1) // bs, 1)
        per = -(-blocks_total // ndev)          # ceil: block-aligned shards
        bounds = [min(i * per * bs, flat.size) for i in range(ndev + 1)]
        shards = [
            (dev, lo, hi)
            for dev, (lo, hi) in zip(devices, zip(bounds, bounds[1:]))
            if hi > lo
        ] or [(devices[0], 0, flat.size)]

        def payload(job) -> bytes:
            dev, lo, hi = job
            with jax.default_device(dev):
                return self.codec.compress(flat[lo:hi], e)

        if self.codec.workers > 1 and len(shards) > 1:
            payloads = _imap_ordered(payload, iter(shards), self.codec.workers)
        else:
            payloads = map(payload, shards)
        for i, pl in enumerate(payloads):
            yield pl, i == len(shards) - 1

    def compress_tree_sharded(self, tree, fileobj, mesh, *, axis: str = "data") -> dict:
        """Sharded :meth:`compress_tree`: each device along mesh ``axis``
        compresses its own block-aligned shard of every large float leaf.

        Shard payloads land in the stream in shard order, so the container
        layout and manifest are structurally identical to a chunked encode
        and :meth:`decompress_tree` restores them unchanged.  Small/raw
        leaves still pack into frame 0 on the host.
        """
        names = list(mesh.axis_names)
        if axis not in names:
            raise ValueError(
                f"mesh has no axis {axis!r} (axes: {tuple(names)})"
            )
        moved = np.moveaxis(np.asarray(mesh.devices), names.index(axis), 0)
        devices = list(moved.reshape(moved.shape[0], -1)[:, 0])
        return self.compress_tree(
            tree, fileobj,
            _leaf_payloads=lambda arr: self._sharded_leaf_payloads(arr, devices),
        )

    # ----------------------------------------------------------- decompress
    def read_manifest(self, fileobj) -> dict:
        idx = container.read_index_footer(fileobj)
        if idx is None:
            raise ValueError(
                "not a TreeCodec stream (no container-v3 index footer)"
            )
        if idx.get("kind") != STREAM_KIND:
            raise ValueError(
                f"not a TreeCodec stream (footer kind {idx.get('kind')!r})"
            )
        return idx

    def _restore_leaf(self, fileobj, idx: dict, meta: dict) -> np.ndarray:
        if not obs.enabled():
            return self._restore_leaf_impl(fileobj, idx, meta)
        with obs.span("tree.leaf_decode", leaf=meta.get("name", "")):
            return self._restore_leaf_impl(fileobj, idx, meta)

    def _restore_leaf_impl(self, fileobj, idx: dict, meta: dict) -> np.ndarray:
        dtype = np_dtype_for(meta["dtype"])
        shape = tuple(meta["shape"])
        if meta["codec"] == "raw":
            frame_off, _len = idx["frames"][meta["frames"][0]]
            inner, size = meta["pack"]
            fileobj.seek(frame_off + container.FRAME_HEADER.size + inner)
            data = container._read_exact(fileobj, size)
            return np.frombuffer(data, dtype=dtype).reshape(shape)
        lo, hi = meta["frames"]
        # preallocated fill: each frame decodes straight into its slice of
        # the output (``out=``), so peak memory stays O(leaf + workers *
        # chunk) with no per-frame result copy
        flat = np.empty(meta["n"], dtype=dtype)

        def jobs() -> Iterator[tuple[bytes, int, int]]:
            off = 0
            for i in range(lo, hi):
                foff, length = idx["frames"][i]
                payload, _flags = container.read_frame_at(fileobj, foff, length, i)
                _code, fn, _e = container.peek_stream_meta(payload)
                if off + fn > flat.size:
                    raise ValueError(
                        f"leaf {meta['name']}: stream has more than the "
                        f"manifest's {meta['n']} elements"
                    )
                yield payload, off, int(fn)
                off += int(fn)

        def decode(job: tuple[bytes, int, int]) -> np.ndarray:
            payload, off, fn = job
            return self.codec.decompress(payload, out=flat[off : off + fn])

        if self.codec.workers > 1 and hi - lo > 1:
            parts = _imap_ordered(decode, jobs(), self.codec.workers)
        else:
            parts = map(decode, jobs())
        filled = 0
        for part in parts:
            filled += part.size
        if filled != flat.size:
            raise ValueError(
                f"leaf {meta['name']}: stream has {filled} elements, "
                f"manifest says {meta['n']}"
            )
        return flat.reshape(shape)

    def decompress_tree(
        self,
        fileobj,
        *,
        select: Iterable[str] | None = None,
        template=None,
    ):
        """Restore leaves from a TreeCodec stream (seekable file object).

        ``select``: iterable of leaf names -- read ONLY those leaves' byte
        ranges (plus the fixed-size index footer); returns ``{name: array}``.
        ``template``: a pytree of arrays/ShapeDtypeStructs -- restore every
        template leaf (by name) and return the filled tree.  With neither,
        returns ``{name: array}`` for every leaf in the stream.
        """
        if select is not None and template is not None:
            raise ValueError("pass select= or template=, not both")
        idx = self.read_manifest(fileobj)
        by_name = {m["name"]: m for m in idx["leaves"]}
        if select is not None:
            select = list(select)
            if len(set(select)) != len(select):
                dupes = sorted({n for n in select if select.count(n) > 1})
                raise ValueError(f"duplicate leaf names in select=: {dupes}")
            out = {}
            for name in select:
                meta = by_name.get(name)
                if meta is None:
                    raise KeyError(f"leaf {name!r} not in stream")
                out[name] = self._restore_leaf(fileobj, idx, meta)
            return out
        if template is not None:
            import jax

            flat, treedef = jax.tree_util.tree_flatten_with_path(template)
            names = [leaf_name(kp) for kp, _ in flat]
            restored = []
            for name in names:
                meta = by_name.get(name)
                if meta is None:
                    raise KeyError(f"leaf {name!r} not in stream")
                restored.append(self._restore_leaf(fileobj, idx, meta))
            return jax.tree_util.tree_unflatten(treedef, restored)
        return {
            m["name"]: self._restore_leaf(fileobj, idx, m) for m in idx["leaves"]
        }
