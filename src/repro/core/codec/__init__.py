"""repro.core.codec -- layered SZx compression (the paper's Algorithm 1 as a
stage pipeline, in the style of cuSZ/FZ-GPU).

Layers:
  plan       -- dtype/error-bound resolution, blocking/padding (Alg. 1 l. 1-2)
  transform  -- fixed-shape block stats / Solution-C shift / XOR-lead /
                byte-plane split, via the kernels.ops dispatch (Alg. 1 l. 3-9)
  container  -- versioned header + section serialization, self-delimiting
                chunk frames (Alg. 1 l. 10, the host compaction boundary)

  device     -- device-resident stream assembly: the fused encode AND the
                byte-layout derivation run on device; a chunk reaches the
                host as ONE device_get (DeviceEncoding, the record shared by
                every consumer)

Front-ends over the same core:
  SZxCodec    -- byte-stream codec (monolithic + chunked streaming,
                 multi-dtype: f32/f64/f16/bf16)
  PlanesCodec -- fixed-shape in-graph codec (gradient / KV-cache compression)
  TreeCodec   -- pytree codec: one multi-leaf container-v3 stream per tree,
                 seekable index footer, select= partial restore
"""
from repro.core.codec import container, device, plan, transform  # noqa: F401
from repro.core.codec.device import DeviceEncoding  # noqa: F401
from repro.core.codec.plan import DEFAULT_BLOCK_SIZE, Bound  # noqa: F401
from repro.core.codec.planes_codec import PlanesCodec  # noqa: F401
from repro.core.codec.tree import TreeCodec  # noqa: F401
from repro.core.codec.szx_codec import (  # noqa: F401
    DEFAULT_CHUNK_BYTES,
    CompressionStats,
    SZxCodec,
    compress,
    compress_with_stats,
    decompress,
)
