"""Byte-stream front-end: SZxCodec (monolithic + chunked streaming).

This is the host-facing API over the plan -> transform -> container pipeline.
``compress``/``decompress`` handle whole arrays; ``compress_chunked`` /
``decompress_chunked`` process arbitrarily large arrays in bounded-memory
chunks, each chunk an independent, self-delimiting frame (the paper's
Fig. 13 checkpoint dump/load use case at scale).  Chunk payloads are
bit-identical to compressing the same slice monolithically, so the chunked
path inherits every error-bound guarantee of the monolithic one.
"""
from __future__ import annotations

import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Iterable, Iterator

import numpy as np

from repro import obs
from repro.core.codec import container, plan as plan_mod, transform
from repro.core.codec.plan import DEFAULT_BLOCK_SIZE, Bound, Plan

DEFAULT_CHUNK_BYTES = 64 << 20     # 64 MB of input per frame


def _imap_ordered(fn: Callable, items: Iterable, workers: int) -> Iterator:
    """Ordered, bounded-lookahead parallel map over a thread pool.

    Results are yielded strictly in input order; at most ``2 * workers`` items
    are in flight, so peak memory stays O(workers * item) no matter how slowly
    the consumer drains.  Frame bodies are numpy-heavy and numpy releases the
    GIL, so threads give real parallelism without pickling the input.
    """
    lookahead = 2 * workers
    with ThreadPoolExecutor(max_workers=workers) as pool:
        pending: deque = deque()
        try:
            for item in items:
                pending.append(pool.submit(fn, item))
                if obs.enabled():
                    obs.gauge("codec.pipeline.queue_depth").set(len(pending))
                if len(pending) >= lookahead:
                    yield pending.popleft().result()
            while pending:
                if obs.enabled():
                    obs.gauge("codec.pipeline.queue_depth").set(len(pending))
                yield pending.popleft().result()
        finally:
            while pending:
                pending.popleft().cancel()


def _validate_select(select) -> list[int]:
    """Normalize a frame selection: integers, strictly increasing, non-empty.

    Out-of-range, duplicate, and unsorted selections all raise a clear
    ValueError here (or in the caller, for the upper range check) instead of
    leaking numpy/IndexError from the read path.
    """
    out = []
    for i in select:
        if isinstance(i, bool) or not isinstance(i, (int, np.integer)):
            raise ValueError(
                f"select= expects integer frame indices, got {i!r}"
            )
        i = int(i)
        if i < 0:
            raise ValueError(f"frame index {i} out of range (negative)")
        if out and i <= out[-1]:
            raise ValueError(
                f"select= must be strictly increasing (got {i} after "
                f"{out[-1]}: duplicates/unsorted selections are ambiguous)"
            )
        out.append(i)
    if not out:
        raise ValueError("empty SZx frame selection")
    return out


@dataclass(frozen=True)
class CompressionStats:
    n: int
    raw_bytes: int
    compressed_bytes: int
    ratio: float
    constant_block_fraction: float
    mean_bytes_per_value: float
    error_bound: float


@dataclass(frozen=True)
class SZxCodec:
    """Configured byte-stream codec; instances are cheap and immutable.

    ``backend`` picks the width-generic kernel implementation for EVERY
    stream dtype (f32/f64/f16/bf16): 'jax' jitted oracle, 'kernel' Pallas,
    'numpy' mirror, or 'auto'; all are bit-identical per dtype.  Each frame
    body stages ONE fused encode program (stats + pack, a single
    host<->device round trip) -- including under ``workers > 1``, where the
    chunked paths' frame bodies run on a thread pool (frames are independent
    and order-tagged); the byte output is identical to the serial path and
    memory stays O(workers * chunk).
    """

    block_size: int = DEFAULT_BLOCK_SIZE
    backend: str = "auto"          # kernels.ops backend (all dtypes)
    workers: int = 1               # threads for compress_chunked/decompress_chunked
    stage: str | int | None = None  # negotiated second stage for chunked frames
                                    # (None | 'bitshuffle-rle' | 'bitshuffle-zstd'
                                    # | 'deflate'; see repro.core.codec.stage)

    def __post_init__(self):
        if self.stage is not None:
            from repro.core.codec import stage as stage_mod

            stage_mod.resolve(self.stage)   # unknown/unavailable -> raises now

    # ------------------------------------------------------------- monolithic
    def compress(self, x, bound: Bound | float | None = None, *,
                 mode: str | None = None, dtype=None,
                 error_bound: float | None = None) -> bytes:
        """Compress an array (f32/f64/f16/bf16) into one v2 stream.

        bound: a :class:`repro.api.Bound` (``Bound.abs(1e-3)`` /
               ``Bound.rel(1e-4)``) or a bare float meaning ``Bound.abs``.
        dtype: optionally force the codec dtype (input is cast first).
        The legacy ``(error_bound, mode=)`` kwargs still work but emit a
        ``DeprecationWarning``.
        """
        b = plan_mod.as_bound(bound, mode, error_bound=error_bound,
                              owner="SZxCodec.compress")
        p, xt = plan_mod.make_plan(
            x, b, block_size=self.block_size, backend=self.backend, dtype=dtype,
        )
        if not obs.enabled():
            return self._compress_planned(xt, p)
        t0 = time.perf_counter()
        with obs.span("codec.compress", n=int(p.n), dtype=p.dtype.name):
            buf = self._compress_planned(xt, p)
        obs.stream_stats.record_compress(buf, time.perf_counter() - t0)
        return buf

    def _compress_planned(self, xt: np.ndarray, p: Plan) -> bytes:
        from repro.kernels import ops

        xb = plan_mod.to_blocks(xt, p)
        if ops._resolve(p.backend) == "numpy":
            # host mirror: encode + serialize entirely in numpy (byte-identical
            # to the device path; this is also the benchmark hot path)
            enc = transform.encode_blocks(xb, p)
            return container.build_stream(p, enc)
        # device backends: fused stats+pack AND layout derivation stay on
        # device; the frame reaches the host as ONE device_get (device.py)
        from repro.core.codec import device

        return device.encode_to_stream(xb, p)

    def decompress(self, buf: bytes, *, out: np.ndarray | None = None) -> np.ndarray:
        """Decompress one v2 stream -> flat array in the stream's dtype.

        On the device backends ('jax'/'kernel', or 'auto' resolving to them)
        the whole decode is device-resident -- ONE ``jax.device_put`` of the
        raw body bytes, on-device section parsing + the fused unpack+compose
        program, one readback (``device.decode_stream``); the numpy backend
        keeps the host mirror.  With ``out`` (a flat (n,) array in the
        stream's dtype) the result is written in place and ``out`` returned.
        """
        if not obs.enabled():
            return self._decompress_impl(buf, out=out)
        t0 = time.perf_counter()
        with obs.span("codec.decompress"):
            res = self._decompress_impl(buf, out=out)
        obs.stream_stats.record_decompress(
            res.nbytes, time.perf_counter() - t0
        )
        return res

    def _decompress_impl(self, buf: bytes, *,
                         out: np.ndarray | None = None) -> np.ndarray:
        from repro.kernels import ops

        if ops._resolve(self.backend) != "numpy":
            from repro.core.codec import device

            res = device.decode_stream(buf, backend=self.backend, out=out)
            if res is not None:
                return res
        p, enc = container.parse_stream(buf, backend=self.backend)
        if out is not None and p.n == p.nblocks * p.block_size:
            transform.decode_blocks(
                enc, p, out=out.reshape(p.nblocks, p.block_size)
            )
            return out
        xb = transform.decode_blocks(enc, p)
        flat = np.asarray(xb).reshape(-1)[: p.n]
        if out is not None:
            np.copyto(out, flat)
            return out
        return flat

    def decompress_range(self, buf: bytes, lo_block: int, hi_block: int) -> np.ndarray:
        """Partial decode of one v2 stream: blocks [lo_block, hi_block) only.

        Returns the flat values covered by those blocks (the trailing padded
        values of the stream's final block are clipped), i.e. elements
        ``[lo_block * bs, min(hi_block * bs, n))`` of ``decompress(buf)`` --
        at O(range) decode cost.  Parsing is still O(stream); callers that
        also want byte reads proportional to the range use the
        section-level API (``repro.store``).  Device backends decode the
        range with the same one-put fused program as :meth:`decompress`.
        """
        if not obs.enabled():
            return self._decompress_range_impl(buf, lo_block, hi_block)
        t0 = time.perf_counter()
        with obs.span("codec.decompress_range", lo=lo_block, hi=hi_block):
            res = self._decompress_range_impl(buf, lo_block, hi_block)
        obs.stream_stats.record_decompress(
            res.nbytes, time.perf_counter() - t0, kind="range"
        )
        return res

    def _decompress_range_impl(self, buf: bytes, lo_block: int,
                               hi_block: int) -> np.ndarray:
        from repro.kernels import ops

        if ops._resolve(self.backend) != "numpy":
            from repro.core.codec import device

            res = device.decode_stream(
                buf, backend=self.backend, block_range=(lo_block, hi_block)
            )
            if res is not None:
                return res
        p, enc = container.parse_stream(buf, backend=self.backend)
        xb = transform.decode_block_range(enc, p, lo_block, hi_block)
        flat = np.asarray(xb).reshape(-1)
        return flat[: min(hi_block * p.block_size, p.n) - lo_block * p.block_size]

    def compress_with_stats(self, x, bound: Bound | float | None = None,
                            **kw) -> tuple[bytes, CompressionStats]:
        buf = self.compress(x, bound, **kw)
        _, _, _, _, n, e, nb, nnc, _ = container.HEADER.unpack_from(buf, 0)
        itemsize = plan_mod.spec_for_code(buf[5]).itemsize
        return buf, CompressionStats(
            n=int(n),
            raw_bytes=itemsize * int(n),
            compressed_bytes=len(buf),
            ratio=itemsize * int(n) / len(buf),
            constant_block_fraction=1.0 - nnc / max(nb, 1),
            mean_bytes_per_value=len(buf) / max(int(n), 1),
            error_bound=float(e),
        )

    # ---------------------------------------------------------------- chunked
    def iter_chunk_payloads(
        self,
        x,
        bound: Bound | float | None = None,
        *,
        mode: str | None = None,
        chunk_bytes: int = DEFAULT_CHUNK_BYTES,
        dtype=None,
        error_bound: float | None = None,
    ) -> Iterator[tuple[bytes, bool]]:
        """Yield ``(payload, is_last)`` covering ``x`` in chunk order.

        The frame-less core of :meth:`compress_chunked` -- and the ONE place
        the chunk count is derived, so every wrapper agrees on which payload
        closes the sequence.  The bound (:class:`Bound` or bare-float abs)
        is resolved over the FULL array first (so ``Bound.rel`` matches the
        monolithic stream -- every chunk carries the same absolute ``e``),
        then each block-aligned chunk is compressed independently; each
        payload is bit-identical to ``compress(chunk, e_abs)``.  With
        ``workers > 1`` the chunk bodies run concurrently but payloads are
        yielded strictly in order.  Callers that interleave several arrays
        into one stream (``TreeCodec``) wrap these in their own frames.
        """
        b = plan_mod.as_bound(bound, mode, error_bound=error_bound,
                              owner="SZxCodec.iter_chunk_payloads")
        x = np.asarray(x)
        if dtype is not None:
            x = x.astype(np.dtype(dtype), copy=False)
        spec = plan_mod.spec_for(x.dtype)
        e = plan_mod.resolve_error_bound(x, b, spec=spec)
        flat = x.reshape(-1)
        per_chunk = plan_mod.chunk_elements(self.block_size, chunk_bytes, spec.itemsize)
        nchunks = max((flat.size + per_chunk - 1) // per_chunk, 1)

        def payload(i: int) -> bytes:
            return self.compress(flat[i * per_chunk : (i + 1) * per_chunk], e)

        if self.workers > 1 and nchunks > 1:
            payloads = _imap_ordered(payload, range(nchunks), self.workers)
        else:
            payloads = map(payload, range(nchunks))
        for i, pl in enumerate(payloads):
            yield pl, i == nchunks - 1

    def compress_chunked(
        self,
        x,
        bound: Bound | float | None = None,
        *,
        mode: str | None = None,
        chunk_bytes: int = DEFAULT_CHUNK_BYTES,
        dtype=None,
        error_bound: float | None = None,
    ) -> Iterator[bytes]:
        """Yield self-delimiting frames covering ``x`` in order.

        Frames wrap :meth:`iter_chunk_payloads` payloads: peak memory is
        O(workers * chunk), each payload bit-identical to the monolithic
        compression of its slice, the byte stream identical for any worker
        count.
        """
        b = plan_mod.as_bound(bound, mode, error_bound=error_bound,
                              owner="SZxCodec.compress_chunked")
        for i, (payload, last) in enumerate(
            self.iter_chunk_payloads(x, b, chunk_bytes=chunk_bytes, dtype=dtype)
        ):
            yield container.build_frame(payload, i, last=last, stage=self.stage)

    def decompress_chunked(self, frames, *, n: int | None = None) -> np.ndarray:
        """Decompress a frame sequence -> flat array.

        ``frames`` may be concatenated bytes, a binary file object, or an
        iterable of frame byte strings (e.g. from :meth:`compress_chunked`).
        Pass ``n`` (the total element count, e.g. from a manifest) to
        preallocate the output and keep peak memory at O(n + workers * chunk):
        each frame's element count is peeked from its header and the frame
        decodes straight into its slice of the output (``out=``), with no
        per-frame result copy -- including under ``workers > 1``.  Without
        ``n`` the decoded chunks are buffered and concatenated, peaking at
        ~2x the output size.  With ``workers > 1`` frame payloads decode
        concurrently; results are consumed strictly in frame order.
        """
        out = None

        def jobs() -> Iterator[tuple[bytes, int, int]]:
            nonlocal out
            spec_code = None
            off = 0
            for payload in container.iter_frames(frames):
                if len(payload) <= 5:
                    raise ValueError("truncated SZx stream (shorter than header)")
                if spec_code is None:
                    spec_code = payload[5]
                    if n is not None:
                        out = np.empty(
                            n, plan_mod.spec_for_code(spec_code).np_dtype
                        )
                elif payload[5] != spec_code:
                    raise ValueError("SZx frame sequence mixes dtypes")
                _code, fn, _e = container.peek_stream_meta(payload)
                if out is not None and off + fn > n:
                    raise ValueError(
                        f"SZx frame sequence longer than expected ({n} elements)"
                    )
                yield payload, off, int(fn)
                off += int(fn)

        def decode(job: tuple[bytes, int, int]) -> np.ndarray:
            payload, off, fn = job
            if out is not None:
                return self.decompress(payload, out=out[off : off + fn])
            return self.decompress(payload)

        if self.workers > 1:
            decoded = _imap_ordered(decode, jobs(), self.workers)
        else:
            decoded = map(decode, jobs())

        parts: list[np.ndarray] = []
        filled = 0
        seen = False
        for part in decoded:
            seen = True
            if out is None:
                parts.append(part)
            filled += part.size
        if not seen:
            raise ValueError("empty SZx frame sequence")
        if out is not None:
            if filled != n:
                raise ValueError(
                    f"SZx frame sequence has {filled} elements, expected {n}"
                )
            return out
        return np.concatenate(parts) if len(parts) > 1 else parts[0]

    def dump_chunked(self, x, fileobj, bound: Bound | float | None = None, *,
                     index: bool = True, **kw) -> int:
        """Stream ``compress_chunked`` frames straight to a file; returns
        bytes written.  Peak memory stays O(workers * chunk).

        With ``index=True`` (the default) a container-v3 footer is appended
        after the LAST frame: per-frame ``[offset, length, elements]`` plus
        the stream totals, enabling random access (``load_chunked`` with
        ``select=``).  ``index=False`` reproduces the footer-less v2 stream.
        """
        x_arr = np.asarray(x)
        written = 0
        frames_idx: list[list[int]] = []
        dtype_code = None
        for frame in self.compress_chunked(x_arr, bound, **kw):
            if index:
                dtype_code, payload_n, _e = container.peek_stream_meta(
                    memoryview(frame)[container.FRAME_HEADER.size:]
                )
                frames_idx.append([written, len(frame), int(payload_n)])
            fileobj.write(frame)
            written += len(frame)
        if index:
            footer = container.build_index_footer(
                {
                    "v": container.INDEX_VERSION,
                    "kind": "szx-chunked",
                    "n": int(x_arr.size),
                    "dtype": dtype_code,
                    "frames": frames_idx,
                }
            )
            fileobj.write(footer)
            written += len(footer)
        return written

    def load_chunked(self, fileobj, *, n: int | None = None,
                     select=None) -> np.ndarray:
        """Read + decompress a frame sequence from a file object.  Pass ``n``
        (total element count) to preallocate: peak memory
        O(n + workers * chunk).

        ``select``: a strictly increasing iterable of in-range frame indices
        -- decode ONLY those frames (concatenated), reading only their byte
        ranges via the container-v3 index footer (requires a seekable stream
        written with ``index=True``; raises ValueError on v2 streams).  A
        present-but-corrupt footer falls back to a sequential decode of the
        whole stream (with a RuntimeWarning), still returning only the
        selected frames' elements.
        """
        if select is None:
            return self.decompress_chunked(fileobj, n=n)
        select = _validate_select(select)
        idx = container.read_index_footer_safe(fileobj)
        if idx is not None and idx.get("kind") != "szx-chunked":
            raise ValueError(
                f"not a single-array chunked stream (footer kind "
                f"{idx.get('kind')!r}); tree streams restore via "
                "TreeCodec.decompress_tree"
            )
        if idx is None:
            # distinguish "no footer was ever written" (v2: select= is a
            # caller error) from "footer present but unreadable" (corrupt:
            # fall back to the sequential decode select= still works on).
            # A valid trailer starts with the SZXI magic in the last 20
            # bytes; a corrupt-but-present footer usually still does.
            end = fileobj.seek(0, 2)
            fileobj.seek(max(end - container.INDEX_TRAILER.size, 0))
            trailer = fileobj.read(container.INDEX_TRAILER.size)
            fileobj.seek(0)
            if container.INDEX_MAGIC not in trailer:
                raise ValueError(
                    "select= needs a container-v3 index footer; this stream "
                    "has none (rewrite it with dump_chunked(..., index=True))"
                )
            wanted = set(select)
            parts = []
            for i, payload in enumerate(container.iter_frames(fileobj)):
                if i in wanted:
                    parts.append(self.decompress(payload))
            if select[-1] >= i + 1:
                raise ValueError(
                    f"frame index {select[-1]} out of range [0, {i + 1})"
                )
            return np.concatenate(parts) if len(parts) > 1 else parts[0]
        frames = idx["frames"]
        parts = []
        for i in select:
            if i >= len(frames):
                raise ValueError(f"frame index {i} out of range [0, {len(frames)})")
            off, length, _elems = frames[i]
            payload, _flags = container.read_frame_at(fileobj, off, length, i)
            parts.append(self.decompress(payload))
        return np.concatenate(parts) if len(parts) > 1 else parts[0]


# functional API (compat shim repro.core.szx re-exports these)
def compress(x, bound: Bound | float | None = None, *, mode: str | None = None,
             block_size: int = DEFAULT_BLOCK_SIZE, backend: str = "auto",
             dtype=None, error_bound: float | None = None) -> bytes:
    b = plan_mod.as_bound(bound, mode, error_bound=error_bound,
                          owner="szx_codec.compress")
    return SZxCodec(block_size, backend).compress(x, b, dtype=dtype)


def decompress(buf: bytes, *, backend: str = "auto") -> np.ndarray:
    return SZxCodec(backend=backend).decompress(buf)


def compress_with_stats(x, bound: Bound | float | None = None, *,
                        mode: str | None = None,
                        block_size: int = DEFAULT_BLOCK_SIZE, backend: str = "auto",
                        dtype=None, error_bound: float | None = None,
                        ) -> tuple[bytes, CompressionStats]:
    b = plan_mod.as_bound(bound, mode, error_bound=error_bound,
                          owner="szx_codec.compress_with_stats")
    return SZxCodec(block_size, backend).compress_with_stats(x, b, dtype=dtype)
