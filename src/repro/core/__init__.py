"""repro.core -- the paper's contribution: SZx ultra-fast error-bounded lossy
compression, as a composable JAX substrate (faithful codec + in-graph planes
codec + gradient/KV-cache integrations)."""

from repro.core import codec, metrics, planes, szx  # noqa: F401
from repro.core.codec import PlanesCodec, SZxCodec  # noqa: F401
from repro.core.szx import (  # noqa: F401
    compress,
    compress_with_stats,
    decompress,
    roundtrip_max_error,
)
