"""SZx (UFZ) -- faithful error-bounded lossy compressor, JAX/TPU-adapted.

Implements Algorithm 1 of the paper end-to-end:
  * fixed-size 1D blocks, constant-block detection via mu = (min+max)/2
  * required-bit computation from the radius/error-bound exponents (Formula 4)
  * Solution-C bitwise right-shift byte alignment (Formula 5)
  * XOR identical-leading-byte elision with a 2-bit/value code
  * variable-length mid-byte stream

The fixed-shape array transforms (block stats, shift, XOR-lead, byte-plane
split) run through ``repro.kernels.ops`` (Pallas kernel or jnp oracle); the
variable-length compaction/serialization is host-side numpy, mirroring how a
TPU deployment would stream fixed-shape kernel output through a host DMA and
compact it on the fly.

Stream layout (little-endian):
  magic 'SZXJ' | version u8 | dtype u8 | block_size u16 | n u64 | e f64
  | nblocks u32 | n_nonconst u32 | nmid u64
  | const bitmap ceil(nb/8) | mu f32*nb | reqlen u8*nnc
  | L 2-bit*(nnc*bs) | mid-byte stream
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np

MAGIC = b"SZXJ"
VERSION = 2
_HDR = struct.Struct("<4sBBHQdIIQ")

DEFAULT_BLOCK_SIZE = 128  # paper Fig. 8: best compression-ratio/PSNR tradeoff


def _to_blocks(x: np.ndarray, bs: int) -> tuple[np.ndarray, int]:
    """Flatten and pad (edge-replicate) to a whole number of blocks."""
    flat = np.asarray(x, np.float32).reshape(-1)
    n = flat.size
    pad = (-n) % bs
    if pad:
        flat = np.concatenate([flat, np.full(pad, flat[-1], np.float32)])
    return flat.reshape(-1, bs), n


def _encode_arrays(xb: np.ndarray, e: float, backend: str):
    """Run the fixed-shape transform; returns numpy arrays."""
    from repro.kernels import ops

    mu, radius, const, reqlen, shift, nbytes = ops.block_stats(xb, e, backend=backend)
    planes, L, mid = ops.pack(xb, mu, shift, nbytes, backend=backend)
    return tuple(np.asarray(a) for a in (mu, const, reqlen, shift, nbytes, planes, L, mid))


def _pack_2bit(codes: np.ndarray) -> np.ndarray:
    """codes: (m,) uint8 in [0,3] -> ceil(m/4) bytes."""
    pad = (-codes.size) % 4
    if pad:
        codes = np.concatenate([codes, np.zeros(pad, np.uint8)])
    c = codes.reshape(-1, 4)
    return (c[:, 0] | (c[:, 1] << 2) | (c[:, 2] << 4) | (c[:, 3] << 6)).astype(np.uint8)


def _unpack_2bit(raw: np.ndarray, m: int) -> np.ndarray:
    b = raw.astype(np.uint8)
    out = np.empty((b.size, 4), np.uint8)
    out[:, 0] = b & 3
    out[:, 1] = (b >> 2) & 3
    out[:, 2] = (b >> 4) & 3
    out[:, 3] = (b >> 6) & 3
    return out.reshape(-1)[:m]


def compress(
    x,
    error_bound: float,
    *,
    mode: str = "abs",
    block_size: int = DEFAULT_BLOCK_SIZE,
    backend: str = "auto",
) -> bytes:
    """Compress an array of float32 values.

    mode: 'abs' -- `error_bound` is the absolute bound e.
          'rel' -- value-range-relative: e = error_bound * (max(x) - min(x)),
                   matching the paper's REL bounds.
    backend: 'auto' | 'jax' | 'kernel' | 'numpy' (see repro.kernels.ops).
    """
    x = np.asarray(x, np.float32)
    if mode == "rel":
        rng = float(x.max() - x.min()) if x.size else 0.0
        e = float(error_bound) * rng
        if e == 0.0:
            e = float(np.finfo(np.float32).tiny)
    elif mode == "abs":
        e = float(error_bound)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    if e <= 0:
        raise ValueError("error bound must be positive")

    xb, n = _to_blocks(x, block_size)
    nb = xb.shape[0]
    mu, const, reqlen, shift, nbytes, planes, L, mid = _encode_arrays(xb, e, backend)

    nc = ~const
    nnc = int(nc.sum())
    # mid-byte mask in (block, value, byteplane) order so each value's bytes
    # are contiguous in the stream (paper Fig. 4 layout)
    planes_t = planes.transpose(0, 2, 1)                        # (nb, bs, 4)
    j = np.arange(4, dtype=np.int32)[None, None, :]
    mask = (L[:, :, None] <= j) & (j < nbytes[:, None, None])
    mask &= nc[:, None, None]
    mid_stream = planes_t[mask]                                  # (nmid,) uint8

    out = [
        _HDR.pack(
            MAGIC, VERSION, 0, block_size, n, e, nb, nnc, int(mid_stream.size)
        ),
        np.packbits(const.astype(np.uint8)).tobytes(),
        mu.astype(np.float32).tobytes(),
        reqlen[nc].astype(np.uint8).tobytes(),
        _pack_2bit(L[nc].reshape(-1).astype(np.uint8)).tobytes(),
        mid_stream.tobytes(),
    ]
    return b"".join(out)


def decompress(buf: bytes, *, backend: str = "auto") -> np.ndarray:
    """Decompress a stream produced by :func:`compress` -> flat float32 array."""
    from repro.kernels import ops

    if len(buf) < _HDR.size:
        raise ValueError("truncated SZx stream")
    magic, version, dtype, bs, n, e, nb, nnc, nmid = _HDR.unpack_from(buf, 0)
    if magic != MAGIC or version != VERSION or dtype != 0:
        raise ValueError("bad SZx stream header")
    off = _HDR.size

    nbm = (nb + 7) // 8
    const = np.unpackbits(np.frombuffer(buf, np.uint8, nbm, off))[:nb].astype(bool)
    off += nbm
    mu = np.frombuffer(buf, np.float32, nb, off).copy()
    off += 4 * nb
    reqlen_nc = np.frombuffer(buf, np.uint8, nnc, off).astype(np.int32)
    off += nnc
    nl = (nnc * bs + 3) // 4
    L_nc = _unpack_2bit(np.frombuffer(buf, np.uint8, nl, off), nnc * bs)
    off += nl
    mid_stream = np.frombuffer(buf, np.uint8, nmid, off)

    nc = ~const
    reqlen = np.zeros(nb, np.int32)
    reqlen[nc] = reqlen_nc
    shift = np.where(const, 0, (8 - reqlen % 8) % 8).astype(np.int32)
    nbytes = np.where(const, 0, (reqlen + shift) // 8).astype(np.int32)
    L = np.zeros((nb, bs), np.int32)
    L[nc] = L_nc.reshape(nnc, bs)

    planes_t = np.zeros((nb, bs, 4), np.uint8)
    j = np.arange(4, dtype=np.int32)[None, None, :]
    mask = (L[:, :, None] <= j) & (j < nbytes[:, None, None])
    mask &= nc[:, None, None]
    planes_t[mask] = mid_stream
    planes = planes_t.transpose(0, 2, 1)

    x = np.asarray(ops.unpack(planes, mu, shift, nbytes, L, backend=backend))
    return x.reshape(-1)[:n]


@dataclass(frozen=True)
class CompressionStats:
    n: int
    raw_bytes: int
    compressed_bytes: int
    ratio: float
    constant_block_fraction: float
    mean_bytes_per_value: float
    error_bound: float


def compress_with_stats(x, error_bound, **kw) -> tuple[bytes, CompressionStats]:
    x = np.asarray(x, np.float32)
    buf = compress(x, error_bound, **kw)
    magic, version, dtype, bs, n, e, nb, nnc, nmid = _HDR.unpack_from(buf, 0)
    return buf, CompressionStats(
        n=int(n),
        raw_bytes=4 * int(n),
        compressed_bytes=len(buf),
        ratio=4.0 * int(n) / len(buf),
        constant_block_fraction=1.0 - nnc / max(nb, 1),
        mean_bytes_per_value=len(buf) / max(int(n), 1),
        error_bound=float(e),
    )


def roundtrip_max_error(x, error_bound, **kw) -> float:
    x = np.asarray(x, np.float32)
    y = decompress(compress(x, error_bound, **kw))
    return float(np.abs(x.reshape(-1) - y).max()) if x.size else 0.0
