"""Compat shim over ``repro.core.codec`` -- the original float32 SZx API.

The monolithic encoder that used to live here was decomposed into the layered
``repro.core.codec`` package (plan / transform / container + SZxCodec /
PlanesCodec front-ends).  This module keeps the old public surface working
unchanged: float32-only byte-stream compression with the exact v2 stream
layout (golden-bytes pinned in tests/test_codec.py).

New code should use :class:`repro.core.codec.SZxCodec`, which adds chunked
streaming and native f64/f16/bf16 support.
"""
from __future__ import annotations

import numpy as np

from repro.core import codec as _codec
from repro.core.codec import container as _container
from repro.core.codec import plan as _plan
from repro.core.codec.szx_codec import CompressionStats  # noqa: F401  (re-export)

MAGIC = _container.MAGIC
VERSION = _container.VERSION
_HDR = _container.HEADER

DEFAULT_BLOCK_SIZE = _plan.DEFAULT_BLOCK_SIZE  # paper Fig. 8 tradeoff


def compress(
    x,
    error_bound: float,
    *,
    mode: str = "abs",
    block_size: int = DEFAULT_BLOCK_SIZE,
    backend: str = "auto",
) -> bytes:
    """Compress an array of float32 values (other dtypes are cast, as the
    original monolith did; use SZxCodec for native multi-dtype streams)."""
    return _codec.compress(
        np.asarray(x, np.float32), error_bound,
        mode=mode, block_size=block_size, backend=backend,
    )


def decompress(buf: bytes, *, backend: str = "auto") -> np.ndarray:
    """Decompress a stream produced by :func:`compress` -> flat float32."""
    return _codec.decompress(buf, backend=backend)


def compress_with_stats(x, error_bound, **kw) -> tuple[bytes, CompressionStats]:
    return _codec.compress_with_stats(np.asarray(x, np.float32), error_bound, **kw)


def roundtrip_max_error(x, error_bound, **kw) -> float:
    x = np.asarray(x, np.float32)
    y = decompress(compress(x, error_bound, **kw))
    return float(np.abs(x.reshape(-1) - y).max()) if x.size else 0.0
