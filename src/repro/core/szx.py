"""LEGACY compat shim over ``repro.core.codec`` -- the original float32 API.

.. deprecated::
    This module is the frozen pre-1.0 surface, kept so old callers and the
    golden-bytes tests keep working unchanged (float32-only, positional
    ``(error_bound, mode=)`` spelling).  New code should import from
    :mod:`repro.api` -- :class:`repro.api.SZxCodec` adds chunked streaming
    and native f64/f16/bf16 support, and takes a :class:`repro.api.Bound`.

The monolithic encoder that used to live here was decomposed into the layered
``repro.core.codec`` package (plan / transform / container + SZxCodec /
PlanesCodec front-ends); byte output is golden-bytes pinned in
tests/test_codec.py.
"""
from __future__ import annotations

import numpy as np

from repro.core import codec as _codec
from repro.core.codec import container as _container
from repro.core.codec import plan as _plan
from repro.core.codec.szx_codec import CompressionStats  # noqa: F401  (re-export)

MAGIC = _container.MAGIC
VERSION = _container.VERSION
_HDR = _container.HEADER

DEFAULT_BLOCK_SIZE = _plan.DEFAULT_BLOCK_SIZE  # paper Fig. 8 tradeoff


def compress(
    x,
    error_bound,
    *,
    mode: str = "abs",
    block_size: int = DEFAULT_BLOCK_SIZE,
    backend: str = "auto",
) -> bytes:
    """Compress an array of float32 values (other dtypes are cast, as the
    original monolith did; use repro.api.SZxCodec for native multi-dtype
    streams).  ``error_bound`` may also be a :class:`repro.api.Bound`."""
    b = error_bound if isinstance(error_bound, _plan.Bound) \
        else _plan.Bound(float(error_bound), mode)
    return _codec.compress(
        np.asarray(x, np.float32), b, block_size=block_size, backend=backend,
    )


def decompress(buf: bytes, *, backend: str = "auto") -> np.ndarray:
    """Decompress a stream produced by :func:`compress` -> flat float32."""
    return _codec.decompress(buf, backend=backend)


def compress_with_stats(x, error_bound, *, mode: str = "abs",
                        **kw) -> tuple[bytes, CompressionStats]:
    b = error_bound if isinstance(error_bound, _plan.Bound) \
        else _plan.Bound(float(error_bound), mode)
    return _codec.compress_with_stats(np.asarray(x, np.float32), b, **kw)


def roundtrip_max_error(x, error_bound, **kw) -> float:
    x = np.asarray(x, np.float32)
    y = decompress(compress(x, error_bound, **kw))
    return float(np.abs(x.reshape(-1) - y).max()) if x.size else 0.0
