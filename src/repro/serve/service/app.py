"""Production store service: asyncio HTTP tier over the array store.

Architecture: :class:`StoreService` is a SYNCHRONOUS request core (route ->
:class:`Response`), shared verbatim by three frontends -- the stdlib
``asyncio.start_server`` HTTP/1.1 server (:class:`HttpServer`, the default),
a uvicorn-compatible ASGI adapter (:func:`asgi_app`, optional, no hard
dependency), and direct in-process calls (tests).  CPU-bound work (chunk
decode) runs on the event loop's default thread-pool executor, so the
accept/parse path never blocks behind a decode.

Endpoints (all GET/HEAD):

    /v1/                               service + store summary (JSON)
    /v1/metrics                        cache hit/miss/eviction counters,
                                       per-route latency, per-tenant usage
    /v1/stores                         registered store names
    /v1/stores/{name}/info             geometry of the CURRENT file (410 if
                                       the backing file vanished)
    /v1/stores/{name}/read?roi=...     decoded ROI; ETag + If-None-Match/304
    /v1/stores/{name}/stats[?header_only=1]   compressed-domain query
    /v1/stores/{name}/raw[?shard=i]    compressed file bytes; Range/206
    /v1/stores/{name}/chunk/{cid}      one chunk's compressed frame; 307
                                       redirect when a remote shard owns it
    /info /stats /read                 legacy single-store aliases (default
                                       store), response shapes unchanged

Every decoded ROI is assembled from the shared decoded-chunk LRU cache
(:mod:`.cache`): hot chunks decode once and serve every reader.  ETags are
strong (container footer CRC, :func:`.registry.compute_etag`), so CDN and
client caches revalidate with If-None-Match for free.  Errors are JSON
envelopes ``{"error": {"code", "message"}}`` (legacy routes keep their flat
``{"error": msg}`` shape).

Tenancy: requests carry an optional ``X-Tenant`` header (default
``"anonymous"``); the registry enforces per-tenant request/byte quotas
(429 when spent).
"""
from __future__ import annotations

import asyncio
import json
import os
import socket
import threading
import time
import urllib.parse
from dataclasses import dataclass, field

from repro import obs
from repro.serve.service.cache import LRUBytesCache
from repro.serve.service.metrics import Metrics
from repro.serve.service.registry import (
    QuotaExceeded,
    StoreGone,
    StoreNotFound,
    StoreRegistry,
)
from repro.store.grid import parse_roi

_REASONS = {
    200: "OK", 206: "Partial Content", 304: "Not Modified",
    307: "Temporary Redirect", 400: "Bad Request", 404: "Not Found",
    405: "Method Not Allowed", 410: "Gone", 416: "Range Not Satisfiable",
    429: "Too Many Requests", 500: "Internal Server Error",
}


@dataclass
class Response:
    status: int
    body: bytes = b""
    headers: list = field(default_factory=list)
    content_type: str = "application/octet-stream"


def _json_response(status: int, payload, headers: list | None = None) -> Response:
    return Response(
        status, json.dumps(payload).encode(), headers or [],
        "application/json",
    )


def _error(status: int, message: str, *, legacy: bool = False) -> Response:
    payload = {"error": message} if legacy else \
        {"error": {"code": status, "message": message}}
    return _json_response(status, payload)


class _HandledError(Exception):
    """Internal control flow: carries a finished error Response."""

    def __init__(self, resp: Response):
        self.resp = resp


class StoreService:
    """The synchronous request core shared by every frontend."""

    def __init__(self, *, backend: str = "numpy",
                 cache_bytes: int = 256 << 20,
                 quota_requests: int | None = None,
                 quota_bytes: int | None = None):
        self.cache = LRUBytesCache(cache_bytes)
        self.registry = StoreRegistry(
            backend=backend, cache=self.cache,
            quota_requests=quota_requests, quota_bytes=quota_bytes,
        )
        self.metrics = Metrics()
        self.default_store: str | None = None

    def add_store(self, name: str, path) -> None:
        self.registry.add(name, path)
        if self.default_store is None:
            self.default_store = name

    def close(self) -> None:
        self.registry.close()

    # ------------------------------------------------------------ dispatch
    def handle(self, method: str, target: str, headers: dict) -> Response:
        """One request -> one Response.  ``headers`` keys are lower-case."""
        t0 = time.perf_counter()
        url = urllib.parse.urlsplit(target)
        q = urllib.parse.parse_qs(url.query)
        tenant = headers.get("x-tenant", "anonymous")
        route = url.path
        try:
            if method not in ("GET", "HEAD"):
                resp = _error(405, f"method {method} not allowed")
            else:
                with obs.span("serve.request", route=route):
                    self.registry.charge(tenant, requests=1)
                    resp = self._route(url.path, q, headers)
                self.registry.charge(tenant, nbytes=len(resp.body))
        except _HandledError as err:
            resp = err.resp
        except QuotaExceeded as err:
            resp = _error(429, str(err))
        except StoreNotFound as err:
            resp = _error(404, f"unknown store {err.args[0]!r}")
        except StoreGone as err:
            resp = _error(410, str(err))
        except (ValueError, TypeError, IndexError, KeyError) as err:
            legacy = not url.path.startswith("/v1/")
            resp = _error(400, str(err), legacy=legacy)
        resp.headers = [("Content-Type", resp.content_type)] + resp.headers
        self.metrics.observe(
            route, resp.status, time.perf_counter() - t0, len(resp.body),
            tenant,
        )
        return resp

    def _route(self, path: str, q: dict, headers: dict) -> Response:
        if path in ("/v1", "/v1/"):
            return self._summary()
        if path == "/v1/metrics":
            return self._metrics(headers)
        if path == "/v1/stores":
            return _json_response(200, {"stores": self.registry.names()})
        if path.startswith("/v1/stores/"):
            rest = path[len("/v1/stores/"):]
            name, _, verb = rest.partition("/")
            if verb == "info":
                return self._info(name, headers)
            if verb == "read":
                return self._read(name, q, headers)
            if verb == "stats":
                return self._stats(name, q)
            if verb == "raw":
                return self._raw(name, q, headers)
            if verb.startswith("chunk/"):
                return self._chunk(name, verb[len("chunk/"):], headers)
            raise _HandledError(_error(404, f"unknown path {path}"))
        # ------------------------------------------- legacy single-store API
        if self.default_store is not None:
            if path == "/info":
                return self._info(self.default_store, headers, legacy=True)
            if path == "/stats":
                return self._stats(self.default_store, q)
            if path == "/read":
                return self._read(self.default_store, q, headers)
        raise _HandledError(
            _error(404, f"unknown path {path}",
                   legacy=not path.startswith("/v1/"))
        )

    # ------------------------------------------------------------ endpoints
    def _summary(self) -> Response:
        stores = {}
        for name in self.registry.names():
            entry = self.registry.entry(name)
            try:
                with entry.acquire() as (ca, etag):
                    stores[name] = {
                        "shape": list(ca.shape), "dtype": ca.dtype.name,
                        "etag": etag,
                        "sharded": entry.path.endswith(".json"),
                    }
            except StoreGone:
                stores[name] = {"gone": True}
        return _json_response(200, {
            "service": "repro-store", "api": "v1", "stores": stores,
            "endpoints": [
                "/v1/metrics", "/v1/stores",
                "/v1/stores/{name}/info", "/v1/stores/{name}/read?roi=...",
                "/v1/stores/{name}/stats", "/v1/stores/{name}/raw",
                "/v1/stores/{name}/chunk/{cid}",
            ],
        })

    def _metrics(self, headers: dict | None = None) -> Response:
        """JSON snapshot (default, schema unchanged) or -- with
        ``Accept: text/plain`` -- the shared registry's Prometheus text
        exposition, which includes codec/store/cache series when telemetry
        is enabled."""
        cache_stats = self.cache.stats()
        if obs.enabled():
            for k, v in cache_stats.items():
                if isinstance(v, (int, float)):
                    obs.gauge("serve.cache", stat=k).set(v)
        accept = (headers or {}).get("accept", "")
        if "text/plain" in accept:
            return Response(
                200, obs.prometheus_text().encode(), [],
                "text/plain; version=0.0.4; charset=utf-8",
            )
        snap = self.metrics.snapshot()
        snap["cache"] = cache_stats
        if obs.enabled():
            # additive key: shared-registry view (codec/store/ingest series)
            snap["obs"] = obs.REGISTRY.snapshot()
        return _json_response(200, snap)

    @staticmethod
    def _not_modified(headers: dict, etag: str) -> bool:
        inm = headers.get("if-none-match")
        if inm is None:
            return False
        return inm.strip() == "*" or etag in [
            t.strip() for t in inm.split(",")
        ]

    def _info(self, name: str, headers: dict, *, legacy: bool = False
              ) -> Response:
        entry = self.registry.entry(name)
        # served from the CURRENT handle (revalidated against the file), so
        # replacing the store file is reflected immediately and a vanished
        # file answers 410 -- not the stale startup snapshot
        with entry.acquire() as (ca, etag):
            if self._not_modified(headers, etag):
                return Response(304, b"", [("ETag", etag)])
            meta = {
                "shape": list(ca.shape),
                "chunk_shape": list(ca.chunk_shape),
                "dtype": ca.dtype.name,
                "e": ca.error_bound,
                "nchunks": ca.nchunks,
                "raw_bytes": ca.nbytes,
                "stored_bytes": ca.stored_bytes,
            }
            if not legacy:
                meta.update(
                    name=name, etag=etag, attrs=ca.attrs,
                    sharded=entry.path.endswith(".json"),
                )
            return _json_response(200, meta, [("ETag", etag)])

    def _read(self, name: str, q: dict, headers: dict) -> Response:
        roi = parse_roi(q.get("roi", [None])[0])
        entry = self.registry.entry(name)
        with entry.acquire() as (ca, etag):
            if self._not_modified(headers, etag):
                return Response(304, b"", [("ETag", etag)])
            out = ca[roi]
            return Response(200, out.tobytes(), [
                ("ETag", etag),
                ("X-Dtype", out.dtype.name),
                ("X-Shape", ",".join(map(str, out.shape))),
            ])

    def _stats(self, name: str, q: dict) -> Response:
        header_only = q.get("header_only", ["0"])[0] not in ("0", "")
        entry = self.registry.entry(name)
        with entry.acquire() as (ca, _etag):
            return _json_response(200, ca.stats(header_only=header_only).to_dict())

    def _raw_target(self, entry, q: dict) -> str:
        """Resolve the raw byte target: the store file, or one shard."""
        man = entry.manifest()
        if man is None:
            if "shard" in q:
                raise ValueError("single-file store has no shards")
            return entry.path
        si = int(q.get("shard", ["0"])[0])
        shards = man["shards"]
        if not 0 <= si < len(shards):
            raise ValueError(f"shard {si} out of range [0, {len(shards)})")
        loc = str(shards[si]["file"])
        if "://" in loc:
            raise _HandledError(Response(
                307, b"", [("Location", loc)], "text/plain",
            ))
        return os.path.join(os.path.dirname(entry.path), loc)

    def _raw(self, name: str, q: dict, headers: dict) -> Response:
        """Compressed byte ranges -- the CDN-cacheable path.  ``Range:
        bytes=lo-hi`` serves 206 with ``Content-Range``; a syntactically
        valid but unsatisfiable range serves 416."""
        entry = self.registry.entry(name)
        # etag WITHOUT a decode handle: raw bytes must stay servable for
        # manifests whose other shards live behind URLs
        etag = entry.etag_only()
        target = self._raw_target(entry, q)
        if self._not_modified(headers, etag):
            return Response(304, b"", [("ETag", etag)])
        try:
            size = os.path.getsize(target)
        except FileNotFoundError:
            raise StoreGone(
                f"store {name!r}: shard file {target} vanished"
            ) from None
        rng = headers.get("range")
        base = [("ETag", etag), ("Accept-Ranges", "bytes")]
        if rng is None:
            with open(target, "rb") as f:
                return Response(200, f.read(), base)
        lo, hi = _parse_range(rng, size)
        if lo is None:
            return Response(416, b"", base + [
                ("Content-Range", f"bytes */{size}"),
            ])
        with open(target, "rb") as f:
            f.seek(lo)
            body = f.read(hi - lo + 1)
        return Response(206, body, base + [
            ("Content-Range", f"bytes {lo}-{hi}/{size}"),
        ])

    def _chunk(self, name: str, cid_text: str, headers: dict) -> Response:
        """One chunk's compressed frame bytes (random access by chunk id).
        When a REMOTE shard owns the chunk, answer 307 to the shard URL with
        the frame's byte range in ``X-Chunk-Offset``/``X-Chunk-Length`` so
        the client can Range-request it there."""
        cid = int(cid_text)
        entry = self.registry.entry(name)
        man = entry.manifest()
        if man is not None:
            for sh in man["shards"]:
                lo, hi = (int(v) for v in sh["chunks"])
                if lo <= cid < hi:
                    off, length, _elems = (
                        int(v) for v in sh["frames"][cid - lo]
                    )
                    loc = str(sh["file"])
                    if "://" in loc:
                        return Response(307, b"", [
                            ("Location", loc),
                            ("X-Chunk-Offset", str(off)),
                            ("X-Chunk-Length", str(length)),
                        ], "text/plain")
                    path = os.path.join(os.path.dirname(entry.path), loc)
                    etag = entry.etag_only()
                    if self._not_modified(headers, etag):
                        return Response(304, b"", [("ETag", etag)])
                    with open(path, "rb") as f:
                        f.seek(off)
                        body = f.read(length)
                    return Response(200, body, [("ETag", etag)])
            raise ValueError(f"chunk {cid} out of range")
        with entry.acquire() as (ca, etag):
            if self._not_modified(headers, etag):
                return Response(304, b"", [("ETag", etag)])
            if not 0 <= cid < ca.nchunks:
                raise ValueError(
                    f"chunk {cid} out of range [0, {ca.nchunks})"
                )
            off, length, _elems = (int(v) for v in ca._frames[cid])
            f = ca._src(cid)
            f.seek(off)
            body = f.read(length)
        return Response(200, body, [("ETag", etag)])


def _parse_range(text: str, size: int):
    """One ``bytes=lo-hi`` range -> inclusive (lo, hi), or (None, None) when
    unsatisfiable.  Malformed syntax raises ValueError (-> 400); suffix form
    ``bytes=-N`` and open end ``bytes=lo-`` follow RFC 9110."""
    unit, _, spec = text.partition("=")
    if unit.strip() != "bytes" or "," in spec:
        raise ValueError(f"unsupported Range {text!r}")
    lo_s, dash, hi_s = spec.strip().partition("-")
    if not dash:
        raise ValueError(f"malformed Range {text!r}")
    if not lo_s:                         # suffix: last N bytes
        n = int(hi_s)
        if n == 0:
            return None, None
        return max(size - n, 0), size - 1
    lo = int(lo_s)
    hi = int(hi_s) if hi_s else size - 1
    if lo >= size or hi < lo:
        return None, None
    return lo, min(hi, size - 1)


# ---------------------------------------------------------------- asyncio tier
class HttpServer:
    """stdlib-asyncio HTTP/1.1 frontend with the ThreadingHTTPServer-ish
    lifecycle the existing callers/tests expect: bind in the constructor
    (``server_address`` is known immediately), blocking ``serve_forever``
    on any thread, thread-safe ``shutdown()``, idempotent ``server_close``.
    """

    def __init__(self, service: StoreService, host: str = "127.0.0.1",
                 port: int = 0):
        self.service = service
        self._sock = socket.create_server((host, port))
        self.server_address = self._sock.getsockname()[:2]
        self._loop: asyncio.AbstractEventLoop | None = None
        self._started = threading.Event()
        self._done = threading.Event()
        self._stop: asyncio.Event | None = None

    def serve_forever(self) -> None:
        try:
            asyncio.run(self._main())
        finally:
            self._done.set()

    async def _main(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._stop = asyncio.Event()
        server = await asyncio.start_server(self._client, sock=self._sock)
        self._started.set()
        try:
            await self._stop.wait()
        finally:
            server.close()
            await server.wait_closed()

    def shutdown(self) -> None:
        """Stop serve_forever from any thread; returns when it exited."""
        if not self._started.is_set():
            return
        self._loop.call_soon_threadsafe(self._stop.set)
        self._done.wait()

    def server_close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
        self.service.close()

    async def _client(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        loop = asyncio.get_running_loop()
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                line = line.strip()
                if not line:
                    continue
                try:
                    method, target, version = line.decode("latin1").split()
                except ValueError:
                    break
                headers: dict[str, str] = {}
                while True:
                    h = await reader.readline()
                    if h in (b"\r\n", b"\n", b""):
                        break
                    k, _, v = h.decode("latin1").partition(":")
                    headers[k.strip().lower()] = v.strip()
                # GET/HEAD only: any request body is unread by design
                resp = await loop.run_in_executor(
                    None, self.service.handle, method, target, headers,
                )
                keep = (version == "HTTP/1.1"
                        and headers.get("connection", "").lower() != "close")
                body = b"" if method == "HEAD" else resp.body
                out = [f"HTTP/1.1 {resp.status} "
                       f"{_REASONS.get(resp.status, 'Unknown')}\r\n"]
                for k, v in resp.headers:
                    out.append(f"{k}: {v}\r\n")
                out.append(f"Content-Length: {len(resp.body)}\r\n")
                out.append(
                    f"Connection: {'keep-alive' if keep else 'close'}\r\n\r\n"
                )
                writer.write("".join(out).encode("latin1") + body)
                await writer.drain()
                if not keep:
                    break
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass


def asgi_app(service: StoreService):
    """Uvicorn-compatible ASGI 3 adapter over the same request core.

        uvicorn "my_module:app"   where   app = asgi_app(service)

    Optional: nothing imports this unless an ASGI server is in play, so the
    service keeps zero non-stdlib dependencies.
    """

    async def app(scope, receive, send):
        if scope["type"] == "lifespan":          # accept startup/shutdown
            while True:
                msg = await receive()
                if msg["type"] == "lifespan.startup":
                    await send({"type": "lifespan.startup.complete"})
                elif msg["type"] == "lifespan.shutdown":
                    await send({"type": "lifespan.shutdown.complete"})
                    return
            return
        if scope["type"] != "http":
            raise RuntimeError(f"unsupported ASGI scope {scope['type']!r}")
        while True:                              # drain any request body
            msg = await receive()
            if msg["type"] != "http.request" or not msg.get("more_body"):
                break
        target = scope["path"]
        if scope.get("query_string"):
            target += "?" + scope["query_string"].decode("latin1")
        headers = {
            k.decode("latin1").lower(): v.decode("latin1")
            for k, v in scope.get("headers", [])
        }
        loop = asyncio.get_running_loop()
        resp = await loop.run_in_executor(
            None, service.handle, scope["method"], target, headers,
        )
        await send({
            "type": "http.response.start",
            "status": resp.status,
            "headers": [
                (k.encode("latin1"), v.encode("latin1"))
                for k, v in resp.headers
            ] + [(b"content-length", str(len(resp.body)).encode())],
        })
        body = b"" if scope["method"] == "HEAD" else resp.body
        await send({"type": "http.response.body", "body": body})

    return app
