"""Production store serving tier.

- :mod:`.app` -- the synchronous request core (:class:`~.app.StoreService`),
  the stdlib-asyncio HTTP frontend (:class:`~.app.HttpServer`) and the
  optional ASGI adapter (:func:`~.app.asgi_app`).
- :mod:`.cache` -- size-bounded decoded-chunk LRU shared by all stores.
- :mod:`.registry` -- named stores, revalidating handles, ETags, quotas.
- :mod:`.metrics` -- request counters and latency percentiles.
"""
from repro.serve.service.app import HttpServer, StoreService, asgi_app
from repro.serve.service.cache import LRUBytesCache
from repro.serve.service.metrics import Metrics
from repro.serve.service.registry import (
    QuotaExceeded,
    StoreGone,
    StoreNotFound,
    StoreRegistry,
    compute_etag,
)

__all__ = [
    "HttpServer",
    "LRUBytesCache",
    "Metrics",
    "QuotaExceeded",
    "StoreGone",
    "StoreNotFound",
    "StoreRegistry",
    "StoreService",
    "asgi_app",
    "compute_etag",
]
