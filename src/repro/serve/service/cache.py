"""Size-bounded LRU cache for decoded chunk ranges.

The serving tier's working-set memory: hot chunks decode ONCE and serve
many readers.  Keys are ``(namespace, chunk_id, lo_block, hi_block)`` where
the namespace encodes store identity AND content version (the registry uses
the store's ETag, so replacing a store file on disk implicitly invalidates
every cached chunk of the old bytes -- no explicit flush protocol).

Thread-safe: one mutex around the OrderedDict; get/put are O(1).  Values
are read-only numpy arrays shared by reference between concurrent readers
-- the budget bounds decoded bytes held, not entry count.  Counters
(hits/misses/evictions) are served at ``/v1/metrics``.
"""
from __future__ import annotations

import threading
from collections import OrderedDict


class LRUBytesCache:
    """LRU keyed mapping bounded by total value bytes, with hit counters."""

    def __init__(self, max_bytes: int = 256 << 20):
        if max_bytes < 0:
            raise ValueError("cache budget must be >= 0")
        self.max_bytes = int(max_bytes)
        self._lock = threading.Lock()
        self._data: OrderedDict = OrderedDict()   # key -> (value, nbytes)
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key):
        with self._lock:
            entry = self._data.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return entry[0]

    def put(self, key, value, nbytes: int) -> None:
        nbytes = int(nbytes)
        with self._lock:
            old = self._data.pop(key, None)
            if old is not None:
                self._bytes -= old[1]
            if nbytes > self.max_bytes:
                # value alone busts the budget: don't thrash the whole cache
                return
            self._data[key] = (value, nbytes)
            self._bytes += nbytes
            while self._bytes > self.max_bytes:
                _k, (_v, nb) = self._data.popitem(last=False)
                self._bytes -= nb
                self.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self._bytes = 0

    @property
    def nbytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._data)

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._data),
                "bytes": self._bytes,
                "max_bytes": self.max_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0,
            }
