"""Request counters and latency percentiles for the store service.

Lock-guarded in-process counters plus a bounded ring of recent request
latencies per route class; the ``/v1/metrics`` endpoint serves
``snapshot()``.  Percentiles are computed over the ring at snapshot time
(the ring is small), so the hot path cost is one append under a mutex.

Every observation is also mirrored into the shared :mod:`repro.obs`
registry (``serve.*`` series) when telemetry is enabled, so the service
shows up in the same Prometheus exposition / Chrome trace as the codec
and store layers.  The local snapshot schema is unchanged.
"""
from __future__ import annotations

import math
import threading
from collections import defaultdict, deque

from repro import obs


class Metrics:
    def __init__(self, window: int = 2048):
        self._lock = threading.Lock()
        self._window = window
        self.requests = 0
        self.errors = 0
        self.bytes_sent = 0
        self.by_route: dict[str, int] = defaultdict(int)
        self.by_status: dict[int, int] = defaultdict(int)
        self.by_tenant: dict[str, dict] = defaultdict(
            lambda: {"requests": 0, "bytes": 0}
        )
        self._lat: dict[str, deque] = defaultdict(
            lambda: deque(maxlen=self._window)
        )

    def observe(self, route: str, status: int, seconds: float,
                nbytes: int, tenant: str | None = None) -> None:
        with self._lock:
            self.requests += 1
            self.bytes_sent += nbytes
            self.by_route[route] += 1
            self.by_status[status] += 1
            if status >= 400:
                self.errors += 1
            if tenant is not None:
                t = self.by_tenant[tenant]
                t["requests"] += 1
                t["bytes"] += nbytes
            self._lat[route].append(seconds)
        if obs.enabled():
            obs.counter("serve.requests", route=route).inc()
            obs.counter("serve.responses", status=str(status)).inc()
            obs.counter("serve.bytes_sent").inc(nbytes)
            if status >= 400:
                obs.counter("serve.errors").inc()
            if tenant is not None:
                obs.counter("serve.tenant_requests", tenant=tenant).inc()
            obs.histogram("serve.request_seconds", route=route).observe(seconds)

    @staticmethod
    def _pct(samples: list[float], q: float) -> float:
        """Nearest-rank (ceil) percentile: the smallest sample s such that at
        least ``q`` of the samples are <= s.  The previous round-half-up rank
        over-shot on small windows (p50 of [10,20,30,40] gave 30, not 20)."""
        if not samples:
            return 0.0
        samples = sorted(samples)
        idx = max(math.ceil(q * len(samples)), 1) - 1
        return samples[min(idx, len(samples) - 1)]

    def snapshot(self) -> dict:
        with self._lock:
            lat = {
                route: {
                    "count": len(d),
                    "p50_ms": self._pct(list(d), 0.50) * 1e3,
                    "p99_ms": self._pct(list(d), 0.99) * 1e3,
                }
                for route, d in self._lat.items()
            }
            return {
                "requests": self.requests,
                "errors": self.errors,
                "bytes_sent": self.bytes_sent,
                "by_route": dict(self.by_route),
                "by_status": {str(k): v for k, v in self.by_status.items()},
                "by_tenant": {k: dict(v) for k, v in self.by_tenant.items()},
                "latency": lat,
            }
