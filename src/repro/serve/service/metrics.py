"""Request counters and latency percentiles for the store service.

Lock-guarded in-process counters plus a bounded ring of recent request
latencies per route class; the ``/v1/metrics`` endpoint serves
``snapshot()``.  Percentiles are computed over the ring at snapshot time
(the ring is small), so the hot path cost is one append under a mutex.
"""
from __future__ import annotations

import threading
from collections import defaultdict, deque


class Metrics:
    def __init__(self, window: int = 2048):
        self._lock = threading.Lock()
        self._window = window
        self.requests = 0
        self.errors = 0
        self.bytes_sent = 0
        self.by_route: dict[str, int] = defaultdict(int)
        self.by_status: dict[int, int] = defaultdict(int)
        self.by_tenant: dict[str, dict] = defaultdict(
            lambda: {"requests": 0, "bytes": 0}
        )
        self._lat: dict[str, deque] = defaultdict(
            lambda: deque(maxlen=self._window)
        )

    def observe(self, route: str, status: int, seconds: float,
                nbytes: int, tenant: str | None = None) -> None:
        with self._lock:
            self.requests += 1
            self.bytes_sent += nbytes
            self.by_route[route] += 1
            self.by_status[status] += 1
            if status >= 400:
                self.errors += 1
            if tenant is not None:
                t = self.by_tenant[tenant]
                t["requests"] += 1
                t["bytes"] += nbytes
            self._lat[route].append(seconds)

    @staticmethod
    def _pct(samples: list[float], q: float) -> float:
        if not samples:
            return 0.0
        samples = sorted(samples)
        i = min(int(q * (len(samples) - 1) + 0.5), len(samples) - 1)
        return samples[i]

    def snapshot(self) -> dict:
        with self._lock:
            lat = {
                route: {
                    "count": len(d),
                    "p50_ms": self._pct(list(d), 0.50) * 1e3,
                    "p99_ms": self._pct(list(d), 0.99) * 1e3,
                }
                for route, d in self._lat.items()
            }
            return {
                "requests": self.requests,
                "errors": self.errors,
                "bytes_sent": self.bytes_sent,
                "by_route": dict(self.by_route),
                "by_status": {str(k): v for k, v in self.by_status.items()},
                "by_tenant": {k: dict(v) for k, v in self.by_tenant.items()},
                "latency": lat,
            }
