"""Multi-store registry: named stores, revalidating handles, ETags, quotas.

Each registered store is opened lazily and REVALIDATED on every access
against the backing file's ``(mtime_ns, size, inode)`` signature: replacing
the file atomically swaps in a fresh handle (and a fresh ETag, which also
namespaces the decoded-chunk cache -- stale entries die by key, not by
flush), and a vanished file raises :class:`StoreGone` so the service
answers 410 instead of serving stale startup metadata.

ETags are STRONG validators derived from the container index footer's
CRC32 (the trailer field that already authenticates the index) plus the
file size; a sharded store's ETag is the CRC32 of its manifest JSON.  Two
byte-identical stores get the same ETag; any content change flips it.

Per-tenant quotas are cumulative request/byte budgets checked before the
work is done; exceeding one raises :class:`QuotaExceeded` (served as 429).
"""
from __future__ import annotations

import os
import threading
import zlib
from contextlib import contextmanager

from repro.core.codec import container
from repro.store.array import ArrayStore


class StoreNotFound(KeyError):
    """No store registered under that name (-> 404)."""


class StoreGone(RuntimeError):
    """The store's backing file vanished after registration (-> 410)."""


class QuotaExceeded(RuntimeError):
    """The tenant's request or byte budget is spent (-> 429)."""


def compute_etag(path: str) -> str:
    """Strong ETag of a store file or manifest (no full-file read).

    Store files: the index trailer's CRC32 over the footer JSON -- one
    fixed-size read at the tail.  Manifests (or any file without a trailer):
    CRC32 of the file bytes (manifests are small).
    """
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        if size >= container.INDEX_TRAILER.size:
            f.seek(size - container.INDEX_TRAILER.size)
            tail = f.read(container.INDEX_TRAILER.size)
            magic, _v, _flags, _res, _length, crc = \
                container.INDEX_TRAILER.unpack(tail)
            if magic == container.INDEX_MAGIC:
                return f'"{crc:08x}-{size:x}"'
        f.seek(0)
        crc = zlib.crc32(f.read())
    return f'"{crc:08x}-{size:x}"'


def _sig(path: str):
    st = os.stat(path)
    return (st.st_mtime_ns, st.st_size, st.st_ino)


class _Entry:
    """One registered store: path + revalidated handle + ETag."""

    def __init__(self, name: str, path: str, *, backend: str, cache):
        self.name = name
        self.path = os.fspath(path)
        self.backend = backend
        self.cache = cache
        self.lock = threading.Lock()   # CompressedArray is not thread-safe
        self._handle = None
        self._sig = None
        self.etag = None

    def _revalidate_locked(self) -> None:
        try:
            sig = _sig(self.path)
        except FileNotFoundError:
            if self._handle is not None:
                self._handle.close()
                self._handle = None
            raise StoreGone(
                f"store {self.name!r}: backing file {self.path} vanished"
            ) from None
        if self._handle is not None and sig == self._sig:
            return
        if self._handle is not None:
            self._handle.close()
            self._handle = None
        etag = compute_etag(self.path)
        self._handle = ArrayStore.open(
            self.path, backend=self.backend, cache=self.cache,
            cache_ns=f"{self.name}:{etag}",
        )
        self._sig = sig
        self.etag = etag

    @contextmanager
    def acquire(self):
        """Exclusive access to the CURRENT handle: ``(array, etag)``.

        Exclusive because a CompressedArray carries one seek cursor per
        file; the decoded-chunk cache in front of it is what concurrent
        readers actually share.
        """
        with self.lock:
            self._revalidate_locked()
            yield self._handle, self.etag

    def etag_only(self) -> str:
        """The current ETag WITHOUT opening a decode handle.

        Needed for raw-byte and shard routes on manifests that reference
        remote shards (``ArrayStore.open`` requires local files).
        """
        try:
            return compute_etag(self.path)
        except FileNotFoundError:
            raise StoreGone(
                f"store {self.name!r}: backing file {self.path} vanished"
            ) from None

    def manifest(self) -> dict | None:
        """The parsed shard manifest, or None for single-file stores."""
        if not self.path.endswith(".json"):
            return None
        import json

        try:
            with open(self.path) as f:
                return json.load(f)
        except FileNotFoundError:
            raise StoreGone(
                f"store {self.name!r}: backing file {self.path} vanished"
            ) from None

    def close(self) -> None:
        with self.lock:
            if self._handle is not None:
                self._handle.close()
                self._handle = None


class TenantQuota:
    """Cumulative per-tenant budgets (None = unlimited)."""

    def __init__(self, max_requests: int | None = None,
                 max_bytes: int | None = None):
        self.max_requests = max_requests
        self.max_bytes = max_bytes
        self.requests = 0
        self.bytes = 0

    def charge(self, *, requests: int = 0, nbytes: int = 0) -> None:
        if (requests and self.max_requests is not None
                and self.requests + requests > self.max_requests):
            raise QuotaExceeded("request quota exhausted")
        if (nbytes and self.max_bytes is not None
                and self.bytes + nbytes > self.max_bytes):
            raise QuotaExceeded("byte quota exhausted")
        self.requests += requests
        self.bytes += nbytes


class StoreRegistry:
    def __init__(self, *, backend: str = "numpy", cache=None,
                 quota_requests: int | None = None,
                 quota_bytes: int | None = None):
        self.backend = backend
        self.cache = cache
        self._stores: dict[str, _Entry] = {}
        self._lock = threading.Lock()
        self._quotas: dict[str, TenantQuota] = {}
        self._quota_defaults = (quota_requests, quota_bytes)

    def add(self, name: str, path) -> _Entry:
        if not name or "/" in name:
            raise ValueError(f"bad store name {name!r}")
        entry = _Entry(name, path, backend=self.backend, cache=self.cache)
        with self._lock:
            self._stores[name] = entry
        return entry

    def remove(self, name: str) -> None:
        with self._lock:
            entry = self._stores.pop(name, None)
        if entry is not None:
            entry.close()

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._stores)

    def entry(self, name: str) -> _Entry:
        with self._lock:
            entry = self._stores.get(name)
        if entry is None:
            raise StoreNotFound(name)
        return entry

    def close(self) -> None:
        with self._lock:
            entries = list(self._stores.values())
            self._stores.clear()
        for e in entries:
            e.close()

    # ------------------------------------------------------------- quotas
    def set_quota(self, tenant: str, *, max_requests: int | None = None,
                  max_bytes: int | None = None) -> None:
        with self._lock:
            self._quotas[tenant] = TenantQuota(max_requests, max_bytes)

    def charge(self, tenant: str, *, requests: int = 0,
               nbytes: int = 0) -> None:
        with self._lock:
            q = self._quotas.get(tenant)
            if q is None:
                mr, mb = self._quota_defaults
                if mr is None and mb is None:
                    return
                q = self._quotas[tenant] = TenantQuota(mr, mb)
            q.charge(requests=requests, nbytes=nbytes)
