"""Stdlib HTTP client for the store service: numpy-style remote ROI reads.

``RemoteStore`` speaks the service's wire API (``docs/SERVICE.md``) with
nothing but ``urllib``: ``/info`` for geometry, ``/read?roi=`` for decoded
windows (dtype/shape recovered from the ``X-Dtype``/``X-Shape`` response
headers), ``/stats`` for compressed-domain queries.  Point it at either

  * a service root (``http://host:port``) -- uses the legacy default-store
    endpoints, or
  * a store base (``http://host:port/v1/stores/<name>``) -- uses the
    multi-store v1 endpoints.

Every request is an independent ``urlopen``, so one client is safe to share
across loader worker threads; the server's decoded-chunk LRU keeps repeated
windows cheap.  This is the transport behind
``repro.data.store_loader``'s URL sources.
"""
from __future__ import annotations

import json
import urllib.error
import urllib.parse
import urllib.request

import numpy as np

from repro.core.codec.tree import np_dtype_for


def roi_text(key) -> str:
    """A ``__getitem__`` key (ints / step-1 slices / Ellipsis) -> the
    service's textual ROI (the inverse of ``store.grid.parse_roi``)."""
    if key is Ellipsis or key is None:
        return ""
    if not isinstance(key, tuple):
        key = (key,)
    parts = []
    for k in key:
        if k is Ellipsis:
            parts.append("...")
        elif isinstance(k, slice):
            if k.step not in (None, 1):
                raise ValueError(
                    f"remote ROI reads support step-1 slices only, got {k}"
                )
            lo = "" if k.start is None else int(k.start)
            hi = "" if k.stop is None else int(k.stop)
            parts.append(f"{lo}:{hi}")
        elif hasattr(k, "__index__"):
            parts.append(str(k.__index__()))
        else:
            raise TypeError(
                f"remote ROI reads support ints, step-1 slices, and "
                f"Ellipsis; got {type(k).__name__}"
            )
    return ",".join(parts)


class RemoteStore:
    """Lazy remote view of one served store: ``remote[roi]`` -> ndarray."""

    def __init__(self, url: str, *, timeout: float = 60.0):
        self._base = url.rstrip("/")
        self._timeout = float(timeout)
        self._info: dict | None = None

    def _get(self, path: str) -> tuple[dict, bytes]:
        req = urllib.request.Request(self._base + path)
        try:
            with urllib.request.urlopen(req, timeout=self._timeout) as r:
                return dict(r.headers), r.read()
        except urllib.error.HTTPError as err:
            detail = err.read().decode("utf-8", errors="replace")[:500]
            raise ValueError(
                f"store service returned {err.code} for "
                f"{self._base + path}: {detail}"
            ) from None

    # ------------------------------------------------------------- metadata
    def info(self, *, refresh: bool = False) -> dict:
        if self._info is None or refresh:
            _, body = self._get("/info")
            self._info = json.loads(body)
        return self._info

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(int(d) for d in self.info()["shape"])

    @property
    def dtype(self) -> np.dtype:
        return np_dtype_for(self.info()["dtype"])

    @property
    def ndim(self) -> int:
        return len(self.shape)

    def __repr__(self) -> str:
        return f"RemoteStore({self._base!r})"

    # ------------------------------------------------------------ ROI reads
    def read_bytes(self, roi: str) -> tuple[dict, bytes]:
        """Raw decoded bytes of a textual ROI, plus the response headers."""
        path = "/read"
        if roi:
            path += "?roi=" + urllib.parse.quote(roi)
        return self._get(path)

    def read(self, key=Ellipsis) -> np.ndarray:
        headers, body = self.read_bytes(roi_text(key))
        dtype = np_dtype_for(headers.get("X-Dtype", self.info()["dtype"]))
        shape_text = headers.get("X-Shape", "")
        shape = tuple(int(s) for s in shape_text.split(",")) if shape_text \
            else ()
        return np.frombuffer(body, dtype).reshape(shape)

    def __getitem__(self, key) -> np.ndarray:
        return self.read(key)

    # ------------------------------------------------- compressed-domain stats
    def stats(self, *, header_only: bool = False) -> dict:
        path = "/stats" + ("?header_only=1" if header_only else "")
        _, body = self._get(path)
        return json.loads(body)
