"""HTTP slice/query service over compressed array stores.

Compatibility front door for the production serving tier in
:mod:`repro.serve.service`.  The legacy single-store endpoints keep their
exact shapes --

    /info                    store geometry (JSON)
    /stats[?header_only=1]   compressed-domain aggregate query (JSON)
    /read?roi=0:16,:,3       ROI slice; raw little-endian bytes
                             (C order, dtype/shape in X-Dtype/X-Shape headers)

-- and the full ``/v1`` API (multi-store registry, decoded-chunk LRU cache,
ETag/If-None-Match, Range over compressed bytes, shard redirects, metrics,
quotas) is served by the same process; see :mod:`repro.serve.service.app`.

Telemetry: every request -- legacy routes included -- flows through the
shared :class:`~repro.serve.service.app.StoreService` core, which wraps each
handler in one ``serve.request`` span and mirrors counters/latency into the
shared :mod:`repro.obs` registry when ``SZX_OBS=1``; ``GET /v1/metrics``
with ``Accept: text/plain`` serves the Prometheus exposition (see
docs/OBSERVABILITY.md).

``/info`` is now answered from the registry's CURRENT revalidated handle:
replacing the store file updates the metadata immediately, and a vanished
file answers 410 instead of the stale startup snapshot (the old behaviour
cached ``/info`` at ``make_server`` time).

Start it with ``python -m repro.store serve FILE`` or :func:`serve_store`;
:func:`make_server` is the embeddable/testable hook -- it binds the socket
synchronously (``server_address`` is valid before ``serve_forever`` runs)
and keeps the ThreadingHTTPServer-style lifecycle
(``serve_forever``/``shutdown``/``server_close``).
"""
from __future__ import annotations

from repro.serve.service.app import HttpServer, StoreService, asgi_app

__all__ = ["make_server", "serve_store", "make_service", "asgi_app"]

DEFAULT_CACHE_BYTES = 256 << 20


def make_service(path: str | None = None, *, backend: str = "numpy",
                 cache_bytes: int = DEFAULT_CACHE_BYTES,
                 quota_requests: int | None = None,
                 quota_bytes: int | None = None) -> StoreService:
    """Build the request core, optionally pre-registering one default store.

    ``path`` may be a single ``.szs`` store file or a shard-manifest
    ``.json``; more stores can be added later with ``service.add_store``.
    """
    service = StoreService(
        backend=backend, cache_bytes=cache_bytes,
        quota_requests=quota_requests, quota_bytes=quota_bytes,
    )
    if path is not None:
        service.add_store("default", path)
    return service


def make_server(path: str, host: str = "127.0.0.1", port: int = 0,
                *, backend: str = "numpy",
                cache_bytes: int = DEFAULT_CACHE_BYTES) -> HttpServer:
    """Build (but do not run) the HTTP server for one store file.

    The returned object binds its socket immediately and exposes
    ``server_address``, ``serve_forever()``, ``shutdown()`` and
    ``server_close()``.
    """
    service = make_service(path, backend=backend, cache_bytes=cache_bytes)
    return HttpServer(service, host, port)


def serve_store(path: str, host: str = "127.0.0.1", port: int = 8117,
                *, backend: str = "numpy") -> None:
    """Run the service until interrupted (the ``python -m repro.store serve``
    entry point)."""
    srv = make_server(path, host, port, backend=backend)
    host, port = srv.server_address[:2]
    print(f"serving compressed array store {path} on http://{host}:{port} "
          "(/info /stats /read?roi=... + /v1/...)")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.shutdown()
        srv.server_close()
