"""HTTP slice/query service over a compressed array store.

The store-backed serving layer: many concurrent readers pull ROI slices and
aggregate queries of ONE huge compressed array without any server-side
materialization -- each request decodes only the chunks/blocks its ROI
touches (``repro.store``'s lazy read path), so working memory per request is
O(ROI), and the whole array lives on disk compressed.

Endpoints (all GET):

    /info                    store geometry + compression stats (JSON)
    /stats[?header_only=1]   compressed-domain aggregate query (JSON)
    /read?roi=0:16,:,3       ROI slice; raw little-endian bytes
                             (C order, dtype/shape in X-Dtype/X-Shape headers)

Built on the stdlib ThreadingHTTPServer: every request opens its own
``CompressedArray`` handle (a footer read), so readers never contend on a
shared seek cursor.  Start it with ``python -m repro.store serve FILE`` or
:func:`serve_store`; :func:`make_server` is the embeddable/testable hook.
"""
from __future__ import annotations

import json
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer


def make_server(path: str, host: str = "127.0.0.1", port: int = 0,
                *, backend: str = "numpy") -> ThreadingHTTPServer:
    """Build (but do not run) the threading HTTP server for one store file."""
    from repro.store import ArrayStore
    from repro.store.__main__ import parse_roi

    with ArrayStore.open(path) as ca:      # validate once at startup
        meta = {
            "shape": list(ca.shape),
            "chunk_shape": list(ca.chunk_shape),
            "dtype": ca.dtype.name,
            "e": ca.error_bound,
            "nchunks": ca.nchunks,
            "raw_bytes": ca.nbytes,
            "stored_bytes": ca.stored_bytes,
        }

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *a):          # quiet by default
            pass

        def _json(self, code: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):                   # noqa: N802 (stdlib API name)
            url = urllib.parse.urlparse(self.path)
            q = urllib.parse.parse_qs(url.query)
            try:
                if url.path == "/info":
                    self._json(200, meta)
                elif url.path == "/stats":
                    header_only = q.get("header_only", ["0"])[0] not in ("0", "")
                    with ArrayStore.open(path, backend=backend) as ca:
                        stats = ca.stats(header_only=header_only).to_dict()
                    self._json(200, stats)
                elif url.path == "/read":
                    roi = parse_roi(q.get("roi", [None])[0])
                    with ArrayStore.open(path, backend=backend) as ca:
                        out = ca[roi]
                    body = out.tobytes()
                    self.send_response(200)
                    self.send_header("Content-Type", "application/octet-stream")
                    self.send_header("Content-Length", str(len(body)))
                    self.send_header("X-Dtype", out.dtype.name)
                    self.send_header("X-Shape", ",".join(map(str, out.shape)))
                    self.end_headers()
                    self.wfile.write(body)
                else:
                    self._json(404, {"error": f"unknown path {url.path}"})
            except (ValueError, TypeError, IndexError, KeyError) as err:
                self._json(400, {"error": str(err)})

    return ThreadingHTTPServer((host, port), Handler)


def serve_store(path: str, host: str = "127.0.0.1", port: int = 8117,
                *, backend: str = "numpy") -> None:
    """Run the service until interrupted (the ``python -m repro.store serve``
    entry point)."""
    srv = make_server(path, host, port, backend=backend)
    host, port = srv.server_address[:2]
    print(f"serving compressed array store {path} on http://{host}:{port} "
          "(/info /stats /read?roi=...)")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.server_close()
