"""Serving engine: prefill + single-token decode with KV caches.

Cache modes:
  'dense'      -- bf16 K/V slabs (B, W, Hkv, hd)
  'compressed' -- SZx-planes K/V: per (position, kv-head) channel block of
                  head_dim values -> mu (f32) + sexp (int8) + P uint8 planes.
                  ~1.9x less HBM traffic than bf16 at P=1 (the paper's
                  in-memory-compression use case applied to decode, which is
                  KV-bandwidth-bound -- see DESIGN.md section 3).

Sliding-window archs use a ring buffer of W = window slots (slot = pos % W)
with an absolute-position array for masking, so long_500k decode allocates
only the window.  SSM/hybrid archs carry O(1) state.  The whole decode step
is one jit-able function: scan over layers, fixed shapes throughout.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.codec import PlanesCodec
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.sharding import rules_active, shard_activation as _sa

NEG_INF = -1e30


def _reduce_scores(s):
    """Replicate hd-partial scores across 'model'.

    Under a sharding-rules context the cross-shard sum is the decode hot
    collective; casting the partials to bf16 halves the wire bytes (scores
    tolerate bf16 -- perf iteration H3.3).  Outside a rules context (unit
    tests, single device) this is an exact no-op."""
    if not rules_active():
        return s
    s = s.astype(jnp.bfloat16)
    s = _sa(s, ("act_batch", None, None, None))
    return s.astype(jnp.float32)


# ---------------------------------------------------------------------------
# channel-block SZx-planes helpers (block = head_dim values of one position)
# ---------------------------------------------------------------------------

def _kv_encode(x, num_planes: int):
    """x: (..., hd) -> (mu f32, sexp int8, planes uint8 (P, ..., hd)).

    The head_dim axis IS the block, so this is PlanesCodec at block level,
    through the shared device-resident record (``DeviceEncoding``, kind
    'szx-planes' -- the same representation the checkpoint and gradient
    paths carry); sexp is clipped to int8 for the cache slab (HBM bytes are
    the point)."""
    enc = PlanesCodec(num_planes).encode_blocks_device(x.astype(jnp.float32))
    enc = enc.replace(sexp=jnp.clip(enc["sexp"], -127, 127).astype(jnp.int8))
    return enc["mu"], enc["sexp"], enc["planes"]


def _kv_decode(mu, sexp, planes, dtype):
    """Inverse of :func:`_kv_encode`, through the same ``DeviceEncoding``
    record -- the decode mirror of the shared device-resident path, so the
    cache dequant and the stream/gradient decoders exercise ONE codec
    entry point (``PlanesCodec.decode_encoding``)."""
    from repro.core.codec.device import DeviceEncoding

    codec = PlanesCodec(planes.shape[0])
    enc = DeviceEncoding.make(
        "szx-planes",
        {"mu": mu, "sexp": sexp, "planes": planes},
        num_planes=planes.shape[0],
    )
    return codec.decode_encoding(enc).astype(dtype)


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def cache_window(cfg: ArchConfig, seq_len: int) -> int:
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def make_cache(
    cfg: ArchConfig,
    batch: int,
    seq_len: int,
    *,
    kv_mode: str = "dense",
    num_planes: int = 1,
    dtype=jnp.bfloat16,
) -> dict:
    """Zero-initialized cache pytree (dry-run uses jax.eval_shape of this)."""
    w = cache_window(cfg, seq_len)
    hd = cfg.resolved_head_dim
    lay: dict[str, Any] = {}
    nl = cfg.n_layers
    if cfg.n_heads and cfg.family != "ssm":
        if kv_mode == "dense":
            for nm in ("k", "v"):
                lay[nm] = jnp.zeros((nl, batch, w, cfg.n_kv_heads, hd), dtype)
        else:
            for nm in ("k", "v"):
                lay[nm + "mu"] = jnp.zeros((nl, batch, w, cfg.n_kv_heads), jnp.float32)
                lay[nm + "sexp"] = jnp.zeros((nl, batch, w, cfg.n_kv_heads), jnp.int8)
                lay[nm + "pl"] = jnp.zeros(
                    (nl, num_planes, batch, w, cfg.n_kv_heads, hd), jnp.uint8
                )
    if cfg.ssm_state and cfg.family in ("ssm", "hybrid"):
        lay["state"] = jnp.zeros(
            (nl, batch, cfg.ssm_n_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
        )
        lay["conv"] = jnp.zeros(
            (nl, batch, cfg.ssm_conv_width - 1, L.ssm_conv_channels(cfg)), dtype
        )
    has_attn = bool(cfg.n_heads) and cfg.family != "ssm"
    cache: dict[str, Any] = {
        "pos": jnp.int32(0),
        "slot_pos": jnp.full((w if has_attn else 1,), -1, jnp.int32),
        "layers": lay,
    }
    if cfg.encoder_decoder:
        # kept OUTSIDE the scanned layer cache: read-only at decode, so it
        # must not round-trip through scan outputs every step
        cache["cross"] = {
            nm: jnp.zeros((nl, batch, cfg.encoder_len, cfg.n_kv_heads, hd), dtype)
            for nm in ("k", "v")
        }
    return cache


def cache_specs(cfg, batch, seq_len, **kw):
    return jax.eval_shape(
        functools.partial(make_cache, cfg, batch, seq_len, **kw)
    )


# ---------------------------------------------------------------------------
# decode attention over a (possibly compressed, possibly ring) cache slab
# ---------------------------------------------------------------------------

def _slab_attend(q, kslab, vslab, slot_pos, qpos, *, window: int):
    """q: (B,1,Hq,hd); slabs: (B,W,Hkv,hd); slot_pos: (W,) absolute positions.

    Single-shot masked attention (W is at most the cell seq_len; chunking for
    big W happens in the caller via _chunked_slab_attend)."""
    b, _, hq, hd = q.shape
    hkv = kslab.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd)
    # cache is head_dim-sharded over 'model'; reshard the (tiny) q the same
    # way so the d-contraction computes partial scores locally, then
    # all-reduce the small scores -- NOT all-gather the K chunk
    qg = _sa(qg, ("act_batch", None, None, "act_hd"))
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qg, kslab, preferred_element_type=jnp.float32
    ) / math.sqrt(hd)
    s = _reduce_scores(s)
    valid = (slot_pos >= 0) & (slot_pos <= qpos)
    if window:
        valid &= qpos - slot_pos < window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m), 0.0)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, vslab, preferred_element_type=jnp.float32)
    out = out / jnp.maximum(p.sum(-1)[..., None], 1e-30)
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def _chunked_slab_attend(
    q, get_chunk, nchunks, chunk, slot_pos, qpos, *, window, decode_chunk=None
):
    """Online-softmax scan over cache chunks.

    get_chunk(i) -> raw cache slices (counted as HBM reads); decode_chunk
    (optional) dequantizes them -> (k, v).  The dequant+attend body is tagged
    vmem_tile: on TPU it is one fused decompress-attend kernel whose decoded
    tiles never hit HBM (DESIGN.md section 3) -- the roofline memory term then
    reflects the *compressed* cache bytes, which is the paper's win.
    """
    b, _, hq, hd = q.shape
    if decode_chunk is None:
        decode_chunk = lambda raw: raw  # noqa: E731

    def step(carry, i):
        raw = get_chunk(i)                       # HBM loads (counted)
        sp = jax.lax.dynamic_slice_in_dim(slot_pos, i * chunk, chunk)
        with jax.named_scope("vmem_tile"):       # fused dequant+attend tile
            return _tile(carry, raw, sp), None

    def _tile(carry, raw, sp):
        m, l, acc = carry
        kc, vc = decode_chunk(raw)
        hkv = kc.shape[2]
        g = hq // hkv
        qg = q.reshape(b, hkv, g, hd)
        qg = _sa(qg, ("act_batch", None, None, "act_hd"))   # see _slab_attend
        s = jnp.einsum(
            "bhgd,bkhd->bhgk", qg, kc, preferred_element_type=jnp.float32
        ) / math.sqrt(hd)
        s = _reduce_scores(s)
        valid = (sp >= 0) & (sp <= qpos)
        if window:
            valid &= qpos - sp < window
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        pv = jnp.einsum("bhgk,bkhd->bhgd", p, vc, preferred_element_type=jnp.float32)
        return (m_new, l_new, alpha[..., None] * acc + pv)

    # hkv sizes the carriers; fetch statically from the chunk shape
    k0, _ = jax.eval_shape(lambda i: decode_chunk(get_chunk(i)), jnp.int32(0))
    hkv = k0.shape[2]
    g = hq // hkv
    m0 = jnp.full((b, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nchunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


DECODE_CHUNK = 2048


def decode_attention(p, x1, lc, cache_meta, cfg: ArchConfig, *, kv_mode, num_planes):
    """One layer's decode-attention incl. cache append.  Returns (out, new_lc)."""
    b = x1.shape[0]
    hd = cfg.resolved_head_dim
    pos, slot_pos, w = cache_meta["pos"], cache_meta["slot_pos"], cache_meta["w"]
    slot = pos % w
    q = L.dense(x1, p["wq"]).reshape(b, 1, cfg.n_heads, hd)
    k = L.dense(x1, p["wk"]).reshape(b, 1, cfg.n_kv_heads, hd)
    v = L.dense(x1, p["wv"]).reshape(b, 1, cfg.n_kv_heads, hd)
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    new_lc = {}
    window = cfg.sliding_window
    if kv_mode == "dense":
        kslab = jax.lax.dynamic_update_slice_in_dim(lc["k"], k, slot, axis=1)
        vslab = jax.lax.dynamic_update_slice_in_dim(lc["v"], v, slot, axis=1)
        new_lc["k"], new_lc["v"] = kslab, vslab
        if w <= DECODE_CHUNK * 2:
            out = _slab_attend(q, kslab, vslab, slot_pos, pos, window=window)
        else:
            nch = w // DECODE_CHUNK

            def get_chunk(i):
                kc = jax.lax.dynamic_slice_in_dim(kslab, i * DECODE_CHUNK, DECODE_CHUNK, 1)
                vc = jax.lax.dynamic_slice_in_dim(vslab, i * DECODE_CHUNK, DECODE_CHUNK, 1)
                return kc, vc

            out = _chunked_slab_attend(
                q, get_chunk, nch, DECODE_CHUNK, slot_pos, pos, window=window
            )
    else:
        kmu, ksexp, kpl = _kv_encode(k[:, 0], num_planes)   # (B,Hkv),(B,Hkv),(P,B,Hkv,hd)
        vmu, vsexp, vpl = _kv_encode(v[:, 0], num_planes)
        ins = lambda slab, val: jax.lax.dynamic_update_slice_in_dim(  # noqa: E731
            slab, val[:, None] if val.ndim == slab.ndim - 1 else val, slot, axis=1
        )
        new_lc["kmu"] = ins(lc["kmu"], kmu)
        new_lc["ksexp"] = ins(lc["ksexp"], ksexp)
        new_lc["vmu"] = ins(lc["vmu"], vmu)
        new_lc["vsexp"] = ins(lc["vsexp"], vsexp)
        new_lc["kpl"] = jax.lax.dynamic_update_slice_in_dim(
            lc["kpl"], kpl[:, :, None], slot, axis=2
        )
        new_lc["vpl"] = jax.lax.dynamic_update_slice_in_dim(
            lc["vpl"], vpl[:, :, None], slot, axis=2
        )
        ck = min(w, DECODE_CHUNK)
        nch = w // ck

        def get_chunk(i):
            sl = lambda a, ax: jax.lax.dynamic_slice_in_dim(a, i * ck, ck, ax)  # noqa: E731
            return (
                sl(new_lc["kmu"], 1), sl(new_lc["ksexp"], 1), sl(new_lc["kpl"], 2),
                sl(new_lc["vmu"], 1), sl(new_lc["vsexp"], 1), sl(new_lc["vpl"], 2),
            )

        def decode_chunk(raw):
            kmu_, ksexp_, kpl_, vmu_, vsexp_, vpl_ = raw
            return (
                _kv_decode(kmu_, ksexp_, kpl_, x1.dtype),
                _kv_decode(vmu_, vsexp_, vpl_, x1.dtype),
            )

        out = _chunked_slab_attend(
            q, get_chunk, nch, ck, slot_pos, pos, window=window,
            decode_chunk=decode_chunk,
        )
    out = L.dense(out.reshape(b, 1, cfg.n_heads * hd), p["wo"])
    return out, new_lc


def _cross_attend(p, x1, lc, cfg):
    """Decoder cross-attention against the cached encoder K/V."""
    b = x1.shape[0]
    hd = cfg.resolved_head_dim
    q = L.dense(x1, p["wq"]).reshape(b, 1, cfg.n_heads, hd)
    t = lc["cross_k"].shape[1]
    slotp = jnp.arange(t, dtype=jnp.int32)
    out = _slab_attend(q, lc["cross_k"], lc["cross_v"], slotp, jnp.int32(t), window=0)
    return L.dense(out.reshape(b, 1, cfg.n_heads * hd), p["wo"])


# ---------------------------------------------------------------------------
# prefill / decode steps
# ---------------------------------------------------------------------------

def prefill(
    params,
    cfg: ArchConfig,
    tokens,
    *,
    frames=None,
    image_embeds=None,
    seq_len: int | None = None,
    kv_mode: str = "dense",
    num_planes: int = 1,
):
    """Run the full-context forward, build the cache, return (cache, logits)."""
    h = T.embed_tokens(params, cfg, tokens)
    if cfg.prefix_embeds and image_embeds is not None:
        pre = L.dense(image_embeds.astype(h.dtype), params["frontend_proj"])
        h = jnp.concatenate([pre, h], axis=1)
    enc_out = None
    if cfg.encoder_decoder:
        enc_out = T.encode(params, cfg, frames)
    h, _, caps = T._run_layers(
        params["layers"], h, cfg, causal=True, enc_out=enc_out, capture=True
    )
    h = L.rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = T.logits_for(params, cfg, h[:, -1:])

    b, s = h.shape[0], h.shape[1]
    w = cache_window(cfg, seq_len or s)
    cache = make_cache(cfg, b, seq_len or s, kv_mode=kv_mode, num_planes=num_planes,
                       dtype=h.dtype)
    lay = cache["layers"]
    take = min(w, s)
    src_pos = jnp.arange(s - take, s)
    slots = src_pos % w
    if "k" in lay or "kmu" in lay:
        k_t = caps["k"][:, :, s - take :].astype(h.dtype)   # (L,B,take,Hkv,hd)
        v_t = caps["v"][:, :, s - take :].astype(h.dtype)
        if kv_mode == "dense":
            lay["k"] = lay["k"].at[:, :, slots].set(k_t)
            lay["v"] = lay["v"].at[:, :, slots].set(v_t)
        else:
            for nm, t_ in (("k", k_t), ("v", v_t)):
                mu, sexp, pl = _kv_encode(t_, num_planes)   # pl: (P,L,B,take,Hkv,hd)
                lay[nm + "mu"] = lay[nm + "mu"].at[:, :, slots].set(mu)
                lay[nm + "sexp"] = lay[nm + "sexp"].at[:, :, slots].set(sexp)
                lay[nm + "pl"] = (
                    lay[nm + "pl"].at[:, :, :, slots].set(jnp.moveaxis(pl, 0, 1))
                )
    if "state" in lay:
        lay["state"] = caps["state"]
        lay["conv"] = caps["conv"].astype(h.dtype)
    if cfg.encoder_decoder:
        cache["cross"] = {
            "k": caps["cross_k"].astype(h.dtype),
            "v": caps["cross_v"].astype(h.dtype),
        }
    cache["pos"] = jnp.int32(s)
    if cache["slot_pos"].shape[0] == w:
        cache["slot_pos"] = jnp.full((w,), -1, jnp.int32).at[slots].set(src_pos)
    return cache, logits


def decode_step(
    params, cfg: ArchConfig, cache, token, *, kv_mode: str = "dense", num_planes: int = 1
):
    """One token for every sequence in the batch.  Returns (logits, new_cache)."""
    h = T.embed_tokens(params, cfg, token)
    h = _sa(h, ("act_batch", None, None))
    pos = cache["pos"]
    w = cache["slot_pos"].shape[0]
    # mark the current token's slot BEFORE the layer scan so attention can
    # see the token it is appending (self-attention to position `pos`)
    slot_pos = cache["slot_pos"].at[pos % w].set(pos)
    meta = {"pos": pos, "slot_pos": slot_pos, "w": w}
    xs = (params["layers"], cache["layers"])
    if cfg.encoder_decoder:
        xs = xs + (cache["cross"],)

    def body(h, xs):
        lp, lc = xs[0], xs[1]
        new_lc = dict(lc)
        hn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        mix = None
        if cfg.n_heads and cfg.family != "ssm":
            out, upd = decode_attention(
                lp["attn"], hn, lc, meta, cfg, kv_mode=kv_mode, num_planes=num_planes
            )
            new_lc.update(upd)
            mix = out
        if "ssm" in lp:
            out, st, cv = L.mamba2_decode(lp["ssm"], hn, lc["state"], lc["conv"], cfg)
            new_lc["state"], new_lc["conv"] = st, cv
            mix = out if mix is None else 0.5 * (mix + out)
        h = h + mix
        if "cross" in lp:
            hn = L.rms_norm(h, lp["ln_cross"], cfg.norm_eps)
            h = h + _cross_attend(lp["cross"], hn, {"cross_k": xs[2]["k"], "cross_v": xs[2]["v"]}, cfg)
        h, _ = T.ffn_part(lp, h, cfg)
        return h, new_lc

    h, new_layers = jax.lax.scan(body, h, xs)
    h = L.rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = T.logits_for(params, cfg, h)
    new_cache = {
        "pos": pos + 1,
        "slot_pos": slot_pos,
        "layers": new_layers,
    }
    if cfg.encoder_decoder:
        new_cache["cross"] = cache["cross"]
    return logits, new_cache
