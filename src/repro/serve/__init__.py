"""Serving runtime: prefill/decode engine with dense or SZx-compressed KV."""
from repro.serve import engine  # noqa: F401
