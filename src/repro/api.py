"""repro.api -- the curated public surface of the repro package.

Everything supported for external use is importable from here (and
re-exported at the top level: ``import repro; repro.SZxCodec``):

  Bound        -- the unified error-bound spec: ``Bound.abs(1e-3)`` /
                  ``Bound.rel(1e-4)``; every bound-taking API also accepts a
                  bare float, meaning ``Bound.abs``
  SZxCodec     -- byte-stream codec (monolithic + chunked streaming,
                  f32/f64/f16/bf16)
  TreeCodec    -- pytree codec: one multi-leaf container-v3 stream per tree
  PlanesCodec  -- fixed-shape in-graph codec (gradient / KV-cache planes)
  ArrayStore   -- block-addressable compressed N-d array store (lazy ROI
                  reads, compressed-domain queries, sharded manifests)
  StoreLoader  -- streaming training ingest: pipelined shuffled-ROI-window
                  batches over an ArrayStore (file, manifest, or service
                  URL), bytes read ∝ batch
  RemoteStore  -- stdlib HTTP client for the store service (remote ROI reads)
  CheckpointManager -- fault-tolerant checkpoints over TreeCodec streams
  compress / decompress / compress_with_stats -- one-shot functional API

Anything imported from deeper module paths (``repro.core.codec.*``,
``repro.store.*``) is a stable-ish internal: it works, but only the names
listed here are covered by the deprecation policy.  The historical
``repro.core.szx`` float32 module is a frozen legacy shim.
"""
from repro.core.codec.plan import Bound  # noqa: F401
from repro.core.codec.planes_codec import PlanesCodec  # noqa: F401
from repro.core.codec.szx_codec import (  # noqa: F401
    CompressionStats,
    SZxCodec,
    compress,
    compress_with_stats,
    decompress,
)
from repro.core.codec.tree import TreeCodec  # noqa: F401


def __getattr__(name):
    # Heavy optional surfaces resolve lazily so `import repro.api` stays
    # cheap and never drags in jax for codec-only callers.
    if name == "ArrayStore":
        from repro.store import ArrayStore

        return ArrayStore
    if name == "CompressedArray":
        from repro.store import CompressedArray

        return CompressedArray
    if name == "CheckpointManager":
        from repro.checkpoint.manager import CheckpointManager

        return CheckpointManager
    if name == "StoreLoader":
        from repro.data.store_loader import StoreLoader

        return StoreLoader
    if name == "StoreLM":
        from repro.data.store_loader import StoreLM

        return StoreLM
    if name == "RemoteStore":
        from repro.serve.client import RemoteStore

        return RemoteStore
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")


__all__ = [
    "Bound",
    "SZxCodec",
    "TreeCodec",
    "PlanesCodec",
    "ArrayStore",
    "CompressedArray",
    "CheckpointManager",
    "StoreLoader",
    "StoreLM",
    "RemoteStore",
    "CompressionStats",
    "compress",
    "compress_with_stats",
    "decompress",
]
