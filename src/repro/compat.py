"""Version-portability shims for the JAX APIs this repo straddles.

The codebase targets the modern ``jax.shard_map`` entry point (``axis_names``
selects the manual axes, ``check_vma`` toggles the varying-manual-axes check).
Older installs (<= 0.4.x) only ship ``jax.experimental.shard_map.shard_map``
whose equivalent knobs are ``auto`` (the complement of the manual axes) and
``check_rep``.  Routing every call site through :func:`shard_map` keeps the
rest of the code on one spelling.
"""
from __future__ import annotations

from typing import Callable

import jax


def shard_map(
    f: Callable,
    *,
    mesh,
    in_specs,
    out_specs,
    axis_names: set | None = None,
    check_vma: bool = False,
):
    """``jax.shard_map`` with fallback to ``jax.experimental.shard_map``."""
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kw,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    auto: frozenset = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, auto=auto,
    )


def axis_size(axis_name) -> int:
    """Static size of a mapped mesh axis, inside shard_map/pmap bodies.

    Older JAX has no ``jax.lax.axis_size``; ``psum(1, axis)`` of a literal is
    constant-folded to the (static) axis size there.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def partial_manual_supported() -> bool:
    """Whether shard_map may leave some mesh axes automatic (GSPMD) while
    others are manual.  Old XLA (no native ``jax.shard_map``) fatally asserts
    on collectives under partial-manual regions, so callers must fall back to
    fully-manual bodies there."""
    return hasattr(jax, "shard_map")


def sharding_hints_supported() -> bool:
    """Whether with_sharding_constraint is safe at the current trace point.

    Old JAX/XLA (no native ``jax.shard_map``) fatally asserts
    (``IsManualSubgroup``) when a named-sharding constraint appears inside a
    partial-manual shard_map region, so activation hints must be dropped
    there; they are only hints, correctness is unaffected.
    """
    if hasattr(jax, "shard_map"):
        return True
    try:
        from jax._src.core import get_axis_env

        return not get_axis_env().axis_names()
    except Exception:
        return True


def compiled_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` across JAX versions (dict vs 1-list)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return dict(cost)
