"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests/test_trainer.py):
  * checkpoint every N steps through CheckpointManager (atomic, keep-k,
    optional SZx compression, async)
  * automatic restart: on any step failure the loop restores the latest
    committed checkpoint and replays the data stream from that step
    (deterministic pipeline => exact-once semantics), with bounded retries
  * straggler detection: per-step wall times tracked; steps slower than
    `straggler_factor` x the trailing median are counted and surfaced in
    metrics (at fleet scale this signal feeds the scheduler that evicts slow
    hosts; here it is logged and tested via fault injection)
  * elastic restore: checkpoints are topology-free (full logical arrays), so
    a run can resume on a different mesh/device count -- restore() takes the
    new shardings
  * loss/grad-norm metrics history for regression tests
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro import obs
from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    checkpoint_every: int = 50
    max_restarts: int = 3
    straggler_factor: float = 3.0
    straggler_window: int = 32
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        step_fn: Callable,                    # (state, batch) -> (state, metrics)
        batch_fn: Callable[[int], Any],       # step -> batch (deterministic)
        ckpt: CheckpointManager,
        *,
        fault_hook: Optional[Callable[[int], None]] = None,  # test injection
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.fault_hook = fault_hook
        self.history: list[dict] = []
        self.step_times: list[float] = []
        self.straggler_steps: list[int] = []
        self.restarts = 0

    # ------------------------------------------------------------------
    def _maybe_flag_straggler(self, step: int, dt: float) -> None:
        w = self.step_times[-self.cfg.straggler_window :]
        if len(w) >= 8:
            med = statistics.median(w)
            if dt > self.cfg.straggler_factor * med:
                self.straggler_steps.append(step)

    def run(self, state) -> Any:
        cfg = self.cfg
        start = self.ckpt.latest_step()
        step = 0
        if start is not None:
            state, step = self.ckpt.restore(state, start)
            step += 1

        while step < cfg.total_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                batch = self.batch_fn(step)
                t0 = time.time()
                with obs.span("train.step", step=step):
                    state, metrics = self.step_fn(state, batch)
                    jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                if obs.enabled():
                    obs.counter("train.steps").inc()
                    obs.histogram("train.step_seconds").observe(dt)
                self._maybe_flag_straggler(step, dt)
                self.step_times.append(dt)
                rec = {
                    "step": step,
                    "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics.get("grad_norm", 0.0)),
                    "dt": dt,
                }
                self.history.append(rec)
                if not np.isfinite(rec["loss"]):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                if step % cfg.checkpoint_every == 0 and step > 0:
                    self.ckpt.save(step, state)
                step += 1
            except (KeyboardInterrupt, SystemExit):
                raise
            except BaseException as e:
                self.restarts += 1
                if self.restarts > cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={cfg.max_restarts}"
                    ) from e
                latest = self.ckpt.latest_step()
                if latest is None:
                    # nothing committed yet: restart from the initial state is
                    # the caller's job; re-raise
                    raise
                state, restored = self.ckpt.restore(state, latest)
                step = restored + 1
        self.ckpt.wait() if self.ckpt.async_save else None
        self.ckpt.save(cfg.total_steps - 1, state)
        return state
