"""Training runtime: step factory + fault-tolerant trainer loop."""
