"""Training step factory: plain GSPMD step, or hierarchical step with SZx
gradient compression on the cross-pod reduction.

Plain: one jit; DP/TP/EP/FSDP all via GSPMD from the param/batch shardings.

Compressed: ``jax.shard_map`` manual over 'pod' (auto over 'data'/'model'),
per-pod grads + error feedback -> szx-planes encode -> all_gather('pod') of
the ~4x-smaller payload -> decode+mean -> optimizer.  See
repro.core.grad_compress and DESIGN.md section 3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.core import grad_compress
from repro.models import transformer as T
from repro.optim.adamw import AdamW


def init_state(cfg: ArchConfig, opt: AdamW, key, *, ef_planes: int = 0) -> dict:
    params = T.init_params(cfg, key)
    state = {"params": params, "opt": opt.init(params)}
    if ef_planes:
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros((2,) + p.shape, jnp.bfloat16), params
        )
    return state


def state_specs(cfg: ArchConfig, state_tree, mesh):
    """PartitionSpec pytree for a train state (params/opt share param specs)."""
    from repro.launch.mesh import param_specs_tree

    pspecs = param_specs_tree(cfg, state_tree["params"], mesh)
    out = {
        "params": pspecs,
        "opt": type(state_tree["opt"])(
            step=P(),
            m=param_specs_tree(cfg, state_tree["opt"].m, mesh),
            v=param_specs_tree(cfg, state_tree["opt"].v, mesh),
        ),
    }
    if "ef" in state_tree:
        out["ef"] = jax.tree.map(lambda s: P("pod", *s), pspecs)
    return out


def make_train_step(cfg: ArchConfig, opt: AdamW, *, mesh=None, compress_planes: int = 0):
    loss_of = lambda p, b: T.loss_fn(p, cfg, b)  # noqa: E731

    if not compress_planes:

        def train_step(state, batch):
            loss, grads = jax.value_and_grad(loss_of)(state["params"], batch)
            params, opt_state, metrics = opt.update(grads, state["opt"], state["params"])
            return {"params": params, "opt": opt_state}, {"loss": loss, **metrics}

        return train_step

    assert mesh is not None and "pod" in mesh.axis_names
    # Preferred layout: only 'pod' is manual; 'data'/'model' stay automatic so
    # GSPMD keeps the intra-pod DP/TP shardings.  Old XLA cannot compile
    # collectives under partial-manual regions, so there we go fully manual:
    # the batch is split over 'data' explicitly, the intra-pod gradient mean
    # becomes an explicit full-precision pmean('data'), and the 'model' axis
    # computes redundantly (params replicated) -- same math, no TP overlap.
    partial = compat.partial_manual_supported()
    data_axes = tuple(a for a in ("data",) if a in mesh.axis_names)

    def per_pod(params, ef, batch):
        ef = jax.tree.map(lambda e: e[0], ef)            # strip sharded pod dim
        loss, grads = jax.value_and_grad(loss_of)(params, batch)
        if not partial and data_axes:
            loss = jax.lax.pmean(loss, data_axes)
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, data_axes), grads)
        g_eff = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e.astype(jnp.float32), grads, ef
        )
        mean, resid = grad_compress.compressed_psum_mean(
            g_eff, "pod", num_planes=compress_planes
        )
        loss = jax.lax.pmean(loss, "pod")
        resid = jax.tree.map(lambda r: r.astype(jnp.bfloat16)[None], resid)
        return loss, mean, resid

    batch_spec = P("pod") if partial else P(("pod",) + data_axes)
    inner = shard_map(
        per_pod,
        mesh=mesh,
        axis_names={"pod"} if partial else set(mesh.axis_names),
        in_specs=(P(), P("pod"), batch_spec),
        out_specs=(P(), P(), P("pod")),
        check_vma=False,
    )

    def train_step(state, batch):
        loss, grads, ef = inner(state["params"], state["ef"], batch)
        params, opt_state, metrics = opt.update(grads, state["opt"], state["params"])
        return (
            {"params": params, "opt": opt_state, "ef": ef},
            {"loss": loss, **metrics},
        )

    return train_step
