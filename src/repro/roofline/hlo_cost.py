"""Loop-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count (verified empirically -- scan L=4 and L=8 report identical flops).
Every model here runs layers/chunks under ``lax.scan``, so naive counts are
off by 10-100x.  This module parses the post-SPMD per-device HLO text into a
computation graph, extracts while-loop trip counts from their condition
computations, and accumulates flops / HBM bytes / collective bytes with the
correct multipliers.

Traffic model (per instruction):
  fusion            -> operands + result hit HBM; internals are free
  dot               -> operands + result; flops = 2 * prod(result) * prod(contracting)
  other compute ops -> operands + result
  tuple/gte/param/bitcast/while/call shells -> free (bodies accounted)

Validated against analytic 6*N*D for the dense-LM train cells (see
EXPERIMENTS.md section Roofline cross-check).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s*([\w\-]+)\((.*?)\)(.*)$"
)
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALL_ATTR = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w.\-]+)")
_CONST_INT = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "custom-call",
    "rng-bit-generator", "rng-get-and-update-state",
}
_COLL_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    param_types: dict[str, str]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[dict] = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {}

    def __add__(self, o: "Cost") -> "Cost":
        c = dict(self.coll)
        for k, v in o.coll.items():
            c[k] = c.get(k, 0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes, c)

    def __mul__(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, {kk: v * k for kk, v in self.coll.items()})


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.rstrip().endswith("{"):
                params = {}
                for pm in re.finditer(r"%?([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?)", m.group(2)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(m.group(1), [], params)
                if line.startswith("ENTRY"):
                    entry_name = m.group(1)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR.match(line)
        if im:
            name, tstr, opcode, opnds, attrs = im.groups()
            cur.instrs.append(
                Instr(name, tstr, opcode, _OPERAND.findall(opnds), attrs)
            )
    comps["__entry__"] = comps.get(entry_name, next(iter(comps.values())))
    return comps


_CONST_LINE = re.compile(r"%?([\w.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)")
_CMP_LINE = re.compile(
    r"compare\(\s*%?([\w.\-]+),\s*%?([\w.\-]+)\s*\).*direction=(\w+)"
)


def trip_counts(text: str) -> dict[str, int]:
    """cond-computation name -> trip count, via compare-against-constant."""
    counts: dict[str, int] = {}
    cur = None
    consts: dict[str, int] = {}
    trip = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            consts, trip = {}, None
            continue
        if line.startswith("}"):
            if cur and trip is not None:
                counts[cur] = trip
            cur = None
            continue
        if cur is None:
            continue
        cm = _CONST_LINE.search(line)
        if cm:
            consts[cm.group(1)] = int(cm.group(2))
        km = _CMP_LINE.search(line)
        if km:
            a, b, d = km.groups()
            val = consts.get(b, consts.get(a))
            if val is not None:
                trip = val + 1 if d in ("LE", "GE") else val
    return counts


def _dot_flops(ins: Instr, types: dict[str, str]) -> float:
    out_dims = _shape_dims(ins.type_str)
    out_n = 1
    for d in out_dims:
        out_n *= d
    cm = _CONTRACT.search(ins.attrs)
    contract = 1
    if cm and ins.operands:
        lhs_t = types.get(ins.operands[0], "")
        lhs_dims = _shape_dims(lhs_t)
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_n * contract


def _fusion_traffic(ins: Instr, called: Optional[Computation], types: dict) -> Cost:
    """HBM bytes for one fusion call, slice-aware.

    Scan carries/xs appear as huge fusion operands that are only touched via
    dynamic-(update-)slice inside the fused computation; charging the full
    buffer overcounts traffic ~trip-count-fold.  For each fusion parameter we
    charge the slice sizes actually read; a parameter that is the in-place
    target of a dynamic-update-slice is aliased and charges only the update.
    """
    res_b = _type_bytes(ins.type_str)
    if called is None:
        return Cost(0.0, res_b + sum(_type_bytes(types.get(o, "")) for o in ins.operands))

    # Parameters are NOT listed in index order inside the computation; XLA
    # names them param_<index>[.suffix], so recover the operand mapping from
    # the name (fallback: textual order).
    param_instrs = [p for p in called.instrs if p.opcode == "parameter"]
    param_names = [p.name for p in param_instrs]
    param_index: dict[str, int] = {}
    for pos, p in enumerate(param_instrs):
        m = re.match(r"param_(\d+)", p.name)
        param_index[p.name] = int(m.group(1)) if m else pos
    inner_types = dict(called.param_types)
    for ci in called.instrs:
        inner_types[ci.name] = ci.type_str
    uses: dict[str, list[tuple[str, int, str]]] = {p: [] for p in param_names}
    alias: dict[str, str] = {}   # bitcast/convert chains back to a parameter
    _ALIAS_OPS = ("bitcast", "reshape", "copy", "convert", "transpose")
    for ci in called.instrs:
        if ci.opcode in _ALIAS_OPS and ci.operands:
            src = alias.get(ci.operands[0], ci.operands[0])
            if src in uses:
                alias[ci.name] = src
                continue  # pure alias hop: not a real use of the parameter
        for pos, o in enumerate(ci.operands):
            src = alias.get(o, o)
            if src in uses:
                uses[src].append((ci.opcode, pos, ci.name))

    bytes_total = 0.0
    for p in param_names:
        i = param_index[p]
        full = _type_bytes(
            types.get(ins.operands[i], "") if i < len(ins.operands) else inner_types.get(p, "")
        ) or _type_bytes(inner_types.get(p, ""))
        ulist = uses.get(p, [])
        if ulist and all(
            (op_ == "dynamic-slice")
            or (op_ == "dynamic-update-slice" and pos == 0)
            for op_, pos, _ in ulist
        ):
            b = 0.0
            for op_, pos, uname in ulist:
                if op_ == "dynamic-slice":
                    b += _type_bytes(inner_types.get(uname, ""))
                else:                       # DUS target: aliased in place
                    du = next(
                        (c for c in called.instrs if c.name == uname), None
                    )
                    if du is not None and len(du.operands) > 1:
                        b += _type_bytes(inner_types.get(alias.get(du.operands[1], du.operands[1]), ""))
            bytes_total += b
        else:
            bytes_total += full

    # result: a DUS writing into a parameter aliases the output buffer (the
    # scan-carry in-place update pattern); charge updates, not the full stack
    dus_updates = [
        ci for ci in called.instrs
        if ci.opcode == "dynamic-update-slice"
        and ci.operands
        and alias.get(ci.operands[0], ci.operands[0]) in uses
    ]
    if dus_updates:
        for du in dus_updates:
            if len(du.operands) > 1:
                bytes_total += _type_bytes(
                    inner_types.get(alias.get(du.operands[1], du.operands[1]), "")
                )
    else:
        bytes_total += res_b
    return Cost(0.0, bytes_total)


def _tainted_comps(comps) -> set:
    """Computations that contain a vmem_tile tag anywhere: these are the
    bodies of flash-attention / SSD tile loops that a TPU deployment runs as
    one fused Pallas kernel.  XLA drops metadata on decomposed dots, so the
    tag is resolved at computation granularity."""
    out = set()
    for name, comp in comps.items():
        for ins in comp.instrs:
            if "vmem_tile" in ins.attrs:
                out.add(name)
                break
    return out


def analyze_text(text: str) -> Cost:
    comps = parse_module(text)
    trips = trip_counts(text)
    tainted = _tainted_comps(comps)
    memo: dict[str, Cost] = {}

    def comp_cost(name: str, depth=0) -> Cost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 50:
            return Cost()
        in_kernel = name in tainted
        types = dict(comp.param_types)
        for ins in comp.instrs:
            types[ins.name] = ins.type_str
        total = Cost()
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                body = cond = None
                for mm in re.finditer(r"(body|condition)=%?([\w.\-]+)", ins.attrs):
                    if mm.group(1) == "body":
                        body = mm.group(2)
                    else:
                        cond = mm.group(2)
                tm = _TRIP_RE.search(ins.attrs)   # XLA backend_config annotation
                t = int(tm.group(1)) if tm else trips.get(cond, 1)
                inner = comp_cost(body, depth + 1) + comp_cost(cond, depth + 1)
                total = total + inner * t
                continue
            if op in ("call", "conditional", "async-start"):
                for cn in _CALL_ATTR.findall(ins.attrs):
                    total = total + comp_cost(cn, depth + 1)
                continue
            # ops tagged vmem_tile run inside a fused TPU kernel (Pallas
            # flash-attention / SSD): tiles stay in VMEM -> no HBM traffic,
            # flops and collectives still count
            vmem = in_kernel or "vmem_tile" in ins.attrs
            if op == "fusion":
                called = None
                for cn in _CALL_ATTR.findall(ins.attrs):
                    called = cn
                if not vmem:
                    total = total + _fusion_traffic(ins, comps.get(called), types)
                if called:
                    total = total + Cost(comp_cost(called, depth + 1).flops, 0.0)
                continue
            if op in _FREE_OPS:
                continue
            if op in _COLL_OPS:
                kind = op.replace("-start", "")
                b = _type_bytes(ins.type_str)
                total = total + Cost(0.0, 0.0 if vmem else b, {kind: b})
                continue
            if op.endswith("-done"):
                continue
            res_b = 0 if vmem else _type_bytes(ins.type_str)
            if vmem:
                c = Cost(0.0, 0.0)
                if op in ("dot", "convolution"):
                    c.flops = _dot_flops(ins, types)
                total = total + c
                continue
            if op == "dynamic-update-slice":
                # in-place: read the update, write the slice (target aliased)
                upd_b = _type_bytes(types.get(ins.operands[1], "")) if len(ins.operands) > 1 else res_b
                total = total + Cost(0.0, 2.0 * upd_b)
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                total = total + Cost(0.0, 2.0 * res_b)   # read slice, write result
                continue
            if op == "scatter":
                upd_b = _type_bytes(types.get(ins.operands[-1], "")) if ins.operands else res_b
                total = total + Cost(0.0, 3.0 * upd_b)   # read+write region, read updates
                continue
            if op == "broadcast":
                total = total + Cost(0.0, res_b)
                continue
            # generic compute op: operands + result hit memory
            opb = sum(_type_bytes(types.get(o, "")) for o in ins.operands)
            c = Cost(0.0, opb + res_b)
            if op in ("dot", "convolution"):
                c.flops = _dot_flops(ins, types)
            total = total + c
        memo[name] = total
        return total

    # count fused-computation flops when called via fusion only (handled
    # above); entry cost covers everything reachable
    return comp_cost(comps["__entry__"].name)


def attribute(text: str, top: int = 25) -> list[dict]:
    """Per-instruction (bytes x trip) attribution -- the dry-run 'profiler'.

    Returns the top-N instructions by HBM traffic with their loop multiplier,
    used by the section-Perf hillclimb loop to find the dominant consumers.
    """
    comps = parse_module(text)
    tainted = _tainted_comps(comps)
    mult: dict[str, float] = {}

    def walk(name, m, depth=0):
        comp = comps.get(name)
        if comp is None or depth > 40:
            return
        mult[name] = max(mult.get(name, 0.0), m)
        for ins in comp.instrs:
            if ins.opcode == "while":
                body = None
                for mm in re.finditer(r"body=%?([\w.\-]+)", ins.attrs):
                    body = mm.group(1)
                tm = _TRIP_RE.search(ins.attrs)
                trip = int(tm.group(1)) if tm else 1
                if body:
                    walk(body, m * trip, depth + 1)
            elif ins.opcode in ("call", "conditional"):
                for cn in _CALL_ATTR.findall(ins.attrs):
                    walk(cn, m, depth + 1)

    walk(comps["__entry__"].name, 1.0)
    rows = []
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if not m:
            continue
        types = dict(comp.param_types)
        for ins in comp.instrs:
            types[ins.name] = ins.type_str
        for ins in comp.instrs:
            op = ins.opcode
            if op in _FREE_OPS or op in ("while", "call", "conditional") or op.endswith("-done"):
                continue
            res_b = _type_bytes(ins.type_str)
            if (cname in tainted or "vmem_tile" in ins.attrs) and op not in _COLL_OPS:
                continue
            if op == "fusion":
                called = None
                for cn in _CALL_ATTR.findall(ins.attrs):
                    called = cn
                b = _fusion_traffic(ins, comps.get(called), types).bytes
            elif op in _COLL_OPS:
                b = res_b
            elif op == "dynamic-update-slice":
                b = 2.0 * (_type_bytes(types.get(ins.operands[1], "")) if len(ins.operands) > 1 else res_b)
            elif op in ("dynamic-slice", "slice", "gather"):
                b = 2.0 * res_b
            elif op == "broadcast":
                b = res_b
            else:
                b = res_b + sum(_type_bytes(types.get(o, "")) for o in ins.operands)
            rows.append({
                "total_bytes": b * m, "bytes": b, "trip": m, "op": op,
                "comp": cname, "name": ins.name, "type": ins.type_str[:60],
                "is_coll": op in _COLL_OPS,
            })
    rows.sort(key=lambda r: -r["total_bytes"])
    return rows[:top]
