"""Roofline-term derivation from dry-run compiled artifacts."""
