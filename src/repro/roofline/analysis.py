"""Roofline-term derivation from compiled dry-run artifacts.

TPU v5e constants (targets; the container is CPU-only so terms are derived
from the compiled HLO, not measured):
    197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s/link ICI.

collective_bytes is NOT in cost_analysis, so we parse the post-SPMD
(per-device) HLO from ``compiled.as_text()`` and sum the result-buffer sizes
of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute.  Ring-cost factors: all-reduce moves ~2x its buffer per
device; the others ~1x.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# result type(s) precede "= <kind>(" in HLO text; shapes look like f32[4,8]{1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\(?[^=]*?\)?)\s*(" + "|".join(_COLL_KINDS) + r")(?:-start|-done)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict[str, int]:
    """Sum result-buffer bytes per collective kind (per-device module)."""
    out: dict[str, int] = {k: 0 for k in _COLL_KINDS}
    for m in _LINE_RE.finditer(hlo_text):
        kind = m.group(2)
        # async pairs appear as -start/-done; count the op once via -start,
        # plain ops have no suffix and are counted directly
        tail = hlo_text[m.end() - len(kind) - 10 : m.end()]
        if "-done(" in tail:
            continue
        out[kind] += _shape_bytes(m.group(1))
    return out


def wire_bytes(coll: dict[str, int]) -> float:
    """Effective per-device bytes on the wire (ring algorithm factors)."""
    return (
        2.0 * coll.get("all-reduce", 0)
        + 1.0 * coll.get("all-gather", 0)
        + 1.0 * coll.get("reduce-scatter", 0)
        + 1.0 * coll.get("all-to-all", 0)
        + 1.0 * coll.get("collective-permute", 0)
    )


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops (loop-aware)
    hbm_bytes: float             # per-device bytes accessed (loop-aware)
    coll_bytes: float            # per-device effective wire bytes
    collectives: dict[str, int]
    model_flops: float           # analytic 6*N*D (global)
    chips: int
    xla_cost: dict = dataclasses.field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO flops -- catches remat/padding waste."""
        total = self.flops * self.chips
        return (self.model_flops / total) if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful compute time / max(terms): how close the *useful* work is
        to the dominating hardware limit."""
        t_useful = self.model_flops / self.chips / PEAK_FLOPS
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return (t_useful / bound) if bound else 0.0

    def to_dict(self) -> dict[str, Any]:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "collectives": self.collectives,
            "model_flops_global": self.model_flops,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "xla_cost": self.xla_cost,
        }


def analyze(compiled, *, model_flops: float, chips: int) -> Roofline:
    """Derive roofline terms from the compiled per-device HLO.

    Primary source is the loop-aware analyzer in ``hlo_cost`` (XLA's own
    cost_analysis counts while bodies once -- useless for scanned layers);
    XLA numbers are kept in ``xla_cost`` as a cross-check.
    """
    from repro.roofline import hlo_cost

    c = hlo_cost.analyze_text(compiled.as_text())
    xla = compiled.cost_analysis() or {}
    r = Roofline(
        flops=c.flops,
        hbm_bytes=c.bytes,
        coll_bytes=wire_bytes(c.coll),
        collectives={k: int(v) for k, v in c.coll.items()},
        model_flops=model_flops,
        chips=chips,
    )
    r.xla_cost = {
        "flops": float(xla.get("flops", 0.0)),
        "bytes accessed": float(xla.get("bytes accessed", 0.0)),
    }
    return r


def train_model_flops(cfg, tokens: int) -> float:
    """6*N_active*D for one optimizer step over `tokens` tokens."""
    return 6.0 * cfg.active_param_count() * tokens


def decode_model_flops(cfg, batch: int) -> float:
    """2*N_active per generated token (fwd only), plus attention reads are
    counted via the memory term."""
    return 2.0 * cfg.active_param_count() * batch


def sharded_bytes_per_device(shape_tree, spec_tree, mesh) -> float:
    """Per-device bytes of a ShapeDtypeStruct pytree under PartitionSpecs."""
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    leaves = jax.tree.leaves(shape_tree)
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, PartitionSpec))
    total = 0.0
    for leaf, spec in zip(leaves, specs):
        shards = 1
        for ax in tuple(spec):
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                if a is not None:
                    shards *= sizes.get(a, 1)
        total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize / shards
    return total


def decode_floor_fraction(ideal_bytes_dev: float, rl: "Roofline") -> float:
    """Decode is bandwidth-bound by construction: the floor is reading the
    sharded params + KV cache once per token.  Fraction = floor time over the
    dominating measured term."""
    t_floor = ideal_bytes_dev / HBM_BW
    bound = max(rl.t_compute, rl.t_memory, rl.t_collective)
    return (t_floor / bound) if bound else 0.0


def memory_analysis_dict(compiled) -> dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out
