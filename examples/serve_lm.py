"""Serving example: batched prefill + decode with dense vs SZx-compressed KV.

    PYTHONPATH=src python examples/serve_lm.py --tokens 32 --batch 4
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        configs.get("llama3.2-1b").reduced(),
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=4096,
    )
    params = T.init_params(cfg, jax.random.key(0))
    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt), 0, cfg.vocab_size
    )
    max_len = args.prompt + args.tokens

    for kv_mode in ("dense", "compressed"):
        dec = jax.jit(
            lambda p, c, t, kv=kv_mode: engine.decode_step(p, cfg, c, t, kv_mode=kv)
        )
        cache, logits = engine.prefill(
            params, cfg, prompts, seq_len=max_len, kv_mode=kv_mode
        )
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out = [tok]
        logits, cache = dec(params, cache, tok)       # compile
        t0 = time.time()
        for _ in range(args.tokens - 1):
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
            logits, cache = dec(params, cache, tok)
        jax.block_until_ready(logits)
        dt = time.time() - t0
        total = args.batch * (args.tokens - 1)
        cache_bytes = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)
        )
        print(
            f"kv={kv_mode:10s}: {total/dt:7.1f} tok/s  "
            f"cache={cache_bytes/1e6:6.1f} MB  "
            f"first tokens={[int(t[0,0]) for t in out[:6]]}"
        )


if __name__ == "__main__":
    main()
