"""End-to-end training driver: ~100M-param llama-family model, a few hundred
steps on CPU, with SZx-compressed checkpointing and fault-tolerant restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --d-model 512

The model is the llama3.2-1b config family scaled to ~100M params; data is
the deterministic synthetic pipeline; checkpoints go to /tmp and the loop
demonstrates restart-from-checkpoint by re-invoking run().
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.api import Bound
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SteppedBatches, StoreLM, SyntheticLM
from repro.models import transformer as T
from repro.optim import AdamW, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--data-store", default=None,
                    help="train from a compressed ArrayStore corpus "
                         "(path / manifest / service URL) instead of the "
                         "synthetic stream")
    ap.add_argument("--data-workers", type=int, default=2)
    args = ap.parse_args()

    base = configs.get("llama3.2-1b")
    cfg = dataclasses.replace(
        base,
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=8,
        n_kv_heads=4,
        head_dim=args.d_model // 8,
        d_ff=args.d_model * 4,
        vocab_size=8192,
        compute_dtype="float32",
        remat=False,
    )
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    opt = AdamW(lr=warmup_cosine(3e-4, 20, args.steps))
    params = T.init_params(cfg, jax.random.key(0))
    state = {"params": params, "opt": opt.init(params)}

    @jax.jit
    def step_fn(state, batch):
        loss, grads = jax.value_and_grad(lambda p: T.loss_fn(p, cfg, batch))(
            state["params"]
        )
        p, o, m = opt.update(grads, state["opt"], state["params"])
        return {"params": p, "opt": o}, {"loss": loss, **m}

    if args.data_store:
        ds = StoreLM(
            args.data_store, DataConfig(cfg.vocab_size, args.seq, args.batch),
            workers=args.data_workers,
        )
        src = SteppedBatches(lambda s: ds.batches(start_step=s))
    else:
        ds = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
        src = ds.batch_at
    batch_fn = lambda step: {  # noqa: E731
        k: jnp.asarray(v) for k, v in src(step).items()
    }

    ckpt = CheckpointManager(args.ckpt, keep=2, compress=True, bound=Bound.rel(1e-6))
    tr = Trainer(
        TrainerConfig(total_steps=args.steps, checkpoint_every=50, log_every=20),
        step_fn, batch_fn, ckpt,
    )
    state = tr.run(state)
    first, last = tr.history[0]["loss"], tr.history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} over {len(tr.history)} steps "
          f"({tr.restarts} restarts, {len(tr.straggler_steps)} straggler steps)")
    print(f"checkpoint stats: {ckpt.stats()}")
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
