"""Quickstart: SZx error-bounded compression of a scientific field.

    PYTHONPATH=src python examples/quickstart.py

Shows the classic float32 byte-stream API (repro.core.szx, unchanged), the
layered codec front-end (repro.core.codec.SZxCodec): native multi-dtype
streams and bounded-memory chunked compression, and the block-addressable
array store (repro.store): lazy ROI reads + compressed-domain queries.
"""
import io
import time

import numpy as np

from repro.core import metrics, szx
from repro.core.codec import SZxCodec
from repro.data import scidata
from repro.store import ArrayStore


def main():
    name, x = next(iter(scidata.fields("Miranda")))
    print(f"field {name}: shape={x.shape} ({x.nbytes/1e6:.1f} MB)")

    for rel in (1e-2, 1e-3, 1e-4):
        t0 = time.time()
        buf, stats = szx.compress_with_stats(x, rel, mode="rel", backend="numpy")
        t_c = time.time() - t0
        t0 = time.time()
        y = szx.decompress(buf, backend="numpy").reshape(x.shape)
        t_d = time.time() - t0
        err = np.abs(x - y).max()
        print(
            f"REL={rel:g}: CR={stats.ratio:6.2f}  "
            f"comp={x.nbytes/1e6/t_c:5.0f} MB/s  decomp={x.nbytes/1e6/t_d:5.0f} MB/s  "
            f"PSNR={metrics.psnr(x, y):5.1f} dB  max|err|/e={err/stats.error_bound:.3f}"
        )
        assert err <= stats.error_bound, "error bound violated!"
    print("error bound strictly respected at every setting")

    # --- layered codec: multi-dtype + chunked streaming ------------------
    codec = SZxCodec(backend="numpy")
    for dtype in (np.float64, np.float16):
        xd = x.astype(dtype)
        buf = codec.compress(xd, 1e-2, mode="rel")
        y = codec.decompress(buf)
        print(
            f"native {np.dtype(dtype).name}: CR={xd.nbytes/len(buf):5.2f}  "
            f"decoded dtype={y.dtype}"
        )
    sink = io.BytesIO()
    written = codec.dump_chunked(x, sink, 1e-3, mode="rel", chunk_bytes=1 << 20)
    sink.seek(0)
    y = codec.load_chunked(sink).reshape(x.shape)
    e = 1e-3 * float(x.max() - x.min())
    print(
        f"chunked: {written/1e6:.1f} MB in 1 MB self-delimiting frames, "
        f"max|err|/e={np.abs(x - y).max() / e:.3f}"
    )
    assert np.abs(x - y).max() <= e, "chunked error bound violated!"

    # --- array store: lazy ROI reads + compressed-domain queries ----------
    store = io.BytesIO()
    ArrayStore.save(store, x, 1e-3, mode="rel")
    ca = ArrayStore.open(store)
    t0 = time.time()
    roi = ca[x.shape[0] // 2, : x.shape[1] // 2]       # one half-plane slice
    t_roi = time.time() - t0
    assert np.abs(roi - x[x.shape[0] // 2, : x.shape[1] // 2]).max() <= e
    stats = ca.stats()                                  # exact, from headers
    hdr = ca.stats(header_only=True)                    # intervals, no planes
    print(
        f"store: {ca.nchunks} chunks of {ca.chunk_shape}, ROI {roi.nbytes/1e3:.0f} kB "
        f"in {t_roi*1e3:.1f} ms; query mean={stats.mean[0]:.4f} "
        f"(numpy {float(np.mean(x, dtype=np.float64)):.4f}), "
        f"{hdr.const_blocks}/{hdr.nblocks} blocks answered header-only"
    )
    assert abs(stats.mean[0] - float(np.mean(x, dtype=np.float64))) <= e


if __name__ == "__main__":
    main()
