"""Quickstart: SZx error-bounded compression of a scientific field.

    PYTHONPATH=src python examples/quickstart.py

Shows the public repro.api surface: the one-shot functional API with the
unified Bound spec, the layered codec front-end (repro.api.SZxCodec):
native multi-dtype streams and bounded-memory chunked compression, and the
block-addressable array store (repro.api.ArrayStore): lazy ROI reads +
compressed-domain queries.
"""
import io
import time

import numpy as np

from repro.api import ArrayStore, Bound, SZxCodec, compress_with_stats, decompress
from repro.core import metrics
from repro.data import scidata


def main():
    name, x = next(iter(scidata.fields("Miranda")))
    print(f"field {name}: shape={x.shape} ({x.nbytes/1e6:.1f} MB)")

    for rel in (1e-2, 1e-3, 1e-4):
        t0 = time.time()
        buf, stats = compress_with_stats(x, Bound.rel(rel), backend="numpy")
        t_c = time.time() - t0
        t0 = time.time()
        y = decompress(buf, backend="numpy").reshape(x.shape)
        t_d = time.time() - t0
        err = np.abs(x - y).max()
        print(
            f"REL={rel:g}: CR={stats.ratio:6.2f}  "
            f"comp={x.nbytes/1e6/t_c:5.0f} MB/s  decomp={x.nbytes/1e6/t_d:5.0f} MB/s  "
            f"PSNR={metrics.psnr(x, y):5.1f} dB  max|err|/e={err/stats.error_bound:.3f}"
        )
        assert err <= stats.error_bound, "error bound violated!"
    print("error bound strictly respected at every setting")

    # --- layered codec: multi-dtype + chunked streaming ------------------
    codec = SZxCodec(backend="numpy")
    for dtype in (np.float64, np.float16):
        xd = x.astype(dtype)
        buf = codec.compress(xd, Bound.rel(1e-2))
        y = codec.decompress(buf)
        print(
            f"native {np.dtype(dtype).name}: CR={xd.nbytes/len(buf):5.2f}  "
            f"decoded dtype={y.dtype}"
        )
    sink = io.BytesIO()
    written = codec.dump_chunked(x, sink, Bound.rel(1e-3), chunk_bytes=1 << 20)
    sink.seek(0)
    y = codec.load_chunked(sink).reshape(x.shape)
    e = 1e-3 * float(x.max() - x.min())
    print(
        f"chunked: {written/1e6:.1f} MB in 1 MB self-delimiting frames, "
        f"max|err|/e={np.abs(x - y).max() / e:.3f}"
    )
    assert np.abs(x - y).max() <= e, "chunked error bound violated!"

    # --- array store: lazy ROI reads + compressed-domain queries ----------
    store = io.BytesIO()
    ArrayStore.save(store, x, Bound.rel(1e-3))
    ca = ArrayStore.open(store)
    t0 = time.time()
    roi = ca[x.shape[0] // 2, : x.shape[1] // 2]       # one half-plane slice
    t_roi = time.time() - t0
    assert np.abs(roi - x[x.shape[0] // 2, : x.shape[1] // 2]).max() <= e
    stats = ca.stats()                                  # exact, from headers
    hdr = ca.stats(header_only=True)                    # intervals, no planes
    print(
        f"store: {ca.nchunks} chunks of {ca.chunk_shape}, ROI {roi.nbytes/1e3:.0f} kB "
        f"in {t_roi*1e3:.1f} ms; query mean={stats.mean[0]:.4f} "
        f"(numpy {float(np.mean(x, dtype=np.float64)):.4f}), "
        f"{hdr.const_blocks}/{hdr.nblocks} blocks answered header-only"
    )
    assert abs(stats.mean[0] - float(np.mean(x, dtype=np.float64))) <= e


if __name__ == "__main__":
    main()
