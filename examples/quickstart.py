"""Quickstart: SZx error-bounded compression of a scientific field.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import metrics, szx
from repro.data import scidata


def main():
    name, x = next(iter(scidata.fields("Miranda")))
    print(f"field {name}: shape={x.shape} ({x.nbytes/1e6:.1f} MB)")

    for rel in (1e-2, 1e-3, 1e-4):
        t0 = time.time()
        buf, stats = szx.compress_with_stats(x, rel, mode="rel", backend="numpy")
        t_c = time.time() - t0
        t0 = time.time()
        y = szx.decompress(buf, backend="numpy").reshape(x.shape)
        t_d = time.time() - t0
        err = np.abs(x - y).max()
        print(
            f"REL={rel:g}: CR={stats.ratio:6.2f}  "
            f"comp={x.nbytes/1e6/t_c:5.0f} MB/s  decomp={x.nbytes/1e6/t_d:5.0f} MB/s  "
            f"PSNR={metrics.psnr(x, y):5.1f} dB  max|err|/e={err/stats.error_bound:.3f}"
        )
        assert err <= stats.error_bound, "error bound violated!"
    print("error bound strictly respected at every setting")


if __name__ == "__main__":
    main()
