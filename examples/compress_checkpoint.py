"""Checkpoint compression demo (the paper's Fig. 13 dump/load use case at
framework level): save a model state raw vs SZx-compressed, compare size and
verify the error bound end-to-end.

    PYTHONPATH=src python examples/compress_checkpoint.py
"""
import dataclasses
import shutil
import time

import jax
import numpy as np

from repro import configs
from repro.api import Bound
from repro.checkpoint import CheckpointManager
from repro.models import transformer as T


def main():
    cfg = dataclasses.replace(
        configs.get("llama3.2-1b").reduced(),
        n_layers=4, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=16384,
    )
    params = T.init_params(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"state: {n/1e6:.1f}M params ({4*n/1e6:.0f} MB fp32)")

    for compress, tag in ((False, "raw"), (True, "szx(rel 1e-5)")):
        root = f"/tmp/repro_ckpt_{int(compress)}"
        shutil.rmtree(root, ignore_errors=True)
        m = CheckpointManager(root, compress=compress, bound=Bound.rel(1e-5))
        t0 = time.time()
        m.save(0, params)
        dt = time.time() - t0
        st = m.stats()
        restored, _ = m.restore(params)
        worst = 0.0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
            rng = a.max() - a.min()
            if rng > 0:
                worst = max(worst, float(np.abs(a - b).max() / rng))
        print(
            f"{tag:16s}: {st['stored_bytes']/1e6:7.1f} MB  ratio={st['ratio']:5.2f}  "
            f"save={dt:5.2f}s  worst rel err={worst:.2e}"
        )


if __name__ == "__main__":
    main()
